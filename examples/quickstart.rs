//! Quickstart: the DistCA public API in one page.
//!
//! Samples a long-context batch, packs it, runs the communication-aware
//! greedy scheduler (§4.2), and prints the resulting attention-server
//! plan — then simulates one training iteration under every strategy to
//! show the headline comparison.
//!
//! Run: `cargo run --release --example quickstart`

use distca::config::{run::DataDist, ClusterConfig, ModelConfig};
use distca::coordinator::scheduler::items_from_chunks;
use distca::coordinator::{schedule, Profiler, SchedulerCfg};
use distca::data::distributions::sampler_for;
use distca::model::FlopsModel;
use distca::sim::strategies::{
    run_distca, run_packed_dp, run_perdoc_cp, run_wlb_ideal, SimParams,
};
use distca::util::rng::Rng;
use distca::util::tables::{bytes, f, secs, Table};

fn main() {
    // ----- 1. a long-context training batch ------------------------------
    let model = ModelConfig::llama3_8b();
    let cluster = ClusterConfig::h200(4); // 32 GPUs = 4 logical devices @ TP=8
    let max_doc = 128 * 1024;
    let mut rng = Rng::new(0xD15C);
    let docs = sampler_for(DataDist::Pretrain, max_doc).sample_tokens(
        &mut rng,
        4 * max_doc, // 4 chunks of 128K tokens
        0,
    );
    println!(
        "sampled {} documents, {} tokens (longest {})\n",
        docs.len(),
        docs.iter().map(|d| d.len).sum::<usize>(),
        docs.iter().map(|d| d.len).max().unwrap()
    );

    // ----- 2. schedule CA-tasks over in-place attention servers ----------
    let f_model = FlopsModel::new(&model);
    let prof = Profiler::analytic(&f_model, &cluster);
    let chunks = distca::sim::strategies::distca_placement(&docs, 4);
    let items = items_from_chunks(&chunks);
    let plan = schedule(
        &items,
        4,
        &f_model,
        &prof,
        &model,
        &SchedulerCfg { tolerance: 0.10, ..Default::default() },
    );

    let mut t = Table::new(
        "attention-server plan (one layer, forward)",
        &["server", "CA load (est)", "vs target", "dispatch out", "dispatch in"],
    );
    for s in 0..plan.n_servers {
        let out: f64 = plan.comm_matrix[s].iter().sum();
        let inc: f64 = (0..plan.n_servers).map(|o| plan.comm_matrix[o][s]).sum();
        t.row(&[
            s.to_string(),
            secs(plan.server_load[s]),
            format!("{:+.1}%", (plan.server_load[s] / plan.target_load - 1.0) * 100.0),
            bytes(out),
            bytes(inc),
        ]);
    }
    t.print();
    println!(
        "imbalance {:.3} | {} items ({} migrated) | total dispatch {}\n",
        plan.imbalance(),
        plan.assignments.len(),
        plan.assignments.iter().filter(|a| !a.is_local()).count(),
        bytes(plan.total_comm_bytes()),
    );

    // ----- 3. one simulated iteration under each strategy ----------------
    let params = SimParams::new(model, cluster, 8, 1);
    let reports = vec![
        run_packed_dp(&docs, max_doc, &params),
        run_perdoc_cp(&docs, max_doc, 4, &params),
        run_wlb_ideal(&docs, max_doc, &params),
        run_distca(&docs, max_doc, &params),
    ];
    let mut t = Table::new(
        "one training iteration, 32 H200 GPUs (simulated)",
        &["strategy", "config", "iter time", "tok/s", "idle%", "mem div", "comm"],
    );
    for r in &reports {
        t.row(&[
            r.strategy.clone(),
            r.config.clone(),
            secs(r.iter_time),
            format!("{:.3e}", r.throughput()),
            f(r.idle_fraction() * 100.0, 1),
            f(r.memory_divergence(), 2),
            bytes(r.comm_bytes),
        ]);
    }
    t.print();
    let wlb = &reports[2];
    let ca = &reports[3];
    println!(
        "DistCA speedup over WLB-ideal: {:.2}x (paper reports 1.05-1.35x depending on scale)",
        wlb.iter_time / ca.iter_time
    );
}
