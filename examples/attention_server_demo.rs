//! Disaggregation on the real runtime: a packed batch's core attention is
//! partitioned into CA-tasks by the §4.2 scheduler, dispatched to N
//! attention-server worker threads (each owning a compiled Pallas-CA
//! executable), gathered, and compared against the monolithic kernel
//! output — the numbers must match to float tolerance.
//!
//! Run: `make artifacts && cargo run --release --example attention_server_demo`

use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::{schedule, Item, Profiler, SchedulerCfg};
use distca::model::FlopsModel;
use distca::runtime::ca_exec::{synthetic_task, CaExecutor, CaTaskTensors};
use distca::runtime::{artifacts_available, artifacts_dir, Runtime};
use distca::server::{run_disaggregated, DispatchedTask};
use distca::util::rng::Rng;
use distca::util::tables::{secs, Table};

const H: usize = 12;
const HKV: usize = 12;
const D: usize = 64;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        anyhow::bail!("run `make artifacts` first");
    }
    let dir = artifacts_dir();
    let n_servers = 2usize;

    // --- the workload: 2 documents, one long (skewed), homes 0 and 1 ----
    let mut rng = Rng::new(99);
    let docs: Vec<(u32, usize, usize)> = vec![
        (0, 512, 0), // (doc id, len, home device) — the heavy doc
        (1, 128, 1),
    ];
    // Tensors per document (Q/K/V as the pre-CA layers would produce).
    let tensors: Vec<CaTaskTensors> = docs
        .iter()
        .map(|&(_, len, _)| synthetic_task(&mut rng, len, len, H, HKV, D))
        .collect();

    // --- schedule: balance CA across the two in-place servers -----------
    let model = ModelConfig::tiny_100m();
    let f = FlopsModel::new(&model);
    let prof = Profiler::analytic(&f, &ClusterConfig::h200(1));
    let items: Vec<Item> = docs
        .iter()
        .map(|&(id, len, home)| Item::whole_doc(id, len, home))
        .collect();
    let plan = schedule(
        &items,
        n_servers,
        &f,
        &prof,
        &model,
        &SchedulerCfg { tolerance: 0.05, ..Default::default() },
    );
    let mut t = Table::new("scheduler plan", &["doc", "q range", "home", "server"]);
    for a in &plan.assignments {
        for task in a.item.ca_tasks() {
            t.row(&[
                task.doc.to_string(),
                format!("[{}, {})", task.q_start, task.q_start + task.q_len),
                task.home.to_string(),
                a.server.to_string(),
            ]);
        }
    }
    t.print();
    println!("imbalance: {:.3}\n", plan.imbalance());

    // --- build the dispatch: slice each doc's tensors per CA-task -------
    let q_row = H * D;
    let kv_row = HKV * D;
    let mut dispatched = Vec::new();
    for a in &plan.assignments {
        let (_, len, _) = docs[a.item.doc as usize];
        let full = &tensors[a.item.doc as usize];
        for task in a.item.ca_tasks() {
            let q = full.q[task.q_start * q_row..(task.q_start + task.q_len) * q_row].to_vec();
            let k = full.k[..task.kv_len * kv_row].to_vec();
            let v = full.v[..task.kv_len * kv_row].to_vec();
            assert!(task.kv_len <= len);
            dispatched.push(DispatchedTask {
                doc: task.doc,
                q_start: task.q_start,
                server: a.server,
                home: task.home,
                tensors: CaTaskTensors { q, k, v, q_len: task.q_len, kv_len: task.kv_len },
            });
        }
    }
    println!(
        "dispatching {} CA-tasks to {n_servers} attention servers...",
        dispatched.len()
    );
    let t0 = std::time::Instant::now();
    let outputs = run_disaggregated(&dir, n_servers, dispatched, 1024, 2048, H, HKV, D)?;
    let dis_time = t0.elapsed().as_secs_f64();

    // --- monolithic baseline: each doc in one kernel call on one device --
    let rt = Runtime::cpu()?;
    let exec = CaExecutor::load(&rt, &dir, 1024, 2048, H, HKV, D)?;
    let t0 = std::time::Instant::now();
    let mono = exec.run_batch(&rt, &tensors)?;
    let mono_time = t0.elapsed().as_secs_f64();

    // --- reassemble + compare -------------------------------------------
    let mut max_diff = 0f32;
    for out in &outputs {
        let (_, len, _) = docs[out.doc as usize];
        let whole = &mono[out.doc as usize];
        assert!(out.q_start + out.o.len() / q_row <= len);
        let base = out.q_start * q_row;
        for (i, x) in out.o.iter().enumerate() {
            max_diff = max_diff.max((x - whole[base + i]).abs());
        }
    }
    println!(
        "disaggregated {} vs monolithic {} | max |Δ| = {max_diff:.2e}",
        secs(dis_time),
        secs(mono_time)
    );
    anyhow::ensure!(max_diff < 1e-4, "disaggregated output diverged");
    println!("attention_server_demo OK: disaggregated CA is numerically identical");
    Ok(())
}
