//! Cluster-scale sweep: the headline experiment (Figs. 9/10 condensed) on
//! the simulator — DistCA vs WLB-ideal across models, context lengths and
//! GPU counts, averaged over sampled batches.
//!
//! Run: `cargo run --release --example cluster_sweep [n_batches]`

use distca::config::{run::DataDist, ClusterConfig, ModelConfig};
use distca::data::distributions::sampler_for;
use distca::metrics::{comparison_table, ComparisonRow};
use distca::sim::strategies::{run_distca, run_wlb_ideal, SimParams};
use distca::sim::IterationReport;
use distca::util::rng::Rng;

fn main() {
    let n_batches: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(5);

    let grid: &[(&str, usize, usize)] = &[
        ("llama-8b", 128 * 1024, 64),
        ("llama-8b", 256 * 1024, 128),
        ("llama-8b", 512 * 1024, 256),
        ("llama-34b", 128 * 1024, 64),
        ("llama-34b", 256 * 1024, 128),
        ("llama-34b", 512 * 1024, 256),
    ];

    for dist in [DataDist::Pretrain, DataDist::ProLong] {
        let mut rows = Vec::new();
        for &(model_name, max_doc, n_gpus) in grid {
            let model = ModelConfig::by_name(model_name).unwrap();
            let cluster = ClusterConfig::h200(n_gpus / 8);
            let params = SimParams::new(model, cluster, 8, 1);
            let batch_tokens = (n_gpus / 8) * max_doc.min(131_072) * 2;
            let mut wlb_reports = Vec::new();
            let mut ca_reports = Vec::new();
            for b in 0..n_batches {
                let mut rng = Rng::new(0xFEEDu64 + b as u64 * 7919 + max_doc as u64);
                let docs =
                    sampler_for(dist, max_doc).sample_tokens(&mut rng, batch_tokens, 0);
                wlb_reports.push(run_wlb_ideal(&docs, max_doc, &params));
                ca_reports.push(run_distca(&docs, max_doc, &params));
            }
            rows.push(ComparisonRow {
                model: model_name.into(),
                max_doc_len: max_doc,
                n_gpus,
                dataset: dist.name().into(),
                baseline: IterationReport::average(&wlb_reports),
                distca: IterationReport::average(&ca_reports),
            });
        }
        comparison_table(
            &format!("DistCA vs WLB-ideal — {} (avg of {n_batches} batches)", dist.name()),
            &rows,
        )
        .print();
        let speedups: Vec<f64> = rows.iter().map(|r| r.speedup()).collect();
        println!(
            "speedup range: {:.2}x - {:.2}x (paper: 1.05-1.35x)\n",
            speedups.iter().cloned().fold(f64::INFINITY, f64::min),
            speedups.iter().cloned().fold(0.0, f64::max)
        );
    }
}
