//! Elastic ping-pong pipeline parallelism on the real threaded runtime:
//! a scheduled batch is split into two nano-batch waves (ping/pong), a
//! server is **killed mid-PP-tick** — after the ping wave shipped, with
//! the pong wave still pending — and the coordinator recovers
//! wave-scoped: only the ping wave's in-flight CA-tasks are cancelled
//! and re-dispatched, the pong wave is re-planned against the fresh
//! membership epoch before any bytes move, and the assembled output
//! still matches the monolithic oracle **bit-for-bit**.
//!
//! Uses the pure-Rust reference CA kernel, so it runs on a bare checkout
//! (no AOT artifacts needed):
//! `cargo run --release --example elastic_pp_demo`

use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::{schedule, Item, Profiler, SchedulerCfg};
use distca::elastic::{
    ElasticCfg, ElasticCoordinator, ElasticTask, FaultPlan, ReferenceCaCompute,
};
use distca::model::FlopsModel;
use distca::runtime::ca_exec::{synthetic_task, CaTaskTensors};
use distca::util::rng::{seed_from_env, Rng};
use distca::util::tables::{secs, Table};

const H: usize = 4;
const HKV: usize = 2;
const D: usize = 16;
const N_SERVERS: usize = 4;

fn main() -> anyhow::Result<()> {
    let seed = seed_from_env(101);
    let mut rng = Rng::new(seed);

    // --- the workload: skewed documents homed across the pool ----------
    let docs: Vec<(u32, usize, usize)> = vec![
        (0, 512, 0), // (doc id, len, home) — the heavy doc
        (1, 256, 1),
        (2, 256, 2),
        (3, 128, 3),
        (4, 128, 1),
        (5, 256, 2),
    ];
    let tensors: Vec<CaTaskTensors> = docs
        .iter()
        .map(|&(_, len, _)| synthetic_task(&mut rng, len, len, H, HKV, D))
        .collect();

    // --- schedule CA across the pool (the normal §4.2 path) ------------
    let model = ModelConfig::tiny_100m();
    let f = FlopsModel::new(&model);
    let prof = Profiler::analytic(&f, &ClusterConfig::h200(1));
    let items: Vec<Item> = docs
        .iter()
        .map(|&(id, len, home)| Item::whole_doc(id, len, home))
        .collect();
    let plan = schedule(
        &items,
        N_SERVERS,
        &f,
        &prof,
        &model,
        &SchedulerCfg { tolerance: 0.05, ..Default::default() },
    );

    // --- carve per-CA-task tensors --------------------------------------
    let q_row = H * D;
    let kv_row = HKV * D;
    let mut tasks = Vec::new();
    for a in &plan.assignments {
        let full = &tensors[a.item.doc as usize];
        for task in a.item.ca_tasks() {
            tasks.push(ElasticTask {
                doc: task.doc,
                q_start: task.q_start,
                server: a.server,
                home: task.home,
                tensors: CaTaskTensors {
                    q: full.q[task.q_start * q_row..(task.q_start + task.q_len) * q_row]
                        .to_vec(),
                    k: full.k[..task.kv_len * kv_row].to_vec(),
                    v: full.v[..task.kv_len * kv_row].to_vec(),
                    q_len: task.q_len,
                    kv_len: task.kv_len,
                },
            });
        }
    }

    // Kill the most-loaded server mid-PP-tick.
    let victim = tasks
        .iter()
        .map(|t| t.server)
        .max_by_key(|&s| tasks.iter().filter(|t| t.server == s).count())
        .unwrap();
    let fault = FaultPlan::new().kill(victim, 0);
    println!(
        "dispatching {} CA-tasks to {N_SERVERS} servers as one PP tick (ping + pong waves);\n\
         fault plan: [{}] — the kill lands between the waves\n",
        tasks.len(),
        fault.to_spec()
    );

    // --- elastic PP tick: kill mid-tick, recover wave-scoped ------------
    let mut co = ElasticCoordinator::spawn(N_SERVERS, ElasticCfg::default(), |_| {
        Box::new(ReferenceCaCompute::new(H, HKV, D))
    });
    let t0 = std::time::Instant::now();
    let outputs = co.run_pp_tick(0, &tasks, &fault)?;
    let elapsed = t0.elapsed().as_secs_f64();
    anyhow::ensure!(
        !co.pool.is_schedulable(victim),
        "victim should be out of the pool"
    );
    let stats = co.shutdown()?;
    let st = &stats[0];

    // --- monolithic oracle: every document in one call ------------------
    let oracle = ReferenceCaCompute::new(H, HKV, D);
    let mono = oracle.run_batch(&tensors);

    // --- reassemble + compare, bitwise ----------------------------------
    anyhow::ensure!(outputs.len() == tasks.len(), "incomplete gather");
    let mut compared = 0usize;
    for out in &outputs {
        let whole = &mono[out.doc as usize];
        let base = out.q_start * q_row;
        for (i, &x) in out.o.iter().enumerate() {
            anyhow::ensure!(
                x.to_bits() == whole[base + i].to_bits(),
                "doc {} row-offset {}: {} != {}",
                out.doc,
                out.q_start,
                x,
                whole[base + i]
            );
            compared += 1;
        }
    }

    let mut t = Table::new("elastic PP recovery", &["metric", "value"]);
    t.row(&["tasks dispatched".into(), st.n_tasks.to_string()]);
    t.row(&["killed server".into(), victim.to_string()]);
    t.row(&["epoch ping/pong".into(), format!("{}/{}", st.wave_epochs[0], st.wave_epochs[1])]);
    t.row(&["ping re-dispatched".into(), st.wave_redispatched[0].to_string()]);
    t.row(&["pong re-dispatched".into(), st.wave_redispatched[1].to_string()]);
    t.row(&["pong remapped".into(), st.remapped.to_string()]);
    t.row(&["cancels sent".into(), st.cancels_sent.to_string()]);
    t.row(&["duplicates suppressed".into(), st.duplicates_suppressed.to_string()]);
    t.row(&["tick wall time".into(), secs(elapsed)]);
    t.row(&["values compared".into(), compared.to_string()]);
    t.print();
    anyhow::ensure!(
        st.wave_epochs[1] > st.wave_epochs[0],
        "the kill must bump the membership epoch between the waves"
    );
    anyhow::ensure!(
        st.redispatched + st.remapped > 0,
        "the kill must have cost something"
    );
    println!(
        "\nelastic_pp_demo OK: server {victim} died mid-PP-tick; {} ping-wave CA-tasks were\n\
         re-dispatched, {} pong-wave tasks were re-planned under the new membership epoch,\n\
         and every output value is bit-identical to the monolithic kernel.",
        st.wave_redispatched[0],
        st.remapped
    );
    Ok(())
}
