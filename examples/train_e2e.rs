//! End-to-end validation (DESIGN.md §5): train the ~106M-parameter tiny
//! LM for a few hundred steps on a synthetic Markov corpus, entirely from
//! rust via the AOT train-step artifact. Proves L1 (Pallas CA kernel
//! inside the step) → L2 (JAX fwd+bwd+AdamW) → L3 (this driver) compose
//! with Python off the request path.
//!
//! Run: `make artifacts && cargo run --release --example train_e2e [steps]`
//!
//! The corpus is a first-order Markov chain (90% deterministic successor)
//! so the loss has a known floor (~1.4 nats) far below the uniform start
//! (ln 32000 ≈ 10.37): the curve must fall decisively from 10.4 toward
//! the floor for the run to count. EXPERIMENTS.md records the curve.

use distca::runtime::train::{MarkovCorpus, TrainDriver};
use distca::runtime::{artifacts_available, artifacts_dir};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    if !artifacts_available() {
        anyhow::bail!(
            "artifacts not found in {:?} — run `make artifacts` first",
            artifacts_dir()
        );
    }

    println!("loading AOT train step from {:?} ...", artifacts_dir());
    let t0 = std::time::Instant::now();
    let driver = TrainDriver::load(&artifacts_dir())?;
    println!(
        "compiled in {:.1}s | params: {} (~{:.0}M)",
        t0.elapsed().as_secs_f64(),
        driver.n_params(),
        driver.n_params() as f64 / 1e6
    );

    // Restrict the corpus to 2048 active token ids (of the model's 32000):
    // the Markov successor table is a permutation, so with the full vocab
    // even the unigram floor equals the uniform start and nothing is
    // learnable in a short run. With 2048 active ids the model first
    // learns the support (10.37 -> ~7.6 nats) and then the bigram
    // structure (floor ~1.9 within the active set).
    let corpus = MarkovCorpus::new(2048, 0.9, 42);
    println!(
        "corpus: 2048 active ids of vocab 32000, Markov p=0.9, floor {:.3} nats; uniform = {:.3}",
        corpus.entropy_floor(),
        (32_000f64).ln()
    );
    println!("training {steps} steps x 512 tokens ...");

    let report = driver.train(&corpus, steps, 7, |s, loss| {
        if s % 10 == 0 || s + 1 == steps {
            println!("step {s:>4}  loss {loss:.4}");
        }
    })?;

    println!("\n=== loss curve (every 10th step) ===");
    let curve: Vec<String> = report
        .losses
        .iter()
        .step_by(10)
        .map(|l| format!("{l:.3}"))
        .collect();
    println!("{}", curve.join(" "));
    println!(
        "\nfirst {:.4} -> last {:.4} (floor {:.3}) | {:.2}s/step | {:.0} tok/s",
        report.first_loss(),
        report.last_loss(),
        report.entropy_floor,
        report.secs_per_step,
        report.tokens_per_step as f64 / report.secs_per_step
    );
    // Expected descent scales with run length (~0.04 nats/step early on,
    // saturating at the corpus floor); require a conservative fraction.
    let expected_drop = (0.02 * steps as f64).clamp(0.2, 8.0);
    anyhow::ensure!(
        report.last_loss() < report.first_loss() - expected_drop,
        "training did not make progress: {:.4} -> {:.4} (needed -{expected_drop:.2})",
        report.first_loss(),
        report.last_loss()
    );
    println!("e2e OK: loss fell {:.2} nats", report.first_loss() - report.last_loss());
    Ok(())
}
