//! Figure 4: what variable-length chunking costs — (a) memory divergence
//! across DP ranks grows with DP size (paper: 1.08-1.17× at 512K);
//! (b) attention-imbalance idle time when the memory cap bites
//! (paper: 19% idle at DP=4, 55% at DP=8 for 512K).

use distca::config::{run::DataDist, ClusterConfig, ModelConfig};
use distca::data::distributions::sampler_for;
use distca::sim::strategies::{run_packed_dp, run_varlen_chunking, SimParams};
use distca::sim::IterationReport;
use distca::util::rng::{seed_from_env, Rng};
use distca::util::tables::{f, Table};

fn main() {
    let model = ModelConfig::llama3_8b();
    let max_doc = 512 * 1024;
    let n_batches = if std::env::var("DISTCA_BENCH_QUICK").is_ok() { 2 } else { 6 };

    let mut ta = Table::new(
        "Fig. 4a — memory divergence of variable-length chunking vs DP size (512K, 8B)",
        &["DP", "#GPU", "varlen mem div", "varlen max mem (GiB/GPU)", "packed mem div"],
    );
    let mut tb = Table::new(
        "Fig. 4b — idle fraction from attention imbalance (512K, 8B)",
        &["DP", "#GPU", "packed-DP idle%", "varlen-chunk idle%"],
    );
    for &dp in &[2usize, 4, 8, 16] {
        let n_gpus = dp * 8;
        let cluster = ClusterConfig::h200(n_gpus / 8);
        let params = SimParams::new(model.clone(), cluster, 8, 1);
        // Batch scales with DP (paper keeps memory full as nodes grow);
        // 128K-token chunks keep the uncapped regime visible in (a)
        // while (b) still shows the cap biting at larger DP.
        let batch_tokens = dp * max_doc / 2;
        let chunk_tokens = 128 * 1024;
        let mut wlb = Vec::new();
        let mut packed = Vec::new();
        for b in 0..n_batches {
            let mut rng = Rng::new(seed_from_env(4000) + b as u64 * 31 + dp as u64);
            let docs =
                sampler_for(DataDist::Pretrain, max_doc).sample_tokens(&mut rng, batch_tokens, 0);
            wlb.push(run_varlen_chunking(&docs, chunk_tokens, &params));
            packed.push(run_packed_dp(&docs, chunk_tokens, &params));
        }
        let wlb = IterationReport::average(&wlb);
        let packed = IterationReport::average(&packed);
        ta.row(&[
            dp.to_string(),
            n_gpus.to_string(),
            f(wlb.memory_divergence(), 2),
            f(wlb.max_memory() / 1e9, 1),
            f(packed.memory_divergence(), 2),
        ]);
        tb.row(&[
            dp.to_string(),
            n_gpus.to_string(),
            f(packed.idle_fraction() * 100.0, 1),
            f(wlb.idle_fraction() * 100.0, 1),
        ]);
    }
    ta.print();
    println!("paper: divergence 1.08-1.17x and growing with DP; fixed packing stays 1.0.\n");
    tb.print();
    println!("paper: idle rises with DP (19% @DP4 -> 55% @DP8 at 512K) once memory caps bite.");
}
