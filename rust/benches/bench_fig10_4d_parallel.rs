//! Figure 10 / Table 4: full 4D parallelism (with PP) — DistCA's
//! tick-aligned schedule (pooling CA across PP stages and DP groups,
//! repurposing warm-up/drain bubbles as attention-server time) vs
//! WLB-ideal under 1F1B. Paper: 1.15-1.30x (8B Pretrain), 1.10-1.35x
//! (8B ProLong), up to 1.15x/1.25x on 34B.

use distca::config::run::{DataDist, RunConfig};
use distca::config::{ClusterConfig, ModelConfig};
use distca::data::distributions::sampler_for;
use distca::metrics::{comparison_table, ComparisonRow};
use distca::sim::strategies::{run_distca, run_wlb_ideal, SimParams};
use distca::sim::IterationReport;
use distca::util::rng::{seed_from_env, Rng};

fn main() {
    let quick = std::env::var("DISTCA_BENCH_QUICK").is_ok();
    let n_batches = if quick { 2 } else { 6 };
    let grid = RunConfig::table4_grid();

    for dist in [DataDist::Pretrain, DataDist::ProLong] {
        let mut rows = Vec::new();
        for rc in &grid {
            if quick && rc.n_gpus > 128 {
                continue;
            }
            if rc.n_gpus > 256 && std::env::var("DISTCA_BENCH_FULL").is_err() {
                continue; // 512-GPU rows only under DISTCA_BENCH_FULL
            }
            let model = ModelConfig::by_name(&rc.model).unwrap();
            let cluster = ClusterConfig::h200(rc.n_gpus / 8);
            let params = SimParams::new(model, cluster, rc.tp, rc.pp);
            // Every DP group needs several microbatches for the pipeline
            // to fill; size the sampled batch accordingly.
            let n_groups = rc.n_gpus / rc.tp / rc.pp;
            let mb_chunk = rc.chunk_tokens / 4;
            let batch_tokens =
                (rc.batch_size * rc.chunk_tokens / 8).max(n_groups * mb_chunk * 2 * rc.pp);
            let mut wlb = Vec::new();
            let mut ca = Vec::new();
            for b in 0..n_batches {
                let mut rng =
                    Rng::new(seed_from_env(1000) + b as u64 * 37 + rc.max_doc_len as u64 + rc.n_gpus as u64);
                let docs = sampler_for(dist, rc.max_doc_len)
                    .sample_tokens(&mut rng, batch_tokens, 0);
                wlb.push(run_wlb_ideal(&docs, mb_chunk, &params));
                ca.push(run_distca(&docs, mb_chunk, &params));
            }
            rows.push(ComparisonRow {
                model: rc.model.clone(),
                max_doc_len: rc.max_doc_len,
                n_gpus: rc.n_gpus,
                dataset: dist.name().into(),
                baseline: IterationReport::average(&wlb),
                distca: IterationReport::average(&ca),
            });
        }
        comparison_table(
            &format!("Fig. 10 / Table 4 — 4D parallel (with PP), {}", dist.name()),
            &rows,
        )
        .print();
        let sp: Vec<f64> = rows.iter().map(|r| r.speedup()).collect();
        let lo = sp.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = sp.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "{}: speedup {lo:.2}x-{hi:.2}x  (paper 8B: {}, 34B up to {})\n",
            dist.name(),
            match dist {
                DataDist::Pretrain => "1.15-1.30x",
                DataDist::ProLong => "1.10-1.35x",
            },
            match dist {
                DataDist::Pretrain => "1.15x",
                DataDist::ProLong => "1.25x",
            }
        );
    }
}
