//! Figure 12: the scheduler's imbalance-tolerance factor ε trades CA
//! balance against communication volume. Paper: for 8B latency is flat
//! over ε ∈ [0, 0.20]; for 34B ε < 0.10 is too restrictive (comm can no
//! longer hide) and large ε raises latency ~linearly; tuning ε from 0 to
//! 0.15 cuts communication 20-25% at unchanged latency.

use distca::config::{run::DataDist, ClusterConfig, ModelConfig};
use distca::data::distributions::sampler_for;
use distca::sim::strategies::{run_distca, SimParams};
use distca::sim::IterationReport;
use distca::util::rng::{seed_from_env, Rng};
use distca::util::tables::{bytes, f, secs, Table};

fn main() {
    let n_batches = if std::env::var("DISTCA_BENCH_QUICK").is_ok() { 2 } else { 5 };
    let tolerances = [0.0, 0.05, 0.10, 0.15, 0.20, 0.30, 0.50];

    for &(model_name, nodes, total_tokens) in &[
        ("llama-8b", 8usize, 1024 * 1024usize),
        ("llama-34b", 8, 512 * 1024),
        ("llama-8b", 16, 2 * 1024 * 1024),
        ("llama-34b", 16, 1024 * 1024),
    ] {
        let model = ModelConfig::by_name(model_name).unwrap();
        let max_doc = 128 * 1024;
        let mut t = Table::new(
            &format!("Fig. 12 — tolerance sweep, {model_name}, {nodes} nodes (Pretrain, 128K)"),
            &["epsilon", "iter time", "comm volume", "vs eps=0 comm", "idle%"],
        );
        let mut base_comm = 0.0f64;
        for &eps in &tolerances {
            let mut params =
                SimParams::new(model.clone(), ClusterConfig::h200(nodes), 8, 1);
            params.tolerance = eps;
            let mut reports = Vec::new();
            for b in 0..n_batches {
                let mut rng = Rng::new(seed_from_env(1200) + b as u64 * 17 + nodes as u64);
                let docs = sampler_for(DataDist::Pretrain, max_doc)
                    .sample_tokens(&mut rng, total_tokens, 0);
                reports.push(run_distca(&docs, max_doc, &params));
            }
            let avg = IterationReport::average(&reports);
            if eps == 0.0 {
                base_comm = avg.comm_bytes;
            }
            t.row(&[
                format!("{eps:.2}"),
                secs(avg.iter_time),
                bytes(avg.comm_bytes),
                format!("{:+.0}%", (avg.comm_bytes / base_comm - 1.0) * 100.0),
                f(avg.idle_fraction() * 100.0, 1),
            ]);
        }
        t.print();
        println!();
    }
    println!(
        "paper: latency flat for small eps then rising ~linearly; comm falls 20-25%\n\
         from eps=0 to eps=0.15; 34B at low eps pays extra latency (unhidden comm)."
    );
}
