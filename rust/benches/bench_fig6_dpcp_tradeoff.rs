//! Figure 6: the DP×CP trade-off on a 64-GPU, 512K-token workload —
//! higher CP balances but adds all-gather and memory pressure; higher DP
//! runs into attention imbalance. Neither end wins; DistCA sidesteps the
//! dilemma.

use distca::config::{run::DataDist, ClusterConfig, ModelConfig};
use distca::data::distributions::sampler_for;
use distca::sim::strategies::{run_distca, wlb_sweep, SimParams};
use distca::sim::IterationReport;
use distca::util::rng::{seed_from_env, Rng};
use distca::util::tables::{f, secs, Table};

fn main() {
    let model = ModelConfig::llama3_8b();
    let cluster = ClusterConfig::h200(8); // 64 GPUs
    let params = SimParams::new(model, cluster, 8, 1);
    let max_doc = 512 * 1024;
    let n_batches = if std::env::var("DISTCA_BENCH_QUICK").is_ok() { 2 } else { 6 };

    // Collect per-(dp, cp) averages across batches.
    let mut sweeps: Vec<Vec<IterationReport>> = Vec::new();
    let mut distca_reports = Vec::new();
    for b in 0..n_batches {
        let mut rng = Rng::new(seed_from_env(600) + b as u64);
        let docs = sampler_for(DataDist::Pretrain, max_doc).sample_tokens(
            &mut rng,
            2 * max_doc,
            0,
        );
        sweeps.push(wlb_sweep(&docs, max_doc / 2, &params));
        distca_reports.push(run_distca(&docs, max_doc / 2, &params));
    }
    let n_cfg = sweeps[0].len();
    let mut t = Table::new(
        "Fig. 6 — DP x CP sweep, 64 GPUs, 512K max doc (WLB chunking)",
        &["config", "iter time", "tok/s", "idle%", "mem div", "OOM?"],
    );
    for c in 0..n_cfg {
        let series: Vec<IterationReport> =
            sweeps.iter().map(|s| s[c].clone()).collect();
        let avg = IterationReport::average(&series);
        t.row(&[
            avg.config.clone(),
            secs(avg.iter_time),
            format!("{:.3e}", avg.throughput()),
            f(avg.idle_fraction() * 100.0, 1),
            f(avg.memory_divergence(), 2),
            if avg.oom { "OOM".into() } else { "-".into() },
        ]);
    }
    let ca = IterationReport::average(&distca_reports);
    t.row(&[
        ca.config.clone(),
        secs(ca.iter_time),
        format!("{:.3e}", ca.throughput()),
        f(ca.idle_fraction() * 100.0, 1),
        f(ca.memory_divergence(), 2),
        if ca.oom { "OOM".into() } else { "-".into() },
    ]);
    t.print();
    println!(
        "paper: raising CP cuts imbalance but lowers throughput / risks OOM; raising DP \
         brings imbalance back. DistCA (last row) balances without the trade-off."
    );
}
