//! Memory balance (§5, Fig. 3b): per-server peak *transient* bytes of
//! DistCA's balanced in-place execution vs the colocated baseline.
//!
//! For each sampled batch the §4.2 scheduler's plan is replayed through
//! per-server arenas (O overwrites Q in place, KV frees post-task) and
//! compared against the colocated baseline: compute-balanced
//! whole-document placement with out-of-place outputs, whose bytes
//! inherit the token skew the FLOPs balance creates (Fig. 1's dilemma).
//! The headline series is the max/mean balance ratio — DistCA's should
//! sit near 1.0 where the colocated baseline's reflects the data skew —
//! plus the absolute peaks the in-place reuse saves.
//!
//! Also timed: the memory-aware scheduling path itself (`mem_budget`
//! set) vs the unconstrained scheduler, so the budget machinery's cost
//! is visible.
//!
//! Machine-readable output: `BENCH_memory.json` in the working
//! directory (peak per-server bytes, max/mean ratios, DistCA vs
//! colocated, per batch and aggregated).
//!
//! Reproducibility: everything derives from `DISTCA_SEED` (default
//! 4242); `DISTCA_BENCH_QUICK=1` shrinks the workload.

use distca::bench::BenchRunner;
use distca::config::run::DataDist;
use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::scheduler::items_from_chunks;
use distca::coordinator::{schedule, Profiler, SchedulerCfg};
use distca::data::distributions::sampler_for;
use distca::memplan::MemReport;
use distca::model::FlopsModel;
use distca::sim::strategies::distca_placement;
use distca::util::json::Json;
use distca::util::rng::{seed_from_env, Rng};
use distca::util::tables::{bytes, f, Table};

fn main() {
    let quick = std::env::var("DISTCA_BENCH_QUICK").is_ok();
    let seed = seed_from_env(4242);
    println!("seed {seed} (override with DISTCA_SEED)\n");

    let n = 8usize;
    let n_batches = if quick { 3 } else { 6 };
    let max_doc = if quick { 65_536 } else { 131_072 };
    let model = ModelConfig::llama3_8b();
    let fm = FlopsModel::new(&model);
    let prof = Profiler::analytic(&fm, &ClusterConfig::h200(n));
    let cfg = SchedulerCfg::default();

    let mut t = Table::new(
        &format!(
            "transient-memory balance — {n} servers, Pretrain {}K, {n_batches} batches",
            max_doc / 1024
        ),
        &[
            "batch", "distca max", "distca ratio", "coloc max", "coloc ratio", "in-place saved",
        ],
    );
    let mut per_batch = Vec::new();
    let mut worst_distca_ratio = 0.0f64;
    let mut worst_coloc_ratio = 0.0f64;
    let mut agg_distca = vec![0.0f64; n];
    let mut agg_coloc = vec![0.0f64; n];

    for b in 0..n_batches {
        let mut rng = Rng::new(seed + b as u64 * 7919);
        let docs = sampler_for(DataDist::Pretrain, max_doc).sample_tokens(&mut rng, n * max_doc, 0);
        let chunks = distca_placement(&docs, n);
        let items = items_from_chunks(&chunks);
        let plan = schedule(&items, n, &fm, &prof, &model, &cfg);
        let distca = MemReport::for_plan(&plan, &model, 0.0).expect("unbounded replay");
        let coloc = MemReport::colocated(&items, n, &model);
        // In-place saving on the same balanced assignment: replay the
        // plan out-of-place and diff the worst server.
        let coloc_style_on_plan = {
            let mut peaks = Vec::with_capacity(n);
            for srv in 0..n {
                let shapes: Vec<(usize, usize)> = plan
                    .assignments
                    .iter()
                    .filter(|a| a.server == srv)
                    .flat_map(|a| a.item.ca_tasks())
                    .map(|ct| (ct.q_len, ct.kv_len))
                    .collect();
                peaks.push(
                    distca::memplan::replay_server_tick(&shapes, &model, 0, false)
                        .expect("unbounded replay")
                        .peak_bytes() as f64,
                );
            }
            MemReport::from_peaks(peaks, 0.0)
        };
        let saved = coloc_style_on_plan.max_peak() - distca.max_peak();
        // In-place alone already guarantees ≤ on the same assignment;
        // balancing makes the absolute worst server strictly cheaper.
        assert!(
            distca.max_peak() < coloc.max_peak(),
            "batch {b}: DistCA max {} must be strictly below colocated {}",
            distca.max_peak(),
            coloc.max_peak()
        );
        worst_distca_ratio = worst_distca_ratio.max(distca.max_mean_ratio());
        worst_coloc_ratio = worst_coloc_ratio.max(coloc.max_mean_ratio());
        for s in 0..n {
            agg_distca[s] = agg_distca[s].max(distca.per_server_peak[s]);
            agg_coloc[s] = agg_coloc[s].max(coloc.per_server_peak[s]);
        }
        t.row(&[
            b.to_string(),
            bytes(distca.max_peak()),
            f(distca.max_mean_ratio(), 3),
            bytes(coloc.max_peak()),
            f(coloc.max_mean_ratio(), 3),
            bytes(saved),
        ]);
        per_batch.push(Json::obj(vec![
            ("batch", Json::Num(b as f64)),
            ("distca_in_place", distca.to_json()),
            ("colocated_baseline", coloc.to_json()),
            ("in_place_saved_bytes", Json::Num(saved)),
        ]));
    }
    t.print();

    let agg_distca_rep = MemReport::from_peaks(agg_distca, 0.0);
    let agg_coloc_rep = MemReport::from_peaks(agg_coloc, 0.0);
    println!(
        "aggregate max/mean ratio: DistCA {:.3} vs colocated {:.3} (worst batch: {:.3} vs {:.3})",
        agg_distca_rep.max_mean_ratio(),
        agg_coloc_rep.max_mean_ratio(),
        worst_distca_ratio,
        worst_coloc_ratio,
    );
    assert!(
        agg_distca_rep.max_mean_ratio() < agg_coloc_rep.max_mean_ratio(),
        "DistCA in-place must balance transient memory strictly better than colocated \
         ({} vs {})",
        agg_distca_rep.max_mean_ratio(),
        agg_coloc_rep.max_mean_ratio()
    );

    // Scheduler cost of the memory constraint (budget = 1.25x free peak).
    let mut runner = BenchRunner::new("memory-aware scheduling");
    let mut rng = Rng::new(seed ^ 0x3E3A);
    let docs = sampler_for(DataDist::Pretrain, max_doc).sample_tokens(&mut rng, n * max_doc, 0);
    let chunks = distca_placement(&docs, n);
    let items = items_from_chunks(&chunks);
    let free_peak = {
        let plan = schedule(&items, n, &fm, &prof, &model, &cfg);
        MemReport::for_plan(&plan, &model, 0.0).expect("replay").max_peak()
    };
    runner.bench("schedule (unconstrained)", || {
        schedule(&items, n, &fm, &prof, &model, &cfg).assignments.len()
    });
    let mem_cfg = SchedulerCfg { mem_budget: 1.25 * free_peak, ..Default::default() };
    runner.bench("schedule (mem_budget)", || {
        schedule(&items, n, &fm, &prof, &model, &mem_cfg).assignments.len()
    });
    runner.finish();

    let out = Json::obj(vec![
        ("bench", Json::Str("memory_balance".into())),
        ("seed", Json::Num(seed as f64)),
        ("n_servers", Json::Num(n as f64)),
        ("max_doc", Json::Num(max_doc as f64)),
        ("n_batches", Json::Num(n_batches as f64)),
        ("aggregate_distca", agg_distca_rep.to_json()),
        ("aggregate_colocated", agg_coloc_rep.to_json()),
        ("worst_distca_ratio", Json::Num(worst_distca_ratio)),
        ("worst_colocated_ratio", Json::Num(worst_coloc_ratio)),
        ("per_batch", Json::Arr(per_batch)),
    ]);
    let path = "BENCH_memory.json";
    std::fs::write(path, out.to_string_pretty()).expect("write BENCH_memory.json");
    println!("\nwrote {path}");
}
