//! Figure 11: communication-overlap ablation. Three modes on the same
//! workload: "Signal" (1-byte messages — the pure compute-balance floor),
//! DistCA ping-pong, and "Single Stream" (no overlap). Paper: DistCA ≈
//! Signal (comm fully hidden) while Single Stream is 10-17% slower; the
//! only exception is the smallest compute (8B, 8 nodes) where compute is
//! too small to hide everything.

use distca::config::{run::DataDist, ClusterConfig, ModelConfig};
use distca::data::distributions::sampler_for;
use distca::sim::strategies::{run_distca, CommMode, SimParams};
use distca::sim::IterationReport;
use distca::util::rng::{seed_from_env, Rng};
use distca::util::tables::{secs, Table};

fn main() {
    let n_batches = if std::env::var("DISTCA_BENCH_QUICK").is_ok() { 2 } else { 6 };
    let mut t = Table::new(
        "Fig. 11 — overlap ablation (Pretrain, 128K max doc)",
        &["model", "nodes", "Signal", "DistCA", "SingleStream", "DistCA/Signal", "SS/DistCA"],
    );
    for &(model_name, nodes) in &[
        ("llama-8b", 8usize),
        ("llama-8b", 16),
        ("llama-34b", 8),
        ("llama-34b", 16),
    ] {
        let model = ModelConfig::by_name(model_name).unwrap();
        let max_doc = 128 * 1024;
        let batch_tokens = nodes * max_doc; // saturate compute
        let mut results = Vec::new();
        for mode in [CommMode::Signal, CommMode::PingPong, CommMode::SingleStream] {
            let mut params =
                SimParams::new(model.clone(), ClusterConfig::h200(nodes), 8, 1);
            params.comm_mode = mode;
            let mut reports = Vec::new();
            for b in 0..n_batches {
                let mut rng = Rng::new(seed_from_env(1100) + b as u64 * 13 + nodes as u64);
                let docs = sampler_for(DataDist::Pretrain, max_doc)
                    .sample_tokens(&mut rng, batch_tokens, 0);
                reports.push(run_distca(&docs, max_doc, &params));
            }
            results.push(IterationReport::average(&reports).iter_time);
        }
        let (sig, pp, ss) = (results[0], results[1], results[2]);
        t.row(&[
            model_name.into(),
            nodes.to_string(),
            secs(sig),
            secs(pp),
            secs(ss),
            format!("{:.3}", pp / sig),
            format!("{:.3}", ss / pp),
        ]);
    }
    t.print();
    println!(
        "paper: DistCA/Signal ~= 1.00 (comm fully hidden; slight excess only on the\n\
         smallest compute), SingleStream/DistCA ~= 1.10-1.17."
    );
}
