//! Elastic recovery: what does a dead or slow attention server cost once
//! CA-tasks can be re-dispatched (DistCA §3 statelessness)?
//!
//! Sim mode sweeps fault plans over an 8-server pool and reports recovery
//! time and goodput retention; the headline check is that re-dispatch
//! beats both the "waiting" floor (redo the killed tick from scratch)
//! and raw proportional capacity loss. Threaded mode runs the reference
//! kernel under a mid-run kill and reports wall-clock recovery with
//! bit-exact output verification.
//!
//! Reproducibility: every stream derives from `DISTCA_SEED` (default
//! 4242); `DISTCA_BENCH_QUICK=1` shrinks the workload.

use distca::config::run::DataDist;
use distca::config::{ClusterConfig, ModelConfig};
use distca::data::distributions::sampler_for;
use distca::data::Document;
use distca::elastic::{
    run_elastic_sim, ElasticCfg, ElasticCoordinator, ElasticSimCfg, ElasticTask, FaultPlan,
    ReferenceCaCompute,
};
use distca::runtime::ca_exec::synthetic_task;
use distca::sim::strategies::SimParams;
use distca::util::rng::{seed_from_env, Rng};
use distca::util::tables::{f, secs, Table};

fn sim_batches(seed: u64, ticks: usize, n_servers: usize, max_doc: usize) -> Vec<Vec<Document>> {
    (0..ticks)
        .map(|t| {
            let mut rng = Rng::new(seed + t as u64 * 7919);
            sampler_for(DataDist::Pretrain, max_doc).sample_tokens(
                &mut rng,
                n_servers * max_doc,
                0,
            )
        })
        .collect()
}

fn sim_mode(seed: u64, quick: bool) {
    let n = 8usize;
    let ticks = if quick { 4 } else { 6 };
    let max_doc = if quick { 65_536 } else { 131_072 };
    let kill_tick = ticks / 2;
    let p = SimParams::new(ModelConfig::llama3_8b(), ClusterConfig::h200(n), 8, 1);
    let batches = sim_batches(seed, ticks, n, max_doc);

    let mut rng = Rng::new(seed ^ 0xFA17_FA17);
    let plans: Vec<(String, FaultPlan)> = vec![
        ("none".into(), FaultPlan::new()),
        (format!("kill:1@{kill_tick}"), FaultPlan::new().kill(1, kill_tick)),
        (
            format!("kill:1@{kill_tick},rejoin:1@{}", kill_tick + 2),
            FaultPlan::new().kill(1, kill_tick).rejoin(1, kill_tick + 2),
        ),
        ("slow:2@1x0.25".into(), FaultPlan::new().slow(2, 1, 0.25)),
        (
            "random(seeded)".into(),
            FaultPlan::random(&mut rng, n, ticks, 1, 1),
        ),
    ];

    let mut t = Table::new(
        &format!("elastic recovery (sim) — {n} servers, {ticks} ticks, Pretrain {}K", max_doc / 1024),
        &["fault plan", "total", "fault-free", "overhead", "goodput", "redisp", "lost"],
    );
    let mut killed_only = None;
    for (name, plan) in &plans {
        let r = run_elastic_sim(&batches, n, &p, plan, &ElasticSimCfg::default())
            .expect("elastic sim");
        t.row(&[
            name.clone(),
            secs(r.total_time),
            secs(r.fault_free_time),
            secs(r.recovery_overhead()),
            f(r.goodput_ratio(), 3),
            r.redispatched.to_string(),
            r.lost_tasks.to_string(),
        ]);
        if name.starts_with("kill") && !name.contains("rejoin") {
            killed_only = Some(r);
        }
    }
    t.print();

    // Re-dispatch vs the alternatives, on the kill-only plan.
    if let Some(r) = killed_only {
        let killed_tick = &r.per_tick[kill_tick];
        // "Waiting" floor: without re-dispatch the killed tick cannot
        // complete; the cheapest alternative is to redo it entirely.
        let waiting_total = r.fault_free_time + killed_tick.fault_free_time;
        // Proportional capacity loss: (n-1)/n of throughput from the kill
        // tick onward, as if the whole tick slowed instead of recovering.
        let prop_ratio = {
            let pre: f64 = r.per_tick[..kill_tick]
                .iter()
                .map(|x| x.fault_free_time)
                .sum();
            let post: f64 = r.per_tick[kill_tick..]
                .iter()
                .map(|x| x.fault_free_time)
                .sum();
            r.fault_free_time / (pre + post * n as f64 / (n - 1) as f64)
        };
        println!(
            "kill-only: recovery {} on the killed tick (fault-free {}), total {} vs waiting floor {}",
            secs(killed_tick.tick_time - killed_tick.fault_free_time),
            secs(killed_tick.fault_free_time),
            secs(r.total_time),
            secs(waiting_total),
        );
        println!(
            "goodput: elastic {:.3} vs proportional-loss {:.3} — re-dispatch {} waiting",
            r.goodput_ratio(),
            prop_ratio,
            if r.total_time < waiting_total { "beats" } else { "does NOT beat" },
        );
    }
    println!();
}

fn threaded_mode(seed: u64, quick: bool) {
    const H: usize = 4;
    const HKV: usize = 2;
    const D: usize = 16;
    let n = 4usize;
    let ticks = if quick { 2 } else { 3 };
    let kill_tick = 1usize;
    let oracle = ReferenceCaCompute::new(H, HKV, D);

    let run = |fault: &FaultPlan| -> (f64, Vec<distca::elastic::TickStats>) {
        let mut co = ElasticCoordinator::spawn(n, ElasticCfg::default(), |_| {
            Box::new(ReferenceCaCompute::new(H, HKV, D))
        });
        let mut rng = Rng::new(seed);
        let t0 = std::time::Instant::now();
        for tick in 0..ticks {
            let alive = co.pool.schedulable();
            let mut tasks = Vec::new();
            for i in 0..3 * n {
                let len = if i % 3 == 0 { 256 } else { 128 };
                let server = alive[i % alive.len()];
                tasks.push(ElasticTask {
                    doc: (tick * 1000 + i) as u32,
                    q_start: 0,
                    server,
                    home: server,
                    tensors: synthetic_task(&mut rng, len, len, H, HKV, D),
                });
            }
            let outputs = co.run_tick(tick, &tasks, fault).expect("tick");
            for out in &outputs {
                let task = tasks
                    .iter()
                    .find(|t| t.doc == out.doc && t.q_start == out.q_start)
                    .unwrap();
                let expect = oracle.run_batch(std::slice::from_ref(&task.tensors));
                assert_eq!(out.o, expect[0], "output diverged from the oracle");
            }
        }
        let elapsed = t0.elapsed().as_secs_f64();
        (elapsed, co.shutdown().expect("shutdown"))
    };

    let (base_time, _) = run(&FaultPlan::new());
    let fault = FaultPlan::new().kill(1, kill_tick);
    let (fault_time, stats) = run(&fault);

    let mut t = Table::new(
        &format!("elastic recovery (threaded) — {n} reference servers, {ticks} ticks, kill:1@{kill_tick}"),
        &["tick", "tasks", "redisp", "cancels", "dups", "deadline rounds", "elapsed"],
    );
    for st in &stats {
        t.row(&[
            st.tick.to_string(),
            st.n_tasks.to_string(),
            st.redispatched.to_string(),
            st.cancels_sent.to_string(),
            st.duplicates_suppressed.to_string(),
            st.deadline_rounds.to_string(),
            secs(st.elapsed),
        ]);
    }
    t.print();
    let redisp: usize = stats.iter().map(|s| s.redispatched).sum();
    println!(
        "fault-free wall {} vs with-kill {} (recovery overhead {}), {} tasks re-dispatched;\n\
         every gathered value was bit-identical to the monolithic oracle.",
        secs(base_time),
        secs(fault_time),
        secs((fault_time - base_time).max(0.0)),
        redisp,
    );
    println!(
        "overhead is dominated by the detection grace window ({}ms); goodput loss stays \n\
         far below the 1/{n} proportional floor because survivors absorb the victim's work.",
        ElasticCfg::default().grace.as_millis(),
    );
}

fn main() {
    let quick = std::env::var("DISTCA_BENCH_QUICK").is_ok();
    let seed = seed_from_env(4242);
    println!("seed {seed} (override with DISTCA_SEED)\n");
    sim_mode(seed, quick);
    threaded_mode(seed, quick);
}
