//! Appendix A: the maximum number of shards a document can be split into
//! with dispatch communication fully hidden under context-independent
//! compute: `s ≤ 2(tB − size_q)/size_kv − 1`. Paper's worked example:
//! Llama-34B at 50 GB/s and 50% MFU ⇒ s ≈ 31, growing with model size.

use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::comm::{max_partition_bound, token_linear_time};
use distca::util::tables::{f, Table};

fn main() {
    let mut t = Table::new(
        "Appendix A — max overlap-free partition count s",
        &["model", "IB bw (GB/s)", "t (us/token)", "s bound"],
    );
    for model in [ModelConfig::llama3_8b(), ModelConfig::llama_34b()] {
        for bw_gb in [25.0f64, 50.0, 100.0, 200.0] {
            let mut cluster = ClusterConfig::h200(1);
            cluster.ib_bw = bw_gb * 1e9;
            let tt = token_linear_time(&model, &cluster);
            let s = max_partition_bound(&model, &cluster);
            t.row(&[
                model.name.clone(),
                f(bw_gb, 0),
                format!("{:.3}", tt * 1e6),
                f(s.max(0.0), 1),
            ]);
        }
    }
    t.print();
    println!(
        "paper: s ~= 31 for 34B at 50 GB/s; the bound grows with hidden size\n\
         (t scales quadratically in h) and with bandwidth."
    );
}
