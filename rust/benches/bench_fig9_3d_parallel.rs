//! Figure 9 / Table 3: 3D parallelism (no PP) — DistCA vs WLB-ideal over
//! the paper's full grid (model × MaxDocLen × #GPU × dataset), average of
//! sampled batches. Paper: 1.07-1.20x (Pretrain), 1.05-1.12x (ProLong).

use distca::config::run::{DataDist, RunConfig};
use distca::config::{ClusterConfig, ModelConfig};
use distca::data::distributions::sampler_for;
use distca::metrics::{comparison_table, ComparisonRow};
use distca::sim::strategies::{run_distca, run_wlb_ideal, SimParams};
use distca::sim::IterationReport;
use distca::util::rng::{seed_from_env, Rng};

fn main() {
    let quick = std::env::var("DISTCA_BENCH_QUICK").is_ok();
    let n_batches = if quick { 2 } else { 8 };
    let grid = RunConfig::table3_grid();

    for dist in [DataDist::Pretrain, DataDist::ProLong] {
        let mut rows = Vec::new();
        for rc in &grid {
            if quick && rc.n_gpus > 128 {
                continue;
            }
            let model = ModelConfig::by_name(&rc.model).unwrap();
            let cluster = ClusterConfig::h200(rc.n_gpus / 8);
            let params = SimParams::new(model, cluster, rc.tp, 1);
            let batch_tokens = rc.batch_size * rc.chunk_tokens / 2;
            let mut wlb = Vec::new();
            let mut ca = Vec::new();
            for b in 0..n_batches {
                let mut rng =
                    Rng::new(seed_from_env(900) + b as u64 * 101 + rc.max_doc_len as u64 + rc.n_gpus as u64);
                let docs = sampler_for(dist, rc.max_doc_len)
                    .sample_tokens(&mut rng, batch_tokens, 0);
                wlb.push(run_wlb_ideal(&docs, rc.chunk_tokens / 2, &params));
                ca.push(run_distca(&docs, rc.chunk_tokens / 2, &params));
            }
            rows.push(ComparisonRow {
                model: rc.model.clone(),
                max_doc_len: rc.max_doc_len,
                n_gpus: rc.n_gpus,
                dataset: dist.name().into(),
                baseline: IterationReport::average(&wlb),
                distca: IterationReport::average(&ca),
            });
        }
        comparison_table(
            &format!("Fig. 9 / Table 3 — 3D parallel (no PP), {}", dist.name()),
            &rows,
        )
        .print();
        let sp: Vec<f64> = rows.iter().map(|r| r.speedup()).collect();
        let lo = sp.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = sp.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "{}: speedup {lo:.2}x-{hi:.2}x  (paper: {})\n",
            dist.name(),
            match dist {
                DataDist::Pretrain => "1.07-1.20x",
                DataDist::ProLong => "1.05-1.12x",
            }
        );
    }
}
