//! L3 hot path: the §4.2 greedy scheduler itself. The paper runs it on
//! CPU, prefetching the next batch's plan while the current batch
//! computes — so it must stay well under one iteration's wall-clock.
//! Target: <1 ms per microbatch schedule at 64 servers, sub-100 ms at
//! 512-GPU scale. §Perf in EXPERIMENTS.md tracks before/after.

use distca::bench::BenchRunner;
use distca::config::{run::DataDist, ClusterConfig, ModelConfig};
use distca::coordinator::scheduler::items_from_chunks;
use distca::coordinator::{schedule, Profiler, SchedulerCfg};
use distca::data::distributions::sampler_for;
use distca::model::FlopsModel;
use distca::sim::strategies::distca_placement;
use distca::util::rng::{seed_from_env, Rng};

fn main() {
    let model = ModelConfig::llama3_8b();
    let f = FlopsModel::new(&model);
    let mut runner = BenchRunner::new("scheduler hot path");

    for &(n_servers, max_doc, tokens) in &[
        (8usize, 131_072usize, 1_048_576usize),
        (32, 131_072, 4_194_304),
        (64, 524_288, 8_388_608),
        (128, 524_288, 16_777_216),
    ] {
        let cluster = ClusterConfig::h200(n_servers);
        let prof = Profiler::analytic(&f, &cluster);
        let mut rng = Rng::new(seed_from_env(42));
        let docs =
            sampler_for(DataDist::Pretrain, max_doc).sample_tokens(&mut rng, tokens, 0);
        let chunks = distca_placement(&docs, n_servers);
        let items = items_from_chunks(&chunks);
        let cfg = SchedulerCfg::default();
        let label = format!(
            "schedule n={n_servers} items={} ({}M tok)",
            items.len(),
            tokens / 1_048_576
        );
        runner.bench_with_units(&label, items.len() as f64, || {
            schedule(&items, n_servers, &f, &prof, &model, &cfg)
        });
    }
    runner.finish();
    println!("target: <1 ms at 8-64 servers; <100 ms at 128+ (prefetched off the critical path).");
}
