//! L3 hot path: the §4.2 greedy scheduler itself. The paper runs it on
//! CPU, prefetching the next batch's plan while the current batch
//! computes — so it must stay well under one iteration's wall-clock.
//! Target: <1 ms per microbatch schedule at 64 servers, sub-100 ms at
//! 512-GPU scale. §Perf in EXPERIMENTS.md tracks before/after.
//!
//! The heterogeneous-speeds section times the belief-aware planner
//! (`schedule_with_beliefs`, one server believed 4× slow) against the
//! uniform path on the same items and writes `BENCH_hetero.json` with
//! both predicted makespans — the plan-time answer to the straggler
//! problem, quantified.

use distca::bench::BenchRunner;
use distca::config::{run::DataDist, ClusterConfig, ModelConfig};
use distca::coordinator::scheduler::items_from_chunks;
use distca::coordinator::{schedule, schedule_with_beliefs, Profiler, SchedulerCfg, ServerBelief};
use distca::data::distributions::sampler_for;
use distca::model::FlopsModel;
use distca::sim::strategies::distca_placement;
use distca::util::json::Json;
use distca::util::rng::{seed_from_env, Rng};

fn main() {
    let model = ModelConfig::llama3_8b();
    let f = FlopsModel::new(&model);
    let mut runner = BenchRunner::new("scheduler hot path");
    let mut hetero_cases: Vec<Json> = Vec::new();

    for &(n_servers, max_doc, tokens) in &[
        (8usize, 131_072usize, 1_048_576usize),
        (32, 131_072, 4_194_304),
        (64, 524_288, 8_388_608),
        (128, 524_288, 16_777_216),
    ] {
        let cluster = ClusterConfig::h200(n_servers);
        let prof = Profiler::analytic(&f, &cluster);
        let mut rng = Rng::new(seed_from_env(42));
        let docs =
            sampler_for(DataDist::Pretrain, max_doc).sample_tokens(&mut rng, tokens, 0);
        let chunks = distca_placement(&docs, n_servers);
        let items = items_from_chunks(&chunks);
        let cfg = SchedulerCfg::default();
        let label = format!(
            "schedule n={n_servers} items={} ({}M tok)",
            items.len(),
            tokens / 1_048_576
        );
        runner.bench_with_units(&label, items.len() as f64, || {
            schedule(&items, n_servers, &f, &prof, &model, &cfg)
        });

        // Heterogeneous beliefs: server 1 believed 4× slow. Same items,
        // same tolerance — the extra cost of time-balancing must stay
        // within the same hot-path budget.
        let mut speeds = vec![1.0f64; n_servers];
        speeds[1] = 0.25;
        let beliefs = ServerBelief::from_speeds(&speeds, 0.0);
        let hetero_label = format!(
            "schedule-hetero n={n_servers} items={} (1 server 4x slow)",
            items.len()
        );
        runner.bench_with_units(&hetero_label, items.len() as f64, || {
            schedule_with_beliefs(&items, &beliefs, &f, &prof, &model, &cfg)
        });

        let uniform = schedule(&items, n_servers, &f, &prof, &model, &cfg);
        let aware = schedule_with_beliefs(&items, &beliefs, &f, &prof, &model, &cfg);
        let uniform_makespan = uniform.makespan_under(&speeds);
        hetero_cases.push(Json::obj(vec![
            ("n_servers", Json::Num(n_servers as f64)),
            ("n_items", Json::Num(items.len() as f64)),
            ("slow_server", Json::Num(1.0)),
            ("believed_speed", Json::Num(0.25)),
            ("uniform_makespan_s", Json::Num(uniform_makespan)),
            ("speed_aware_makespan_s", Json::Num(aware.predicted_makespan())),
            (
                "improvement",
                Json::Num(uniform_makespan / aware.predicted_makespan().max(1e-12)),
            ),
            ("speed_aware_imbalance", Json::Num(aware.imbalance())),
            ("comm_bytes_uniform", Json::Num(uniform.total_comm_bytes())),
            ("comm_bytes_speed_aware", Json::Num(aware.total_comm_bytes())),
        ]));
    }
    runner.finish();

    let out = Json::obj(vec![("cases", Json::Arr(hetero_cases))]);
    let path = "BENCH_hetero.json";
    std::fs::write(path, out.to_string_pretty()).expect("write BENCH_hetero.json");
    println!("wrote {path}");
    println!("target: <1 ms at 8-64 servers; <100 ms at 128+ (prefetched off the critical path).");
}
