//! Runtime hot path on the real PJRT backend: fused CA batch execution
//! latency (the attention server's serving primitive) and executable-
//! cache effectiveness. Skips when artifacts are absent.

use distca::bench::BenchRunner;
use distca::runtime::ca_exec::{synthetic_task, CaExecutor};
use distca::runtime::{artifacts_available, artifacts_dir, Runtime};
use distca::util::rng::{seed_from_env, Rng};

fn main() {
    if !artifacts_available() {
        println!("skipping runtime hotpath bench: run `make artifacts` first");
        return;
    }
    let rt = Runtime::cpu().expect("PJRT");
    let dir = artifacts_dir();
    let mut runner = BenchRunner::new("runtime hot path (CPU PJRT)");

    // Executable cache: second load must be ~free.
    let t0 = std::time::Instant::now();
    let _ = CaExecutor::load(&rt, &dir, 512, 1024, 12, 12, 64).unwrap();
    let cold = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let exec = CaExecutor::load(&rt, &dir, 512, 1024, 12, 12, 64).unwrap();
    let warm = t0.elapsed().as_secs_f64();
    println!(
        "executable load: cold {:.1} ms, cached {:.3} ms ({}x)\n",
        cold * 1e3,
        warm * 1e3,
        (cold / warm.max(1e-9)) as u64
    );

    let mut rng = Rng::new(seed_from_env(3));
    let one = vec![synthetic_task(&mut rng, 512, 1024, 12, 12, 64)];
    runner.bench_with_units("CA fused batch 1x(512q,1024kv)", 512.0, || {
        exec.run_batch(&rt, &one).unwrap()
    });
    let four = vec![
        synthetic_task(&mut rng, 128, 256, 12, 12, 64),
        synthetic_task(&mut rng, 128, 256, 12, 12, 64),
        synthetic_task(&mut rng, 128, 256, 12, 12, 64),
        synthetic_task(&mut rng, 128, 256, 12, 12, 64),
    ];
    runner.bench_with_units("CA fused batch 4x(128q,256kv)", 512.0, || {
        exec.run_batch(&rt, &four).unwrap()
    });
    runner.finish();
    println!("fused-batch latency is the attention server's tick budget (§4.1).");
}
