//! Figure 3: context-parallelism overheads at scale, Llama-8B, 32K docs.
//!
//! (a) the KV all-gather's share of per-layer latency grows with CP
//!     degree (paper: ~3% at 2 nodes → ~40% at 32 nodes);
//! (b) the gathered-KV share of memory grows with CP degree
//!     (paper: ~3% at 2 nodes → ~30% at 16 nodes).

use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::Profiler;
use distca::model::{FlopsModel, MemoryModel};
use distca::util::tables::Table;

fn main() {
    let model = ModelConfig::llama3_8b();
    let f = FlopsModel::new(&model);
    let mem = MemoryModel::new(&model);
    let doc_len = 32_768usize;

    let mut t = Table::new(
        "Fig. 3a — all-gather share of per-layer time (per-doc CP, 32K docs)",
        &["nodes (CP)", "compute/rank (ms)", "allgather (ms)", "AG share"],
    );
    for &nodes in &[2usize, 4, 8, 16, 32] {
        let cluster = ClusterConfig::h200(nodes);
        let prof = Profiler::analytic(&f, &cluster);
        let cp = nodes; // one logical device per node at TP=8
        // Per-rank CA+linear for its head-tail share of each doc; chunk
        // has `cp` docs of 32K so every rank stays busy.
        let docs_per_chunk = cp;
        let shards = distca::parallel::cp::per_document_cp_shards(0, doc_len, cp);
        let s0 = shards[0];
        let mut shapes = Vec::new();
        for _ in 0..docs_per_chunk {
            shapes.push((s0.width as f64, (s0.head_start + s0.width) as f64));
            shapes.push((
                (s0.width + s0.extra) as f64,
                (s0.tail_start + s0.width + s0.extra) as f64,
            ));
        }
        let ca = prof.predict_batch(&shapes) / 8.0;
        let lin = f.linear_fwd(docs_per_chunk * doc_len / cp) / (8.0 * cluster.linear_flops());
        let compute = ca + lin;
        // TP=8 shards KV heads: each GPU gathers 1/8 of the KV stream
        // over its own NIC.
        let bytes_per_rank =
            (docs_per_chunk * doc_len / cp * model.kv_bytes_per_token()) as f64 / 8.0;
        let ag = cluster.allgather_time(bytes_per_rank, cp, true);
        t.row(&[
            nodes.to_string(),
            format!("{:.2}", compute * 1e3),
            format!("{:.2}", ag * 1e3),
            format!("{:.0}%", ag / (ag + compute) * 100.0),
        ]);
    }
    t.print();
    println!("paper: AG share rises from ~3% (2 nodes) to ~40% (32 nodes).\n");

    let mut t = Table::new(
        "Fig. 3b — memory breakdown under per-doc CP (worst rank)",
        &["nodes (CP)", "weights+opt", "activations", "gathered KV", "KV share"],
    );
    for &nodes in &[2usize, 4, 8, 16] {
        let cluster = ClusterConfig::h200(nodes);
        let cp = nodes;
        // Per-rank resident tokens chosen to fill memory (as the paper
        // scales batch with nodes): fixed per-rank token budget.
        let resident = mem
            .max_tokens_per_gpu(&cluster, 8, 1)
            .min(512 * 1024 / 8 * cp) // cap by workload
            / 2;
        // Worst rank retains the full gathered KV of every document it
        // participates in: resident × cp tokens across layers.
        let gathered = (resident * cp) as f64 * mem.n_layers;
        let b = mem.breakdown(resident, gathered, 8, 1);
        t.row(&[
            nodes.to_string(),
            distca::util::tables::bytes(b.weights_optimizer),
            distca::util::tables::bytes(b.activations),
            distca::util::tables::bytes(b.gathered_kv),
            format!("{:.0}%", b.kv_fraction() * 100.0),
        ]);
    }
    t.print();
    println!("paper: KV fraction grows ~3% (2 nodes) to ~30% (16 nodes).");
}
