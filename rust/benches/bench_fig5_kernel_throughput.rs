//! Figure 5: core-attention kernel throughput vs document-shard length.
//!
//! The paper profiles FA2 on 32K-token chunks packed with shards of fixed
//! length and random context sizes, showing throughput collapses below
//! the 128-token kernel tile and plateaus above it. We regenerate the
//! series from the analytic profiler (H200-calibrated) and — when
//! `artifacts/profiler_grid.json` exists — from the measured
//! interpret-mode Pallas grid.
//!
//! Then the *real* kernels: the oracle (`ReferenceCaCompute`) against
//! the fast path (`kernel::FastCaCompute`, scalar and AVX2 renderings,
//! then thread scaling) on a fixed Fig. 5-flavoured fused batch. The
//! shape is deterministic — same tasks in quick and full mode, only the
//! iteration counts differ — so the emitted `BENCH_kernel.json` has a
//! hand-auditable schema for the `distca drift` gate: `bit_exact` and
//! the shape leaves are seeded facts, every timing-derived number is a
//! wall-clock key. Machine-readable output: `BENCH_kernel.json` in the
//! working directory.

use distca::bench::BenchRunner;
use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::Profiler;
use distca::elastic::ReferenceCaCompute;
use distca::kernel::{avx2_available, FastCaCompute, KernelBackend};
use distca::model::FlopsModel;
use distca::runtime::ca_exec::{synthetic_task, CaTaskTensors};
use distca::util::json::Json;
use distca::util::rng::{seed_from_env, Rng};
use distca::util::tables::Table;

/// The measured fused batch: 4 CA-tasks of 64 query rows over context
/// ramps 128/256/384/512 at llama-ish GQA dims. Shapes are fixed (not
/// sampled) so `flops_per_iter` is a committed constant the drift gate
/// can check exactly.
const KB_TASKS: usize = 4;
const KB_Q: usize = 64;
const KB_KV_BASE: usize = 128;
const KB_H: usize = 8;
const KB_HKV: usize = 2;
const KB_D: usize = 64;

fn kernel_batch(seed: u64) -> Vec<CaTaskTensors> {
    let mut rng = Rng::new(seed ^ 0xF16_5);
    (0..KB_TASKS)
        .map(|i| {
            let kv = KB_KV_BASE * (1 + (i % 4));
            synthetic_task(&mut rng, KB_Q, kv, KB_H, KB_HKV, KB_D)
        })
        .collect()
}

/// Nominal FLOPs of one batch pass (4·h·d per (q, kv) pair, causality
/// ignored): a fixed label for throughput math, identical in quick and
/// full mode.
fn kernel_batch_flops() -> f64 {
    (0..KB_TASKS)
        .map(|i| {
            let kv = KB_KV_BASE * (1 + (i % 4));
            4.0 * (KB_H * KB_D * KB_Q * kv) as f64
        })
        .sum()
}

fn bits_equal(a: &[Vec<f32>], b: &[Vec<f32>]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.len() == y.len() && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
        })
}

fn main() {
    let model = ModelConfig::llama3_8b();
    let f = FlopsModel::new(&model);
    let cluster = ClusterConfig::h200(1);
    let prof = Profiler::analytic(&f, &cluster);

    let shard_lens = [16usize, 32, 64, 128, 256, 512, 1024, 2048, 4096];
    let chunk_tokens = 32_768;
    let mut rng = Rng::new(seed_from_env(5));

    let mut t = Table::new(
        "Fig. 5 — CA throughput vs shard length (32K-token fused chunk)",
        &["shard len", "throughput (TFLOP/s)", "% of plateau", "note"],
    );
    // Plateau reference: long shards.
    let plateau = prof.throughput(4096.0, 16384.0);
    for &len in &shard_lens {
        // Random context per shard, as in the paper's methodology.
        let n_shards = chunk_tokens / len.max(1);
        let mut tput_sum = 0.0;
        let samples = 16;
        for _ in 0..samples {
            let mut shapes = Vec::with_capacity(n_shards);
            for _ in 0..n_shards {
                let ctx = len + (rng.gen_index(0, 16) * len);
                shapes.push((len as f64, ctx as f64));
            }
            let lat = prof.predict_batch(&shapes);
            let flops: f64 = shapes
                .iter()
                .map(|&(q, kv)| 4.0 * f.h_q * Profiler::causal_pairs(q, kv))
                .sum();
            tput_sum += flops / lat;
        }
        let tput = tput_sum / samples as f64;
        let note = if len < 128 {
            "below tile: padding waste"
        } else {
            "at/above tile"
        };
        t.row(&[
            len.to_string(),
            format!("{:.1}", tput / 1e12),
            format!("{:.0}%", tput / plateau * 100.0),
            note.into(),
        ]);
    }
    t.print();
    println!("paper: throughput drops sharply below 128 tokens, flat above — the knee that sets the 128-multiple sharding rule.\n");

    // Measured Pallas grid, if present.
    let grid_path = distca::runtime::artifacts_dir().join("profiler_grid.json");
    if let Ok(j) = distca::util::json::parse_file(&grid_path) {
        if let Ok(measured) = Profiler::from_json(&j) {
            let mut t = Table::new(
                "measured interpret-mode Pallas grid (CPU; shape calibration only)",
                &["q len", "kv len", "latency (ms)"],
            );
            for (qi, &q) in measured.q_grid.iter().enumerate() {
                for (ki, &kv) in measured.kv_grid.iter().enumerate() {
                    if ki % 2 == 0 {
                        t.row(&[
                            format!("{q}"),
                            format!("{kv}"),
                            format!("{:.2}", measured.latency[qi][ki] * 1e3),
                        ]);
                    }
                }
            }
            t.print();
        }
    } else {
        println!("(no artifacts/profiler_grid.json — run `make artifacts PROFILE=1` for measured Pallas numbers)");
    }

    // ── Measured: oracle vs fast-path kernel on a fixed fused batch ──
    let seed = seed_from_env(7);
    let batch = kernel_batch(seed);
    let flops_per_iter = kernel_batch_flops();
    let avx2 = avx2_available();

    let (h, hkv, d) = (KB_H, KB_HKV, KB_D);
    let oracle = ReferenceCaCompute::new(h, hkv, d);
    let want = oracle.run_batch(&batch);

    // Admission check before timing anything: every fast rendering must
    // reproduce the oracle's bytes exactly, or the numbers below would
    // describe a different function.
    let scalar1 = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Scalar).threads(1);
    assert!(
        bits_equal(&want, &scalar1.run_batch(&batch).expect("scalar run")),
        "fast scalar kernel diverged from oracle bytes"
    );
    let scalar8 = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Scalar).threads(8);
    assert!(
        bits_equal(&want, &scalar8.run_batch(&batch).expect("scalar 8t run")),
        "threaded partition changed kernel bytes"
    );
    if avx2 {
        let v1 = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Avx2).threads(1);
        assert!(
            bits_equal(&want, &v1.run_batch(&batch).expect("avx2 run")),
            "fast AVX2 kernel diverged from oracle bytes"
        );
    }

    let mut r = BenchRunner::new("fig5 kernel — oracle vs fast path (4 tasks, 64q, kv 128..512)");
    let m = r.bench("oracle 1t", || oracle.run_batch(&batch));
    let oracle_mean = m.mean_s;
    let m = r.bench("fast scalar 1t", || scalar1.run_batch(&batch).unwrap());
    let scalar_mean = m.mean_s;
    let avx2_mean = if avx2 {
        let v1 = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Avx2).threads(1);
        let m = r.bench("fast avx2 1t", || v1.run_batch(&batch).unwrap());
        m.mean_s
    } else {
        0.0
    };

    // Thread scaling on the auto-detected backend. Thread counts are
    // pinned (not host-derived) so the emitted array keeps a fixed
    // length for the drift gate.
    let mut thread_rows = Vec::new();
    let mut t1_mean = 0.0_f64;
    for &n in &[1usize, 2, 4] {
        let k = FastCaCompute::new(h, hkv, d).threads(n);
        let m = r.bench(&format!("fast auto {n}t"), || k.run_batch(&batch).unwrap());
        let mean = m.mean_s;
        if n == 1 {
            t1_mean = mean;
        }
        let speedup = if mean > 0.0 { t1_mean / mean } else { 0.0 };
        thread_rows.push(Json::obj(vec![
            ("threads", Json::Num(n as f64)),
            ("mean_s", Json::Num(mean)),
            ("tasks_per_s", Json::Num(if mean > 0.0 { KB_TASKS as f64 / mean } else { 0.0 })),
            ("speedup_vs_1t", Json::Num(speedup)),
            ("parallel_efficiency", Json::Num(speedup / n as f64)),
        ]));
    }
    r.finish();

    let gflops = |mean: f64| if mean > 0.0 { flops_per_iter / mean / 1e9 } else { 0.0 };
    let speedup = |mean: f64| if mean > 0.0 { oracle_mean / mean } else { 0.0 };
    println!(
        "fast path vs oracle (bit-exact): scalar {:.2}x, avx2 {} ({})",
        speedup(scalar_mean),
        if avx2 { format!("{:.2}x", speedup(avx2_mean)) } else { "n/a".into() },
        distca::kernel::kernel_label(),
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("kernel_throughput".into())),
        ("seed", Json::Num(seed as f64)),
        ("n_tasks", Json::Num(KB_TASKS as f64)),
        ("q_len", Json::Num(KB_Q as f64)),
        ("n_heads", Json::Num(KB_H as f64)),
        ("n_kv_heads", Json::Num(KB_HKV as f64)),
        ("head_dim", Json::Num(KB_D as f64)),
        ("flops_per_iter", Json::Num(flops_per_iter)),
        ("bit_exact", Json::Bool(true)),
        ("avx2_detected", Json::Num(if avx2 { 1.0 } else { 0.0 })),
        (
            "oracle",
            Json::obj(vec![
                ("mean_s", Json::Num(oracle_mean)),
                ("gflops", Json::Num(gflops(oracle_mean))),
            ]),
        ),
        (
            "scalar",
            Json::obj(vec![
                ("mean_s", Json::Num(scalar_mean)),
                ("gflops", Json::Num(gflops(scalar_mean))),
                ("speedup_vs_oracle", Json::Num(speedup(scalar_mean))),
            ]),
        ),
        (
            "avx2",
            Json::obj(vec![
                ("mean_s", Json::Num(avx2_mean)),
                ("gflops", Json::Num(gflops(avx2_mean))),
                ("speedup_vs_oracle", Json::Num(speedup(avx2_mean))),
            ]),
        ),
        ("threads", Json::Arr(thread_rows)),
    ]);
    let path = "BENCH_kernel.json";
    std::fs::write(path, out.to_string_pretty()).expect("write BENCH_kernel.json");
    println!("\nwrote {path}");
}
