//! Figure 5: core-attention kernel throughput vs document-shard length.
//!
//! The paper profiles FA2 on 32K-token chunks packed with shards of fixed
//! length and random context sizes, showing throughput collapses below
//! the 128-token kernel tile and plateaus above it. We regenerate the
//! series from the analytic profiler (H200-calibrated) and — when
//! `artifacts/profiler_grid.json` exists — from the measured
//! interpret-mode Pallas grid.

use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::Profiler;
use distca::model::FlopsModel;
use distca::util::rng::{seed_from_env, Rng};
use distca::util::tables::Table;

fn main() {
    let model = ModelConfig::llama3_8b();
    let f = FlopsModel::new(&model);
    let cluster = ClusterConfig::h200(1);
    let prof = Profiler::analytic(&f, &cluster);

    let shard_lens = [16usize, 32, 64, 128, 256, 512, 1024, 2048, 4096];
    let chunk_tokens = 32_768;
    let mut rng = Rng::new(seed_from_env(5));

    let mut t = Table::new(
        "Fig. 5 — CA throughput vs shard length (32K-token fused chunk)",
        &["shard len", "throughput (TFLOP/s)", "% of plateau", "note"],
    );
    // Plateau reference: long shards.
    let plateau = prof.throughput(4096.0, 16384.0);
    for &len in &shard_lens {
        // Random context per shard, as in the paper's methodology.
        let n_shards = chunk_tokens / len.max(1);
        let mut tput_sum = 0.0;
        let samples = 16;
        for _ in 0..samples {
            let mut shapes = Vec::with_capacity(n_shards);
            for _ in 0..n_shards {
                let ctx = len + (rng.gen_index(0, 16) * len);
                shapes.push((len as f64, ctx as f64));
            }
            let lat = prof.predict_batch(&shapes);
            let flops: f64 = shapes
                .iter()
                .map(|&(q, kv)| 4.0 * f.h_q * Profiler::causal_pairs(q, kv))
                .sum();
            tput_sum += flops / lat;
        }
        let tput = tput_sum / samples as f64;
        let note = if len < 128 {
            "below tile: padding waste"
        } else {
            "at/above tile"
        };
        t.row(&[
            len.to_string(),
            format!("{:.1}", tput / 1e12),
            format!("{:.0}%", tput / plateau * 100.0),
            note.into(),
        ]);
    }
    t.print();
    println!("paper: throughput drops sharply below 128 tokens, flat above — the knee that sets the 128-multiple sharding rule.\n");

    // Measured Pallas grid, if present.
    let grid_path = distca::runtime::artifacts_dir().join("profiler_grid.json");
    if let Ok(j) = distca::util::json::parse_file(&grid_path) {
        if let Ok(measured) = Profiler::from_json(&j) {
            let mut t = Table::new(
                "measured interpret-mode Pallas grid (CPU; shape calibration only)",
                &["q len", "kv len", "latency (ms)"],
            );
            for (qi, &q) in measured.q_grid.iter().enumerate() {
                for (ki, &kv) in measured.kv_grid.iter().enumerate() {
                    if ki % 2 == 0 {
                        t.row(&[
                            format!("{q}"),
                            format!("{kv}"),
                            format!("{:.2}", measured.latency[qi][ki] * 1e3),
                        ]);
                    }
                }
            }
            t.print();
        }
    } else {
        println!("(no artifacts/profiler_grid.json — run `make artifacts PROFILE=1` for measured Pallas numbers)");
    }
}
