//! Integration tests for the unified tracing plane (`distca::obs`):
//!
//! * the threaded `ElasticCoordinator` with a wall-clock recorder emits
//!   a structurally valid trace whose per-server phase seconds sum to
//!   the tick wall-time (the acceptance bound is ±5%; the recorder's
//!   phase-accounting identity gives ~0) and survives a disk roundtrip;
//! * the loopback TCP pool (real sockets, worker-side `Stats` frames)
//!   produces the same identity from worker-measured compute shipped
//!   over the wire — gated behind `DISTCA_NET_TESTS=1` like the rest of
//!   the socket suite;
//! * the discrete-event simulator drives the *same* recorder API on the
//!   virtual clock and yields a trace that validates, abuts tick
//!   windows, and renders through `distca report`'s breakdown;
//! * the lineage log is an exact audit of recovery: per-tick hop totals
//!   by reason equal the `TickStats` counters bump-for-bump, and the
//!   reconstructed journeys carry the re-dispatch chains.

use std::sync::Arc;

use distca::elastic::{
    run_elastic_sim_obs, ElasticCfg, ElasticCoordinator, ElasticSimCfg, ElasticTask, FaultPlan,
    ReferenceCaCompute,
};
use distca::obs::report::breakdown;
use distca::obs::trace::{export, parse_trace, read_trace, validate, write_trace};
use distca::obs::{ClockSource, Phase, Recorder, Span};
use distca::runtime::ca_exec::synthetic_task;
use distca::util::rng::Rng;

const H: usize = 2;
const HKV: usize = 1;
const D: usize = 4;

fn synthetic_tick(rng: &mut Rng, tick: usize, n: usize, alive: &[usize]) -> Vec<ElasticTask> {
    let mut tasks = Vec::new();
    for i in 0..2 * n {
        let len = if i % 3 == 0 { 128 } else { 64 };
        let server = alive[i % alive.len()];
        tasks.push(ElasticTask {
            doc: (tick * 1000 + i) as u32,
            q_start: 0,
            server,
            home: server,
            tensors: synthetic_task(rng, len, len, H, HKV, D),
        });
    }
    tasks
}

/// Per (tick, server): compute + wire_wait + gather seconds must equal
/// the tick span within `tol_frac` of the tick time. Returns how many
/// (tick, server) rows were checked so callers can assert coverage.
fn assert_phase_sums(spans: &[Span], tol_frac: f64) -> usize {
    use std::collections::BTreeMap;
    let mut tick_dur: BTreeMap<usize, f64> = BTreeMap::new();
    for s in spans {
        if s.phase == Phase::Tick {
            tick_dur.insert(s.tick, s.dur_s);
        }
    }
    let mut sums: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    for s in spans {
        if let (Phase::Compute | Phase::WireWait | Phase::Gather, Some(srv)) = (s.phase, s.server)
        {
            *sums.entry((s.tick, srv)).or_insert(0.0) += s.dur_s;
        }
    }
    for (&(tick, srv), &sum) in &sums {
        let dur = tick_dur[&tick];
        assert!(
            (sum - dur).abs() <= tol_frac * dur + 1e-9,
            "tick {tick} server {srv}: phases sum to {sum}s vs tick {dur}s \
             (off by {:.1}%)",
            100.0 * (sum - dur).abs() / dur.max(1e-12),
        );
    }
    sums.len()
}

#[test]
fn threaded_trace_validates_and_phases_sum_to_tick_time() {
    const N: usize = 3;
    const TICKS: usize = 3;
    let mut co =
        ElasticCoordinator::spawn(N, ElasticCfg::default(), |_| {
            Box::new(ReferenceCaCompute::new(H, HKV, D))
        });
    let recorder = Recorder::new_wall();
    co.set_recorder(Arc::clone(&recorder));
    let fault = FaultPlan::new();
    let mut rng = Rng::new(7);
    for tick in 0..TICKS {
        let alive = co.pool.schedulable();
        let tasks = synthetic_tick(&mut rng, tick, N, &alive);
        let outputs = co.run_tick(tick, &tasks, &fault).expect("tick");
        assert_eq!(outputs.len(), tasks.len());
    }
    co.shutdown().expect("shutdown");

    let spans = recorder.spans();
    validate(&spans).expect("threaded spans must satisfy nesting + disjointness");
    let ticks_seen = spans.iter().filter(|s| s.phase == Phase::Tick).count();
    assert_eq!(ticks_seen, TICKS, "one tick container per tick");
    // In-process workers report measured compute through the
    // late-bound cell, so the trace must carry compute spans.
    assert!(
        spans.iter().any(|s| s.phase == Phase::Compute),
        "no compute spans in the threaded trace"
    );
    let rows = assert_phase_sums(&spans, 0.05);
    assert!(rows >= TICKS, "expected per-server rows in every tick, got {rows}");

    // Disk roundtrip: the exported file is what Perfetto loads and what
    // `distca report` reads back — it must validate identically.
    let path = std::env::temp_dir()
        .join(format!("distca_obs_threaded_{}.json", std::process::id()));
    write_trace(&recorder, &path).expect("write trace");
    let parsed = read_trace(&path).expect("read trace");
    let _ = std::fs::remove_file(&path);
    assert_eq!(parsed.clock, ClockSource::Wall);
    validate(&parsed.spans).expect("roundtripped spans must still validate");
    assert_phase_sums(&parsed.spans, 0.05);
    let report = breakdown(&parsed).expect("breakdown");
    assert_eq!(report.ticks.len(), TICKS);
    assert!(report.render().contains("Per-tick summary"));
}

/// The lineage acceptance bar: every recovery counter the coordinator
/// bumps has exactly one adjacent lineage hop with the matching reason,
/// so for any faulted run the per-tick [`hop_totals`] derived from the
/// lineage log must equal the `TickStats` counters *exactly* —
/// `Speculative` ↔ `redispatched`, `Kill` ↔ `send_failovers`,
/// `Oom` ↔ `oom_evicted`, `Drain` ↔ `drain_redirected` — and the
/// stale-dedup events must equal `duplicates_suppressed`. The journeys
/// reconstructed from the same log must carry the re-dispatch chains
/// `report --lineage` renders.
#[test]
fn lineage_hops_match_tick_stats_counters_exactly() {
    use distca::obs::lineage::{hop_totals, journeys, RedispatchReason};

    const N: usize = 3;
    const TICKS: usize = 4;
    let mut co = ElasticCoordinator::spawn(N, ElasticCfg::default(), |_| {
        Box::new(ReferenceCaCompute::new(H, HKV, D))
    });
    let recorder = Recorder::new_wall();
    co.set_recorder(Arc::clone(&recorder));
    // Kill server 1 mid-tick 1 (deadline re-dispatch and/or send
    // failover), then overflow server 2's arena at tick 2 (OOM
    // eviction). Server 0 is never faulted, so the pool survives.
    let fault = FaultPlan::new().kill(1, 1).oom(2, 2);
    let mut rng = Rng::new(23);
    for tick in 0..TICKS {
        let alive = co.pool.schedulable();
        let tasks = synthetic_tick(&mut rng, tick, N, &alive);
        let outputs = co.run_tick(tick, &tasks, &fault).expect("tick");
        assert_eq!(outputs.len(), tasks.len(), "tick {tick}: incomplete gather");
    }
    let stats = co.shutdown().expect("shutdown");
    assert_eq!(stats.len(), TICKS);

    let events = recorder.lineage_events();
    assert!(!events.is_empty(), "a faulted run must leave a lineage log");
    let hops = hop_totals(&events);
    let mut stale_by_tick = std::collections::BTreeMap::<usize, u64>::new();
    for ev in &events {
        if matches!(ev.stage, distca::obs::lineage::LineageStage::StaleDeduped { .. }) {
            *stale_by_tick.entry(ev.tick).or_insert(0) += 1;
        }
    }

    let mut total_hops = 0u64;
    for st in &stats {
        let empty = std::collections::BTreeMap::new();
        let by_reason = hops.get(&st.tick).unwrap_or(&empty);
        let get = |r: RedispatchReason| by_reason.get(&r).copied().unwrap_or(0);
        assert_eq!(
            get(RedispatchReason::Speculative),
            st.redispatched as u64,
            "tick {}: speculative hops vs redispatched",
            st.tick
        );
        assert_eq!(
            get(RedispatchReason::Kill),
            st.send_failovers as u64,
            "tick {}: kill hops vs send_failovers",
            st.tick
        );
        assert_eq!(
            get(RedispatchReason::Oom),
            st.oom_evicted as u64,
            "tick {}: oom hops vs oom_evicted",
            st.tick
        );
        assert_eq!(
            get(RedispatchReason::Drain),
            st.drain_redirected as u64,
            "tick {}: drain hops vs drain_redirected",
            st.tick
        );
        assert_eq!(
            stale_by_tick.get(&st.tick).copied().unwrap_or(0),
            st.duplicates_suppressed as u64,
            "tick {}: stale-dedup events vs duplicates_suppressed",
            st.tick
        );
        total_hops += by_reason.values().sum::<u64>();
    }
    // The scripted faults must actually have forced recovery somewhere —
    // otherwise the equalities above are vacuous.
    assert!(total_hops > 0, "scripted kill/oom produced no lineage hops");

    // Journey reconstruction: every hop shows up in exactly one task's
    // chain, and a faulted tick's chain names the reason.
    let js = journeys(&events);
    let chained: u64 = js.iter().map(|j| u64::from(j.hops())).sum();
    assert_eq!(chained, total_hops, "journeys must account for every hop");
    let faulted = js.iter().find(|j| j.hops() > 0).expect("a re-dispatched journey");
    assert_ne!(faulted.reason_chain(), "-", "chain must name its reasons");
    assert!(
        faulted.completed.is_some(),
        "re-dispatched task {:#x} never completed",
        faulted.tag
    );
}

/// The networked acceptance case: a loopback soak over real TCP
/// sockets, with worker-measured compute arriving on the `Stats` wire
/// path, must produce per-server phase seconds summing (±5%) to the
/// tick wall-time. Gated like the other socket tests.
#[test]
fn loopback_trace_phase_sums_from_wire_stats() {
    if std::env::var("DISTCA_NET_TESTS").is_err() {
        eprintln!("skipping loopback trace test (set DISTCA_NET_TESTS=1 to run)");
        return;
    }
    const N: usize = 4;
    const TICKS: usize = 2;
    let pool = distca::net::loopback::spawn_loopback_pool(N, H, HKV, D).expect("loopback pool");
    let mut co = pool.coordinator(ElasticCfg::default());
    let recorder = Recorder::new_wall();
    co.set_recorder(Arc::clone(&recorder));
    let fault = FaultPlan::new();
    let mut rng = Rng::new(11);
    for tick in 0..TICKS {
        let alive = co.pool.schedulable();
        let tasks = synthetic_tick(&mut rng, tick, N, &alive);
        let outputs = co.run_tick(tick, &tasks, &fault).expect("tick");
        assert_eq!(outputs.len(), tasks.len());
    }
    co.shutdown().expect("shutdown");

    // The loopback harness runs with heartbeats off, so workers flush
    // their span buffers exactly once — right before the Goodbye on
    // worker shutdown. Drain the fabric's event queue until every
    // worker has said goodbye, then feed the Stats payloads into the
    // recorder the same way the serve loop does.
    let mut stats_payloads: Vec<(usize, Vec<f32>)> = Vec::new();
    let mut goodbyes = 0usize;
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while goodbyes < N && std::time::Instant::now() < deadline {
        for ev in pool.fabric.poll_events() {
            match ev {
                distca::net::NetEvent::Stats { rank, payload } => {
                    stats_payloads.push((rank, payload))
                }
                distca::net::NetEvent::Goodbye { .. } => goodbyes += 1,
                _ => {}
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    assert_eq!(goodbyes, N, "every worker must say goodbye at shutdown");
    pool.join().expect("worker join");

    let sink = Some(Arc::clone(&recorder));
    let mut n_obs = 0usize;
    for (rank, payload) in &stats_payloads {
        distca::net::serve::feed_stats(&sink, *rank, payload);
        n_obs += payload.len() / 4;
    }
    assert!(
        n_obs >= 2 * N * TICKS,
        "expected one wire-shipped compute observation per task, got {n_obs}"
    );

    let spans = recorder.spans();
    validate(&spans).expect("loopback spans must validate");
    assert!(spans.iter().any(|s| s.phase == Phase::Compute));
    let rows = assert_phase_sums(&spans, 0.05);
    assert!(rows > 0, "no per-server rows in the loopback trace");
}

#[test]
fn virtual_sim_trace_validates_and_fills_every_tick() {
    use distca::config::run::DataDist;
    use distca::config::{ClusterConfig, ModelConfig};
    use distca::data::distributions::sampler_for;
    use distca::sim::strategies::SimParams;

    const N: usize = 4;
    const TICKS: usize = 2;
    let max_doc = 4096;
    let p = SimParams::new(ModelConfig::tiny_100m(), ClusterConfig::h200(1), 1, 1);
    let batches: Vec<_> = (0..TICKS)
        .map(|t| {
            let mut rng = Rng::new(42 + t as u64 * 7919);
            sampler_for(DataDist::Pretrain, max_doc).sample_tokens(&mut rng, N * max_doc, 0)
        })
        .collect();
    let recorder = Recorder::new_virtual();
    let report = run_elastic_sim_obs(
        &batches,
        N,
        &p,
        &FaultPlan::new(),
        &ElasticSimCfg::default(),
        Some(&recorder),
    )
    .expect("sim");

    let spans = recorder.spans();
    validate(&spans).expect("virtual-clock spans must validate");
    let mut ticks: Vec<&Span> = spans.iter().filter(|s| s.phase == Phase::Tick).collect();
    ticks.sort_by_key(|s| s.tick);
    assert_eq!(ticks.len(), TICKS);
    // Tick windows abut on the simulated timeline and reproduce the
    // sim's own per-tick makespans.
    for (i, t) in ticks.iter().enumerate() {
        assert!(
            (t.dur_s - report.per_tick[i].tick_time).abs() <= 1e-9,
            "tick {i} container {}s vs sim makespan {}s",
            t.dur_s,
            report.per_tick[i].tick_time
        );
    }
    assert!(
        (ticks[1].start_s - (ticks[0].start_s + ticks[0].dur_s)).abs() <= 1e-9,
        "tick windows must abut"
    );
    // Fault-free: compute + gather fill every engaged server's share of
    // the tick exactly (no wire on a simulated fabric).
    let rows = assert_phase_sums(&spans, 0.05);
    assert!(rows > 0);
    assert!(!spans.iter().any(|s| s.phase == Phase::WireWait));

    // One exporter covers both clocks: the same file format parses back
    // as a virtual trace and renders through the report path.
    let parsed = parse_trace(&export(&recorder)).expect("parse");
    assert_eq!(parsed.clock, ClockSource::Virtual);
    validate(&parsed.spans).expect("roundtrip validates");
    assert!(
        parsed.speeds.iter().all(|&(_, _, believed, observed)| believed > 0.0
            && observed.is_none()),
        "sim speed samples carry beliefs only"
    );
    let rep = breakdown(&parsed).expect("breakdown");
    assert_eq!(rep.clock, ClockSource::Virtual);
    assert!(rep.render().contains("virtual clock"));
}
