//! Property tests for the elastic subsystem on the in-repo
//! `util::quickcheck` harness. `FaultPlan` implements `Shrink`, so a
//! failing case reduces to a minimal fault script plus a minimal doc
//! set before panicking.
//!
//! Invariants:
//! * **tokens conserved** — whatever kills/drains/re-dispatches a fault
//!   plan causes, the gathered outputs cover exactly the dispatched
//!   query tokens, each exactly once;
//! * **no double completion** — first-response-wins dedup leaves no
//!   `(doc, q_start)` tag with two kept outputs;
//! * **PoolView bijection** — under arbitrary join/leave/kill/restore/
//!   drain/degrade sequences, the physical↔virtual mapping stays a
//!   bijection over the schedulable set;
//! * **partial drain** — a drained resource never loses (and the
//!   failover layer never re-dispatches) a task it already started.

use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::{
    schedule, schedule_with_beliefs, Item, Profiler, SchedulerCfg, ServerBelief,
};
use distca::elastic::{
    run_elastic_exec, ElasticTask, FaultEvent, FaultPlan, ReferenceCaCompute, ServerPool,
};
use distca::model::FlopsModel;
use distca::runtime::ca_exec::synthetic_task;
use distca::sim::engine::Engine;
use distca::util::quickcheck::{check, ensure, PropResult};
use distca::util::rng::Rng;

const H: usize = 2;
const HKV: usize = 1;
const D: usize = 4;
const N_SERVERS: usize = 3;

/// Sanitize an arbitrary (possibly shrunk) fault plan: server 0 is never
/// killed or drained, so the pool always has a survivor — the same rule
/// `FaultPlan::random` follows. Slow factors are forced valid.
fn sanitize(plan: &FaultPlan) -> FaultPlan {
    let mut out = FaultPlan::new();
    for ev in &plan.events {
        match *ev {
            FaultEvent::Kill { server, tick } if server >= 1 => {
                out.events.push(FaultEvent::Kill { server, tick });
            }
            FaultEvent::Drain { server, tick } if server >= 1 => {
                out.events.push(FaultEvent::Drain { server, tick });
            }
            FaultEvent::Oom { server, tick } if server >= 1 => {
                // OOM victims survive, but they take no re-dispatch this
                // tick — keeping server 0 victim-free keeps a target.
                out.events.push(FaultEvent::Oom { server, tick });
            }
            FaultEvent::Rejoin { server, tick } => {
                out.events.push(FaultEvent::Rejoin { server, tick });
            }
            FaultEvent::Slow { server, tick, factor } => {
                let factor = if factor.is_finite() && factor > 0.0 { factor } else { 0.5 };
                out.events.push(FaultEvent::Slow { server, tick, factor });
            }
            _ => {}
        }
    }
    out
}

/// Build whole-doc CA-tasks from a raw spec; lengths and servers are
/// sanitized so every shrunk input stays well-formed.
fn build_tasks(spec: &[(usize, usize)]) -> Vec<ElasticTask> {
    let mut rng = Rng::new(0xBEEF);
    spec.iter()
        .enumerate()
        .map(|(j, &(len_raw, srv_raw))| {
            let len = 2 * (1 + len_raw % 6); // 2..=12, even
            let server = srv_raw % N_SERVERS;
            ElasticTask {
                doc: j as u32,
                q_start: 0,
                server,
                home: server % 2,
                tensors: synthetic_task(&mut rng, len, len, H, HKV, D),
            }
        })
        .collect()
}

fn gen_task_spec(r: &mut Rng) -> Vec<(usize, usize)> {
    let n = 1 + r.gen_index(0, 8);
    (0..n)
        .map(|_| (r.gen_index(0, 64), r.gen_index(0, 64)))
        .collect()
}

fn gen_fault_plan(r: &mut Rng) -> FaultPlan {
    let mut plan = FaultPlan::new();
    for _ in 0..r.gen_index(0, 4) {
        let server = r.gen_index(0, N_SERVERS + 1); // may exceed capacity
        let tick = r.gen_index(0, 3);
        match r.gen_index(0, 5) {
            0 => plan = plan.kill(server, tick),
            1 => plan = plan.drain(server, tick),
            2 => plan = plan.slow(server, tick, r.gen_f64(0.2, 0.9)),
            3 => plan = plan.oom(server, tick),
            _ => plan = plan.rejoin(server, tick),
        }
    }
    plan
}

/// Run the deterministic exec over two ticks and check conservation,
/// dedup, and the partial-drain contract.
fn exec_invariants(spec: &[(usize, usize)], plan: &FaultPlan) -> PropResult {
    let fault = sanitize(plan);
    let mut pool = ServerPool::new(N_SERVERS);
    let mut compute = ReferenceCaCompute::new(H, HKV, D);
    for tick in 0..2 {
        let tasks = build_tasks(spec);
        let rep = run_elastic_exec(&mut pool, tick, &tasks, &fault, &mut compute)
            .map_err(|e| format!("tick {tick}: {e}"))?;
        ensure(
            rep.outputs.len() == tasks.len(),
            format!("tick {tick}: {} outputs for {} tasks", rep.outputs.len(), tasks.len()),
        )?;
        ensure(rep.duplicates == 0, "deterministic exec produced a duplicate")?;
        // Tokens conserved: the kept outputs cover exactly the
        // dispatched query tokens.
        let sent: usize = tasks.iter().map(|t| t.tensors.q_len).sum();
        let mut got = 0usize;
        for out in &rep.outputs {
            let task = tasks
                .iter()
                .find(|t| t.doc == out.doc && t.q_start == out.q_start)
                .ok_or_else(|| format!("tick {tick}: unknown output doc {}", out.doc))?;
            ensure(
                out.o.len() == task.tensors.q_len * H * D,
                format!("tick {tick}: doc {} wrong output size", out.doc),
            )?;
            got += task.tensors.q_len;
        }
        ensure(got == sent, format!("tick {tick}: {got} tokens gathered of {sent} sent"))?;
        // No task both kept-by-drainee and re-sent.
        for tag in &rep.drain_kept {
            ensure(
                !rep.drain_redirected.contains(tag) && !rep.redispatched.contains(tag),
                format!("tick {tick}: started task {tag} was re-dispatched"),
            )?;
        }
    }
    Ok(())
}

#[test]
fn prop_tokens_conserved_and_no_double_completion() {
    check(
        80,
        |r| (gen_task_spec(r), gen_fault_plan(r)),
        |(spec, plan)| exec_invariants(spec, plan),
    );
}

/// Arbitrary membership op sequences keep the PoolView a bijection.
#[test]
fn prop_pool_view_stays_a_bijection() {
    check(
        120,
        |r| {
            let n = 1 + r.gen_index(0, 12);
            (0..n)
                .map(|_| (r.gen_index(0, 6), r.gen_index(0, 6)))
                .collect::<Vec<(usize, usize)>>()
        },
        |ops| {
            let mut pool = ServerPool::new(2);
            for &(kind, srv_raw) in ops {
                let srv = srv_raw % pool.capacity();
                match kind {
                    0 => {
                        pool.join();
                    }
                    1 => pool.leave(srv),
                    2 => pool.kill(srv),
                    3 => pool.restore(srv),
                    4 => pool.drain(srv),
                    _ => pool.degrade(srv, 0.5),
                }
                if pool.n_schedulable() == 0 {
                    continue; // view() is documented to panic here
                }
                let view = pool.view();
                ensure(
                    view.n() == pool.n_schedulable(),
                    format!("view n {} vs schedulable {}", view.n(), pool.n_schedulable()),
                )?;
                for v in 0..view.n() {
                    let phys = view.to_physical(v);
                    ensure(
                        pool.is_schedulable(phys),
                        format!("virtual {v} maps to unschedulable {phys}"),
                    )?;
                    ensure(
                        view.to_virtual(phys) == Some(v),
                        format!("round-trip failed at virtual {v} (phys {phys})"),
                    )?;
                }
                let mut mapped = 0usize;
                for phys in 0..pool.capacity() {
                    if let Some(v) = view.to_virtual(phys) {
                        mapped += 1;
                        ensure(
                            view.to_physical(v) == phys,
                            format!("round-trip failed at phys {phys} (virt {v})"),
                        )?;
                    } else {
                        ensure(
                            !pool.is_schedulable(phys),
                            format!("schedulable {phys} missing from the view"),
                        )?;
                    }
                }
                ensure(mapped == view.n(), "virtual index space has holes")?;
            }
            Ok(())
        },
    );
}

/// Under any belief-speed vector, the speed-aware plan's predicted
/// makespan never exceeds the uniform (FLOPs-balanced) plan's makespan
/// evaluated under the same speeds: planning with the belief can only
/// help. (Equal-speed vectors reduce both to the identical plan, so the
/// bound is tight there.)
#[test]
fn prop_speed_aware_makespan_no_worse_than_uniform() {
    let m = ModelConfig::llama3_8b();
    let f = FlopsModel::new(&m);
    let prof = Profiler::analytic(&f, &ClusterConfig::h200(1));
    const N: usize = 4;
    check(
        30,
        |r: &mut Rng| {
            let n_items = 1 + r.gen_index(0, 12);
            let items: Vec<(u64, u64)> = (0..n_items)
                .map(|_| (r.gen_range(1, 48), r.gen_range(0, N as u64)))
                .collect();
            // Speeds in tenths: 0.1 ..= 1.0 per server.
            let speeds: Vec<u64> = (0..N).map(|_| r.gen_range(1, 11)).collect();
            (items, speeds)
        },
        |(spec, speeds_raw)| {
            if spec.is_empty() {
                return Ok(());
            }
            let items: Vec<Item> = spec
                .iter()
                .enumerate()
                .map(|(d, &(l, h))| {
                    Item::whole_doc(d as u32, (1 + l as usize) * 256, h as usize % N)
                })
                .collect();
            let speeds: Vec<f64> =
                speeds_raw.iter().map(|&s| (1 + s.min(9)) as f64 / 10.0).collect();
            if speeds.len() != N {
                return Ok(()); // shrunk vector: speeds no longer per-server
            }
            let cfg = SchedulerCfg::default();
            let uniform = schedule(&items, N, &f, &prof, &m, &cfg);
            let aware = schedule_with_beliefs(
                &items,
                &ServerBelief::from_speeds(&speeds, 0.0),
                &f,
                &prof,
                &m,
                &cfg,
            );
            aware.validate(&items, &f).map_err(|e| e)?;
            let uni_mk = uniform.makespan_under(&speeds);
            // The bound is exact in the uniform-speed limit (identical
            // plans); the 1% grace absorbs greedy knife-edges on
            // unsplittable minimum-width shards plus float drift.
            ensure(
                aware.predicted_makespan() <= uni_mk * 1.01 + 1e-12,
                format!(
                    "belief-aware makespan {} exceeds uniform {uni_mk} at speeds {speeds:?}",
                    aware.predicted_makespan()
                ),
            )
        },
    );
}

/// Engine-level partial drain: a drained resource never cuts a started
/// task, and everything it revoked was unstarted.
#[test]
fn prop_drain_never_revokes_started_tasks() {
    check(
        100,
        |r| {
            let n = 1 + r.gen_index(0, 10);
            let tasks: Vec<(usize, usize)> = (0..n)
                .map(|_| (r.gen_index(0, 2), 1 + r.gen_index(0, 50)))
                .collect();
            (tasks, r.gen_index(0, 40))
        },
        |(tasks, drain_at_raw)| {
            let mut e = Engine::new(2);
            let ids: Vec<usize> = tasks
                .iter()
                .map(|&(res, dur)| e.add_task(res, dur as f64 / 10.0, &[]))
                .collect();
            e.drain_resource(0, *drain_at_raw as f64 / 10.0);
            e.run();
            for &id in &ids {
                if e.is_done(id) {
                    continue;
                }
                ensure(
                    !e.started(id),
                    format!("drained resource cut started task {id}"),
                )?;
            }
            // Everything on the undrained resource completes.
            for (&id, &(res, _)) in ids.iter().zip(tasks.iter()) {
                if res == 1 {
                    ensure(e.is_done(id), format!("task {id} on live resource not done"))?;
                }
            }
            Ok(())
        },
    );
}
