//! Property tests for the multi-tenant gateway's queueing, admission,
//! and accounting layers.
//!
//! The invariants the gateway's fairness and audit claims rest on:
//!
//! 1. **No starvation**: under self-clocked WFQ, a backlogged tenant is
//!    served within a bounded number of pops regardless of how much
//!    higher-weight traffic competes — the bound follows from the
//!    finish-stamp ordering, not from luck.
//! 2. **Budget safety**: an admitted wave never exceeds the pair or
//!    byte budget, for any seeded task mix and any budget.
//! 3. **Liveness**: a backlogged queue always admits at least one task
//!    per wave (enqueue-time oversize rejection guarantees the head
//!    fits a fresh wave).
//! 4. **Conservation**: the double-entry ledger's per-tenant rows sum
//!    exactly to the independently tracked pool totals across any
//!    seeded admit → dispatch → complete/redispatch history.

use std::collections::BTreeMap;

use distca::gateway::{Admission, Ledger, QueuedTask, SloClass, WaveBudget, WfqQueue};
use distca::util::rng::Rng;

fn slo(rng: &mut Rng) -> SloClass {
    SloClass::ALL[rng.gen_index(0, 3)]
}

#[test]
fn wfq_serves_every_backlogged_tenant_within_a_weighted_bound() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(0x0FA1_0000 ^ seed);
        let n_tenants = 2 + rng.gen_index(0, 8); // 2..=9
        let mut q = WfqQueue::new();
        for t in 0..n_tenants {
            let w = slo(&mut rng).weight();
            for seq in 0..(1 + rng.gen_index(0, 20)) as u32 {
                // Uniform cost: the SCFQ bound below is then exact — a
                // tenant at weight w_min (1) has its head stamped at
                // cost/1, and any competitor at weight w_max (4) fits at
                // most 4 tasks under that stamp.
                q.push(QueuedTask::new(t as u32, seq, 8, 0, 8.0), w);
            }
        }
        // Every backlogged tenant must be served within one weighted
        // round: at most (w_max / w_min) = 4 pops per competitor before
        // the slowest tenant's head stamp is reached. Starvation would
        // blow past this immediately (the backlogs run 20 deep).
        let mut seen = BTreeMap::new();
        let backlogged = q.backlogged_tenants();
        let mut pops = 0usize;
        while seen.len() < backlogged {
            let task = q.pop().expect("queue drained before every tenant was served");
            seen.entry(task.tenant).or_insert(pops);
            pops += 1;
            assert!(
                pops <= 4 * n_tenants,
                "seed {seed}: {pops} pops before all {backlogged} tenants served"
            );
        }
    }
}

#[test]
fn late_arrival_to_a_loaded_queue_is_served_promptly() {
    for seed in 0..40u64 {
        let mut rng = Rng::new(0x1A7E_0000 ^ seed);
        let mut q = WfqQueue::new();
        // A deep, heavy backlog for one Batch-class tenant...
        for seq in 0..400u32 {
            q.push(QueuedTask::new(0, seq, 32, 0, 32.0), SloClass::Batch.weight());
        }
        // Burn some service so vtime is mid-stream, not zero.
        for _ in 0..rng.gen_index(0, 50) {
            q.pop();
        }
        // ...then an Interactive tenant shows up with one small task.
        q.push(QueuedTask::new(1, 0, 8, 0, 8.0), SloClass::Interactive.weight());
        let mut pops = 0usize;
        loop {
            let t = q.pop().expect("queue drained without serving the late tenant");
            pops += 1;
            if t.tenant == 1 {
                break;
            }
            assert!(pops < 8, "seed {seed}: late interactive tenant starved behind backlog");
        }
    }
}

#[test]
fn admitted_waves_never_exceed_either_budget_and_make_progress() {
    for seed in 0..80u64 {
        let mut rng = Rng::new(0xADB1_0000 ^ seed);
        let budget = WaveBudget::new(
            rng.gen_f64(200.0, 5000.0),
            rng.gen_f64(100.0, 3000.0),
        );
        let mut adm = Admission::new(budget);
        let mut queued = 0usize;
        let mut rejected = 0usize;
        for t in 0..(1 + rng.gen_index(0, 12)) as u32 {
            let class = slo(&mut rng);
            for seq in 0..(1 + rng.gen_index(0, 15)) as u32 {
                let len = 2 + rng.gen_index(0, 40);
                let bytes = rng.gen_f64(1.0, 400.0);
                if adm.push(QueuedTask::new(t, seq, len, 0, bytes), class) {
                    queued += 1;
                } else {
                    rejected += 1;
                }
            }
        }
        assert_eq!(adm.rejected_oversize, rejected, "seed {seed}");
        let mut drained = 0usize;
        let mut waves = 0usize;
        while !adm.queue().is_empty() {
            let (wave, stats) = adm.admit_wave();
            // Liveness: a backlogged queue admits at least the head.
            assert!(!wave.is_empty(), "seed {seed}: wave admitted nothing with a backlog");
            // Safety: both budgets hold with room to spare for f64 sums.
            let pairs: f64 = wave.iter().map(|t| t.cost).sum();
            let bytes: f64 = wave.iter().map(|t| t.bytes).sum();
            assert!(pairs <= budget.pairs * (1.0 + 1e-12), "seed {seed}: pairs {pairs}");
            assert!(bytes <= budget.bytes * (1.0 + 1e-12), "seed {seed}: bytes {bytes}");
            assert_eq!(stats.admitted, wave.len(), "seed {seed}");
            drained += wave.len();
            waves += 1;
            assert!(waves <= queued + 1, "seed {seed}: admission failed to make progress");
        }
        assert_eq!(drained, queued, "seed {seed}: tasks lost between push and admit");
    }
}

#[test]
fn ledger_conserves_tasks_and_bytes_across_random_histories() {
    for seed in 0..60u64 {
        let mut rng = Rng::new(0x1ED6_0000 ^ seed);
        let mut ledger = Ledger::new();
        let n_tenants = 1 + rng.gen_index(0, 30);
        // Drive a plausible admit → dispatch → complete history with
        // rejections and re-dispatches mixed in, then audit.
        let mut admitted: Vec<(u32, SloClass)> = Vec::new();
        for t in 0..n_tenants as u32 {
            let class = slo(&mut rng);
            for _ in 0..rng.gen_index(0, 12) {
                ledger.note_arrival(t, class);
                if rng.gen_index(0, 10) == 0 {
                    ledger.note_rejected(t, class);
                } else {
                    let len = 4 + rng.gen_index(0, 60);
                    ledger.note_admit(
                        t,
                        class,
                        (len * 40) as f64,
                        4.0 * 64.0 * (len * len) as f64,
                        rng.gen_index(0, 6),
                    );
                    admitted.push((t, class));
                }
            }
        }
        for &(t, class) in &admitted {
            if rng.gen_index(0, 8) == 0 {
                ledger.note_redispatch(t, class, 1 + rng.gen_index(0, 2));
            }
            ledger.note_complete(t, class);
        }
        let errs = ledger.conservation_errors();
        assert!(errs.is_empty(), "seed {seed}: {errs:?}");
        let pool = ledger.pool();
        assert_eq!(pool.admitted, admitted.len(), "seed {seed}");
        assert_eq!(pool.completed, pool.admitted, "seed {seed}");
        // And the audit actually bites: drop one completion attribution
        // (complete a tenant that never admitted) and it must fire.
        ledger.note_complete(n_tenants as u32 + 7, SloClass::Standard);
        assert!(!ledger.conservation_errors().is_empty(), "seed {seed}: audit is vacuous");
    }
}

#[test]
fn accounting_survives_a_full_gateway_run_end_to_end() {
    // The in-process gateway enforces conservation, bit-exactness, and
    // drain-completeness internally (run_gateway errors otherwise);
    // this pins the external view: totals line up across the report.
    let cfg = distca::gateway::GatewayCfg {
        tenants: 24,
        workers: 2,
        waves: 4,
        arrival_rate: 24.0,
        seed: 11,
        ..Default::default()
    };
    let report = distca::gateway::run_gateway(&cfg).expect("gateway run");
    let pool = report.ledger.pool();
    assert_eq!(pool.admitted + pool.rejected, pool.arrived);
    assert_eq!(pool.completed, pool.admitted);
    let row_admitted: usize = report.ledger.tenants().values().map(|r| r.admitted).sum();
    assert_eq!(row_admitted, pool.admitted);
    let wave_admitted: usize = report.per_wave.iter().map(|r| r.admitted).sum();
    assert_eq!(wave_admitted, pool.admitted, "per-wave rows disagree with the ledger");
}
