//! Property tests for the mergeable log-bucketed histograms
//! (`obs::hist`) — the invariants the live `/metrics` quantiles and the
//! post-hoc merged STATS roll-ups rest on:
//!
//! 1. **Merge exactness**: a histogram merged from randomly-split
//!    shards has *identical* bucket counts, total count, and min/max
//!    bit patterns to the histogram of the concatenated samples, for
//!    any shard split — so every quantile query agrees exactly. (The
//!    running `sum` is f64 and addition order differs across shard
//!    splits, so it is checked to relative epsilon, not bits.) This is
//!    what makes per-worker shards roll up into one truthful tail.
//! 2. **Quantile error bound across magnitudes**: for samples anywhere
//!    from ~10 ns to minutes, the estimated quantile is within the
//!    documented [`QUANTILE_REL_ERROR`] of the true nearest-rank sample
//!    quantile.
//! 3. **Bit-exact serialization**: `to_json` → JSON text → parse →
//!    `from_json` reproduces the histogram exactly, including the
//!    sum/min/max bit patterns that plain JSON numbers cannot carry.

use distca::obs::hist::{LogHistogram, MIN_V, QUANTILE_REL_ERROR};
use distca::util::json::parse;
use distca::util::rng::Rng;

/// Random positive duration spanning ~9 decades (log-uniform).
fn random_duration(rng: &mut Rng) -> f64 {
    let exp = rng.gen_f64(-8.0, 2.8); // 10 ns .. ~10 min
    10f64.powf(exp)
}

#[test]
fn merged_shards_equal_the_concatenated_histogram() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(0xB16_B00B5 ^ seed);
        let n = rng.gen_index(1, 500);
        let n_shards = rng.gen_index(1, 8);
        let mut whole = LogHistogram::new();
        let mut shards: Vec<LogHistogram> = (0..n_shards).map(|_| LogHistogram::new()).collect();
        for _ in 0..n {
            let v = random_duration(&mut rng);
            whole.observe(v);
            shards[rng.gen_index(0, n_shards)].observe(v);
        }
        let mut merged = LogHistogram::new();
        for s in &shards {
            merged.merge(s);
        }
        // Every quantile-relevant field is exact: counts, min/max bits.
        assert_eq!(merged.count(), whole.count(), "seed {seed}: count");
        assert_eq!(
            merged.min().to_bits(),
            whole.min().to_bits(),
            "seed {seed}: min bits"
        );
        assert_eq!(
            merged.max().to_bits(),
            whole.max().to_bits(),
            "seed {seed}: max bits"
        );
        assert_eq!(
            merged.to_json().get("buckets").unwrap().to_string_compact(),
            whole.to_json().get("buckets").unwrap().to_string_compact(),
            "seed {seed}: bucket counts differ"
        );
        for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(
                merged.quantile(q).map(f64::to_bits),
                whole.quantile(q).map(f64::to_bits),
                "seed {seed}: quantile {q} differs"
            );
        }
        // f64 addition is order-sensitive, so the running sum is only
        // epsilon-equal across shard splits.
        let rel = (merged.sum() - whole.sum()).abs() / whole.sum().max(f64::MIN_POSITIVE);
        assert!(rel < 1e-9, "seed {seed}: sum rel err {rel}");
    }
}

#[test]
fn quantile_error_bound_holds_across_magnitudes() {
    // One decade-wide sample cloud per magnitude, ns to minutes: the
    // relative-error bound must hold at every scale the system measures
    // (kernel inner loops through full soaks).
    for (m, &mag) in [1e-7, 1e-5, 1e-3, 1e-1, 10.0, 600.0].iter().enumerate() {
        let mut rng = Rng::new(0xC0FFEE ^ m as u64);
        let n = 2000;
        let mut h = LogHistogram::new();
        let mut samples: Vec<f64> = Vec::with_capacity(n);
        for _ in 0..n {
            let v = mag * rng.gen_f64(0.3, 3.0);
            assert!(v > MIN_V, "test samples must sit above the floor bucket");
            h.observe(v);
            samples.push(v);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.01, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0] {
            // Nearest-rank truth: smallest sample at cumulative rank
            // >= ceil(q * n).
            let rank = ((q * n as f64).ceil() as usize).max(1);
            let truth = samples[rank - 1];
            let est = h.quantile(q).unwrap();
            let rel = (est - truth).abs() / truth;
            assert!(
                rel <= QUANTILE_REL_ERROR,
                "magnitude {mag}: q={q} est {est} vs true {truth} (rel {rel})"
            );
        }
    }
}

#[test]
fn serialization_roundtrips_bit_exact_through_json_text() {
    for seed in 0..20u64 {
        let mut rng = Rng::new(0x5E_12_1A_11 ^ seed);
        let mut h = LogHistogram::new();
        for _ in 0..rng.gen_index(0, 300) {
            h.observe(random_duration(&mut rng));
        }
        // Include degenerate observations: zeros and clamped values all
        // have to survive the wire form too.
        if rng.gen_index(0, 2) == 0 {
            h.observe(0.0);
            h.observe(1e9);
        }
        let text = h.to_json().to_string_compact();
        let back = LogHistogram::from_json(&parse(&text).unwrap()).unwrap();
        assert_eq!(back, h, "seed {seed}");
        assert_eq!(back.sum().to_bits(), h.sum().to_bits(), "seed {seed}: sum bits");
        assert_eq!(back.min().to_bits(), h.min().to_bits(), "seed {seed}: min bits");
        assert_eq!(back.max().to_bits(), h.max().to_bits(), "seed {seed}: max bits");
        assert_eq!(
            back.to_json().to_string_compact(),
            text,
            "seed {seed}: re-serialization differs"
        );
    }
}
