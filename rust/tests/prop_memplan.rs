//! Property tests for the memory-disaggregated execution model (§5,
//! Fig. 3b) on the in-repo `util::quickcheck` harness.
//!
//! Invariants:
//! * **budget is hard** — whatever allocation/free/in-place sequence a
//!   tick performs, the arena's peak never exceeds its budget, and a
//!   failed allocation leaves the arena untouched;
//! * **no leak across ticks** — every byte a tick allocates is freed by
//!   tick end, so consecutive ticks on one arena start from zero;
//! * **in-place reuse never aliases** — live regions stay pairwise
//!   disjoint through arbitrary interleavings of allocs, frees, and
//!   O-overwrites-Q in-place writes;
//! * **memory-feasible plans stay within ε** — with a budget the
//!   unconstrained optimum fits under (1.5× its peak), the §4.2
//!   scheduler emits plans whose per-server arena peaks respect the
//!   budget *and* whose compute load still meets the tolerance.

use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::scheduler::schedule;
use distca::coordinator::{Item, Profiler, SchedulerCfg};
use distca::memplan::{replay_server_tick, Arena, MemReport, SlotId};
use distca::model::FlopsModel;
use distca::util::quickcheck::{check, ensure, PropResult};
use distca::util::rng::Rng;

/// One scripted arena op: sizes are raw and sanitized in the driver so
/// shrunk inputs stay well-formed.
type OpSpec = (usize, usize); // (kind, size_raw)

fn drive_arena(budget: u64, ops: &[OpSpec]) -> PropResult {
    let mut arena = Arena::new(budget);
    let mut live: Vec<SlotId> = Vec::new();
    for &(kind, size_raw) in ops {
        match kind % 3 {
            0 => {
                // Alloc (may legitimately fail on a full arena).
                let len = 1 + (size_raw as u64 % budget);
                let before = (arena.live_bytes(), arena.n_live());
                match arena.alloc(len) {
                    Ok(s) => live.push(s),
                    Err(e) => {
                        ensure(
                            e.requested == len && e.budget == budget,
                            format!("OomError misreports: {e}"),
                        )?;
                        ensure(
                            (arena.live_bytes(), arena.n_live()) == before,
                            "failed alloc mutated the arena",
                        )?;
                    }
                }
            }
            1 => {
                // Free the oldest live slot.
                if !live.is_empty() {
                    arena.free(live.remove(0));
                }
            }
            _ => {
                // In-place overwrite of the newest live slot (O over Q).
                if let Some(&s) = live.last() {
                    let cur = arena.slot_len(s);
                    let new_len = 1 + (size_raw as u64 % cur);
                    arena.write_in_place(s, new_len);
                }
            }
        }
        ensure(
            arena.peak_bytes() <= budget,
            format!("peak {} exceeded budget {budget}", arena.peak_bytes()),
        )?;
        ensure(
            arena.live_bytes() <= arena.peak_bytes(),
            "live exceeds recorded peak",
        )?;
        arena.check_no_alias()?;
    }
    for s in live {
        arena.free(s);
    }
    arena.check_drained()?;
    Ok(())
}

#[test]
fn prop_arena_peak_never_exceeds_budget() {
    check(
        150,
        |r: &mut Rng| {
            let budget = 64 + r.gen_range(0, 4096);
            let n = 1 + r.gen_index(0, 40);
            let ops: Vec<OpSpec> = (0..n)
                .map(|_| (r.gen_index(0, 3), r.gen_index(0, 1 << 16)))
                .collect();
            (budget, ops)
        },
        |(budget, ops)| drive_arena((*budget).max(1), ops),
    );
}

#[test]
fn prop_every_alloc_freed_by_tick_end() {
    // Tick replay semantics on ONE arena across consecutive ticks: tick
    // boundaries must leave zero live bytes, so tick N+1's peak cannot
    // be inflated by tick N's leftovers.
    check(
        100,
        |r: &mut Rng| {
            let n = 1 + r.gen_index(0, 8);
            (0..n)
                .map(|_| (1 + r.gen_index(0, 64), 1 + r.gen_index(0, 64)))
                .collect::<Vec<(usize, usize)>>()
        },
        |shapes| {
            // Shrunk inputs may reach zero; sizes stay ≥ 1 byte.
            let shapes: Vec<(u64, u64)> = shapes
                .iter()
                .map(|&(q, kv)| (q.max(1) as u64, kv.max(1) as u64))
                .collect();
            let mut arena = Arena::unbounded();
            let mut tick_peaks = Vec::new();
            for _tick in 0..2 {
                let base_allocs = arena.n_allocs();
                let mut slots = Vec::new();
                for &(q, kv) in &shapes {
                    slots.push((arena.alloc(q).unwrap(), arena.alloc(kv).unwrap()));
                }
                let mut outs = Vec::new();
                for &(q_slot, kv_slot) in &slots {
                    let q_len = arena.slot_len(q_slot);
                    outs.push(arena.write_in_place(q_slot, q_len));
                    arena.free(kv_slot);
                }
                for o in outs {
                    arena.free(o);
                }
                ensure(
                    arena.live_bytes() == 0 && arena.n_live() == 0,
                    format!("tick leaked {} bytes", arena.live_bytes()),
                )?;
                ensure(
                    arena.n_allocs() - base_allocs == 2 * shapes.len() as u64,
                    "in-place O must not count as a fresh allocation",
                )?;
                tick_peaks.push(arena.peak_bytes());
            }
            ensure(
                tick_peaks[0] == tick_peaks[1],
                format!("peak drifted across ticks: {tick_peaks:?}"),
            )
        },
    );
}

#[test]
fn prop_in_place_reuse_never_aliases() {
    // replay_server_tick is the production replay: its arena must stay
    // alias-free and its in-place peak must equal Σ(Q+KV) exactly.
    let m = ModelConfig::llama3_8b();
    check(
        100,
        |r: &mut Rng| {
            let n = 1 + r.gen_index(0, 10);
            (0..n)
                .map(|_| {
                    let q = 1 + r.gen_index(0, 512);
                    let kv = q + r.gen_index(0, 512);
                    (q, kv)
                })
                .collect::<Vec<(usize, usize)>>()
        },
        |shapes| {
            // Shrunk inputs may reach zero; token counts stay ≥ 1.
            let shapes: Vec<(usize, usize)> =
                shapes.iter().map(|&(q, kv)| (q.max(1), kv.max(1))).collect();
            let arena = replay_server_tick(&shapes, &m, 0, true)
                .map_err(|e| format!("unbounded replay failed: {e}"))?;
            arena.check_no_alias()?;
            arena.check_drained()?;
            let expect: u64 = shapes
                .iter()
                .map(|&(q, kv)| {
                    (q * m.q_bytes_per_token() + kv * m.kv_bytes_per_token()) as u64
                })
                .sum();
            ensure(
                arena.peak_bytes() == expect,
                format!("in-place peak {} != Σ(Q+KV) {expect}", arena.peak_bytes()),
            )?;
            // Out-of-place costs strictly more on non-empty ticks.
            let outp = replay_server_tick(&shapes, &m, 0, false)
                .map_err(|e| format!("{e}"))?
                .peak_bytes();
            ensure(
                shapes.is_empty() || outp > arena.peak_bytes(),
                "O-overwrites-Q must save bytes",
            )
        },
    );
}

#[test]
fn prop_mem_feasible_plans_stay_within_tolerance() {
    let m = ModelConfig::llama3_8b();
    let f = FlopsModel::new(&m);
    let prof = Profiler::analytic(&f, &ClusterConfig::h200(1));
    const N_SERVERS: usize = 4;
    const TOL: f64 = 0.3;
    check(
        40,
        |r: &mut Rng| {
            let n = 2 + r.gen_index(0, 12);
            (0..n)
                .map(|_| (1 + r.gen_index(0, 32), r.gen_index(0, N_SERVERS)))
                .collect::<Vec<(usize, usize)>>()
        },
        |spec| {
            let items: Vec<Item> = spec
                .iter()
                .enumerate()
                .map(|(d, &(len_units, home))| {
                    Item::whole_doc(d as u32, len_units.clamp(1, 32) * 512, home % N_SERVERS)
                })
                .collect();
            let base = SchedulerCfg { tolerance: TOL, ..Default::default() };
            let un = schedule(&items, N_SERVERS, &f, &prof, &m, &base);
            let max_un = un.server_load.iter().cloned().fold(0.0f64, f64::max);
            if max_un > un.target_load * (1.0 + TOL) + 1e-9 {
                // The instance is not ε-balanceable at all (e.g. one doc
                // dominates); memory feasibility is moot.
                return Ok(());
            }
            let free_mem = MemReport::for_plan(&un, &m, 0.0)
                .map_err(|e| format!("unbounded replay failed: {e}"))?;
            let budget = 1.5 * free_mem.max_peak();
            let cfg = SchedulerCfg { mem_budget: budget, ..base };
            let plan = schedule(&items, N_SERVERS, &f, &prof, &m, &cfg);
            plan.validate(&items, &f)?;
            let mem = MemReport::for_plan(&plan, &m, budget)
                .map_err(|e| format!("plan exceeds its own budget: {e}"))?;
            ensure(
                mem.within_budget(),
                format!(
                    "peaks {:?} exceed budget {budget}",
                    mem.per_server_peak
                ),
            )?;
            let max_load = plan.server_load.iter().cloned().fold(0.0f64, f64::max);
            ensure(
                max_load <= plan.target_load * (1.0 + TOL) + 1e-9,
                format!(
                    "memory-feasible plan broke compute tolerance: max {max_load} \
                     vs target {} (ε = {TOL}); unconstrained max was {max_un}",
                    plan.target_load
                ),
            )
        },
    );
}
