//! Property tests for the net wire codec.
//!
//! The invariants the TCP fabric's bit-exactness rests on:
//!
//! 1. **Split-independence**: however the byte stream is chopped into
//!    read chunks (mid-header, mid-payload, many frames per chunk),
//!    the decoded frame sequence is identical — f32 *bit patterns*
//!    included.
//! 2. **Exact counts beyond 2^24**: the payload element count is an
//!    integer wire field, never an f32 value-cast (the PR-1 bit-cast
//!    header regression class) — a 2^24+1-element payload round-trips
//!    exactly.
//! 3. **Descriptive rejection**: truncated streams and frames claiming
//!    more than the payload cap fail loudly, with errors naming the
//!    problem, never a silent drop or a bogus frame.
//! 4. **Tenant integrity** (DCA2): the header's tenant field is derived
//!    from the tag on encode, validated against the tag on decode, and
//!    survives any split boundary — including one inside the tenant
//!    field itself.

use distca::exchange::transport::Message;
use distca::net::codec::{
    Frame, FrameDecoder, FrameKind, HEADER_BYTES, MAGIC, MAX_PAYLOAD_ELEMS, MAX_WIRE_TENANT,
};
use distca::server::{tag_wire_tenant, tenant_doc, MAX_TENANTS, MAX_TENANT_SEQ};
use distca::util::rng::Rng;

fn random_kind(rng: &mut Rng) -> FrameKind {
    match rng.gen_index(0, 6) {
        0 => FrameKind::Msg,
        1 => FrameKind::Hello,
        2 => FrameKind::Config,
        3 => FrameKind::Heartbeat,
        4 => FrameKind::Drain,
        _ => FrameKind::Goodbye,
    }
}

/// Finite payloads only: the equality assertion uses `PartialEq`, and
/// NaN bit-patterns get their own dedicated test below.
/// Roughly half the `Msg` frames carry a tenant-tagged doc in the tag's
/// high bits, so every split-boundary sweep also exercises the DCA2
/// tenant field; the header tenant is always the tag-derived value
/// (anything else is malformed by design and tested separately).
fn random_frame(rng: &mut Rng) -> Frame {
    let len = rng.gen_index(0, 40);
    let kind = random_kind(rng);
    let tag = if kind == FrameKind::Msg && rng.gen_index(0, 2) == 0 {
        let doc = tenant_doc(
            rng.gen_index(0, MAX_TENANTS as usize) as u32,
            rng.gen_index(0, MAX_TENANT_SEQ as usize) as u32,
        );
        ((doc as u64) << 32) | rng.gen_index(0, 4096) as u64
    } else {
        rng.next_u64()
    };
    Frame {
        kind,
        dst: rng.gen_index(0, 64) as u32,
        src: rng.next_u64(),
        tenant: if kind == FrameKind::Msg { tag_wire_tenant(tag) } else { 0 },
        tag,
        wave: rng.gen_index(0, 2) as u8,
        epoch: rng.next_u64() >> 8,
        payload: (0..len).map(|_| rng.gen_f64(-1e6, 1e6) as f32).collect(),
    }
}

#[test]
fn roundtrip_under_arbitrary_split_boundaries() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(0xC0DE_C0DE ^ seed);
        let frames: Vec<Frame> =
            (0..1 + rng.gen_index(0, 6)).map(|_| random_frame(&mut rng)).collect();
        let mut bytes = Vec::new();
        for f in &frames {
            bytes.extend_from_slice(&f.encode().unwrap());
        }
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        let mut off = 0usize;
        while off < bytes.len() {
            // Chunk sizes from 1 byte (worst case: every boundary is a
            // split) up to ~100 bytes (several splits per frame).
            let step = 1 + rng.gen_index(0, 97);
            let end = (off + step).min(bytes.len());
            dec.push(&bytes[off..end]);
            off = end;
            while let Some(f) = dec.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, frames, "seed {seed}: split decoding diverged");
        dec.finish().unwrap();
    }
}

#[test]
fn byte_at_a_time_decoding_matches_whole_buffer() {
    let mut rng = Rng::new(7);
    let f = random_frame(&mut rng);
    let bytes = f.encode().unwrap();
    let mut dec = FrameDecoder::new();
    let mut got = None;
    for (i, &b) in bytes.iter().enumerate() {
        dec.push(&[b]);
        if let Some(frame) = dec.next_frame().unwrap() {
            assert_eq!(i, bytes.len() - 1, "frame completed before its last byte");
            got = Some(frame);
        }
    }
    assert_eq!(got.expect("frame never completed"), f);
}

#[test]
fn nan_and_bitcast_header_words_survive_bit_for_bit() {
    // The elastic payload layout ships bit-cast u32 headers inside f32
    // slots; some of those bit patterns are NaNs. The codec must carry
    // the *bits*, not the values.
    let patterns: Vec<u32> =
        vec![0x7FC0_1234, 0xFFC0_0000, 0x0000_0001, 0x8000_0000, u32::MAX, (1 << 24) + 1];
    let f = Frame {
        kind: FrameKind::Msg,
        dst: 0,
        src: 0,
        tag: 1,
        wave: 0,
        epoch: 0,
        payload: patterns.iter().map(|&b| f32::from_bits(b)).collect(),
    };
    let mut dec = FrameDecoder::new();
    dec.push(&f.encode().unwrap());
    let g = dec.next_frame().unwrap().unwrap();
    let got: Vec<u32> = g.payload.iter().map(|w| w.to_bits()).collect();
    assert_eq!(got, patterns);
}

#[test]
fn payload_count_beyond_f32_mantissa_is_exact() {
    // 2^24 + 1 elements: a value-cast f32 length would round this to
    // 2^24 and corrupt the stream; the u32 count field must not.
    let n = (1usize << 24) + 1;
    let mut payload = vec![0.0f32; n];
    payload[n - 1] = 42.5;
    let f = Frame { kind: FrameKind::Msg, dst: 3, src: 7, tag: 9, wave: 0, epoch: 0, payload };
    let bytes = f.encode().unwrap();
    assert_eq!(bytes.len(), HEADER_BYTES + 4 * n);
    let mut dec = FrameDecoder::new();
    dec.push(&bytes);
    let g = dec.next_frame().unwrap().unwrap();
    assert_eq!(g.payload.len(), n);
    assert_eq!(g.payload[n - 1], 42.5);
    assert_eq!(g.payload[n - 2], 0.0);
    dec.finish().unwrap();
}

#[test]
fn truncated_stream_rejected_with_descriptive_error() {
    let mut rng = Rng::new(11);
    let f = random_frame(&mut rng);
    let bytes = f.encode().unwrap();
    // Cut anywhere: mid-header and mid-payload both stay pending, and
    // EOF turns "pending" into a loud truncation error.
    for cut in [1, HEADER_BYTES - 1, HEADER_BYTES, bytes.len() - 1] {
        if cut >= bytes.len() {
            continue;
        }
        let mut dec = FrameDecoder::new();
        dec.push(&bytes[..cut]);
        assert!(dec.next_frame().unwrap().is_none(), "cut {cut}: frame from partial bytes");
        let err = dec.finish().unwrap_err();
        assert!(err.to_string().contains("truncated"), "cut {cut}: {err}");
    }
}

#[test]
fn oversized_frame_rejected_with_descriptive_error() {
    // Decode side: a header claiming more than the cap is rejected
    // before any allocation.
    let mut hdr = Vec::new();
    hdr.extend_from_slice(&MAGIC.to_le_bytes());
    hdr.push(1); // Msg
    hdr.extend_from_slice(&0u32.to_le_bytes());
    hdr.extend_from_slice(&0u64.to_le_bytes());
    hdr.extend_from_slice(&0u64.to_le_bytes());
    hdr.push(0); // wave
    hdr.extend_from_slice(&0u64.to_le_bytes()); // epoch
    hdr.extend_from_slice(&0u32.to_le_bytes()); // tenant
    hdr.extend_from_slice(&(MAX_PAYLOAD_ELEMS + 1).to_le_bytes());
    let mut dec = FrameDecoder::new();
    dec.push(&hdr);
    let err = dec.next_frame().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("oversized"), "{msg}");
    assert!(msg.contains(&MAX_PAYLOAD_ELEMS.to_string()), "cap not named: {msg}");
}

#[test]
fn garbage_prefix_rejected_not_skipped() {
    let mut rng = Rng::new(13);
    let mut bytes = vec![0x00, 0x11, 0x22, 0x33];
    bytes.extend_from_slice(&random_frame(&mut rng).encode().unwrap());
    let mut dec = FrameDecoder::new();
    dec.push(&bytes);
    // A length-prefixed stream has no resync point: corrupt magic is a
    // hard error, never a silent scan-forward.
    assert!(dec.next_frame().is_err());
}

#[test]
fn tenant_field_survives_splits_inside_the_tenant_bytes() {
    // A tenant-tagged frame chopped at every possible boundary —
    // including offsets 34..38, *inside* the tenant field — decodes to
    // the same frame, tenant included.
    let doc = tenant_doc(MAX_TENANTS - 1, MAX_TENANT_SEQ - 1);
    let tag = ((doc as u64) << 32) | 17;
    let f = Frame::msg(3, Message { src: 1, tag, payload: vec![1.5, -2.5] });
    assert_eq!(f.tenant, MAX_TENANTS, "max tenant id maps to the max wire tenant");
    let bytes = f.encode().unwrap();
    for cut in 1..bytes.len() {
        let mut dec = FrameDecoder::new();
        dec.push(&bytes[..cut]);
        assert!(dec.next_frame().unwrap().is_none(), "cut {cut}: early frame");
        dec.push(&bytes[cut..]);
        let g = dec.next_frame().unwrap().unwrap();
        assert_eq!(g, f, "cut {cut}: tenant frame diverged");
        assert_eq!(g.tenant, MAX_TENANTS);
        dec.finish().unwrap();
    }
}

#[test]
fn corrupted_tenant_field_rejected_descriptively() {
    // Flip the wire tenant of an untenanted Msg frame to a nonzero
    // value: the decoder must call out the tag/header disagreement.
    let f = Frame::msg(0, Message { src: 2, tag: 5, payload: vec![1.0] });
    let mut bytes = f.encode().unwrap();
    bytes[34] = 9; // tenant field little-endian low byte
    let mut dec = FrameDecoder::new();
    dec.push(&bytes);
    let err = dec.next_frame().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("malformed tenant"), "{msg}");
    assert!(msg.contains("9"), "claimed tenant not named: {msg}");
}

#[test]
fn out_of_range_tenant_field_rejected_before_payload() {
    // A header claiming a tenant beyond the 15-bit space is rejected
    // from the header alone — no payload bytes needed.
    let f = Frame::msg(0, Message { src: 2, tag: 5, payload: vec![1.0; 8] });
    let mut bytes = f.encode().unwrap();
    bytes[34..38].copy_from_slice(&(MAX_WIRE_TENANT + 1).to_le_bytes());
    let mut dec = FrameDecoder::new();
    dec.push(&bytes[..HEADER_BYTES]);
    let err = dec.next_frame().unwrap_err();
    assert!(err.to_string().contains("exceeds"), "{err}");
}

#[test]
fn truncation_inside_the_tenant_field_is_flagged_at_eof() {
    let f = Frame::msg(1, Message { src: 0, tag: 3, payload: vec![2.0] });
    let bytes = f.encode().unwrap();
    for cut in 34..38 {
        let mut dec = FrameDecoder::new();
        dec.push(&bytes[..cut]);
        assert!(dec.next_frame().unwrap().is_none(), "cut {cut}: frame from partial header");
        let err = dec.finish().unwrap_err();
        assert!(err.to_string().contains("truncated"), "cut {cut}: {err}");
    }
}

#[test]
fn coordinator_src_sentinel_roundtrips_through_message() {
    let m = Message { src: usize::MAX, tag: (1 << 63) | 5, payload: vec![2.0] };
    let f = Frame::msg(9, m.clone());
    let mut dec = FrameDecoder::new();
    dec.push(&f.encode().unwrap());
    let g = dec.next_frame().unwrap().unwrap();
    assert_eq!(g.dst, 9);
    assert_eq!(g.into_message(), m);
}
