//! Property tests for the net wire codec.
//!
//! The invariants the TCP fabric's bit-exactness rests on:
//!
//! 1. **Split-independence**: however the byte stream is chopped into
//!    read chunks (mid-header, mid-payload, many frames per chunk),
//!    the decoded frame sequence is identical — f32 *bit patterns*
//!    included.
//! 2. **Exact counts beyond 2^24**: the payload element count is an
//!    integer wire field, never an f32 value-cast (the PR-1 bit-cast
//!    header regression class) — a 2^24+1-element payload round-trips
//!    exactly.
//! 3. **Descriptive rejection**: truncated streams and frames claiming
//!    more than the payload cap fail loudly, with errors naming the
//!    problem, never a silent drop or a bogus frame.
//! 4. **Tenant integrity** (DCA2): the header's tenant field is derived
//!    from the tag on encode, validated against the tag on decode, and
//!    survives any split boundary — including one inside the tenant
//!    field itself. The DCA3 `trace` field rides the same sweeps: every
//!    random frame carries a random 64-bit trace id that must survive
//!    bit-exact.
//! 5. **Zero-copy discipline**: decoding into pooled recv buffers
//!    changes no bits, strands no buffers on error paths, and the
//!    borrowed task views it feeds keep the worker's in-place arena
//!    writes alias-free.

use distca::elastic::decode_elastic_view;
use distca::exchange::transport::Message;
use distca::memplan::Arena;
use distca::net::codec::{
    Frame, FrameDecoder, FrameKind, PayloadPool, HEADER_BYTES, MAGIC, MAX_PAYLOAD_ELEMS,
    MAX_WIRE_TENANT,
};
use distca::server::{tag_wire_tenant, tenant_doc, MAX_TENANTS, MAX_TENANT_SEQ};
use distca::util::rng::Rng;

fn random_kind(rng: &mut Rng) -> FrameKind {
    match rng.gen_index(0, 6) {
        0 => FrameKind::Msg,
        1 => FrameKind::Hello,
        2 => FrameKind::Config,
        3 => FrameKind::Heartbeat,
        4 => FrameKind::Drain,
        _ => FrameKind::Goodbye,
    }
}

/// Finite payloads only: the equality assertion uses `PartialEq`, and
/// NaN bit-patterns get their own dedicated test below.
/// Roughly half the `Msg` frames carry a tenant-tagged doc in the tag's
/// high bits, so every split-boundary sweep also exercises the DCA2
/// tenant field; the header tenant is always the tag-derived value
/// (anything else is malformed by design and tested separately).
fn random_frame(rng: &mut Rng) -> Frame {
    let len = rng.gen_index(0, 40);
    let kind = random_kind(rng);
    let tag = if kind == FrameKind::Msg && rng.gen_index(0, 2) == 0 {
        let doc = tenant_doc(
            rng.gen_index(0, MAX_TENANTS as usize) as u32,
            rng.gen_index(0, MAX_TENANT_SEQ as usize) as u32,
        );
        ((doc as u64) << 32) | rng.gen_index(0, 4096) as u64
    } else {
        rng.next_u64()
    };
    Frame {
        kind,
        dst: rng.gen_index(0, 64) as u32,
        src: rng.next_u64(),
        tenant: if kind == FrameKind::Msg { tag_wire_tenant(tag) } else { 0 },
        tag,
        wave: rng.gen_index(0, 2) as u8,
        epoch: rng.next_u64() >> 8,
        trace: rng.next_u64(),
        payload: (0..len).map(|_| rng.gen_f64(-1e6, 1e6) as f32).collect(),
    }
}

#[test]
fn roundtrip_under_arbitrary_split_boundaries() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(0xC0DE_C0DE ^ seed);
        let frames: Vec<Frame> =
            (0..1 + rng.gen_index(0, 6)).map(|_| random_frame(&mut rng)).collect();
        let mut bytes = Vec::new();
        for f in &frames {
            bytes.extend_from_slice(&f.encode().unwrap());
        }
        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        let mut off = 0usize;
        while off < bytes.len() {
            // Chunk sizes from 1 byte (worst case: every boundary is a
            // split) up to ~100 bytes (several splits per frame).
            let step = 1 + rng.gen_index(0, 97);
            let end = (off + step).min(bytes.len());
            dec.push(&bytes[off..end]);
            off = end;
            while let Some(f) = dec.next_frame().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, frames, "seed {seed}: split decoding diverged");
        dec.finish().unwrap();
    }
}

#[test]
fn byte_at_a_time_decoding_matches_whole_buffer() {
    let mut rng = Rng::new(7);
    let f = random_frame(&mut rng);
    let bytes = f.encode().unwrap();
    let mut dec = FrameDecoder::new();
    let mut got = None;
    for (i, &b) in bytes.iter().enumerate() {
        dec.push(&[b]);
        if let Some(frame) = dec.next_frame().unwrap() {
            assert_eq!(i, bytes.len() - 1, "frame completed before its last byte");
            got = Some(frame);
        }
    }
    assert_eq!(got.expect("frame never completed"), f);
}

#[test]
fn nan_and_bitcast_header_words_survive_bit_for_bit() {
    // The elastic payload layout ships bit-cast u32 headers inside f32
    // slots; some of those bit patterns are NaNs. The codec must carry
    // the *bits*, not the values.
    let patterns: Vec<u32> =
        vec![0x7FC0_1234, 0xFFC0_0000, 0x0000_0001, 0x8000_0000, u32::MAX, (1 << 24) + 1];
    let f = Frame {
        kind: FrameKind::Msg,
        dst: 0,
        src: 0,
        tenant: 0,
        tag: 1,
        wave: 0,
        epoch: 0,
        trace: 0,
        payload: patterns.iter().map(|&b| f32::from_bits(b)).collect(),
    };
    let mut dec = FrameDecoder::new();
    dec.push(&f.encode().unwrap());
    let g = dec.next_frame().unwrap().unwrap();
    let got: Vec<u32> = g.payload.iter().map(|w| w.to_bits()).collect();
    assert_eq!(got, patterns);
}

#[test]
fn payload_count_beyond_f32_mantissa_is_exact() {
    // 2^24 + 1 elements: a value-cast f32 length would round this to
    // 2^24 and corrupt the stream; the u32 count field must not.
    let n = (1usize << 24) + 1;
    let mut payload = vec![0.0f32; n];
    payload[n - 1] = 42.5;
    let f = Frame {
        kind: FrameKind::Msg,
        dst: 3,
        src: 7,
        tenant: 0,
        tag: 9,
        wave: 0,
        epoch: 0,
        trace: 0,
        payload,
    };
    let bytes = f.encode().unwrap();
    assert_eq!(bytes.len(), HEADER_BYTES + 4 * n);
    let mut dec = FrameDecoder::new();
    dec.push(&bytes);
    let g = dec.next_frame().unwrap().unwrap();
    assert_eq!(g.payload.len(), n);
    assert_eq!(g.payload[n - 1], 42.5);
    assert_eq!(g.payload[n - 2], 0.0);
    dec.finish().unwrap();
}

#[test]
fn truncated_stream_rejected_with_descriptive_error() {
    let mut rng = Rng::new(11);
    let f = random_frame(&mut rng);
    let bytes = f.encode().unwrap();
    // Cut anywhere: mid-header and mid-payload both stay pending, and
    // EOF turns "pending" into a loud truncation error.
    for cut in [1, HEADER_BYTES - 1, HEADER_BYTES, bytes.len() - 1] {
        if cut >= bytes.len() {
            continue;
        }
        let mut dec = FrameDecoder::new();
        dec.push(&bytes[..cut]);
        assert!(dec.next_frame().unwrap().is_none(), "cut {cut}: frame from partial bytes");
        let err = dec.finish().unwrap_err();
        assert!(err.to_string().contains("truncated"), "cut {cut}: {err}");
    }
}

#[test]
fn oversized_frame_rejected_with_descriptive_error() {
    // Decode side: a header claiming more than the cap is rejected
    // before any allocation.
    let mut hdr = Vec::new();
    hdr.extend_from_slice(&MAGIC.to_le_bytes());
    hdr.push(1); // Msg
    hdr.extend_from_slice(&0u32.to_le_bytes());
    hdr.extend_from_slice(&0u64.to_le_bytes());
    hdr.extend_from_slice(&0u64.to_le_bytes());
    hdr.push(0); // wave
    hdr.extend_from_slice(&0u64.to_le_bytes()); // epoch
    hdr.extend_from_slice(&0u32.to_le_bytes()); // tenant
    hdr.extend_from_slice(&0u64.to_le_bytes()); // trace
    hdr.extend_from_slice(&(MAX_PAYLOAD_ELEMS + 1).to_le_bytes());
    let mut dec = FrameDecoder::new();
    dec.push(&hdr);
    let err = dec.next_frame().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("oversized"), "{msg}");
    assert!(msg.contains(&MAX_PAYLOAD_ELEMS.to_string()), "cap not named: {msg}");
}

#[test]
fn garbage_prefix_rejected_not_skipped() {
    let mut rng = Rng::new(13);
    let mut bytes = vec![0x00, 0x11, 0x22, 0x33];
    bytes.extend_from_slice(&random_frame(&mut rng).encode().unwrap());
    let mut dec = FrameDecoder::new();
    dec.push(&bytes);
    // A length-prefixed stream has no resync point: corrupt magic is a
    // hard error, never a silent scan-forward.
    assert!(dec.next_frame().is_err());
}

#[test]
fn tenant_field_survives_splits_inside_the_tenant_bytes() {
    // A tenant-tagged frame chopped at every possible boundary —
    // including offsets 34..38, *inside* the tenant field — decodes to
    // the same frame, tenant included.
    let doc = tenant_doc(MAX_TENANTS - 1, MAX_TENANT_SEQ - 1);
    let tag = ((doc as u64) << 32) | 17;
    let f = Frame::msg(3, Message { src: 1, tag, payload: vec![1.5, -2.5] });
    assert_eq!(f.tenant, MAX_TENANTS, "max tenant id maps to the max wire tenant");
    let bytes = f.encode().unwrap();
    for cut in 1..bytes.len() {
        let mut dec = FrameDecoder::new();
        dec.push(&bytes[..cut]);
        assert!(dec.next_frame().unwrap().is_none(), "cut {cut}: early frame");
        dec.push(&bytes[cut..]);
        let g = dec.next_frame().unwrap().unwrap();
        assert_eq!(g, f, "cut {cut}: tenant frame diverged");
        assert_eq!(g.tenant, MAX_TENANTS);
        dec.finish().unwrap();
    }
}

#[test]
fn corrupted_tenant_field_rejected_descriptively() {
    // Flip the wire tenant of an untenanted Msg frame to a nonzero
    // value: the decoder must call out the tag/header disagreement.
    let f = Frame::msg(0, Message { src: 2, tag: 5, payload: vec![1.0] });
    let mut bytes = f.encode().unwrap();
    bytes[34] = 9; // tenant field little-endian low byte
    let mut dec = FrameDecoder::new();
    dec.push(&bytes);
    let err = dec.next_frame().unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("malformed tenant"), "{msg}");
    assert!(msg.contains("9"), "claimed tenant not named: {msg}");
}

#[test]
fn out_of_range_tenant_field_rejected_before_payload() {
    // A header claiming a tenant beyond the 15-bit space is rejected
    // from the header alone — no payload bytes needed.
    let f = Frame::msg(0, Message { src: 2, tag: 5, payload: vec![1.0; 8] });
    let mut bytes = f.encode().unwrap();
    bytes[34..38].copy_from_slice(&(MAX_WIRE_TENANT + 1).to_le_bytes());
    let mut dec = FrameDecoder::new();
    dec.push(&bytes[..HEADER_BYTES]);
    let err = dec.next_frame().unwrap_err();
    assert!(err.to_string().contains("exceeds"), "{err}");
}

#[test]
fn truncation_inside_the_tenant_field_is_flagged_at_eof() {
    let f = Frame::msg(1, Message { src: 0, tag: 3, payload: vec![2.0] });
    let bytes = f.encode().unwrap();
    for cut in 34..38 {
        let mut dec = FrameDecoder::new();
        dec.push(&bytes[..cut]);
        assert!(dec.next_frame().unwrap().is_none(), "cut {cut}: frame from partial header");
        let err = dec.finish().unwrap_err();
        assert!(err.to_string().contains("truncated"), "cut {cut}: {err}");
    }
}

#[test]
fn coordinator_src_sentinel_roundtrips_through_message() {
    let m = Message { src: usize::MAX, tag: (1 << 63) | 5, payload: vec![2.0] };
    let f = Frame::msg(9, m.clone());
    let mut dec = FrameDecoder::new();
    dec.push(&f.encode().unwrap());
    let g = dec.next_frame().unwrap().unwrap();
    assert_eq!(g.dst, 9);
    assert_eq!(g.into_message(), m);
}

// ---------------------------------------------------------------------
// Zero-copy data plane: pooled recv buffers and in-place arena writes.
// ---------------------------------------------------------------------

/// Pooled decode is byte-for-byte the same decode: frames read into
/// recycled buffers across arbitrary split boundaries carry identical
/// f32 bit patterns (NaN payloads included), and every handed-out
/// buffer is accounted for — recycling them all brings `outstanding`
/// back to zero and parks them on the free list for the next pass.
#[test]
fn pooled_decode_preserves_bits_across_splits_and_recycles_buffers() {
    let pool = PayloadPool::new(64);
    for seed in 0..60u64 {
        let mut rng = Rng::new(0xB00F ^ seed);
        let mut frames: Vec<Frame> =
            (0..2 + rng.gen_index(0, 5)).map(|_| random_frame(&mut rng)).collect();
        // One frame of adversarial bit patterns per round: value-level
        // equality would pass a decoder that canonicalizes NaNs; the
        // to_bits comparison below must not.
        frames.push(Frame {
            kind: FrameKind::Msg,
            dst: 1,
            src: 2,
            tenant: 0,
            tag: 11,
            wave: 0,
            epoch: 0,
            trace: 0,
            payload: [0x7FC0_1234u32, 0xFFC0_0000, 0x0000_0001, 0x8000_0000, u32::MAX]
                .iter()
                .map(|&b| f32::from_bits(b))
                .collect(),
        });
        let mut bytes = Vec::new();
        for f in &frames {
            bytes.extend_from_slice(&f.encode().unwrap());
        }

        let mut dec = FrameDecoder::new();
        let mut got = Vec::new();
        let mut off = 0usize;
        while off < bytes.len() {
            let step = 1 + rng.gen_index(0, 97);
            let end = (off + step).min(bytes.len());
            dec.push(&bytes[off..end]);
            off = end;
            while let Some(f) = dec.next_frame_pooled(&pool).unwrap() {
                got.push(f);
            }
        }
        dec.finish().unwrap();

        assert_eq!(got.len(), frames.len(), "seed {seed}: frame count diverged");
        assert_eq!(pool.outstanding(), got.len() as isize, "seed {seed}: pool accounting");
        for (g, f) in got.iter().zip(&frames) {
            let gb: Vec<u32> = g.payload.iter().map(|w| w.to_bits()).collect();
            let fb: Vec<u32> = f.payload.iter().map(|w| w.to_bits()).collect();
            assert_eq!(gb, fb, "seed {seed}: pooled decode changed payload bits");
        }
        // Consumer done: recycle every payload. The pool must balance
        // exactly — a leak here is a buffer allocated per frame forever.
        for g in got {
            pool.put(g.payload);
        }
        assert_eq!(pool.outstanding(), 0, "seed {seed}: leaked pooled buffers");
        assert!(pool.pooled() > 0, "seed {seed}: nothing parked for reuse");
    }
}

/// Decode-error and partial-frame paths must never strand a pool
/// buffer: the decoder only takes one once the header has validated
/// AND the payload bytes are fully buffered, so truncation, oversized
/// claims, corrupt magic, and malformed tenants all leave the pool
/// untouched.
#[test]
fn pool_buffers_are_not_stranded_on_decode_error_paths() {
    let pool = PayloadPool::new(8);

    // Partial frame: header present, payload incomplete.
    let f = Frame::msg(0, Message { src: 1, tag: 3, payload: vec![1.0, 2.0, 3.0] });
    let bytes = f.encode().unwrap();
    let mut dec = FrameDecoder::new();
    dec.push(&bytes[..bytes.len() - 1]);
    assert!(dec.next_frame_pooled(&pool).unwrap().is_none());
    assert_eq!(pool.outstanding(), 0, "partial frame took a buffer early");

    // Oversized claim: rejected from the header, before any get().
    let mut hdr = Vec::new();
    hdr.extend_from_slice(&MAGIC.to_le_bytes());
    hdr.push(1); // Msg
    hdr.extend_from_slice(&0u32.to_le_bytes());
    hdr.extend_from_slice(&0u64.to_le_bytes());
    hdr.extend_from_slice(&0u64.to_le_bytes());
    hdr.push(0); // wave
    hdr.extend_from_slice(&0u64.to_le_bytes()); // epoch
    hdr.extend_from_slice(&0u32.to_le_bytes()); // tenant
    hdr.extend_from_slice(&0u64.to_le_bytes()); // trace
    hdr.extend_from_slice(&(MAX_PAYLOAD_ELEMS + 1).to_le_bytes());
    let mut dec = FrameDecoder::new();
    dec.push(&hdr);
    assert!(dec.next_frame_pooled(&pool).is_err());
    assert_eq!(pool.outstanding(), 0, "oversized reject stranded a buffer");

    // Corrupt magic: hard error, no resync, no buffer.
    let mut dec = FrameDecoder::new();
    dec.push(&[0xDE, 0xAD, 0xBE, 0xEF, 0, 0, 0, 0]);
    assert!(dec.next_frame_pooled(&pool).is_err());
    assert_eq!(pool.outstanding(), 0, "corrupt magic stranded a buffer");

    // Malformed tenant: full frame buffered, rejected at validation —
    // still before the buffer is taken.
    let f = Frame::msg(0, Message { src: 2, tag: 5, payload: vec![1.0] });
    let mut bytes = f.encode().unwrap();
    bytes[34] = 9;
    let mut dec = FrameDecoder::new();
    dec.push(&bytes);
    assert!(dec.next_frame_pooled(&pool).is_err());
    assert_eq!(pool.outstanding(), 0, "tenant reject stranded a buffer");

    // And a clean decode through the same pool still balances.
    let f = Frame::msg(0, Message { src: 1, tag: 3, payload: vec![4.0, 5.0] });
    let mut dec = FrameDecoder::new();
    dec.push(&f.encode().unwrap());
    let g = dec.next_frame_pooled(&pool).unwrap().unwrap();
    assert_eq!(pool.outstanding(), 1);
    pool.put(g.payload);
    assert_eq!(pool.outstanding(), 0);
    assert_eq!(pool.pooled(), 1);
}

/// The borrowed-view decode plus the worker's in-place arena sequence:
/// `decode_elastic_view` yields slices into the recv buffer (no copy —
/// checked by pointer identity), and the §5 buffer lifecycle the
/// worker mirrors (alloc Q, alloc KV, O overwrites Q in place, KV
/// freed) never aliases live Q bytes.
#[test]
fn elastic_view_is_zero_copy_and_in_place_o_never_aliases_live_q() {
    let (q_len, kv_len, h, hkv, d) = (4usize, 8usize, 2usize, 1usize, 8usize);
    let q_sz = q_len * h * d;
    let kv_sz = kv_len * hkv * d;
    let mut rng = Rng::new(99);
    // Wire layout: [q_len, kv_len, tick, q_sz] bit-cast header words,
    // then Q, K, V flattened.
    let mut payload = vec![
        f32::from_bits(q_len as u32),
        f32::from_bits(kv_len as u32),
        f32::from_bits(0),
        f32::from_bits(q_sz as u32),
    ];
    for _ in 0..q_sz + 2 * kv_sz {
        payload.push(rng.gen_f64(-1.0, 1.0) as f32);
    }

    let view = decode_elastic_view(&payload, q_len, kv_len).unwrap();
    assert_eq!(view.q.len(), q_sz);
    assert_eq!(view.k.len(), kv_sz);
    assert_eq!(view.v.len(), kv_sz);
    // Zero-copy: the view's slices are the payload's own bytes.
    assert!(std::ptr::eq(view.q.as_ptr(), payload[4..].as_ptr()));
    assert!(std::ptr::eq(view.k.as_ptr(), payload[4 + q_sz..].as_ptr()));
    assert!(std::ptr::eq(view.v.as_ptr(), payload[4 + q_sz + kv_sz..].as_ptr()));

    // The worker's byte lifecycle against a real arena: O lands in the
    // Q slot (in place), never overlapping anything still live.
    let mut arena = Arena::unbounded();
    let q_slot = arena.alloc((q_sz * 4) as u64).unwrap();
    let kv_slot = arena.alloc((2 * kv_sz * 4) as u64).unwrap();
    let o_slot = arena.write_in_place(q_slot, (q_sz * 4) as u64);
    arena.free(kv_slot);
    arena.check_no_alias().expect("in-place O aliased a live slot");
    arena.free(o_slot);
    arena.check_drained().expect("task left bytes live");

    // Malformed payloads still reject cleanly through the view path.
    assert!(decode_elastic_view(&payload[..3], q_len, kv_len).is_err());
    assert!(decode_elastic_view(&payload, 0, kv_len).is_err());
    let mut bad = payload.clone();
    bad[3] = f32::from_bits((q_sz + 1) as u32); // odd k/v remainder
    assert!(decode_elastic_view(&bad, q_len, kv_len).is_err());
}
