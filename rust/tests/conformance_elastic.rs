//! Differential conformance suite for the elastic execution paths.
//!
//! For ≥ 50 seeded `(doc set, fault plan)` cases, every execution path —
//! the deterministic single-threaded reference (`run_elastic_exec`),
//! the threaded `ElasticCoordinator` (flat `run_tick`), and the two PP
//! ping-pong paths (`run_elastic_exec_pp`, threaded `run_pp_tick`) —
//! must produce **bit-exact** CA outputs vs. the pure-Rust GQA oracle,
//! fault plans included: recovery must not change results. Statelessness
//! (§3) is what makes this a meaningful invariant: a CA-task is a pure
//! (Q, KV) → O function, so kills, partial drains, OOM evictions
//! (`oom:` — arena overflow, the victim surviving the tick), slowdowns,
//! rejoins, re-dispatch, and first-response-wins dedup may change *who*
//! computes a task and *when*, never *what* it returns. The exec paths
//! additionally replay their kept computations through per-server
//! in-place arenas (`ExecReport::mem`), asserting the §5 memory model
//! holds on the same runs.

use std::collections::BTreeSet;
use std::time::Duration;

use distca::config::run::DataDist;
use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::scheduler::items_from_chunks;
use distca::coordinator::{schedule, schedule_with_beliefs, SchedulerCfg, ServerBelief};
use distca::data::distributions::sampler_for;
use distca::elastic::{
    run_elastic_exec, run_elastic_exec_pp, ElasticCfg, ElasticCoordinator, ElasticTask,
    FaultPlan, ReferenceCaCompute, ServerPool,
};
use distca::kernel::{avx2_available, FastCaCompute};
use distca::runtime::ca_exec::synthetic_task;
use distca::server::TaskOutput;
use distca::sim::strategies::{distca_placement, SimParams};
use distca::sim::Engine;
use distca::util::rng::Rng;

const H: usize = 2;
const HKV: usize = 1;
const D: usize = 8;

fn dims() -> ReferenceCaCompute {
    ReferenceCaCompute::new(H, HKV, D)
}

/// One seeded conformance case: a few ticks of whole-doc CA-tasks with
/// planned server assignments, plus a fault plan.
struct Case {
    n_servers: usize,
    ticks: Vec<Vec<ElasticTask>>,
    fault: FaultPlan,
}

fn gen_case(seed: u64) -> Case {
    let mut rng = Rng::new(0xC0F0_0000 ^ seed);
    let n_servers = 2 + (seed as usize % 3); // 2..=4
    let n_ticks = 2 + (seed as usize % 2); // 2..=3
    let mut ticks = Vec::new();
    for t in 0..n_ticks {
        let n_docs = 3 + rng.gen_index(0, 4); // 3..=6
        let mut tasks = Vec::new();
        for j in 0..n_docs {
            let len = 2 * (1 + rng.gen_index(0, 8)); // 2..=16, even
            // The plan may name servers that later die — every path must
            // remap or re-dispatch without changing the output.
            let server = rng.gen_index(0, n_servers);
            tasks.push(ElasticTask {
                doc: (t * 100 + j) as u32,
                q_start: 0,
                server,
                home: server % 2,
                tensors: synthetic_task(&mut rng, len, len, H, HKV, D),
            });
        }
        ticks.push(tasks);
    }
    // Seeded fault plan; server 0 is never killed so the pool survives.
    let mut fault = FaultPlan::random(&mut rng, n_servers, n_ticks, 1, 1);
    if n_servers >= 3 && seed % 3 == 0 {
        // Exercise partial drain too (server 0 stays untouched).
        fault = fault.drain(2, rng.gen_index(0, n_ticks));
    }
    if seed % 4 == 1 {
        // Arena-overflow eviction (§5): recovery must be invisible in
        // the outputs on every path. Tick 0 is safe — random kills and
        // slows fire at tick >= 1, so server 0 always remains a live
        // re-dispatch target even alongside a tick-0 drain of server 2.
        fault = fault.oom(1, 0);
    }
    Case { n_servers, ticks, fault }
}

/// Bit-exact comparison of one tick's outputs against the oracle.
fn check_tick(label: &str, seed: u64, tasks: &[ElasticTask], outputs: &[TaskOutput]) {
    assert_eq!(
        outputs.len(),
        tasks.len(),
        "{label} seed {seed}: incomplete gather ({} of {})",
        outputs.len(),
        tasks.len()
    );
    let mut seen = BTreeSet::new();
    let oracle = dims();
    for out in outputs {
        assert!(
            seen.insert((out.doc, out.q_start)),
            "{label} seed {seed}: duplicate output for doc {}",
            out.doc
        );
        let task = tasks
            .iter()
            .find(|t| t.doc == out.doc && t.q_start == out.q_start)
            .unwrap_or_else(|| panic!("{label} seed {seed}: unknown output doc {}", out.doc));
        let expect = oracle.run_batch(std::slice::from_ref(&task.tensors));
        assert_eq!(out.o.len(), expect[0].len(), "{label} seed {seed}: shape");
        for (i, (&a, &b)) in out.o.iter().zip(&expect[0]).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{label} seed {seed}: doc {} diverged at {i}",
                out.doc
            );
        }
    }
}

/// Quick coordinator knobs: tight deadlines, mild injected slowdowns, so
/// 50+ threaded cases stay fast while still exercising re-dispatch.
fn quick_cfg() -> ElasticCfg {
    ElasticCfg {
        grace: Duration::from_millis(25),
        slow_task_unit: Duration::from_millis(2),
        ..Default::default()
    }
}

const SEEDS: u64 = 56;

#[test]
fn exec_reference_matches_oracle_for_seeded_cases() {
    for seed in 0..SEEDS {
        let case = gen_case(seed);
        let mut pool = ServerPool::new(case.n_servers);
        let mut compute = dims();
        for (t, tasks) in case.ticks.iter().enumerate() {
            let rep = run_elastic_exec(&mut pool, t, tasks, &case.fault, &mut compute)
                .unwrap_or_else(|e| panic!("exec seed {seed} tick {t}: {e}"));
            check_tick("exec", seed, tasks, &rep.outputs);
            // Partial drain: a started (kept) task is never re-sent.
            for tag in &rep.drain_kept {
                assert!(
                    !rep.drain_redirected.contains(tag) && !rep.redispatched.contains(tag),
                    "exec seed {seed}: started task {tag} re-dispatched"
                );
            }
            // OOM evictions: the victim never computes an evicted task,
            // and the victim stays in the pool.
            for tag in &rep.oom_evicted {
                assert!(
                    pool.is_schedulable(1),
                    "exec seed {seed}: OOM victim left the pool"
                );
                assert_ne!(
                    rep.computed_by[tag], 1,
                    "exec seed {seed}: evicted task {tag} computed on the victim"
                );
            }
            // The §5 memory model holds: the per-server arena replay is
            // leak-free by construction and reports a positive peak for
            // every server that computed anything.
            assert_eq!(rep.mem.per_server_peak.len(), case.n_servers);
            let computed: std::collections::BTreeSet<usize> =
                rep.computed_by.values().copied().collect();
            for (s, &peak) in rep.mem.per_server_peak.iter().enumerate() {
                assert_eq!(
                    peak > 0.0,
                    computed.contains(&s),
                    "exec seed {seed}: server {s} peak {peak} vs kept set {computed:?}"
                );
            }
        }
    }
}

#[test]
fn exec_pp_matches_oracle_for_seeded_cases() {
    for seed in 0..SEEDS {
        let case = gen_case(seed);
        let mut pool = ServerPool::new(case.n_servers);
        let mut compute = dims();
        for (t, tasks) in case.ticks.iter().enumerate() {
            let rep = run_elastic_exec_pp(&mut pool, t, tasks, &case.fault, &mut compute)
                .unwrap_or_else(|e| panic!("exec-pp seed {seed} tick {t}: {e}"));
            check_tick("exec-pp", seed, tasks, &rep.outputs);
        }
    }
}

#[test]
fn threaded_flat_matches_oracle_for_seeded_cases() {
    for seed in 0..SEEDS {
        let case = gen_case(seed);
        let mut co =
            ElasticCoordinator::spawn(case.n_servers, quick_cfg(), |_| Box::new(dims()));
        for (t, tasks) in case.ticks.iter().enumerate() {
            let outputs = co
                .run_tick(t, tasks, &case.fault)
                .unwrap_or_else(|e| panic!("threaded seed {seed} tick {t}: {e}"));
            check_tick("threaded", seed, tasks, &outputs);
        }
        co.shutdown().unwrap();
    }
}

/// Heterogeneous pools, slow-from-tick-0: server 1 is *believed* 4×
/// slow before the first tick (pre-degraded, exactly as a gray verdict
/// or a `--belief-speeds` seed would leave the pool). Every execution
/// path must shed its share at plan time and stay bit-exact vs the
/// oracle — belief-aware planning may change *who* computes a task,
/// never *what* it returns.
#[test]
fn heterogeneous_beliefs_from_tick0_match_oracle_on_all_paths() {
    const SLOW: usize = 1;
    const SPEED: f64 = 0.25;
    for seed in 0..16u64 {
        let case = gen_case(seed);

        // Deterministic exec, flat.
        let mut pool = ServerPool::new(case.n_servers);
        pool.degrade(SLOW, SPEED);
        let mut compute = dims();
        for (t, tasks) in case.ticks.iter().enumerate() {
            let rep = run_elastic_exec(&mut pool, t, tasks, &case.fault, &mut compute)
                .unwrap_or_else(|e| panic!("hetero exec seed {seed} tick {t}: {e}"));
            check_tick("hetero-exec", seed, tasks, &rep.outputs);
        }

        // Deterministic exec, PP waves.
        let mut pool = ServerPool::new(case.n_servers);
        pool.degrade(SLOW, SPEED);
        let mut compute = dims();
        for (t, tasks) in case.ticks.iter().enumerate() {
            let rep = run_elastic_exec_pp(&mut pool, t, tasks, &case.fault, &mut compute)
                .unwrap_or_else(|e| panic!("hetero exec-pp seed {seed} tick {t}: {e}"));
            check_tick("hetero-exec-pp", seed, tasks, &rep.outputs);
        }

        // Threaded, flat.
        let mut co =
            ElasticCoordinator::spawn(case.n_servers, quick_cfg(), |_| Box::new(dims()));
        co.pool.degrade(SLOW, SPEED);
        for (t, tasks) in case.ticks.iter().enumerate() {
            let outputs = co
                .run_tick(t, tasks, &case.fault)
                .unwrap_or_else(|e| panic!("hetero threaded seed {seed} tick {t}: {e}"));
            check_tick("hetero-threaded", seed, tasks, &outputs);
        }
        co.shutdown().unwrap();

        // Threaded, PP waves.
        let mut co =
            ElasticCoordinator::spawn(case.n_servers, quick_cfg(), |_| Box::new(dims()));
        co.pool.degrade(SLOW, SPEED);
        for (t, tasks) in case.ticks.iter().enumerate() {
            let outputs = co
                .run_pp_tick(t, tasks, &case.fault)
                .unwrap_or_else(|e| panic!("hetero threaded-pp seed {seed} tick {t}: {e}"));
            check_tick("hetero-threaded-pp", seed, tasks, &outputs);
        }
        co.shutdown().unwrap();
    }
}

/// The acceptance bar for the belief-speed scheduler: with one server
/// believed 4× slow, the speed-aware plan's *simulated* makespan (on a
/// discrete-event engine whose actual speeds equal the beliefs) is
/// strictly lower than the uniform plan's on the same doc set, and its
/// own prediction matches the simulation.
#[test]
fn speed_aware_plan_beats_uniform_with_4x_slow_belief() {
    let model = ModelConfig::llama3_8b();
    let p = SimParams::new(model.clone(), ClusterConfig::h200(4), 8, 1);
    let n = 4usize;
    let mut rng = Rng::new(42);
    let docs = sampler_for(DataDist::Pretrain, 65_536).sample_tokens(&mut rng, 4 * 65_536, 0);
    let chunks = distca_placement(&docs, n);
    let mut items = items_from_chunks(&chunks);
    for it in &mut items {
        if it.home >= n {
            it.home = n - 1;
        }
    }
    let speeds = [1.0, 0.25, 1.0, 1.0];
    let cfg = SchedulerCfg::default();
    let uniform = schedule(&items, n, &p.f, &p.prof, &model, &cfg);
    let aware = schedule_with_beliefs(
        &items,
        &ServerBelief::from_speeds(&speeds, 0.0),
        &p.f,
        &p.prof,
        &model,
        &cfg,
    );
    aware.validate(&items, &p.f).unwrap();

    let simulate = |plan: &distca::coordinator::Plan| -> f64 {
        let mut eng = Engine::new(n);
        for (v, &sp) in speeds.iter().enumerate() {
            eng.set_speed(v, sp);
        }
        for a in &plan.assignments {
            let cost: f64 = a
                .item
                .ca_tasks()
                .iter()
                .map(|t| p.prof.predict(t.q_len as f64, t.kv_len as f64))
                .sum();
            eng.add_task(a.server, cost, &[]);
        }
        eng.run()
    };
    let mk_uniform = simulate(&uniform);
    let mk_aware = simulate(&aware);
    assert!(
        mk_aware < mk_uniform,
        "speed-aware simulated makespan {mk_aware} must strictly beat uniform {mk_uniform}"
    );
    assert!(
        (aware.predicted_makespan() - mk_aware).abs() / mk_aware < 1e-6,
        "prediction {} must match simulation {mk_aware}",
        aware.predicted_makespan()
    );
}

/// The `net` path column: the same seeded `(docs, fault-plan)` cases,
/// bit-exact **over real localhost TCP sockets** — every byte crosses
/// the length-prefixed codec and a `TcpTransport`, with the worker
/// loops on the far side of an accepted connection
/// (`net::loopback::spawn_loopback_pool`). Gated behind
/// `DISTCA_NET_TESTS=1` so the default test run stays hermetic (no
/// sockets opened); CI's net-smoke job sets the gate.
#[test]
fn net_loopback_matches_oracle_for_seeded_cases() {
    if std::env::var("DISTCA_NET_TESTS").is_err() {
        eprintln!("skipping net loopback conformance (set DISTCA_NET_TESTS=1 to run)");
        return;
    }
    // Fewer seeds than the in-process paths: each case stands up a
    // socket pool, and the fault space is already covered above — this
    // column proves the *wire* changes nothing.
    for seed in 0..16u64 {
        let case = gen_case(seed);
        let pool = distca::net::loopback::spawn_loopback_pool(case.n_servers, H, HKV, D)
            .unwrap_or_else(|e| panic!("net seed {seed}: spawning loopback pool: {e}"));
        let mut co = pool.coordinator(quick_cfg());
        for (t, tasks) in case.ticks.iter().enumerate() {
            let outputs = co
                .run_tick(t, tasks, &case.fault)
                .unwrap_or_else(|e| panic!("net seed {seed} tick {t}: {e}"));
            check_tick("net", seed, tasks, &outputs);
        }
        co.shutdown().unwrap();
        pool.join().unwrap_or_else(|e| panic!("net seed {seed}: worker join: {e}"));
    }
}

/// The `net --pp` column: the seeded cases again, but each tick runs as
/// two overlapped waves **over real localhost sockets** — pong frames
/// ship while ping compute is still in flight, wave-epoch stamps ride
/// the frame header, and scripted kills land between the waves. Gated
/// like the flat net column.
#[test]
fn net_loopback_pp_matches_oracle_for_seeded_cases() {
    if std::env::var("DISTCA_NET_TESTS").is_err() {
        eprintln!("skipping net loopback pp conformance (set DISTCA_NET_TESTS=1 to run)");
        return;
    }
    for seed in 0..16u64 {
        let case = gen_case(seed);
        let pool = distca::net::loopback::spawn_loopback_pool(case.n_servers, H, HKV, D)
            .unwrap_or_else(|e| panic!("net-pp seed {seed}: spawning loopback pool: {e}"));
        let mut co = pool.coordinator(quick_cfg());
        for (t, tasks) in case.ticks.iter().enumerate() {
            let outputs = co
                .run_pp_tick(t, tasks, &case.fault)
                .unwrap_or_else(|e| panic!("net-pp seed {seed} tick {t}: {e}"));
            check_tick("net-pp", seed, tasks, &outputs);
        }
        let stats = co.shutdown().unwrap();
        for st in &stats {
            let kill_tick = case
                .fault
                .events_at(st.tick)
                .iter()
                .any(|e| matches!(e, distca::elastic::FaultEvent::Kill { .. }));
            if kill_tick {
                assert!(
                    st.wave_epochs[1] > st.wave_epochs[0],
                    "net-pp seed {seed} tick {}: the kill must land between the waves: {st:?}",
                    st.tick
                );
            }
        }
        pool.join().unwrap_or_else(|e| panic!("net-pp seed {seed}: worker join: {e}"));
    }
}

/// Mid-wave SIGKILL over the wire (the tentpole's recovery invariant):
/// the boundary hook drops a worker's connection while the ping wave is
/// genuinely in flight — the wire-level equivalent of a SIGKILL's EOF —
/// and the tick must still gather bit-exact, with the membership epoch
/// bumped *between* the wave stamps and the pong wave (planned under
/// the post-kill epoch) never needing a re-dispatch. Gated like the
/// other socket tests.
#[test]
fn net_loopback_pp_mid_wave_kill_redispatches_only_inflight_wave() {
    if std::env::var("DISTCA_NET_TESTS").is_err() {
        eprintln!("skipping net mid-wave kill conformance (set DISTCA_NET_TESTS=1 to run)");
        return;
    }
    const N: usize = 3;
    const VICTIM: usize = 1;
    let mut rng = Rng::new(0xDEAD_5160);
    let tasks: Vec<ElasticTask> = (0..8)
        .map(|j| {
            let len = 2 * (1 + rng.gen_index(0, 8));
            let server = j % N; // victim owns a share of both waves
            ElasticTask {
                doc: j as u32,
                q_start: 0,
                server,
                home: server % 2,
                tensors: synthetic_task(&mut rng, len, len, H, HKV, D),
            }
        })
        .collect();

    let pool = distca::net::loopback::spawn_loopback_pool(N, H, HKV, D)
        .unwrap_or_else(|e| panic!("mid-wave kill: spawning loopback pool: {e}"));
    // Generous grace: the only re-dispatches this test may observe are
    // the victim's genuinely lost ping tasks, never a spurious deadline
    // on a healthy worker (which would fail the pong assertion below).
    let mut co = pool.coordinator(ElasticCfg {
        grace: Duration::from_millis(500),
        slow_task_unit: Duration::from_millis(2),
        ..Default::default()
    });

    let fabric = std::sync::Arc::clone(&pool.fabric);
    let mut fired = false;
    let mut boundary = || -> Vec<usize> {
        if fired {
            return Vec::new();
        }
        fired = true;
        // Drop the victim's socket mid-wave: its writer queue dies, its
        // worker loop sees EOF and exits — exactly the coordinator-side
        // observable of a SIGKILL'd worker process.
        fabric.close_conn(VICTIM);
        vec![VICTIM]
    };
    let outputs = co
        .run_pp_tick_with_boundary(0, &tasks, &FaultPlan::new(), &mut boundary)
        .unwrap_or_else(|e| panic!("mid-wave kill tick: {e}"));
    check_tick("net-midwave-kill", 0, &tasks, &outputs);

    let stats = co.shutdown().unwrap();
    let st = &stats[0];
    assert_eq!(
        st.mid_tick_disconnects, 1,
        "the boundary EOF must be applied as a mid-tick disconnect: {st:?}"
    );
    assert!(
        st.wave_epochs[1] > st.wave_epochs[0],
        "the mid-wave kill must land between the wave stamps: {st:?}"
    );
    assert_eq!(
        st.wave_redispatched[1], 0,
        "the pong wave plans around the victim pre-dispatch — only the \
         in-flight ping wave may re-dispatch: {st:?}"
    );
    pool.join().unwrap_or_else(|e| panic!("mid-wave kill: worker join: {e}"));
}

/// End-to-end `soak --pp` through the shipped binary: spawned worker
/// processes, a scripted mid-wave SIGKILL at tick 1 and a rejoin at
/// tick 3, JSON report on stdout. Asserts the report's wave-epoch
/// ordering on the kill tick and the bit-exact verdict — the CI
/// net-smoke runs the same shape with a pinned seed. Gated like the
/// other socket tests.
#[test]
fn soak_pp_binary_survives_scripted_sigkill_bit_exact() {
    if std::env::var("DISTCA_NET_TESTS").is_err() {
        eprintln!("skipping soak --pp subprocess test (set DISTCA_NET_TESTS=1 to run)");
        return;
    }
    let bench = std::env::temp_dir().join(format!("distca-soak-pp-{}.json", std::process::id()));
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_distca"))
        .args([
            "soak",
            "--pp",
            "--workers",
            "4",
            "--spawn",
            "--ticks",
            "4",
            "--docs-per-tick",
            "8",
            "--seed",
            "7",
            "--fault",
            "kill:1@1,rejoin:1@3",
            "--json",
            "--bench-out",
        ])
        .arg(&bench)
        .output()
        .expect("launching distca soak --pp");
    let _ = std::fs::remove_file(&bench);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "soak --pp exited with {:?}\nstdout:\n{stdout}\nstderr:\n{}",
        out.status.code(),
        String::from_utf8_lossy(&out.stderr)
    );
    // The JSON report is the last thing on stdout; skip any "wrote …"
    // progress lines before it.
    let json_start = stdout.find('{').expect("JSON report on stdout");
    let report = distca::util::json::parse(&stdout[json_start..])
        .unwrap_or_else(|e| panic!("parsing soak --pp report: {e}\n{stdout}"));
    assert_eq!(report.get("pp").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(report.get("bit_exact").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(
        report.get("total_process_kills").and_then(|v| v.as_usize()),
        Some(1),
        "exactly the scripted SIGKILL: {stdout}"
    );
    assert_eq!(
        report.get("total_rejoins").and_then(|v| v.as_usize()),
        Some(1),
        "exactly the scripted rejoin: {stdout}"
    );
    let ticks = report.get("per_tick").and_then(|v| v.as_arr()).expect("per_tick array");
    let kill_tick = ticks
        .iter()
        .find(|t| t.get("tick").and_then(|v| v.as_usize()) == Some(1))
        .expect("tick 1 record");
    let ping = kill_tick.get("wave_epoch_ping").and_then(|v| v.as_u64()).unwrap();
    let pong = kill_tick.get("wave_epoch_pong").and_then(|v| v.as_u64()).unwrap();
    assert!(
        pong > ping,
        "the scripted SIGKILL must land between the waves (ping {ping}, pong {pong}): {stdout}"
    );
    assert_eq!(
        kill_tick.get("mid_wave_kills").and_then(|v| v.as_usize()),
        Some(1),
        "tick 1 must record the kill as mid-wave: {stdout}"
    );
}

/// The gateway column: seeded multi-tenant mixes folded into fused
/// cross-tenant waves over the same threaded pool, under kill:/drain:/
/// oom: fault plans. `run_gateway` verifies every gathered output
/// bit-exact against the *tenant's own* GQA oracle stream and audits
/// the double-entry ledger internally — a mis-attributed re-dispatch, a
/// cross-tenant tensor mixup, or a dropped tenant tag fails the run.
/// This column re-asserts the external invariants on the report so a
/// future soft-failure refactor of `run_gateway` cannot go unnoticed.
#[test]
fn gateway_multi_tenant_mixes_match_oracle_under_faults() {
    for seed in 0..20u64 {
        let workers = 2 + (seed as usize % 3); // 2..=4
        // Never fault server 0 (the pool must survive); faults land on
        // dispatched waves >= 1. Rotate through the three fault kinds
        // plus a fault-free control case.
        let fault = match seed % 4 {
            0 => FaultPlan::new(),
            1 => FaultPlan::new().kill(1, 1),
            2 => FaultPlan::new().drain(1, 1),
            _ => FaultPlan::new().oom(1, 1),
        };
        let cfg = distca::gateway::GatewayCfg {
            tenants: 8 + (seed as usize % 40),
            workers,
            waves: 3,
            arrival_rate: 24.0,
            seed: 0x6A7E_0000 ^ seed,
            fault,
            // Flat load: every arrival wave dispatches, so a fault at
            // dispatch tick 1 always has a later wave to observe it in.
            diurnal_period: 0.0,
            ..Default::default()
        };
        let report = distca::gateway::run_gateway(&cfg)
            .unwrap_or_else(|e| panic!("gateway seed {seed}: {e}"));
        let pool = report.ledger.pool();
        assert!(pool.admitted > 0, "gateway seed {seed}: vacuous case (nothing admitted)");
        assert_eq!(
            pool.completed, pool.admitted,
            "gateway seed {seed}: drained run left work incomplete"
        );
        assert!(
            report.ledger.conservation_errors().is_empty(),
            "gateway seed {seed}: ledger audit failed"
        );
        // A killed worker shrinks the pool: some wave must have seen
        // fewer live workers than it started with.
        if seed % 4 == 1 {
            assert!(
                report.per_wave.iter().any(|r| r.n_alive < workers),
                "gateway seed {seed}: the scripted kill never surfaced"
            );
        }
    }
}

/// The `fastkernel` column: the same seeded `(docs, fault-plan)` cases
/// — kills, drains, OOM evictions — on all four execution paths, with
/// the fast-path GQA kernel (`kernel::FastCaCompute`, AVX2 when the
/// host has it, scalar otherwise) as the servers' compute instead of
/// the reference. `check_tick` compares every output against the
/// oracle's bytes, so this column *is* the kernel's admission bar under
/// recovery: re-dispatch, drain hand-off, and eviction replay must all
/// reproduce `ReferenceCaCompute` bit-for-bit through the fast path.
#[test]
fn fastkernel_matches_oracle_on_all_four_paths() {
    let note = if avx2_available() { "avx2" } else { "scalar" };
    for seed in 0..24u64 {
        let case = gen_case(seed);

        // Deterministic exec, flat.
        let mut pool = ServerPool::new(case.n_servers);
        let mut compute = FastCaCompute::new(H, HKV, D);
        for (t, tasks) in case.ticks.iter().enumerate() {
            let rep = run_elastic_exec(&mut pool, t, tasks, &case.fault, &mut compute)
                .unwrap_or_else(|e| panic!("fastkernel({note}) exec seed {seed} tick {t}: {e}"));
            check_tick("fastkernel-exec", seed, tasks, &rep.outputs);
        }

        // Deterministic exec, PP waves.
        let mut pool = ServerPool::new(case.n_servers);
        let mut compute = FastCaCompute::new(H, HKV, D);
        for (t, tasks) in case.ticks.iter().enumerate() {
            let rep = run_elastic_exec_pp(&mut pool, t, tasks, &case.fault, &mut compute)
                .unwrap_or_else(|e| {
                    panic!("fastkernel({note}) exec-pp seed {seed} tick {t}: {e}")
                });
            check_tick("fastkernel-exec-pp", seed, tasks, &rep.outputs);
        }

        // Threaded coordinator, flat ticks.
        let mut co = ElasticCoordinator::spawn(case.n_servers, quick_cfg(), |_| {
            Box::new(FastCaCompute::new(H, HKV, D))
        });
        for (t, tasks) in case.ticks.iter().enumerate() {
            let outputs = co.run_tick(t, tasks, &case.fault).unwrap_or_else(|e| {
                panic!("fastkernel({note}) threaded seed {seed} tick {t}: {e}")
            });
            check_tick("fastkernel-threaded", seed, tasks, &outputs);
        }
        co.shutdown().unwrap();

        // Threaded coordinator, PP ping-pong waves.
        let mut co = ElasticCoordinator::spawn(case.n_servers, quick_cfg(), |_| {
            Box::new(FastCaCompute::new(H, HKV, D))
        });
        for (t, tasks) in case.ticks.iter().enumerate() {
            let outputs = co.run_pp_tick(t, tasks, &case.fault).unwrap_or_else(|e| {
                panic!("fastkernel({note}) threaded-pp seed {seed} tick {t}: {e}")
            });
            check_tick("fastkernel-threaded-pp", seed, tasks, &outputs);
        }
        co.shutdown().unwrap();
    }
}

#[test]
fn threaded_pp_matches_oracle_for_seeded_cases() {
    for seed in 0..SEEDS {
        let case = gen_case(seed);
        let mut co =
            ElasticCoordinator::spawn(case.n_servers, quick_cfg(), |_| Box::new(dims()));
        for (t, tasks) in case.ticks.iter().enumerate() {
            let outputs = co
                .run_pp_tick(t, tasks, &case.fault)
                .unwrap_or_else(|e| panic!("threaded-pp seed {seed} tick {t}: {e}"));
            check_tick("threaded-pp", seed, tasks, &outputs);
        }
        let stats = co.shutdown().unwrap();
        // Wave scoping: a scripted kill always bumps the membership
        // epoch *between* the waves (strict — epochs are monotone, so
        // `>=` would be vacuous), and the pong wave is planned under the
        // post-kill epoch.
        for st in &stats {
            let kill_tick = case
                .fault
                .events_at(st.tick)
                .iter()
                .any(|e| matches!(e, distca::elastic::FaultEvent::Kill { .. }));
            if kill_tick {
                assert!(
                    st.wave_epochs[1] > st.wave_epochs[0],
                    "seed {seed} tick {}: the kill must land between the waves: {st:?}",
                    st.tick
                );
            }
        }
    }
}
