//! Integration tests over the real PJRT runtime + AOT artifacts.
//! These skip (pass vacuously, with a note) when `make artifacts` hasn't
//! run, so `cargo test` works on a fresh checkout.

use distca::runtime::ca_exec::{synthetic_task, CaExecutor};
use distca::runtime::train::{make_batch, MarkovCorpus, TrainDriver, BLOCK_Q, TRAIN_T};
use distca::runtime::{artifacts_available, artifacts_dir, Runtime};
use distca::util::rng::Rng;

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
#[ignore = "requires a vendored xla-rs PJRT backend; the default build links the host-only xla-stub"]
fn pjrt_client_boots() {
    // Under `--include-ignored` on the default (xla-stub) build, skip
    // instead of failing: device creation is exactly the stub boundary.
    match Runtime::cpu() {
        Ok(rt) => assert!(!rt.platform().is_empty()),
        Err(e) if format!("{e:#}").contains("xla-stub") => {
            eprintln!("skipping: default build links the host-only xla-stub");
        }
        Err(e) => panic!("PJRT CPU client: {e:#}"),
    }
}

#[test]
fn ca_artifact_loads_and_runs() {
    require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let dir = artifacts_dir();
    let exec = CaExecutor::load(&rt, &dir, 512, 1024, 12, 12, 64).expect("load CA artifact");
    let mut rng = Rng::new(7);
    let tasks = vec![
        synthetic_task(&mut rng, 128, 256, 12, 12, 64),
        synthetic_task(&mut rng, 256, 512, 12, 12, 64),
    ];
    assert!(exec.fits(&tasks));
    let out = exec.run_batch(&rt, &tasks).expect("run CA batch");
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].len(), 128 * 12 * 64);
    assert_eq!(out[1].len(), 256 * 12 * 64);
    // Softmax outputs are convex combinations of V entries (|V| <= 1 here)
    // so every output element must be bounded.
    for o in &out {
        assert!(o.iter().all(|x| x.is_finite() && x.abs() <= 1.0 + 1e-4));
    }
}

#[test]
fn ca_fused_batch_matches_separate_calls() {
    // Composability on the REAL runtime: a fused two-task batch equals
    // two single-task calls (§3.3).
    require_artifacts!();
    let rt = Runtime::cpu().unwrap();
    let dir = artifacts_dir();
    let exec = CaExecutor::load(&rt, &dir, 512, 1024, 12, 12, 64).unwrap();
    let mut rng = Rng::new(11);
    let t1 = synthetic_task(&mut rng, 128, 128, 12, 12, 64);
    let t2 = synthetic_task(&mut rng, 128, 384, 12, 12, 64);
    let fused = exec.run_batch(&rt, &[t1.clone(), t2.clone()]).unwrap();
    let solo1 = exec.run_batch(&rt, &[t1]).unwrap();
    let solo2 = exec.run_batch(&rt, &[t2]).unwrap();
    let close = |a: &[f32], b: &[f32]| {
        a.iter().zip(b).all(|(x, y)| (x - y).abs() < 1e-5)
    };
    assert!(close(&fused[0], &solo1[0]), "task 1 diverged under fusion");
    assert!(close(&fused[1], &solo2[0]), "task 2 diverged under fusion");
}

#[test]
fn train_step_executes_and_loss_decreases() {
    require_artifacts!();
    let driver = TrainDriver::load(&artifacts_dir()).expect("load train driver");
    assert!(driver.n_params() > 90_000_000, "tiny LM must be ~100M params");
    let corpus = MarkovCorpus::new(32_000, 0.9, 42);
    let report = driver
        .train(&corpus, 8, 1, |_, _| {})
        .expect("run train steps");
    assert_eq!(report.losses.len(), 8);
    // Starts near uniform ln(32000) ~ 10.4 and must already move down.
    assert!(report.first_loss() > 8.0, "first loss {}", report.first_loss());
    assert!(
        report.last_loss() < report.first_loss(),
        "loss must decrease: {:?}",
        report.losses
    );
}

#[test]
fn batch_builder_respects_kernel_contract() {
    let corpus = MarkovCorpus::new(1000, 0.9, 1);
    let mut rng = Rng::new(2);
    for lens in [vec![512], vec![256, 256], vec![128, 128, 128, 128]] {
        let b = make_batch(&corpus, &mut rng, &lens);
        assert_eq!(b.tokens.len(), TRAIN_T);
        assert_eq!(b.block_meta.len(), TRAIN_T / BLOCK_Q * 4);
        // every target is a valid token id
        assert!(b.targets.iter().all(|&t| t >= 0 && (t as usize) < 1000));
    }
}
