//! Differential property suite for the fast-path GQA kernel: the
//! harness that keeps `kernel::FastCaCompute` honest.
//!
//! The kernel's admission contract is *bit-exactness* against
//! [`ReferenceCaCompute`] — not closeness, equality of every output
//! f32's bit pattern. All backends render one pinned reduction order
//! (see `kernel::flash`), so any divergence is a bug in a backend, not
//! an accepted rounding difference. The suite sweeps:
//!
//! * **GQA ratios** `h/hkv ∈ {1, 2, 4, 8}` — the K/V-head sharing the
//!   `(task, head)` work partition must index correctly;
//! * **ragged lengths** — `q_len`/`kv_len` from 1 through multiples of
//!   the KV chunk, sitting exactly on, one short of, and one past every
//!   block boundary (the streaming-softmax chunk loop's edge cases);
//! * **head dims** with and without a `% 4` SIMD tail;
//! * **adversarial floats** — NaNs (payloads included), ±inf,
//!   subnormals, −0.0 injected into Q/K/V: specials must *propagate*
//!   identically, because the elastic wire ships bit-cast header words
//!   that are NaNs, and a backend that canonicalizes would pass value
//!   comparisons while corrupting bytes;
//! * **thread counts** — the dynamic `(task, head)` partition must be
//!   invisible in the bytes;
//! * **`DISTCA_KERNEL` selection** — every env value must build the
//!   backend it names, and all of them must agree bitwise.

use distca::elastic::{CaCompute, ReferenceCaCompute};
use distca::kernel::{
    avx2_available, choice_from_env, FastCaCompute, KernelBackend, KernelChoice, KV_CHUNK,
};
use distca::runtime::ca_exec::{synthetic_task, CaTaskTensors};
use distca::util::rng::Rng;

/// Length pairs covering the chunk-boundary lattice: singletons, exact
/// chunk multiples, one-off-each-side, and ragged interiors.
fn length_grid() -> Vec<(usize, usize)> {
    vec![
        (1, 1),
        (1, 2),
        (2, 2),
        (1, KV_CHUNK),
        (3, 7),
        (5, KV_CHUNK - 1),
        (KV_CHUNK - 1, KV_CHUNK - 1),
        (KV_CHUNK, KV_CHUNK),
        (KV_CHUNK + 1, KV_CHUNK + 1),
        (7, KV_CHUNK + 1),
        (KV_CHUNK, 2 * KV_CHUNK),
        (33, 2 * KV_CHUNK + 5),
    ]
}

/// The GQA sweep: `(h, hkv)` pairs at ratios 1, 2, 4, 8.
fn gqa_grid() -> Vec<(usize, usize)> {
    vec![(2, 2), (2, 1), (4, 2), (8, 2), (8, 1), (4, 1)]
}

fn assert_outputs_bit_eq(want: &[Vec<f32>], got: &[Vec<f32>], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: batch size diverged");
    for (ti, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(w.len(), g.len(), "{ctx}: task {ti} output length");
        for (i, (a, b)) in w.iter().zip(g).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{ctx}: task {ti} elem {i}: {a:?} ({:#010x}) vs {b:?} ({:#010x})",
                a.to_bits(),
                b.to_bits(),
            );
        }
    }
}

#[test]
fn scalar_fast_path_is_bit_exact_over_gqa_ratios_and_ragged_lengths() {
    let mut rng = Rng::new(0xFA57);
    for (h, hkv) in gqa_grid() {
        // d = 10 exercises the 4-lane dot's scalar tail; d = 16 is the
        // tail-free path.
        for d in [10usize, 16] {
            let oracle = ReferenceCaCompute::new(h, hkv, d);
            let fast = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Scalar).threads(1);
            for (q_len, kv_len) in length_grid() {
                let t = synthetic_task(&mut rng, q_len, kv_len, h, hkv, d);
                let want = oracle.run_batch(std::slice::from_ref(&t));
                let got = fast.run_batch(std::slice::from_ref(&t)).unwrap();
                let ctx = format!("h{h}/hkv{hkv}/d{d} q{q_len}/kv{kv_len}");
                assert_outputs_bit_eq(&want, &got, &ctx);
            }
        }
    }
}

#[test]
fn avx2_equals_scalar_and_oracle_bitwise() {
    if !avx2_available() {
        eprintln!("skipping: no AVX2/FMA on this host");
        return;
    }
    let mut rng = Rng::new(0xA5A5);
    for (h, hkv) in gqa_grid() {
        for d in [10usize, 16] {
            let oracle = ReferenceCaCompute::new(h, hkv, d);
            let scalar = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Scalar).threads(1);
            let avx2 = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Avx2).threads(1);
            for (q_len, kv_len) in length_grid() {
                let t = synthetic_task(&mut rng, q_len, kv_len, h, hkv, d);
                let want = oracle.run_batch(std::slice::from_ref(&t));
                let s = scalar.run_batch(std::slice::from_ref(&t)).unwrap();
                let a = avx2.run_batch(std::slice::from_ref(&t)).unwrap();
                let ctx = format!("h{h}/hkv{hkv}/d{d} q{q_len}/kv{kv_len}");
                assert_outputs_bit_eq(&s, &a, &format!("{ctx} [avx2 vs scalar]"));
                assert_outputs_bit_eq(&want, &a, &format!("{ctx} [avx2 vs oracle]"));
            }
        }
    }
}

/// Special-value f32 bit patterns, payloaded NaNs included.
const SPECIALS: [u32; 9] = [
    0x7FC0_0000, // canonical quiet NaN
    0xFFC0_1234, // negative NaN with payload bits
    0x7F80_0000, // +inf
    0xFF80_0000, // -inf
    0x0000_0001, // smallest positive subnormal
    0x8000_0001, // smallest negative subnormal
    0x8000_0000, // -0.0
    0x7F7F_FFFF, // f32::MAX
    0x0080_0000, // smallest positive normal
];

fn inject_specials(t: &mut CaTaskTensors, rng: &mut Rng) {
    for buf in [&mut t.q, &mut t.k, &mut t.v] {
        let n = 1 + rng.gen_index(0, 4);
        for _ in 0..n {
            let i = rng.gen_index(0, buf.len());
            buf[i] = f32::from_bits(SPECIALS[rng.gen_index(0, SPECIALS.len())]);
        }
    }
}

#[test]
fn adversarial_float_payloads_propagate_identically() {
    let (h, hkv, d) = (4usize, 2usize, 16usize);
    let oracle = ReferenceCaCompute::new(h, hkv, d);
    let scalar = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Scalar).threads(1);
    let avx2 = avx2_available()
        .then(|| FastCaCompute::new(h, hkv, d).backend(KernelBackend::Avx2).threads(1));
    let mut rng = Rng::new(0xBAD_F00D);
    for round in 0..40 {
        let (q_len, kv_len) = length_grid()[round % length_grid().len()];
        let mut t = synthetic_task(&mut rng, q_len, kv_len, h, hkv, d);
        inject_specials(&mut t, &mut rng);
        let want = oracle.run_batch(std::slice::from_ref(&t));
        let got = scalar.run_batch(std::slice::from_ref(&t)).unwrap();
        let ctx = format!("round {round} q{q_len}/kv{kv_len}");
        assert_outputs_bit_eq(&want, &got, &format!("{ctx} [scalar]"));
        if let Some(avx2) = &avx2 {
            let a = avx2.run_batch(std::slice::from_ref(&t)).unwrap();
            assert_outputs_bit_eq(&want, &a, &format!("{ctx} [avx2]"));
        }
    }
}

#[test]
fn fully_poisoned_tensors_agree_with_the_oracle() {
    // Whole-tensor pathologies: every score -inf (softmax over an empty
    // effective support), every Q NaN (total poisoning). The *value* is
    // garbage by construction; what matters is that every backend emits
    // the same garbage bits.
    let (h, hkv, d) = (2usize, 1usize, 8usize);
    let oracle = ReferenceCaCompute::new(h, hkv, d);
    let scalar = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Scalar).threads(1);
    let avx2 = avx2_available()
        .then(|| FastCaCompute::new(h, hkv, d).backend(KernelBackend::Avx2).threads(1));
    let mut rng = Rng::new(5);
    for pattern in [0xFF80_0000u32, 0x7FC0_0000, 0x7F80_0000] {
        for target in 0..3usize {
            let mut t = synthetic_task(&mut rng, 5, 9, h, hkv, d);
            let buf = match target {
                0 => &mut t.q,
                1 => &mut t.k,
                _ => &mut t.v,
            };
            for w in buf.iter_mut() {
                *w = f32::from_bits(pattern);
            }
            let want = oracle.run_batch(std::slice::from_ref(&t));
            let got = scalar.run_batch(std::slice::from_ref(&t)).unwrap();
            let ctx = format!("pattern {pattern:#010x} target {target}");
            assert_outputs_bit_eq(&want, &got, &format!("{ctx} [scalar]"));
            if let Some(avx2) = &avx2 {
                let a = avx2.run_batch(std::slice::from_ref(&t)).unwrap();
                assert_outputs_bit_eq(&want, &a, &format!("{ctx} [avx2]"));
            }
        }
    }
}

#[test]
fn thread_partition_never_changes_bytes() {
    let (h, hkv, d) = (8usize, 2usize, 16usize);
    let oracle = ReferenceCaCompute::new(h, hkv, d);
    let mut rng = Rng::new(0x7EAD);
    // Mixed-size batch large enough to clear the inline threshold, so
    // the scoped pool genuinely engages.
    let tasks: Vec<CaTaskTensors> = (0..8)
        .map(|i| {
            let kv = 64 + 32 * i;
            synthetic_task(&mut rng, 48 + i, kv, h, hkv, d)
        })
        .collect();
    let want = oracle.run_batch(&tasks);
    for backend in [Some(KernelBackend::Scalar), avx2_available().then_some(KernelBackend::Avx2)]
        .into_iter()
        .flatten()
    {
        let one = FastCaCompute::new(h, hkv, d).backend(backend).threads(1);
        let many = FastCaCompute::new(h, hkv, d).backend(backend).threads(8);
        let a = one.run_batch(&tasks).unwrap();
        let b = many.run_batch(&tasks).unwrap();
        assert_outputs_bit_eq(&a, &b, &format!("{backend:?} 1 vs 8 threads"));
        assert_outputs_bit_eq(&want, &b, &format!("{backend:?} threaded vs oracle"));
    }
}

/// The env selector drives everything (`distca worker`, the threaded
/// coordinator, the gateway): each value must map to the backend it
/// names and produce oracle bytes. One test fn mutates the env var so
/// the cases can't race each other under the parallel test runner; no
/// other test in this binary reads `DISTCA_KERNEL`.
#[test]
fn distca_kernel_env_selects_and_all_choices_agree() {
    let (h, hkv, d) = (4usize, 2usize, 16usize);
    let mut rng = Rng::new(0xE47);
    let t = synthetic_task(&mut rng, 37, 90, h, hkv, d);
    let oracle = ReferenceCaCompute::new(h, hkv, d);
    let want = oracle.run_batch(std::slice::from_ref(&t));

    let mut cases = vec![
        ("oracle", KernelChoice::Oracle),
        ("scalar", KernelChoice::Scalar),
        ("fast", KernelChoice::Fast),
    ];
    if avx2_available() {
        cases.push(("avx2", KernelChoice::Avx2));
    }
    for (val, expect) in cases {
        std::env::set_var("DISTCA_KERNEL", val);
        assert_eq!(choice_from_env(), expect, "DISTCA_KERNEL={val}");
        let mut compute = distca::kernel::compute_from_env(h, hkv, d);
        let got = vec![compute.run(&t).unwrap()];
        assert_outputs_bit_eq(&want, &got, &format!("DISTCA_KERNEL={val}"));
    }
    std::env::remove_var("DISTCA_KERNEL");
    assert_eq!(choice_from_env(), KernelChoice::Fast, "unset defaults to fast");
}
