//! System-level integration tests over the simulator + coordinator stack
//! (no PJRT required): the paper's qualitative claims as assertions.

use distca::config::run::DataDist;
use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::scheduler::items_from_chunks;
use distca::coordinator::{schedule, Profiler, SchedulerCfg};
use distca::data::distributions::sampler_for;
use distca::metrics::{speedup, weak_scaling_efficiency};
use distca::model::FlopsModel;
use distca::sim::strategies::{
    run_distca, run_packed_dp, run_perdoc_cp, run_varlen_chunking, run_wlb_ideal, CommMode,
    SimParams,
};
use distca::sim::IterationReport;
use distca::util::rng::Rng;

fn sample(dist: DataDist, max_doc: usize, tokens: usize, seed: u64) -> Vec<distca::data::Document> {
    let mut rng = Rng::new(seed);
    sampler_for(dist, max_doc).sample_tokens(&mut rng, tokens, 0)
}

fn avg<F: Fn(u64) -> IterationReport>(n: usize, f: F) -> IterationReport {
    let reports: Vec<IterationReport> = (0..n as u64).map(f).collect();
    IterationReport::average(&reports)
}

/// §6.2 headline: DistCA beats WLB-ideal across a small grid.
#[test]
fn distca_beats_wlb_across_grid() {
    for (model, nodes, max_doc) in [
        (ModelConfig::llama3_8b(), 8usize, 131_072usize),
        (ModelConfig::llama3_8b(), 16, 262_144),
        (ModelConfig::llama_34b(), 8, 131_072),
    ] {
        let p = SimParams::new(model.clone(), ClusterConfig::h200(nodes), 8, 1);
        let tokens = nodes * max_doc;
        let wlb = avg(3, |s| {
            run_wlb_ideal(&sample(DataDist::Pretrain, max_doc, tokens, 70 + s), max_doc, &p)
        });
        let ca = avg(3, |s| {
            run_distca(&sample(DataDist::Pretrain, max_doc, tokens, 70 + s), max_doc, &p)
        });
        let sp = speedup(&wlb, &ca);
        assert!(
            sp > 1.0,
            "{} {nodes} nodes {max_doc}: speedup {sp:.3} must exceed 1.0",
            model.name
        );
        assert!(sp < 2.5, "speedup {sp:.3} implausibly large — cost model drift?");
    }
}

/// §6: DistCA eliminates DP stragglers (near-perfect compute balance)
/// and keeps memory balanced where WLB chunking diverges.
#[test]
fn distca_balances_compute_and_memory() {
    let p = SimParams::new(ModelConfig::llama3_8b(), ClusterConfig::h200(8), 8, 1);
    let docs = sample(DataDist::ProLong, 262_144, 8 * 262_144, 5);
    let packed = run_packed_dp(&docs, 262_144, &p);
    let varlen = run_varlen_chunking(&docs, 131_072, &p);
    let ca = run_distca(&docs, 262_144, &p);
    assert!(ca.idle_fraction() < packed.idle_fraction());
    assert!(ca.idle_fraction() < 0.10, "near-perfect balance, got {}", ca.idle_fraction());
    assert!(ca.memory_divergence() <= varlen.memory_divergence() + 1e-9);
    assert!((ca.memory_divergence() - 1.0).abs() < 0.05);
}

/// §6.2: weak scaling of DistCA is near-linear.
#[test]
fn distca_weak_scaling_near_linear() {
    let max_doc = 131_072;
    let mut series = Vec::new();
    for nodes in [4usize, 8, 16] {
        let p = SimParams::new(ModelConfig::llama3_8b(), ClusterConfig::h200(nodes), 8, 1);
        let tokens = nodes * max_doc; // constant work per node
        let r = avg(3, |s| {
            run_distca(&sample(DataDist::Pretrain, max_doc, tokens, 80 + s), max_doc, &p)
        });
        series.push((nodes * 8, r.throughput()));
    }
    for (n, eff) in weak_scaling_efficiency(&series) {
        assert!(eff > 0.75, "weak-scaling efficiency at {n} GPUs: {eff:.3}");
    }
}

/// Fig. 11's ordering holds end-to-end for every model/scale combo.
#[test]
fn comm_mode_ordering() {
    for nodes in [4usize, 8] {
        let docs = sample(DataDist::Pretrain, 131_072, nodes * 131_072, 11);
        let run = |mode| {
            let mut p =
                SimParams::new(ModelConfig::llama3_8b(), ClusterConfig::h200(nodes), 8, 1);
            p.comm_mode = mode;
            run_distca(&docs, 131_072, &p).iter_time
        };
        let sig = run(CommMode::Signal);
        let pp = run(CommMode::PingPong);
        let ss = run(CommMode::SingleStream);
        assert!(sig <= pp + 1e-12 && pp <= ss + 1e-12, "{sig} {pp} {ss}");
    }
}

/// Per-document CP trades stragglers for all-gather: both effects visible.
#[test]
fn cp_tradeoff_visible() {
    let p = SimParams::new(ModelConfig::llama3_8b(), ClusterConfig::h200(8), 8, 1);
    let docs = sample(DataDist::Pretrain, 262_144, 4 * 262_144, 13);
    let dp = run_packed_dp(&docs, 262_144, &p);
    let cp = run_perdoc_cp(&docs, 262_144, 8, &p);
    assert!(cp.idle_fraction() < dp.idle_fraction(), "CP must balance");
    assert!(cp.comm_bytes > 0.0, "CP must pay all-gather bytes");
}

/// The scheduler's plan stays valid on real sampled workloads at scale.
#[test]
fn scheduler_plan_valid_at_scale() {
    let model = ModelConfig::llama3_8b();
    let f = FlopsModel::new(&model);
    let cluster = ClusterConfig::h200(32);
    let prof = Profiler::analytic(&f, &cluster);
    let docs = sample(DataDist::ProLong, 524_288, 16 * 524_288, 17);
    let chunks = distca::sim::strategies::distca_placement(&docs, 32);
    let items = items_from_chunks(&chunks);
    let plan = schedule(
        &items,
        32,
        &f,
        &prof,
        &model,
        &SchedulerCfg { tolerance: 0.05, ..Default::default() },
    );
    plan.validate(&items, &f).expect("plan invariants");
    assert!(plan.imbalance() < 1.10, "imbalance {}", plan.imbalance());
    // All-to-all bottleneck consistency with the exchange module.
    let a2a = distca::exchange::AllToAll::from_plan(&plan);
    assert!((a2a.total() - plan.total_comm_bytes()).abs() < 1.0);
    assert!(a2a.bottleneck_bytes() <= plan.total_comm_bytes() + 1.0);
}

/// CLI end-to-end: parse + run a simulate command programmatically.
#[test]
fn cli_args_parse_and_dispatch() {
    use distca::cli::{Args, FlagSpec};
    let specs = vec![
        FlagSpec::value("gpus", "", Some("64")),
        FlagSpec::boolean("json", ""),
    ];
    let raw: Vec<String> = ["simulate", "--gpus", "32", "--json"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let args = Args::parse(&raw, &specs).unwrap();
    assert_eq!(args.subcommand.as_deref(), Some("simulate"));
    assert_eq!(args.get_usize("gpus", 0).unwrap(), 32);
    assert!(args.get_bool("json"));
}

/// Reports round-trip through the JSON substrate.
#[test]
fn report_json_roundtrip_fields() {
    let p = SimParams::new(ModelConfig::llama3_8b(), ClusterConfig::h200(4), 8, 1);
    let docs = sample(DataDist::Pretrain, 65_536, 4 * 65_536, 19);
    let r = run_distca(&docs, 65_536, &p);
    let j = r.to_json();
    let text = j.to_string_pretty();
    let back = distca::util::json::parse(&text).unwrap();
    assert_eq!(back.get("strategy").unwrap().as_str(), Some("DistCA"));
    assert!(back.get("throughput_tok_s").unwrap().as_f64().unwrap() > 0.0);
}
