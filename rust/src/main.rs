//! `distca` — the launcher.
//!
//! Subcommands:
//!   simulate   one training iteration under a strategy on the simulated
//!              H200 cluster (the paper's testbed substitute)
//!   compare    DistCA vs WLB-ideal on one configuration
//!   schedule   run the §4.2 scheduler on a sampled batch and dump the
//!              plan (optionally as JSON)
//!   gateway    multi-tenant serving gateway over the shared pool
//!              (WFQ + admission; --soak for a 10k-tenant population)
//!   train      end-to-end tiny-LM training through the AOT artifacts
//!   report     straggler attribution from a --trace-out trace file,
//!              or per-tenant accounting from --gateway JSONL
//!   drift      compare a regenerated BENCH_*.json against its baseline
//!   bound      Appendix A max-partition bound for a model/bandwidth
//!   info       print model/cluster configuration tables

use distca::cli::{usage, Args, FlagSpec};
use distca::config::run::{DataDist, Strategy};
use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::scheduler::items_from_chunks;
use distca::coordinator::{
    schedule, schedule_with_beliefs, Profiler, SchedulerCfg, ServerBelief,
};
use distca::data::distributions::sampler_for;
use distca::elastic::{
    pp_tick_horizon, run_distca_pp_elastic, run_elastic_sim, run_elastic_sim_obs,
    sim_auto_mem_budget, AutoscaleCfg, ElasticCfg, ElasticCoordinator, ElasticPpCfg,
    ElasticSimCfg, ElasticTask, FaultPlan, ReferenceCaCompute,
};
use distca::obs::drift::{compare, wall_clock_keys, DriftCfg};
use distca::obs::report::breakdown;
use distca::obs::trace::{read_trace, write_trace};
use distca::obs::Recorder;
use distca::memplan::MemReport;
use distca::model::FlopsModel;
use distca::runtime::ca_exec::synthetic_task;
use distca::runtime::train::{MarkovCorpus, TrainDriver};
use distca::sim::strategies::{
    distca_placement, run_distca, run_packed_dp, run_perdoc_cp, run_wlb_ideal, SimParams,
};
use distca::util::json::Json;
use distca::util::rng::Rng;
use distca::util::tables::{bytes, f as fmt_f, secs, Table};

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("simulate", "simulate one iteration under --strategy"),
    ("compare", "DistCA vs WLB-ideal on one configuration"),
    ("schedule", "run the scheduler on a sampled batch; print the plan"),
    ("memory", "per-server transient-memory balance: DistCA in-place vs colocated"),
    ("elastic", "elastic server pool under a fault plan (sim or threaded; --pp for PP ticks)"),
    ("worker", "attention-server worker daemon: listen for a coordinator over TCP"),
    ("serve", "networked coordinator over worker processes (--spawn | --connect a,b,c)"),
    ("soak", "networked soak harness: replay a document-length mix, emit BENCH_net.json"),
    ("gateway", "multi-tenant gateway: WFQ + admission over the shared pool (--soak: 10k tenants)"),
    ("train", "train the tiny LM end-to-end via AOT artifacts"),
    ("report", "straggler attribution from a --trace-out file (Fig. 11-style overlap table)"),
    ("top", "live dashboard: poll a --metrics-listen endpoint, render quantiles + gauges"),
    ("obsbench", "recorder/lineage overhead microbench; write BENCH_obs.json"),
    ("drift", "compare a regenerated BENCH_*.json snapshot against its committed baseline"),
    ("bound", "Appendix A max-partition bound"),
    ("info", "print model & cluster configs"),
];

fn specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec::value("model", "llama-8b | llama-34b | tiny-100m", Some("llama-8b")),
        FlagSpec::value("gpus", "number of GPUs (multiple of 8)", Some("64")),
        FlagSpec::value("max-doc-len", "max document length (tokens)", Some("131072")),
        FlagSpec::value("tokens", "tokens per batch (default: 2 chunks)", None),
        FlagSpec::value("strategy", "packed | cp | wlb | distca", Some("distca")),
        FlagSpec::value("data", "pretrain | prolong", Some("pretrain")),
        FlagSpec::value("tp", "tensor-parallel degree", Some("8")),
        FlagSpec::optional_value(
            "pp",
            "pipeline-parallel degree; bare --pp selects ping-pong PP ticks (elastic: degree 2; serve/soak: overlapped wire waves)",
            "1",
        ),
        FlagSpec::value("cp", "context-parallel degree (cp strategy)", Some("4")),
        FlagSpec::value("tolerance", "scheduler imbalance tolerance", Some("0.10")),
        FlagSpec::value("seed", "PRNG seed (default: $DISTCA_SEED, else 42)", None),
        FlagSpec::value("batches", "batches to average", Some("5")),
        FlagSpec::value("steps", "train steps (train)", Some("100")),
        FlagSpec::value("ticks", "scheduling rounds (elastic; default 4)", None),
        FlagSpec::value("servers", "pool size (elastic; default: gpus/tp)", None),
        FlagSpec::value("runtime", "sim | threaded (elastic)", Some("sim")),
        FlagSpec::value(
            "fault",
            "fault spec, e.g. kill:1@2,slow:2@1x0.25,drain:0@2,rejoin:1@3",
            None,
        ),
        FlagSpec::value("fault-plan", "JSON fault-plan file (elastic)", None),
        FlagSpec::value(
            "mem-budget",
            "per-server arena byte budget (schedule/memory/elastic sim; 0 = unconstrained, \
             memory and elastic sim accept `auto` = 1.25x the unconstrained peak)",
            None,
        ),
        FlagSpec::value(
            "speeds",
            "comma-separated believed per-server speeds (schedule: plan estimated \
             seconds against them and report the makespan vs the uniform plan)",
            None,
        ),
        FlagSpec::value(
            "belief-speeds",
            "comma-separated believed per-server speeds seeded before tick 0 \
             (elastic --runtime sim, incl. --pp: slow-from-tick-0 beliefs)",
            None,
        ),
        FlagSpec::boolean("autoscale", "enable pool autoscaling (elastic, incl. --pp sim)"),
        FlagSpec::value(
            "listen",
            "worker listen address (worker; :0 = kernel port)",
            Some("127.0.0.1:0"),
        ),
        FlagSpec::value("port-file", "write the bound worker address here (worker)", None),
        FlagSpec::value("workers", "worker process count (serve/soak)", Some("4")),
        FlagSpec::boolean("spawn", "spawn local worker processes (serve/soak)"),
        FlagSpec::value("connect", "comma-separated worker addresses (serve/soak)", None),
        FlagSpec::value(
            "docs-per-tick",
            "documents sampled per tick (serve/soak; default 2x workers)",
            None,
        ),
        FlagSpec::value("stats-out", "per-server per-tick JSONL stats path (serve/soak)", None),
        FlagSpec::value(
            "bench-out",
            "summary JSON path (soak: default BENCH_net.json; gateway --soak: BENCH_gateway.json)",
            None,
        ),
        FlagSpec::value("tenants", "synthetic tenant population (gateway; soak default 10000)", None),
        FlagSpec::value(
            "arrival-rate",
            "pool-wide mean doc arrivals per wave (gateway; default 12x workers)",
            None,
        ),
        FlagSpec::boolean("soak", "gateway soak: 10k-tenant defaults, write BENCH_gateway.json"),
        FlagSpec::value(
            "accounting-out",
            "per-wave + per-tenant accounting JSONL path (gateway)",
            None,
        ),
        FlagSpec::value(
            "diurnal",
            "diurnal cycle length in waves, 0 disables (gateway)",
            Some("24"),
        ),
        FlagSpec::value(
            "gateway",
            "gateway --accounting-out JSONL to render as a per-tenant table (report)",
            None,
        ),
        FlagSpec::value(
            "trace-out",
            "Chrome trace-event JSON output, Perfetto-loadable (elastic, serve/soak)",
            None,
        ),
        FlagSpec::value("trace", "trace file to analyze (report)", None),
        FlagSpec::value("baseline", "committed BENCH_*.json snapshot (drift)", None),
        FlagSpec::value("candidate", "freshly regenerated BENCH_*.json (drift)", None),
        FlagSpec::value(
            "drift-tolerance",
            "max relative deviation for numeric leaves (drift)",
            Some("0.2"),
        ),
        FlagSpec::value(
            "hb-ms",
            "worker heartbeat interval in ms (serve/soak; 0 disables)",
            Some("200"),
        ),
        FlagSpec::value(
            "metrics-listen",
            "live Prometheus-text metrics endpoint, e.g. 127.0.0.1:9464 or :0 (serve/soak/gateway)",
            None,
        ),
        FlagSpec::value(
            "metrics-addr",
            "metrics endpoint to poll, host:port (top)",
            None,
        ),
        FlagSpec::value("interval-ms", "dashboard refresh interval in ms (top)", Some("1000")),
        FlagSpec::value(
            "iterations",
            "dashboard refresh count, 0 = until interrupted (top)",
            Some("0"),
        ),
        FlagSpec::boolean(
            "lineage",
            "render the straggler root-cause table from the trace's lineage sidecar (report)",
        ),
        FlagSpec::boolean("json", "emit JSON instead of tables"),
        FlagSpec::boolean("verbose", "debug logging"),
    ]
}

fn main() {
    distca::util::logging::init_from_env();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&raw, &specs()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n\n{}", usage("distca", SUBCOMMANDS, &specs()));
            std::process::exit(2);
        }
    };
    if args.get_bool("verbose") {
        distca::util::logging::set_level(distca::util::logging::Level::Debug);
    }
    let result = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("compare") => cmd_compare(&args),
        Some("schedule") => cmd_schedule(&args),
        Some("memory") => cmd_memory(&args),
        Some("elastic") => cmd_elastic(&args),
        Some("worker") => cmd_worker(&args),
        Some("serve") => cmd_net(&args, false),
        Some("soak") => cmd_net(&args, true),
        Some("gateway") => cmd_gateway(&args),
        Some("train") => cmd_train(&args),
        Some("report") => cmd_report(&args),
        Some("top") => cmd_top(&args),
        Some("obsbench") => cmd_obsbench(&args),
        Some("drift") => cmd_drift(&args),
        Some("bound") => cmd_bound(&args),
        Some("info") => cmd_info(&args),
        _ => {
            println!("{}", usage("distca", SUBCOMMANDS, &specs()));
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Setup {
    model: ModelConfig,
    params: SimParams,
    max_doc: usize,
    tokens: usize,
    data: DataDist,
    seed: u64,
    batches: usize,
}

fn setup(args: &Args) -> anyhow::Result<Setup> {
    let model = ModelConfig::by_name(args.req("model")?)
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let gpus = args.get_usize("gpus", 64)?;
    anyhow::ensure!(gpus % 8 == 0, "--gpus must be a multiple of 8");
    let tp = args.get_usize("tp", 8)?;
    let pp = args.get_usize("pp", 1)?;
    let max_doc = args.get_usize("max-doc-len", 131_072)?;
    let tokens = args.get_usize("tokens", 2 * max_doc * (gpus / 64).max(1))?;
    let mut params = SimParams::new(model.clone(), ClusterConfig::h200(gpus / 8), tp, pp);
    params.tolerance = args.get_f64("tolerance", 0.10)?;
    Ok(Setup {
        model,
        params,
        max_doc,
        tokens,
        data: DataDist::from_str(args.req("data")?)
            .ok_or_else(|| anyhow::anyhow!("unknown data distribution"))?,
        seed: match args.get_parse::<u64>("seed")? {
            Some(s) => s,
            None => distca::util::rng::seed_from_env(42),
        },
        batches: args.get_usize("batches", 5)?,
    })
}

fn report_row(t: &mut Table, r: &distca::sim::IterationReport) {
    t.row(&[
        r.strategy.clone(),
        r.config.clone(),
        secs(r.iter_time),
        format!("{:.3e}", r.throughput()),
        fmt_f(r.idle_fraction() * 100.0, 1),
        fmt_f(r.memory_divergence(), 2),
        bytes(r.comm_bytes),
        if r.oom { "OOM".into() } else { "-".into() },
    ]);
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let s = setup(args)?;
    let strategy = Strategy::from_str(args.req("strategy")?)
        .ok_or_else(|| anyhow::anyhow!("unknown strategy"))?;
    let cp = args.get_usize("cp", 4)?;
    let mut reports = Vec::new();
    for b in 0..s.batches {
        let mut rng = Rng::new(s.seed + b as u64 * 7919);
        let docs = sampler_for(s.data, s.max_doc).sample_tokens(&mut rng, s.tokens, 0);
        reports.push(match strategy {
            Strategy::Packed => run_packed_dp(&docs, s.max_doc, &s.params),
            Strategy::PerDocCp => run_perdoc_cp(&docs, s.max_doc, cp, &s.params),
            Strategy::WlbIdeal => run_wlb_ideal(&docs, s.max_doc, &s.params),
            Strategy::DistCa => run_distca(&docs, s.max_doc, &s.params),
        });
    }
    let avg = distca::sim::IterationReport::average(&reports);
    if args.get_bool("json") {
        println!("{}", avg.to_json().to_string_pretty());
    } else {
        let mut t = Table::new(
            &format!("{} on {} GPUs, {} (avg of {})", strategy.name(),
                     s.params.cluster.n_gpus(), s.data.name(), s.batches),
            &["strategy", "config", "iter", "tok/s", "idle%", "mem div", "comm", "oom"],
        );
        report_row(&mut t, &avg);
        t.print();
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let s = setup(args)?;
    let mut wlb = Vec::new();
    let mut ca = Vec::new();
    for b in 0..s.batches {
        let mut rng = Rng::new(s.seed + b as u64 * 7919);
        let docs = sampler_for(s.data, s.max_doc).sample_tokens(&mut rng, s.tokens, 0);
        wlb.push(run_wlb_ideal(&docs, s.max_doc, &s.params));
        ca.push(run_distca(&docs, s.max_doc, &s.params));
    }
    let wlb = distca::sim::IterationReport::average(&wlb);
    let ca = distca::sim::IterationReport::average(&ca);
    if args.get_bool("json") {
        let j = Json::obj(vec![
            ("baseline", wlb.to_json()),
            ("distca", ca.to_json()),
            ("speedup", Json::Num(wlb.iter_time / ca.iter_time)),
        ]);
        println!("{}", j.to_string_pretty());
    } else {
        let mut t = Table::new(
            &format!("{} | {} GPUs | maxdoc {}K | {}", s.model.name,
                     s.params.cluster.n_gpus(), s.max_doc / 1024, s.data.name()),
            &["strategy", "config", "iter", "tok/s", "idle%", "mem div", "comm", "oom"],
        );
        report_row(&mut t, &wlb);
        report_row(&mut t, &ca);
        t.print();
        println!("speedup: {:.2}x", wlb.iter_time / ca.iter_time);
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> anyhow::Result<()> {
    let s = setup(args)?;
    anyhow::ensure!(
        args.get("belief-speeds").is_none(),
        "--belief-speeds belongs to `distca elastic`; schedule takes --speeds"
    );
    let n = s.params.n_logical();
    let mut rng = Rng::new(s.seed);
    let docs = sampler_for(s.data, s.max_doc).sample_tokens(&mut rng, s.tokens, 0);
    let chunks = distca_placement(&docs, n);
    let items = items_from_chunks(&chunks);
    let f = FlopsModel::new(&s.model);
    let prof = Profiler::analytic(&f, &s.params.cluster);
    let mem_budget = args.get_f64("mem-budget", 0.0)?;
    let speeds = args.get("speeds").map(|spec| parse_speeds(spec, n)).transpose()?;
    let cfg = SchedulerCfg { tolerance: s.params.tolerance, mem_budget, ..Default::default() };
    let t0 = std::time::Instant::now();
    let plan = match &speeds {
        Some(sp) => schedule_with_beliefs(
            &items,
            &ServerBelief::from_speeds(sp, mem_budget),
            &f,
            &prof,
            &s.model,
            &cfg,
        ),
        None => schedule(&items, n, &f, &prof, &s.model, &cfg),
    };
    let dt = t0.elapsed();
    // Heterogeneity report: the uniform (FLOPs-balanced) plan evaluated
    // under the same believed speeds, for comparison.
    let uniform_makespan = speeds
        .as_ref()
        .map(|sp| schedule(&items, n, &f, &prof, &s.model, &cfg).makespan_under(sp));
    let mem = MemReport::for_plan(&plan, &s.model, mem_budget).map_err(|e| {
        anyhow::anyhow!(
            "--mem-budget {mem_budget} is infeasible for this batch \
             (best-effort plan still overflows: {e}); raise the budget"
        )
    })?;
    if args.get_bool("json") {
        let servers: Vec<Json> = (0..n)
            .map(|srv| {
                Json::obj(vec![
                    ("server", Json::Num(srv as f64)),
                    ("load_s", Json::Num(plan.server_load[srv])),
                    (
                        "tasks",
                        Json::Num(
                            plan.assignments.iter().filter(|a| a.server == srv).count() as f64,
                        ),
                    ),
                ])
            })
            .collect();
        let mut fields = vec![
            ("n_servers", Json::Num(n as f64)),
            ("imbalance", Json::Num(plan.imbalance())),
            ("total_comm_bytes", Json::Num(plan.total_comm_bytes())),
            ("local_fraction", Json::Num(plan.local_fraction())),
            ("schedule_time_s", Json::Num(dt.as_secs_f64())),
            ("predicted_makespan_s", Json::Num(plan.predicted_makespan())),
            ("transient_mem", mem.to_json()),
            ("servers", Json::Arr(servers)),
        ];
        if let (Some(sp), Some(u)) = (&speeds, uniform_makespan) {
            fields.push((
                "believed_speeds",
                Json::Arr(sp.iter().map(|&v| Json::Num(v)).collect()),
            ));
            fields.push(("uniform_plan_makespan_s", Json::Num(u)));
        }
        let j = Json::obj(fields);
        println!("{}", j.to_string_pretty());
    } else {
        let mut t = Table::new(
            &format!("plan: {} items -> {} servers in {}", items.len(), n, secs(dt.as_secs_f64())),
            &["server", "CA load", "vs target", "tasks"],
        );
        for srv in 0..n {
            t.row(&[
                srv.to_string(),
                secs(plan.server_load[srv]),
                format!("{:+.1}%", (plan.server_load[srv] / plan.target_load - 1.0) * 100.0),
                plan.assignments.iter().filter(|a| a.server == srv).count().to_string(),
            ]);
        }
        t.print();
        println!(
            "imbalance {:.3} | dispatch {} | {:.0}% local",
            plan.imbalance(),
            bytes(plan.total_comm_bytes()),
            plan.local_fraction() * 100.0
        );
        if let (Some(sp), Some(u)) = (&speeds, uniform_makespan) {
            println!(
                "believed speeds {:?}: makespan {} vs uniform plan {} ({:.2}x better)",
                sp,
                secs(plan.predicted_makespan()),
                secs(u),
                u / plan.predicted_makespan().max(1e-12),
            );
        }
        println!(
            "arena peak {} max / {} mean (ratio {:.3}){}",
            bytes(mem.max_peak()),
            bytes(mem.mean_peak()),
            mem.max_mean_ratio(),
            if mem_budget > 0.0 {
                let verdict = if mem.within_budget() { "ok" } else { "EXCEEDED" };
                format!(" | budget {} — {verdict}", bytes(mem_budget))
            } else {
                String::new()
            }
        );
    }
    Ok(())
}

/// `distca memory` — the §5 / Fig. 3b claim, measured: per-server
/// transient arena bytes of DistCA's balanced in-place execution vs the
/// colocated baseline (compute-balanced whole-document placement, whose
/// bytes inherit the token skew — Fig. 1's dilemma), optionally under a
/// hard `--mem-budget` (explicit bytes or `auto` = 1.25× the
/// unconstrained peak).
fn cmd_memory(args: &Args) -> anyhow::Result<()> {
    let s = setup(args)?;
    let n = s.params.n_logical();
    let mut rng = Rng::new(s.seed);
    let docs = sampler_for(s.data, s.max_doc).sample_tokens(&mut rng, s.tokens, 0);
    let chunks = distca_placement(&docs, n);
    let items = items_from_chunks(&chunks);
    let f = FlopsModel::new(&s.model);
    let prof = Profiler::analytic(&f, &s.params.cluster);

    // The unconstrained plan sets the "free" balance and the auto budget.
    let base_cfg = SchedulerCfg { tolerance: s.params.tolerance, ..Default::default() };
    let unconstrained = schedule(&items, n, &f, &prof, &s.model, &base_cfg);
    let free_mem = MemReport::for_plan(&unconstrained, &s.model, 0.0)
        .expect("unbounded replay cannot OOM");

    let budget = match args.get("mem-budget") {
        None => 0.0,
        Some("auto") => 1.25 * free_mem.max_peak(),
        Some(v) => v
            .parse::<f64>()
            .map_err(|_| anyhow::anyhow!("--mem-budget: expected bytes or `auto`, got `{v}`"))?,
    };
    let (plan, mem) = if budget > 0.0 {
        let cfg = SchedulerCfg { mem_budget: budget, ..base_cfg };
        let plan = schedule(&items, n, &f, &prof, &s.model, &cfg);
        let mem = MemReport::for_plan(&plan, &s.model, budget).map_err(|e| {
            anyhow::anyhow!(
                "--mem-budget {budget} is infeasible for this batch \
                 (best-effort plan still overflows: {e}); raise the budget"
            )
        })?;
        (plan, mem)
    } else {
        (unconstrained, free_mem.clone())
    };
    let colocated = MemReport::colocated(&items, n, &s.model);

    if args.get_bool("json") {
        let j = Json::obj(vec![
            ("n_servers", Json::Num(n as f64)),
            ("budget_bytes", Json::Num(budget)),
            ("compute_imbalance", Json::Num(plan.imbalance())),
            ("distca_in_place", mem.to_json()),
            ("colocated_baseline", colocated.to_json()),
            (
                "ratio_improvement",
                Json::Num(colocated.max_mean_ratio() / mem.max_mean_ratio()),
            ),
        ]);
        println!("{}", j.to_string_pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!(
            "transient memory: {} items -> {n} servers ({}, maxdoc {}K)",
            items.len(),
            s.data.name(),
            s.max_doc / 1024
        ),
        &["server", "DistCA in-place", "vs mean", "colocated", "vs mean"],
    );
    for srv in 0..n {
        let d = mem.per_server_peak[srv];
        let c = colocated.per_server_peak[srv];
        t.row(&[
            srv.to_string(),
            bytes(d),
            format!("{:+.1}%", (d / mem.mean_peak().max(1.0) - 1.0) * 100.0),
            bytes(c),
            format!("{:+.1}%", (c / colocated.mean_peak().max(1.0) - 1.0) * 100.0),
        ]);
    }
    t.print();
    println!(
        "max/mean ratio: DistCA {:.3} vs colocated {:.3} | compute imbalance {:.3}{}",
        mem.max_mean_ratio(),
        colocated.max_mean_ratio(),
        plan.imbalance(),
        if budget > 0.0 {
            format!(
                " | budget {} — {}",
                bytes(budget),
                if mem.within_budget() { "ok" } else { "EXCEEDED" }
            )
        } else {
            String::new()
        }
    );
    Ok(())
}

/// Parse a comma-separated believed-speed list (`1,0.25,1`), padding
/// with 1.0 up to `n` servers. Rejects non-positive or non-finite
/// entries and lists longer than the pool.
fn parse_speeds(spec: &str, n: usize) -> anyhow::Result<Vec<f64>> {
    let mut out: Vec<f64> = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let v: f64 = tok
            .parse()
            .map_err(|_| anyhow::anyhow!("bad believed speed `{tok}`"))?;
        anyhow::ensure!(v > 0.0 && v.is_finite(), "believed speed {v} must be positive");
        out.push(v);
    }
    anyhow::ensure!(!out.is_empty(), "empty believed-speed list");
    anyhow::ensure!(
        out.len() <= n,
        "{} believed speeds for a pool of {n} servers",
        out.len()
    );
    out.resize(n, 1.0);
    Ok(out)
}

/// Resolve the fault plan from `--fault-plan` (JSON file), `--fault`
/// (compact spec), or — when neither is given — a seeded random plan.
/// Exception: when a heterogeneity-study flag (`--belief-speeds` /
/// `--mem-budget`) is present, the *absence* of a fault flag means a
/// fault-free run — beliefs and budgets are the scenario under study,
/// and injecting random kills would muddy the zero-re-dispatch claim.
fn fault_plan_from(args: &Args, n_servers: usize, ticks: usize, seed: u64) -> anyhow::Result<FaultPlan> {
    if let Some(path) = args.get("fault-plan") {
        let j = distca::util::json::parse_file(std::path::Path::new(path))
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        return FaultPlan::from_json(&j).map_err(|e| anyhow::anyhow!("{path}: {e}"));
    }
    if let Some(spec) = args.get("fault") {
        return FaultPlan::parse_spec(spec).map_err(|e| anyhow::anyhow!(e));
    }
    if args.get("belief-speeds").is_some() || args.get("mem-budget").is_some() {
        return Ok(FaultPlan::new());
    }
    anyhow::ensure!(n_servers >= 2 && ticks >= 2, "random fault plan needs >=2 servers and ticks");
    let mut rng = Rng::new(seed ^ 0xFA17_FA17);
    Ok(FaultPlan::random(&mut rng, n_servers, ticks, 1, 1))
}

/// Reject fault events that would silently never fire — an unknown
/// server or a tick the run never reaches would make a "fault-covered"
/// run vacuously green. A `Rejoin` past the horizon stays legal: it is
/// a recovery, and "the server never comes back within the observation
/// window" is a legitimate plan shape.
fn ensure_fault_in_scope(fault: &FaultPlan, n_servers: usize, ticks: usize) -> anyhow::Result<()> {
    for ev in &fault.events {
        anyhow::ensure!(
            ev.server() < n_servers,
            "fault `{}` names server {} but the pool has only {n_servers} servers",
            ev.to_spec(),
            ev.server()
        );
        if matches!(ev, distca::elastic::FaultEvent::Rejoin { .. }) {
            continue;
        }
        anyhow::ensure!(
            ev.tick() < ticks,
            "fault `{}` names tick {} but the run has only {ticks} ticks",
            ev.to_spec(),
            ev.tick()
        );
    }
    Ok(())
}

fn cmd_elastic(args: &Args) -> anyhow::Result<()> {
    let s = setup(args)?;
    anyhow::ensure!(
        args.get("speeds").is_none(),
        "--speeds belongs to `distca schedule`; elastic takes --belief-speeds"
    );
    // Belief seeding and byte budgets are simulator features: the
    // threaded runtime learns beliefs through the gray-health loop and
    // models memory only via scripted `oom:` events.
    if args.req("runtime")? == "threaded" {
        anyhow::ensure!(
            args.get("belief-speeds").is_none(),
            "--belief-speeds applies to --runtime sim (the threaded runtime learns \
             beliefs via gray demotion)"
        );
        anyhow::ensure!(
            args.get("mem-budget").is_none(),
            "--mem-budget applies to --runtime sim (use an oom: fault for the threaded runtime)"
        );
    }
    // `--pp` (bare or with a degree >= 2) selects elastic ping-pong PP:
    // membership events land mid-PP-tick, wave-scoped.
    let pp_mode = args.get_bool("pp") || s.params.pp >= 2;
    if pp_mode && args.req("runtime")? == "sim" {
        // The PP sim derives pool size and tick count from the schedule
        // itself, so its fault plan is built (and validated) in there.
        return cmd_elastic_pp_sim(args, &s);
    }
    // The threaded PP runtime executes one PP tick (two nano-batch
    // waves) at a time; a pipeline depth beyond 2 only shapes the sim
    // schedule — accepting it here would silently change nothing.
    anyhow::ensure!(
        !(pp_mode && s.params.pp > 2),
        "--pp {} is only meaningful with --runtime sim; the threaded runtime runs \
         tick-at-a-time (use bare --pp or --pp 2)",
        s.params.pp
    );
    let n = args.get_usize("servers", s.params.n_logical())?;
    anyhow::ensure!(n >= 2, "--servers must be at least 2");
    let ticks = args.get_usize("ticks", 4)?;
    let fault = fault_plan_from(args, n, ticks, s.seed)?;
    ensure_fault_in_scope(&fault, n, ticks)?;
    match (args.req("runtime")?, pp_mode) {
        ("sim", _) => cmd_elastic_sim(args, &s, n, ticks, &fault),
        ("threaded", false) => cmd_elastic_threaded(args, n, ticks, s.seed, &fault),
        ("threaded", true) => cmd_elastic_pp_threaded(args, n, ticks, s.seed, &fault),
        (other, _) => anyhow::bail!("--runtime must be sim or threaded, got `{other}`"),
    }
}

fn cmd_elastic_pp_sim(args: &Args, s: &Setup) -> anyhow::Result<()> {
    let mut params = s.params.clone();
    if params.pp < 2 {
        params.pp = 2;
    }
    anyhow::ensure!(
        params.n_logical() % params.pp == 0,
        "{} logical devices not divisible by pp={}",
        params.n_logical(),
        params.pp
    );
    // The attention-server pool under PP is the cluster's logical
    // devices, and the tick count is the schedule's own horizon —
    // reject flags that would otherwise be silently ignored.
    anyhow::ensure!(
        args.get("servers").is_none(),
        "--servers does not apply to --pp sim (the pool is gpus/tp logical devices)"
    );
    anyhow::ensure!(
        args.get("ticks").is_none(),
        "--ticks does not apply to --pp sim (the schedule runs 2(m + pp - 1) PP ticks)"
    );
    anyhow::ensure!(
        args.get("mem-budget").is_none(),
        "--mem-budget applies to the flat elastic sim only (the PP sim models bytes \
         via scripted oom: events; see ElasticSimCfg::mem_budget)"
    );
    let n = params.n_logical();
    let mut rng = Rng::new(s.seed);
    let docs = sampler_for(s.data, s.max_doc).sample_tokens(&mut rng, s.tokens, 0);
    // Real horizon of the same-phase schedule: 2(m + pp - 1) ticks.
    let pp_ticks = pp_tick_horizon(&docs, s.max_doc, &params);
    let fault = fault_plan_from(args, n, pp_ticks, s.seed)?;
    ensure_fault_in_scope(&fault, n, pp_ticks)?;
    // Autoscaling runs on the wave clock at ping boundaries; capacity is
    // capped at the physical topology, so growth restores dead servers
    // rather than minting devices the cluster does not have.
    let cfg = ElasticPpCfg {
        autoscale: args
            .get_bool("autoscale")
            .then(|| AutoscaleCfg { max_servers: n, ..Default::default() }),
        belief_speeds: args
            .get("belief-speeds")
            .map(|spec| parse_speeds(spec, n))
            .transpose()?,
        ..Default::default()
    };
    let report = run_distca_pp_elastic(&docs, s.max_doc, &params, &fault, &cfg)?;
    if args.get_bool("json") {
        println!("{}", report.to_json().to_string_pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!(
            "elastic PP sim: {} devices, pp={}, {} ticks, fault plan [{}]",
            params.n_logical(),
            params.pp,
            report.per_tick.len(),
            if fault.is_empty() { "none".to_string() } else { fault.to_spec() }
        ),
        &[
            "tick", "ph", "alive", "tasks", "lost", "redisp", "remap", "kept", "oom",
            "demoted", "epochs", "tick time", "fault-free", "events",
        ],
    );
    for r in &report.per_tick {
        t.row(&[
            r.tick.to_string(),
            match r.phase {
                distca::parallel::pipeline::PipePhase::Forward => "F".into(),
                distca::parallel::pipeline::PipePhase::Backward => "B".into(),
            },
            r.n_alive.to_string(),
            r.n_tasks.to_string(),
            r.lost_tasks.to_string(),
            r.redispatched.to_string(),
            r.remapped.to_string(),
            r.drain_kept.to_string(),
            r.oom_evicted.to_string(),
            r.demoted.to_string(),
            format!("{}/{}", r.epochs[0], r.epochs[1]),
            secs(r.tick_time),
            secs(r.fault_free_time),
            r.events.join(" "),
        ]);
    }
    t.print();
    println!(
        "total {} | fault-free {} | recovery overhead {} | goodput ratio {:.3} | {} re-dispatched, {} remapped, {} lost",
        secs(report.total_time),
        secs(report.fault_free_time),
        secs(report.recovery_overhead()),
        report.goodput_ratio(),
        report.redispatched,
        report.remapped,
        report.lost_tasks,
    );
    Ok(())
}

fn cmd_elastic_pp_threaded(
    args: &Args,
    n: usize,
    ticks: usize,
    seed: u64,
    fault: &FaultPlan,
) -> anyhow::Result<()> {
    let autoscale = args
        .get_bool("autoscale")
        .then(|| AutoscaleCfg { max_servers: n, ..Default::default() });
    let trace_out = args.get("trace-out").map(std::path::Path::new);
    let (stats, alive) = run_threaded_ticks(n, ticks, seed, fault, true, autoscale, trace_out)?;
    let rows: Vec<Vec<String>> = stats
        .iter()
        .zip(&alive)
        .map(|(st, &n_alive)| {
            vec![
                st.tick.to_string(),
                n_alive.to_string(),
                st.n_tasks.to_string(),
                st.redispatched.to_string(),
                st.remapped.to_string(),
                format!("{}/{}", st.wave_redispatched[0], st.wave_redispatched[1]),
                format!("{}/{}", st.wave_epochs[0], st.wave_epochs[1]),
                secs(st.elapsed),
            ]
        })
        .collect();
    if args.get_bool("json") {
        let per_tick: Vec<Json> = stats
            .iter()
            .map(|st| {
                Json::obj(vec![
                    ("tick", Json::Num(st.tick as f64)),
                    ("tasks", Json::Num(st.n_tasks as f64)),
                    ("redispatched", Json::Num(st.redispatched as f64)),
                    ("remapped", Json::Num(st.remapped as f64)),
                    ("ping_redispatched", Json::Num(st.wave_redispatched[0] as f64)),
                    ("pong_redispatched", Json::Num(st.wave_redispatched[1] as f64)),
                    ("epoch_ping", Json::Num(st.wave_epochs[0] as f64)),
                    ("epoch_pong", Json::Num(st.wave_epochs[1] as f64)),
                    ("duplicates_suppressed", Json::Num(st.duplicates_suppressed as f64)),
                    ("elapsed_s", Json::Num(st.elapsed)),
                ])
            })
            .collect();
        let j = Json::obj(vec![
            ("servers", Json::Num(n as f64)),
            ("ticks", Json::Num(ticks as f64)),
            ("mode", Json::Str("pp".into())),
            ("fault_plan", Json::Str(fault.to_spec())),
            ("bit_exact", Json::Bool(true)),
            ("per_tick", Json::Arr(per_tick)),
        ]);
        println!("{}", j.to_string_pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!(
            "elastic PP threaded: {n} reference servers, {ticks} PP ticks, fault plan [{}] — all outputs bit-exact",
            if fault.is_empty() { "none".to_string() } else { fault.to_spec() }
        ),
        &["tick", "alive", "tasks", "redisp", "remap", "wave redisp", "epochs", "elapsed"],
    );
    for r in rows {
        t.row(&r);
    }
    t.print();
    let redisp: usize = stats.iter().map(|s| s.redispatched).sum();
    let remap: usize = stats.iter().map(|s| s.remapped).sum();
    println!(
        "re-dispatched {redisp} (ping-wave only) | remapped {remap} | outputs verified against the monolithic oracle"
    );
    Ok(())
}

fn cmd_elastic_sim(
    args: &Args,
    s: &Setup,
    n: usize,
    ticks: usize,
    fault: &FaultPlan,
) -> anyhow::Result<()> {
    let batches: Vec<Vec<distca::data::Document>> = (0..ticks)
        .map(|t| {
            let mut rng = Rng::new(s.seed + t as u64 * 7919);
            sampler_for(s.data, s.max_doc).sample_tokens(&mut rng, s.tokens, 0)
        })
        .collect();
    let mem_budget = match args.get("mem-budget") {
        None => 0.0,
        Some("auto") => sim_auto_mem_budget(&batches, n, &s.params, 1.25)?,
        Some(v) => v.parse::<f64>().map_err(|_| {
            anyhow::anyhow!("--mem-budget: expected bytes or `auto`, got `{v}`")
        })?,
    };
    let cfg = ElasticSimCfg {
        autoscale: args.get_bool("autoscale").then(AutoscaleCfg::default),
        belief_speeds: args
            .get("belief-speeds")
            .map(|spec| parse_speeds(spec, n))
            .transpose()?,
        mem_budget,
        ..Default::default()
    };
    // `--trace-out` on the sim path emits the same trace schema on the
    // virtual clock: one recorder API, two clock sources.
    let recorder = args.get("trace-out").map(|_| Recorder::new_virtual());
    let report = match &recorder {
        Some(r) => run_elastic_sim_obs(&batches, n, &s.params, fault, &cfg, Some(r))?,
        None => run_elastic_sim(&batches, n, &s.params, fault, &cfg)?,
    };
    if let (Some(r), Some(path)) = (&recorder, args.get("trace-out")) {
        write_trace(r, std::path::Path::new(path))?;
        println!("wrote {path}");
    }
    if args.get_bool("json") {
        println!("{}", report.to_json().to_string_pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!(
            "elastic sim: {n} servers, {ticks} ticks, fault plan [{}]",
            if fault.is_empty() { "none".to_string() } else { fault.to_spec() }
        ),
        &["tick", "alive", "tasks", "lost", "redisp", "spec", "tick time", "fault-free", "goodput", "events"],
    );
    for r in &report.per_tick {
        t.row(&[
            r.tick.to_string(),
            r.n_alive.to_string(),
            r.n_tasks.to_string(),
            r.lost_tasks.to_string(),
            r.redispatched.to_string(),
            r.speculated.to_string(),
            secs(r.tick_time),
            secs(r.fault_free_time),
            fmt_f(r.goodput, 3),
            r.events.join(" "),
        ]);
    }
    t.print();
    println!(
        "total {} | fault-free {} | recovery overhead {} | goodput ratio {:.3} | {} re-dispatched, {} lost",
        secs(report.total_time),
        secs(report.fault_free_time),
        secs(report.recovery_overhead()),
        report.goodput_ratio(),
        report.redispatched,
        report.lost_tasks,
    );
    Ok(())
}

/// Drive the threaded runtime for `ticks` synthetic ticks — flat
/// (`run_tick`) or ping-pong PP (`run_pp_tick`) — verifying every
/// output bit-for-bit against the monolithic oracle. Returns the tick
/// stats plus the schedulable-server count each tick saw. `autoscale`
/// wires wave-clock scaling into `run_pp_tick` (the flat path ignores
/// it — scaling is decided at ping boundaries only). `trace_out`
/// attaches a wall-clock recorder and writes the Chrome trace after
/// shutdown.
fn run_threaded_ticks(
    n: usize,
    ticks: usize,
    seed: u64,
    fault: &FaultPlan,
    pp: bool,
    autoscale: Option<AutoscaleCfg>,
    trace_out: Option<&std::path::Path>,
) -> anyhow::Result<(Vec<distca::elastic::TickStats>, Vec<usize>)> {
    const H: usize = 4;
    const HKV: usize = 2;
    const D: usize = 16;
    let oracle = ReferenceCaCompute::new(H, HKV, D);
    let cfg = ElasticCfg { autoscale, ..Default::default() };
    let mut co =
        ElasticCoordinator::spawn(n, cfg, |_| distca::kernel::compute_from_env(H, HKV, D));
    let recorder = trace_out.map(|_| Recorder::new_wall());
    if let Some(r) = &recorder {
        co.set_recorder(r.clone());
    }
    let mut rng = Rng::new(seed);
    let mut alive_per_tick = Vec::with_capacity(ticks);
    for tick in 0..ticks {
        let alive = co.pool.schedulable();
        anyhow::ensure!(!alive.is_empty(), "tick {tick}: pool is empty");
        alive_per_tick.push(alive.len());
        let mut tasks = Vec::new();
        for i in 0..2 * n {
            let len = if i % 3 == 0 { 256 } else { 128 };
            let server = alive[i % alive.len()];
            tasks.push(ElasticTask {
                doc: (tick * 1000 + i) as u32,
                q_start: 0,
                server,
                home: server,
                tensors: synthetic_task(&mut rng, len, len, H, HKV, D),
            });
        }
        let outputs = if pp {
            co.run_pp_tick(tick, &tasks, fault)?
        } else {
            co.run_tick(tick, &tasks, fault)?
        };
        for out in &outputs {
            let task = tasks
                .iter()
                .find(|t| t.doc == out.doc && t.q_start == out.q_start)
                .expect("unknown output");
            let expect = oracle.run_batch(std::slice::from_ref(&task.tensors));
            anyhow::ensure!(out.o == expect[0], "tick {tick} doc {}: output diverged", out.doc);
        }
    }
    let stats = co.shutdown()?;
    if let (Some(r), Some(path)) = (&recorder, trace_out) {
        write_trace(r, path)?;
        println!("wrote {}", path.display());
    }
    Ok((stats, alive_per_tick))
}

fn cmd_elastic_threaded(
    args: &Args,
    n: usize,
    ticks: usize,
    seed: u64,
    fault: &FaultPlan,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        !args.get_bool("autoscale"),
        "--autoscale on the threaded runtime requires --pp \
         (scaling decisions happen on the wave clock at ping boundaries)"
    );
    let trace_out = args.get("trace-out").map(std::path::Path::new);
    let (stats, alive) = run_threaded_ticks(n, ticks, seed, fault, false, None, trace_out)?;
    let rows: Vec<Vec<String>> = stats
        .iter()
        .zip(&alive)
        .map(|(st, &n_alive)| {
            vec![
                st.tick.to_string(),
                n_alive.to_string(),
                st.n_tasks.to_string(),
                st.redispatched.to_string(),
                st.cancels_sent.to_string(),
                st.duplicates_suppressed.to_string(),
                secs(st.elapsed),
            ]
        })
        .collect();
    if args.get_bool("json") {
        let per_tick: Vec<Json> = stats
            .iter()
            .map(|st| {
                Json::obj(vec![
                    ("tick", Json::Num(st.tick as f64)),
                    ("tasks", Json::Num(st.n_tasks as f64)),
                    ("redispatched", Json::Num(st.redispatched as f64)),
                    ("cancels_sent", Json::Num(st.cancels_sent as f64)),
                    ("duplicates_suppressed", Json::Num(st.duplicates_suppressed as f64)),
                    ("deadline_rounds", Json::Num(st.deadline_rounds as f64)),
                    ("elapsed_s", Json::Num(st.elapsed)),
                ])
            })
            .collect();
        let j = Json::obj(vec![
            ("servers", Json::Num(n as f64)),
            ("ticks", Json::Num(ticks as f64)),
            ("fault_plan", Json::Str(fault.to_spec())),
            ("bit_exact", Json::Bool(true)),
            ("per_tick", Json::Arr(per_tick)),
        ]);
        println!("{}", j.to_string_pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!(
            "elastic threaded: {n} reference servers, {ticks} ticks, fault plan [{}] — all outputs bit-exact",
            if fault.is_empty() { "none".to_string() } else { fault.to_spec() }
        ),
        &["tick", "alive", "tasks", "redisp", "cancels", "dups", "elapsed"],
    );
    for r in rows {
        t.row(&r);
    }
    t.print();
    let redisp: usize = stats.iter().map(|s| s.redispatched).sum();
    let dups: usize = stats.iter().map(|s| s.duplicates_suppressed).sum();
    println!("re-dispatched {redisp} | duplicates suppressed {dups} | outputs verified against the monolithic oracle");
    Ok(())
}

/// `distca worker` — one attention-server daemon process.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let cfg = distca::net::WorkerCfg {
        listen: args.req("listen")?.to_string(),
        port_file: args.get("port-file").map(std::path::PathBuf::from),
    };
    distca::net::run_worker(&cfg)
}

/// Shared `distca serve` / `distca soak` front-end: build the config,
/// run the networked session, print the report.
fn cmd_net(args: &Args, soak: bool) -> anyhow::Result<()> {
    let workers = args.get_usize("workers", 4)?;
    anyhow::ensure!(workers >= 2, "--workers must be at least 2");
    let spawn = args.get_bool("spawn");
    let connect: Vec<String> = args
        .get("connect")
        .map(|s| {
            s.split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect()
        })
        .unwrap_or_default();
    let ticks = args.get_usize("ticks", if soak { 8 } else { 4 })?;
    let seed = match args.get_parse::<u64>("seed")? {
        Some(s) => s,
        None => distca::util::rng::seed_from_env(42),
    };
    // Scripted faults are explicit-only on the net paths (no seeded
    // random default: a SIGKILL is a heavyweight event to surprise a
    // user with). kills/rejoins run at the process level.
    let fault = match (args.get("fault-plan"), args.get("fault")) {
        (Some(path), _) => {
            let j = distca::util::json::parse_file(std::path::Path::new(path))
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            FaultPlan::from_json(&j).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
        }
        (None, Some(spec)) => FaultPlan::parse_spec(spec).map_err(|e| anyhow::anyhow!(e))?,
        (None, None) => FaultPlan::new(),
    };
    ensure_fault_in_scope(&fault, workers, ticks)?;
    let hb_ms = args.get_u64("hb-ms", 200)?;
    let cfg = distca::net::ServeCfg {
        workers,
        spawn,
        connect,
        ticks,
        docs_per_tick: args.get_usize("docs-per-tick", 2 * workers)?,
        seed,
        data: DataDist::from_str(args.req("data")?)
            .ok_or_else(|| anyhow::anyhow!("unknown data distribution"))?,
        max_doc: args.get_usize("max-doc-len", 131_072)?,
        fault,
        pp: args.get_bool("pp"),
        stats_out: args.get("stats-out").map(std::path::PathBuf::from),
        trace_out: args.get("trace-out").map(std::path::PathBuf::from),
        bench_out: match args.get("bench-out") {
            Some(p) => Some(std::path::PathBuf::from(p)),
            None if soak => Some(std::path::PathBuf::from("BENCH_net.json")),
            None => None,
        },
        hb_interval: std::time::Duration::from_millis(hb_ms),
        hb_timeout: std::time::Duration::from_millis(if hb_ms == 0 {
            0
        } else {
            (hb_ms * 10).max(2000)
        }),
        metrics_listen: args.get("metrics-listen").map(String::from),
    };
    let report = distca::net::run_serve(&cfg)?;
    if args.get_bool("json") {
        println!("{}", report.to_json().to_string_pretty());
        return Ok(());
    }
    let mut t = Table::new(
        &format!(
            "net {}{}: {} workers ({}), {} ticks, fault plan [{}] — all outputs bit-exact over TCP",
            if soak { "soak" } else { "serve" },
            if cfg.pp { " --pp" } else { "" },
            report.workers,
            if cfg.spawn { "spawned" } else { "connected" },
            report.per_tick.len(),
            if cfg.fault.is_empty() { "none".to_string() } else { cfg.fault.to_spec() }
        ),
        &[
            "tick", "alive", "tasks", "redisp", "sendfail", "remap", "conn-kill", "sigkill",
            "rejoin", "bytes", "ovl-gather", "ovl-eff", "makespan",
        ],
    );
    for r in &report.per_tick {
        t.row(&[
            r.tick.to_string(),
            r.n_alive.to_string(),
            r.n_tasks.to_string(),
            r.redispatched.to_string(),
            r.send_failovers.to_string(),
            r.remapped.to_string(),
            r.connection_kills.to_string(),
            r.process_kills.to_string(),
            r.rejoins.to_string(),
            bytes(r.bytes_dispatched),
            r.overlap_gathered.to_string(),
            format!("{:.0}%", r.overlap_efficiency * 100.0),
            secs(r.elapsed),
        ]);
    }
    t.print();
    println!(
        "re-dispatched {} | send failovers {} | SIGKILLs {} | connection kills {} | rejoins {} | overlap-gathered {} | overlap efficiency {:.0}% | {:.0} tokens/s end-to-end ({} kernel) | outputs verified against the monolithic oracle",
        report.total_redispatched,
        report.total_send_failovers,
        report.total_process_kills,
        report.total_connection_kills,
        report.total_rejoins,
        report.total_overlap_gathered,
        report.overlap_efficiency * 100.0,
        report.tokens_per_s,
        distca::kernel::kernel_label(),
    );
    if let Some(p) = &cfg.bench_out {
        println!("wrote {}", p.display());
    }
    if let Some(p) = &cfg.stats_out {
        println!("wrote {}", p.display());
    }
    Ok(())
}

/// `distca gateway` — multi-tenant serving over the shared pool: seeded
/// tenant streams, weighted-fair queueing, believed-capacity admission,
/// fused cross-tenant waves, per-tenant bit-exactness, and a
/// double-entry accounting audit. `--soak` scales the defaults to a
/// 10k-tenant diurnal population and writes `BENCH_gateway.json`.
fn cmd_gateway(args: &Args) -> anyhow::Result<()> {
    let soak = args.get_bool("soak");
    let workers = args.get_usize("workers", 4)?;
    anyhow::ensure!(workers >= 2, "--workers must be at least 2");
    let spawn = args.get_bool("spawn");
    let connect: Vec<String> = args
        .get("connect")
        .map(|s| {
            s.split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect()
        })
        .unwrap_or_default();
    let waves = args.get_usize("ticks", if soak { 24 } else { 8 })?;
    let tenants = args.get_usize("tenants", if soak { 10_000 } else { 32 })?;
    let seed = match args.get_parse::<u64>("seed")? {
        Some(s) => s,
        None => distca::util::rng::seed_from_env(42),
    };
    // Explicit-only faults, as on the net paths. The plan indexes
    // *dispatched* waves; under any backlog every arrival wave
    // dispatches, so the arrival horizon is the scope to validate.
    let fault = match (args.get("fault-plan"), args.get("fault")) {
        (Some(path), _) => {
            let j = distca::util::json::parse_file(std::path::Path::new(path))
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            FaultPlan::from_json(&j).map_err(|e| anyhow::anyhow!("{path}: {e}"))?
        }
        (None, Some(spec)) => FaultPlan::parse_spec(spec).map_err(|e| anyhow::anyhow!(e))?,
        (None, None) => FaultPlan::new(),
    };
    ensure_fault_in_scope(&fault, workers, waves)?;
    let cfg = distca::gateway::GatewayCfg {
        tenants,
        workers,
        waves,
        arrival_rate: args.get_f64("arrival-rate", 12.0 * workers as f64)?,
        seed,
        fault,
        spawn,
        connect,
        diurnal_period: args.get_f64("diurnal", 24.0)?,
        metrics_listen: args.get("metrics-listen").map(String::from),
        accounting_out: args.get("accounting-out").map(std::path::PathBuf::from),
        bench_out: match args.get("bench-out") {
            Some(p) => Some(std::path::PathBuf::from(p)),
            None if soak => Some(std::path::PathBuf::from("BENCH_gateway.json")),
            None => None,
        },
        ..Default::default()
    };
    let report = distca::gateway::run_gateway(&cfg)?;
    if args.get_bool("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        let mut t = Table::new(
            &format!(
                "gateway: {} tenants -> {} workers ({}), {} arrival waves (+{} drain), fault plan [{}] — all outputs bit-exact per tenant",
                report.tenants,
                report.workers,
                if cfg.spawn {
                    "spawned"
                } else if cfg.connect.is_empty() {
                    "in-process"
                } else {
                    "connected"
                },
                report.arrival_waves,
                report.total_waves - report.arrival_waves,
                if cfg.fault.is_empty() { "none".to_string() } else { cfg.fault.to_spec() }
            ),
            &[
                "wave", "arrivals", "admit", "backlog", "tenants", "sat", "pairs", "bytes",
                "alive", "redisp", "elapsed",
            ],
        );
        for r in &report.per_wave {
            t.row(&[
                r.wave.to_string(),
                r.arrivals.to_string(),
                r.admitted.to_string(),
                r.backlog.to_string(),
                r.wave_tenants.to_string(),
                if r.saturated { "yes".into() } else { "-".into() },
                fmt_f(r.admitted_pairs, 0),
                bytes(r.admitted_bytes),
                r.n_alive.to_string(),
                r.redispatched.to_string(),
                secs(r.elapsed),
            ]);
        }
        t.print();
        let mut ct = Table::new(
            "per-SLO-class accounting (tenant rows sum exactly to pool totals)",
            &[
                "class", "tenants", "admitted", "completed", "bytes", "flops", "mean wait",
                "max wait", "bound", "target", "breaches", "burn",
            ],
        );
        for class in distca::gateway::SloClass::ALL {
            let rows: Vec<&distca::gateway::TenantAccount> = report
                .ledger
                .tenants()
                .values()
                .filter(|r| r.slo == Some(class))
                .collect();
            let admitted: usize = rows.iter().map(|r| r.admitted).sum();
            let wait_sum: usize = rows.iter().map(|r| r.wait_waves_sum).sum();
            let slo = report.ledger.slo().get(&class).cloned().unwrap_or_default();
            ct.row(&[
                class.name().to_string(),
                rows.len().to_string(),
                admitted.to_string(),
                rows.iter().map(|r| r.completed).sum::<usize>().to_string(),
                bytes(rows.iter().map(|r| r.bytes).sum::<f64>()),
                format!("{:.2e}", rows.iter().map(|r| r.flops).sum::<f64>()),
                fmt_f(if admitted > 0 { wait_sum as f64 / admitted as f64 } else { 0.0 }, 2),
                rows.iter().map(|r| r.max_wait_waves).max().unwrap_or(0).to_string(),
                class.wait_bound_waves().to_string(),
                secs(class.latency_target_s()),
                format!("{}/{}", slo.breaches, slo.tasks),
                fmt_f(slo.burn_rate(), 2),
            ]);
        }
        ct.print();
        let p = report.ledger.pool();
        println!(
            "arrived {} | admitted {} | completed {} | rejected oversize {} | re-dispatched {} | max backlog {} | saturated waves {} | forced admissions {}",
            p.arrived,
            p.admitted,
            p.completed,
            report.rejected_oversize,
            p.redispatched,
            report.max_backlog,
            report.saturated_waves,
            report.forced_admissions,
        );
    }
    for b in &report.starvation_breaches {
        eprintln!(
            "starvation: tenant {} ({}) waited {} waves, bound {}",
            b.tenant,
            b.slo.name(),
            b.max_wait_waves,
            b.bound_waves
        );
    }
    if let Some(p) = &cfg.bench_out {
        println!("wrote {}", p.display());
    }
    if let Some(p) = &cfg.accounting_out {
        println!("wrote {}", p.display());
    }
    anyhow::ensure!(
        !soak || report.starvation_breaches.is_empty(),
        "{} tenant(s) exceeded their SLO wait bound during the soak",
        report.starvation_breaches.len()
    );
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let steps = args.get_usize("steps", 100)?;
    anyhow::ensure!(
        distca::runtime::artifacts_available(),
        "artifacts missing — run `make artifacts`"
    );
    let driver = TrainDriver::load(&distca::runtime::artifacts_dir())?;
    println!("params: {} (~{:.0}M)", driver.n_params(), driver.n_params() as f64 / 1e6);
    let corpus = MarkovCorpus::new(2048, 0.9, 42);
    let seed = match args.get_parse::<u64>("seed")? {
        Some(s) => s,
        None => distca::util::rng::seed_from_env(42),
    };
    let report = driver.train(&corpus, steps, seed, |s, l| {
        if s % 10 == 0 {
            println!("step {s:>4}  loss {l:.4}");
        }
    })?;
    println!(
        "loss {:.4} -> {:.4} (floor {:.3}) | {:.2}s/step",
        report.first_loss(),
        report.last_loss(),
        report.entropy_floor,
        report.secs_per_step
    );
    Ok(())
}

/// `distca report` — render the Fig. 11-style straggler-attribution
/// overlap table from a `--trace-out` trace file (wall or virtual
/// clock: the breakdown is clock-agnostic), or the per-tenant
/// accounting table from a gateway `--accounting-out` JSONL stream.
fn cmd_report(args: &Args) -> anyhow::Result<()> {
    if let Some(path) = args.get("gateway") {
        anyhow::ensure!(
            args.get("trace").is_none(),
            "pass one of --trace and --gateway, not both"
        );
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        let rows: Vec<Json> = text
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| {
                distca::util::json::parse(l).map_err(|e| anyhow::anyhow!("{path}: {e}"))
            })
            .collect::<anyhow::Result<_>>()?;
        if args.get_bool("json") {
            println!("{}", Json::Arr(rows).to_string_pretty());
        } else {
            println!("{}", distca::obs::report::render_gateway_accounting(&rows, 20)?);
        }
        return Ok(());
    }
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("report needs --trace <file> (a --trace-out output)"))?;
    let trace = read_trace(std::path::Path::new(path))?;
    // Structural validation first: a report over malformed spans would
    // silently mis-attribute phases.
    distca::obs::trace::validate(&trace.spans)
        .map_err(|e| anyhow::anyhow!("{path}: invalid trace: {e}"))?;
    if args.get_bool("lineage") {
        println!("{}", distca::obs::report::render_lineage(&trace, 20)?);
        return Ok(());
    }
    let report = breakdown(&trace)?;
    if args.get_bool("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!("{}", report.render());
    }
    Ok(())
}

/// `distca top` — live terminal dashboard over a `--metrics-listen`
/// endpoint: poll `/metrics`, regroup the summary quantiles per family
/// + label set, and render a refreshing table. `--iterations 0` polls
/// until interrupted; a finite count (CI, scripting) renders that many
/// frames and exits.
fn cmd_top(args: &Args) -> anyhow::Result<()> {
    let addr = args.get("metrics-addr").ok_or_else(|| {
        anyhow::anyhow!("top needs --metrics-addr <host:port> (a --metrics-listen endpoint)")
    })?;
    let interval = args.get_u64("interval-ms", 1000)?;
    let iterations = args.get_usize("iterations", 0)?;
    let mut frame = 0usize;
    loop {
        let body = distca::obs::export::fetch_metrics(addr)?;
        let samples = distca::obs::export::parse_prometheus(&body);
        // Regroup: summary series (quantile label + _sum/_count) fold
        // into one row per (family, labels); everything else is a gauge.
        let strip = |ls: &[(String, String)]| -> String {
            ls.iter()
                .filter(|(k, _)| k != "quantile")
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(",")
        };
        let mut summaries: std::collections::BTreeMap<(String, String), [f64; 5]> =
            Default::default();
        let mut gauges: Vec<(String, String, f64)> = Vec::new();
        for (fam, labels, v) in &samples {
            if let Some((_, q)) = labels.iter().find(|(k, _)| k == "quantile") {
                let e = summaries.entry((fam.clone(), strip(labels))).or_insert([0.0; 5]);
                match q.as_str() {
                    "0.5" => e[0] = *v,
                    "0.95" => e[1] = *v,
                    "0.99" => e[2] = *v,
                    _ => {}
                }
            } else if let Some(base) = fam.strip_suffix("_count") {
                summaries.entry((base.to_string(), strip(labels))).or_insert([0.0; 5])[3] = *v;
            } else if let Some(base) = fam.strip_suffix("_sum") {
                summaries.entry((base.to_string(), strip(labels))).or_insert([0.0; 5])[4] = *v;
            } else {
                gauges.push((fam.clone(), strip(labels), *v));
            }
        }
        if frame > 0 {
            // ANSI clear + home between refreshes, not before the first
            // frame (keeps one-shot output pipeable).
            print!("\x1b[2J\x1b[H");
        }
        let mut t = Table::new(
            &format!("distca top — {addr} (frame {frame})"),
            &["family", "labels", "p50", "p95", "p99", "count", "sum"],
        );
        for ((fam, labels), q) in &summaries {
            t.row(&[
                fam.clone(),
                if labels.is_empty() { "-".into() } else { labels.clone() },
                format!("{:.6}", q[0]),
                format!("{:.6}", q[1]),
                format!("{:.6}", q[2]),
                format!("{}", q[3] as u64),
                format!("{:.3}", q[4]),
            ]);
        }
        t.print();
        if !gauges.is_empty() {
            let mut g = Table::new("gauges & counters", &["family", "labels", "value"]);
            for (fam, labels, v) in &gauges {
                g.row(&[
                    fam.clone(),
                    if labels.is_empty() { "-".into() } else { labels.clone() },
                    fmt_f(*v, 3),
                ]);
            }
            g.print();
        }
        frame += 1;
        if iterations > 0 && frame >= iterations {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(interval));
    }
}

/// `distca obsbench` — measure what the observability plane costs: the
/// same seeded stream of small reference-GQA tasks is run twice, once
/// bare and once with a wall-clock recorder absorbing the full
/// per-task event load (planned/dispatched/completed lineage, phase
/// span, hub histogram sample). Emits `BENCH_obs.json`; the drift gate
/// arms its schema, and CI asserts `overhead_pct` stays small — the
/// tracing plane must never become the straggler it exists to find.
fn cmd_obsbench(args: &Args) -> anyhow::Result<()> {
    const H: usize = 4;
    const HKV: usize = 2;
    const D: usize = 16;
    const LEN: usize = 48;
    let seed = match args.get_parse::<u64>("seed")? {
        Some(s) => s,
        None => distca::util::rng::seed_from_env(42),
    };
    let quick = std::env::var("DISTCA_BENCH_QUICK").is_ok();
    let tasks = args.get_usize("ticks", if quick { 200 } else { 2000 })?;
    let oracle = ReferenceCaCompute::new(H, HKV, D);
    // One shared task batch: identical compute on both sides.
    let mut rng = Rng::new(seed);
    let batch: Vec<_> =
        (0..tasks).map(|_| synthetic_task(&mut rng, LEN, LEN, H, HKV, D)).collect();

    // Bare pass: compute only.
    let t0 = std::time::Instant::now();
    for tensors in &batch {
        std::hint::black_box(oracle.run_batch(std::slice::from_ref(tensors)));
    }
    let off_s = t0.elapsed().as_secs_f64();

    // Instrumented pass: full recorder + lineage + live-hub load per
    // task, the same event mix serve/soak generates.
    let recorder = Recorder::new_wall();
    let hub = distca::obs::export::MetricsHub::new();
    recorder.set_hub(std::sync::Arc::clone(&hub));
    let t1 = std::time::Instant::now();
    for (i, tensors) in batch.iter().enumerate() {
        let tag = i as u64;
        recorder.lineage_planned(0, tag, i % 4, (LEN * LEN) as f64);
        recorder.lineage_dispatched(0, 0, tag, i % 4, tag + 1);
        let c0 = std::time::Instant::now();
        std::hint::black_box(oracle.run_batch(std::slice::from_ref(tensors)));
        let dt = c0.elapsed().as_secs_f64();
        recorder.phase_seconds(0, distca::obs::Phase::Compute, dt);
        recorder.task_completed(0, 0, i % 4, tag, dt);
    }
    let on_s = t1.elapsed().as_secs_f64();

    let overhead_pct = if off_s > 0.0 { (on_s - off_s) / off_s * 100.0 } else { 0.0 };
    let events = recorder.lineage_events().len();
    let hist_count = hub
        .hist("distca_task_latency_seconds")
        .map(|h| h.count())
        .unwrap_or(0);
    anyhow::ensure!(
        events == 3 * tasks,
        "lineage event count {events} != 3 x {tasks} tasks"
    );
    anyhow::ensure!(
        hist_count == tasks as u64,
        "hub histogram holds {hist_count} samples, expected {tasks}"
    );
    let j = Json::obj(vec![
        ("bench", Json::Str("obs_overhead".into())),
        ("seed", Json::Num(seed as f64)),
        ("tasks", Json::Num(tasks as f64)),
        ("lineage_events_per_task", Json::Num(3.0)),
        ("lineage_events", Json::Num(events as f64)),
        ("hist_samples", Json::Num(hist_count as f64)),
        ("obs_off_s", Json::Num(off_s)),
        ("obs_on_s", Json::Num(on_s)),
        ("overhead_pct", Json::Num(overhead_pct)),
    ]);
    let out = args.get("bench-out").unwrap_or("BENCH_obs.json");
    std::fs::write(out, j.to_string_pretty())
        .map_err(|e| anyhow::anyhow!("writing {out}: {e}"))?;
    println!(
        "obs overhead: {tasks} tasks | bare {} | instrumented {} | overhead {overhead_pct:.2}% \
         | {events} lineage events, {hist_count} live histogram samples",
        secs(off_s),
        secs(on_s),
    );
    println!("wrote {out}");
    Ok(())
}

/// `distca drift` — compare a freshly regenerated `BENCH_*.json`
/// against the committed baseline: exact schema (keys, array shapes,
/// value kinds) plus a relative tolerance on numeric leaves, with
/// wall-clock fields exempt from the numeric check. A baseline carrying
/// a top-level `"provisional"` key (committed before any toolchain run
/// could measure real numbers) is schema-checked only. Exits non-zero
/// on violations.
fn cmd_drift(args: &Args) -> anyhow::Result<()> {
    let b_path = args
        .get("baseline")
        .ok_or_else(|| anyhow::anyhow!("drift needs --baseline <file>"))?;
    let c_path = args
        .get("candidate")
        .ok_or_else(|| anyhow::anyhow!("drift needs --candidate <file>"))?;
    let mut baseline = distca::util::json::parse_file(std::path::Path::new(b_path))
        .map_err(|e| anyhow::anyhow!("reading {b_path}: {e}"))?;
    let mut candidate = distca::util::json::parse_file(std::path::Path::new(c_path))
        .map_err(|e| anyhow::anyhow!("reading {c_path}: {e}"))?;
    let mut tolerance = args.get_f64("drift-tolerance", 0.2)?;
    anyhow::ensure!(tolerance >= 0.0, "--drift-tolerance must be non-negative");
    // Provisional baselines pin the schema, not the numbers: strip the
    // marker from both sides and lift the numeric tolerance entirely.
    let provisional = strip_provisional(&mut baseline);
    strip_provisional(&mut candidate);
    if provisional {
        tolerance = f64::INFINITY;
        println!(
            "note: {b_path} is provisional (schema-only check; replace it with a \
             measured run to arm the numeric tolerance)"
        );
    }
    let cfg = DriftCfg { tolerance, skip_keys: wall_clock_keys() };
    let violations = compare(&baseline, &candidate, &cfg);
    if violations.is_empty() {
        println!(
            "{c_path}: no drift vs {b_path} ({})",
            if provisional {
                "schema only".to_string()
            } else {
                format!("±{:.0}% on numeric leaves", 100.0 * tolerance)
            }
        );
        return Ok(());
    }
    for v in &violations {
        eprintln!("drift: {v}");
    }
    anyhow::bail!("{} drift violation(s) vs {b_path}", violations.len());
}

/// Remove a top-level `"provisional"` marker; returns whether one was
/// present.
fn strip_provisional(v: &mut Json) -> bool {
    if let Json::Obj(fields) = v {
        let n = fields.len();
        fields.retain(|(k, _)| k != "provisional");
        return fields.len() != n;
    }
    false
}

fn cmd_bound(args: &Args) -> anyhow::Result<()> {
    let model = ModelConfig::by_name(args.req("model")?)
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let cluster = ClusterConfig::h200(1);
    let s = distca::coordinator::comm::max_partition_bound(&model, &cluster);
    let t = distca::coordinator::comm::token_linear_time(&model, &cluster);
    println!(
        "{}: t = {:.3} us/token, IB {} GB/s  =>  s <= {:.1}",
        model.name,
        t * 1e6,
        cluster.ib_bw / 1e9,
        s
    );
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let gpus = args.get_usize("gpus", 64)?;
    let mut t = Table::new("models (Table 2)", &["name", "layers", "hidden", "heads", "hdim", "kv", "ffn", "params"]);
    for m in [ModelConfig::llama3_8b(), ModelConfig::llama_34b(), ModelConfig::tiny_100m()] {
        t.row(&[
            m.name.clone(),
            m.n_layers.to_string(),
            m.hidden.to_string(),
            m.n_heads.to_string(),
            m.head_dim.to_string(),
            m.kv_heads.to_string(),
            m.intermediate.to_string(),
            format!("{:.1}B", m.param_count() as f64 / 1e9),
        ]);
    }
    t.print();
    let c = ClusterConfig::h200(gpus / 8);
    println!(
        "cluster: {} ({} GPUs, {:.0} TFLOP/s bf16/GPU, NVLink {:.0} GB/s, IB {:.0} GB/s, HBM {:.0} GB)",
        c.name, c.n_gpus(), c.peak_flops / 1e12, c.nvlink_bw / 1e9, c.ib_bw / 1e9, c.hbm_bytes / 1e9
    );
    Ok(())
}
