//! `distca` — the launcher.
//!
//! Subcommands:
//!   simulate   one training iteration under a strategy on the simulated
//!              H200 cluster (the paper's testbed substitute)
//!   compare    DistCA vs WLB-ideal on one configuration
//!   schedule   run the §4.2 scheduler on a sampled batch and dump the
//!              plan (optionally as JSON)
//!   train      end-to-end tiny-LM training through the AOT artifacts
//!   bound      Appendix A max-partition bound for a model/bandwidth
//!   info       print model/cluster configuration tables

use distca::cli::{usage, Args, FlagSpec};
use distca::config::run::{DataDist, Strategy};
use distca::config::{ClusterConfig, ModelConfig};
use distca::coordinator::scheduler::items_from_chunks;
use distca::coordinator::{schedule, Profiler, SchedulerCfg};
use distca::data::distributions::sampler_for;
use distca::model::FlopsModel;
use distca::runtime::train::{MarkovCorpus, TrainDriver};
use distca::sim::strategies::{
    distca_placement, run_distca, run_packed_dp, run_perdoc_cp, run_wlb_ideal, SimParams,
};
use distca::util::json::Json;
use distca::util::rng::Rng;
use distca::util::tables::{bytes, f as fmt_f, secs, Table};

const SUBCOMMANDS: &[(&str, &str)] = &[
    ("simulate", "simulate one iteration under --strategy"),
    ("compare", "DistCA vs WLB-ideal on one configuration"),
    ("schedule", "run the scheduler on a sampled batch; print the plan"),
    ("train", "train the tiny LM end-to-end via AOT artifacts"),
    ("bound", "Appendix A max-partition bound"),
    ("info", "print model & cluster configs"),
];

fn specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "model", help: "llama-8b | llama-34b | tiny-100m", default: Some("llama-8b"), is_bool: false },
        FlagSpec { name: "gpus", help: "number of GPUs (multiple of 8)", default: Some("64"), is_bool: false },
        FlagSpec { name: "max-doc-len", help: "max document length (tokens)", default: Some("131072"), is_bool: false },
        FlagSpec { name: "tokens", help: "tokens per batch (default: 2 chunks)", default: None, is_bool: false },
        FlagSpec { name: "strategy", help: "packed | cp | wlb | distca", default: Some("distca"), is_bool: false },
        FlagSpec { name: "data", help: "pretrain | prolong", default: Some("pretrain"), is_bool: false },
        FlagSpec { name: "tp", help: "tensor-parallel degree", default: Some("8"), is_bool: false },
        FlagSpec { name: "pp", help: "pipeline-parallel degree", default: Some("1"), is_bool: false },
        FlagSpec { name: "cp", help: "context-parallel degree (cp strategy)", default: Some("4"), is_bool: false },
        FlagSpec { name: "tolerance", help: "scheduler imbalance tolerance", default: Some("0.10"), is_bool: false },
        FlagSpec { name: "seed", help: "PRNG seed", default: Some("42"), is_bool: false },
        FlagSpec { name: "batches", help: "batches to average", default: Some("5"), is_bool: false },
        FlagSpec { name: "steps", help: "train steps (train)", default: Some("100"), is_bool: false },
        FlagSpec { name: "json", help: "emit JSON instead of tables", default: None, is_bool: true },
        FlagSpec { name: "verbose", help: "debug logging", default: None, is_bool: true },
    ]
}

fn main() {
    distca::util::logging::init_from_env();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&raw, &specs()) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n\n{}", usage("distca", SUBCOMMANDS, &specs()));
            std::process::exit(2);
        }
    };
    if args.get_bool("verbose") {
        distca::util::logging::set_level(distca::util::logging::Level::Debug);
    }
    let result = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("compare") => cmd_compare(&args),
        Some("schedule") => cmd_schedule(&args),
        Some("train") => cmd_train(&args),
        Some("bound") => cmd_bound(&args),
        Some("info") => cmd_info(&args),
        _ => {
            println!("{}", usage("distca", SUBCOMMANDS, &specs()));
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Setup {
    model: ModelConfig,
    params: SimParams,
    max_doc: usize,
    tokens: usize,
    data: DataDist,
    seed: u64,
    batches: usize,
}

fn setup(args: &Args) -> anyhow::Result<Setup> {
    let model = ModelConfig::by_name(args.req("model")?)
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let gpus = args.get_usize("gpus", 64)?;
    anyhow::ensure!(gpus % 8 == 0, "--gpus must be a multiple of 8");
    let tp = args.get_usize("tp", 8)?;
    let pp = args.get_usize("pp", 1)?;
    let max_doc = args.get_usize("max-doc-len", 131_072)?;
    let tokens = args.get_usize("tokens", 2 * max_doc * (gpus / 64).max(1))?;
    let mut params = SimParams::new(model.clone(), ClusterConfig::h200(gpus / 8), tp, pp);
    params.tolerance = args.get_f64("tolerance", 0.10)?;
    Ok(Setup {
        model,
        params,
        max_doc,
        tokens,
        data: DataDist::from_str(args.req("data")?)
            .ok_or_else(|| anyhow::anyhow!("unknown data distribution"))?,
        seed: args.get_u64("seed", 42)?,
        batches: args.get_usize("batches", 5)?,
    })
}

fn report_row(t: &mut Table, r: &distca::sim::IterationReport) {
    t.row(&[
        r.strategy.clone(),
        r.config.clone(),
        secs(r.iter_time),
        format!("{:.3e}", r.throughput()),
        fmt_f(r.idle_fraction() * 100.0, 1),
        fmt_f(r.memory_divergence(), 2),
        bytes(r.comm_bytes),
        if r.oom { "OOM".into() } else { "-".into() },
    ]);
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let s = setup(args)?;
    let strategy = Strategy::from_str(args.req("strategy")?)
        .ok_or_else(|| anyhow::anyhow!("unknown strategy"))?;
    let cp = args.get_usize("cp", 4)?;
    let mut reports = Vec::new();
    for b in 0..s.batches {
        let mut rng = Rng::new(s.seed + b as u64 * 7919);
        let docs = sampler_for(s.data, s.max_doc).sample_tokens(&mut rng, s.tokens, 0);
        reports.push(match strategy {
            Strategy::Packed => run_packed_dp(&docs, s.max_doc, &s.params),
            Strategy::PerDocCp => run_perdoc_cp(&docs, s.max_doc, cp, &s.params),
            Strategy::WlbIdeal => run_wlb_ideal(&docs, s.max_doc, &s.params),
            Strategy::DistCa => run_distca(&docs, s.max_doc, &s.params),
        });
    }
    let avg = distca::sim::IterationReport::average(&reports);
    if args.get_bool("json") {
        println!("{}", avg.to_json().to_string_pretty());
    } else {
        let mut t = Table::new(
            &format!("{} on {} GPUs, {} (avg of {})", strategy.name(),
                     s.params.cluster.n_gpus(), s.data.name(), s.batches),
            &["strategy", "config", "iter", "tok/s", "idle%", "mem div", "comm", "oom"],
        );
        report_row(&mut t, &avg);
        t.print();
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let s = setup(args)?;
    let mut wlb = Vec::new();
    let mut ca = Vec::new();
    for b in 0..s.batches {
        let mut rng = Rng::new(s.seed + b as u64 * 7919);
        let docs = sampler_for(s.data, s.max_doc).sample_tokens(&mut rng, s.tokens, 0);
        wlb.push(run_wlb_ideal(&docs, s.max_doc, &s.params));
        ca.push(run_distca(&docs, s.max_doc, &s.params));
    }
    let wlb = distca::sim::IterationReport::average(&wlb);
    let ca = distca::sim::IterationReport::average(&ca);
    if args.get_bool("json") {
        let j = Json::obj(vec![
            ("baseline", wlb.to_json()),
            ("distca", ca.to_json()),
            ("speedup", Json::Num(wlb.iter_time / ca.iter_time)),
        ]);
        println!("{}", j.to_string_pretty());
    } else {
        let mut t = Table::new(
            &format!("{} | {} GPUs | maxdoc {}K | {}", s.model.name,
                     s.params.cluster.n_gpus(), s.max_doc / 1024, s.data.name()),
            &["strategy", "config", "iter", "tok/s", "idle%", "mem div", "comm", "oom"],
        );
        report_row(&mut t, &wlb);
        report_row(&mut t, &ca);
        t.print();
        println!("speedup: {:.2}x", wlb.iter_time / ca.iter_time);
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> anyhow::Result<()> {
    let s = setup(args)?;
    let n = s.params.n_logical();
    let mut rng = Rng::new(s.seed);
    let docs = sampler_for(s.data, s.max_doc).sample_tokens(&mut rng, s.tokens, 0);
    let chunks = distca_placement(&docs, n);
    let items = items_from_chunks(&chunks);
    let f = FlopsModel::new(&s.model);
    let prof = Profiler::analytic(&f, &s.params.cluster);
    let t0 = std::time::Instant::now();
    let plan = schedule(
        &items, n, &f, &prof, &s.model,
        &SchedulerCfg { tolerance: s.params.tolerance, ..Default::default() },
    );
    let dt = t0.elapsed();
    if args.get_bool("json") {
        let servers: Vec<Json> = (0..n)
            .map(|srv| {
                Json::obj(vec![
                    ("server", Json::Num(srv as f64)),
                    ("load_s", Json::Num(plan.server_load[srv])),
                    (
                        "tasks",
                        Json::Num(
                            plan.assignments.iter().filter(|a| a.server == srv).count() as f64,
                        ),
                    ),
                ])
            })
            .collect();
        let j = Json::obj(vec![
            ("n_servers", Json::Num(n as f64)),
            ("imbalance", Json::Num(plan.imbalance())),
            ("total_comm_bytes", Json::Num(plan.total_comm_bytes())),
            ("local_fraction", Json::Num(plan.local_fraction())),
            ("schedule_time_s", Json::Num(dt.as_secs_f64())),
            ("servers", Json::Arr(servers)),
        ]);
        println!("{}", j.to_string_pretty());
    } else {
        let mut t = Table::new(
            &format!("plan: {} items -> {} servers in {}", items.len(), n, secs(dt.as_secs_f64())),
            &["server", "CA load", "vs target", "tasks"],
        );
        for srv in 0..n {
            t.row(&[
                srv.to_string(),
                secs(plan.server_load[srv]),
                format!("{:+.1}%", (plan.server_load[srv] / plan.target_load - 1.0) * 100.0),
                plan.assignments.iter().filter(|a| a.server == srv).count().to_string(),
            ]);
        }
        t.print();
        println!(
            "imbalance {:.3} | dispatch {} | {:.0}% local",
            plan.imbalance(),
            bytes(plan.total_comm_bytes()),
            plan.local_fraction() * 100.0
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let steps = args.get_usize("steps", 100)?;
    anyhow::ensure!(
        distca::runtime::artifacts_available(),
        "artifacts missing — run `make artifacts`"
    );
    let driver = TrainDriver::load(&distca::runtime::artifacts_dir())?;
    println!("params: {} (~{:.0}M)", driver.n_params(), driver.n_params() as f64 / 1e6);
    let corpus = MarkovCorpus::new(2048, 0.9, 42);
    let report = driver.train(&corpus, steps, args.get_u64("seed", 42)?, |s, l| {
        if s % 10 == 0 {
            println!("step {s:>4}  loss {l:.4}");
        }
    })?;
    println!(
        "loss {:.4} -> {:.4} (floor {:.3}) | {:.2}s/step",
        report.first_loss(),
        report.last_loss(),
        report.entropy_floor,
        report.secs_per_step
    );
    Ok(())
}

fn cmd_bound(args: &Args) -> anyhow::Result<()> {
    let model = ModelConfig::by_name(args.req("model")?)
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let cluster = ClusterConfig::h200(1);
    let s = distca::coordinator::comm::max_partition_bound(&model, &cluster);
    let t = distca::coordinator::comm::token_linear_time(&model, &cluster);
    println!(
        "{}: t = {:.3} us/token, IB {} GB/s  =>  s <= {:.1}",
        model.name,
        t * 1e6,
        cluster.ib_bw / 1e9,
        s
    );
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let gpus = args.get_usize("gpus", 64)?;
    let mut t = Table::new("models (Table 2)", &["name", "layers", "hidden", "heads", "hdim", "kv", "ffn", "params"]);
    for m in [ModelConfig::llama3_8b(), ModelConfig::llama_34b(), ModelConfig::tiny_100m()] {
        t.row(&[
            m.name.clone(),
            m.n_layers.to_string(),
            m.hidden.to_string(),
            m.n_heads.to_string(),
            m.head_dim.to_string(),
            m.kv_heads.to_string(),
            m.intermediate.to_string(),
            format!("{:.1}B", m.param_count() as f64 / 1e9),
        ]);
    }
    t.print();
    let c = ClusterConfig::h200(gpus / 8);
    println!(
        "cluster: {} ({} GPUs, {:.0} TFLOP/s bf16/GPU, NVLink {:.0} GB/s, IB {:.0} GB/s, HBM {:.0} GB)",
        c.name, c.n_gpus(), c.peak_flops / 1e12, c.nvlink_bw / 1e9, c.ib_bw / 1e9, c.hbm_bytes / 1e9
    );
    Ok(())
}
