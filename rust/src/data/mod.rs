//! Synthetic workload generation: document-length distributions matching
//! the paper's two input distributions (§6.1), and document packing
//! schemes (fixed-size chunks and WLB-style variable-length chunks).

pub mod distributions;
pub mod packing;

pub use distributions::{DocLenSampler, ProLongSampler, PretrainSampler};
pub use packing::{pack_fixed, pack_variable_length, Chunk};

/// A document: just its id and token length (content never affects the
/// paper's experiments; `examples/train_e2e` generates real token ids
/// separately).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Document {
    pub id: u32,
    pub len: usize,
}

impl Document {
    pub fn new(id: u32, len: usize) -> Self {
        Self { id, len }
    }
}
