//! Document packing (§1, §3.1, §3.2).
//!
//! * [`pack_fixed`] — standard fixed-size packing: first-fit-decreasing
//!   into chunks of exactly `chunk_tokens` tokens (documents are split
//!   across chunk boundaries when necessary, as Megatron does). Memory is
//!   balanced (`Σl` equal), attention compute is not (`Σl²` varies).
//! * [`pack_variable_length`] — WLB-LLM-style variable-length chunking:
//!   redistribute documents across a fixed number of chunks to equalize
//!   `Σl²` (attention FLOPs), letting token counts `Σl` diverge — bounded
//!   by a per-chunk memory cap.

use crate::model::FlopsModel;

use super::Document;

/// A packed chunk: the (id, length)-pieces it holds. A piece may be a
/// *slice* of a document that crossed a chunk boundary; `offset` is its
/// start position within the original document (needed for causal CA
/// accounting).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Chunk {
    pub pieces: Vec<Piece>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Piece {
    pub doc: u32,
    /// Start offset of this piece within its document.
    pub offset: usize,
    pub len: usize,
}

impl Chunk {
    pub fn tokens(&self) -> usize {
        self.pieces.iter().map(|p| p.len).sum()
    }

    /// Forward CA FLOPs of this chunk under a causal mask (each piece
    /// attends to its in-document prefix).
    pub fn ca_flops(&self, f: &FlopsModel) -> f64 {
        self.pieces
            .iter()
            .map(|p| f.ca_task_fwd(p.len, p.offset))
            .sum()
    }

    /// `Σ l²`-style attention load using exact causal accounting.
    pub fn attention_load(&self, f: &FlopsModel) -> f64 {
        self.ca_flops(f)
    }
}

/// Fixed-size packing: greedy first-fit in arrival order, splitting
/// documents at chunk boundaries. Every chunk except possibly the last
/// has exactly `chunk_tokens` tokens.
pub fn pack_fixed(docs: &[Document], chunk_tokens: usize) -> Vec<Chunk> {
    assert!(chunk_tokens > 0);
    let mut chunks = Vec::new();
    let mut current = Chunk::default();
    let mut room = chunk_tokens;
    for d in docs {
        let mut offset = 0usize;
        let mut remaining = d.len;
        while remaining > 0 {
            let take = remaining.min(room);
            current.pieces.push(Piece {
                doc: d.id,
                offset,
                len: take,
            });
            offset += take;
            remaining -= take;
            room -= take;
            if room == 0 {
                chunks.push(std::mem::take(&mut current));
                room = chunk_tokens;
            }
        }
    }
    if !current.pieces.is_empty() {
        chunks.push(current);
    }
    chunks
}

/// WLB-LLM-style variable-length chunking: place whole documents onto
/// `n_chunks` chunks, greedily assigning each document (longest first) to
/// the chunk with the smallest attention load, subject to a token cap per
/// chunk. Documents longer than `token_cap` are split at the cap (they
/// cannot fit anywhere whole).
///
/// Returns the chunks; token counts across chunks generally diverge —
/// that is the method's memory-imbalance cost (Fig. 4a).
pub fn pack_variable_length(
    docs: &[Document],
    n_chunks: usize,
    token_cap: usize,
    f: &FlopsModel,
) -> Vec<Chunk> {
    assert!(n_chunks > 0 && token_cap > 0);
    let mut chunks = vec![Chunk::default(); n_chunks];
    let mut loads = vec![0.0f64; n_chunks];
    let mut tokens = vec![0usize; n_chunks];

    // Longest-processing-time-first greedy on attention load.
    let mut order: Vec<&Document> = docs.iter().collect();
    order.sort_by(|a, b| b.len.cmp(&a.len).then(a.id.cmp(&b.id)));

    for d in order {
        let mut offset = 0usize;
        let mut remaining = d.len;
        while remaining > 0 {
            // Pick the least-loaded chunk that still has token room.
            let mut best: Option<usize> = None;
            for c in 0..n_chunks {
                if tokens[c] >= token_cap {
                    continue;
                }
                if best.map_or(true, |b| loads[c] < loads[b]) {
                    best = Some(c);
                }
            }
            let c = match best {
                Some(c) => c,
                None => {
                    // All chunks at cap: spill round-robin onto the least
                    // token-loaded chunk (models the "memory cap reached"
                    // regime of §3.2 where balance becomes infeasible).
                    (0..n_chunks).min_by_key(|&c| tokens[c]).unwrap()
                }
            };
            let room = token_cap.saturating_sub(tokens[c]).max(1);
            let take = remaining.min(room);
            let piece = Piece {
                doc: d.id,
                offset,
                len: take,
            };
            loads[c] += f.ca_task_fwd(piece.len, piece.offset);
            tokens[c] += take;
            chunks[c].pieces.push(piece);
            offset += take;
            remaining -= take;
        }
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::quickcheck::{check, ensure};
    use crate::util::rng::Rng;
    use crate::util::stats;

    fn fm() -> FlopsModel {
        FlopsModel::new(&ModelConfig::llama3_8b())
    }

    fn docs_of(lens: &[usize]) -> Vec<Document> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| Document::new(i as u32, l))
            .collect()
    }

    #[test]
    fn fixed_pack_exact_chunks() {
        let chunks = pack_fixed(&docs_of(&[1000, 1000, 1000, 1000]), 2000);
        assert_eq!(chunks.len(), 2);
        assert!(chunks.iter().all(|c| c.tokens() == 2000));
    }

    #[test]
    fn fixed_pack_splits_long_docs() {
        let chunks = pack_fixed(&docs_of(&[5000]), 2000);
        assert_eq!(chunks.len(), 3);
        assert_eq!(chunks[0].tokens(), 2000);
        assert_eq!(chunks[2].tokens(), 1000);
        // offsets continue across chunks
        assert_eq!(chunks[1].pieces[0].offset, 2000);
        assert_eq!(chunks[2].pieces[0].offset, 4000);
    }

    #[test]
    fn fixed_pack_conserves_tokens() {
        check(
            60,
            |r: &mut Rng| {
                let n = r.gen_index(1, 20);
                (0..n).map(|_| r.gen_range(64, 8192)).collect::<Vec<u64>>()
            },
            |lens| {
                let docs: Vec<Document> = lens
                    .iter()
                    .enumerate()
                    .map(|(i, &l)| Document::new(i as u32, l as usize))
                    .collect();
                let total: usize = docs.iter().map(|d| d.len).sum();
                let chunks = pack_fixed(&docs, 4096);
                let packed: usize = chunks.iter().map(|c| c.tokens()).sum();
                ensure(packed == total, format!("{packed} != {total}"))
            },
        );
    }

    #[test]
    fn fixed_pack_balanced_memory_imbalanced_compute() {
        // The Fig. 1 situation: equal tokens per chunk but very unequal CA.
        let f = fm();
        let docs = docs_of(&[4096, 1024, 1024, 1024, 1024]);
        let chunks = pack_fixed(&docs, 4096);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].tokens(), chunks[1].tokens());
        let a = chunks[0].ca_flops(&f);
        let b = chunks[1].ca_flops(&f);
        assert!(a / b > 3.5, "CA imbalance should be ~4x, got {}", a / b);
    }

    #[test]
    fn variable_length_balances_compute() {
        let f = fm();
        // Long docs arriving adjacent: fixed packing co-locates them in
        // one chunk (heavy) while other chunks hold only shorts (light).
        // Redistribution fixes the compute imbalance.
        let mut lens = vec![16384usize, 16384];
        lens.extend(std::iter::repeat(2048).take(32));
        let docs = docs_of(&lens);
        let fixed = pack_fixed(&docs, 32768);
        let varlen = pack_variable_length(&docs, fixed.len(), usize::MAX, &f);
        let fixed_loads: Vec<f64> = fixed.iter().map(|c| c.ca_flops(&f)).collect();
        let var_loads: Vec<f64> = varlen.iter().map(|c| c.ca_flops(&f)).collect();
        assert!(
            stats::imbalance_ratio(&var_loads) < stats::imbalance_ratio(&fixed_loads),
            "varlen {:?} should beat fixed {:?}",
            stats::imbalance_ratio(&var_loads),
            stats::imbalance_ratio(&fixed_loads)
        );
    }

    #[test]
    fn variable_length_diverges_memory() {
        // Balancing Σl² makes Σl diverge (Fig. 4a): chunks holding a long
        // document get few tokens, chunks holding only shorts get many.
        let f = fm();
        let mut lens = vec![16384usize, 16384];
        lens.extend(std::iter::repeat(2048).take(32));
        let docs = docs_of(&lens);
        let varlen = pack_variable_length(&docs, 4, usize::MAX, &f);
        let tokens: Vec<f64> = varlen.iter().map(|c| c.tokens() as f64).collect();
        assert!(stats::divergence(&tokens) > 1.05, "tokens {tokens:?}");
    }

    #[test]
    fn variable_length_respects_cap_when_feasible() {
        let f = fm();
        let docs = docs_of(&[1000, 1000, 1000, 1000, 1000, 1000, 1000, 1000]);
        let chunks = pack_variable_length(&docs, 4, 2000, &f);
        for c in &chunks {
            assert!(c.tokens() <= 2000, "chunk over cap: {}", c.tokens());
        }
    }

    #[test]
    fn variable_length_conserves_tokens() {
        check(
            60,
            |r: &mut Rng| {
                let n = r.gen_index(1, 24);
                (0..n).map(|_| r.gen_range(64, 16384)).collect::<Vec<u64>>()
            },
            |lens| {
                let f = fm();
                let docs: Vec<Document> = lens
                    .iter()
                    .enumerate()
                    .map(|(i, &l)| Document::new(i as u32, l as usize))
                    .collect();
                let total: usize = docs.iter().map(|d| d.len).sum();
                let chunks = pack_variable_length(&docs, 4, 32768, &f);
                let packed: usize = chunks.iter().map(|c| c.tokens()).sum();
                ensure(packed == total, format!("{packed} != {total}"))
            },
        );
    }
}
