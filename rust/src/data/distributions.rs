//! Document-length distributions (§6.1 "Input data").
//!
//! * **Pretrain**: a heavy-tailed pretraining length distribution with
//!   long documents *upsampled* following Fu et al. (2024): sample from a
//!   truncated power law, then randomly filter out documents below a
//!   threshold with some probability, which shifts mass to the tail.
//! * **ProLong**: the mixture Gao et al. (2025) found best for long-
//!   context training — a substantial share of very long documents mixed
//!   with ordinary short ones. Compared to Pretrain it has a *higher
//!   fraction of long documents* (the paper leans on this: Pretrain's many
//!   short docs are the harder case for WLB).
//!
//! All lengths are clamped to `[min_len, max_doc_len]` and rounded to a
//! multiple of 16 tokens (tokenizer-chunk granularity; keeps packing
//! arithmetic tidy without affecting any result's shape).

use crate::util::rng::Rng;

use super::Document;

/// Common interface for the corpus samplers.
pub trait DocLenSampler {
    /// Sample one document length in tokens.
    fn sample_len(&self, rng: &mut Rng) -> usize;

    /// Upper bound on lengths this sampler emits.
    fn max_len(&self) -> usize;

    /// Sample documents until `budget_tokens` is reached (last doc
    /// truncated to fit, mirroring how corpora are chunked to a token
    /// budget). Ids are sequential starting at `id0`.
    fn sample_tokens(&self, rng: &mut Rng, budget_tokens: usize, id0: u32) -> Vec<Document> {
        let mut docs = Vec::new();
        let mut total = 0usize;
        let mut id = id0;
        while total < budget_tokens {
            let mut len = self.sample_len(rng);
            if total + len > budget_tokens {
                len = budget_tokens - total;
                if len < MIN_DOC_LEN {
                    // Merge the residue into the previous doc rather than
                    // emitting an untrainable fragment.
                    if let Some(last) = docs.last_mut() {
                        let last: &mut Document = last;
                        last.len += len;
                    }
                    break;
                }
            }
            docs.push(Document::new(id, len));
            id += 1;
            total += len;
        }
        docs
    }
}

/// Minimum document length emitted (tokens).
pub const MIN_DOC_LEN: usize = 64;

fn quantize(len: f64, max_len: usize) -> usize {
    let l = (len as usize).clamp(MIN_DOC_LEN, max_len);
    (l / 16).max(1) * 16
}

/// Pretrain distribution with long-document upsampling.
#[derive(Debug, Clone)]
pub struct PretrainSampler {
    pub max_doc_len: usize,
    /// Power-law shape for the body (larger ⇒ shorter docs dominate).
    pub alpha: f64,
    /// Scale of the power law (typical short-doc length).
    pub x_min: f64,
    /// Probability of *dropping* a document shorter than
    /// `upsample_threshold` and resampling — the Fu et al. filter.
    pub drop_short_prob: f64,
    pub upsample_threshold: usize,
}

impl PretrainSampler {
    pub fn new(max_doc_len: usize) -> Self {
        Self {
            max_doc_len,
            alpha: 1.1,
            x_min: 512.0,
            drop_short_prob: 0.55,
            upsample_threshold: 32_768.min(max_doc_len / 4).max(2048),
        }
    }
}

impl DocLenSampler for PretrainSampler {
    fn sample_len(&self, rng: &mut Rng) -> usize {
        // Rejection loop implements the "randomly filter out documents
        // shorter than a threshold" upsampling.
        for _ in 0..64 {
            let raw = rng.gen_pareto(self.x_min, self.alpha);
            let len = quantize(raw, self.max_doc_len);
            if len < self.upsample_threshold && rng.gen_bool(self.drop_short_prob) {
                continue;
            }
            return len;
        }
        quantize(self.x_min, self.max_doc_len)
    }

    fn max_len(&self) -> usize {
        self.max_doc_len
    }
}

/// ProLong-style mixture: explicit long-document component.
#[derive(Debug, Clone)]
pub struct ProLongSampler {
    pub max_doc_len: usize,
    /// Probability a document comes from the long component.
    pub long_frac: f64,
    /// Short component: lognormal body.
    pub short_mu: f64,
    pub short_sigma: f64,
}

impl ProLongSampler {
    pub fn new(max_doc_len: usize) -> Self {
        Self {
            max_doc_len,
            long_frac: 0.35,
            short_mu: 8.2,   // exp(8.2) ≈ 3.6K tokens typical short doc
            short_sigma: 1.0,
        }
    }
}

impl DocLenSampler for ProLongSampler {
    fn sample_len(&self, rng: &mut Rng) -> usize {
        if rng.gen_bool(self.long_frac) {
            // Long component: uniform in log-space over the top two octaves
            // up to max_doc_len — many docs at or near the context limit.
            let hi = self.max_doc_len as f64;
            let lo = hi / 8.0;
            let len = lo * (hi / lo).powf(rng.next_f64());
            quantize(len, self.max_doc_len)
        } else {
            quantize(
                rng.gen_lognormal(self.short_mu, self.short_sigma),
                self.max_doc_len,
            )
        }
    }

    fn max_len(&self) -> usize {
        self.max_doc_len
    }
}

/// Build the sampler named by a [`crate::config::run::DataDist`].
pub fn sampler_for(
    dist: crate::config::run::DataDist,
    max_doc_len: usize,
) -> Box<dyn DocLenSampler> {
    match dist {
        crate::config::run::DataDist::Pretrain => Box::new(PretrainSampler::new(max_doc_len)),
        crate::config::run::DataDist::ProLong => Box::new(ProLongSampler::new(max_doc_len)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frac_long(lens: &[usize], thresh: usize) -> f64 {
        lens.iter().filter(|&&l| l >= thresh).count() as f64 / lens.len() as f64
    }

    #[test]
    fn lengths_within_bounds_and_quantized() {
        let mut rng = Rng::new(1);
        let s = PretrainSampler::new(131_072);
        for _ in 0..2000 {
            let l = s.sample_len(&mut rng);
            assert!(l >= MIN_DOC_LEN && l <= 131_072);
            assert_eq!(l % 16, 0);
        }
    }

    #[test]
    fn prolong_has_more_long_docs_than_pretrain() {
        // §6.2: "Pretrain contains a higher proportion of short documents".
        let mut rng = Rng::new(2);
        let max = 131_072;
        let p: Vec<usize> = {
            let s = PretrainSampler::new(max);
            (0..4000).map(|_| s.sample_len(&mut rng)).collect()
        };
        let q: Vec<usize> = {
            let s = ProLongSampler::new(max);
            (0..4000).map(|_| s.sample_len(&mut rng)).collect()
        };
        let thresh = max / 8;
        assert!(
            frac_long(&q, thresh) > frac_long(&p, thresh) + 0.05,
            "prolong {:.3} vs pretrain {:.3}",
            frac_long(&q, thresh),
            frac_long(&p, thresh)
        );
    }

    #[test]
    fn upsampling_shifts_mass_to_tail() {
        let mut rng = Rng::new(3);
        let max = 131_072;
        let mut with = PretrainSampler::new(max);
        with.drop_short_prob = 0.8;
        let mut without = PretrainSampler::new(max);
        without.drop_short_prob = 0.0;
        let a: Vec<usize> = (0..4000).map(|_| with.sample_len(&mut rng)).collect();
        let b: Vec<usize> = (0..4000).map(|_| without.sample_len(&mut rng)).collect();
        let mean_a = a.iter().sum::<usize>() as f64 / a.len() as f64;
        let mean_b = b.iter().sum::<usize>() as f64 / b.len() as f64;
        assert!(mean_a > mean_b, "upsampled mean {mean_a} <= raw mean {mean_b}");
    }

    #[test]
    fn sample_tokens_hits_budget() {
        let mut rng = Rng::new(4);
        let s = ProLongSampler::new(65_536);
        let docs = s.sample_tokens(&mut rng, 1_000_000, 0);
        let total: usize = docs.iter().map(|d| d.len).sum();
        assert_eq!(total, 1_000_000);
        // ids sequential
        for (i, d) in docs.iter().enumerate() {
            assert_eq!(d.id as usize, i);
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let s = PretrainSampler::new(131_072);
        let a: Vec<usize> = {
            let mut r = Rng::new(7);
            (0..100).map(|_| s.sample_len(&mut r)).collect()
        };
        let b: Vec<usize> = {
            let mut r = Rng::new(7);
            (0..100).map(|_| s.sample_len(&mut r)).collect()
        };
        assert_eq!(a, b);
    }
}
