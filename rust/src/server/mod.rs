//! Attention servers (§4.1): the worker pool that *executes* CA-tasks.
//!
//! On the paper's testbed an attention server is a GPU role; here each
//! server is a worker thread owning a compiled fused-CA executable
//! (in-place time-sharing becomes thread scheduling on the host CPU —
//! same control structure, different silicon). The coordinator:
//!
//!  1. runs the §4.2 scheduler to get a [`Plan`],
//!  2. dispatches each assignment's Q/KV tensors over the [`Transport`]
//!     (the NVSHMEM all-to-all stand-in),
//!  3. servers batch everything they received for a tick into ONE fused
//!     kernel call (composability) and send outputs home,
//!  4. the coordinator reassembles per-document outputs.
//!
//! `examples/attention_server_demo` drives this end-to-end and checks the
//! disaggregated result bit-for-bit against a monolithic kernel call.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::exchange::transport::{ChannelTransport, Message, Transport};
use crate::runtime::ca_exec::{CaExecutor, CaTaskTensors};
use crate::runtime::Runtime;

// NOTE: the `xla` crate's PJRT handles are intentionally !Send (Rc + raw
// pointers), so every server thread owns a *private* PJRT client — which
// is the honest analogue of the paper's setup anyway: each attention
// server is an independent device with its own compiled executable.

/// A CA request as shipped to a server: tensors plus routing tag.
struct WireTask {
    tensors: CaTaskTensors,
    /// (doc, q_start) packed into the message tag for reassembly.
    tag: u64,
    home: usize,
}

pub(crate) fn pack_tag(doc: u32, q_start: u32) -> u64 {
    ((doc as u64) << 32) | q_start as u64
}

pub(crate) fn unpack_tag(tag: u64) -> (u32, u32) {
    ((tag >> 32) as u32, tag as u32)
}

// ---------------------------------------------------------------------
// Tenant-tagged document ids (the multi-tenant gateway).
//
// The elastic tag space gives the doc id 30 usable bits (bits 62/63 of
// the packed tag are the CANCEL/CTRL flags). The gateway claims bit 29
// of that space as a marker and packs `(tenant, seq)` below it:
//
//   doc = [bit 29 = 1][15 tenant bits][14 per-tenant sequence bits]
//
// Because the tenant lives *inside* the doc id — and therefore inside
// the message tag that every dispatch, response, cancel, dedup, and
// re-dispatch keys on — per-tenant attribution survives the wire
// round-trip with no extra state anywhere: first-response-wins dedup
// and speculative re-dispatch are per-tenant-correct by construction.
// ---------------------------------------------------------------------

/// Doc-id bit marking a gateway (tenant-tagged) document.
pub const TENANT_DOC_FLAG: u32 = 1 << 29;

/// Tenant id space: 15 bits, ids `0..MAX_TENANTS`.
pub const MAX_TENANTS: u32 = 1 << 15;

/// Per-tenant document sequence space: 14 bits.
pub const MAX_TENANT_SEQ: u32 = 1 << 14;

/// Pack a tenant id and its per-tenant document sequence number into a
/// tenant-tagged doc id. Panics on out-of-range inputs — the gateway
/// enforces both bounds at admission, so a violation here is a bug.
pub fn tenant_doc(tenant: u32, seq: u32) -> u32 {
    assert!(tenant < MAX_TENANTS, "tenant {tenant} >= {MAX_TENANTS}");
    assert!(seq < MAX_TENANT_SEQ, "tenant seq {seq} >= {MAX_TENANT_SEQ}");
    TENANT_DOC_FLAG | (tenant << 14) | seq
}

/// The tenant id carried by a doc id, `None` for untenanted docs.
pub fn doc_tenant(doc: u32) -> Option<u32> {
    (doc & TENANT_DOC_FLAG != 0).then_some((doc >> 14) & (MAX_TENANTS - 1))
}

/// Split a tenant-tagged doc id back into `(tenant, seq)`.
pub fn doc_tenant_seq(doc: u32) -> Option<(u32, u32)> {
    doc_tenant(doc).map(|t| (t, doc & (MAX_TENANT_SEQ - 1)))
}

/// The wire form of a tag's tenant: `0` for control/cancel traffic and
/// untenanted docs, `tenant id + 1` for tenant-tagged docs. This is
/// what the frame header's tenant field must equal — the codec derives
/// it on encode and validates it on decode, so a frame whose header
/// tenant disagrees with its tag is rejected as malformed.
pub fn tag_wire_tenant(tag: u64) -> u32 {
    // Bits 62/63 are the elastic CANCEL/CTRL flags: control traffic
    // carries no doc id and is never tenant-attributed.
    if tag & ((1 << 63) | (1 << 62)) != 0 {
        return 0;
    }
    doc_tenant((tag >> 32) as u32).map(|t| t + 1).unwrap_or(0)
}

/// Ship an integer header word inside an f32 payload slot *bit-cast*, not
/// value-cast: `as f32` is exact only below 2^24, which long-context
/// lengths exceed. The bit pattern round-trips any u32 losslessly.
pub(crate) fn header_word(x: usize) -> f32 {
    f32::from_bits(u32::try_from(x).expect("header word exceeds u32"))
}

/// Inverse of [`header_word`].
pub(crate) fn header_usize(w: f32) -> usize {
    w.to_bits() as usize
}

/// Serialize a task into one message payload:
/// [q_len, kv_len, q..., k..., v...].
fn encode(t: &WireTask) -> Message {
    let mut payload = Vec::with_capacity(2 + t.tensors.q.len() + 2 * t.tensors.k.len());
    payload.push(header_word(t.tensors.q_len));
    payload.push(header_word(t.tensors.kv_len));
    payload.extend_from_slice(&t.tensors.q);
    payload.extend_from_slice(&t.tensors.k);
    payload.extend_from_slice(&t.tensors.v);
    Message { src: t.home, tag: t.tag, payload }
}

fn decode(msg: &Message, n_heads: usize, n_kv_heads: usize, d: usize) -> (CaTaskTensors, u64, usize) {
    let q_len = header_usize(msg.payload[0]);
    let kv_len = header_usize(msg.payload[1]);
    let q_sz = q_len * n_heads * d;
    let kv_sz = kv_len * n_kv_heads * d;
    let base = 2;
    (
        CaTaskTensors {
            q: msg.payload[base..base + q_sz].to_vec(),
            k: msg.payload[base + q_sz..base + q_sz + kv_sz].to_vec(),
            v: msg.payload[base + q_sz + kv_sz..base + q_sz + 2 * kv_sz].to_vec(),
            q_len,
            kv_len,
        },
        msg.tag,
        msg.src,
    )
}

/// A dispatched CA-task description for the demo pool: which server runs
/// it, plus its tensors and identity.
pub struct DispatchedTask {
    pub doc: u32,
    pub q_start: usize,
    pub server: usize,
    pub home: usize,
    pub tensors: CaTaskTensors,
}

/// Output of one CA-task, keyed for reassembly.
#[derive(Debug, Clone)]
pub struct TaskOutput {
    pub doc: u32,
    pub q_start: usize,
    pub o: Vec<f32>,
}

/// Run a set of dispatched CA-tasks across `n_servers` worker threads,
/// each executing ONE fused batch on its own [`CaExecutor`], returning
/// outputs to their home ranks over the transport.
///
/// The runtime (PJRT client) is shared; compiled executables are cached
/// inside it, so each thread's `CaExecutor::load` is a cache hit after
/// the first.
pub fn run_disaggregated(
    artifacts: &std::path::Path,
    n_servers: usize,
    tasks: Vec<DispatchedTask>,
    tq: usize,
    tkv: usize,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
) -> Result<Vec<TaskOutput>> {
    let fabric = Arc::new(ChannelTransport::new(2 * n_servers));
    // Ranks [0, n) are servers; ranks [n, 2n) are the home-side receive
    // queues for outputs.
    let mut expected_outputs = 0usize;
    let mut per_server_count = vec![0usize; n_servers];
    for t in &tasks {
        per_server_count[t.server] += 1;
        expected_outputs += 1;
    }
    // Dispatch phase (the all-to-all).
    for t in &tasks {
        let wire = WireTask {
            tensors: t.tensors.clone(),
            tag: pack_tag(t.doc, t.q_start as u32),
            home: t.home,
        };
        fabric
            .send(t.server, encode(&wire))
            .with_context(|| format!("dispatching to server {}", t.server))?;
    }

    // Server phase: worker threads batch + execute + return.
    let mut handles = Vec::new();
    for s in 0..n_servers {
        let fabric = Arc::clone(&fabric);
        let artifacts = artifacts.to_path_buf();
        let n_tasks = per_server_count[s];
        handles.push(std::thread::spawn(move || -> Result<()> {
            if n_tasks == 0 {
                return Ok(());
            }
            let rt = Runtime::cpu()?;
            let exec = CaExecutor::load(&rt, &artifacts, tq, tkv, n_heads, n_kv_heads, head_dim)
                .context("loading CA executable")?;
            let mut batch = Vec::with_capacity(n_tasks);
            let mut tags = Vec::with_capacity(n_tasks);
            let mut homes = Vec::with_capacity(n_tasks);
            for _ in 0..n_tasks {
                let msg = fabric.recv(s);
                let (tensors, tag, home) = decode(&msg, n_heads, n_kv_heads, head_dim);
                batch.push(tensors);
                tags.push(tag);
                homes.push(home);
            }
            anyhow::ensure!(
                CaExecutor::fits(&exec, &batch),
                "server {s}: batch exceeds artifact shape"
            );
            let outputs = exec.run_batch(&rt, &batch)?;
            for ((o, tag), home) in outputs.into_iter().zip(tags).zip(homes) {
                fabric
                    .send(n_servers + home, Message { src: s, tag, payload: o })
                    .with_context(|| format!("server {s}: returning output home"))?;
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("server thread panicked"))??;
    }

    // Gather phase: collect outputs from each home queue.
    let mut outputs = Vec::with_capacity(expected_outputs);
    let mut received = 0usize;
    'outer: for home in 0..n_servers {
        while let Some(msg) = fabric.try_recv(n_servers + home) {
            let (doc, q_start) = unpack_tag(msg.tag);
            outputs.push(TaskOutput { doc, q_start: q_start as usize, o: msg.payload });
            received += 1;
            if received == expected_outputs {
                break 'outer;
            }
        }
    }
    anyhow::ensure!(
        outputs.len() == expected_outputs,
        "lost outputs: {} of {expected_outputs}",
        outputs.len()
    );
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_roundtrip() {
        let tag = pack_tag(0xDEAD, 0xBEEF);
        assert_eq!(unpack_tag(tag), (0xDEAD, 0xBEEF));
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = WireTask {
            tensors: CaTaskTensors {
                q: vec![1.0; 128 * 2 * 4],
                k: vec![2.0; 256 * 1 * 4],
                v: vec![3.0; 256 * 1 * 4],
                q_len: 128,
                kv_len: 256,
            },
            tag: pack_tag(3, 128),
            home: 1,
        };
        let msg = encode(&t);
        let (tensors, tag, home) = decode(&msg, 2, 1, 4);
        assert_eq!(tensors.q_len, 128);
        assert_eq!(tensors.kv_len, 256);
        assert_eq!(tensors.q, t.tensors.q);
        assert_eq!(tensors.v, t.tensors.v);
        assert_eq!(tag, t.tag);
        assert_eq!(home, 1);
    }

    #[test]
    fn header_words_exact_beyond_f32_mantissa() {
        // `as f32` rounds above 2^24; the bit-cast must not. 2^24 + 1 and
        // a realistic 128M-token context both round-trip exactly.
        for len in [0usize, 1, (1 << 24) + 1, (1 << 27) + 3, (1 << 30) + 7] {
            assert_eq!(header_usize(header_word(len)), len, "len {len}");
        }
        // The old value-cast demonstrably loses the +1.
        let lossy = ((1usize << 24) + 1) as f32 as usize;
        assert_ne!(lossy, (1 << 24) + 1);
    }
}
