//! `distca worker` — the attention-server daemon.
//!
//! One worker process is one attention server: it binds a listen
//! address, accepts exactly one coordinator session, handshakes
//! (CONFIG in → HELLO out), then runs the *same* elastic server loop
//! as the in-process runtime ([`run_server_loop`]) over a
//! [`TcpTransport`] — control tags, payload layout, and fault
//! semantics identical on both wires, which is what makes the
//! networked path bit-exact against the in-process one.
//!
//! A heartbeat thread beats on the coordinator connection at the
//! CONFIG-negotiated interval; the coordinator feeds the inter-beat
//! gaps into its health EWMAs. The same thread piggybacks the worker's
//! buffered compute-span observations as STATS frames, so the tracing
//! plane costs no extra connection and no extra wakeups. The worker
//! exits when it receives `CTRL_SHUTDOWN`, or when the coordinator
//! connection drops (the transport synthesizes the same shutdown into
//! its inbox), and sends a final STATS flush plus a GOODBYE on the way
//! out — a connection that dies *without* a goodbye is what the
//! coordinator maps to `kill:`.
//!
//! In daemon mode (`distca worker`) a `SIGTERM` triggers the *drain*
//! path, not the kill path: a watcher thread announces DRAIN on the
//! coordinator connection, the coordinator stops planning onto this
//! rank and completes the tick, and the worker exits through the normal
//! shutdown sequence — final stats flush included. `SIGKILL` remains
//! the scripted crash.

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::elastic::failover::run_server_loop_obs;
use crate::elastic::CaCompute;
use crate::exchange::transport::Transport;
use crate::obs::ComputeSink;
use crate::server::{header_usize, header_word};

use super::codec::{Frame, FrameDecoder, FrameKind};
use super::transport::TcpTransport;

/// Set by the `SIGTERM` handler; polled by the daemon's drain watcher.
static SIGTERM_SEEN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_sigterm(_sig: i32) {
    // Async-signal-safe: one relaxed store, nothing else.
    SIGTERM_SEEN.store(true, Ordering::Relaxed);
}

#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
extern "C" {
    // libc is already linked by std; declaring `signal` directly keeps
    // the crate dependency-free.
    fn signal(signum: i32, handler: usize) -> usize;
}

/// Install the `SIGTERM` → drain flag handler (daemon mode only — a
/// library embedder must not have its process-wide handlers replaced).
fn arm_sigterm() {
    #[cfg(unix)]
    unsafe {
        signal(SIGTERM, on_sigterm as usize);
    }
}

/// Worker-side compute-span buffer: the [`ComputeSink`] behind
/// [`run_server_loop_obs`] on the networked path. Observations
/// accumulate as repeating 4-word groups
/// `[tick, tag_lo, tag_hi, dur_s]` (header-word bit-casts for the
/// integers, a plain f32 for the seconds) and ship to the coordinator
/// as [`FrameKind::Stats`] payloads — on each heartbeat, and once more
/// at shutdown so the final tick's spans are never lost.
struct SpanBuffer {
    words: Mutex<Vec<f32>>,
    /// Span groups lost to a dead connection (a STATS send that
    /// failed): reported to the *next* coordinator session as a
    /// [`STATS_DROPPED_MARKER`] sentinel group, and surfaced there as
    /// `TickStats::stats_dropped`.
    dropped: AtomicU64,
}

/// Sentinel tick value opening a dropped-count STATS group
/// `[MARKER, count_lo, count_hi, 0.0]` — no real tick reaches
/// `u32::MAX`, so the decoder can't confuse it with a span group.
pub(crate) const STATS_DROPPED_MARKER: usize = 0xFFFF_FFFF;

impl SpanBuffer {
    fn new() -> Arc<SpanBuffer> {
        Arc::new(SpanBuffer { words: Mutex::new(Vec::new()), dropped: AtomicU64::new(0) })
    }

    /// Take everything buffered so far (empty ⇒ nothing to send).
    fn drain_words(&self) -> Vec<f32> {
        std::mem::take(&mut *self.words.lock().unwrap())
    }

    /// Record `groups` span groups lost to a failed STATS send.
    fn note_dropped(&self, groups: u64) {
        self.dropped.fetch_add(groups, Ordering::Relaxed);
    }

    /// Take (and reset) the dropped-group count.
    fn take_dropped(&self) -> u64 {
        self.dropped.swap(0, Ordering::Relaxed)
    }
}

impl ComputeSink for SpanBuffer {
    fn record_compute(&self, tick: usize, tag: u64, dur_s: f64) {
        let mut w = self.words.lock().unwrap();
        w.push(header_word(tick));
        w.push(header_word((tag & 0xFFFF_FFFF) as usize));
        w.push(header_word((tag >> 32) as usize));
        w.push(dur_s as f32);
    }
}

/// Ship the buffered spans as one STATS frame; a send failure means the
/// connection is gone, which the main loop detects on its own. Groups
/// lost to a failed send are *counted* (not silently forgotten) and
/// the count rides the next successful flush as a sentinel group, so
/// the coordinator's `stats_dropped` accounting stays honest across a
/// reconnect.
fn flush_stats(fabric: &TcpTransport, rank: usize, spans: &SpanBuffer) {
    let mut words = Vec::new();
    let dropped = spans.take_dropped();
    if dropped > 0 {
        words.push(header_word(STATS_DROPPED_MARKER));
        words.push(header_word((dropped & 0xFFFF_FFFF) as usize));
        words.push(header_word((dropped >> 32) as usize));
        words.push(0.0);
    }
    let data = spans.drain_words();
    let data_groups = (data.len() / 4) as u64;
    words.extend_from_slice(&data);
    if words.is_empty() {
        return;
    }
    if fabric.send_frame(0, &Frame::control(FrameKind::Stats, rank, words)).is_err() {
        // The batch never reached the coordinator. Re-buffering could
        // duplicate observations if the frame was partially written, so
        // the groups are gone — account for them, and carry any not-yet
        // reported drop count forward for the next session to report.
        spans.note_dropped(dropped + data_groups);
    }
}

/// CLI-level knobs for the daemon.
#[derive(Debug, Clone)]
pub struct WorkerCfg {
    /// Listen address, e.g. `127.0.0.1:4500` (`:0` = kernel-assigned).
    pub listen: String,
    /// If set, the actual bound address is written here (atomically:
    /// write-then-rename) so a spawning coordinator can discover a
    /// kernel-assigned port.
    pub port_file: Option<PathBuf>,
}

/// The handshake CONFIG: rank assignment, pool size, attention dims,
/// heartbeat interval. Shipped as bit-cast header words in the frame
/// payload (`[rank, n_servers, n_heads, n_kv_heads, head_dim, hb_ms]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerConfig {
    pub rank: usize,
    pub n_servers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub hb_interval: Duration,
}

impl WorkerConfig {
    /// Encode into a CONFIG frame payload.
    pub fn to_payload(&self) -> Vec<f32> {
        vec![
            header_word(self.rank),
            header_word(self.n_servers),
            header_word(self.n_heads),
            header_word(self.n_kv_heads),
            header_word(self.head_dim),
            header_word(self.hb_interval.as_millis() as usize),
        ]
    }

    pub fn from_payload(payload: &[f32]) -> Result<WorkerConfig> {
        anyhow::ensure!(payload.len() >= 6, "short CONFIG payload ({} words)", payload.len());
        Ok(WorkerConfig {
            rank: header_usize(payload[0]),
            n_servers: header_usize(payload[1]),
            n_heads: header_usize(payload[2]),
            n_kv_heads: header_usize(payload[3]),
            head_dim: header_usize(payload[4]),
            hb_interval: Duration::from_millis(header_usize(payload[5]) as u64),
        })
    }
}

/// Run the daemon: bind, publish the address, accept a coordinator,
/// serve until shutdown. A session that ends in a *disconnect* (no
/// orderly `CTRL_SHUTDOWN`) loops back to `accept` so a coordinator
/// re-dialing a dead `--connect` rank mid-soak finds the daemon still
/// there — and the span buffer (plus any dropped-frame count) carries
/// across sessions, flushed right after the re-registration HELLO.
/// Returns cleanly in all cases so a scripted run never leaks worker
/// processes.
pub fn run_worker(cfg: &WorkerCfg) -> Result<()> {
    let listener =
        TcpListener::bind(&cfg.listen).with_context(|| format!("binding {}", cfg.listen))?;
    let addr = listener.local_addr()?;
    if let Some(pf) = &cfg.port_file {
        // Write-then-rename: the polling coordinator must never read a
        // half-written address.
        let tmp = pf.with_extension("tmp");
        std::fs::write(&tmp, addr.to_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, pf).with_context(|| format!("publishing {}", pf.display()))?;
    }
    println!("distca worker listening on {addr}");
    arm_sigterm();
    let spans = SpanBuffer::new();
    loop {
        let (stream, peer) = listener.accept().context("accepting coordinator")?;
        println!("coordinator connected from {peer}");
        let orderly = serve_session(stream, true, &spans)?;
        if orderly || SIGTERM_SEEN.load(Ordering::Relaxed) {
            break;
        }
        println!("coordinator disconnected; awaiting reconnect on {addr}");
    }
    println!("worker exiting cleanly");
    Ok(())
}

/// Serve one coordinator session on an accepted stream: handshake,
/// heartbeats, then the elastic server loop until shutdown or
/// disconnect. Shared by the daemon and the in-process loopback
/// harness ([`super::loopback`]).
pub fn serve_stream(stream: TcpStream) -> Result<()> {
    serve_session(stream, false, &SpanBuffer::new()).map(|_| ())
}

/// [`serve_stream`] with daemon extras: when `daemon` is true, a
/// watcher thread turns a received `SIGTERM` into one DRAIN frame on
/// the coordinator connection (graceful departure; the tick completes
/// and the final stats flush still happens). Non-daemon embedders (the
/// loopback harness) skip the watcher but keep the stats plane.
///
/// `spans` is owned by the caller so buffered observations survive a
/// session teardown; the daemon reuses one buffer across reconnects.
/// Returns `true` when the session ended in an orderly shutdown (the
/// coordinator connection was still up when the server loop exited)
/// and `false` on a disconnect, so the daemon knows whether to await
/// a reconnect.
fn serve_session(stream: TcpStream, daemon: bool, spans: &Arc<SpanBuffer>) -> Result<bool> {
    let _ = stream.set_nodelay(true);
    // Bounded handshake: a coordinator that connects and goes silent
    // must not hang the daemon. The timeout is cleared afterwards —
    // the transport's reader relies on blocking reads.
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .context("setting handshake timeout")?;
    let (cfg, leftover) = read_config(&stream)?;
    stream.set_read_timeout(None).context("clearing handshake timeout")?;
    anyhow::ensure!(
        cfg.rank < cfg.n_servers,
        "CONFIG assigns rank {} in a pool of {}",
        cfg.rank,
        cfg.n_servers
    );
    let fabric = TcpTransport::worker(cfg.rank, cfg.n_servers, stream, &leftover)
        .context("building worker transport")?;
    fabric
        .send_frame(0, &Frame::control(FrameKind::Hello, cfg.rank, vec![]))
        .map_err(|e| anyhow::anyhow!("registration hello: {e}"))?;
    // Reconnect flush: anything buffered before the previous session
    // died (plus the dropped-frame count) ships right behind the HELLO,
    // not only before GOODBYE — a re-dialed mid-soak worker loses no
    // buffered STATS.
    flush_stats(&fabric, cfg.rank, spans);

    // Heartbeat thread: independent of the (possibly busy) compute
    // loop, so a worker crunching a heavy CA-task still beats. Each
    // beat also flushes the buffered compute spans as a STATS frame.
    let stop = Arc::new(AtomicBool::new(false));
    let hb = if cfg.hb_interval > Duration::ZERO {
        let stop = Arc::clone(&stop);
        let fabric = Arc::clone(&fabric);
        let spans = Arc::clone(spans);
        let rank = cfg.rank;
        let interval = cfg.hb_interval.max(Duration::from_millis(10));
        Some(std::thread::spawn(move || {
            let mut seq = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let beat = Frame::control(FrameKind::Heartbeat, rank, vec![header_word(seq)]);
                if fabric.send_frame(0, &beat).is_err() {
                    break; // connection gone; the main loop exits too
                }
                flush_stats(&fabric, rank, &spans);
                seq += 1;
                std::thread::sleep(interval);
            }
        }))
    } else {
        None
    };

    // SIGTERM → DRAIN watcher (daemon only): graceful departure through
    // the drain path, never the kill path.
    let term_watch = if daemon {
        let stop = Arc::clone(&stop);
        let fabric = Arc::clone(&fabric);
        let rank = cfg.rank;
        Some(std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                if SIGTERM_SEEN.load(Ordering::Relaxed) {
                    let _ = fabric
                        .send_frame(0, &Frame::control(FrameKind::Drain, rank, vec![]));
                    break;
                }
                std::thread::sleep(Duration::from_millis(20));
            }
        }))
    } else {
        None
    };

    // Fast-path GQA kernel by default; `DISTCA_KERNEL=oracle` swaps the
    // reference back in (the coordinator's verify oracle stays the
    // reference either way, so bit-exactness is checked live).
    let compute: Box<dyn CaCompute> =
        crate::kernel::compute_from_env(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim);
    let fabric_dyn: Arc<dyn Transport> = Arc::clone(&fabric) as Arc<dyn Transport>;
    let sink: Arc<dyn ComputeSink> = Arc::clone(spans) as _;
    let result = run_server_loop_obs(fabric_dyn, cfg.rank, cfg.n_servers, compute, Some(sink));

    stop.store(true, Ordering::Relaxed);
    // Orderly shutdown leaves the coordinator connection up (we close
    // it below); a disconnect tore it down before the loop exited.
    let orderly = fabric.is_connected(0);
    // Final stats flush *before* the goodbye: span frames written ahead
    // of GOODBYE on the same ordered stream are never lost to shutdown.
    flush_stats(&fabric, cfg.rank, spans);
    // Best-effort goodbye: a SIGKILLed worker never sends one, and
    // that absence is exactly what the coordinator reads as `kill:`.
    let _ = fabric.send_frame(0, &Frame::control(FrameKind::Goodbye, cfg.rank, vec![]));
    if let Some(h) = hb {
        let _ = h.join();
    }
    if let Some(h) = term_watch {
        let _ = h.join();
    }
    // Close the connection so the coordinator's reader sees EOF right
    // away (matters for the in-process loopback harness, where no
    // process exit closes the socket for us).
    fabric.close_conn(0);
    result.map(|()| orderly)
}

/// Read frames off the raw stream until the CONFIG arrives. Returns the
/// parsed config plus any bytes read past it (they belong to the data
/// stream and are handed to the transport's reader).
fn read_config(mut stream: &TcpStream) -> Result<(WorkerConfig, Vec<u8>)> {
    let mut dec = FrameDecoder::new();
    let mut chunk = [0u8; 4096];
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if let Some(f) = dec.next_frame().map_err(|e| anyhow::anyhow!("handshake: {e}"))? {
            anyhow::ensure!(
                f.kind == FrameKind::Config,
                "expected CONFIG first, got {:?}",
                f.kind
            );
            let cfg = WorkerConfig::from_payload(&f.payload)?;
            let leftover = dec.take_buffered();
            return Ok((cfg, leftover));
        }
        anyhow::ensure!(Instant::now() < deadline, "timed out waiting for CONFIG");
        let n = stream.read(&mut chunk).context("handshake read")?;
        anyhow::ensure!(n > 0, "coordinator closed during handshake");
        dec.push(&chunk[..n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_payload_roundtrips_exactly() {
        let cfg = WorkerConfig {
            rank: 3,
            n_servers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            hb_interval: Duration::from_millis(200),
        };
        let got = WorkerConfig::from_payload(&cfg.to_payload()).unwrap();
        assert_eq!(got, cfg);
        // The header-word scheme keeps large pool sizes exact too.
        let big = WorkerConfig { n_servers: (1 << 24) + 1, ..cfg };
        assert_eq!(WorkerConfig::from_payload(&big.to_payload()).unwrap(), big);
    }

    #[test]
    fn short_config_rejected() {
        assert!(WorkerConfig::from_payload(&[0.0; 3]).is_err());
    }

    #[test]
    fn span_buffer_encodes_four_word_groups() {
        let spans = SpanBuffer::new();
        let tag: u64 = (7 << 32) | 42; // exercises both halves
        spans.record_compute(3, tag, 0.25);
        spans.record_compute(3, 1, 0.5);
        let words = spans.drain_words();
        assert_eq!(words.len(), 8);
        assert_eq!(header_usize(words[0]), 3);
        assert_eq!(header_usize(words[1]), 42);
        assert_eq!(header_usize(words[2]), 7);
        assert_eq!(words[3], 0.25);
        let got = (header_usize(words[2]) as u64) << 32 | header_usize(words[1]) as u64;
        assert_eq!(got, tag);
        // Drained means drained.
        assert!(spans.drain_words().is_empty());
    }

    #[test]
    fn dropped_groups_accumulate_and_drain() {
        let spans = SpanBuffer::new();
        assert_eq!(spans.take_dropped(), 0);
        spans.note_dropped(3);
        spans.note_dropped(2);
        assert_eq!(spans.take_dropped(), 5);
        assert_eq!(spans.take_dropped(), 0);
    }

    #[test]
    fn dropped_marker_roundtrips_as_header_word() {
        // The sentinel tick marker must survive the f32 bit-cast that
        // carries STATS words over the wire.
        assert_eq!(header_usize(header_word(STATS_DROPPED_MARKER)), STATS_DROPPED_MARKER);
    }
}
