//! `distca worker` — the attention-server daemon.
//!
//! One worker process is one attention server: it binds a listen
//! address, accepts exactly one coordinator session, handshakes
//! (CONFIG in → HELLO out), then runs the *same* elastic server loop
//! as the in-process runtime ([`run_server_loop`]) over a
//! [`TcpTransport`] — control tags, payload layout, and fault
//! semantics identical on both wires, which is what makes the
//! networked path bit-exact against the in-process one.
//!
//! A heartbeat thread beats on the coordinator connection at the
//! CONFIG-negotiated interval; the coordinator feeds the inter-beat
//! gaps into its health EWMAs. The worker exits when it receives
//! `CTRL_SHUTDOWN`, or when the coordinator connection drops (the
//! transport synthesizes the same shutdown into its inbox), and sends
//! a GOODBYE on the way out — a connection that dies *without* a
//! goodbye is what the coordinator maps to `kill:`.

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::elastic::failover::run_server_loop;
use crate::elastic::{CaCompute, ReferenceCaCompute};
use crate::exchange::transport::Transport;
use crate::server::{header_usize, header_word};

use super::codec::{Frame, FrameDecoder, FrameKind};
use super::transport::TcpTransport;

/// CLI-level knobs for the daemon.
#[derive(Debug, Clone)]
pub struct WorkerCfg {
    /// Listen address, e.g. `127.0.0.1:4500` (`:0` = kernel-assigned).
    pub listen: String,
    /// If set, the actual bound address is written here (atomically:
    /// write-then-rename) so a spawning coordinator can discover a
    /// kernel-assigned port.
    pub port_file: Option<PathBuf>,
}

/// The handshake CONFIG: rank assignment, pool size, attention dims,
/// heartbeat interval. Shipped as bit-cast header words in the frame
/// payload (`[rank, n_servers, n_heads, n_kv_heads, head_dim, hb_ms]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerConfig {
    pub rank: usize,
    pub n_servers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub hb_interval: Duration,
}

impl WorkerConfig {
    /// Encode into a CONFIG frame payload.
    pub fn to_payload(&self) -> Vec<f32> {
        vec![
            header_word(self.rank),
            header_word(self.n_servers),
            header_word(self.n_heads),
            header_word(self.n_kv_heads),
            header_word(self.head_dim),
            header_word(self.hb_interval.as_millis() as usize),
        ]
    }

    pub fn from_payload(payload: &[f32]) -> Result<WorkerConfig> {
        anyhow::ensure!(payload.len() >= 6, "short CONFIG payload ({} words)", payload.len());
        Ok(WorkerConfig {
            rank: header_usize(payload[0]),
            n_servers: header_usize(payload[1]),
            n_heads: header_usize(payload[2]),
            n_kv_heads: header_usize(payload[3]),
            head_dim: header_usize(payload[4]),
            hb_interval: Duration::from_millis(header_usize(payload[5]) as u64),
        })
    }
}

/// Run the daemon: bind, publish the address, accept one coordinator,
/// serve until shutdown/disconnect. Returns cleanly in both cases so
/// a scripted run never leaks worker processes.
pub fn run_worker(cfg: &WorkerCfg) -> Result<()> {
    let listener =
        TcpListener::bind(&cfg.listen).with_context(|| format!("binding {}", cfg.listen))?;
    let addr = listener.local_addr()?;
    if let Some(pf) = &cfg.port_file {
        // Write-then-rename: the polling coordinator must never read a
        // half-written address.
        let tmp = pf.with_extension("tmp");
        std::fs::write(&tmp, addr.to_string())
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, pf).with_context(|| format!("publishing {}", pf.display()))?;
    }
    println!("distca worker listening on {addr}");
    let (stream, peer) = listener.accept().context("accepting coordinator")?;
    println!("coordinator connected from {peer}");
    serve_stream(stream)?;
    println!("worker exiting cleanly");
    Ok(())
}

/// Serve one coordinator session on an accepted stream: handshake,
/// heartbeats, then the elastic server loop until shutdown or
/// disconnect. Shared by the daemon and the in-process loopback
/// harness ([`super::loopback`]).
pub fn serve_stream(stream: TcpStream) -> Result<()> {
    let _ = stream.set_nodelay(true);
    // Bounded handshake: a coordinator that connects and goes silent
    // must not hang the daemon. The timeout is cleared afterwards —
    // the transport's reader relies on blocking reads.
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .context("setting handshake timeout")?;
    let (cfg, leftover) = read_config(&stream)?;
    stream.set_read_timeout(None).context("clearing handshake timeout")?;
    anyhow::ensure!(
        cfg.rank < cfg.n_servers,
        "CONFIG assigns rank {} in a pool of {}",
        cfg.rank,
        cfg.n_servers
    );
    let fabric = TcpTransport::worker(cfg.rank, cfg.n_servers, stream, &leftover)
        .context("building worker transport")?;
    fabric
        .send_frame(0, &Frame::control(FrameKind::Hello, cfg.rank, vec![]))
        .map_err(|e| anyhow::anyhow!("registration hello: {e}"))?;

    // Heartbeat thread: independent of the (possibly busy) compute
    // loop, so a worker crunching a heavy CA-task still beats.
    let stop = Arc::new(AtomicBool::new(false));
    let hb = if cfg.hb_interval > Duration::ZERO {
        let stop = Arc::clone(&stop);
        let fabric = Arc::clone(&fabric);
        let rank = cfg.rank;
        let interval = cfg.hb_interval.max(Duration::from_millis(10));
        Some(std::thread::spawn(move || {
            let mut seq = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let beat = Frame::control(FrameKind::Heartbeat, rank, vec![header_word(seq)]);
                if fabric.send_frame(0, &beat).is_err() {
                    break; // connection gone; the main loop exits too
                }
                seq += 1;
                std::thread::sleep(interval);
            }
        }))
    } else {
        None
    };

    let compute: Box<dyn CaCompute> =
        Box::new(ReferenceCaCompute::new(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim));
    let fabric_dyn: Arc<dyn Transport> = Arc::clone(&fabric) as Arc<dyn Transport>;
    let result = run_server_loop(fabric_dyn, cfg.rank, cfg.n_servers, compute);

    stop.store(true, Ordering::Relaxed);
    // Best-effort goodbye: a SIGKILLed worker never sends one, and
    // that absence is exactly what the coordinator reads as `kill:`.
    let _ = fabric.send_frame(0, &Frame::control(FrameKind::Goodbye, cfg.rank, vec![]));
    if let Some(h) = hb {
        let _ = h.join();
    }
    // Close the connection so the coordinator's reader sees EOF right
    // away (matters for the in-process loopback harness, where no
    // process exit closes the socket for us).
    fabric.close_conn(0);
    result
}

/// Read frames off the raw stream until the CONFIG arrives. Returns the
/// parsed config plus any bytes read past it (they belong to the data
/// stream and are handed to the transport's reader).
fn read_config(mut stream: &TcpStream) -> Result<(WorkerConfig, Vec<u8>)> {
    let mut dec = FrameDecoder::new();
    let mut chunk = [0u8; 4096];
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if let Some(f) = dec.next_frame().map_err(|e| anyhow::anyhow!("handshake: {e}"))? {
            anyhow::ensure!(
                f.kind == FrameKind::Config,
                "expected CONFIG first, got {:?}",
                f.kind
            );
            let cfg = WorkerConfig::from_payload(&f.payload)?;
            let leftover = dec.take_buffered();
            return Ok((cfg, leftover));
        }
        anyhow::ensure!(Instant::now() < deadline, "timed out waiting for CONFIG");
        let n = stream.read(&mut chunk).context("handshake read")?;
        anyhow::ensure!(n > 0, "coordinator closed during handshake");
        dec.push(&chunk[..n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_payload_roundtrips_exactly() {
        let cfg = WorkerConfig {
            rank: 3,
            n_servers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            hb_interval: Duration::from_millis(200),
        };
        let got = WorkerConfig::from_payload(&cfg.to_payload()).unwrap();
        assert_eq!(got, cfg);
        // The header-word scheme keeps large pool sizes exact too.
        let big = WorkerConfig { n_servers: (1 << 24) + 1, ..cfg };
        assert_eq!(WorkerConfig::from_payload(&big.to_payload()).unwrap(), big);
    }

    #[test]
    fn short_config_rejected() {
        assert!(WorkerConfig::from_payload(&[0.0; 3]).is_err());
    }
}
