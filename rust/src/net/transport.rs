//! [`TcpTransport`]: the socket-backed implementation of
//! [`exchange::Transport`](crate::exchange::Transport).
//!
//! Same rank layout as the in-process fabric — `[0, n)` server
//! inboxes, `[n, 2n)` home output queues — but a rank can live behind
//! a TCP connection instead of a local queue: sends to it are encoded
//! as [`Frame`]s; a reader thread per connection decodes inbound
//! frames into the local queues (data) or an event queue (control:
//! hello / heartbeat / drain / goodbye / disconnect). `server/mod.rs`
//! message discipline and the elastic coordinator's dispatch/gather
//! run unmodified on top.
//!
//! Connection lifecycle *is* the fault model:
//!
//! * a dropped connection surfaces as [`NetEvent::Disconnected`] plus
//!   failing sends — the coordinator maps it to `kill:`;
//! * a [`NetEvent::DrainRequest`] maps to `drain:`;
//! * a reconnection ([`TcpTransport::attach`] on the same slot) maps
//!   to `rejoin:`.
//!
//! On the worker side, a coordinator EOF additionally synthesizes a
//! `CTRL_SHUTDOWN` message into the worker's own inbox so the blocking
//! [`run_server_loop`](crate::elastic::run_server_loop) exits cleanly.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::elastic::failover::{COORD_SRC, CTRL_SHUTDOWN};
use crate::exchange::transport::{Message, SendError, Transport};

use super::codec::{Frame, FrameDecoder, FrameKind};

/// Control-plane event observed on a connection. Drained via
/// [`TcpTransport::poll_events`]; the serve loop maps these onto
/// `ServerPool` membership and the heartbeat EWMAs.
#[derive(Debug, Clone)]
pub enum NetEvent {
    /// Registration: the worker for `rank` answered its CONFIG.
    Hello { rank: usize },
    /// Liveness beat from `rank` (arrival-timestamped locally).
    Heartbeat { rank: usize, at: Instant, seq: u64 },
    /// The worker asks to leave gracefully (`drain:`).
    DrainRequest { rank: usize },
    /// Orderly exit notice.
    Goodbye { rank: usize },
    /// The connection dropped without a goodbye (`kill:`).
    Disconnected { rank: usize },
    /// Observability stats from `rank`: repeating 4-word groups
    /// `[tick, tag_lo, tag_hi, dur_s]` of per-task compute spans
    /// (see [`FrameKind::Stats`]). The serve loop feeds these into its
    /// recorder to refine the compute/wire-wait split.
    Stats { rank: usize, payload: Vec<f32> },
}

struct ConnSlot {
    /// Bumped on every (re)attach; a reader thread may only tear down
    /// the slot it was spawned for, so a reconnect is never clobbered
    /// by the previous connection's dying reader.
    gen: AtomicU64,
    writer: Mutex<Option<TcpStream>>,
}

/// Socket-backed [`Transport`]: local mpsc queues for local ranks,
/// framed TCP for remote ones.
pub struct TcpTransport {
    n_ranks: usize,
    senders: Vec<Sender<Message>>,
    receivers: Vec<Mutex<Receiver<Message>>>,
    /// rank → connection slot carrying it (None = local rank).
    route: Vec<Option<usize>>,
    conns: Vec<ConnSlot>,
    events: Mutex<VecDeque<NetEvent>>,
    /// Worker side: rank whose inbox gets a synthesized
    /// `CTRL_SHUTDOWN` when the coordinator connection drops.
    shutdown_rank_on_eof: Option<usize>,
}

impl TcpTransport {
    fn base(
        n_ranks: usize,
        n_conns: usize,
        route: Vec<Option<usize>>,
        shutdown_rank_on_eof: Option<usize>,
    ) -> TcpTransport {
        assert_eq!(route.len(), n_ranks);
        let mut senders = Vec::with_capacity(n_ranks);
        let mut receivers = Vec::with_capacity(n_ranks);
        for _ in 0..n_ranks {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Mutex::new(rx));
        }
        let conns = (0..n_conns)
            .map(|_| ConnSlot { gen: AtomicU64::new(0), writer: Mutex::new(None) })
            .collect();
        TcpTransport {
            n_ranks,
            senders,
            receivers,
            route,
            conns,
            events: Mutex::new(VecDeque::new()),
            shutdown_rank_on_eof,
        }
    }

    /// Coordinator-side fabric over `n_servers` remote workers:
    /// connection slot `i` carries server rank `i`; home ranks
    /// `[n, 2n)` are local queues the reader threads feed. Workers are
    /// attached afterwards via [`TcpTransport::attach`].
    pub fn coordinator(n_servers: usize) -> Arc<TcpTransport> {
        assert!(n_servers > 0);
        let mut route = vec![None; 2 * n_servers];
        for (r, slot) in route.iter_mut().enumerate().take(n_servers) {
            *slot = Some(r);
        }
        Arc::new(TcpTransport::base(2 * n_servers, n_servers, route, None))
    }

    /// Worker-side fabric: this worker's own rank is a local queue
    /// (its inbox); every other rank routes over the single
    /// coordinator connection (slot 0). `initial` carries any bytes
    /// the handshake read past its last frame.
    pub fn worker(
        rank: usize,
        n_servers: usize,
        stream: TcpStream,
        initial: &[u8],
    ) -> std::io::Result<Arc<TcpTransport>> {
        assert!(rank < n_servers, "worker rank {rank} out of a pool of {n_servers}");
        let n_ranks = 2 * n_servers;
        let mut route = vec![Some(0); n_ranks];
        route[rank] = None;
        let t = Arc::new(TcpTransport::base(n_ranks, 1, route, Some(rank)));
        TcpTransport::attach(&t, 0, rank, stream, initial)?;
        Ok(t)
    }

    /// Attach (or on reconnect, re-attach) `stream` as connection slot
    /// `conn`, whose remote peer speaks for rank `peer_rank`: stores
    /// the writer half and spawns a reader thread that decodes inbound
    /// frames into local queues (data) or the event queue (control).
    /// (An associated fn rather than a method: the reader thread needs
    /// its own `Arc` of the transport.)
    pub fn attach(
        this: &Arc<TcpTransport>,
        conn: usize,
        peer_rank: usize,
        stream: TcpStream,
        initial: &[u8],
    ) -> std::io::Result<()> {
        stream.set_nodelay(true)?;
        let read_half = stream.try_clone()?;
        let gen = {
            let mut w = this.conns[conn].writer.lock().unwrap();
            let g = this.conns[conn].gen.fetch_add(1, Ordering::SeqCst) + 1;
            *w = Some(stream);
            g
        };
        let me = Arc::clone(this);
        let init = initial.to_vec();
        std::thread::spawn(move || me.reader_loop(conn, peer_rank, gen, read_half, init));
        Ok(())
    }

    fn reader_loop(
        &self,
        conn: usize,
        peer_rank: usize,
        gen: u64,
        mut stream: TcpStream,
        initial: Vec<u8>,
    ) {
        let mut dec = FrameDecoder::new();
        dec.push(&initial);
        let mut chunk = vec![0u8; 64 * 1024];
        'stream: loop {
            // Drain everything decodable before the next blocking read.
            loop {
                match dec.next_frame() {
                    Ok(Some(f)) => self.dispatch_frame(peer_rank, f),
                    Ok(None) => break,
                    // Corrupt/desynced stream: there is no resync point
                    // in a length-prefixed protocol — drop the
                    // connection; the peer shows up as Disconnected.
                    Err(_) => break 'stream,
                }
            }
            match stream.read(&mut chunk) {
                Ok(0) | Err(_) => break,
                Ok(n) => dec.push(&chunk[..n]),
            }
        }
        // Only the generation that owns the slot may tear it down — a
        // reconnect may already have replaced the connection. The check
        // happens *under the writer lock* (attach bumps the generation
        // and installs the new writer under the same lock), so a dying
        // reader can never null out a freshly re-attached writer.
        {
            let mut w = self.conns[conn].writer.lock().unwrap();
            if self.conns[conn].gen.load(Ordering::SeqCst) != gen {
                return;
            }
            *w = None;
        }
        self.push_event(NetEvent::Disconnected { rank: peer_rank });
        if let Some(r) = self.shutdown_rank_on_eof {
            // Worker side: unblock the blocking server loop so the
            // process exits instead of hanging on a dead fabric.
            let _ = self.senders[r].send(Message {
                src: COORD_SRC,
                tag: CTRL_SHUTDOWN,
                payload: vec![],
            });
        }
    }

    fn dispatch_frame(&self, peer_rank: usize, f: Frame) {
        match f.kind {
            FrameKind::Msg => {
                let dst = f.dst as usize;
                if dst < self.senders.len() {
                    let _ = self.senders[dst].send(f.into_message());
                }
            }
            FrameKind::Hello => self.push_event(NetEvent::Hello { rank: peer_rank }),
            FrameKind::Heartbeat => {
                let seq = f.payload.first().map(|w| w.to_bits() as u64).unwrap_or(0);
                self.push_event(NetEvent::Heartbeat { rank: peer_rank, at: Instant::now(), seq });
            }
            FrameKind::Drain => self.push_event(NetEvent::DrainRequest { rank: peer_rank }),
            FrameKind::Goodbye => self.push_event(NetEvent::Goodbye { rank: peer_rank }),
            FrameKind::Stats => {
                self.push_event(NetEvent::Stats { rank: peer_rank, payload: f.payload })
            }
            // CONFIG is consumed during the handshake, before the
            // transport owns the stream; a late one is ignored.
            FrameKind::Config => {}
        }
    }

    fn push_event(&self, ev: NetEvent) {
        self.events.lock().unwrap().push_back(ev);
    }

    /// Drain all pending control-plane events.
    pub fn poll_events(&self) -> Vec<NetEvent> {
        self.events.lock().unwrap().drain(..).collect()
    }

    /// Whether connection slot `conn` currently has a live writer.
    pub fn is_connected(&self, conn: usize) -> bool {
        self.conns.get(conn).is_some_and(|c| c.writer.lock().unwrap().is_some())
    }

    /// Write a control frame over connection slot `conn`.
    pub fn send_frame(&self, conn: usize, frame: &Frame) -> Result<(), SendError> {
        self.write_frame(conn, frame).map_err(|reason| SendError { dst: conn, reason })
    }

    fn write_frame(&self, conn: usize, frame: &Frame) -> Result<(), String> {
        let bytes = frame.encode().map_err(|e| e.to_string())?;
        let Some(slot) = self.conns.get(conn) else {
            return Err(format!("no connection slot {conn}"));
        };
        let mut guard = slot.writer.lock().unwrap();
        let Some(stream) = guard.as_mut() else {
            return Err("connection down".to_string());
        };
        match stream.write_all(&bytes) {
            Ok(()) => Ok(()),
            Err(e) => {
                // Drop the writer immediately: every later send fails
                // fast instead of re-discovering the broken pipe. The
                // reader thread reports the Disconnected event.
                *guard = None;
                Err(format!("write failed: {e}"))
            }
        }
    }

    /// Hard-close connection slot `conn` (the peer sees EOF). Used by
    /// the `--connect` fault backend, where there is no child process
    /// to SIGKILL.
    pub fn close_conn(&self, conn: usize) {
        if let Some(slot) = self.conns.get(conn) {
            if let Some(s) = slot.writer.lock().unwrap().take() {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Transport for TcpTransport {
    fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    fn send(&self, dst: usize, msg: Message) -> Result<(), SendError> {
        match self.route.get(dst).copied().flatten() {
            None => {
                let Some(tx) = self.senders.get(dst) else {
                    return Err(SendError {
                        dst,
                        reason: format!("rank out of range (fabric has {})", self.n_ranks),
                    });
                };
                tx.send(msg)
                    .map_err(|_| SendError { dst, reason: "local receiver dropped".into() })
            }
            Some(conn) => {
                let frame = Frame::msg(dst, msg);
                self.write_frame(conn, &frame).map_err(|reason| SendError { dst, reason })
            }
        }
    }

    fn recv(&self, rank: usize) -> Message {
        self.receivers[rank]
            .lock()
            .unwrap()
            .recv()
            .expect("transport dropped while receiving")
    }

    fn try_recv(&self, rank: usize) -> Option<Message> {
        self.receivers[rank].lock().unwrap().try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Two coordinator-side transports wired back-to-back would need a
    /// worker loop; here we just check framing over a real socket pair:
    /// coordinator → worker data, worker → home data, and EOF events.
    #[test]
    fn socket_pair_carries_messages_and_eof_events() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let n = 2;

        let coord = TcpTransport::coordinator(n);
        let dial = TcpStream::connect(addr).unwrap();
        let (accepted, _) = listener.accept().unwrap();
        TcpTransport::attach(&coord, 0, 0, dial, &[]).unwrap();
        let worker = TcpTransport::worker(0, n, accepted, &[]).unwrap();

        // Coordinator → worker rank 0.
        coord
            .send(0, Message { src: usize::MAX, tag: 42, payload: vec![1.5, -2.0] })
            .unwrap();
        let got = worker.recv(0);
        assert_eq!(got.src, usize::MAX);
        assert_eq!(got.tag, 42);
        assert_eq!(got.payload, vec![1.5, -2.0]);

        // Worker → home queue n + 1 on the coordinator.
        worker.send(n + 1, Message { src: 0, tag: 7, payload: vec![3.0] }).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let back = loop {
            if let Some(m) = coord.try_recv(n + 1) {
                break m;
            }
            assert!(std::time::Instant::now() < deadline, "timed out");
            std::thread::sleep(std::time::Duration::from_millis(1));
        };
        assert_eq!(back.src, 0);
        assert_eq!(back.tag, 7);

        // Coordinator closes: the worker's inbox gets the shutdown
        // sentinel so a blocking server loop exits.
        coord.close_conn(0);
        let sentinel = worker.recv(0);
        assert_eq!(sentinel.tag, CTRL_SHUTDOWN);
        // And the worker-side disconnect is observable as an event.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            if worker
                .poll_events()
                .iter()
                .any(|e| matches!(e, NetEvent::Disconnected { rank: 0 }))
            {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "no disconnect event");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        // Sends to the dead connection fail instead of panicking.
        assert!(worker.send(n + 1, Message { src: 0, tag: 1, payload: vec![] }).is_err());
    }
}
