//! [`TcpTransport`]: the socket-backed implementation of
//! [`exchange::Transport`](crate::exchange::Transport).
//!
//! Same rank layout as the in-process fabric — `[0, n)` server
//! inboxes, `[n, 2n)` home output queues — but a rank can live behind
//! a TCP connection instead of a local queue: sends to it are encoded
//! as [`Frame`]s; a reader thread per connection decodes inbound
//! frames into the local queues (data) or an event queue (control:
//! hello / heartbeat / drain / goodbye / disconnect). `server/mod.rs`
//! message discipline and the elastic coordinator's dispatch/gather
//! run unmodified on top.
//!
//! Connection lifecycle *is* the fault model:
//!
//! * a dropped connection surfaces as [`NetEvent::Disconnected`] plus
//!   failing sends — the coordinator maps it to `kill:`;
//! * a [`NetEvent::DrainRequest`] maps to `drain:`;
//! * a reconnection ([`TcpTransport::attach`] on the same slot) maps
//!   to `rejoin:`.
//!
//! On the worker side, a coordinator EOF additionally synthesizes a
//! `CTRL_SHUTDOWN` message into the worker's own inbox so the blocking
//! [`run_server_loop`](crate::elastic::run_server_loop) exits cleanly.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::elastic::failover::{is_task_tag, COORD_SRC, CTRL_SHUTDOWN};
use crate::exchange::transport::{shutdown_sentinel, Message, SendError, Transport};

use super::codec::{Frame, FrameDecoder, FrameKind, PayloadPool};

/// Control-plane event observed on a connection. Drained via
/// [`TcpTransport::poll_events`]; the serve loop maps these onto
/// `ServerPool` membership and the heartbeat EWMAs.
#[derive(Debug, Clone)]
pub enum NetEvent {
    /// Registration: the worker for `rank` answered its CONFIG.
    Hello { rank: usize },
    /// Liveness beat from `rank` (arrival-timestamped locally).
    Heartbeat { rank: usize, at: Instant, seq: u64 },
    /// The worker asks to leave gracefully (`drain:`).
    DrainRequest { rank: usize },
    /// Orderly exit notice.
    Goodbye { rank: usize },
    /// The connection dropped without a goodbye (`kill:`).
    Disconnected { rank: usize },
    /// Observability stats from `rank`: repeating 4-word groups
    /// `[tick, tag_lo, tag_hi, dur_s]` of per-task compute spans
    /// (see [`FrameKind::Stats`]). The serve loop feeds these into its
    /// recorder to refine the compute/wire-wait split.
    Stats { rank: usize, payload: Vec<f32> },
}

/// The sending side of one live connection: a queue into the
/// connection's dedicated writer thread, plus the stream handle kept
/// for hard closes. Senders enqueue encoded frames and return
/// immediately — the writer thread owns the blocking `write_all`
/// syscall, so a slow or stalled peer never serializes the dispatch
/// loop (the double-buffered send half of the §4.3 overlap).
struct WriterHandle {
    tx: Sender<Vec<u8>>,
    /// Kept so [`TcpTransport::close_conn`] can shut the socket down
    /// even while the writer thread is blocked mid-syscall.
    stream: TcpStream,
}

struct ConnSlot {
    /// Bumped on every (re)attach; a reader or writer thread may only
    /// tear down the slot it was spawned for, so a reconnect is never
    /// clobbered by the previous connection's dying threads.
    gen: AtomicU64,
    writer: Mutex<Option<WriterHandle>>,
}

/// Socket-backed [`Transport`]: local mpsc queues for local ranks,
/// framed TCP for remote ones.
pub struct TcpTransport {
    n_ranks: usize,
    senders: Vec<Sender<Message>>,
    receivers: Vec<Mutex<Receiver<Message>>>,
    /// rank → connection slot carrying it (None = local rank).
    route: Vec<Option<usize>>,
    conns: Vec<ConnSlot>,
    events: Mutex<VecDeque<NetEvent>>,
    /// Worker side: rank whose inbox gets a synthesized
    /// `CTRL_SHUTDOWN` when the coordinator connection drops.
    shutdown_rank_on_eof: Option<usize>,
    /// Current outbound wave stamp, packed `(epoch << 8) | wave`;
    /// 0 = unstamped (flat ticks, pre-`--pp` traffic). Set by the
    /// coordinator via [`Transport::set_wave_stamp`] before each wave's
    /// dispatch and applied to every outbound task frame.
    wave_stamp: AtomicU64,
    /// Worker side: stamp of each inbound task frame — `(wave, epoch,
    /// trace)` — echoed onto the matching response so the coordinator
    /// can attribute it to the wave/epoch it was dispatched under and
    /// to the dispatch hop that produced it. Keyed by task tag (a
    /// re-sent tag simply overwrites — per-connection FIFO makes the
    /// latest request's stamp the one in effect).
    echo: Mutex<HashMap<u64, (u8, u64, u64)>>,
    /// Coordinator side: the lineage trace id to stamp onto the next
    /// outbound data frame carrying each tag, set per physical send via
    /// [`Transport::set_trace_stamp`] and consumed (removed) by the
    /// stamp so a failover re-send under a fresh trace never reuses a
    /// stale id.
    trace_stamp: Mutex<HashMap<u64, u64>>,
    /// Coordinator side: `(tag, trace)` pairs echoed on inbound
    /// responses, drained per tick via [`Transport::take_trace_echoes`]
    /// so the recorder can mark which dispatch won the race.
    trace_echoes: Mutex<Vec<(u64, u64)>>,
    /// Coordinator side: responses whose echoed epoch predates the
    /// current wave stamp — work from a wave that has since been
    /// re-dispatched under a fresh epoch (kept only if dedup hasn't
    /// already seen the tag; counted here either way).
    stale_epoch_frames: AtomicU64,
    /// Recv-payload buffer pool shared by every reader thread: inbound
    /// frames decode into recycled `Vec<f32>`s (via
    /// [`FrameDecoder::next_frame_pooled`]) and consumers hand spent
    /// payloads back through [`Transport::recycle_payload`], so steady
    /// state task traffic reuses a fixed set of buffers instead of
    /// allocating per frame.
    pool: PayloadPool,
}

impl TcpTransport {
    fn base(
        n_ranks: usize,
        n_conns: usize,
        route: Vec<Option<usize>>,
        shutdown_rank_on_eof: Option<usize>,
    ) -> TcpTransport {
        assert_eq!(route.len(), n_ranks);
        let mut senders = Vec::with_capacity(n_ranks);
        let mut receivers = Vec::with_capacity(n_ranks);
        for _ in 0..n_ranks {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Mutex::new(rx));
        }
        let conns = (0..n_conns)
            .map(|_| ConnSlot { gen: AtomicU64::new(0), writer: Mutex::new(None) })
            .collect();
        TcpTransport {
            n_ranks,
            senders,
            receivers,
            route,
            conns,
            events: Mutex::new(VecDeque::new()),
            shutdown_rank_on_eof,
            wave_stamp: AtomicU64::new(0),
            echo: Mutex::new(HashMap::new()),
            trace_stamp: Mutex::new(HashMap::new()),
            trace_echoes: Mutex::new(Vec::new()),
            stale_epoch_frames: AtomicU64::new(0),
            pool: PayloadPool::new(64),
        }
    }

    /// Coordinator-side fabric over `n_servers` remote workers:
    /// connection slot `i` carries server rank `i`; home ranks
    /// `[n, 2n)` are local queues the reader threads feed. Workers are
    /// attached afterwards via [`TcpTransport::attach`].
    pub fn coordinator(n_servers: usize) -> Arc<TcpTransport> {
        assert!(n_servers > 0);
        let mut route = vec![None; 2 * n_servers];
        for (r, slot) in route.iter_mut().enumerate().take(n_servers) {
            *slot = Some(r);
        }
        Arc::new(TcpTransport::base(2 * n_servers, n_servers, route, None))
    }

    /// Worker-side fabric: this worker's own rank is a local queue
    /// (its inbox); every other rank routes over the single
    /// coordinator connection (slot 0). `initial` carries any bytes
    /// the handshake read past its last frame.
    pub fn worker(
        rank: usize,
        n_servers: usize,
        stream: TcpStream,
        initial: &[u8],
    ) -> std::io::Result<Arc<TcpTransport>> {
        assert!(rank < n_servers, "worker rank {rank} out of a pool of {n_servers}");
        let n_ranks = 2 * n_servers;
        let mut route = vec![Some(0); n_ranks];
        route[rank] = None;
        let t = Arc::new(TcpTransport::base(n_ranks, 1, route, Some(rank)));
        TcpTransport::attach(&t, 0, rank, stream, initial)?;
        Ok(t)
    }

    /// Attach (or on reconnect, re-attach) `stream` as connection slot
    /// `conn`, whose remote peer speaks for rank `peer_rank`: stores
    /// the writer half and spawns a reader thread that decodes inbound
    /// frames into local queues (data) or the event queue (control).
    /// (An associated fn rather than a method: the reader thread needs
    /// its own `Arc` of the transport.)
    pub fn attach(
        this: &Arc<TcpTransport>,
        conn: usize,
        peer_rank: usize,
        stream: TcpStream,
        initial: &[u8],
    ) -> std::io::Result<()> {
        stream.set_nodelay(true)?;
        let read_half = stream.try_clone()?;
        let write_half = stream.try_clone()?;
        let (tx, rx) = channel::<Vec<u8>>();
        let gen = {
            let mut w = this.conns[conn].writer.lock().unwrap();
            let g = this.conns[conn].gen.fetch_add(1, Ordering::SeqCst) + 1;
            *w = Some(WriterHandle { tx, stream });
            g
        };
        let me = Arc::clone(this);
        std::thread::spawn(move || me.writer_loop(conn, gen, write_half, rx));
        let me = Arc::clone(this);
        let init = initial.to_vec();
        std::thread::spawn(move || me.reader_loop(conn, peer_rank, gen, read_half, init));
        Ok(())
    }

    /// Per-connection writer: drains the send queue into the socket so
    /// callers never block on the syscall. Exits when the queue's
    /// senders are gone (teardown dropped the [`WriterHandle`]) or on a
    /// write error — in which case it shuts the socket down (the reader
    /// unblocks into its EOF path and reports `Disconnected`) and
    /// clears the slot under the generation check so later sends fail
    /// fast.
    fn writer_loop(&self, conn: usize, gen: u64, mut stream: TcpStream, rx: Receiver<Vec<u8>>) {
        while let Ok(bytes) = rx.recv() {
            if stream.write_all(&bytes).is_err() {
                let _ = stream.shutdown(std::net::Shutdown::Both);
                let mut w = self.conns[conn].writer.lock().unwrap();
                if self.conns[conn].gen.load(Ordering::SeqCst) == gen {
                    *w = None;
                }
                return;
            }
        }
    }

    fn reader_loop(
        &self,
        conn: usize,
        peer_rank: usize,
        gen: u64,
        mut stream: TcpStream,
        initial: Vec<u8>,
    ) {
        let mut dec = FrameDecoder::new();
        dec.push(&initial);
        let mut chunk = vec![0u8; 64 * 1024];
        'stream: loop {
            // Drain everything decodable before the next blocking read.
            loop {
                match dec.next_frame_pooled(&self.pool) {
                    Ok(Some(f)) => self.dispatch_frame(peer_rank, f),
                    Ok(None) => break,
                    // Corrupt/desynced stream: there is no resync point
                    // in a length-prefixed protocol — drop the
                    // connection; the peer shows up as Disconnected.
                    Err(_) => break 'stream,
                }
            }
            match stream.read(&mut chunk) {
                Ok(0) | Err(_) => break,
                Ok(n) => dec.push(&chunk[..n]),
            }
        }
        // Only the generation that owns the slot may tear it down — a
        // reconnect may already have replaced the connection. The check
        // happens *under the writer lock* (attach bumps the generation
        // and installs the new writer under the same lock), so a dying
        // reader can never null out a freshly re-attached writer.
        {
            let mut w = self.conns[conn].writer.lock().unwrap();
            if self.conns[conn].gen.load(Ordering::SeqCst) != gen {
                return;
            }
            *w = None;
        }
        self.push_event(NetEvent::Disconnected { rank: peer_rank });
        if let Some(r) = self.shutdown_rank_on_eof {
            // Worker side: unblock the blocking server loop so the
            // process exits instead of hanging on a dead fabric.
            let _ = self.senders[r].send(Message {
                src: COORD_SRC,
                tag: CTRL_SHUTDOWN,
                payload: vec![],
            });
        }
    }

    fn dispatch_frame(&self, peer_rank: usize, f: Frame) {
        match f.kind {
            FrameKind::Msg => {
                if (f.epoch != 0 || f.trace != 0) && is_task_tag(f.tag) {
                    if self.shutdown_rank_on_eof.is_some() {
                        // Worker side: remember the request's wave and
                        // trace stamps so the response echoes them.
                        // Bounded hygiene: a task whose response never
                        // leaves (cancelled, dead window) would
                        // otherwise pin its entry for the life of the
                        // run.
                        let mut echo = self.echo.lock().unwrap();
                        if echo.len() > 65_536 {
                            echo.clear();
                        }
                        echo.insert(f.tag, (f.wave, f.epoch, f.trace));
                    } else {
                        // Coordinator side: a response stamped with an
                        // epoch older than the current wave's belongs to
                        // work already re-scoped by a mid-wave fault.
                        let cur = self.wave_stamp.load(Ordering::SeqCst) >> 8;
                        if f.epoch != 0 && cur != 0 && f.epoch < cur {
                            self.stale_epoch_frames.fetch_add(1, Ordering::SeqCst);
                        }
                        // An echoed trace names the dispatch hop this
                        // response answers — collected for the lineage
                        // recorder regardless of staleness (the stale
                        // path is exactly the interesting one).
                        if f.trace != 0 {
                            self.trace_echoes.lock().unwrap().push((f.tag, f.trace));
                        }
                    }
                }
                let dst = f.dst as usize;
                if dst < self.senders.len() {
                    let _ = self.senders[dst].send(f.into_message());
                }
            }
            FrameKind::Hello => self.push_event(NetEvent::Hello { rank: peer_rank }),
            FrameKind::Heartbeat => {
                let seq = f.payload.first().map(|w| w.to_bits() as u64).unwrap_or(0);
                self.push_event(NetEvent::Heartbeat { rank: peer_rank, at: Instant::now(), seq });
            }
            FrameKind::Drain => self.push_event(NetEvent::DrainRequest { rank: peer_rank }),
            FrameKind::Goodbye => self.push_event(NetEvent::Goodbye { rank: peer_rank }),
            FrameKind::Stats => {
                self.push_event(NetEvent::Stats { rank: peer_rank, payload: f.payload })
            }
            // CONFIG is consumed during the handshake, before the
            // transport owns the stream; a late one is ignored.
            FrameKind::Config => {}
        }
    }

    fn push_event(&self, ev: NetEvent) {
        self.events.lock().unwrap().push_back(ev);
    }

    /// Drain all pending control-plane events.
    pub fn poll_events(&self) -> Vec<NetEvent> {
        self.events.lock().unwrap().drain(..).collect()
    }

    /// Whether connection slot `conn` currently has a live writer.
    pub fn is_connected(&self, conn: usize) -> bool {
        self.conns.get(conn).is_some_and(|c| c.writer.lock().unwrap().is_some())
    }

    /// Write a control frame over connection slot `conn`.
    pub fn send_frame(&self, conn: usize, frame: &Frame) -> Result<(), SendError> {
        self.write_frame(conn, frame).map_err(|reason| SendError { dst: conn, reason })
    }

    /// Enqueue an encoded frame onto the connection's writer thread.
    /// Non-blocking: the caller returns as soon as the bytes are
    /// queued. A down connection (no handle, or a writer that already
    /// died on a broken pipe) fails fast; bytes queued just before a
    /// peer death are lost with the socket — exactly the in-flight
    /// window the gather's deadline re-dispatch recovers.
    fn write_frame(&self, conn: usize, frame: &Frame) -> Result<(), String> {
        let bytes = frame.encode().map_err(|e| e.to_string())?;
        let Some(slot) = self.conns.get(conn) else {
            return Err(format!("no connection slot {conn}"));
        };
        let guard = slot.writer.lock().unwrap();
        let Some(handle) = guard.as_ref() else {
            return Err("connection down".to_string());
        };
        handle
            .tx
            .send(bytes)
            .map_err(|_| "connection down (writer exited)".to_string())
    }

    /// Apply the current stamp policy to an outbound data frame: the
    /// worker echoes the request's stamp onto its response; the
    /// coordinator stamps with the wave currently being dispatched.
    fn stamp_outbound(&self, f: &mut Frame) {
        if !is_task_tag(f.tag) {
            return;
        }
        if self.shutdown_rank_on_eof.is_some() {
            if let Some((wave, epoch, trace)) = self.echo.lock().unwrap().remove(&f.tag) {
                f.wave = wave;
                f.epoch = epoch;
                f.trace = trace;
            }
        } else {
            let packed = self.wave_stamp.load(Ordering::SeqCst);
            if packed != 0 {
                f.wave = (packed & 0xFF) as u8;
                f.epoch = packed >> 8;
            }
            // Consume the per-send trace stamp: a failover re-send sets
            // a fresh one, so a leftover id can never leak onto it.
            if let Some(trace) = self.trace_stamp.lock().unwrap().remove(&f.tag) {
                f.trace = trace;
            }
        }
    }

    /// Responses observed (since the last call) whose echoed epoch
    /// predated the then-current wave stamp — the wire-visible count of
    /// work outrun by a mid-wave membership change.
    pub fn take_stale_epoch_frames(&self) -> u64 {
        self.stale_epoch_frames.swap(0, Ordering::SeqCst)
    }

    /// Hard-close connection slot `conn` (the peer sees EOF). Used by
    /// the `--connect` fault backend, where there is no child process
    /// to SIGKILL. Dropping the handle also ends the writer thread.
    pub fn close_conn(&self, conn: usize) {
        if let Some(slot) = self.conns.get(conn) {
            if let Some(h) = slot.writer.lock().unwrap().take() {
                let _ = h.stream.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Transport for TcpTransport {
    fn n_ranks(&self) -> usize {
        self.n_ranks
    }

    fn send(&self, dst: usize, msg: Message) -> Result<(), SendError> {
        match self.route.get(dst).copied().flatten() {
            None => {
                let Some(tx) = self.senders.get(dst) else {
                    return Err(SendError {
                        dst,
                        reason: format!("rank out of range (fabric has {})", self.n_ranks),
                    });
                };
                tx.send(msg)
                    .map_err(|_| SendError { dst, reason: "local receiver dropped".into() })
            }
            Some(conn) => {
                let mut frame = Frame::msg(dst, msg);
                self.stamp_outbound(&mut frame);
                self.write_frame(conn, &frame).map_err(|reason| SendError { dst, reason })
            }
        }
    }

    fn recv(&self, rank: usize) -> Message {
        match self.receivers[rank].lock().unwrap().recv() {
            Ok(m) => m,
            // The fabric was torn down around a blocked receive (pool
            // shutdown racing a gather): exit through the orderly
            // shutdown path instead of aborting the process.
            Err(_) => shutdown_sentinel(),
        }
    }

    fn try_recv(&self, rank: usize) -> Option<Message> {
        self.receivers[rank].lock().unwrap().try_recv().ok()
    }

    fn try_recv_for(&self, rank: usize, timeout: Duration) -> Option<Message> {
        match self.receivers[rank].lock().unwrap().recv_timeout(timeout) {
            Ok(m) => Some(m),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(shutdown_sentinel()),
        }
    }

    fn set_wave_stamp(&self, wave: usize, epoch: u64) {
        self.wave_stamp.store((epoch << 8) | (wave as u64 & 0xFF), Ordering::SeqCst);
    }

    fn set_trace_stamp(&self, tag: u64, trace: u64) {
        let mut stamps = self.trace_stamp.lock().unwrap();
        // Same bounded hygiene as the worker echo map: entries for
        // sends that failed before stamping must not pin memory.
        if stamps.len() > 65_536 {
            stamps.clear();
        }
        stamps.insert(tag, trace);
    }

    fn take_trace_echoes(&self) -> Vec<(u64, u64)> {
        std::mem::take(&mut *self.trace_echoes.lock().unwrap())
    }

    fn recycle_payload(&self, buf: Vec<f32>) {
        self.pool.put(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Two coordinator-side transports wired back-to-back would need a
    /// worker loop; here we just check framing over a real socket pair:
    /// coordinator → worker data, worker → home data, and EOF events.
    #[test]
    fn socket_pair_carries_messages_and_eof_events() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let n = 2;

        let coord = TcpTransport::coordinator(n);
        let dial = TcpStream::connect(addr).unwrap();
        let (accepted, _) = listener.accept().unwrap();
        TcpTransport::attach(&coord, 0, 0, dial, &[]).unwrap();
        let worker = TcpTransport::worker(0, n, accepted, &[]).unwrap();

        // Coordinator → worker rank 0.
        coord
            .send(0, Message { src: usize::MAX, tag: 42, payload: vec![1.5, -2.0] })
            .unwrap();
        let got = worker.recv(0);
        assert_eq!(got.src, usize::MAX);
        assert_eq!(got.tag, 42);
        assert_eq!(got.payload, vec![1.5, -2.0]);

        // Worker → home queue n + 1 on the coordinator.
        worker.send(n + 1, Message { src: 0, tag: 7, payload: vec![3.0] }).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let back = loop {
            if let Some(m) = coord.try_recv(n + 1) {
                break m;
            }
            assert!(std::time::Instant::now() < deadline, "timed out");
            std::thread::sleep(std::time::Duration::from_millis(1));
        };
        assert_eq!(back.src, 0);
        assert_eq!(back.tag, 7);

        // Coordinator closes: the worker's inbox gets the shutdown
        // sentinel so a blocking server loop exits.
        coord.close_conn(0);
        let sentinel = worker.recv(0);
        assert_eq!(sentinel.tag, CTRL_SHUTDOWN);
        // And the worker-side disconnect is observable as an event.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        loop {
            if worker
                .poll_events()
                .iter()
                .any(|e| matches!(e, NetEvent::Disconnected { rank: 0 }))
            {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "no disconnect event");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        // Sends to the dead connection fail instead of panicking.
        assert!(worker.send(n + 1, Message { src: 0, tag: 1, payload: vec![] }).is_err());
    }

    /// Wave stamps ride the frame header: the coordinator stamps task
    /// frames with the current (wave, epoch), the worker echoes the
    /// request's stamp onto its response, and a response whose epoch
    /// predates the coordinator's current stamp is counted stale.
    #[test]
    fn wave_stamp_is_echoed_and_stale_epochs_counted() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let n = 2;

        let coord = TcpTransport::coordinator(n);
        let dial = TcpStream::connect(addr).unwrap();
        let (accepted, _) = listener.accept().unwrap();
        TcpTransport::attach(&coord, 0, 0, dial, &[]).unwrap();
        let worker = TcpTransport::worker(0, n, accepted, &[]).unwrap();

        // Ping wave under epoch 5: the task frame to rank 0 is stamped.
        coord.set_wave_stamp(0, 5);
        coord.send(0, Message { src: usize::MAX, tag: 100, payload: vec![1.0] }).unwrap();
        let req = worker.recv(0);
        assert_eq!(req.tag, 100);

        // A mid-wave fault advances the epoch before the response
        // lands: anything echoing epoch 5 is now stale.
        coord.set_wave_stamp(1, 6);
        worker.send(n, Message { src: 0, tag: 100, payload: vec![2.0] }).unwrap();
        let resp = coord
            .try_recv_for(n, Duration::from_secs(5))
            .expect("response did not arrive");
        assert_eq!(resp.tag, 100);
        assert_eq!(resp.payload, vec![2.0]);
        assert_eq!(coord.take_stale_epoch_frames(), 1, "echoed epoch 5 < current 6");
        assert_eq!(coord.take_stale_epoch_frames(), 0, "counter drains on take");

        // Control traffic is never stamped, so it is never stale.
        worker
            .send(n, Message { src: 0, tag: CTRL_SHUTDOWN, payload: vec![] })
            .unwrap();
        let ctrl = coord
            .try_recv_for(n, Duration::from_secs(5))
            .expect("control frame did not arrive");
        assert_eq!(ctrl.tag, CTRL_SHUTDOWN);
        assert_eq!(coord.take_stale_epoch_frames(), 0);
    }

    /// Trace stamps ride the DCA3 frame header: the coordinator stamps
    /// the next send of a tag with the dispatch's trace id, the worker
    /// echoes the request's trace onto its response, and the
    /// coordinator collects the `(tag, trace)` echo — even for flat
    /// (epoch-0) ticks, where wave stamping is inactive.
    #[test]
    fn trace_stamp_is_echoed_and_collected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let n = 2;

        let coord = TcpTransport::coordinator(n);
        let dial = TcpStream::connect(addr).unwrap();
        let (accepted, _) = listener.accept().unwrap();
        TcpTransport::attach(&coord, 0, 0, dial, &[]).unwrap();
        let worker = TcpTransport::worker(0, n, accepted, &[]).unwrap();

        coord.set_trace_stamp(100, 77);
        coord.send(0, Message { src: usize::MAX, tag: 100, payload: vec![1.0] }).unwrap();
        let req = worker.recv(0);
        assert_eq!(req.tag, 100);

        worker.send(n, Message { src: 0, tag: 100, payload: vec![2.0] }).unwrap();
        let resp = coord
            .try_recv_for(n, Duration::from_secs(5))
            .expect("response did not arrive");
        assert_eq!(resp.tag, 100);
        assert_eq!(coord.take_trace_echoes(), vec![(100, 77)]);
        assert_eq!(coord.take_trace_echoes(), Vec::new(), "echoes drain on take");

        // The stamp is consumed by its send: an unstamped re-send of
        // the same tag goes out untraced and echoes nothing.
        coord.send(0, Message { src: usize::MAX, tag: 100, payload: vec![3.0] }).unwrap();
        let req2 = worker.recv(0);
        assert_eq!(req2.tag, 100);
        worker.send(n, Message { src: 0, tag: 100, payload: vec![4.0] }).unwrap();
        let _ = coord.try_recv_for(n, Duration::from_secs(5)).expect("second response");
        assert_eq!(coord.take_trace_echoes(), Vec::new());
    }

    /// Satellite fix: a receiver blocked in `recv` while the transport
    /// is dropped must get the shutdown sentinel, not a panic.
    #[test]
    fn blocked_recv_returns_shutdown_sentinel_when_fabric_drops() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let coord = TcpTransport::coordinator(1);
        let dial = TcpStream::connect(addr).unwrap();
        let (accepted, _) = listener.accept().unwrap();
        TcpTransport::attach(&coord, 0, 0, dial, &[]).unwrap();
        drop(accepted);

        // The home queue's senders live inside the transport itself, so
        // exercise the timeout path (the blocking-recv equivalent used
        // by the gather): nothing arrives, no panic, clean None.
        assert!(coord.try_recv_for(1, Duration::from_millis(50)).is_none());
    }
}
