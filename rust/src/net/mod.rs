//! Networked attention-server runtime: TCP transport, worker daemons,
//! and the soak/load harness.
//!
//! DistCA's attention servers are independent devices reached over a
//! fabric (§4.1 — NVSHMEM all-to-all on the paper's testbed). This
//! module gives the reproduction a **real connection boundary**:
//! attention servers run as separate OS processes speaking a
//! length-prefixed binary protocol over TCP, and the elastic
//! coordinator drives full ticks over the wire through the same
//! [`Transport`](crate::exchange::Transport) trait the in-process
//! channel fabric implements — `server/` message discipline and the
//! `elastic/` dispatch/gather/failover machinery run unmodified.
//!
//! * [`codec`] — the wire format: one frame per message, fixed header
//!   (magic, kind, dst, src, tag, element count) + f32 payload carried
//!   as verbatim bit patterns, so socket runs are *bit-exact* against
//!   channel runs. Incremental [`FrameDecoder`] tolerant of arbitrary
//!   read-boundary splits; truncated and oversized frames rejected
//!   with descriptive errors.
//! * [`transport`] — [`TcpTransport`]: the same `[0, n)` server /
//!   `[n, 2n)` home rank layout, with remote ranks behind framed
//!   sockets and a control-plane event queue (hello / heartbeat /
//!   drain / goodbye / disconnect).
//! * [`worker`] — the `distca worker` daemon: CONFIG/HELLO handshake,
//!   heartbeats, then [`crate::elastic::run_server_loop`] over TCP.
//! * [`serve`] — the `distca serve` / `distca soak` coordinator
//!   front-end: spawns (or connects to) worker processes, replays
//!   seeded document-length mixes, plans with believed speeds,
//!   verifies every tick bit-exact vs the GQA oracle, and emits
//!   per-tick / per-server stats (`--stats-out` JSONL,
//!   `BENCH_net.json`).
//! * [`loopback`] — in-process workers over real localhost sockets:
//!   the hermetic harness the conformance suite uses for its `net`
//!   path.
//!
//! ## Connection lifecycle → fault kind
//!
//! The elastic fault model needs no new kinds — connection states map
//! onto it exactly:
//!
//! | connection observation | fault kind | recovery path |
//! |---|---|---|
//! | EOF without GOODBYE / failed send / stale heartbeats | `kill:` | pool kill → gather deadline → re-dispatch (max-headroom-first) |
//! | DRAIN frame from the worker | `drain:` | rank sits the tick out, leaves at tick end, daemon told to exit (the stock daemon does not yet originate DRAIN) |
//! | reconnection of a dead rank | `rejoin:` | restore + health/EWMA reset |
//!
//! The scripted fault injector gains a **process-level backend**:
//! under `distca serve --spawn`, a `kill:s@t` event SIGKILLs the
//! worker's OS process (the pool is *not* told — detection happens
//! over the wire, like a real crash), and `rejoin:s@t` respawns and
//! reconnects it. `slow:`/`drain:`/`oom:` events stay in-band,
//! identical to the threaded runtime.

pub mod codec;
pub mod loopback;
pub mod serve;
pub mod transport;
pub mod worker;

pub use codec::{CodecError, Frame, FrameDecoder, FrameKind};
pub use serve::{run_serve, NetRunReport, NetTickRecord, ServeCfg, NET_DIMS};
pub use transport::{NetEvent, TcpTransport};
pub use worker::{run_worker, serve_stream, WorkerCfg, WorkerConfig};
