//! In-process loopback harness: worker loops on threads, but every
//! byte crosses a **real localhost TCP socket** through the full
//! codec/transport stack. This is the hermetic middle ground between
//! the threaded `ChannelTransport` runtime and separate-process
//! workers — the conformance suite uses it to run the same seeded
//! `(docs, fault-plan)` cases bit-exact *over sockets* without needing
//! the `distca` binary on PATH.

use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::elastic::{ElasticCfg, ElasticCoordinator};
use crate::exchange::transport::Transport;

use super::codec::{Frame, FrameKind};
use super::transport::TcpTransport;
use super::worker::{serve_stream, WorkerConfig};

/// A live loopback worker pool: the coordinator-side fabric plus the
/// worker threads serving the other end of each socket.
pub struct LoopbackPool {
    pub fabric: Arc<TcpTransport>,
    pub n_servers: usize,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
}

/// Spawn `n` loopback workers (reference GQA compute with the given
/// dims), connect, handshake, and wait for every registration HELLO.
pub fn spawn_loopback_pool(
    n: usize,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
) -> Result<LoopbackPool> {
    assert!(n > 0);
    let fabric = TcpTransport::coordinator(n);
    let mut handles = Vec::with_capacity(n);
    for rank in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").context("binding loopback")?;
        let addr = listener.local_addr()?;
        handles.push(std::thread::spawn(move || {
            let (stream, _) = listener.accept().context("loopback accept")?;
            serve_stream(stream)
        }));
        let stream = TcpStream::connect(addr).context("dialing loopback worker")?;
        TcpTransport::attach(&fabric, rank, rank, stream, &[])?;
        let cfg = WorkerConfig {
            rank,
            n_servers: n,
            n_heads,
            n_kv_heads,
            head_dim,
            // No heartbeats: nothing drains the event queue during a
            // conformance case (liveness policy lives in serve), and
            // 20 beats/s/worker would grow it for the whole run.
            hb_interval: Duration::ZERO,
        };
        fabric
            .send_frame(rank, &Frame::control(FrameKind::Config, usize::MAX, cfg.to_payload()))
            .map_err(|e| anyhow::anyhow!("CONFIG to worker {rank}: {e}"))?;
    }
    // Registration barrier: every worker must HELLO before the first
    // dispatch, or early sends could race the handshake. Same wait as
    // the process path (`serve::wait_hello`); the queued non-HELLO
    // events (heartbeats) are discarded — the loopback harness
    // exercises the data path, liveness policy lives in serve.
    let mut pending = Vec::new();
    for rank in 0..n {
        super::serve::wait_hello(&fabric, rank, &mut pending, Duration::from_secs(10))?;
    }
    Ok(LoopbackPool { fabric, n_servers: n, handles })
}

impl LoopbackPool {
    /// An elastic coordinator driving ticks over this pool's sockets.
    pub fn coordinator(&self, cfg: ElasticCfg) -> ElasticCoordinator {
        let fabric: Arc<dyn Transport> = Arc::clone(&self.fabric) as Arc<dyn Transport>;
        ElasticCoordinator::over_transport(fabric, self.n_servers, cfg)
    }

    /// Join every worker thread (call after the coordinator's
    /// `shutdown()` has broadcast `CTRL_SHUTDOWN`).
    pub fn join(self) -> Result<()> {
        for h in self.handles {
            h.join().map_err(|_| anyhow::anyhow!("loopback worker thread panicked"))??;
        }
        Ok(())
    }
}
