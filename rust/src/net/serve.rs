//! `distca serve` / `distca soak` — the networked coordinator.
//!
//! Drives full elastic ticks over a pool of **separate worker
//! processes** (`--spawn`: children of this process, SIGKILL-able by
//! the fault injector) or externally started daemons (`--connect
//! a,b,c`). Each tick samples a document-length mix from
//! [`crate::data::distributions`], plans with the live pool's
//! believed speeds, dispatches over TCP, and verifies every output
//! **bit-exact** against the pure-Rust GQA oracle — recovery from a
//! mid-run SIGKILL must be invisible in the outputs.
//!
//! ## Connection lifecycle → fault kind
//!
//! | observed | mapped to |
//! |---|---|
//! | connection EOF without GOODBYE, failed send, stale heartbeats | `kill:` (pool kill + re-dispatch) |
//! | DRAIN frame from a worker | `drain:` (graceful leave) |
//! | reconnection of a dead rank | `rejoin:` (restore + health reset) |
//!
//! Scripted `kill:`/`rejoin:` events are executed at the **process
//! level** (`--spawn`: the child is SIGKILLed / respawned; the pool is
//! *not* told — failure must be detected over the wire, like a real
//! crash). `slow:`/`drain:`/`oom:` events stay in-band through the
//! elastic tick path, identical to the threaded runtime.

use std::collections::BTreeMap;
use std::io::Write;
use std::net::{TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::run::DataDist;
use crate::data::distributions::sampler_for;
use crate::elastic::{
    ElasticCfg, ElasticCoordinator, ElasticTask, FaultEvent, FaultPlan, HealthCfg,
    HealthMonitor, ReferenceCaCompute, ServerState,
};
use crate::elastic::failover::{COORD_SRC, CTRL_SHUTDOWN};
use crate::exchange::transport::{Message, Transport};
use crate::obs::export::MetricsHub;
use crate::obs::{trace, Phase, Recorder};
use crate::runtime::ca_exec::synthetic_task;
use crate::server::header_usize;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::codec::{Frame, FrameKind};
use super::transport::{NetEvent, TcpTransport};
use super::worker::{WorkerConfig, STATS_DROPPED_MARKER};

/// Attention dims of the networked reference compute — kept equal to
/// the threaded CLI demo so cross-path comparisons are like-for-like.
pub const NET_DIMS: (usize, usize, usize) = (4, 2, 16);

/// Everything a serve/soak run needs.
#[derive(Debug, Clone)]
pub struct ServeCfg {
    /// Pool size (== worker process count).
    pub workers: usize,
    /// Spawn local `distca worker` children (required for scripted
    /// SIGKILL/respawn faults).
    pub spawn: bool,
    /// Worker addresses when not spawning (len == `workers`).
    pub connect: Vec<String>,
    pub ticks: usize,
    /// Documents sampled per tick.
    pub docs_per_tick: usize,
    pub seed: u64,
    pub data: DataDist,
    pub max_doc: usize,
    /// Scripted faults: kills/rejoins run at the process level,
    /// slows/drains/ooms in-band.
    pub fault: FaultPlan,
    /// Run each tick as two ping-pong nano-batch waves over the wire
    /// (§4.3): pipelined sends overlap the gather, frames carry
    /// wave-scoped epoch stamps, and scripted SIGKILLs land *mid-wave*
    /// (at the ping→pong boundary) instead of at tick start.
    pub pp: bool,
    /// Per-server per-tick JSONL stats sink.
    pub stats_out: Option<PathBuf>,
    /// Soak summary JSON (`BENCH_net.json`).
    pub bench_out: Option<PathBuf>,
    /// Chrome/Perfetto `trace_event` trace sink: arms the wall-clock
    /// [`Recorder`] on the coordinator, assembles worker STATS frames
    /// into the cluster-wide timeline, and writes the trace at
    /// shutdown. `distca report <file>` renders it.
    pub trace_out: Option<PathBuf>,
    /// Worker heartbeat interval (zero disables heartbeats).
    pub hb_interval: Duration,
    /// Beats older than this mark a schedulable worker dead (zero
    /// disables the staleness check).
    pub hb_timeout: Duration,
    /// Bind a live Prometheus-text `/metrics` endpoint here (e.g.
    /// `127.0.0.1:9464`; port 0 = kernel-assigned). Arms the recorder
    /// (like `--trace-out`) and feeds a [`MetricsHub`] with live
    /// counters + latency histograms; `distca top` renders it.
    pub metrics_listen: Option<String>,
}

/// One tick's accounting, network-level fields included.
#[derive(Debug, Clone)]
pub struct NetTickRecord {
    pub tick: usize,
    pub n_alive: usize,
    pub n_tasks: usize,
    /// Gather-deadline re-dispatches (includes SIGKILL recovery).
    pub redispatched: usize,
    /// Tasks failed over at send time (dead connection discovered
    /// while dispatching).
    pub send_failovers: usize,
    /// Tasks remapped pre-dispatch off departed servers.
    pub remapped: usize,
    /// Ranks killed this tick from connection evidence (EOF without
    /// goodbye, stale heartbeats).
    pub connection_kills: usize,
    /// Scripted SIGKILLs applied this tick (`--pp`: at the ping→pong
    /// wave boundary; flat ticks: at tick start).
    pub process_kills: usize,
    /// Rejoins applied this tick: scripted respawn+reconnects, plus
    /// wire re-HELLOs from dead `--connect` ranks whose daemons came
    /// back.
    pub rejoins: usize,
    /// Total wire bytes dispatched (tensors, recovery included).
    pub bytes_dispatched: f64,
    /// Peak per-server dispatched bytes (arena-pressure proxy).
    pub peak_server_bytes: f64,
    /// Membership epochs the (ping, pong) waves were stamped under.
    /// Flat ticks use only the ping slot; a mid-wave kill shows as
    /// `ping < pong`.
    pub wave_epochs: [u64; 2],
    /// Gather re-dispatches attributed to each wave.
    pub wave_redispatched: [usize; 2],
    /// Completions gathered while a wave was still being encoded and
    /// shipped — the comm/compute overlap as a count.
    pub overlap_gathered: usize,
    /// Responses whose echoed wire epoch predated the current wave
    /// stamp ([`TcpTransport::take_stale_epoch_frames`]).
    pub stale_wave_frames: u64,
    /// Connection drops turned into membership fact at the wave
    /// boundary (mid-wave SIGKILL evidence).
    pub mid_wave_kills: usize,
    /// Worker-measured kernel seconds summed over this tick's tasks
    /// (filled post-run from the recorder; 0 when no recorder ran).
    pub compute_s: f64,
    /// Server busy-window time not covered by compute — wire + queue
    /// (filled post-run from the recorder; 0 when no recorder ran).
    pub wire_wait_s: f64,
    /// `compute / (compute + wire_wait)` — the measured Fig. 11
    /// overlap efficiency for this tick (1.0 when nothing measured).
    pub overlap_efficiency: f64,
    /// Wall-clock seconds from dispatch to full gather (makespan).
    pub elapsed: f64,
    /// Query tokens processed this tick (Σ q_len over dispatched
    /// tasks). Deliberately *not* serialized per-tick — the seeded
    /// lengths would make the committed `BENCH_net.json` baseline
    /// impossible to hand-audit; only the run-wide end-to-end
    /// `tokens_per_s` rate is emitted (wall-clock-exempt in drift).
    pub tokens: usize,
}

impl NetTickRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tick", Json::Num(self.tick as f64)),
            ("alive", Json::Num(self.n_alive as f64)),
            ("tasks", Json::Num(self.n_tasks as f64)),
            ("redispatched", Json::Num(self.redispatched as f64)),
            ("send_failovers", Json::Num(self.send_failovers as f64)),
            ("remapped", Json::Num(self.remapped as f64)),
            ("connection_kills", Json::Num(self.connection_kills as f64)),
            ("process_kills", Json::Num(self.process_kills as f64)),
            ("rejoins", Json::Num(self.rejoins as f64)),
            ("bytes_dispatched", Json::Num(self.bytes_dispatched)),
            ("peak_server_bytes", Json::Num(self.peak_server_bytes)),
            ("wave_epoch_ping", Json::Num(self.wave_epochs[0] as f64)),
            ("wave_epoch_pong", Json::Num(self.wave_epochs[1] as f64)),
            ("wave_redispatched_ping", Json::Num(self.wave_redispatched[0] as f64)),
            ("wave_redispatched_pong", Json::Num(self.wave_redispatched[1] as f64)),
            ("overlap_gathered", Json::Num(self.overlap_gathered as f64)),
            ("stale_wave_frames", Json::Num(self.stale_wave_frames as f64)),
            ("mid_wave_kills", Json::Num(self.mid_wave_kills as f64)),
            ("compute_s", Json::Num(self.compute_s)),
            ("wire_wait_s", Json::Num(self.wire_wait_s)),
            ("overlap_efficiency", Json::Num(self.overlap_efficiency)),
            ("makespan_s", Json::Num(self.elapsed)),
        ])
    }
}

/// Aggregate outcome of a serve/soak run. `Ok` means every output of
/// every tick matched the monolithic oracle bit-for-bit.
#[derive(Debug, Clone)]
pub struct NetRunReport {
    pub workers: usize,
    pub seed: u64,
    /// Whether the run executed ticks as ping-pong waves (`--pp`).
    pub pp: bool,
    pub per_tick: Vec<NetTickRecord>,
    pub total_redispatched: usize,
    pub total_send_failovers: usize,
    pub total_connection_kills: usize,
    pub total_process_kills: usize,
    pub total_rejoins: usize,
    /// Completions gathered while a wave was still being dispatched,
    /// summed over the run.
    pub total_overlap_gathered: usize,
    /// Stale-epoch responses observed on the wire, summed over the run.
    pub total_stale_wave_frames: u64,
    /// Run-wide `Σcompute / Σ(compute + wire_wait)` (1.0 when no
    /// recorder measured the split).
    pub overlap_efficiency: f64,
    /// End-to-end throughput: Σ query tokens over all ticks divided by
    /// Σ tick makespans — the soak summary's tokens/sec line. Wall
    /// clock, so exempt from the drift gate's numeric comparison.
    pub tokens_per_s: f64,
}

impl NetRunReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str("net_soak".into())),
            ("workers", Json::Num(self.workers as f64)),
            ("ticks", Json::Num(self.per_tick.len() as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("pp", Json::Bool(self.pp)),
            ("bit_exact", Json::Bool(true)),
            ("total_redispatched", Json::Num(self.total_redispatched as f64)),
            ("total_send_failovers", Json::Num(self.total_send_failovers as f64)),
            ("total_connection_kills", Json::Num(self.total_connection_kills as f64)),
            ("total_process_kills", Json::Num(self.total_process_kills as f64)),
            ("total_rejoins", Json::Num(self.total_rejoins as f64)),
            ("total_overlap_gathered", Json::Num(self.total_overlap_gathered as f64)),
            ("total_stale_wave_frames", Json::Num(self.total_stale_wave_frames as f64)),
            ("overlap_efficiency", Json::Num(self.overlap_efficiency)),
            ("tokens_per_s", Json::Num(self.tokens_per_s)),
            ("per_tick", Json::Arr(self.per_tick.iter().map(|r| r.to_json()).collect())),
        ])
    }
}

// ---------------------------------------------------------------------
// Worker process management (the fault injector's process backend).
// ---------------------------------------------------------------------

/// `pub(crate)` so the multi-tenant gateway ([`crate::gateway`]) reuses
/// the exact process backend — same SIGKILL semantics, same leak-free
/// shutdown — instead of growing a second one.
pub(crate) struct WorkerProcs {
    spawn: bool,
    dir: PathBuf,
    addrs: Vec<String>,
    children: Vec<Option<Child>>,
}

impl WorkerProcs {
    pub(crate) fn start(spawn: bool, n: usize, connect: &[String]) -> Result<WorkerProcs> {
        if spawn {
            let dir = std::env::temp_dir().join(format!("distca-net-{}", std::process::id()));
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating {}", dir.display()))?;
            let mut procs = WorkerProcs {
                spawn,
                dir,
                addrs: vec![String::new(); n],
                children: (0..n).map(|_| None).collect(),
            };
            for i in 0..n {
                procs.spawn_one(i)?;
            }
            Ok(procs)
        } else {
            anyhow::ensure!(
                connect.len() == n,
                "--connect lists {} addresses for {n} workers",
                connect.len()
            );
            Ok(WorkerProcs {
                spawn,
                dir: std::env::temp_dir(),
                addrs: connect.to_vec(),
                children: (0..n).map(|_| None).collect(),
            })
        }
    }

    /// Spawn worker `i` as a child of this process (`distca worker
    /// --listen 127.0.0.1:0 --port-file …`) and wait for it to publish
    /// its kernel-assigned address. Any previous incarnation of slot
    /// `i` is SIGKILLed and reaped first — a scripted `rejoin:` of a
    /// still-live worker must never leak the old OS process (dropping
    /// a `Child` does not kill it).
    fn spawn_one(&mut self, i: usize) -> Result<()> {
        if let Some(old) = self.children[i].as_mut() {
            let _ = old.kill();
            let _ = old.wait();
            self.children[i] = None;
        }
        let port_file = self.dir.join(format!("worker{i}.port"));
        let _ = std::fs::remove_file(&port_file);
        let exe = std::env::current_exe().context("resolving distca binary path")?;
        let child = Command::new(&exe)
            .arg("worker")
            .arg("--listen")
            .arg("127.0.0.1:0")
            .arg("--port-file")
            .arg(&port_file)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .with_context(|| format!("spawning worker {i}"))?;
        self.children[i] = Some(child);
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            if let Ok(addr) = std::fs::read_to_string(&port_file) {
                let addr = addr.trim().to_string();
                if !addr.is_empty() {
                    self.addrs[i] = addr;
                    return Ok(());
                }
            }
            if let Some(c) = self.children[i].as_mut() {
                if let Ok(Some(status)) = c.try_wait() {
                    anyhow::bail!("worker {i} exited during startup ({status})");
                }
            }
            anyhow::ensure!(
                Instant::now() < deadline,
                "worker {i} never published its address"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    pub(crate) fn addr(&self, i: usize) -> &str {
        &self.addrs[i]
    }

    /// The process-level `kill:` backend: SIGKILL the child. The pool
    /// is deliberately *not* informed — detection must happen over the
    /// wire, like a real crash. A worker that already exited on its
    /// own satisfies the fault vacuously (the elastic machinery exists
    /// to recover from exactly that); any connection remnant is
    /// severed either way.
    pub(crate) fn kill(&mut self, i: usize, fabric: &TcpTransport) {
        if let Some(child) = self.children[i].as_mut() {
            let _ = child.kill();
            let _ = child.wait(); // reap the zombie
            self.children[i] = None;
        }
        // --connect mode (no child), or belt-and-braces after SIGKILL:
        // the peer — if any is left — sees EOF, this side sees a dead
        // rank; the same observable fault in every case.
        fabric.close_conn(i);
    }

    pub(crate) fn respawn(&mut self, i: usize) -> Result<()> {
        anyhow::ensure!(
            self.spawn,
            "rejoin:{i} needs --spawn (cannot restart a remote worker daemon)"
        );
        self.spawn_one(i)
    }

    /// Reap every child after the shutdown broadcast; hard-kill
    /// stragglers and report them — a clean run leaks nothing.
    pub(crate) fn shutdown(&mut self) -> Result<()> {
        let deadline = Instant::now() + Duration::from_secs(10);
        let mut hard_killed = 0usize;
        for (i, slot) in self.children.iter_mut().enumerate() {
            if let Some(child) = slot.as_mut() {
                loop {
                    match child.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_millis(20))
                        }
                        _ => {
                            let _ = child.kill();
                            let _ = child.wait();
                            hard_killed += 1;
                            eprintln!("worker {i} did not exit; hard-killed");
                            break;
                        }
                    }
                }
            }
            *slot = None;
        }
        if self.spawn {
            let _ = std::fs::remove_dir_all(&self.dir);
        }
        anyhow::ensure!(
            hard_killed == 0,
            "{hard_killed} workers had to be hard-killed at shutdown"
        );
        Ok(())
    }
}

impl Drop for WorkerProcs {
    fn drop(&mut self) {
        // Abnormal exit: never leak child processes.
        for slot in self.children.iter_mut() {
            if let Some(child) = slot.as_mut() {
                let _ = child.kill();
                let _ = child.wait();
            }
            *slot = None;
        }
    }
}

// ---------------------------------------------------------------------
// The serve loop.
// ---------------------------------------------------------------------

/// Attach an already-dialed `stream` to the fabric as rank `rank` and
/// send the CONFIG handshake (the worker answers with HELLO).
fn attach_and_config(
    fabric: &Arc<TcpTransport>,
    rank: usize,
    n: usize,
    stream: TcpStream,
    hb_interval: Duration,
) -> Result<()> {
    TcpTransport::attach(fabric, rank, rank, stream, &[])?;
    let (h, hkv, d) = NET_DIMS;
    let cfg = WorkerConfig {
        rank,
        n_servers: n,
        n_heads: h,
        n_kv_heads: hkv,
        head_dim: d,
        hb_interval,
    };
    fabric
        .send_frame(rank, &Frame::control(FrameKind::Config, usize::MAX, cfg.to_payload()))
        .map_err(|e| anyhow::anyhow!("CONFIG to worker {rank}: {e}"))?;
    Ok(())
}

/// Dial `addr` (with a short retry window), attach it to the fabric as
/// rank `rank`, and send the CONFIG handshake.
pub(crate) fn connect_and_config(
    fabric: &Arc<TcpTransport>,
    rank: usize,
    n: usize,
    addr: &str,
    hb_interval: Duration,
) -> Result<()> {
    let deadline = Instant::now() + Duration::from_secs(5);
    let stream = loop {
        match TcpStream::connect(addr) {
            Ok(s) => break s,
            Err(e) => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "dialing worker {rank} at {addr}: {e}"
                );
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    };
    attach_and_config(fabric, rank, n, stream, hb_interval)
}

/// One short, non-retrying re-dial of a dead `--connect` rank's daemon
/// (the reconnect half of the over-the-wire `rejoin:` lifecycle — a
/// restarted daemon listens again, and only the coordinator can dial).
/// Returns whether a fresh connection was attached; the daemon's HELLO
/// then restores the rank through the event loop. A daemon that is
/// simply gone costs one bounded `connect_timeout` per tick, nothing
/// more.
fn try_redial(
    fabric: &Arc<TcpTransport>,
    rank: usize,
    n: usize,
    addr: &str,
    hb_interval: Duration,
) -> bool {
    let Ok(mut addrs) = addr.to_socket_addrs() else { return false };
    let Some(sa) = addrs.next() else { return false };
    let Ok(stream) = TcpStream::connect_timeout(&sa, Duration::from_millis(100)) else {
        return false;
    };
    attach_and_config(fabric, rank, n, stream, hb_interval).is_ok()
}

/// Append new transport events to `pending`.
pub(crate) fn drain_events(fabric: &TcpTransport, pending: &mut Vec<NetEvent>) {
    pending.extend(fabric.poll_events());
}

/// Decode one worker STATS frame — repeating 4-word groups
/// `[tick, tag_lo, tag_hi, dur_s]` — into the recorder's worker-side
/// compute observations. A trailing partial group (malformed sender) is
/// ignored rather than trusted. A [`STATS_DROPPED_MARKER`] sentinel
/// group carries the worker's count of span groups lost to a dead
/// connection; the count is returned (and mirrored to the `stats.
/// dropped` counter) so the serve loop can fold it into
/// `TickStats::stats_dropped`. Public so harnesses driving a
/// [`TcpTransport`] directly (loopback soaks, integration tests) reuse
/// the exact production decode path.
pub fn feed_stats(recorder: &Option<Arc<Recorder>>, rank: usize, payload: &[f32]) -> u64 {
    let Some(r) = recorder else { return 0 };
    let mut dropped = 0u64;
    for g in payload.chunks_exact(4) {
        let tick = header_usize(g[0]);
        if tick == STATS_DROPPED_MARKER {
            let count = (header_usize(g[2]) as u64) << 32 | header_usize(g[1]) as u64;
            dropped += count;
            r.counter("stats.dropped", count as f64);
            continue;
        }
        let tag = (header_usize(g[2]) as u64) << 32 | header_usize(g[1]) as u64;
        r.observe_compute(tick, tag, g[3] as f64);
    }
    r.counter(&format!("stats.frames.{rank}"), 1.0);
    dropped
}

/// Block until rank's HELLO arrives (leaving unrelated events queued).
/// `pub(crate)` so the loopback harness and the gateway share the exact
/// registration barrier the process path uses.
pub(crate) fn wait_hello(
    fabric: &TcpTransport,
    rank: usize,
    pending: &mut Vec<NetEvent>,
    timeout: Duration,
) -> Result<()> {
    let deadline = Instant::now() + timeout;
    loop {
        drain_events(fabric, pending);
        if let Some(pos) = pending
            .iter()
            .position(|e| matches!(e, NetEvent::Hello { rank: r } if *r == rank))
        {
            pending.remove(pos);
            return Ok(());
        }
        anyhow::ensure!(
            Instant::now() < deadline,
            "worker {rank} never registered (no HELLO)"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Split scripted faults: kills/rejoins execute at the process level,
/// everything else stays in-band through the elastic tick path.
pub(crate) fn split_fault_plan(plan: &FaultPlan) -> (FaultPlan, FaultPlan) {
    let mut process_plan = FaultPlan::new();
    let mut inband = FaultPlan::new();
    for ev in &plan.events {
        match *ev {
            FaultEvent::Kill { server, tick } => process_plan = process_plan.kill(server, tick),
            FaultEvent::Rejoin { server, tick } => {
                process_plan = process_plan.rejoin(server, tick)
            }
            FaultEvent::Slow { server, tick, factor } => {
                inband = inband.slow(server, tick, factor)
            }
            FaultEvent::Drain { server, tick } => inband = inband.drain(server, tick),
            FaultEvent::Oom { server, tick } => inband = inband.oom(server, tick),
        }
    }
    (process_plan, inband)
}

/// Sample one tick's CA-tasks from the document-length mix: each doc's
/// token length scales down to a reference-kernel-sized task (the
/// oracle is O(len²)), keeping the *shape* of the distribution — the
/// heavy tail lands on the wire as genuinely heavier frames.
fn sample_tick_tasks(
    rng: &mut Rng,
    tick: usize,
    cfg: &ServeCfg,
    alive: &[usize],
) -> Vec<ElasticTask> {
    let (h, hkv, d) = NET_DIMS;
    let sampler = sampler_for(cfg.data, cfg.max_doc);
    let scale = (cfg.max_doc / 128).max(1);
    let mut tasks = Vec::with_capacity(cfg.docs_per_tick);
    for j in 0..cfg.docs_per_tick {
        let len_tokens = sampler.sample_len(rng);
        let q_len = (len_tokens / scale).clamp(4, 256);
        let server = alive[j % alive.len()];
        tasks.push(ElasticTask {
            doc: (tick * 10_000 + j) as u32,
            q_start: 0,
            server,
            home: server,
            tensors: synthetic_task(rng, q_len, q_len, h, hkv, d),
        });
    }
    tasks
}

/// Bit-exactness: every gathered output must equal the monolithic
/// oracle's, bit for bit — recovery may change *who* computed a task,
/// never *what* it returned.
fn verify_outputs(
    tick: usize,
    tasks: &[ElasticTask],
    outputs: &[crate::server::TaskOutput],
    oracle: &ReferenceCaCompute,
) -> Result<()> {
    anyhow::ensure!(
        outputs.len() == tasks.len(),
        "tick {tick}: gathered {} of {} outputs",
        outputs.len(),
        tasks.len()
    );
    for out in outputs {
        let task = tasks
            .iter()
            .find(|t| t.doc == out.doc && t.q_start == out.q_start)
            .ok_or_else(|| anyhow::anyhow!("tick {tick}: unknown output doc {}", out.doc))?;
        let expect = oracle.run_batch(std::slice::from_ref(&task.tensors));
        anyhow::ensure!(
            out.o == expect[0],
            "tick {tick} doc {}: output diverged from the oracle over the wire",
            out.doc
        );
    }
    Ok(())
}

/// Run a full networked serve/soak session. Returns only if **every**
/// tick's outputs were bit-exact against the oracle and shutdown was
/// clean (all workers exited, none leaked).
pub fn run_serve(cfg: &ServeCfg) -> Result<NetRunReport> {
    let n = cfg.workers;
    anyhow::ensure!(n >= 2, "need at least 2 workers");
    anyhow::ensure!(cfg.ticks >= 1, "need at least 1 tick");
    anyhow::ensure!(
        cfg.spawn != !cfg.connect.is_empty(),
        "pass exactly one of --spawn or --connect a,b,c"
    );
    // Fail fast, not at the rejoin tick after a destructive kill has
    // already severed an externally owned daemon.
    anyhow::ensure!(
        cfg.spawn
            || !cfg.fault.events.iter().any(|e| matches!(e, FaultEvent::Rejoin { .. })),
        "scripted rejoin: requires --spawn (a remote daemon cannot be respawned)"
    );

    let fabric = TcpTransport::coordinator(n);
    let mut procs = WorkerProcs::start(cfg.spawn, n, &cfg.connect)?;
    for rank in 0..n {
        connect_and_config(&fabric, rank, n, procs.addr(rank), cfg.hb_interval)?;
    }
    let mut pending: Vec<NetEvent> = Vec::new();
    for rank in 0..n {
        wait_hello(&fabric, rank, &mut pending, Duration::from_secs(10))?;
    }

    let dyn_fabric: Arc<dyn Transport> = Arc::clone(&fabric) as Arc<dyn Transport>;
    let mut co = ElasticCoordinator::over_transport(dyn_fabric, n, ElasticCfg::default());
    // `--pp` always arms the recorder: the per-tick compute/wire-wait
    // split (the measured Fig. 11 number) is part of the bench output
    // even when no trace file is requested. `--metrics-listen` arms it
    // too — the live hub is fed through the recorder's mirrors.
    let recorder: Option<Arc<Recorder>> =
        (cfg.trace_out.is_some() || cfg.pp || cfg.metrics_listen.is_some())
            .then(Recorder::new_wall);
    if let Some(r) = &recorder {
        co.set_recorder(Arc::clone(r));
    }
    let hub = match (&recorder, &cfg.metrics_listen) {
        (Some(r), Some(addr)) => {
            let hub = MetricsHub::new();
            r.set_hub(Arc::clone(&hub));
            let bound = hub.serve(addr)?;
            println!("metrics: http://{bound}/metrics");
            Some(hub)
        }
        _ => None,
    };
    let (h, hkv, d) = NET_DIMS;
    let oracle = ReferenceCaCompute::new(h, hkv, d);
    let (process_plan, inband) = split_fault_plan(&cfg.fault);

    // Heartbeat EWMAs: inter-beat gaps per worker, the liveness-side
    // signal feeding membership (data-path latency EWMAs live in
    // `co.health` and feed gray demotion as usual).
    let mut hb_mon = HealthMonitor::new(n, HealthCfg::default());
    let mut last_beat: Vec<Option<Instant>> = vec![None; n];

    // Buffered: per-server rows every tick add up, and the final flush
    // record below guarantees nothing is lost at pool shutdown.
    let mut stats_file = match &cfg.stats_out {
        Some(p) => Some(std::io::BufWriter::new(
            std::fs::File::create(p).with_context(|| format!("creating {}", p.display()))?,
        )),
        None => None,
    };

    let mut records: Vec<NetTickRecord> = Vec::new();
    let mut rng = Rng::new(cfg.seed);
    // Ranks whose DRAIN request was honored this tick: they sit out the
    // tick (pool `Draining`), then leave at tick end and their daemons
    // are told to exit — the full `drain:` lifecycle over the wire.
    let mut drain_pending: Vec<usize> = Vec::new();

    for tick in 0..cfg.ticks {
        // 1. Scripted process-level faults. Under `--pp`, kills are
        // deferred to the ping→pong wave boundary (the SIGKILL must
        // land while the ping wave is genuinely in flight); rejoins
        // always run at tick start.
        let mut process_kills = 0usize;
        let mut rejoins = 0usize;
        let mut deferred_kills: Vec<usize> = Vec::new();
        for ev in process_plan.events_at(tick) {
            match ev {
                FaultEvent::Kill { server, .. } if server < n => {
                    if cfg.pp {
                        deferred_kills.push(server);
                    } else {
                        procs.kill(server, &fabric);
                        process_kills += 1;
                    }
                }
                FaultEvent::Rejoin { server, .. } if server < n => {
                    procs.respawn(server)?;
                    connect_and_config(&fabric, server, n, procs.addr(server), cfg.hb_interval)?;
                    wait_hello(&fabric, server, &mut pending, Duration::from_secs(10))?;
                    // Purge stale disconnect evidence from before the
                    // respawn — it must not kill the fresh worker.
                    pending.retain(
                        |e| !matches!(e, NetEvent::Disconnected { rank } if *rank == server),
                    );
                    co.pool.restore(server);
                    co.health.reset(server);
                    hb_mon.reset(server);
                    last_beat[server] = None;
                    // A restored rank must not carry a stale honored
                    // drain: it would be shut down again at tick end.
                    drain_pending.retain(|&r| r != server);
                    rejoins += 1;
                }
                _ => {}
            }
        }
        // Worker-dialed reconnect for `--connect` pools: a dead rank
        // whose daemon came back up gets one short re-dial per tick;
        // its re-HELLO below maps to restore + health reset (the same
        // `rejoin:` lifecycle `--spawn` pools get via respawn).
        if !cfg.spawn {
            for rank in 0..n {
                if co.pool.state(rank) == ServerState::Dead && !fabric.is_connected(rank) {
                    try_redial(&fabric, rank, n, procs.addr(rank), cfg.hb_interval);
                }
            }
        }

        // 2. Connection evidence → membership.
        let mut connection_kills = 0usize;
        let mut stats_dropped_tick = 0u64;
        drain_events(&fabric, &mut pending);
        for ev in pending.drain(..) {
            match ev {
                NetEvent::Disconnected { rank } => {
                    if rank < n && co.pool.is_schedulable(rank) {
                        co.pool.kill(rank);
                        co.health.mark_dead(rank);
                        connection_kills += 1;
                    }
                }
                NetEvent::Heartbeat { rank, at, .. } => {
                    if rank < n {
                        if let Some(prev) = last_beat[rank] {
                            hb_mon.observe(rank, (at - prev).as_secs_f64().max(0.0));
                        }
                        last_beat[rank] = Some(at);
                    }
                }
                NetEvent::DrainRequest { rank } => {
                    if rank < n && co.pool.is_schedulable(rank) {
                        co.pool.drain(rank);
                        drain_pending.push(rank);
                    }
                }
                NetEvent::Stats { rank, payload } => {
                    stats_dropped_tick += feed_stats(&recorder, rank, &payload);
                }
                // A re-HELLO on a dead rank is the worker-dialed rejoin
                // completing: the daemon came back (or was re-dialed
                // above) and re-registered. Restore it exactly like a
                // scripted rejoin. Draining ranks are left alone — an
                // honored drain must finish, not resurrect.
                NetEvent::Hello { rank } => {
                    if rank < n && co.pool.state(rank) == ServerState::Dead {
                        co.pool.restore(rank);
                        co.health.reset(rank);
                        hb_mon.reset(rank);
                        last_beat[rank] = None;
                        drain_pending.retain(|&r| r != rank);
                        rejoins += 1;
                    }
                }
                NetEvent::Goodbye { .. } => {}
            }
        }
        // Stale heartbeats without an EOF yet: suspect the worker dead.
        if cfg.hb_timeout > Duration::ZERO && cfg.hb_interval > Duration::ZERO {
            for s in 0..n {
                if co.pool.is_schedulable(s) {
                    if let Some(prev) = last_beat[s] {
                        if prev.elapsed() > cfg.hb_timeout {
                            co.pool.kill(s);
                            co.health.mark_dead(s);
                            connection_kills += 1;
                        }
                    }
                }
            }
        }

        let alive = co.pool.schedulable();
        anyhow::ensure!(!alive.is_empty(), "tick {tick}: no live workers");

        // 3–5. Sample, run over the wire, verify bit-exactness.
        let tasks = sample_tick_tasks(&mut rng, tick, cfg, &alive);
        let outputs = if cfg.pp {
            // Ping-pong waves. Scripted SIGKILLs land in the boundary
            // hook — between the ping dispatch and the pong stamp, while
            // the ping wave is genuinely in flight — and the EOF
            // evidence is waited for (bounded) so the kill is membership
            // fact before the pong wave plans: the ping stamp goes
            // stale, only its in-flight tasks re-dispatch, and
            // `wave_epochs[ping] < wave_epochs[pong]` deterministically.
            let mut boundary = || -> Vec<usize> {
                let mut dropped = Vec::new();
                for &server in &deferred_kills {
                    procs.kill(server, &fabric);
                    process_kills += 1;
                    let deadline = Instant::now() + Duration::from_secs(5);
                    loop {
                        drain_events(&fabric, &mut pending);
                        if let Some(pos) = pending.iter().position(
                            |e| matches!(e, NetEvent::Disconnected { rank } if *rank == server),
                        ) {
                            pending.remove(pos);
                            dropped.push(server);
                            break;
                        }
                        if Instant::now() >= deadline {
                            // No EOF evidence yet: the send-failover and
                            // gather-deadline paths still catch it.
                            break;
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                dropped
            };
            co.run_pp_tick_with_boundary(tick, &tasks, &inband, &mut boundary)?
        } else {
            co.run_tick(tick, &tasks, &inband)?
        };
        verify_outputs(tick, &tasks, &outputs, &oracle)?;
        let stale_wave_frames = fabric.take_stale_epoch_frames();
        // Worker-echoed DCA3 trace ids: which dispatch hop actually won
        // under first-response-wins dedup — the lineage's wire evidence.
        if let Some(r) = &recorder {
            for (tag, trace_id) in fabric.take_trace_echoes() {
                r.lineage_wire_echo(tick, tag, trace_id);
            }
        }
        // STATS groups a worker lost to a dead connection (reported via
        // the reconnect-flush sentinel) are this tick's accounting.
        if stats_dropped_tick > 0 {
            if let Some(st) = co.stats.last_mut() {
                st.stats_dropped += stats_dropped_tick;
            }
        }

        // 6. Accounting.
        let st = co.stats.last().expect("run_tick records stats").clone();
        if let Some(f) = stats_file.as_mut() {
            for s in 0..n {
                let row = Json::obj(vec![
                    ("tick", Json::Num(tick as f64)),
                    ("server", Json::Num(s as f64)),
                    (
                        "believed_speed",
                        Json::Num(if co.pool.is_schedulable(s) { co.pool.speed(s) } else { 0.0 }),
                    ),
                    ("schedulable", Json::Bool(co.pool.is_schedulable(s))),
                    (
                        "bytes_dispatched",
                        Json::Num(st.server_bytes.get(s).copied().unwrap_or(0.0)),
                    ),
                    (
                        "redispatched_to",
                        Json::Num(st.server_redispatched.get(s).copied().unwrap_or(0) as f64),
                    ),
                    (
                        "hb_ewma_s",
                        hb_mon.ewma(s).map(Json::Num).unwrap_or(Json::Null),
                    ),
                ]);
                writeln!(f, "{}", row.to_string_compact())
                    .context("writing --stats-out row")?;
            }
        }
        records.push(NetTickRecord {
            tick,
            n_alive: alive.len(),
            n_tasks: tasks.len(),
            redispatched: st.redispatched,
            send_failovers: st.send_failovers,
            remapped: st.remapped,
            connection_kills,
            process_kills,
            rejoins,
            bytes_dispatched: st.server_bytes.iter().sum(),
            peak_server_bytes: st.server_bytes.iter().cloned().fold(0.0, f64::max),
            wave_epochs: st.wave_epochs,
            wave_redispatched: st.wave_redispatched,
            overlap_gathered: st.overlap_gathered,
            stale_wave_frames,
            mid_wave_kills: st.mid_tick_disconnects,
            compute_s: 0.0,
            wire_wait_s: 0.0,
            overlap_efficiency: 1.0,
            elapsed: st.elapsed,
            tokens: tasks.iter().map(|t| t.tensors.q_len).sum(),
        });

        // Complete honored drains: the drainee sat the tick out, now it
        // leaves the pool and its daemon is told to exit. Its upcoming
        // Disconnected event is expected (the rank is Dead by then, so
        // it is not miscounted as a connection kill). A rank restored
        // since its drain was honored (rejoin, re-HELLO) is no longer
        // Draining and is skipped — an honored drain must never shut
        // down a freshly restored worker.
        for r in drain_pending.drain(..) {
            if co.pool.state(r) != ServerState::Draining {
                continue;
            }
            co.pool.leave(r);
            co.health.mark_dead(r);
            let _ = fabric.send(r, Message { src: COORD_SRC, tag: CTRL_SHUTDOWN, payload: vec![] });
        }
    }

    // The JSONL contract: a reader that sees the flush record knows the
    // file is complete, not truncated by a dying coordinator.
    if let Some(f) = stats_file.as_mut() {
        let row = Json::obj(vec![
            ("flush", Json::Bool(true)),
            ("ticks", Json::Num(cfg.ticks as f64)),
            ("rows", Json::Num((cfg.ticks * n) as f64)),
        ]);
        writeln!(f, "{}", row.to_string_compact()).context("writing --stats-out flush record")?;
        f.flush().context("flushing --stats-out")?;
    }

    // Orderly shutdown: broadcast CTRL_SHUTDOWN over the wire, then
    // reap every child — a clean run leaks nothing.
    co.shutdown()?;
    procs.shutdown()?;

    // The workers' final STATS flush rides ahead of their GOODBYE; give
    // the reader threads a bounded settle window to surface it, then
    // fold everything into the trace.
    if recorder.is_some() {
        let deadline = Instant::now() + Duration::from_secs(1);
        let mut quiet = 0usize;
        while Instant::now() < deadline && quiet < 3 {
            let before = pending.len();
            drain_events(&fabric, &mut pending);
            quiet = if pending.len() == before { quiet + 1 } else { 0 };
            std::thread::sleep(Duration::from_millis(20));
        }
        for ev in pending.drain(..) {
            if let NetEvent::Stats { rank, payload } = ev {
                feed_stats(&recorder, rank, &payload);
            }
        }
    }
    if let (Some(r), Some(path)) = (&recorder, &cfg.trace_out) {
        trace::write_trace(r, path)?;
        println!("wrote {}", path.display());
    }
    // Post-run quantile summary from the live hub — the same numbers
    // the /metrics endpoint served while the run was hot.
    if let Some(hub) = &hub {
        if let Some(h) = hub.hist("distca_task_latency_seconds") {
            let (p50, p95, p99) = h.p50_p95_p99();
            println!(
                "task latency over {} tasks: p50 {p50:.6}s p95 {p95:.6}s p99 {p99:.6}s",
                h.count()
            );
        }
    }

    // Per-tick compute vs wire-wait from the recorder's synthesized
    // spans (worker STATS refine the split where they arrived): the
    // measured overlap-efficiency column of `BENCH_net.json` — Fig. 11
    // on this testbed's wire.
    if let Some(r) = &recorder {
        let mut comp: BTreeMap<usize, f64> = BTreeMap::new();
        let mut wire: BTreeMap<usize, f64> = BTreeMap::new();
        for s in r.spans() {
            match s.phase {
                Phase::Compute => *comp.entry(s.tick).or_insert(0.0) += s.dur_s,
                Phase::WireWait => *wire.entry(s.tick).or_insert(0.0) += s.dur_s,
                _ => {}
            }
        }
        for rec in &mut records {
            let c = comp.get(&rec.tick).copied().unwrap_or(0.0);
            let w = wire.get(&rec.tick).copied().unwrap_or(0.0);
            rec.compute_s = c;
            rec.wire_wait_s = w;
            rec.overlap_efficiency = if c + w > 0.0 { c / (c + w) } else { 1.0 };
        }
    }

    let compute_total: f64 = records.iter().map(|r| r.compute_s).sum();
    let wire_total: f64 = records.iter().map(|r| r.wire_wait_s).sum();
    let tokens_total: f64 = records.iter().map(|r| r.tokens as f64).sum();
    let makespan_total: f64 = records.iter().map(|r| r.elapsed).sum();
    let report = NetRunReport {
        workers: n,
        seed: cfg.seed,
        pp: cfg.pp,
        total_redispatched: records.iter().map(|r| r.redispatched).sum(),
        total_send_failovers: records.iter().map(|r| r.send_failovers).sum(),
        total_connection_kills: records.iter().map(|r| r.connection_kills).sum(),
        total_process_kills: records.iter().map(|r| r.process_kills).sum(),
        total_rejoins: records.iter().map(|r| r.rejoins).sum(),
        total_overlap_gathered: records.iter().map(|r| r.overlap_gathered).sum(),
        total_stale_wave_frames: records.iter().map(|r| r.stale_wave_frames).sum(),
        overlap_efficiency: if compute_total + wire_total > 0.0 {
            compute_total / (compute_total + wire_total)
        } else {
            1.0
        },
        tokens_per_s: if makespan_total > 0.0 { tokens_total / makespan_total } else { 0.0 },
        per_tick: records,
    };
    if let Some(path) = &cfg.bench_out {
        std::fs::write(path, report.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
    }
    Ok(report)
}
