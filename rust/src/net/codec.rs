//! Length-prefixed binary framing for the TCP fabric.
//!
//! One frame carries one [`Message`] (or one control event) with the
//! exact payload the in-process paths use: a `Vec<f32>` whose leading
//! words are *bit-cast* u32 headers ([`crate::server`]'s
//! `header_word` scheme — exact beyond 2^24, the PR-1 regression
//! class). The codec preserves every f32 **bit pattern** verbatim, so
//! a tick that runs over sockets is byte-identical to one that runs
//! over channels.
//!
//! ## Wire format (little-endian)
//!
//! | offset | size | field |
//! |---|---|---|
//! | 0 | 4 | magic `0x44434133` (`"3ACD"` on the wire — `"DCA3"` read big-endian) |
//! | 4 | 1 | frame kind ([`FrameKind`]) |
//! | 5 | 4 | `dst` rank (u32) |
//! | 9 | 8 | `src` rank (u64; `usize::MAX` = coordinator) |
//! | 17 | 8 | `tag` (u64: the `(doc, q_start)` / `CTRL_*` tag space) |
//! | 25 | 1 | `wave` (u8: ping-pong wave index, 0 = ping, 1 = pong) |
//! | 26 | 8 | `epoch` (u64: pool membership epoch the wave was stamped under; 0 = unstamped flat tick) |
//! | 34 | 4 | `tenant` (u32: `0` = untenanted/control, else tenant id + 1 — the gateway's stream id) |
//! | 38 | 8 | `trace` (u64: lineage trace id of the dispatch that sent this frame; 0 = untraced) |
//! | 46 | 4 | payload element count (u32, **count of f32 words**, not bytes) |
//! | 50 | 4·n | payload: each f32 as its u32 bit pattern, LE |
//!
//! ## Version history
//!
//! `DCA3` added the `trace` field: the coordinator stamps every
//! outbound data frame with the lineage trace id of the dispatch that
//! produced it ([`crate::obs::lineage`]), workers echo the request's
//! trace onto the matching response exactly as they echo the wave
//! stamp, and the coordinator can therefore attribute which dispatch
//! hop won under first-response-wins dedup. `0` means untraced
//! (control traffic, or observability disarmed) and is never
//! interpreted.
//!
//! `DCA2` added the `tenant` field (the multi-tenant gateway's stream
//! id, [`crate::server::tag_wire_tenant`]); a peer still speaking
//! `DCA1` or `DCA2` is rejected with a descriptive version-mismatch
//! error rather than desyncing bytes into the first frame. The tenant
//! field is
//! *derived* from the tag on encode and *validated* against the tag on
//! decode: a `Msg` frame whose header tenant disagrees with its
//! tag-encoded tenant — or any frame claiming a tenant id beyond the
//! 15-bit tenant space — is malformed and rejected descriptively.
//! Because workers echo the request tag onto the matching response,
//! the tenant field survives the round-trip structurally: no worker
//! code handles tenants at all.
//!
//! The `wave`/`epoch` pair is the wire form of the in-process
//! [`WaveStamp`](crate::elastic::pool::WaveStamp): the coordinator
//! stamps every data frame of a `--pp` wave with the membership epoch
//! the wave was dispatched under, workers echo the request's stamp
//! onto the matching response, and the coordinator counts responses
//! whose epoch predates the current stamp — so a mid-wave SIGKILL is
//! scoped to exactly the in-flight wave, over sockets just as in
//! process. `0` means the frame predates wave scoping (flat ticks,
//! control traffic) and is never treated as stale.
//!
//! The element count is an integer field, never an f32 — counts above
//! 2^24 are exact by construction. Frames claiming more than
//! [`MAX_PAYLOAD_ELEMS`] elements are rejected with a descriptive
//! error before any allocation, and a stream that ends mid-frame is a
//! *truncated frame* error at [`FrameDecoder::finish`], not a silent
//! drop.

use std::fmt;

use crate::exchange::transport::Message;

/// Stream magic: every frame starts with these four bytes (`"DCA3"`).
pub const MAGIC: u32 = 0x4443_4133;

/// The pre-tenant-field wire version (`"DCA1"`): recognized only to
/// reject it descriptively as a version mismatch.
pub const MAGIC_V1: u32 = 0x4443_4131;

/// The pre-trace-field wire version (`"DCA2"`): recognized only to
/// reject it descriptively as a version mismatch.
pub const MAGIC_V2: u32 = 0x4443_4132;

/// Fixed header size in bytes (everything before the payload):
/// magic, kind, dst, src, tag, wave, epoch, tenant, trace, element count.
pub const HEADER_BYTES: usize = 4 + 1 + 4 + 8 + 8 + 1 + 8 + 4 + 8 + 4;

/// Exclusive cap on the wire tenant field: `0` (untenanted) plus the
/// 15-bit tenant id space shifted by one.
pub const MAX_WIRE_TENANT: u32 = crate::server::MAX_TENANTS;

/// Hard cap on payload element count (2^28 f32 words = 1 GiB): frames
/// beyond this are rejected as corrupt rather than allocated.
pub const MAX_PAYLOAD_ELEMS: u32 = 1 << 28;

/// Codec-level failure: corrupt magic, unknown kind, oversized or
/// truncated frames. Always descriptive — these errors surface in
/// worker logs when a stream desyncs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodecError(pub String);

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "codec error: {}", self.0)
    }
}

impl std::error::Error for CodecError {}

/// What a frame carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// A data-plane [`Message`] for rank `dst` (CA-task tensors,
    /// outputs, or `CTRL_*` control messages — the tag disambiguates).
    Msg,
    /// Worker → coordinator registration: "rank `src` is live".
    Hello,
    /// Coordinator → worker handshake: rank assignment, pool size,
    /// attention dims, heartbeat interval (bit-cast header words).
    Config,
    /// Worker → coordinator liveness beat; payload `[seq]`.
    Heartbeat,
    /// Worker → coordinator: "drain me" — a graceful leave request the
    /// coordinator maps to the `drain:` fault kind.
    Drain,
    /// Worker → coordinator: orderly exit. A connection that drops
    /// *without* a goodbye is a crash — the `kill:` fault kind.
    Goodbye,
    /// Worker → coordinator: observability stats — per-task compute
    /// span records piggybacked on the heartbeat path. Payload is a
    /// repeating 4-word group `[tick, tag_lo, tag_hi, dur_s]` (the
    /// first three bit-cast header words, the duration a plain f32).
    Stats,
}

impl FrameKind {
    pub fn to_byte(self) -> u8 {
        match self {
            FrameKind::Msg => 1,
            FrameKind::Hello => 2,
            FrameKind::Config => 3,
            FrameKind::Heartbeat => 4,
            FrameKind::Drain => 5,
            FrameKind::Goodbye => 6,
            FrameKind::Stats => 7,
        }
    }

    pub fn from_byte(b: u8) -> Result<FrameKind, CodecError> {
        Ok(match b {
            1 => FrameKind::Msg,
            2 => FrameKind::Hello,
            3 => FrameKind::Config,
            4 => FrameKind::Heartbeat,
            5 => FrameKind::Drain,
            6 => FrameKind::Goodbye,
            7 => FrameKind::Stats,
            other => {
                return Err(CodecError(format!(
                    "unknown frame kind {other} (corrupt or desynced stream)"
                )))
            }
        })
    }
}

/// One decoded wire frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub dst: u32,
    pub src: u64,
    pub tag: u64,
    /// Ping-pong wave index this frame belongs to (0 = ping, 1 = pong;
    /// only meaningful when `epoch != 0`).
    pub wave: u8,
    /// Pool membership epoch the frame's wave was stamped under;
    /// 0 = unstamped (flat tick or control traffic).
    pub epoch: u64,
    /// Gateway tenant/stream id in wire form: `0` = untenanted or
    /// control traffic, else tenant id + 1. Always derived from the
    /// tag ([`crate::server::tag_wire_tenant`]); the decoder rejects
    /// frames where the two disagree.
    pub tenant: u32,
    /// Lineage trace id of the dispatch that sent this frame
    /// ([`crate::obs::lineage`]): stamped by the coordinator on
    /// outbound data frames, echoed by workers onto the matching
    /// response. `0` = untraced (control traffic, obs disarmed).
    pub trace: u64,
    pub payload: Vec<f32>,
}

impl Frame {
    /// Wrap a data-plane message bound for rank `dst` (unstamped; the
    /// transport applies the current wave stamp on the way out). The
    /// tenant field is derived from the tag, so a worker echoing a
    /// request tag onto its response re-derives the same tenant — the
    /// id survives the round-trip with no tenant-aware worker code.
    pub fn msg(dst: usize, m: Message) -> Frame {
        Frame {
            kind: FrameKind::Msg,
            dst: dst as u32,
            src: m.src as u64,
            tenant: crate::server::tag_wire_tenant(m.tag),
            tag: m.tag,
            wave: 0,
            epoch: 0,
            trace: 0,
            payload: m.payload,
        }
    }

    /// A control frame from rank `src` (pass `usize::MAX` for the
    /// coordinator).
    pub fn control(kind: FrameKind, src: usize, payload: Vec<f32>) -> Frame {
        Frame { kind, dst: 0, src: src as u64, tag: 0, wave: 0, epoch: 0, tenant: 0, trace: 0, payload }
    }

    /// Unwrap back into the transport message (data frames).
    pub fn into_message(self) -> Message {
        Message { src: self.src as usize, tag: self.tag, payload: self.payload }
    }

    /// Total encoded size in bytes.
    pub fn encoded_len(&self) -> usize {
        HEADER_BYTES + 4 * self.payload.len()
    }

    /// Serialize to wire bytes. Rejects payloads beyond
    /// [`MAX_PAYLOAD_ELEMS`] so a corrupt caller cannot emit a frame no
    /// decoder will accept.
    pub fn encode(&self) -> Result<Vec<u8>, CodecError> {
        if self.payload.len() > MAX_PAYLOAD_ELEMS as usize {
            return Err(CodecError(format!(
                "oversized frame: {} payload elements exceeds the {} cap",
                self.payload.len(),
                MAX_PAYLOAD_ELEMS
            )));
        }
        let mut out = Vec::with_capacity(self.encoded_len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.push(self.kind.to_byte());
        out.extend_from_slice(&self.dst.to_le_bytes());
        out.extend_from_slice(&self.src.to_le_bytes());
        out.extend_from_slice(&self.tag.to_le_bytes());
        out.push(self.wave);
        out.extend_from_slice(&self.epoch.to_le_bytes());
        out.extend_from_slice(&self.tenant.to_le_bytes());
        out.extend_from_slice(&self.trace.to_le_bytes());
        out.extend_from_slice(&(self.payload.len() as u32).to_le_bytes());
        for &w in &self.payload {
            // Bit pattern, not value: NaNs, signed zeros, and bit-cast
            // integer header words all survive verbatim.
            out.extend_from_slice(&w.to_bits().to_le_bytes());
        }
        Ok(out)
    }
}

/// Free-list of recv payload buffers: the zero-copy data plane's
/// allocator. The reader loop decodes each inbound frame's payload into
/// a recycled `Vec<f32>` ([`FrameDecoder::next_frame_pooled`]), the
/// server loop computes straight from a borrowed view of it, and
/// `Transport::recycle_payload` returns it here — so steady-state
/// serving allocates no payload buffers at all, and a task's bytes are
/// touched exactly once between socket and kernel.
///
/// A buffer is taken from the pool only once a frame's header has been
/// validated *and* its payload is fully buffered, so decode errors and
/// partial reads never strand a buffer ([`PayloadPool::outstanding`] is
/// the leak-check counter the codec property tests assert on).
#[derive(Debug)]
pub struct PayloadPool {
    free: std::sync::Mutex<Vec<Vec<f32>>>,
    outstanding: std::sync::atomic::AtomicIsize,
    max_pooled: usize,
}

impl PayloadPool {
    /// A pool that retains at most `max_pooled` free buffers (excess
    /// returns are simply dropped).
    pub fn new(max_pooled: usize) -> PayloadPool {
        PayloadPool {
            free: std::sync::Mutex::new(Vec::new()),
            outstanding: std::sync::atomic::AtomicIsize::new(0),
            max_pooled,
        }
    }

    /// Take a cleared buffer with at least `capacity` reserved —
    /// recycled when possible, freshly allocated when the pool is dry.
    pub fn get(&self, capacity: usize) -> Vec<f32> {
        self.outstanding.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let recycled = self.free.lock().unwrap().pop();
        match recycled {
            Some(mut b) => {
                b.clear();
                b.reserve(capacity);
                b
            }
            None => Vec::with_capacity(capacity),
        }
    }

    /// Return a spent buffer. Accepts buffers of any provenance (the
    /// server loop recycles whatever the fabric delivered).
    pub fn put(&self, mut buf: Vec<f32>) {
        self.outstanding.fetch_sub(1, std::sync::atomic::Ordering::Relaxed);
        buf.clear();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_pooled {
            free.push(buf);
        }
    }

    /// `get`s minus `put`s: zero when every taken buffer came back.
    pub fn outstanding(&self) -> isize {
        self.outstanding.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Free buffers currently parked in the pool.
    pub fn pooled(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// Incremental frame decoder: push bytes in whatever chunks the socket
/// yields, pop complete frames. Split read boundaries — mid-header,
/// mid-payload, many frames per chunk — never change the decoded
/// sequence (property-tested in `tests/prop_net_codec.rs`).
#[derive(Debug, Default)]
pub struct FrameDecoder {
    buf: Vec<u8>,
    read: usize,
}

impl FrameDecoder {
    pub fn new() -> FrameDecoder {
        FrameDecoder::default()
    }

    /// Append raw stream bytes.
    pub fn push(&mut self, bytes: &[u8]) {
        // Compact lazily: once consumed bytes dominate the buffer,
        // drop them so long-lived streams don't grow without bound.
        if self.read > 0 && 2 * self.read >= self.buf.len() {
            self.buf.drain(..self.read);
            self.read = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed by a complete frame.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.read
    }

    /// Take the unconsumed bytes out of the decoder (handshake →
    /// transport handoff: whatever was read past the CONFIG frame
    /// belongs to the data stream).
    pub fn take_buffered(&mut self) -> Vec<u8> {
        let rest = self.buf[self.read..].to_vec();
        self.buf.clear();
        self.read = 0;
        rest
    }

    /// Decode the next complete frame, `None` if more bytes are needed.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, CodecError> {
        self.next_frame_with(Vec::with_capacity)
    }

    /// [`FrameDecoder::next_frame`], decoding the payload into a buffer
    /// recycled from `pool`. The buffer is requested only after the
    /// header validates and the payload is fully buffered, so no error
    /// or partial-read path can leak one.
    pub fn next_frame_pooled(&mut self, pool: &PayloadPool) -> Result<Option<Frame>, CodecError> {
        self.next_frame_with(|cap| pool.get(cap))
    }

    fn next_frame_with(
        &mut self,
        make_buf: impl FnOnce(usize) -> Vec<f32>,
    ) -> Result<Option<Frame>, CodecError> {
        let b = &self.buf[self.read..];
        if b.len() < HEADER_BYTES {
            return Ok(None);
        }
        let magic = u32::from_le_bytes(b[0..4].try_into().unwrap());
        if magic == MAGIC_V1 {
            return Err(CodecError(format!(
                "wire version mismatch: peer sent a DCA1 frame (magic 0x{MAGIC_V1:08x}, \
                 no tenant field); this build speaks DCA3 (0x{MAGIC:08x})"
            )));
        }
        if magic == MAGIC_V2 {
            return Err(CodecError(format!(
                "wire version mismatch: peer sent a DCA2 frame (magic 0x{MAGIC_V2:08x}, \
                 no trace field); this build speaks DCA3 (0x{MAGIC:08x})"
            )));
        }
        if magic != MAGIC {
            return Err(CodecError(format!(
                "bad magic 0x{magic:08x} (expected 0x{MAGIC:08x}; corrupt or non-DistCA stream)"
            )));
        }
        let kind = FrameKind::from_byte(b[4])?;
        let dst = u32::from_le_bytes(b[5..9].try_into().unwrap());
        let src = u64::from_le_bytes(b[9..17].try_into().unwrap());
        let tag = u64::from_le_bytes(b[17..25].try_into().unwrap());
        let wave = b[25];
        let epoch = u64::from_le_bytes(b[26..34].try_into().unwrap());
        let tenant = u32::from_le_bytes(b[34..38].try_into().unwrap());
        if tenant > MAX_WIRE_TENANT {
            return Err(CodecError(format!(
                "malformed tenant field: wire tenant {tenant} exceeds the \
                 {MAX_WIRE_TENANT} cap (15-bit tenant space)"
            )));
        }
        let expect_tenant =
            if kind == FrameKind::Msg { crate::server::tag_wire_tenant(tag) } else { 0 };
        if tenant != expect_tenant {
            return Err(CodecError(format!(
                "malformed tenant field: header claims wire tenant {tenant} but the \
                 {kind:?} frame's tag 0x{tag:016x} encodes wire tenant {expect_tenant}"
            )));
        }
        let trace = u64::from_le_bytes(b[38..46].try_into().unwrap());
        let len = u32::from_le_bytes(b[46..50].try_into().unwrap());
        if len > MAX_PAYLOAD_ELEMS {
            return Err(CodecError(format!(
                "oversized frame: header claims {len} payload elements, cap is {MAX_PAYLOAD_ELEMS}"
            )));
        }
        let need = HEADER_BYTES + 4 * len as usize;
        if b.len() < need {
            return Ok(None);
        }
        // Bulk bit-cast decode in one pass — the only time these bytes
        // are touched before the kernel reads them.
        let mut payload = make_buf(len as usize);
        debug_assert!(payload.is_empty(), "pool must hand out cleared buffers");
        payload.extend(
            b[HEADER_BYTES..need]
                .chunks_exact(4)
                .map(|w| f32::from_bits(u32::from_le_bytes(w.try_into().unwrap()))),
        );
        self.read += need;
        Ok(Some(Frame { kind, dst, src, tag, wave, epoch, tenant, trace, payload }))
    }

    /// Call at stream EOF: leftover bytes mean the peer died mid-write.
    pub fn finish(&self) -> Result<(), CodecError> {
        let left = self.buffered();
        if left == 0 {
            Ok(())
        } else {
            Err(CodecError(format!(
                "truncated frame at EOF: {left} bytes of an incomplete frame buffered"
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Frame {
        Frame {
            kind: FrameKind::Msg,
            dst: 3,
            src: 1,
            tag: 0xDEAD_BEEF_CAFE,
            wave: 1,
            epoch: 0x0102_0304_0506,
            tenant: 0,
            trace: 0x0A0B_0C0D_0E0F,
            payload: vec![1.0, -2.5, 0.0, f32::from_bits(0x0123_4567)],
        }
    }

    #[test]
    fn roundtrip_one_frame() {
        let f = sample();
        let bytes = f.encode().unwrap();
        assert_eq!(bytes.len(), f.encoded_len());
        let mut dec = FrameDecoder::new();
        dec.push(&bytes);
        let g = dec.next_frame().unwrap().unwrap();
        assert_eq!(g, f);
        assert!(dec.next_frame().unwrap().is_none());
        dec.finish().unwrap();
    }

    #[test]
    fn message_roundtrip_preserves_coordinator_src() {
        let m = Message { src: usize::MAX, tag: 7, payload: vec![1.0] };
        let f = Frame::msg(4, m.clone());
        let bytes = f.encode().unwrap();
        let mut dec = FrameDecoder::new();
        dec.push(&bytes);
        let g = dec.next_frame().unwrap().unwrap();
        assert_eq!(g.dst, 4);
        assert_eq!(g.into_message(), m);
    }

    #[test]
    fn control_kinds_roundtrip() {
        for kind in [
            FrameKind::Hello,
            FrameKind::Config,
            FrameKind::Heartbeat,
            FrameKind::Drain,
            FrameKind::Goodbye,
            FrameKind::Stats,
        ] {
            let f = Frame::control(kind, 2, vec![5.0]);
            let mut dec = FrameDecoder::new();
            dec.push(&f.encode().unwrap());
            assert_eq!(dec.next_frame().unwrap().unwrap().kind, kind);
        }
    }

    #[test]
    fn bad_magic_rejected() {
        let mut bytes = sample().encode().unwrap();
        bytes[0] ^= 0xFF;
        let mut dec = FrameDecoder::new();
        dec.push(&bytes);
        let err = dec.next_frame().unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut bytes = sample().encode().unwrap();
        bytes[4] = 99;
        let mut dec = FrameDecoder::new();
        dec.push(&bytes);
        let err = dec.next_frame().unwrap_err();
        assert!(err.to_string().contains("kind"), "{err}");
    }

    #[test]
    fn truncated_stream_flagged_at_finish() {
        let bytes = sample().encode().unwrap();
        let mut dec = FrameDecoder::new();
        dec.push(&bytes[..bytes.len() - 1]);
        assert!(dec.next_frame().unwrap().is_none());
        let err = dec.finish().unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn oversized_header_rejected_without_allocation() {
        let mut hdr = Vec::new();
        hdr.extend_from_slice(&MAGIC.to_le_bytes());
        hdr.push(1);
        hdr.extend_from_slice(&0u32.to_le_bytes());
        hdr.extend_from_slice(&0u64.to_le_bytes());
        hdr.extend_from_slice(&0u64.to_le_bytes());
        hdr.push(0); // wave
        hdr.extend_from_slice(&0u64.to_le_bytes()); // epoch
        hdr.extend_from_slice(&0u32.to_le_bytes()); // tenant
        hdr.extend_from_slice(&0u64.to_le_bytes()); // trace
        hdr.extend_from_slice(&(MAX_PAYLOAD_ELEMS + 1).to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.push(&hdr);
        let err = dec.next_frame().unwrap_err();
        assert!(err.to_string().contains("oversized"), "{err}");
    }

    #[test]
    fn wave_stamp_roundtrips_and_defaults_to_unstamped() {
        // Constructors produce unstamped frames...
        let f = Frame::msg(2, Message { src: 0, tag: 9, payload: vec![1.0] });
        assert_eq!((f.wave, f.epoch), (0, 0));
        // ...and a stamped frame survives the wire bit-exact.
        let mut g = f;
        g.wave = 1;
        g.epoch = u64::MAX >> 8;
        let mut dec = FrameDecoder::new();
        dec.push(&g.encode().unwrap());
        let h = dec.next_frame().unwrap().unwrap();
        assert_eq!(h.wave, 1);
        assert_eq!(h.epoch, u64::MAX >> 8);
    }

    #[test]
    fn tenant_derived_from_tag_and_roundtripped() {
        use crate::server::{tag_wire_tenant, tenant_doc};
        let doc = tenant_doc(1234, 7);
        let tag = ((doc as u64) << 32) | 16;
        let f = Frame::msg(2, Message { src: 0, tag, payload: vec![1.0] });
        assert_eq!(f.tenant, 1235, "wire tenant is tenant id + 1");
        assert_eq!(f.tenant, tag_wire_tenant(tag));
        let mut dec = FrameDecoder::new();
        dec.push(&f.encode().unwrap());
        let g = dec.next_frame().unwrap().unwrap();
        assert_eq!(g.tenant, 1235);
        assert_eq!(g, f);
    }

    #[test]
    fn v1_magic_rejected_as_version_mismatch() {
        let mut bytes = sample().encode().unwrap();
        bytes[0..4].copy_from_slice(&MAGIC_V1.to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.push(&bytes);
        let err = dec.next_frame().unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
        assert!(err.to_string().contains("DCA1"), "{err}");
    }

    #[test]
    fn v2_magic_rejected_as_version_mismatch() {
        let mut bytes = sample().encode().unwrap();
        bytes[0..4].copy_from_slice(&MAGIC_V2.to_le_bytes());
        let mut dec = FrameDecoder::new();
        dec.push(&bytes);
        let err = dec.next_frame().unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
        assert!(err.to_string().contains("DCA2"), "{err}");
    }

    #[test]
    fn trace_stamp_roundtrips_and_defaults_to_untraced() {
        // Constructors produce untraced frames...
        let f = Frame::msg(2, Message { src: 0, tag: 9, payload: vec![1.0] });
        assert_eq!(f.trace, 0);
        // ...and a stamped trace id survives the wire bit-exact.
        let mut g = f;
        g.trace = u64::MAX - 7;
        let mut dec = FrameDecoder::new();
        dec.push(&g.encode().unwrap());
        let h = dec.next_frame().unwrap().unwrap();
        assert_eq!(h.trace, u64::MAX - 7);
    }

    #[test]
    fn tenant_tag_mismatch_rejected() {
        // Header claims tenant 5 but the tag encodes no tenant at all.
        let mut f = sample();
        f.tenant = 5;
        let mut dec = FrameDecoder::new();
        dec.push(&f.encode().unwrap());
        let err = dec.next_frame().unwrap_err();
        assert!(err.to_string().contains("malformed tenant"), "{err}");
    }

    #[test]
    fn out_of_range_tenant_rejected() {
        let mut f = sample();
        f.tenant = MAX_WIRE_TENANT + 1;
        let mut dec = FrameDecoder::new();
        dec.push(&f.encode().unwrap());
        let err = dec.next_frame().unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
    }

    #[test]
    fn control_frames_must_carry_zero_tenant() {
        let mut f = Frame::control(FrameKind::Heartbeat, 2, vec![1.0]);
        f.tenant = 3;
        let mut dec = FrameDecoder::new();
        dec.push(&f.encode().unwrap());
        let err = dec.next_frame().unwrap_err();
        assert!(err.to_string().contains("malformed tenant"), "{err}");
    }

    #[test]
    fn take_buffered_hands_off_the_tail() {
        let a = sample().encode().unwrap();
        let b = sample().encode().unwrap();
        let mut dec = FrameDecoder::new();
        dec.push(&a);
        dec.push(&b[..10]);
        assert!(dec.next_frame().unwrap().is_some());
        let rest = dec.take_buffered();
        assert_eq!(rest, &b[..10]);
        assert_eq!(dec.buffered(), 0);
    }
}
