//! The paper's contribution: core attention disaggregation (CAD).
//!
//! * [`item`] — the scheduling unit algebra: head-tail [`item::Item`]s
//!   (documents or 128-aligned shards) and the [`item::CaTask`]s they map
//!   to;
//! * [`profiler`] — CA latency prediction: a (q_len × kv_len) grid with
//!   bilinear interpolation and a saturation region (§4.2 "Profiler"),
//!   either analytic (Fig. 5 shaped) or loaded from measured JSON;
//! * [`comm`] — Appendix A's max-partition bound and Appendix B's
//!   closed-form minimal-communication shard selection `v(·)`;
//! * [`scheduler`] — the communication-aware greedy balancer (§4.2),
//!   heterogeneity-aware: [`scheduler::schedule_with_beliefs`] balances
//!   estimated *seconds* against per-server
//!   [`scheduler::ServerBelief`]s (believed speed × arena byte budget)
//!   instead of assuming uniform servers;
//! * [`pingpong`] — the Fig.-7 overlap timeline (§4.1);
//! * [`plan`] — the scheduler's output: CA-task → attention-server
//!   assignments plus the all-to-all byte matrix.

pub mod comm;
pub mod item;
pub mod pingpong;
pub mod plan;
pub mod profiler;
pub mod scheduler;

pub use item::{CaTask, Item, BLOCK_TOKENS};
pub use pingpong::{split_waves, PingPongBuffer, Wave};
pub use plan::Plan;
pub use profiler::Profiler;
pub use scheduler::{schedule, schedule_with_beliefs, PoolCapacity, SchedulerCfg, ServerBelief};
