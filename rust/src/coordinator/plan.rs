//! Scheduler output (§4.1): the CA-task → attention-server assignment and
//! the all-to-all communication it implies.

use crate::config::ModelConfig;
use crate::model::FlopsModel;

use super::item::Item;

/// One scheduled Item: where its CA executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Assignment {
    pub item: Item,
    pub server: usize,
}

impl Assignment {
    pub fn is_local(&self) -> bool {
        self.item.home == self.server
    }
}

/// A complete schedule for one microbatch / PP tick.
#[derive(Debug, Clone)]
pub struct Plan {
    pub n_servers: usize,
    pub assignments: Vec<Assignment>,
    /// Estimated CA execution time per server (seconds), under the
    /// believed speed the plan was built against (uniform plans: the
    /// nominal cost — the two coincide at speed 1.0).
    pub server_load: Vec<f64>,
    /// Ideal makespan T̄ = Σ cost / Σ believed speed (seconds); with
    /// uniform servers this is the paper's per-server ideal F̄.
    pub target_load: f64,
    /// Dispatch bytes `comm[src][dst]`: Q+KV sent from home `src` to
    /// server `dst` (dst ≠ src entries only).
    pub comm_matrix: Vec<Vec<f64>>,
    /// Output-return bytes `ret[server][home]`.
    pub return_matrix: Vec<Vec<f64>>,
}

impl Plan {
    /// Build the comm matrices from assignments.
    pub fn with_comm(mut self, m: &ModelConfig) -> Plan {
        let n = self.n_servers;
        let mut comm = vec![vec![0.0; n]; n];
        let mut ret = vec![vec![0.0; n]; n];
        for a in &self.assignments {
            if a.is_local() {
                continue;
            }
            let q = (a.item.q_tokens() * m.q_bytes_per_token()) as f64;
            let kv = (a.item.kv_context_tokens() * m.kv_bytes_per_token()) as f64;
            comm[a.item.home][a.server] += q + kv;
            ret[a.server][a.item.home] += q; // O is Q-shaped
        }
        self.comm_matrix = comm;
        self.return_matrix = ret;
        self
    }

    /// Total bytes moved (dispatch + return).
    pub fn total_comm_bytes(&self) -> f64 {
        let d: f64 = self.comm_matrix.iter().flatten().sum();
        let r: f64 = self.return_matrix.iter().flatten().sum();
        d + r
    }

    /// Max bytes any single server sends or receives in the dispatch
    /// all-to-all — the straggler link (§3.3: spread communication-heavy
    /// shards across destinations).
    pub fn max_link_bytes(&self) -> f64 {
        let n = self.n_servers;
        let mut mx: f64 = 0.0;
        for s in 0..n {
            let send: f64 = self.comm_matrix[s].iter().sum::<f64>()
                + self.return_matrix[s].iter().sum::<f64>();
            let recv: f64 = (0..n)
                .map(|o| self.comm_matrix[o][s] + self.return_matrix[o][s])
                .sum();
            mx = mx.max(send).max(recv);
        }
        mx
    }

    /// `max load / mean load` across servers (time terms: a
    /// belief-aware plan is balanced when every server takes the same
    /// *seconds*, not the same FLOPs).
    pub fn imbalance(&self) -> f64 {
        crate::util::stats::imbalance_ratio(&self.server_load)
    }

    /// The plan's predicted makespan (seconds): the slowest server's
    /// estimated execution time under the believed speeds the plan was
    /// built against. Comparable across belief vectors — the quantity
    /// the heterogeneity-aware scheduler minimizes.
    pub fn predicted_makespan(&self) -> f64 {
        self.server_load.iter().cloned().fold(0.0, f64::max)
    }

    /// Evaluate a *uniform* plan (whose `server_load` is nominal work —
    /// speed 1.0 everywhere) under a different speed vector: the
    /// makespan it would actually achieve on servers running at
    /// `speeds`. This is the baseline a belief-aware plan's
    /// [`Plan::predicted_makespan`] is compared against. Extra servers
    /// beyond `speeds.len()` are treated as nominal.
    pub fn makespan_under(&self, speeds: &[f64]) -> f64 {
        self.server_load
            .iter()
            .enumerate()
            .map(|(s, w)| w / speeds.get(s).copied().unwrap_or(1.0))
            .fold(0.0, f64::max)
    }

    /// Fraction of items that stayed home.
    pub fn local_fraction(&self) -> f64 {
        if self.assignments.is_empty() {
            return 1.0;
        }
        self.assignments.iter().filter(|a| a.is_local()).count() as f64
            / self.assignments.len() as f64
    }

    /// Invariant checks used by tests and the property suite:
    /// * every document's query tokens are covered exactly once;
    /// * every assignment's server index is valid;
    /// * CA FLOPs are conserved vs. the original docs.
    pub fn validate(&self, original: &[Item], f: &FlopsModel) -> Result<(), String> {
        for a in &self.assignments {
            if a.server >= self.n_servers {
                return Err(format!("assignment to invalid server {}", a.server));
            }
        }
        // Token conservation per document.
        use std::collections::BTreeMap;
        let mut orig_tokens: BTreeMap<u32, usize> = BTreeMap::new();
        let mut orig_flops: BTreeMap<u32, f64> = BTreeMap::new();
        for it in original {
            *orig_tokens.entry(it.doc).or_default() += it.q_tokens();
            *orig_flops.entry(it.doc).or_insert(0.0) += it.ca_fwd_flops(f);
        }
        let mut got_tokens: BTreeMap<u32, usize> = BTreeMap::new();
        let mut got_flops: BTreeMap<u32, f64> = BTreeMap::new();
        for a in &self.assignments {
            *got_tokens.entry(a.item.doc).or_default() += a.item.q_tokens();
            *got_flops.entry(a.item.doc).or_insert(0.0) += a.item.ca_fwd_flops(f);
        }
        if orig_tokens != got_tokens {
            return Err(format!(
                "token conservation violated: {orig_tokens:?} vs {got_tokens:?}"
            ));
        }
        for (doc, &fl) in &orig_flops {
            let got = got_flops.get(doc).copied().unwrap_or(0.0);
            if (got - fl).abs() / fl.max(1.0) > 1e-6 {
                return Err(format!("flops conservation violated for doc {doc}: {fl} vs {got}"));
            }
        }
        // No overlapping ranges within a document.
        let mut ranges: BTreeMap<u32, Vec<(usize, usize)>> = BTreeMap::new();
        for a in &self.assignments {
            for t in a.item.ca_tasks() {
                ranges
                    .entry(t.doc)
                    .or_default()
                    .push((t.q_start, t.q_start + t.q_len));
            }
        }
        for (doc, mut rs) in ranges {
            rs.sort();
            for w in rs.windows(2) {
                if w[0].1 > w[1].0 {
                    return Err(format!("doc {doc}: overlapping q ranges {w:?}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn plan_with(assignments: Vec<Assignment>, n: usize) -> Plan {
        Plan {
            n_servers: n,
            assignments,
            server_load: vec![1.0; n],
            target_load: 1.0,
            comm_matrix: vec![],
            return_matrix: vec![],
        }
        .with_comm(&ModelConfig::llama3_8b())
    }

    #[test]
    fn local_assignments_cost_no_comm() {
        let it = Item::whole_doc(0, 4096, 1);
        let p = plan_with(vec![Assignment { item: it, server: 1 }], 4);
        assert_eq!(p.total_comm_bytes(), 0.0);
        assert_eq!(p.local_fraction(), 1.0);
    }

    #[test]
    fn remote_assignment_populates_matrices() {
        let m = ModelConfig::llama3_8b();
        let it = Item::whole_doc(0, 4096, 0);
        let p = plan_with(vec![Assignment { item: it, server: 2 }], 4);
        let q = (4096 * m.q_bytes_per_token()) as f64;
        let kv = (4096 * m.kv_bytes_per_token()) as f64;
        assert_eq!(p.comm_matrix[0][2], q + kv);
        assert_eq!(p.return_matrix[2][0], q);
        assert_eq!(p.total_comm_bytes(), 2.0 * q + kv);
        assert!(p.max_link_bytes() > 0.0);
    }

    #[test]
    fn validate_catches_lost_tokens() {
        let f = crate::model::FlopsModel::new(&ModelConfig::llama3_8b());
        let orig = vec![Item::whole_doc(0, 8192, 0)];
        let (a, _b) = orig[0].split_at(2048);
        // Plan drops piece b.
        let p = plan_with(vec![Assignment { item: a, server: 0 }], 2);
        assert!(p.validate(&orig, &f).is_err());
    }

    #[test]
    fn validate_catches_duplicates() {
        let f = crate::model::FlopsModel::new(&ModelConfig::llama3_8b());
        let orig = vec![Item::whole_doc(0, 8192, 0)];
        let p = plan_with(
            vec![
                Assignment { item: orig[0], server: 0 },
                Assignment { item: orig[0], server: 1 },
            ],
            2,
        );
        assert!(p.validate(&orig, &f).is_err());
    }

    #[test]
    fn validate_accepts_exact_partition() {
        let f = crate::model::FlopsModel::new(&ModelConfig::llama3_8b());
        let orig = vec![Item::whole_doc(0, 8192, 0), Item::whole_doc(1, 4096, 1)];
        let (a, b) = orig[0].split_at(1024);
        let p = plan_with(
            vec![
                Assignment { item: a, server: 1 },
                Assignment { item: b, server: 0 },
                Assignment { item: orig[1], server: 1 },
            ],
            2,
        );
        p.validate(&orig, &f).unwrap();
    }
}
