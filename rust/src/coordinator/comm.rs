//! Communication models from the paper's appendices.
//!
//! * [`max_partition_bound`] — Appendix A: the largest number of shards a
//!   document can be split into before the per-layer Q/KV dispatch can no
//!   longer hide under the context-independent compute
//!   (`s ≤ 2(tB − h_q)/h_kv − 1`). With Llama-34B, IB at 50 GB/s and 50%
//!   MFU this evaluates to ≈ 31.
//! * [`migration_comm`] — Appendix B: the minimal communication volume
//!   `v(·)` for migrating `ΔF` FLOPs out of a head-tail Item, and the
//!   optimal sub-shard size `n_q` achieving it.

use crate::config::{ClusterConfig, ModelConfig};

/// Appendix A: time to compute one token's context-independent layers.
pub fn token_linear_time(m: &ModelConfig, cluster: &ClusterConfig) -> f64 {
    let h = m.hidden as f64;
    let h_kv = m.h_kv() as f64;
    let i = m.intermediate as f64;
    let flops = 2.0 * h * (2.0 * h + h_kv + 3.0 * i);
    flops / cluster.linear_flops()
}

/// Appendix A: upper bound on the number of shards `s` a document can be
/// partitioned into with communication fully overlapped:
/// `s ≤ 2(tB − size_q)/size_kv − 1`, where `t` is the per-token
/// context-independent compute time, `B` the network bandwidth, and
/// `size_q`/`size_kv` the per-token Q and per-tensor KV byte sizes.
pub fn max_partition_bound(m: &ModelConfig, cluster: &ClusterConfig) -> f64 {
    let t = token_linear_time(m, cluster);
    let b = cluster.ib_bw;
    let size_q = m.q_bytes_per_token() as f64;
    // Note: the paper's formula uses `h_kv` per-tensor (4 KB for 34B) but
    // its worked example lands at ≈31, which is only consistent with the
    // *combined* K+V byte count (8 KB) — physically correct, since both
    // tensors are transferred. We follow the worked example.
    let size_kv = m.kv_bytes_per_token() as f64; // K and V combined
    2.0 * (t * b - size_q) / size_kv - 1.0
}

/// Result of Appendix B's minimal-communication shard selection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationComm {
    /// Query tokens (per half) of the sub-shard to migrate.
    pub n_q: f64,
    /// Communication bytes for the migration.
    pub bytes: f64,
}

/// Appendix B (head-tail form): given an Item with per-half query width
/// `l_q_half` whose halves span a document of length `l_doc` starting at
/// head offset `i` (so per-half KV reach `l_kv = l_doc - i` for the tail),
/// find the sub-shard carrying the fraction `alpha = ΔF/F_item` of the
/// Item's FLOPs with minimal communication.
///
/// Using the paper's parametrization: an Item owns head `[i, j)` and tail
/// `[l-j, l-i)`; `L_q = j - i` (per-half width), `n_kv = j` for the head
/// half, and a sub-shard keeping the *outer* ranges `[i, i+n_q)` +
/// `[l-i-n_q, l-i)` costs
/// `Comm(n_q) = L_doc·size_kv + ½·size_q·(n_q(2+β) − αβ·L_q(2L_kv−L_q)/n_q)`
/// — decreasing in `n_q` over the feasible range, so the optimum sits at
/// the smallest feasible `n_q`:
/// `n_q_min = L_kv − sqrt(L_kv² − α(2L_kv − L_q)L_q)`.
pub fn migration_comm(
    alpha: f64,
    l_q: f64,
    l_kv: f64,
    l_doc: f64,
    size_q: f64,
    size_kv: f64,
) -> MigrationComm {
    assert!((0.0..=1.0 + 1e-9).contains(&alpha), "alpha out of range: {alpha}");
    assert!(l_q > 0.0 && l_kv >= l_q, "bad geometry l_q={l_q} l_kv={l_kv}");
    let beta = size_kv / size_q;
    let disc = l_kv * l_kv - alpha * (2.0 * l_kv - l_q) * l_q;
    let n_q_min = if disc <= 0.0 {
        l_q // degenerate: take the whole Item
    } else {
        (l_kv - disc.sqrt()).min(l_q).max(0.0)
    };
    let n_q = n_q_min.max(1.0);
    let bytes = l_doc * size_kv
        + 0.5
            * size_q
            * (n_q * (2.0 + beta) - alpha * beta * l_q * (2.0 * l_kv - l_q) / n_q);
    MigrationComm {
        n_q,
        bytes: bytes.max(0.0),
    }
}

/// Exact byte count for migrating an [`super::item::Item`] to a remote
/// server: Q for both halves in, KV prefix `[0, l - i)` in, O back.
/// This is what the scheduler and the all-to-all plan actually use; the
/// closed form above is used for *ranking* candidates cheaply (and tested
/// to agree in ordering).
pub fn item_migration_bytes(item: &super::item::Item, m: &ModelConfig) -> f64 {
    let q = item.q_tokens() * m.q_bytes_per_token();
    let kv = item.kv_context_tokens() * m.kv_bytes_per_token();
    let o = item.q_tokens() * m.q_bytes_per_token();
    (q + kv + o) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::item::Item;

    #[test]
    fn appendix_a_llama34b_is_31() {
        // Appendix A works the example: t ≈ 2.796 µs, B = 50 GB/s,
        // size_q = 16 KB, size_kv = 4 KB ⇒ s ≈ 31.
        let m = ModelConfig::llama_34b();
        let c = ClusterConfig::h200(1);
        let t = token_linear_time(&m, &c);
        assert!((t - 2.796e-6).abs() < 0.05e-6, "t = {t}");
        let s = max_partition_bound(&m, &c);
        assert!((s - 31.0).abs() < 2.5, "s = {s}");
    }

    #[test]
    fn bound_increases_for_larger_models() {
        // Appendix A: t scales quadratically with hidden size, so larger
        // models admit more shards.
        let c = ClusterConfig::h200(1);
        let s8 = max_partition_bound(&ModelConfig::llama3_8b(), &c);
        let s34 = max_partition_bound(&ModelConfig::llama_34b(), &c);
        assert!(s34 > s8, "s34 {s34} <= s8 {s8}");
    }

    #[test]
    fn bound_increases_with_bandwidth() {
        let m = ModelConfig::llama_34b();
        let mut c = ClusterConfig::h200(1);
        let s50 = max_partition_bound(&m, &c);
        c.ib_bw = 100e9;
        let s100 = max_partition_bound(&m, &c);
        assert!(s100 > s50);
    }

    #[test]
    fn migration_comm_monotone_in_alpha() {
        // More FLOPs migrated ⇒ at least as many bytes.
        let mut prev = 0.0;
        for k in 1..=10 {
            let alpha = k as f64 / 10.0;
            let mc = migration_comm(alpha, 4096.0, 8192.0, 16384.0, 16384.0, 8192.0);
            assert!(mc.bytes >= prev - 1.0, "alpha {alpha}: {} < {prev}", mc.bytes);
            prev = mc.bytes;
        }
    }

    #[test]
    fn migration_full_item_takes_whole_width() {
        let mc = migration_comm(1.0, 4096.0, 8192.0, 16384.0, 16384.0, 8192.0);
        assert!((mc.n_q - 4096.0).abs() < 1.0, "n_q = {}", mc.n_q);
    }

    #[test]
    fn migration_small_alpha_small_shard() {
        let mc = migration_comm(0.05, 4096.0, 8192.0, 16384.0, 16384.0, 8192.0);
        assert!(mc.n_q < 4096.0 * 0.25, "n_q = {}", mc.n_q);
    }

    #[test]
    fn exact_item_bytes() {
        let m = ModelConfig::llama_34b();
        let it = Item::whole_doc(0, 8192, 0);
        let bytes = item_migration_bytes(&it, &m);
        // Q+O: 2 * 8192 tok * 16KB; KV: 8192 tok * 8KB
        let expect = (2.0 * 8192.0 * 16384.0) + (8192.0 * 8192.0);
        assert!((bytes - expect).abs() < 1.0, "{bytes} vs {expect}");
    }

    #[test]
    fn splitting_outer_costs_less_kv_than_inner() {
        // The outer shard keeps KV reach l - i; the inner shard's reach is
        // smaller — matching Appendix B's preference ordering.
        let m = ModelConfig::llama3_8b();
        let it = Item::whole_doc(0, 32768, 0);
        let (outer, inner) = it.split_outer(8192);
        let b_outer = item_migration_bytes(&outer, &m);
        let b_inner = item_migration_bytes(&inner, &m);
        // outer has fewer q tokens but full KV reach; inner has more q but
        // shallower KV. Both must be positive and distinct.
        assert!(b_outer > 0.0 && b_inner > 0.0 && b_outer != b_inner);
        assert!(outer.kv_context_tokens() > inner.kv_context_tokens());
    }
}
