//! CA-task latency prediction (§4.2 "Profiler").
//!
//! The scheduler costs CA-tasks with a profiler: a grid of measured
//! (q_len × kv_len) → latency points, queried by bilinear interpolation;
//! in the saturation region (kernel at peak throughput) cost falls back
//! to `flops / max_throughput`.
//!
//! Two constructions:
//! * [`Profiler::analytic`] — Fig.-5-shaped model: peak throughput for
//!   shards ≥ the 128-token tile, padding-waste throughput collapse below
//!   it (a q-shard of `q < 128` occupies a whole tile ⇒ effective FLOPs
//!   are computed at `⌈q/128⌉·128` rows);
//! * [`Profiler::from_json`] — measured grid emitted by
//!   `python/compile/aot.py --profile` (interpret-mode Pallas timings),
//!   same JSON schema.

use crate::config::ClusterConfig;
use crate::model::FlopsModel;
use crate::util::json::{Json, JsonError};

use super::item::BLOCK_TOKENS;

/// Latency grid over (q_len, kv_len).
#[derive(Debug, Clone)]
pub struct Profiler {
    /// Grid coordinates, ascending.
    pub q_grid: Vec<f64>,
    pub kv_grid: Vec<f64>,
    /// `latency[qi][ki]` seconds for one forward CA call.
    pub latency: Vec<Vec<f64>>,
    /// Peak sustained throughput (FLOP/s) — the saturation region rate.
    pub peak_flops: f64,
    /// FLOPs model used to convert shapes → FLOPs.
    pub h_q: f64,
}

impl Profiler {
    /// Analytic Fig.-5 model from the cluster's attention MFU.
    pub fn analytic(f: &FlopsModel, cluster: &ClusterConfig) -> Profiler {
        let peak = cluster.attention_flops();
        let q_grid: Vec<f64> = [
            16usize, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536,
            131072,
        ]
        .iter()
        .map(|&x| x as f64)
        .collect();
        let kv_grid = q_grid.clone();
        let mut latency = vec![vec![0.0; kv_grid.len()]; q_grid.len()];
        for (qi, &q) in q_grid.iter().enumerate() {
            for (ki, &kv) in kv_grid.iter().enumerate() {
                latency[qi][ki] = Self::analytic_latency(f.h_q, peak, q, kv);
            }
        }
        Profiler {
            q_grid,
            kv_grid,
            latency,
            peak_flops: peak,
            h_q: f.h_q,
        }
    }

    /// (query, key) pair count of a causal CA-task shape: `q` query rows
    /// whose context reaches `kv` keys — rows attend to `kv-q+1 … kv`
    /// keys, a trapezoid of `q·kv − q(q−1)/2` pairs. Modern varlen
    /// kernels skip the empty causal half, so cost tracks this, not the
    /// `q·kv` rectangle.
    pub fn causal_pairs(q: f64, kv: f64) -> f64 {
        let kv = kv.max(q); // a task's context includes its own rows
        q * kv - q * (q - 1.0) / 2.0
    }

    /// One grid point of the analytic model: causal FLOPs at tile-padded
    /// shapes over peak throughput, plus a fixed kernel-launch floor.
    fn analytic_latency(h_q: f64, peak: f64, q: f64, kv: f64) -> f64 {
        let block = BLOCK_TOKENS as f64;
        let q_pad = (q / block).ceil() * block;
        let kv_pad = (kv / block).ceil() * block;
        let flops = 4.0 * h_q * Self::causal_pairs(q_pad, kv_pad);
        const LAUNCH_OVERHEAD: f64 = 4e-6;
        LAUNCH_OVERHEAD + flops / peak
    }

    /// Load a measured grid from JSON:
    /// `{"q_grid": [...], "kv_grid": [...], "latency": [[...]], "peak_flops": x, "h_q": x}`.
    pub fn from_json(v: &Json) -> Result<Profiler, JsonError> {
        let q_grid = v
            .req("q_grid")?
            .as_f64_vec()
            .ok_or_else(|| JsonError("q_grid must be an array".into()))?;
        let kv_grid = v
            .req("kv_grid")?
            .as_f64_vec()
            .ok_or_else(|| JsonError("kv_grid must be an array".into()))?;
        let lat_rows = v
            .req("latency")?
            .as_arr()
            .ok_or_else(|| JsonError("latency must be an array".into()))?;
        let mut latency = Vec::with_capacity(lat_rows.len());
        for row in lat_rows {
            latency.push(
                row.as_f64_vec()
                    .ok_or_else(|| JsonError("latency rows must be arrays".into()))?,
            );
        }
        if q_grid.is_empty() || kv_grid.is_empty() {
            return Err(JsonError("q_grid and kv_grid must be non-empty".into()));
        }
        for (name, grid) in [("q_grid", &q_grid), ("kv_grid", &kv_grid)] {
            if grid.iter().any(|x| !x.is_finite()) {
                return Err(JsonError(format!("{name} has a non-finite coordinate")));
            }
            if grid.windows(2).any(|w| w[0] >= w[1]) {
                return Err(JsonError(format!("{name} must be strictly ascending")));
            }
        }
        if latency.len() != q_grid.len()
            || latency.iter().any(|r| r.len() != kv_grid.len())
        {
            return Err(JsonError("latency shape mismatch".into()));
        }
        let peak_flops = v
            .req("peak_flops")?
            .as_f64()
            .ok_or_else(|| JsonError("peak_flops must be a number".into()))?;
        if !(peak_flops.is_finite() && peak_flops > 0.0) {
            return Err(JsonError("peak_flops must be positive and finite".into()));
        }
        Ok(Profiler {
            q_grid,
            kv_grid,
            latency,
            peak_flops,
            h_q: v
                .req("h_q")?
                .as_f64()
                .ok_or_else(|| JsonError("h_q must be a number".into()))?,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "q_grid",
                Json::Arr(self.q_grid.iter().map(|&x| Json::Num(x)).collect()),
            ),
            (
                "kv_grid",
                Json::Arr(self.kv_grid.iter().map(|&x| Json::Num(x)).collect()),
            ),
            (
                "latency",
                Json::Arr(
                    self.latency
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|&x| Json::Num(x)).collect()))
                        .collect(),
                ),
            ),
            ("peak_flops", Json::Num(self.peak_flops)),
            ("h_q", Json::Num(self.h_q)),
        ])
    }

    /// Predicted forward latency of a CA shape by bilinear interpolation
    /// over the four nearest grid points; saturation-region shapes
    /// (predicted throughput ≥ peak) use `flops/peak` directly (§4.2).
    pub fn predict(&self, q_len: f64, kv_len: f64) -> f64 {
        let interp = self.bilinear(q_len.max(1.0), kv_len.max(1.0));
        let flops = 4.0 * self.h_q * Self::causal_pairs(q_len, kv_len);
        let floor = flops / self.peak_flops;
        // If interpolation claims super-peak throughput, clamp to peak.
        interp.max(floor)
    }

    /// Predicted latency of a whole *fused batch* of CA-tasks: shards are
    /// batched into one kernel call, so cost is the sum of per-task tile
    /// work (composability, §3.3) plus one launch.
    pub fn predict_batch(&self, shapes: &[(f64, f64)]) -> f64 {
        if shapes.is_empty() {
            return 0.0;
        }
        let per_task: f64 = shapes.iter().map(|&(q, kv)| self.predict(q, kv)).sum();
        // One fused launch replaces per-task launches: subtract the
        // repeated floor (approximated by the smallest grid latency).
        let launch = self.latency[0][0].min(4e-6);
        per_task - launch * (shapes.len() - 1) as f64
    }

    /// Effective throughput (useful FLOP/s) at a shape — the Fig. 5
    /// y-axis: *useful* (unpadded) causal FLOPs over predicted latency.
    pub fn throughput(&self, q_len: f64, kv_len: f64) -> f64 {
        let flops = 4.0 * self.h_q * Self::causal_pairs(q_len, kv_len);
        flops / self.predict(q_len, kv_len)
    }

    fn bracket(grid: &[f64], x: f64) -> (usize, usize, f64) {
        if x <= grid[0] {
            return (0, 0, 0.0);
        }
        if x >= *grid.last().unwrap() {
            let n = grid.len() - 1;
            return (n, n, 0.0);
        }
        let hi = grid.partition_point(|&g| g < x);
        let lo = hi - 1;
        let frac = (x - grid[lo]) / (grid[hi] - grid[lo]);
        (lo, hi, frac)
    }

    fn bilinear(&self, q: f64, kv: f64) -> f64 {
        let (q0, q1, fq) = Self::bracket(&self.q_grid, q);
        let (k0, k1, fk) = Self::bracket(&self.kv_grid, kv);
        let l00 = self.latency[q0][k0];
        let l01 = self.latency[q0][k1];
        let l10 = self.latency[q1][k0];
        let l11 = self.latency[q1][k1];
        let top = l00 * (1.0 - fk) + l01 * fk;
        let bot = l10 * (1.0 - fk) + l11 * fk;
        top * (1.0 - fq) + bot * fq
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn prof() -> Profiler {
        Profiler::analytic(
            &FlopsModel::new(&ModelConfig::llama3_8b()),
            &ClusterConfig::h200(1),
        )
    }

    #[test]
    fn grid_points_exact() {
        let p = prof();
        // At a grid point, prediction equals the stored latency (up to the
        // saturation clamp).
        let qi = 7; // 2048
        let ki = 9; // 8192
        let pred = p.predict(p.q_grid[qi], p.kv_grid[ki]);
        assert!((pred - p.latency[qi][ki]).abs() / pred < 1e-9);
    }

    #[test]
    fn fig5_throughput_knee_at_128() {
        // Fig. 5: throughput collapses below the 128-token tile and
        // plateaus above it.
        let p = prof();
        let kv = 32_768.0;
        let t16 = p.throughput(16.0, kv);
        let t64 = p.throughput(64.0, kv);
        let t128 = p.throughput(128.0, kv);
        let t1024 = p.throughput(1024.0, kv);
        assert!(t16 < 0.25 * t128, "16-token shard should waste >75% of tile");
        assert!(t64 < 0.75 * t128);
        // plateau: ≥128 within 10% of each other (launch overhead shrinks)
        assert!((t1024 - t128).abs() / t1024 < 0.15, "t128={t128} t1024={t1024}");
    }

    #[test]
    fn interpolation_between_grid_points() {
        let p = prof();
        let a = p.predict(2048.0, 8192.0);
        let b = p.predict(4096.0, 8192.0);
        let mid = p.predict(3072.0, 8192.0);
        assert!(a < mid && mid < b, "{a} {mid} {b}");
    }

    #[test]
    fn saturation_region_uses_peak() {
        let p = prof();
        // Far beyond grid: latency ≥ flops/peak and close to it.
        let q = 200_000.0;
        let kv = 200_000.0;
        let flops = 4.0 * p.h_q * Profiler::causal_pairs(q, kv);
        let pred = p.predict(q, kv);
        assert!(pred >= flops / p.peak_flops * 0.999);
        assert!(pred <= flops / p.peak_flops * 1.10, "should be near peak");
    }

    #[test]
    fn batch_cheaper_than_separate_calls() {
        let p = prof();
        let shapes = vec![(512.0, 4096.0); 16];
        let fused = p.predict_batch(&shapes);
        let separate: f64 = shapes.iter().map(|&(q, kv)| p.predict(q, kv)).sum();
        assert!(fused <= separate);
        assert!(fused > 0.0);
    }

    #[test]
    fn json_roundtrip() {
        let p = prof();
        let j = p.to_json();
        let q = Profiler::from_json(&j).unwrap();
        assert_eq!(p.q_grid, q.q_grid);
        assert_eq!(p.latency, q.latency);
        let shape = (3000.0, 12000.0);
        assert!((p.predict(shape.0, shape.1) - q.predict(shape.0, shape.1)).abs() < 1e-12);
    }

    #[test]
    fn from_json_shape_mismatch_rejected() {
        let j = crate::util::json::parse(
            r#"{"q_grid":[1,2],"kv_grid":[1],"latency":[[1.0]],"peak_flops":1.0,"h_q":1.0}"#,
        )
        .unwrap();
        assert!(Profiler::from_json(&j).is_err());
    }

    #[test]
    fn from_json_malformed_grids_error_instead_of_panicking() {
        // Each of these would previously survive construction and then
        // panic (index out of bounds / divide by zero) inside
        // `bracket`; now they are descriptive load-time errors.
        let cases = [
            // Empty grid: 0 == 0 satisfied the old shape check.
            (
                r#"{"q_grid":[],"kv_grid":[],"latency":[],"peak_flops":1.0,"h_q":1.0}"#,
                "non-empty",
            ),
            // Non-ascending axis: partition_point needs sorted input.
            (
                r#"{"q_grid":[2,1],"kv_grid":[1,2],"latency":[[1.0,1.0],[1.0,1.0]],"peak_flops":1.0,"h_q":1.0}"#,
                "ascending",
            ),
            // Duplicate coordinate: zero-width bracket divides by zero.
            (
                r#"{"q_grid":[1,1],"kv_grid":[1,2],"latency":[[1.0,1.0],[1.0,1.0]],"peak_flops":1.0,"h_q":1.0}"#,
                "ascending",
            ),
            // Ragged latency rows.
            (
                r#"{"q_grid":[1,2],"kv_grid":[1,2],"latency":[[1.0,1.0],[1.0]],"peak_flops":1.0,"h_q":1.0}"#,
                "shape",
            ),
            // Degenerate peak throughput: predict would return inf.
            (
                r#"{"q_grid":[1,2],"kv_grid":[1,2],"latency":[[1.0,1.0],[1.0,1.0]],"peak_flops":0.0,"h_q":1.0}"#,
                "peak_flops",
            ),
            // Non-numeric grid coordinate: `as_f64_vec` drops it, so
            // the truncated axis surfaces as a shape mismatch.
            (
                r#"{"q_grid":[1,"x"],"kv_grid":[1,2],"latency":[[1.0,1.0],[1.0,1.0]],"peak_flops":1.0,"h_q":1.0}"#,
                "shape",
            ),
        ];
        for (src, needle) in cases {
            let j = crate::util::json::parse(src).unwrap();
            let err = Profiler::from_json(&j)
                .expect_err(&format!("should reject: {src}"))
                .to_string();
            assert!(err.contains(needle), "error `{err}` should mention `{needle}`");
        }
    }

    #[test]
    fn tiny_valid_grid_predicts_without_panicking() {
        // One-point and two-point grids exercise the bracket edges.
        let j = crate::util::json::parse(
            r#"{"q_grid":[128],"kv_grid":[128],"latency":[[1e-5]],"peak_flops":1e12,"h_q":1.0}"#,
        )
        .unwrap();
        let p = Profiler::from_json(&j).unwrap();
        for (q, kv) in [(1.0, 1.0), (128.0, 128.0), (1e6, 1e6)] {
            let pred = p.predict(q, kv);
            assert!(pred.is_finite() && pred >= 0.0, "predict({q},{kv}) = {pred}");
        }
        assert!(p.predict_batch(&[(64.0, 64.0), (256.0, 512.0)]).is_finite());
        assert_eq!(p.predict_batch(&[]), 0.0);
    }
}
