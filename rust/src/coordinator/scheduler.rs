//! Communication-aware greedy scheduling (§4.2), heterogeneity-aware.
//!
//! Input: a batch of head-tail [`Item`]s (each resident on its home
//! device) and one [`ServerBelief`] per attention server — the believed
//! execution speed plus the transient-arena byte budget. Output: a
//! [`Plan`] assigning every (possibly split) Item to a server such that
//!
//! 1. per-server CA *time* is within `ε·T̄` of the ideal makespan
//!    `T̄ = Σ cost / Σ speed`: loads are balanced in **estimated
//!    seconds** (`item_cost / believed speed`), not raw FLOPs, so a
//!    server believed 4× slow receives ~¼ the work *at plan time*
//!    instead of being rescued post-hoc by re-dispatch (with uniform
//!    beliefs this degenerates to the paper's FLOPs balance exactly),
//! 2. communication volume is greedily minimized: each migration picks
//!    the candidate with the highest priority `E = ΔF_max / V_comm`
//!    (compute moved per byte), where `ΔF_max = min(F_item, S_source,
//!    D_destination)` and partial moves use Appendix B's
//!    minimal-communication outer sub-shard, and
//! 3. (with a byte budget in force — `SchedulerCfg::mem_budget` or the
//!    per-server `ServerBelief::mem_budget` override) every server's
//!    transient arena — the in-place Q+KV bytes of its assigned
//!    CA-tasks, §5 / Fig. 3b — stays under the hard byte budget: a
//!    repair pre-pass drains overfull home placements, and migrations
//!    that would overflow the destination are rejected or partial-split
//!    to fit, each checked against its *own destination's* budget.
//!
//! A useful identity (proved in `item.rs` tests): a head-tail Item's CA
//! FLOPs are *exactly proportional to its width* — `pairs = W·(l+1)` —
//! so a ΔF-sized sub-shard is simply `α·W` wide, and the KV prefix
//! `[0, l-i)` is a fixed per-item transfer cost regardless of how little
//! Q moves. The E-ranking therefore naturally prefers (a) whole-item
//! moves, (b) long documents (quadratic compute per linear KV bytes),
//! exactly the behaviours §3.3 calls out.

use crate::config::ModelConfig;
use crate::model::FlopsModel;

use super::item::Item;
use super::plan::{Assignment, Plan};
use super::profiler::Profiler;

/// Scheduler knobs.
#[derive(Debug, Clone)]
pub struct SchedulerCfg {
    /// Imbalance tolerance ε (§4.2 step 3, Fig. 12): stop balancing a
    /// server once its load is within `ε·F̄`.
    pub tolerance: f64,
    /// Minimum migration efficiency (FLOPs per byte) to accept a move;
    /// below this, remaining moves are "insignificant migrations".
    pub min_efficiency: f64,
    /// Safety valve on migration rounds.
    pub max_moves: usize,
    /// Per-server dispatch bandwidth (bytes/s). When non-zero, the
    /// scheduler refuses migrations whose cumulative receive time at the
    /// destination would exceed the per-layer overlap window — the
    /// Appendix A condition `t·l ≥ bytes/B` that keeps communication
    /// hideable under the ping-pong schedule. 0 disables the check.
    pub server_bw: f64,
    /// Extra per-layer compute (seconds) available to hide communication
    /// under, beyond the CA target itself (the context-independent
    /// layers' time — Appendix A's `t·l`).
    pub extra_window: f64,
    /// Fraction of the window communication may fill (headroom).
    pub overlap_frac: f64,
    /// Hard per-server transient-arena byte budget (§5, Fig. 3b): the
    /// in-place Q+KV bytes of a server's assigned CA-tasks may not
    /// exceed this. A memory-repair pre-pass first moves work off
    /// servers whose seeded (home) load already overflows; the balancing
    /// loop then rejects — or partial-splits down to fit — any migration
    /// that would overflow the destination's arena, so emitted plans are
    /// feasible in bytes as well as balanced in FLOPs. Infeasible
    /// budgets (a shard that fits nowhere) degrade to best effort.
    /// 0.0 disables memory-aware planning. This is the *uniform* budget;
    /// [`ServerBelief::mem_budget`] overrides it per server.
    pub mem_budget: f64,
}

/// Per-server planning belief: what the scheduler assumes about one
/// attention server's execution speed and arena headroom (ROADMAP's
/// "belief-speed-aware scheduler" + "belief-byte-aware" follow-ups).
///
/// Sourced from the elastic layer: speeds come from
/// [`crate::elastic::ServerPool::believed_speeds`] (scripted slowdowns
/// and health-driven gray demotions), budgets from the §5 memory model
/// ([`crate::memplan::MemReport`] / per-server `Arena` limits).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerBelief {
    /// Believed execution-rate multiplier (1.0 = nominal, 0.25 = four
    /// times slower). Must be positive and finite.
    pub speed: f64,
    /// Hard transient-arena byte budget for this server; 0 falls back
    /// to the uniform [`SchedulerCfg::mem_budget`].
    pub mem_budget: f64,
}

impl Default for ServerBelief {
    fn default() -> ServerBelief {
        ServerBelief { speed: 1.0, mem_budget: 0.0 }
    }
}

impl ServerBelief {
    /// Nominal belief: full speed, no per-server byte budget.
    pub fn nominal() -> ServerBelief {
        ServerBelief::default()
    }

    /// One belief per entry of `speeds`, all sharing `mem_budget`.
    pub fn from_speeds(speeds: &[f64], mem_budget: f64) -> Vec<ServerBelief> {
        speeds.iter().map(|&speed| ServerBelief { speed, mem_budget }).collect()
    }
}

/// Aggregate pool capacity under the current beliefs — the supply side
/// of the gateway's admission decision. Where [`schedule_with_beliefs`]
/// answers "who runs what", this answers the coarser question the
/// admission controller needs *before* a wave exists: how much work and
/// how many bytes can the pool absorb per wave at all.
///
/// Both budgets are believed quantities, not measurements: speeds come
/// from the same [`ServerBelief`]s the planner balances against (gray
/// demotions and scripted slowdowns shrink them), byte headroom from
/// the §5 per-server arena budgets. A wave admitted against this
/// estimate is therefore exactly a wave the planner can place without
/// repair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolCapacity {
    /// Sum of believed speed multipliers over schedulable servers — the
    /// pool's work-per-wave throughput in nominal-server units.
    pub total_speed: f64,
    /// Sum of per-server arena byte budgets (`0` entries fall back to
    /// `uniform_budget`); `0.0` when no budget is in force anywhere,
    /// meaning byte admission is unbounded.
    pub total_bytes: f64,
    /// Servers contributing capacity (believed speed > 0).
    pub n_servers: usize,
}

impl PoolCapacity {
    /// Aggregate `beliefs` (one per schedulable server). `uniform_budget`
    /// plays the role of [`SchedulerCfg::mem_budget`]: the per-server
    /// fallback wherever a belief carries no byte budget of its own.
    pub fn from_beliefs(beliefs: &[ServerBelief], uniform_budget: f64) -> PoolCapacity {
        let mut cap = PoolCapacity { total_speed: 0.0, total_bytes: 0.0, n_servers: 0 };
        for b in beliefs {
            if b.speed <= 0.0 {
                continue;
            }
            cap.total_speed += b.speed;
            cap.total_bytes += if b.mem_budget > 0.0 { b.mem_budget } else { uniform_budget };
            cap.n_servers += 1;
        }
        cap
    }

    /// Causal-pair budget of one wave: how much CA work the pool can
    /// believe-complete inside `wave_seconds`, at `pairs_per_second`
    /// pairs per nominal server. The admission controller stops
    /// admitting once a wave's summed `q_len·kv_len` reaches this.
    pub fn pair_budget(&self, wave_seconds: f64, pairs_per_second: f64) -> f64 {
        self.total_speed * wave_seconds.max(0.0) * pairs_per_second.max(0.0)
    }

    /// Byte budget of one wave, scaled by `fill` (a safety factor in
    /// (0, 1]: admitting to 100% of arena headroom leaves recovery
    /// re-sends nowhere to land). `f64::INFINITY` when no arena budget
    /// is in force.
    pub fn byte_budget(&self, fill: f64) -> f64 {
        if self.total_bytes <= 0.0 {
            f64::INFINITY
        } else {
            self.total_bytes * fill.clamp(0.0, 1.0)
        }
    }
}

impl Default for SchedulerCfg {
    fn default() -> Self {
        Self {
            tolerance: 0.10,
            min_efficiency: 1.0, // 1 FLOP per byte is far below any useful move
            max_moves: 100_000,
            server_bw: 0.0,
            extra_window: 0.0,
            overlap_frac: 1.0,
            mem_budget: 0.0,
        }
    }
}

/// Estimated execution cost (seconds) of an Item's CA on a server.
fn item_cost(item: &Item, prof: &Profiler) -> f64 {
    item.ca_tasks()
        .iter()
        .map(|t| prof.predict(t.q_len as f64, t.kv_len as f64))
        .sum()
}

/// Dispatch bytes to move an Item away from home: Q both halves + KV
/// prefix + O return.
fn item_bytes(item: &Item, m: &ModelConfig) -> f64 {
    super::comm::item_migration_bytes(item, m)
}

/// Transient arena bytes the Item occupies on whichever server runs it
/// (in-place execution: Q + causal KV per CA-task, O reuses Q's slot).
fn item_mem(item: &Item, m: &ModelConfig) -> f64 {
    crate::memplan::item_arena_bytes(item, m)
}

/// Largest grid-quantized outer-shard width (query tokens) of `it` whose
/// arena bytes fit in `headroom`, or `None` when even the minimal shard
/// does not fit (the KV prefix is a fixed per-shard cost — Appendix B —
/// so a shard can be byte-expensive no matter how little Q moves).
///
/// The outer shard of `(l, i, j)` at width `q` is `(l, i, i+q/2)`: two
/// CA-tasks with KV lengths `i + q/2` and `l − i`, so its arena bytes
/// are *affine in q* — `q·qb + (l + q/2)·kvb` — and the widest fitting
/// width is a closed-form inversion (plus a defensive walk-down in case
/// rounding overshoots), not a grid scan.
fn split_to_fit(it: &Item, headroom: f64, m: &ModelConfig) -> Option<usize> {
    let grid = 2 * super::item::BLOCK_TOKENS;
    let qb = m.q_bytes_per_token() as f64;
    let kvb = m.kv_bytes_per_token() as f64;
    let fixed = it.doc_len as f64 * kvb; // the per-shard KV-prefix floor
    if headroom <= fixed {
        return None; // even a zero-width shard's KV does not fit
    }
    let q_max = ((headroom - fixed) / (qb + kvb / 2.0)) as usize;
    let mut q = it.quantize_split(q_max)?;
    // quantize_split clamps into [grid, max]; walk down past any
    // round-up (and verify against the authoritative byte model).
    loop {
        let (outer, _) = it.split_outer(q);
        if item_mem(&outer, m) <= headroom {
            return Some(q);
        }
        if q <= grid {
            return None; // the minimal shard does not fit
        }
        q -= grid;
    }
}

/// Schedule a batch of Items onto `n_servers` *uniform* attention
/// servers (the paper's homogeneous §4.2 setting): nominal beliefs,
/// with `cfg.mem_budget` as the shared arena budget. Delegates to
/// [`schedule_with_beliefs`].
///
/// Items whose `home >= n_servers` panic: homes and servers share the
/// same index space (in-place attention servers, §4.1).
pub fn schedule(
    items: &[Item],
    n_servers: usize,
    f: &FlopsModel,
    prof: &Profiler,
    m: &ModelConfig,
    cfg: &SchedulerCfg,
) -> Plan {
    assert!(n_servers > 0);
    let beliefs = vec![ServerBelief::nominal(); n_servers];
    schedule_with_beliefs(items, &beliefs, f, prof, m, cfg)
}

/// Schedule a batch of Items onto one attention server per entry of
/// `beliefs`, balancing **estimated seconds** (`item_cost / believed
/// speed`) instead of raw FLOPs and holding every server's transient
/// arena under its own byte budget.
///
/// With uniform beliefs (all speeds 1.0, all budgets 0) this is exactly
/// [`schedule`]. The emitted [`Plan`]'s `server_load` is in *believed
/// seconds* and `target_load` is the ideal makespan
/// `T̄ = Σ cost / Σ speed`, so [`Plan::predicted_makespan`] compares
/// directly across belief vectors.
///
/// # Example: a server believed 4× slow gets ~¼ the work at plan time
///
/// ```
/// use distca::config::{ClusterConfig, ModelConfig};
/// use distca::coordinator::{
///     schedule, schedule_with_beliefs, Item, Profiler, SchedulerCfg, ServerBelief,
/// };
/// use distca::model::FlopsModel;
///
/// let m = ModelConfig::llama3_8b();
/// let f = FlopsModel::new(&m);
/// let prof = Profiler::analytic(&f, &ClusterConfig::h200(1));
/// let items: Vec<Item> =
///     (0u32..8).map(|d| Item::whole_doc(d, 8192, d as usize % 2)).collect();
/// let cfg = SchedulerCfg::default();
///
/// let speeds = [0.25, 1.0];
/// let aware =
///     schedule_with_beliefs(&items, &ServerBelief::from_speeds(&speeds, 0.0), &f, &prof, &m, &cfg);
/// let uniform = schedule(&items, 2, &f, &prof, &m, &cfg);
///
/// // Evaluated under the believed speeds, the speed-aware plan's
/// // makespan beats the uniform (FLOPs-balanced) plan's.
/// assert!(aware.predicted_makespan() < uniform.makespan_under(&speeds));
/// ```
pub fn schedule_with_beliefs(
    items: &[Item],
    beliefs: &[ServerBelief],
    f: &FlopsModel,
    prof: &Profiler,
    m: &ModelConfig,
    cfg: &SchedulerCfg,
) -> Plan {
    let n_servers = beliefs.len();
    assert!(n_servers > 0);
    let speeds: Vec<f64> = beliefs
        .iter()
        .map(|b| {
            assert!(b.speed > 0.0 && b.speed.is_finite(), "bad believed speed {}", b.speed);
            b.speed
        })
        .collect();
    // Effective per-server arena budget: the belief's own, else the
    // uniform cfg one; 0 = unconstrained.
    let budget: Vec<f64> = beliefs
        .iter()
        .map(|b| if b.mem_budget > 0.0 { b.mem_budget } else { cfg.mem_budget })
        .collect();
    let mem_aware = budget.iter().any(|&b| b > 0.0);
    let headroom_of = |d: usize, mem: &[f64]| -> f64 {
        if budget[d] > 0.0 {
            budget[d] - mem[d]
        } else {
            f64::INFINITY
        }
    };
    // Per-server worklists, seeded at home. Costs are cached alongside
    // each item: the candidate scan touches every item per move, and
    // profiler interpolation dominated the profile before caching
    // (see EXPERIMENTS.md §Perf).
    // (item, cached CA cost, cached arena bytes) per server.
    let mut server_items: Vec<Vec<(Item, f64, f64)>> = vec![Vec::new(); n_servers];
    // Estimated *seconds* per server under its believed speed.
    let mut load = vec![0.0f64; n_servers];
    // Per-server transient arena bytes (in-place Q+KV of every assigned
    // CA-task) — the quantity the byte budgets hard-bound.
    let mut mem = vec![0.0f64; n_servers];
    let mut total_work = 0.0f64;
    for it in items {
        assert!(it.home < n_servers, "item home {} >= n_servers {n_servers}", it.home);
        let cost = item_cost(it, prof);
        let bytes = item_mem(it, m);
        load[it.home] += cost / speeds[it.home];
        mem[it.home] += bytes;
        total_work += cost;
        server_items[it.home].push((*it, cost, bytes));
    }
    let speed_sum: f64 = speeds.iter().sum();
    // Ideal makespan: every server busy exactly T̄ seconds.
    let target = total_work / speed_sum;
    let tol = cfg.tolerance * target;
    // Appendix-A overlap window: how many dispatch bytes a destination
    // may receive per layer and still hide them under compute.
    let hide_bytes_cap = if cfg.server_bw > 0.0 {
        cfg.overlap_frac * (target + cfg.extra_window) * cfg.server_bw
    } else {
        f64::INFINITY
    };
    let mut recv_bytes = vec![0.0f64; n_servers];

    // Memory-repair pre-pass: seeded (home) placement can overflow the
    // arena budget regardless of FLOPs balance — e.g. every item homed
    // on one survivor after a mass failure. Move the largest items (or
    // the widest shard that fits) toward the max-headroom server until
    // every arena is under budget or nothing movable remains. The
    // balancing loop below never re-overflows a repaired server: splits
    // only shrink the source's bytes and every migration re-checks the
    // destination.
    if mem_aware && n_servers > 1 {
        let mut repair_moves = 0usize;
        while repair_moves < cfg.max_moves {
            // Worst offender: the server most over its *own* budget.
            let src = match (0..n_servers)
                .filter(|&s| budget[s] > 0.0 && mem[s] > budget[s])
                .max_by(|&a, &b| {
                    (mem[a] - budget[a]).partial_cmp(&(mem[b] - budget[b])).unwrap()
                })
            {
                Some(s) => s,
                None => break, // every arena fits
            };
            // Best destination: the most remaining byte headroom under
            // its own budget (unconstrained servers tie at infinity and
            // break toward the fewest resident bytes).
            let dst = match (0..n_servers).filter(|&d| d != src).max_by(|&a, &b| {
                headroom_of(a, &mem)
                    .partial_cmp(&headroom_of(b, &mem))
                    .unwrap()
                    .then(mem[b].partial_cmp(&mem[a]).unwrap())
            }) {
                Some(d) => d,
                None => break,
            };
            let headroom = headroom_of(dst, &mem);
            if headroom <= 0.0 {
                break; // no destination has any arena space left
            }
            // Candidate items, largest bytes first — but an unmovable
            // giant (its minimal shard still carries the full KV prefix)
            // must not block smaller items that fit whole.
            let mut order: Vec<usize> = (0..server_items[src].len()).collect();
            order.sort_by(|&a, &b| {
                server_items[src][b]
                    .2
                    .partial_cmp(&server_items[src][a].2)
                    .unwrap()
            });
            let mut moved = false;
            for idx in order {
                let (it, f_item, m_item) = server_items[src][idx];
                if m_item <= headroom {
                    server_items[src].swap_remove(idx);
                    load[src] -= f_item / speeds[src];
                    load[dst] += f_item / speeds[dst];
                    mem[src] -= m_item;
                    mem[dst] += m_item;
                    if it.home != dst {
                        recv_bytes[dst] += item_bytes(&it, m);
                    }
                    server_items[dst].push((it, f_item, m_item));
                    moved = true;
                    break;
                }
                // Whole item does not fit: ship the widest shard the
                // destination can absorb, if any.
                if let Some(q) = split_to_fit(&it, headroom, m) {
                    let (outer, inner) = it.split_outer(q);
                    let (c_outer, c_inner) =
                        (item_cost(&outer, prof), item_cost(&inner, prof));
                    let (m_outer, m_inner) = (item_mem(&outer, m), item_mem(&inner, m));
                    server_items[src][idx] = (inner, c_inner, m_inner);
                    load[src] += (c_inner - f_item) / speeds[src];
                    mem[src] += m_inner - m_item;
                    load[dst] += c_outer / speeds[dst];
                    mem[dst] += m_outer;
                    if outer.home != dst {
                        recv_bytes[dst] += item_bytes(&outer, m);
                    }
                    server_items[dst].push((outer, c_outer, m_outer));
                    moved = true;
                    break;
                }
            }
            if !moved {
                break; // nothing on the worst server fits anywhere: best effort
            }
            repair_moves += 1;
        }
    }

    // Track which (server, item) pairs migrated away from home — those
    // already paid their KV transfer and can be re-split for free-ish,
    // but we keep the model simple: every remote item's bytes are counted
    // once, at final plan construction.
    let mut moves = 0usize;
    loop {
        if moves >= cfg.max_moves {
            break;
        }
        // Most-deficit destination first (step 1: sort by descending deficit).
        let (dst, deficit) = match (0..n_servers)
            .map(|s| (s, target - load[s]))
            .filter(|&(_, d)| d > tol)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        {
            Some(x) => x,
            None => break, // all servers within tolerance
        };

        // Step 2: best candidate across all surplus sources. Deficits
        // and surpluses are *seconds*; candidate work is converted
        // through the believed speeds on both ends.
        // (src, idx, move_cost, efficiency, dispatch_bytes)
        // Arena budget: bytes the destination can still absorb.
        let dst_headroom = headroom_of(dst, &mem);
        // Work (nominal cost) the destination absorbs within its deficit.
        let absorb = deficit * speeds[dst];
        let mut best: Option<(usize, usize, f64, f64, f64)> = None;
        for src in 0..n_servers {
            if src == dst {
                continue;
            }
            // Work the source can shed before dropping below target.
            let surplus = (load[src] - target) * speeds[src];
            if surplus <= 0.0 {
                continue;
            }
            for (idx, &(ref it, f_item, m_item)) in server_items[src].iter().enumerate() {
                if f_item <= 0.0 {
                    continue;
                }
                let df_max = f_item.min(surplus).min(absorb);
                if df_max <= 0.0 {
                    continue;
                }
                // Byte cap: the widest piece of this item the destination
                // arena can hold (whole item, a shard, or nothing).
                let q_byte_cap = if m_item <= dst_headroom {
                    it.q_tokens()
                } else {
                    match split_to_fit(it, dst_headroom, m) {
                        Some(q) => q,
                        None => continue, // no shard of it fits in bytes
                    }
                };
                // Communication: moving to the item's own home is free
                // (it executes where its tensors live).
                let (bytes, movable) = if it.home == dst {
                    // epsilon bytes => enormous E; still byte-capped.
                    (1.0, df_max.min(f_item * q_byte_cap as f64 / it.q_tokens() as f64))
                } else if df_max >= f_item * 0.999 && q_byte_cap == it.q_tokens() {
                    (item_bytes(it, m), f_item)
                } else {
                    // Partial move: Appendix B — KV prefix is fixed, Q/O
                    // scale with the migrated width. Quantize to the
                    // 128-token grid; skip unsplittable items.
                    let alpha = df_max / f_item;
                    let desired_q = ((alpha * it.q_tokens() as f64) as usize).min(q_byte_cap);
                    match it.quantize_split(desired_q) {
                        // Too small to split: whole move only — and only
                        // when the whole item fits the destination arena.
                        None if q_byte_cap == it.q_tokens() => (item_bytes(it, m), f_item),
                        None => continue,
                        Some(q) => {
                            let q = q.min(q_byte_cap);
                            let (outer, _) = it.split_outer(q);
                            (item_bytes(&outer, m), f_item * q as f64 / it.q_tokens() as f64)
                        }
                    }
                };
                // Don't overshoot the destination badly (time terms).
                if movable > absorb * 1.5 && movable < f_item * 0.999 {
                    continue;
                }
                // Appendix-A overlap check: the destination must still be
                // able to hide its cumulative dispatch traffic.
                if it.home != dst && recv_bytes[dst] + bytes > hide_bytes_cap {
                    continue;
                }
                let flops_moved = it.ca_fwd_flops(f) * (movable / f_item);
                let eff = flops_moved / bytes;
                if best.map_or(true, |(_, _, _, be, _)| eff > be) {
                    best = Some((src, idx, movable, eff, bytes));
                }
            }
        }

        let (src, idx, move_cost, eff, move_bytes) = match best {
            Some(b) => b,
            None => break, // nothing movable
        };
        if eff < cfg.min_efficiency {
            break; // step 3: remaining moves are not worth their bytes
        }

        let (it, f_item, m_item) = server_items[src][idx];
        if move_cost >= f_item * 0.999 {
            // Whole-item migration.
            if budget[dst] > 0.0 && mem[dst] + m_item > budget[dst] + 1e-9 {
                break; // defensive: the scan only offers fitting moves
            }
            if it.home != dst {
                recv_bytes[dst] += move_bytes;
            }
            server_items[src].swap_remove(idx);
            server_items[dst].push((it, f_item, m_item));
            load[src] -= f_item / speeds[src];
            load[dst] += f_item / speeds[dst];
            mem[src] -= m_item;
            mem[dst] += m_item;
        } else {
            let alpha = move_cost / f_item;
            let desired_q = (alpha * it.q_tokens() as f64) as usize;
            let q = match it.quantize_split(desired_q) {
                Some(q) => q,
                None => break, // defensive; shouldn't happen
            };
            let (outer, inner) = it.split_outer(q);
            let m_outer = item_mem(&outer, m);
            if budget[dst] > 0.0 && mem[dst] + m_outer > budget[dst] + 1e-9 {
                break; // grid rounding overshot the arena headroom
            }
            if it.home != dst {
                recv_bytes[dst] += move_bytes;
            }
            let c_outer = item_cost(&outer, prof);
            let c_inner = item_cost(&inner, prof);
            let m_inner = item_mem(&inner, m);
            server_items[src][idx] = (inner, c_inner, m_inner);
            server_items[dst].push((outer, c_outer, m_outer));
            load[src] += (c_inner - f_item) / speeds[src];
            load[dst] += c_outer / speeds[dst];
            mem[src] += m_inner - m_item;
            mem[dst] += m_outer;
        }
        moves += 1;
    }

    let mut assignments = Vec::with_capacity(items.len());
    for (s, list) in server_items.iter().enumerate() {
        for (it, _, _) in list {
            assignments.push(Assignment { item: *it, server: s });
        }
    }
    Plan {
        n_servers,
        assignments,
        server_load: load,
        target_load: target,
        comm_matrix: vec![],
        return_matrix: vec![],
    }
    .with_comm(m)
}

/// Convenience: build Items from packed chunks, one home device per chunk
/// (the device that runs the chunk's context-independent layers).
pub fn items_from_chunks(chunks: &[crate::data::Chunk]) -> Vec<Item> {
    let mut items = Vec::new();
    for (dev, chunk) in chunks.iter().enumerate() {
        for p in &chunk.pieces {
            // Pieces that are slices of a split document enter as
            // head-tail items over their own slice (the slice is the
            // schedulable unit; its causal context is handled at CA-task
            // level through the offset).
            let mut len = p.len;
            if len % 2 != 0 {
                len -= 1; // drop an odd token from scheduling granularity
            }
            if len == 0 {
                continue;
            }
            if p.offset == 0 {
                items.push(Item::whole_doc(p.doc, len, dev));
            } else {
                // A mid-document slice [offset, offset+len): represent as
                // an Item of the *virtual* document [0, offset+len) whose
                // head-tail ranges cover exactly this slice. Choosing
                // i = offset, j = offset + len/2 gives head+tail =
                // [offset, offset+len) when mirrored about the slice end:
                // doc_len' = 2·offset + len keeps tail = [offset+len/2,
                // offset+len).
                let virt_len = 2 * p.offset + len;
                items.push(Item {
                    doc: p.doc,
                    doc_len: virt_len,
                    i: p.offset,
                    j: p.offset + len / 2,
                    home: dev,
                });
            }
        }
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, ModelConfig};
    use crate::coordinator::item::BLOCK_TOKENS;
    use crate::util::quickcheck::{check, ensure};
    use crate::util::rng::Rng;

    fn setup() -> (FlopsModel, Profiler, ModelConfig) {
        let m = ModelConfig::llama3_8b();
        let f = FlopsModel::new(&m);
        let prof = Profiler::analytic(&f, &ClusterConfig::h200(1));
        (f, prof, m)
    }

    fn whole(doc: u32, len: usize, home: usize) -> Item {
        Item::whole_doc(doc, len, home)
    }

    #[test]
    fn already_balanced_no_moves() {
        let (f, prof, m) = setup();
        let items = vec![whole(0, 8192, 0), whole(1, 8192, 1)];
        let plan = schedule(&items, 2, &f, &prof, &m, &SchedulerCfg::default());
        assert_eq!(plan.local_fraction(), 1.0);
        assert_eq!(plan.total_comm_bytes(), 0.0);
        plan.validate(&items, &f).unwrap();
    }

    #[test]
    fn fig1_imbalance_resolved() {
        // The motivating example: one 4×1K chunk vs one 1×4K chunk.
        let (f, prof, m) = setup();
        let mut items = vec![whole(0, 4096, 0)];
        for d in 1..=4 {
            items.push(whole(d, 1024, 1));
        }
        let before: f64 = {
            let l0: f64 = items[..1].iter().map(|i| i.ca_fwd_flops(&f)).sum();
            let l1: f64 = items[1..].iter().map(|i| i.ca_fwd_flops(&f)).sum();
            l0 / l1
        };
        assert!(before > 3.5, "premise: imbalance ~4x, got {before}");
        let plan = schedule(&items, 2, &f, &prof, &m, &SchedulerCfg::default());
        plan.validate(&items, &f).unwrap();
        assert!(
            plan.imbalance() < 1.0 + 0.12,
            "imbalance {} should be within tolerance",
            plan.imbalance()
        );
        assert!(plan.total_comm_bytes() > 0.0, "must have moved something");
    }

    #[test]
    fn tolerance_respected_when_feasible() {
        let (f, prof, m) = setup();
        let mut rng = Rng::new(99);
        let mut items = Vec::new();
        for d in 0..32 {
            let len = (rng.gen_range(8, 256) * 256) as usize;
            items.push(whole(d, len, (d % 8) as usize));
        }
        for &tol in &[0.05, 0.1, 0.3] {
            let cfg = SchedulerCfg { tolerance: tol, ..Default::default() };
            let plan = schedule(&items, 8, &f, &prof, &m, &cfg);
            plan.validate(&items, &f).unwrap();
            let max = plan.server_load.iter().cloned().fold(0.0, f64::max);
            assert!(
                max <= plan.target_load * (1.0 + tol) + 1e-9,
                "tol {tol}: max {max} > target {} * (1+tol)",
                plan.target_load
            );
        }
    }

    #[test]
    fn lower_tolerance_more_comm() {
        // Fig. 12's trade-off: tighter balance costs more bytes.
        let (f, prof, m) = setup();
        let mut rng = Rng::new(7);
        let mut items = Vec::new();
        for d in 0..48 {
            let len = (rng.gen_range(4, 200) * 256) as usize;
            items.push(whole(d, len, (d % 8) as usize));
        }
        let comm_at = |tol: f64| {
            let cfg = SchedulerCfg { tolerance: tol, ..Default::default() };
            schedule(&items, 8, &f, &prof, &m, &cfg).total_comm_bytes()
        };
        let tight = comm_at(0.01);
        let loose = comm_at(0.40);
        assert!(tight >= loose, "tight {tight} < loose {loose}");
    }

    #[test]
    fn splits_are_block_aligned() {
        let (f, prof, m) = setup();
        // One giant doc on server 0, nothing elsewhere: must split.
        let items = vec![whole(0, 65536, 0)];
        let plan = schedule(&items, 4, &f, &prof, &m, &SchedulerCfg::default());
        plan.validate(&items, &f).unwrap();
        assert!(plan.assignments.len() > 1, "giant doc must be split");
        for a in &plan.assignments {
            // every shard half is a multiple of 128 except possibly the
            // innermost remainder piece (document tail)
            let w = a.item.half_width();
            if a.item.i != 0 || a.item.j * 2 != a.item.doc_len {
                // split pieces: outer ones start at i multiple of 128
                assert_eq!(a.item.i % BLOCK_TOKENS, 0, "i not aligned: {:?}", a.item);
            }
            assert!(w > 0);
        }
        assert!(plan.imbalance() < 1.15, "imbalance {}", plan.imbalance());
    }

    #[test]
    fn migration_prefers_long_documents() {
        // §3.3: the scheduler shards long docs (high FLOPs/byte), not
        // short ones.
        let (f, prof, m) = setup();
        let mut items = vec![whole(0, 32768, 0), whole(1, 32768, 0)];
        for d in 2..18 {
            items.push(whole(d, 2048, 0));
        }
        // server 1 idle; migrations should come from the long docs.
        let plan = schedule(&items, 2, &f, &prof, &m, &SchedulerCfg::default());
        plan.validate(&items, &f).unwrap();
        let migrated_short = plan
            .assignments
            .iter()
            .filter(|a| !a.is_local() && a.item.doc >= 2)
            .count();
        let migrated_long = plan
            .assignments
            .iter()
            .filter(|a| !a.is_local() && a.item.doc < 2)
            .count();
        assert!(
            migrated_long > 0 && migrated_short <= migrated_long,
            "long {migrated_long} short {migrated_short}"
        );
    }

    #[test]
    fn conservation_property() {
        let (f, prof, m) = setup();
        check(
            30,
            |r: &mut Rng| {
                let n = r.gen_index(1, 24);
                (0..n as u64)
                    .map(|_d| {
                        (
                            r.gen_range(1, 128) * 256, // len
                            r.gen_range(0, 4),          // home
                        )
                    })
                    .map(|(l, h)| (l, h))
                    .collect::<Vec<(u64, u64)>>()
            },
            |spec| {
                let items: Vec<Item> = spec
                    .iter()
                    .enumerate()
                    .map(|(d, &(l, h))| whole(d as u32, l as usize, h as usize))
                    .collect();
                if items.is_empty() {
                    return Ok(());
                }
                let plan = schedule(&items, 4, &f, &prof, &m, &SchedulerCfg::default());
                plan.validate(&items, &f).map_err(|e| e)?;
                ensure(
                    plan.assignments.len() >= items.len(),
                    "assignments cannot shrink",
                )
            },
        );
    }

    #[test]
    fn single_server_identity() {
        let (f, prof, m) = setup();
        let items = vec![whole(0, 4096, 0), whole(1, 8192, 0)];
        let plan = schedule(&items, 1, &f, &prof, &m, &SchedulerCfg::default());
        assert_eq!(plan.assignments.len(), 2);
        assert_eq!(plan.total_comm_bytes(), 0.0);
    }

    #[test]
    fn empty_batch() {
        let (f, prof, m) = setup();
        let plan = schedule(&[], 4, &f, &prof, &m, &SchedulerCfg::default());
        assert!(plan.assignments.is_empty());
        assert_eq!(plan.imbalance(), 1.0);
    }

    // ----- failover-transition degenerates ------------------------------
    // These are exactly the states the elastic pool passes through when
    // membership collapses or work concentrates: they must neither panic
    // nor emit invalid plans.

    #[test]
    fn empty_batch_single_server() {
        // A drained-down pool between batches: 1 server, nothing to do.
        let (f, prof, m) = setup();
        let plan = schedule(&[], 1, &f, &prof, &m, &SchedulerCfg::default());
        assert!(plan.assignments.is_empty());
        assert_eq!(plan.n_servers, 1);
        assert_eq!(plan.total_comm_bytes(), 0.0);
        plan.validate(&[], &f).unwrap();
    }

    #[test]
    fn all_items_homed_on_one_server_spread_out() {
        // After a mass failure + rejoin, every surviving item can be
        // homed on the single server that stayed up; the scheduler must
        // spread the load across the recovered pool.
        let (f, prof, m) = setup();
        let items: Vec<Item> = (0..16)
            .map(|d| whole(d, 8192, 0))
            .collect();
        let plan = schedule(&items, 8, &f, &prof, &m, &SchedulerCfg::default());
        plan.validate(&items, &f).unwrap();
        assert!(
            plan.imbalance() < 1.25,
            "one-home batch must still balance: {}",
            plan.imbalance()
        );
        let used: std::collections::BTreeSet<usize> =
            plan.assignments.iter().map(|a| a.server).collect();
        assert!(used.len() > 1, "work must leave the overloaded home");
    }

    #[test]
    fn single_heavy_item_single_server() {
        // Failover end state: one server left, one giant doc. Nothing to
        // balance against — the plan is the identity and must be valid.
        let (f, prof, m) = setup();
        let items = vec![whole(0, 131_072, 0)];
        let plan = schedule(&items, 1, &f, &prof, &m, &SchedulerCfg::default());
        plan.validate(&items, &f).unwrap();
        assert_eq!(plan.assignments.len(), 1);
        assert_eq!(plan.total_comm_bytes(), 0.0);
        assert_eq!(plan.imbalance(), 1.0);
    }

    #[test]
    fn more_servers_than_items() {
        // A freshly grown pool can exceed the batch's parallelism; spare
        // servers idle (or receive shards) without invalidating the plan.
        let (f, prof, m) = setup();
        let items = vec![whole(0, 4096, 0), whole(1, 4096, 1)];
        let plan = schedule(&items, 8, &f, &prof, &m, &SchedulerCfg::default());
        plan.validate(&items, &f).unwrap();
        assert!(plan.assignments.len() >= items.len());
    }

    #[test]
    fn zero_length_pieces_are_dropped_not_scheduled() {
        // items_from_chunks drops empty/odd residue pieces; the scheduler
        // must cope with the resulting sparse batch.
        let docs = vec![crate::data::Document::new(0, 1)];
        let chunks = crate::data::pack_fixed(&docs, 4096);
        let items = items_from_chunks(&chunks);
        assert!(items.is_empty(), "a 1-token doc cannot be scheduled");
    }

    #[test]
    fn items_from_chunks_roundtrip() {
        let docs = vec![
            crate::data::Document::new(0, 4096),
            crate::data::Document::new(1, 6144),
        ];
        let chunks = crate::data::pack_fixed(&docs, 5120);
        let items = items_from_chunks(&chunks);
        let total: usize = items.iter().map(|i| i.q_tokens()).sum();
        assert_eq!(total, 4096 + 6144);
        // Homes match chunk indices.
        assert!(items.iter().all(|i| (i.home) < chunks.len()));
    }

    // ----- memory-aware planning (§5, Fig. 3b) ---------------------------

    fn plan_peaks(plan: &crate::coordinator::Plan, m: &ModelConfig) -> Vec<f64> {
        crate::memplan::MemReport::for_plan(plan, m, 0.0)
            .unwrap()
            .per_server_peak
    }

    #[test]
    fn mem_budget_zero_leaves_plans_unconstrained() {
        // Budget 0 must take the exact legacy code path: identical plans.
        let (f, prof, m) = setup();
        let mut rng = Rng::new(11);
        let items: Vec<Item> = (0..24)
            .map(|d| whole(d, (rng.gen_range(8, 128) * 256) as usize, (d % 4) as usize))
            .collect();
        let a = schedule(&items, 4, &f, &prof, &m, &SchedulerCfg::default());
        let b = schedule(
            &items,
            4,
            &f,
            &prof,
            &m,
            &SchedulerCfg { mem_budget: 0.0, ..Default::default() },
        );
        assert_eq!(a.assignments.len(), b.assignments.len());
        assert_eq!(a.server_load, b.server_load);
    }

    #[test]
    fn mem_repair_drains_overfull_home() {
        // Mass-failure aftermath: every item homed on server 0. A finite
        // budget must spread the arena bytes even before FLOPs balancing.
        let (f, prof, m) = setup();
        let items: Vec<Item> = (0..16).map(|d| whole(d, 8192, 0)).collect();
        let total_bytes: f64 = items
            .iter()
            .map(|it| crate::memplan::item_arena_bytes(it, &m))
            .sum();
        let budget = 1.4 * total_bytes / 4.0;
        let cfg = SchedulerCfg { mem_budget: budget, ..Default::default() };
        let plan = schedule(&items, 4, &f, &prof, &m, &cfg);
        plan.validate(&items, &f).unwrap();
        for (s, &p) in plan_peaks(&plan, &m).iter().enumerate() {
            assert!(p <= budget + 1e-6, "server {s} peak {p} exceeds budget {budget}");
        }
        // A feasible budget must not wreck compute balance.
        assert!(
            plan.imbalance() < 1.30,
            "memory-feasible plan too imbalanced: {}",
            plan.imbalance()
        );
    }

    #[test]
    fn mem_repair_skips_unmovable_giant() {
        // The overfull server's largest item (a giant doc whose minimal
        // shard still carries the full KV prefix) fits nowhere — repair
        // must move the small docs instead of giving up.
        let (f, prof, m) = setup();
        let giant0 = whole(0, 65536, 0);
        let giant1 = whole(1, 65536, 1);
        let g_bytes = crate::memplan::item_arena_bytes(&giant0, &m);
        let small_bytes = crate::memplan::item_arena_bytes(&whole(9, 512, 0), &m);
        let mut items = vec![giant0, giant1];
        for d in 2..10 {
            items.push(whole(d, 512, 0));
        }
        // Each giant plus ~7.5 smalls fits; server 0 (giant + 8 smalls)
        // does not, and server 1's headroom is far below any giant shard.
        let budget = g_bytes + 7.5 * small_bytes;
        let cfg = SchedulerCfg { mem_budget: budget, ..Default::default() };
        let plan = schedule(&items, 2, &f, &prof, &m, &cfg);
        plan.validate(&items, &f).unwrap();
        for (s, &p) in plan_peaks(&plan, &m).iter().enumerate() {
            assert!(p <= budget + 1e-6, "server {s} peak {p} exceeds budget {budget}");
        }
    }

    #[test]
    fn mem_budget_bounds_giant_doc_shards() {
        // One giant doc: shards carry the full KV prefix, so per-server
        // bytes are irreducible below ~doc KV. A budget slightly above
        // the whole item's bytes must still admit a valid, feasible plan.
        let (f, prof, m) = setup();
        let items = vec![whole(0, 65536, 0)];
        let whole_bytes = crate::memplan::item_arena_bytes(&items[0], &m);
        let budget = 1.25 * whole_bytes;
        let cfg = SchedulerCfg { mem_budget: budget, ..Default::default() };
        let plan = schedule(&items, 4, &f, &prof, &m, &cfg);
        plan.validate(&items, &f).unwrap();
        for &p in &plan_peaks(&plan, &m) {
            assert!(p <= budget + 1e-6, "peak {p} exceeds budget {budget}");
        }
    }

    #[test]
    fn infeasible_budget_degrades_to_best_effort() {
        // A budget below any single shard's bytes cannot be satisfied;
        // the scheduler must neither panic nor lose tokens.
        let (f, prof, m) = setup();
        let items = vec![whole(0, 32768, 0), whole(1, 32768, 0)];
        let cfg = SchedulerCfg { mem_budget: 1.0, ..Default::default() };
        let plan = schedule(&items, 4, &f, &prof, &m, &cfg);
        plan.validate(&items, &f).unwrap();
        assert!(plan.assignments.len() >= items.len());
    }

    #[test]
    fn split_to_fit_is_monotone_and_byte_safe() {
        let (_f, _prof, m) = setup();
        let it = whole(0, 65536, 0);
        let whole_bytes = crate::memplan::item_arena_bytes(&it, &m);
        // Generous headroom: the widest splittable shard fits.
        let q_max = split_to_fit(&it, whole_bytes, &m).unwrap();
        assert!(q_max >= 2 * BLOCK_TOKENS && q_max < it.q_tokens());
        // Shard bytes at the returned width respect the headroom.
        for frac in [0.55, 0.7, 0.9] {
            let headroom = whole_bytes * frac;
            if let Some(q) = split_to_fit(&it, headroom, &m) {
                let (outer, _) = it.split_outer(q);
                assert!(crate::memplan::item_arena_bytes(&outer, &m) <= headroom);
            }
        }
        // A headroom below the minimal shard's bytes yields None.
        assert!(split_to_fit(&it, 1.0, &m).is_none());
    }

    // ----- belief-aware planning (heterogeneous servers) -----------------

    #[test]
    fn uniform_beliefs_reproduce_schedule_exactly() {
        let (f, prof, m) = setup();
        let mut rng = Rng::new(5);
        let items: Vec<Item> = (0..24)
            .map(|d| whole(d, (rng.gen_range(4, 96) * 256) as usize, (d % 4) as usize))
            .collect();
        let cfg = SchedulerCfg::default();
        let nominal = vec![ServerBelief::nominal(); 4];
        let a = schedule(&items, 4, &f, &prof, &m, &cfg);
        let b = schedule_with_beliefs(&items, &nominal, &f, &prof, &m, &cfg);
        assert_eq!(a.server_load, b.server_load);
        assert_eq!(a.assignments.len(), b.assignments.len());
        assert_eq!(a.target_load, b.target_load);
    }

    #[test]
    fn slow_belief_receives_proportionally_less_work() {
        let (f, prof, m) = setup();
        let items: Vec<Item> = (0..16).map(|d| whole(d, 8192, (d % 4) as usize)).collect();
        let speeds = [1.0, 0.25, 1.0, 1.0];
        let plan = schedule_with_beliefs(
            &items,
            &ServerBelief::from_speeds(&speeds, 0.0),
            &f,
            &prof,
            &m,
            &SchedulerCfg::default(),
        );
        plan.validate(&items, &f).unwrap();
        // server_load is believed seconds: time balance within tolerance.
        assert!(
            plan.predicted_makespan() <= plan.target_load * 1.25,
            "makespan {} vs ideal {}",
            plan.predicted_makespan(),
            plan.target_load
        );
        // Nominal *work* on the slow server is ~its speed share:
        // ideal 0.25/3.25 ≈ 7.7% of the total; allow generous slack.
        let work: Vec<f64> = (0..4)
            .map(|s| plan.server_load[s] * speeds[s])
            .collect();
        let total: f64 = work.iter().sum();
        assert!(
            work[1] < 0.20 * total,
            "slow server kept {} of {total} work",
            work[1]
        );
        assert!(
            work[1] < work[0] && work[1] < work[2] && work[1] < work[3],
            "the believed-slow server must hold the least work: {work:?}"
        );
    }

    #[test]
    fn per_server_budgets_bound_each_destination() {
        // Two servers with tight budgets, two without: repair and
        // migration must respect each destination's own budget.
        let (f, prof, m) = setup();
        let items: Vec<Item> = (0..12).map(|d| whole(d, 8192, (d % 4) as usize)).collect();
        let per_item = crate::memplan::item_arena_bytes(&items[0], &m);
        let beliefs = vec![
            ServerBelief { speed: 1.0, mem_budget: 2.5 * per_item },
            ServerBelief { speed: 1.0, mem_budget: 2.5 * per_item },
            ServerBelief { speed: 1.0, mem_budget: 0.0 },
            ServerBelief { speed: 1.0, mem_budget: 0.0 },
        ];
        let plan = schedule_with_beliefs(
            &items,
            &beliefs,
            &f,
            &prof,
            &m,
            &SchedulerCfg::default(),
        );
        plan.validate(&items, &f).unwrap();
        let peaks = plan_peaks(&plan, &m);
        for s in 0..2 {
            assert!(
                peaks[s] <= 2.5 * per_item + 1e-6,
                "server {s} peak {} exceeds its own budget {}",
                peaks[s],
                2.5 * per_item
            );
        }
    }

    #[test]
    fn belief_budget_overrides_uniform_cfg_budget() {
        let (f, prof, m) = setup();
        let items: Vec<Item> = (0..8).map(|d| whole(d, 8192, 0)).collect();
        let per_item = crate::memplan::item_arena_bytes(&items[0], &m);
        // Uniform cfg budget is generous; server 0's belief tightens it.
        let beliefs = vec![
            ServerBelief { speed: 1.0, mem_budget: 1.5 * per_item },
            ServerBelief::nominal(),
        ];
        let cfg = SchedulerCfg { mem_budget: 100.0 * per_item, ..Default::default() };
        let plan = schedule_with_beliefs(&items, &beliefs, &f, &prof, &m, &cfg);
        plan.validate(&items, &f).unwrap();
        let peaks = plan_peaks(&plan, &m);
        assert!(
            peaks[0] <= 1.5 * per_item + 1e-6,
            "belief budget must override the uniform one: peak {}",
            peaks[0]
        );
    }

    #[test]
    fn midslice_item_flops_match_taskwise() {
        // An Item built from a mid-document slice must cost exactly the
        // causal FLOPs of that slice.
        let (f, _prof, _m) = setup();
        let chunks = crate::data::pack_fixed(&[crate::data::Document::new(0, 10000)], 4096);
        let items = items_from_chunks(&chunks);
        let got: f64 = items.iter().map(|i| i.ca_fwd_flops(&f)).sum();
        // Slices: [0,4096) offset 0 (even), [4096,8192) offset 4096,
        // [8192,10000) len 1808 even. Expected via ca_task_fwd:
        let expect = f.ca_task_fwd(4096, 0) + f.ca_task_fwd(4096, 4096) + f.ca_task_fwd(1808, 8192);
        assert!((got - expect).abs() / expect < 1e-9, "{got} vs {expect}");
    }
}
