//! Scheduling units (§4.2 "Scheduling units") and CA-tasks (§4.1).
//!
//! An [`Item`] is a document or a shard of one, kept in **head-tail**
//! form (Appendix B): the Item `(l, i, j)` owns the query tokens
//! `[i, j)` *and* the mirror range `[l-j, l-i)` of a length-`l` document.
//! A whole document is `(l, 0, ⌈l/2⌉)`. Head-tail pairing makes FLOPs a
//! function of width only (not position), which is what keeps
//! FLOPs-based cost estimation accurate (Appendix B's closing remark) —
//! and the pair algebra is closed under splitting:
//! `(l, i, j) → (l, i, k) + (l, k, j)`.
//!
//! Each Item maps to (up to) two [`CaTask`]s — one per half — each being
//! a query shard plus its causal KV context `kv(t) = context(q(t))`.

use crate::model::FlopsModel;

/// Attention-kernel block size in tokens: shards must be multiples of
/// this or they underfill kernel tiles (Fig. 5's 128-token knee).
pub const BLOCK_TOKENS: usize = 128;

/// A head-tail scheduling unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Item {
    pub doc: u32,
    /// Full document length `l`.
    pub doc_len: usize,
    /// Head range start (`i` in Appendix B).
    pub i: usize,
    /// Head range end (`j`); the tail range is `[l-j, l-i)`.
    pub j: usize,
    /// Logical device that computes this Item's context-independent
    /// layers (where its Q/K/V are produced and its O must return).
    pub home: usize,
}

impl Item {
    /// A whole document as one Item. For odd lengths the head gets the
    /// extra token (ranges `[0, ⌈l/2⌉)` + `[⌊l/2⌋... )` overlap by one iff
    /// l is odd — avoided by requiring even `l`; corpus lengths are
    /// 16-aligned per `data::distributions`).
    pub fn whole_doc(doc: u32, doc_len: usize, home: usize) -> Item {
        assert!(doc_len % 2 == 0, "document length must be even, got {doc_len}");
        Item {
            doc,
            doc_len,
            i: 0,
            j: doc_len / 2,
            home,
        }
    }

    /// Query tokens owned (both halves).
    pub fn q_tokens(&self) -> usize {
        2 * (self.j - self.i)
    }

    /// Width of each half.
    pub fn half_width(&self) -> usize {
        self.j - self.i
    }

    /// Forward CA FLOPs of both halves (exact causal accounting).
    pub fn ca_fwd_flops(&self, f: &FlopsModel) -> f64 {
        f.ca_headtail_fwd(self.doc_len, self.i, self.j)
    }

    /// Forward+backward CA FLOPs.
    pub fn ca_train_flops(&self, f: &FlopsModel) -> f64 {
        self.ca_fwd_flops(f) * (1.0 + crate::model::flops::CA_BWD_FACTOR)
    }

    /// KV context tokens required if this Item executes away from home:
    /// the tail half `[l-j, l-i)` needs `KV[0, l-i)`, which subsumes the
    /// head's `KV[0, j)` whenever `j ≤ l-i` (always true for `j ≤ l/2`).
    pub fn kv_context_tokens(&self) -> usize {
        self.doc_len - self.i
    }

    /// Split into `(l, i, k)` and `(l, k, j)` at head position `k`.
    /// Both sub-Items inherit `home`.
    pub fn split_at(&self, k: usize) -> (Item, Item) {
        assert!(self.i < k && k < self.j, "split point {k} outside ({}, {})", self.i, self.j);
        (
            Item { j: k, ..*self },
            Item { i: k, ..*self },
        )
    }

    /// Split so the *outer* sub-Item (the one containing positions `i`
    /// and `l-i`, i.e. the cheapest KV-wise to keep remote) has `n_q`
    /// query tokens. `n_q` must be even and < q_tokens().
    pub fn split_outer(&self, n_q: usize) -> (Item, Item) {
        assert!(n_q % 2 == 0 && n_q > 0 && n_q < self.q_tokens());
        self.split_at(self.i + n_q / 2)
    }

    /// Round a desired query-token count to the kernel block grid
    /// (multiples of `2·BLOCK_TOKENS` — each half a multiple of 128),
    /// clamped to `[2·BLOCK, q_tokens - 2·BLOCK]` so both sides of a
    /// split stay block-aligned and non-empty. Returns `None` if the Item
    /// is too small to split on the grid.
    pub fn quantize_split(&self, desired_q: usize) -> Option<usize> {
        let grid = 2 * BLOCK_TOKENS;
        if self.q_tokens() < 2 * grid {
            return None;
        }
        let max_q = self.q_tokens() - grid;
        let q = (desired_q / grid).max(1) * grid;
        Some(q.clamp(grid, max_q - max_q % grid))
    }

    /// The CA-tasks of this Item: head shard + tail shard (merged into
    /// one when the ranges touch, i.e. the Item is a whole document).
    pub fn ca_tasks(&self) -> Vec<CaTask> {
        let l = self.doc_len;
        if self.j * 2 == l && self.i == 0 {
            // Whole document: one contiguous task [0, l).
            return vec![CaTask {
                doc: self.doc,
                q_start: 0,
                q_len: l,
                kv_len: l,
                home: self.home,
            }];
        }
        let head = CaTask {
            doc: self.doc,
            q_start: self.i,
            q_len: self.j - self.i,
            kv_len: self.j,
            home: self.home,
        };
        let tail = CaTask {
            doc: self.doc,
            q_start: l - self.j,
            q_len: self.j - self.i,
            kv_len: l - self.i,
            home: self.home,
        };
        if head.q_start + head.q_len == tail.q_start {
            // Adjacent halves (whole-doc-with-offset); merge.
            return vec![CaTask {
                doc: self.doc,
                q_start: head.q_start,
                q_len: head.q_len + tail.q_len,
                kv_len: tail.kv_len,
                home: self.home,
            }];
        }
        vec![head, tail]
    }
}

/// A core-attention task `t`: the CA computation of query shard `q(t)`
/// (rows `[q_start, q_start+q_len)` of a document) against its causal
/// context `kv(t) = KV[0, kv_len)` where `kv_len = q_start + q_len`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CaTask {
    pub doc: u32,
    pub q_start: usize,
    pub q_len: usize,
    /// Context length: `q_start + q_len` under the causal mask.
    pub kv_len: usize,
    /// Device where Q/K/V live and O must return.
    pub home: usize,
}

impl CaTask {
    /// Forward FLOPs (exact causal).
    pub fn fwd_flops(&self, f: &FlopsModel) -> f64 {
        f.ca_task_fwd(self.q_len, self.q_start)
    }

    /// Bytes that must move if executed on a server other than `home`:
    /// Q in, KV context in, O out.
    pub fn remote_bytes(&self, q_bytes_per_tok: usize, kv_bytes_per_tok: usize) -> f64 {
        (self.q_len * q_bytes_per_tok      // Q in
            + self.kv_len * kv_bytes_per_tok // KV context in
            + self.q_len * q_bytes_per_tok)  // O back
            as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::quickcheck::{check, ensure};
    use crate::util::rng::Rng;

    fn fm() -> FlopsModel {
        FlopsModel::new(&ModelConfig::llama3_8b())
    }

    #[test]
    fn whole_doc_flops_match_document() {
        let f = fm();
        let it = Item::whole_doc(0, 8192, 0);
        let whole = f.ca_doc_fwd(8192);
        assert!((it.ca_fwd_flops(&f) - whole).abs() / whole < 1e-12);
        assert_eq!(it.q_tokens(), 8192);
        assert_eq!(it.kv_context_tokens(), 8192);
    }

    #[test]
    fn split_conserves_tokens_and_flops() {
        let f = fm();
        let it = Item::whole_doc(0, 16384, 0);
        let (a, b) = it.split_at(2048);
        assert_eq!(a.q_tokens() + b.q_tokens(), it.q_tokens());
        let sum = a.ca_fwd_flops(&f) + b.ca_fwd_flops(&f);
        let whole = it.ca_fwd_flops(&f);
        assert!((sum - whole).abs() / whole < 1e-12);
    }

    #[test]
    fn split_outer_width() {
        let it = Item::whole_doc(0, 16384, 0);
        let (outer, inner) = it.split_outer(4096);
        assert_eq!(outer.q_tokens(), 4096);
        assert_eq!(inner.q_tokens(), 16384 - 4096);
        // The outer piece needs more KV context (it holds the latest
        // tokens of the doc).
        assert!(outer.kv_context_tokens() > inner.kv_context_tokens());
    }

    #[test]
    fn recursive_splits_conserve() {
        let f = fm();
        check(
            60,
            |r: &mut Rng| {
                let l = r.gen_range(8, 512) * 256; // even, big enough
                let splits = r.gen_range(0, 4);
                (l, splits)
            },
            |&(l, splits)| {
                let it = Item::whole_doc(0, l as usize, 0);
                let mut items = vec![it];
                let mut rng = Rng::new(l ^ splits);
                for _ in 0..splits {
                    // Split the widest item if possible.
                    items.sort_by_key(|x| std::cmp::Reverse(x.q_tokens()));
                    let top = items[0];
                    if let Some(q) = top.quantize_split(top.q_tokens() / 2) {
                        let (a, b) = top.split_outer(q);
                        items[0] = a;
                        items.push(b);
                    }
                    let _ = rng.next_u64();
                }
                let tok: usize = items.iter().map(|x| x.q_tokens()).sum();
                let fl: f64 = items.iter().map(|x| x.ca_fwd_flops(&f)).sum();
                let whole = it.ca_fwd_flops(&f);
                ensure(tok == it.q_tokens(), format!("tokens {tok}"))?;
                ensure(
                    (fl - whole).abs() / whole < 1e-9,
                    format!("flops {fl} vs {whole}"),
                )
            },
        );
    }

    #[test]
    fn quantize_split_block_aligned() {
        let it = Item::whole_doc(0, 16384, 0);
        for want in [1, 200, 4000, 16000] {
            if let Some(q) = it.quantize_split(want) {
                assert_eq!(q % (2 * BLOCK_TOKENS), 0);
                assert!(q >= 2 * BLOCK_TOKENS);
                assert!(it.q_tokens() - q >= 2 * BLOCK_TOKENS);
            }
        }
        // Too small to split:
        let small = Item::whole_doc(1, 256, 0);
        assert!(small.quantize_split(128).is_none());
    }

    #[test]
    fn ca_tasks_whole_doc_single() {
        let it = Item::whole_doc(0, 4096, 3);
        let ts = it.ca_tasks();
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].q_len, 4096);
        assert_eq!(ts[0].kv_len, 4096);
        assert_eq!(ts[0].home, 3);
    }

    #[test]
    fn ca_tasks_shard_pair() {
        let it = Item::whole_doc(0, 16384, 0);
        let (outer, inner) = it.split_outer(4096);
        let ts = outer.ca_tasks();
        assert_eq!(ts.len(), 2);
        // head [0, 2048) with kv 2048; tail [14336, 16384) with kv 16384
        assert_eq!((ts[0].q_start, ts[0].q_len, ts[0].kv_len), (0, 2048, 2048));
        assert_eq!((ts[1].q_start, ts[1].q_len, ts[1].kv_len), (14336, 2048, 16384));
        // inner pair merges into its own head-tail
        let ti = inner.ca_tasks();
        assert_eq!(ti.len(), 1); // [2048, 8192) + [8192, 14336) are adjacent
        assert_eq!((ti[0].q_start, ti[0].q_len, ti[0].kv_len), (2048, 12288, 14336));
    }

    #[test]
    fn ca_tasks_flops_match_item() {
        let f = fm();
        let it = Item::whole_doc(0, 32768, 0);
        let (outer, inner) = it.split_outer(8192);
        for x in [outer, inner] {
            let task_sum: f64 = x.ca_tasks().iter().map(|t| t.fwd_flops(&f)).sum();
            let item_flops = x.ca_fwd_flops(&f);
            assert!(
                (task_sum - item_flops).abs() / item_flops < 1e-9,
                "{task_sum} vs {item_flops}"
            );
        }
    }

    #[test]
    fn remote_bytes_counts_q_kv_o() {
        let t = CaTask { doc: 0, q_start: 0, q_len: 100, kv_len: 100, home: 0 };
        let b = t.remote_bytes(10, 4);
        assert_eq!(b, (100 * 10 + 100 * 4 + 100 * 10) as f64);
    }
}
