//! Ping-pong execution (§4.1, Fig. 7): overlap the CA dispatch/return
//! communication of one nano-batch with the computation of the other.
//!
//! Each microbatch is split into two equal-token nano-batches, "ping" and
//! "pong". Per transformer layer, the GPU timeline alternates:
//!
//! ```text
//! compute:  CA(i,0) CA(i,1) | postCA(i,0)+preCA(i+1,0) | postCA(i,1)+preCA(i+1,1) | CA(i+1,0) ...
//! comm:     exit(i,0)/enter(i+1,0) run UNDER the (i,1)-side compute and vice versa
//! ```
//!
//! [`layer_time`] computes the per-layer makespan of this schedule given
//! the four primitive durations, and its degenerate variants model the
//! Fig.-11 ablations: `single_stream` (communication serializes with
//! compute) and `signal_only` (communication is free — the pure
//! compute-imbalance floor).
//!
//! For *elastic* PP execution the module also provides the wave-level
//! bookkeeping: [`Wave`] names the two nano-batch waves of a PP tick,
//! [`split_waves`] partitions a tick's CA-tasks into them, and
//! [`PingPongBuffer`] records, per wave, the membership epoch the wave
//! was dispatched under plus its in-flight task tags — exactly the state
//! the failover layer needs to re-dispatch *only* the wave a mid-tick
//! fault hit while the other wave's communication stays overlapped.

/// Primitive durations for one *nano-batch* at one layer (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NanoCosts {
    /// Context-independent compute around the CA boundary
    /// (post-CA of layer i fused with pre-CA of layer i+1).
    pub linear: f64,
    /// Core-attention execution on this GPU's attention-server role
    /// (its share of the fused batched kernel).
    pub ca: f64,
    /// Dispatch communication (Q/KV out + in) for this nano-batch.
    pub comm_in: f64,
    /// Return communication (O back).
    pub comm_out: f64,
}

impl NanoCosts {
    pub fn total_comm(&self) -> f64 {
        self.comm_in + self.comm_out
    }
}

/// Per-layer time under the ping-pong schedule: each nano-batch's
/// communication overlaps the *other* nano-batch's compute. The layer
/// completes when both nano-batches' compute and comm are done; comm for
/// nano `a` can hide under compute of nano `b` (and vice versa), so the
/// makespan is `max(total_compute, compute_a + comm_b, compute_b +
/// comm_a)` reduced to the standard two-stage overlap bound:
/// `max(C_total, max_i(comm_i) + compute_other_floor)` — we model it as
/// the critical path of the Fig.-7 DAG.
pub fn layer_time_pingpong(ping: NanoCosts, pong: NanoCosts) -> f64 {
    // Compute occupies the GPU serially: CA(0), CA(1), lin(0), lin(1).
    let compute_total = ping.ca + pong.ca + ping.linear + pong.linear;
    // Ping's comm must fit under pong's compute slots and vice versa;
    // if comm exceeds the available overlap window it extends the
    // critical path.
    let ping_window = pong.ca + pong.linear;
    let pong_window = ping.ca + ping.linear;
    let ping_spill = (ping.total_comm() - ping_window).max(0.0);
    let pong_spill = (pong.total_comm() - pong_window).max(0.0);
    compute_total + ping_spill + pong_spill
}

/// Per-layer time with communication on the same stream (no overlap) —
/// the "Single Stream" ablation of Fig. 11.
pub fn layer_time_single_stream(ping: NanoCosts, pong: NanoCosts) -> f64 {
    ping.ca + pong.ca + ping.linear + pong.linear + ping.total_comm() + pong.total_comm()
}

/// Per-layer time when communication is free (1-byte "Signal" ablation):
/// the floor set purely by compute balance.
pub fn layer_time_signal(ping: NanoCosts, pong: NanoCosts) -> f64 {
    ping.ca + pong.ca + ping.linear + pong.linear
}

/// Split a microbatch's costs into two equal nano-batches. Token counts
/// divide evenly; CA and comm divide with the tokens (CA-tasks are
/// token-divisible — the same composability that enables CAD).
pub fn split_nano(linear: f64, ca: f64, comm_in: f64, comm_out: f64) -> (NanoCosts, NanoCosts) {
    let half = |x: f64| x / 2.0;
    let n = NanoCosts {
        linear: half(linear),
        ca: half(ca),
        comm_in: half(comm_in),
        comm_out: half(comm_out),
    };
    (n, n)
}

/// Whether communication is fully hidden at these costs.
pub fn fully_overlapped(ping: NanoCosts, pong: NanoCosts) -> bool {
    (layer_time_pingpong(ping, pong) - layer_time_signal(ping, pong)).abs() < 1e-12
}

// ---------------------------------------------------------------------
// Elastic PP: wave identity and the per-tick double buffer.
// ---------------------------------------------------------------------

/// One of the two nano-batch waves of a PP tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Wave {
    Ping,
    Pong,
}

impl Wave {
    pub const BOTH: [Wave; 2] = [Wave::Ping, Wave::Pong];

    /// The wave whose communication this wave's compute hides.
    pub fn other(self) -> Wave {
        match self {
            Wave::Ping => Wave::Pong,
            Wave::Pong => Wave::Ping,
        }
    }

    pub fn index(self) -> usize {
        match self {
            Wave::Ping => 0,
            Wave::Pong => 1,
        }
    }
}

impl std::fmt::Display for Wave {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Wave::Ping => write!(f, "ping"),
            Wave::Pong => write!(f, "pong"),
        }
    }
}

/// Split a tick's CA-tasks into two near-equal-weight waves: greedy
/// assignment of each task (in input order) to the lighter wave.
/// Deterministic, and balanced within one max task weight. Returns the
/// index sets of (ping, pong).
pub fn split_waves<T>(tasks: &[T], weight: impl Fn(&T) -> f64) -> (Vec<usize>, Vec<usize>) {
    let mut ping = Vec::new();
    let mut pong = Vec::new();
    let (mut wp, mut wq) = (0.0f64, 0.0f64);
    for (i, t) in tasks.iter().enumerate() {
        let w = weight(t);
        if wp <= wq {
            ping.push(i);
            wp += w;
        } else {
            pong.push(i);
            wq += w;
        }
    }
    (ping, pong)
}

/// The per-tick double buffer of elastic ping-pong execution.
///
/// Each wave carries the pool's membership epoch it was dispatched
/// under; a fault that bumps the epoch mid-tick therefore splits the
/// tick's tasks into a *stale* wave (already in flight — its losses are
/// re-dispatched task-by-task) and a *fresh* wave (not yet dispatched —
/// simply re-planned against the new membership, no loss). Completion is
/// first-response-wins at the tag level; the buffer only tracks what is
/// still outstanding per wave.
#[derive(Debug, Clone, Default)]
pub struct PingPongBuffer {
    epochs: [u64; 2],
    dispatched: [bool; 2],
    in_flight: [std::collections::BTreeSet<u64>; 2],
}

impl PingPongBuffer {
    pub fn new() -> PingPongBuffer {
        PingPongBuffer::default()
    }

    /// Record a wave's dispatch: the membership epoch it was planned
    /// against and the tags now in flight.
    pub fn begin_wave(
        &mut self,
        wave: Wave,
        epoch: u64,
        tags: impl IntoIterator<Item = u64>,
    ) {
        let i = wave.index();
        self.epochs[i] = epoch;
        self.dispatched[i] = true;
        self.in_flight[i] = tags.into_iter().collect();
    }

    /// Membership epoch `wave` was dispatched under.
    pub fn epoch_of(&self, wave: Wave) -> u64 {
        self.epochs[wave.index()]
    }

    /// Which wave holds `tag`, if it is still in flight.
    pub fn wave_of(&self, tag: u64) -> Option<Wave> {
        Wave::BOTH
            .into_iter()
            .find(|w| self.in_flight[w.index()].contains(&tag))
    }

    /// Mark `tag` complete; returns the wave it belonged to (None for a
    /// duplicate or unknown tag — first response already won).
    pub fn complete(&mut self, tag: u64) -> Option<Wave> {
        let wave = self.wave_of(tag)?;
        self.in_flight[wave.index()].remove(&tag);
        Some(wave)
    }

    /// Tags still outstanding in `wave`, ascending.
    pub fn in_flight(&self, wave: Wave) -> Vec<u64> {
        self.in_flight[wave.index()].iter().copied().collect()
    }

    /// Total outstanding tags across both waves.
    pub fn outstanding(&self) -> usize {
        self.in_flight.iter().map(|s| s.len()).sum()
    }

    /// A dispatched wave with nothing outstanding has drained.
    pub fn drained(&self, wave: Wave) -> bool {
        self.dispatched[wave.index()] && self.in_flight[wave.index()].is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nano(linear: f64, ca: f64, cin: f64, cout: f64) -> NanoCosts {
        NanoCosts { linear, ca, comm_in: cin, comm_out: cout }
    }

    #[test]
    fn small_comm_fully_hidden() {
        let (p, q) = split_nano(10.0, 6.0, 2.0, 1.0);
        assert!(fully_overlapped(p, q));
        assert_eq!(layer_time_pingpong(p, q), layer_time_signal(p, q));
    }

    #[test]
    fn large_comm_spills() {
        // Comm bigger than the other nano's compute window must extend
        // the makespan, but by less than serial execution.
        let p = nano(1.0, 1.0, 10.0, 5.0);
        let q = nano(1.0, 1.0, 10.0, 5.0);
        let pp = layer_time_pingpong(p, q);
        let ss = layer_time_single_stream(p, q);
        let sig = layer_time_signal(p, q);
        assert!(pp > sig);
        assert!(pp < ss);
        // exact: compute 4, windows 2 each, spill (15-2)*2 = 26 -> 30
        assert!((pp - 30.0).abs() < 1e-9);
        assert!((ss - 34.0).abs() < 1e-9);
    }

    #[test]
    fn single_stream_penalty_shape() {
        // Fig. 11: single stream is 10-17% slower when comm ≈ 10-17% of
        // compute.
        let comm = 0.15;
        let (p, q) = split_nano(0.7, 0.3, comm, comm * 0.3);
        let pp = layer_time_pingpong(p, q);
        let ss = layer_time_single_stream(p, q);
        let ratio = ss / pp;
        assert!(ratio > 1.10 && ratio < 1.25, "ratio {ratio}");
    }

    #[test]
    fn signal_is_lower_bound() {
        for seed in 0..20u64 {
            let mut r = crate::util::rng::Rng::new(seed);
            let p = nano(r.next_f64(), r.next_f64(), r.next_f64(), r.next_f64());
            let q = nano(r.next_f64(), r.next_f64(), r.next_f64(), r.next_f64());
            let sig = layer_time_signal(p, q);
            let pp = layer_time_pingpong(p, q);
            let ss = layer_time_single_stream(p, q);
            assert!(sig <= pp + 1e-12);
            assert!(pp <= ss + 1e-12);
        }
    }

    #[test]
    fn split_halves_everything() {
        let (p, q) = split_nano(8.0, 4.0, 2.0, 1.0);
        assert_eq!(p, q);
        assert_eq!(p.linear, 4.0);
        assert_eq!(p.ca, 2.0);
        assert_eq!(p.total_comm(), 1.5);
    }

    #[test]
    fn split_waves_balances_weights() {
        let ws = [5.0, 3.0, 2.0, 2.0, 1.0, 1.0];
        let (ping, pong) = split_waves(&ws, |&w| w);
        assert_eq!(ping.len() + pong.len(), ws.len());
        let sum = |idx: &[usize]| idx.iter().map(|&i| ws[i]).sum::<f64>();
        let (a, b) = (sum(&ping), sum(&pong));
        assert!((a - b).abs() <= 5.0, "waves {a} vs {b} unbalanced");
        // No index in both waves.
        for i in &ping {
            assert!(!pong.contains(i));
        }
    }

    #[test]
    fn split_waves_empty_and_single() {
        let (ping, pong) = split_waves::<f64>(&[], |_| 1.0);
        assert!(ping.is_empty() && pong.is_empty());
        let (ping, pong) = split_waves(&[7.0], |&w| w);
        assert_eq!(ping, vec![0]);
        assert!(pong.is_empty());
    }

    #[test]
    fn pingpong_buffer_tracks_waves_and_epochs() {
        let mut buf = PingPongBuffer::new();
        buf.begin_wave(Wave::Ping, 3, [10u64, 11, 12]);
        buf.begin_wave(Wave::Pong, 4, [20u64, 21]);
        assert_eq!(buf.epoch_of(Wave::Ping), 3);
        assert_eq!(buf.epoch_of(Wave::Pong), 4);
        assert_eq!(buf.outstanding(), 5);
        assert_eq!(buf.wave_of(11), Some(Wave::Ping));
        assert_eq!(buf.wave_of(21), Some(Wave::Pong));
        assert_eq!(buf.complete(11), Some(Wave::Ping));
        assert_eq!(buf.complete(11), None, "duplicate must be rejected");
        assert_eq!(buf.in_flight(Wave::Ping), vec![10, 12]);
        assert!(!buf.drained(Wave::Ping));
        buf.complete(10);
        buf.complete(12);
        assert!(buf.drained(Wave::Ping));
        assert!(!buf.drained(Wave::Pong));
    }

    #[test]
    fn wave_other_flips() {
        assert_eq!(Wave::Ping.other(), Wave::Pong);
        assert_eq!(Wave::Pong.other(), Wave::Ping);
        assert_eq!(Wave::Ping.to_string(), "ping");
    }
}
