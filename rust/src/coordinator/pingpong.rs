//! Ping-pong execution (§4.1, Fig. 7): overlap the CA dispatch/return
//! communication of one nano-batch with the computation of the other.
//!
//! Each microbatch is split into two equal-token nano-batches, "ping" and
//! "pong". Per transformer layer, the GPU timeline alternates:
//!
//! ```text
//! compute:  CA(i,0) CA(i,1) | postCA(i,0)+preCA(i+1,0) | postCA(i,1)+preCA(i+1,1) | CA(i+1,0) ...
//! comm:     exit(i,0)/enter(i+1,0) run UNDER the (i,1)-side compute and vice versa
//! ```
//!
//! [`layer_time`] computes the per-layer makespan of this schedule given
//! the four primitive durations, and its degenerate variants model the
//! Fig.-11 ablations: `single_stream` (communication serializes with
//! compute) and `signal_only` (communication is free — the pure
//! compute-imbalance floor).

/// Primitive durations for one *nano-batch* at one layer (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NanoCosts {
    /// Context-independent compute around the CA boundary
    /// (post-CA of layer i fused with pre-CA of layer i+1).
    pub linear: f64,
    /// Core-attention execution on this GPU's attention-server role
    /// (its share of the fused batched kernel).
    pub ca: f64,
    /// Dispatch communication (Q/KV out + in) for this nano-batch.
    pub comm_in: f64,
    /// Return communication (O back).
    pub comm_out: f64,
}

impl NanoCosts {
    pub fn total_comm(&self) -> f64 {
        self.comm_in + self.comm_out
    }
}

/// Per-layer time under the ping-pong schedule: each nano-batch's
/// communication overlaps the *other* nano-batch's compute. The layer
/// completes when both nano-batches' compute and comm are done; comm for
/// nano `a` can hide under compute of nano `b` (and vice versa), so the
/// makespan is `max(total_compute, compute_a + comm_b, compute_b +
/// comm_a)` reduced to the standard two-stage overlap bound:
/// `max(C_total, max_i(comm_i) + compute_other_floor)` — we model it as
/// the critical path of the Fig.-7 DAG.
pub fn layer_time_pingpong(ping: NanoCosts, pong: NanoCosts) -> f64 {
    // Compute occupies the GPU serially: CA(0), CA(1), lin(0), lin(1).
    let compute_total = ping.ca + pong.ca + ping.linear + pong.linear;
    // Ping's comm must fit under pong's compute slots and vice versa;
    // if comm exceeds the available overlap window it extends the
    // critical path.
    let ping_window = pong.ca + pong.linear;
    let pong_window = ping.ca + ping.linear;
    let ping_spill = (ping.total_comm() - ping_window).max(0.0);
    let pong_spill = (pong.total_comm() - pong_window).max(0.0);
    compute_total + ping_spill + pong_spill
}

/// Per-layer time with communication on the same stream (no overlap) —
/// the "Single Stream" ablation of Fig. 11.
pub fn layer_time_single_stream(ping: NanoCosts, pong: NanoCosts) -> f64 {
    ping.ca + pong.ca + ping.linear + pong.linear + ping.total_comm() + pong.total_comm()
}

/// Per-layer time when communication is free (1-byte "Signal" ablation):
/// the floor set purely by compute balance.
pub fn layer_time_signal(ping: NanoCosts, pong: NanoCosts) -> f64 {
    ping.ca + pong.ca + ping.linear + pong.linear
}

/// Split a microbatch's costs into two equal nano-batches. Token counts
/// divide evenly; CA and comm divide with the tokens (CA-tasks are
/// token-divisible — the same composability that enables CAD).
pub fn split_nano(linear: f64, ca: f64, comm_in: f64, comm_out: f64) -> (NanoCosts, NanoCosts) {
    let half = |x: f64| x / 2.0;
    let n = NanoCosts {
        linear: half(linear),
        ca: half(ca),
        comm_in: half(comm_in),
        comm_out: half(comm_out),
    };
    (n, n)
}

/// Whether communication is fully hidden at these costs.
pub fn fully_overlapped(ping: NanoCosts, pong: NanoCosts) -> bool {
    (layer_time_pingpong(ping, pong) - layer_time_signal(ping, pong)).abs() < 1e-12
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nano(linear: f64, ca: f64, cin: f64, cout: f64) -> NanoCosts {
        NanoCosts { linear, ca, comm_in: cin, comm_out: cout }
    }

    #[test]
    fn small_comm_fully_hidden() {
        let (p, q) = split_nano(10.0, 6.0, 2.0, 1.0);
        assert!(fully_overlapped(p, q));
        assert_eq!(layer_time_pingpong(p, q), layer_time_signal(p, q));
    }

    #[test]
    fn large_comm_spills() {
        // Comm bigger than the other nano's compute window must extend
        // the makespan, but by less than serial execution.
        let p = nano(1.0, 1.0, 10.0, 5.0);
        let q = nano(1.0, 1.0, 10.0, 5.0);
        let pp = layer_time_pingpong(p, q);
        let ss = layer_time_single_stream(p, q);
        let sig = layer_time_signal(p, q);
        assert!(pp > sig);
        assert!(pp < ss);
        // exact: compute 4, windows 2 each, spill (15-2)*2 = 26 -> 30
        assert!((pp - 30.0).abs() < 1e-9);
        assert!((ss - 34.0).abs() < 1e-9);
    }

    #[test]
    fn single_stream_penalty_shape() {
        // Fig. 11: single stream is 10-17% slower when comm ≈ 10-17% of
        // compute.
        let comm = 0.15;
        let (p, q) = split_nano(0.7, 0.3, comm, comm * 0.3);
        let pp = layer_time_pingpong(p, q);
        let ss = layer_time_single_stream(p, q);
        let ratio = ss / pp;
        assert!(ratio > 1.10 && ratio < 1.25, "ratio {ratio}");
    }

    #[test]
    fn signal_is_lower_bound() {
        for seed in 0..20u64 {
            let mut r = crate::util::rng::Rng::new(seed);
            let p = nano(r.next_f64(), r.next_f64(), r.next_f64(), r.next_f64());
            let q = nano(r.next_f64(), r.next_f64(), r.next_f64(), r.next_f64());
            let sig = layer_time_signal(p, q);
            let pp = layer_time_pingpong(p, q);
            let ss = layer_time_single_stream(p, q);
            assert!(sig <= pp + 1e-12);
            assert!(pp <= ss + 1e-12);
        }
    }

    #[test]
    fn split_halves_everything() {
        let (p, q) = split_nano(8.0, 4.0, 2.0, 1.0);
        assert_eq!(p, q);
        assert_eq!(p.linear, 4.0);
        assert_eq!(p.ca, 2.0);
        assert_eq!(p.total_comm(), 1.5);
    }
}
