//! Discrete-event cluster simulator — the substitute for the paper's
//! 64–512 H200 testbed (see DESIGN.md §2).
//!
//! The simulator executes *the same plans* the real coordinator emits:
//! a training iteration becomes a dependency DAG of compute tasks (pinned
//! to devices) and communication tasks (pinned to links), scheduled
//! as-soon-as-possible by [`engine::Engine`]. Strategy executors in
//! [`strategies`] build the DAG for each balancing scheme — plain packed
//! DP, per-document CP, WLB-ideal, and DistCA — and [`report`] collects
//! the quantities the paper plots (iteration time, idle fraction, memory
//! divergence, communication share).
//!
//! The engine also models the elastic pool's failure modes: per-resource
//! speed factors (stragglers), revocation (kills), partial drains, and
//! per-resource byte budgets with OOM eviction.
//!
//! # Example: a straggler and a revocation
//!
//! ```
//! use distca::sim::Engine;
//!
//! let mut eng = Engine::new(2);
//! eng.set_speed(1, 0.5); // resource 1 runs at half rate
//! let a = eng.add_task(0, 1.0, &[]);
//! let b = eng.add_task(1, 1.0, &[]); // takes 2.0 seconds at 0.5x
//! eng.revoke_resource(0, 0.25); // resource 0 dies mid-task
//! let makespan = eng.run();
//! assert!(!eng.is_done(a) && eng.revoked() == vec![a]);
//! assert!(eng.is_done(b));
//! assert!((makespan - 2.0).abs() < 1e-12);
//! ```

pub mod engine;
pub mod report;
pub mod strategies;

pub use engine::{Engine, TaskId};
pub use report::IterationReport;
