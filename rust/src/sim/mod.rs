//! Discrete-event cluster simulator — the substitute for the paper's
//! 64–512 H200 testbed (see DESIGN.md §2).
//!
//! The simulator executes *the same plans* the real coordinator emits:
//! a training iteration becomes a dependency DAG of compute tasks (pinned
//! to devices) and communication tasks (pinned to links), scheduled
//! as-soon-as-possible by [`engine::Engine`]. Strategy executors in
//! [`strategies`] build the DAG for each balancing scheme — plain packed
//! DP, per-document CP, WLB-ideal, and DistCA — and [`report`] collects
//! the quantities the paper plots (iteration time, idle fraction, memory
//! divergence, communication share).

pub mod engine;
pub mod report;
pub mod strategies;

pub use engine::{Engine, TaskId};
pub use report::IterationReport;
