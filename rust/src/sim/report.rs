//! Simulation outputs: the per-iteration quantities the paper reports.

use crate::util::json::Json;
use crate::util::stats;

/// Result of simulating one training iteration (or the average of many).
#[derive(Debug, Clone)]
pub struct IterationReport {
    pub strategy: String,
    /// End-to-end iteration time (seconds).
    pub iter_time: f64,
    /// Total tokens processed this iteration.
    pub tokens: usize,
    /// Per logical-device *compute busy* time (seconds) — used for the
    /// idle-fraction metric of Fig. 4b.
    pub device_busy: Vec<f64>,
    /// Per logical-device peak memory (bytes, per GPU within the device's
    /// TP group).
    pub device_mem: Vec<f64>,
    /// Total communication volume (bytes) attributable to the balancing
    /// scheme (CP all-gather or CAD dispatch).
    pub comm_bytes: f64,
    /// Communication time NOT hidden by compute (seconds).
    pub comm_exposed: f64,
    /// Did any device exceed HBM?
    pub oom: bool,
    /// Free-form config description (e.g. "dp=4 cp=2").
    pub config: String,
    /// Transient-memory balance of the strategy's plans (§5, Fig. 3b):
    /// per-server peak arena bytes from an in-place replay. `None` for
    /// strategies without a CA-dispatch plan to replay.
    pub mem: Option<crate::memplan::MemReport>,
}

impl IterationReport {
    /// Tokens per second.
    pub fn throughput(&self) -> f64 {
        if self.iter_time <= 0.0 {
            return 0.0;
        }
        self.tokens as f64 / self.iter_time
    }

    /// Fig. 4b's metric: mean idle time / iteration time across devices.
    pub fn idle_fraction(&self) -> f64 {
        if self.iter_time <= 0.0 || self.device_busy.is_empty() {
            return 0.0;
        }
        let mean_busy = stats::mean(&self.device_busy);
        (1.0 - mean_busy / self.iter_time).max(0.0)
    }

    /// Fig. 4a's metric: max/min memory across devices.
    pub fn memory_divergence(&self) -> f64 {
        if self.device_mem.is_empty() {
            return 1.0;
        }
        stats::divergence(&self.device_mem)
    }

    pub fn max_memory(&self) -> f64 {
        stats::max(&self.device_mem)
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("strategy", Json::Str(self.strategy.clone())),
            ("config", Json::Str(self.config.clone())),
            ("iter_time_s", Json::Num(self.iter_time)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("throughput_tok_s", Json::Num(self.throughput())),
            ("idle_fraction", Json::Num(self.idle_fraction())),
            ("memory_divergence", Json::Num(self.memory_divergence())),
            ("max_memory_bytes", Json::Num(self.max_memory())),
            ("comm_bytes", Json::Num(self.comm_bytes)),
            ("comm_exposed_s", Json::Num(self.comm_exposed)),
            ("oom", Json::Bool(self.oom)),
        ];
        if let Some(mem) = &self.mem {
            fields.push(("transient_mem", mem.to_json()));
        }
        Json::obj(fields)
    }

    /// Average several per-batch reports (paper: mean over 30 sampled
    /// batches). OOM if any batch OOMs; memory is the max — including
    /// the transient-arena peaks, which combine element-wise (the
    /// worst-case footprint any batch produced on each server).
    pub fn average(reports: &[IterationReport]) -> IterationReport {
        assert!(!reports.is_empty());
        let n = reports.len() as f64;
        let ndev = reports[0].device_busy.len();
        let mut busy = vec![0.0; ndev];
        let mut mem = vec![0.0f64; reports[0].device_mem.len()];
        for r in reports {
            for (i, b) in r.device_busy.iter().enumerate() {
                busy[i] += b / n;
            }
            for (i, m) in r.device_mem.iter().enumerate() {
                mem[i] = mem[i].max(*m);
            }
        }
        let mut arena: Option<crate::memplan::MemReport> = None;
        for r in reports.iter().filter_map(|r| r.mem.as_ref()) {
            match &mut arena {
                None => arena = Some(r.clone()),
                Some(acc) => {
                    for (a, &p) in acc.per_server_peak.iter_mut().zip(&r.per_server_peak) {
                        *a = a.max(p);
                    }
                }
            }
        }
        IterationReport {
            strategy: reports[0].strategy.clone(),
            iter_time: reports.iter().map(|r| r.iter_time).sum::<f64>() / n,
            tokens: (reports.iter().map(|r| r.tokens).sum::<usize>() as f64 / n) as usize,
            device_busy: busy,
            device_mem: mem,
            comm_bytes: reports.iter().map(|r| r.comm_bytes).sum::<f64>() / n,
            comm_exposed: reports.iter().map(|r| r.comm_exposed).sum::<f64>() / n,
            oom: reports.iter().any(|r| r.oom),
            config: reports[0].config.clone(),
            mem: arena,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rep(iter: f64, busy: Vec<f64>) -> IterationReport {
        IterationReport {
            strategy: "test".into(),
            iter_time: iter,
            tokens: 1000,
            device_busy: busy,
            device_mem: vec![1e9, 2e9],
            comm_bytes: 0.0,
            comm_exposed: 0.0,
            oom: false,
            config: String::new(),
            mem: None,
        }
    }

    #[test]
    fn throughput_and_idle() {
        let r = rep(2.0, vec![2.0, 1.0]);
        assert_eq!(r.throughput(), 500.0);
        assert!((r.idle_fraction() - 0.25).abs() < 1e-12);
        assert_eq!(r.memory_divergence(), 2.0);
    }

    #[test]
    fn average_combines() {
        let a = rep(1.0, vec![1.0, 1.0]);
        let b = rep(3.0, vec![3.0, 1.0]);
        let avg = IterationReport::average(&[a, b]);
        assert_eq!(avg.iter_time, 2.0);
        assert_eq!(avg.device_busy, vec![2.0, 1.0]);
        assert!(!avg.oom);
    }

    #[test]
    fn json_has_fields() {
        let j = rep(1.0, vec![1.0]).to_json();
        assert!(j.get("throughput_tok_s").is_some());
        assert!(j.get("idle_fraction").is_some());
        assert!(j.get("transient_mem").is_none(), "absent without a mem report");
    }

    #[test]
    fn mem_report_joins_and_averages_element_wise() {
        let mut a = rep(1.0, vec![1.0, 1.0]);
        a.mem = Some(crate::memplan::MemReport::from_peaks(vec![10.0, 30.0], 0.0));
        let mut b = rep(3.0, vec![3.0, 1.0]);
        b.mem = Some(crate::memplan::MemReport::from_peaks(vec![20.0, 5.0], 0.0));
        let avg = IterationReport::average(&[a, b]);
        let m = avg.mem.expect("mem must survive averaging");
        assert_eq!(m.per_server_peak, vec![20.0, 30.0], "element-wise max");
        let j = avg.to_json();
        assert!(j.get("transient_mem").is_some());
        assert!(j
            .get("transient_mem")
            .unwrap()
            .get("max_mean_ratio")
            .is_some());
    }
}
