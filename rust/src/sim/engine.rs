//! Dependency-DAG discrete-event engine.
//!
//! A simulation is a set of tasks; each task occupies one *resource*
//! (a device's compute stream, a device's communication stream, or a
//! shared link) for a duration, and may depend on other tasks. The engine
//! schedules every task as soon as (a) all dependencies finished and
//! (b) its resource is free, processing resources FIFO in insertion
//! order. This is a classic list-scheduling event simulation — O((T + E)
//! log T) — fast enough to sweep the paper's 512-GPU configurations in
//! milliseconds.

use std::collections::BinaryHeap;

/// Task handle.
pub type TaskId = usize;

/// Resource handle (device stream, link, …).
pub type ResourceId = usize;

#[derive(Debug, Clone)]
struct Task {
    resource: ResourceId,
    duration: f64,
    /// number of unfinished deps
    pending: usize,
    /// earliest start permitted by deps
    ready_at: f64,
    start: f64,
    finish: f64,
    done: bool,
    tag: u32,
}

/// Min-heap item ordered by time.
#[derive(PartialEq)]
struct Event {
    time: f64,
    task: TaskId,
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse for min-heap; tie-break on task id for determinism.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.task.cmp(&self.task))
    }
}

/// The simulation engine.
#[derive(Debug, Default)]
pub struct Engine {
    tasks: Vec<Task>,
    dependents: Vec<Vec<TaskId>>,
    n_resources: usize,
}

impl Engine {
    pub fn new(n_resources: usize) -> Self {
        Self {
            tasks: Vec::new(),
            dependents: Vec::new(),
            n_resources,
        }
    }

    /// Allocate an extra resource lane (e.g. a comm stream added late).
    pub fn add_resource(&mut self) -> ResourceId {
        self.n_resources += 1;
        self.n_resources - 1
    }

    pub fn n_resources(&self) -> usize {
        self.n_resources
    }

    /// Add a task occupying `resource` for `duration` after `deps`.
    pub fn add_task(&mut self, resource: ResourceId, duration: f64, deps: &[TaskId]) -> TaskId {
        self.add_task_tagged(resource, duration, deps, 0)
    }

    /// Tagged variant (tags let reports aggregate by kind).
    pub fn add_task_tagged(
        &mut self,
        resource: ResourceId,
        duration: f64,
        deps: &[TaskId],
        tag: u32,
    ) -> TaskId {
        assert!(resource < self.n_resources, "bad resource {resource}");
        assert!(duration >= 0.0 && duration.is_finite(), "bad duration {duration}");
        let id = self.tasks.len();
        for &d in deps {
            assert!(d < id, "dep {d} must precede task {id}");
        }
        self.tasks.push(Task {
            resource,
            duration,
            pending: deps.len(),
            ready_at: 0.0,
            start: 0.0,
            finish: 0.0,
            done: false,
            tag,
        });
        self.dependents.push(Vec::new());
        for &d in deps {
            self.dependents[d].push(id);
        }
        id
    }

    /// Run the simulation; returns the makespan.
    pub fn run(&mut self) -> f64 {
        let n = self.tasks.len();
        if n == 0 {
            return 0.0;
        }
        // Per-resource FIFO queues of ready tasks (insertion order = task
        // id order for determinism and program-order execution on a
        // device).
        let mut ready: Vec<std::collections::VecDeque<TaskId>> =
            vec![Default::default(); self.n_resources];
        let mut res_free_at = vec![0.0f64; self.n_resources];
        let mut res_busy = vec![false; self.n_resources];
        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        let mut completed = 0usize;
        let mut makespan = 0.0f64;

        for (id, t) in self.tasks.iter().enumerate() {
            if t.pending == 0 {
                ready[t.resource].push_back(id);
            }
        }
        // Kick off initial tasks.
        let mut now = 0.0f64;
        loop {
            // Start every idle resource's next ready task.
            for r in 0..self.n_resources {
                if res_busy[r] {
                    continue;
                }
                // find first ready task whose ready_at <= now
                if let Some(&cand) = ready[r].front() {
                    let t = &self.tasks[cand];
                    let start = now.max(res_free_at[r]).max(t.ready_at);
                    if start <= now + 1e-18 {
                        ready[r].pop_front();
                        let task = &mut self.tasks[cand];
                        task.start = now;
                        task.finish = now + task.duration;
                        res_busy[r] = true;
                        res_free_at[r] = task.finish;
                        heap.push(Event { time: task.finish, task: cand });
                    }
                }
            }
            // Advance to next completion.
            let ev = match heap.pop() {
                Some(e) => e,
                None => break,
            };
            now = ev.time;
            makespan = makespan.max(now);
            let tid = ev.task;
            self.tasks[tid].done = true;
            completed += 1;
            res_busy[self.tasks[tid].resource] = false;
            let deps_of: Vec<TaskId> = self.dependents[tid].clone();
            for dep in deps_of {
                let t = &mut self.tasks[dep];
                t.pending -= 1;
                t.ready_at = t.ready_at.max(now);
                if t.pending == 0 {
                    ready[t.resource].push_back(dep);
                }
            }
        }
        assert_eq!(completed, n, "deadlock: {} of {n} tasks completed", completed);
        makespan
    }

    /// Finish time of a task (after `run`).
    pub fn finish_of(&self, id: TaskId) -> f64 {
        assert!(self.tasks[id].done, "task {id} never ran");
        self.tasks[id].finish
    }

    /// Busy time per resource (after `run`).
    pub fn busy_per_resource(&self) -> Vec<f64> {
        let mut busy = vec![0.0; self.n_resources];
        for t in &self.tasks {
            busy[t.resource] += t.duration;
        }
        busy
    }

    /// Busy time per resource restricted to a tag.
    pub fn busy_per_resource_tagged(&self, tag: u32) -> Vec<f64> {
        let mut busy = vec![0.0; self.n_resources];
        for t in &self.tasks {
            if t.tag == tag {
                busy[t.resource] += t.duration;
            }
        }
        busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        assert_eq!(Engine::new(2).run(), 0.0);
    }

    #[test]
    fn serial_on_one_resource() {
        let mut e = Engine::new(1);
        e.add_task(0, 1.0, &[]);
        e.add_task(0, 2.0, &[]);
        e.add_task(0, 3.0, &[]);
        assert!((e.run() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_resources() {
        let mut e = Engine::new(3);
        e.add_task(0, 1.0, &[]);
        e.add_task(1, 2.0, &[]);
        e.add_task(2, 3.0, &[]);
        assert!((e.run() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn dependency_chain_across_resources() {
        let mut e = Engine::new(2);
        let a = e.add_task(0, 1.0, &[]);
        let b = e.add_task(1, 1.0, &[a]);
        let c = e.add_task(0, 1.0, &[b]);
        assert!((e.run() - 3.0).abs() < 1e-12);
        assert!((e.finish_of(c) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn diamond_dag() {
        let mut e = Engine::new(2);
        let a = e.add_task(0, 1.0, &[]);
        let b = e.add_task(0, 2.0, &[a]);
        let c = e.add_task(1, 3.0, &[a]);
        let _d = e.add_task(0, 1.0, &[b, c]);
        // a(0..1); b(1..3) on r0; c(1..4) on r1; d starts at 4 -> 5.
        assert!((e.run() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn fifo_order_on_resource() {
        // Tasks on the same resource run in insertion order when both
        // ready — models program order on a GPU stream.
        let mut e = Engine::new(1);
        let a = e.add_task(0, 5.0, &[]);
        let b = e.add_task(0, 1.0, &[]);
        e.run();
        assert!(e.finish_of(a) < e.finish_of(b));
    }

    #[test]
    fn pipeline_two_stages() {
        // Two-stage pipeline, 3 microbatches, fwd only, unit time:
        // classic makespan = stages + microbatches - 1 = 4.
        let mut e = Engine::new(2);
        let mut prev: Option<TaskId> = None;
        let mut finals = Vec::new();
        for _mb in 0..3 {
            let s0 = match prev {
                // enforce program order on stage 0 implicitly by FIFO
                _ => e.add_task(0, 1.0, &[]),
            };
            let s1 = e.add_task(1, 1.0, &[s0]);
            prev = Some(s0);
            finals.push(s1);
        }
        assert!((e.run() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn busy_accounting() {
        let mut e = Engine::new(2);
        e.add_task_tagged(0, 1.5, &[], 7);
        e.add_task_tagged(1, 2.5, &[], 7);
        e.add_task_tagged(0, 1.0, &[], 9);
        e.run();
        let busy = e.busy_per_resource();
        assert_eq!(busy, vec![2.5, 2.5]);
        assert_eq!(e.busy_per_resource_tagged(7), vec![1.5, 2.5]);
    }

    #[test]
    #[should_panic]
    fn forward_dep_rejected() {
        let mut e = Engine::new(1);
        e.add_task(0, 1.0, &[3]);
    }

    #[test]
    fn deterministic_makespan() {
        let build = || {
            let mut e = Engine::new(4);
            let mut r = crate::util::rng::Rng::new(42);
            let mut ids: Vec<TaskId> = Vec::new();
            for i in 0..200 {
                let res = r.gen_index(0, 4);
                let dur = r.gen_f64(0.1, 2.0);
                let deps: Vec<TaskId> = if i > 0 && r.gen_bool(0.5) {
                    vec![ids[r.gen_index(0, ids.len())]]
                } else {
                    vec![]
                };
                ids.push(e.add_task(res, dur, &deps));
            }
            e.run()
        };
        assert_eq!(build(), build());
    }
}
