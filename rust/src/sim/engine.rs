//! Dependency-DAG discrete-event engine.
//!
//! A simulation is a set of tasks; each task occupies one *resource*
//! (a device's compute stream, a device's communication stream, or a
//! shared link) for a duration, and may depend on other tasks. The engine
//! schedules every task as soon as (a) all dependencies finished and
//! (b) its resource is free, processing resources FIFO in insertion
//! order. This is a classic list-scheduling event simulation — O((T + E)
//! log T) — fast enough to sweep the paper's 512-GPU configurations in
//! milliseconds.
//!
//! For the elastic attention-server pool the engine additionally models
//! *degraded* and *revoked* resources:
//!
//! * [`Engine::set_speed`] scales a resource's execution rate (a 0.5×
//!   resource takes 2× the nominal duration) — the straggler model;
//! * [`Engine::revoke_resource`] declares a resource dead from a given
//!   time: a task running past that instant is cut short and marked
//!   revoked (its partial work is lost — core attention is stateless, so
//!   nothing else is), queued tasks on the resource never start, and
//!   every transitive dependent of a revoked task is revoked with it.
//!   [`Engine::revoked`] lists the casualties so a failover layer can
//!   re-dispatch them (typically via [`Engine::add_task_at`] in a
//!   recovery wave, earliest-started at detection time);
//! * [`Engine::drain_resource`] is the *partial-drain* primitive: from
//!   the given time the resource starts nothing new, but the task already
//!   running is allowed to finish — only the unstarted tail of its queue
//!   is revoked (and therefore re-dispatchable);
//! * [`Engine::add_barrier`] inserts a PP-tick barrier: a zero-duration,
//!   resource-less join point. The revocation cascade *stops* at
//!   barriers — a revoked dependency counts as resolved at its cut time,
//!   because the elastic layer guarantees the lost work is re-dispatched
//!   and re-accounted within the tick, so work scheduled behind the tick
//!   barrier must not be collaterally revoked.
//!
//! For the memory-disaggregated execution model (§5, Fig. 3b) the engine
//! additionally tracks *live bytes* per resource:
//!
//! * [`Engine::add_task_mem`] attaches a transient byte footprint to a
//!   task — resident on its resource from *admission* (the moment the
//!   task is dependency-ready and its inputs are dispatched into the
//!   server's arena) until it finishes or is revoked, so queued tasks'
//!   bytes coexist even though compute serializes (the Q+KV of an
//!   in-place CA-task);
//! * [`Engine::set_mem_budget`] sets a hard per-resource byte budget: a
//!   task whose start would push live bytes past the budget is *evicted*
//!   (revoked at its would-be start, listed in
//!   [`Engine::oom_evictions`]) instead of started — the simulator-level
//!   OOM the elastic layer recovers from by re-dispatching to a resource
//!   with headroom (statelessness, §3). This is also the *organic* OOM
//!   path: `ElasticSimCfg::mem_budget` wires per-resource budgets from
//!   the §5 memory model, so fault-free-but-tight configurations evict
//!   through this budget with no scripted `oom:` event at all;
//! * [`Engine::mem_peak_per_resource`] reports each resource's byte
//!   high-water mark — the quantity `MemReport` summarizes.

use std::collections::BinaryHeap;

/// Task handle.
pub type TaskId = usize;

/// Resource handle (device stream, link, …).
pub type ResourceId = usize;

#[derive(Debug, Clone)]
struct Task {
    resource: ResourceId,
    duration: f64,
    /// number of unfinished deps
    pending: usize,
    /// earliest start permitted by deps (and `add_task_at`)
    ready_at: f64,
    start: f64,
    finish: f64,
    started: bool,
    done: bool,
    revoked: bool,
    /// Tick barrier: completes when all deps resolve, occupies nothing.
    barrier: bool,
    tag: u32,
    /// Transient bytes resident on the resource while the task is
    /// admitted (queued or running) — the dispatched Q+KV of a CA-task.
    mem: f64,
    /// Are this task's bytes currently counted in the resource's live
    /// total? (Guards against double release on revoke paths.)
    mem_live: bool,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EventKind {
    /// A running task reaches its finish (or cut-short) time.
    Finish,
    /// A future `ready_at` arrives; re-run the start phase.
    Wake,
}

/// Min-heap item ordered by time.
#[derive(PartialEq)]
struct Event {
    time: f64,
    task: TaskId,
    kind: EventKind,
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reverse for min-heap; tie-break on task id then kind for
        // determinism (Finish before Wake at equal time/task).
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.task.cmp(&self.task))
            .then((other.kind == EventKind::Wake).cmp(&(self.kind == EventKind::Wake)))
    }
}

/// The simulation engine.
#[derive(Debug, Default)]
pub struct Engine {
    tasks: Vec<Task>,
    dependents: Vec<Vec<TaskId>>,
    n_resources: usize,
    /// Execution-rate multiplier per resource (1.0 = nominal).
    speed: Vec<f64>,
    /// Time at which each resource dies, if ever.
    revoked_at: Vec<Option<f64>>,
    /// Time from which each resource starts no new tasks (partial drain).
    drained_at: Vec<Option<f64>>,
    /// Hard live-byte budget per resource (0 = unlimited).
    mem_budget: Vec<f64>,
    /// Live bytes per resource during `run` (admitted tasks' footprints).
    live_mem: Vec<f64>,
    /// Byte high-water mark per resource (after `run`).
    mem_peak: Vec<f64>,
    /// OOM evictions: `(resource, task, time)` of tasks whose admission
    /// would have overflowed the resource's byte budget.
    oom_events: Vec<(ResourceId, TaskId, f64)>,
}

impl Engine {
    pub fn new(n_resources: usize) -> Self {
        Self {
            tasks: Vec::new(),
            dependents: Vec::new(),
            n_resources,
            speed: vec![1.0; n_resources],
            revoked_at: vec![None; n_resources],
            drained_at: vec![None; n_resources],
            mem_budget: vec![0.0; n_resources],
            live_mem: vec![0.0; n_resources],
            mem_peak: vec![0.0; n_resources],
            oom_events: Vec::new(),
        }
    }

    /// Allocate an extra resource lane (e.g. a comm stream added late).
    pub fn add_resource(&mut self) -> ResourceId {
        self.n_resources += 1;
        self.speed.push(1.0);
        self.revoked_at.push(None);
        self.drained_at.push(None);
        self.mem_budget.push(0.0);
        self.live_mem.push(0.0);
        self.mem_peak.push(0.0);
        self.n_resources - 1
    }

    pub fn n_resources(&self) -> usize {
        self.n_resources
    }

    /// Set a resource's execution-rate multiplier: tasks on it take
    /// `duration / factor`. A factor below 1.0 models a straggler.
    pub fn set_speed(&mut self, resource: ResourceId, factor: f64) {
        assert!(resource < self.n_resources, "bad resource {resource}");
        assert!(factor > 0.0 && factor.is_finite(), "bad speed {factor}");
        self.speed[resource] = factor;
    }

    /// Set a resource's hard live-byte budget (0 = unlimited). A task
    /// whose start would push live bytes past the budget is evicted
    /// (revoked, never started) and listed in [`Engine::oom_evictions`].
    /// Must be called before [`Engine::run`].
    pub fn set_mem_budget(&mut self, resource: ResourceId, bytes: f64) {
        assert!(resource < self.n_resources, "bad resource {resource}");
        assert!(bytes >= 0.0 && bytes.is_finite(), "bad mem budget {bytes}");
        self.mem_budget[resource] = bytes;
    }

    /// Declare `resource` dead from time `t` onward (earliest call wins).
    /// Must be called before [`Engine::run`].
    pub fn revoke_resource(&mut self, resource: ResourceId, t: f64) {
        assert!(resource < self.n_resources, "bad resource {resource}");
        assert!(t >= 0.0 && t.is_finite(), "bad revocation time {t}");
        self.revoked_at[resource] = Some(match self.revoked_at[resource] {
            Some(prev) => prev.min(t),
            None => t,
        });
    }

    /// Declare `resource` draining from time `t` (earliest call wins): the
    /// task running at `t` finishes, but nothing queued behind it starts —
    /// the unstarted tail is revoked for the failover layer to
    /// re-dispatch. Must be called before [`Engine::run`].
    pub fn drain_resource(&mut self, resource: ResourceId, t: f64) {
        assert!(resource < self.n_resources, "bad resource {resource}");
        assert!(t >= 0.0 && t.is_finite(), "bad drain time {t}");
        self.drained_at[resource] = Some(match self.drained_at[resource] {
            Some(prev) => prev.min(t),
            None => t,
        });
    }

    /// Add a task occupying `resource` for `duration` after `deps`.
    pub fn add_task(&mut self, resource: ResourceId, duration: f64, deps: &[TaskId]) -> TaskId {
        self.add_task_full(resource, duration, deps, 0, 0.0, 0.0)
    }

    /// Tagged variant (tags let reports aggregate by kind).
    pub fn add_task_tagged(
        &mut self,
        resource: ResourceId,
        duration: f64,
        deps: &[TaskId],
        tag: u32,
    ) -> TaskId {
        self.add_task_full(resource, duration, deps, tag, 0.0, 0.0)
    }

    /// Variant with an earliest-start time — the recovery-wave primitive:
    /// a re-dispatched task cannot begin before the failure is detected.
    pub fn add_task_at(
        &mut self,
        resource: ResourceId,
        duration: f64,
        deps: &[TaskId],
        earliest_start: f64,
    ) -> TaskId {
        self.add_task_full(resource, duration, deps, 0, earliest_start, 0.0)
    }

    /// Variant carrying a transient byte footprint: `mem_bytes` are live
    /// on the resource from the task's *admission* (dependency-ready:
    /// its inputs occupy the arena while it queues) to its finish or
    /// revocation — an in-place CA-task's Q+KV. With a
    /// [`Engine::set_mem_budget`] in force, an admission that would
    /// overflow evicts the task instead (OOM).
    pub fn add_task_mem(
        &mut self,
        resource: ResourceId,
        duration: f64,
        deps: &[TaskId],
        mem_bytes: f64,
    ) -> TaskId {
        self.add_task_full(resource, duration, deps, 0, 0.0, mem_bytes)
    }

    /// Full variant: earliest start plus a transient byte footprint —
    /// the recovery-wave primitive for memory-tracked CA-tasks.
    pub fn add_task_mem_at(
        &mut self,
        resource: ResourceId,
        duration: f64,
        deps: &[TaskId],
        mem_bytes: f64,
        earliest_start: f64,
    ) -> TaskId {
        self.add_task_full(resource, duration, deps, 0, earliest_start, mem_bytes)
    }

    fn add_task_full(
        &mut self,
        resource: ResourceId,
        duration: f64,
        deps: &[TaskId],
        tag: u32,
        earliest_start: f64,
        mem: f64,
    ) -> TaskId {
        assert!(resource < self.n_resources, "bad resource {resource}");
        assert!(duration >= 0.0 && duration.is_finite(), "bad duration {duration}");
        assert!(
            earliest_start >= 0.0 && earliest_start.is_finite(),
            "bad earliest_start {earliest_start}"
        );
        assert!(mem >= 0.0 && mem.is_finite(), "bad mem bytes {mem}");
        let id = self.tasks.len();
        for &d in deps {
            assert!(d < id, "dep {d} must precede task {id}");
        }
        self.tasks.push(Task {
            resource,
            duration,
            pending: deps.len(),
            ready_at: earliest_start,
            start: 0.0,
            finish: 0.0,
            started: false,
            done: false,
            revoked: false,
            barrier: false,
            tag,
            mem,
            mem_live: false,
        });
        self.dependents.push(Vec::new());
        for &d in deps {
            self.dependents[d].push(id);
        }
        id
    }

    /// Add a PP-tick barrier: a zero-duration join point that occupies no
    /// resource and completes when every dependency *resolves* (finishes,
    /// or is revoked — the cascade stops here, see the module docs).
    /// Tasks depending on the barrier belong to the next tick and survive
    /// same-tick revocations.
    pub fn add_barrier(&mut self, deps: &[TaskId]) -> TaskId {
        let id = self.tasks.len();
        for &d in deps {
            assert!(d < id, "dep {d} must precede barrier {id}");
        }
        self.tasks.push(Task {
            resource: usize::MAX,
            duration: 0.0,
            pending: deps.len(),
            ready_at: 0.0,
            start: 0.0,
            finish: 0.0,
            started: false,
            done: false,
            revoked: false,
            barrier: true,
            tag: 0,
            mem: 0.0,
            mem_live: false,
        });
        self.dependents.push(Vec::new());
        for &d in deps {
            self.dependents[d].push(id);
        }
        id
    }

    /// [`Engine::revoke_cascade`] plus scheduling of the completion
    /// events of any barriers the cascade resolved; returns the newly
    /// revoked count.
    fn revoke_and_schedule(
        &mut self,
        tid: TaskId,
        time: f64,
        heap: &mut BinaryHeap<Event>,
    ) -> usize {
        let (count, barriers) = self.revoke_cascade(tid, time);
        for b in barriers {
            heap.push(Event {
                time: self.tasks[b].ready_at,
                task: b,
                kind: EventKind::Finish,
            });
        }
        count
    }

    /// Mark `tid` revoked at `time` and cascade to every transitive
    /// dependent (a task whose dependency never completes can never run)
    /// — except across barriers: a revoked dependency of a barrier counts
    /// as resolved at its cut time, so the cascade never crosses a tick
    /// boundary. Returns how many tasks were newly revoked plus the
    /// barriers whose last dependency just resolved (the caller schedules
    /// their completion events).
    fn revoke_cascade(&mut self, tid: TaskId, time: f64) -> (usize, Vec<TaskId>) {
        let mut count = 0usize;
        let mut resolved_barriers = Vec::new();
        let mut work = vec![tid];
        while let Some(t) = work.pop() {
            if self.tasks[t].barrier {
                let task = &mut self.tasks[t];
                task.pending -= 1;
                task.ready_at = task.ready_at.max(time);
                if task.pending == 0 && !task.done {
                    resolved_barriers.push(t);
                }
                continue;
            }
            if self.tasks[t].done || self.tasks[t].revoked {
                continue;
            }
            self.tasks[t].revoked = true;
            self.release_mem(t);
            if !self.tasks[t].started {
                self.tasks[t].start = time;
            }
            self.tasks[t].finish = time;
            count += 1;
            work.extend(self.dependents[t].iter().copied());
        }
        (count, resolved_barriers)
    }

    /// Release a task's live bytes (idempotent — `mem_live` guards the
    /// revoke paths against double release).
    fn release_mem(&mut self, tid: TaskId) {
        if self.tasks[tid].mem_live {
            let r = self.tasks[tid].resource;
            let m = self.tasks[tid].mem;
            self.tasks[tid].mem_live = false;
            self.live_mem[r] -= m;
        }
    }

    /// Admit a dependency-ready task onto its resource queue, charging
    /// its transient bytes against the resource's budget. Over budget ⇒
    /// OOM eviction: the task (and its dependents) are revoked at `time`
    /// for the failover layer to re-dispatch. Returns whether admitted.
    fn try_admit(
        &mut self,
        id: TaskId,
        time: f64,
        ready: &mut [std::collections::VecDeque<TaskId>],
        heap: &mut BinaryHeap<Event>,
        revoked_count: &mut usize,
    ) -> bool {
        let r = self.tasks[id].resource;
        let mem = self.tasks[id].mem;
        if mem > 0.0 {
            let budget = self.mem_budget[r];
            if budget > 0.0 && self.live_mem[r] + mem > budget + 1e-9 {
                self.oom_events.push((r, id, time));
                *revoked_count += self.revoke_and_schedule(id, time, heap);
                return false;
            }
            self.live_mem[r] += mem;
            self.tasks[id].mem_live = true;
            self.mem_peak[r] = self.mem_peak[r].max(self.live_mem[r]);
        }
        ready[r].push_back(id);
        if self.tasks[id].ready_at > time + 1e-18 {
            heap.push(Event { time: self.tasks[id].ready_at, task: id, kind: EventKind::Wake });
        }
        true
    }

    /// Run the simulation; returns the makespan of executed work (revoked
    /// tasks count only up to their cut-short time).
    pub fn run(&mut self) -> f64 {
        let n = self.tasks.len();
        if n == 0 {
            return 0.0;
        }
        // Per-resource FIFO queues of ready tasks (insertion order = task
        // id order for determinism and program-order execution on a
        // device).
        let mut ready: Vec<std::collections::VecDeque<TaskId>> =
            vec![Default::default(); self.n_resources];
        let mut res_free_at = vec![0.0f64; self.n_resources];
        let mut res_busy = vec![false; self.n_resources];
        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        let mut completed = 0usize;
        let mut revoked_count = 0usize;
        let mut makespan = 0.0f64;

        let roots: Vec<TaskId> = self
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.pending == 0)
            .map(|(id, _)| id)
            .collect();
        for id in roots {
            if self.tasks[id].barrier {
                let at = self.tasks[id].ready_at;
                heap.push(Event { time: at, task: id, kind: EventKind::Finish });
            } else {
                self.try_admit(id, 0.0, &mut ready, &mut heap, &mut revoked_count);
            }
        }
        let mut now = 0.0f64;
        loop {
            // Start every idle resource's next ready task (program order:
            // only the queue front may start; revoked entries drain).
            for r in 0..self.n_resources {
                if res_busy[r] {
                    continue;
                }
                while let Some(&cand) = ready[r].front() {
                    if self.tasks[cand].revoked {
                        ready[r].pop_front();
                        continue;
                    }
                    if let Some(rt) = self.revoked_at[r] {
                        if now + 1e-18 >= rt {
                            // Dead resource: everything queued is lost.
                            ready[r].pop_front();
                            revoked_count +=
                                self.revoke_and_schedule(cand, now.max(rt), &mut heap);
                            continue;
                        }
                    }
                    if let Some(dt) = self.drained_at[r] {
                        if now + 1e-18 >= dt {
                            // Draining resource: the running task (if any)
                            // already left this queue and will finish; the
                            // unstarted tail is revoked for re-dispatch.
                            ready[r].pop_front();
                            revoked_count +=
                                self.revoke_and_schedule(cand, now.max(dt), &mut heap);
                            continue;
                        }
                    }
                    let t = &self.tasks[cand];
                    let start = now.max(res_free_at[r]).max(t.ready_at);
                    if start <= now + 1e-18 {
                        ready[r].pop_front();
                        let mut finish = now + self.tasks[cand].duration / self.speed[r];
                        if let Some(rt) = self.revoked_at[r] {
                            // The task will be interrupted mid-flight.
                            finish = finish.min(rt);
                        }
                        let task = &mut self.tasks[cand];
                        task.start = now;
                        task.finish = finish;
                        task.started = true;
                        res_busy[r] = true;
                        res_free_at[r] = finish;
                        heap.push(Event { time: finish, task: cand, kind: EventKind::Finish });
                    }
                    break;
                }
            }
            // Advance to the next event.
            let ev = match heap.pop() {
                Some(e) => e,
                None => break,
            };
            now = now.max(ev.time);
            if ev.kind == EventKind::Wake {
                continue; // a ready_at arrived; retry the start phase
            }
            let tid = ev.task;
            makespan = makespan.max(ev.time);
            if self.tasks[tid].barrier {
                self.tasks[tid].start = ev.time;
                self.tasks[tid].finish = ev.time;
                self.tasks[tid].done = true;
                completed += 1;
            } else {
                let r = self.tasks[tid].resource;
                res_busy[r] = false;
                // Buffers release the instant the task leaves the
                // resource — completed or cut short.
                self.release_mem(tid);
                let interrupted =
                    self.revoked_at[r].map_or(false, |rt| ev.time + 1e-18 >= rt);
                if interrupted {
                    revoked_count += self.revoke_and_schedule(tid, ev.time, &mut heap);
                    continue;
                }
                self.tasks[tid].done = true;
                completed += 1;
            }
            let deps_of: Vec<TaskId> = self.dependents[tid].clone();
            for dep in deps_of {
                if self.tasks[dep].revoked {
                    continue;
                }
                self.tasks[dep].pending -= 1;
                let at = self.tasks[dep].ready_at.max(now);
                self.tasks[dep].ready_at = at;
                if self.tasks[dep].pending == 0 {
                    if self.tasks[dep].barrier {
                        heap.push(Event { time: at, task: dep, kind: EventKind::Finish });
                    } else {
                        self.try_admit(dep, now, &mut ready, &mut heap, &mut revoked_count);
                    }
                }
            }
        }
        assert_eq!(
            completed + revoked_count,
            n,
            "deadlock: {completed} done + {revoked_count} revoked of {n} tasks"
        );
        makespan
    }

    /// Finish time of a task (after `run`).
    pub fn finish_of(&self, id: TaskId) -> f64 {
        assert!(self.tasks[id].done, "task {id} never ran");
        self.tasks[id].finish
    }

    /// Start time of a task that ran (after `run`) — with
    /// [`Engine::finish_of`], the span the tracing plane records for
    /// virtual-clock compute spans.
    pub fn start_of(&self, id: TaskId) -> f64 {
        assert!(self.tasks[id].started, "task {id} never started");
        self.tasks[id].start
    }

    /// Did the task complete (vs. being revoked)?
    pub fn is_done(&self, id: TaskId) -> bool {
        self.tasks[id].done
    }

    /// Was the task ever started? A drained resource finishes what it
    /// started; only never-started tasks may be re-dispatched by the
    /// partial-drain path.
    pub fn started(&self, id: TaskId) -> bool {
        self.tasks[id].started
    }

    /// Tasks revoked during `run` (directly or by cascade), in id order.
    pub fn revoked(&self) -> Vec<TaskId> {
        self.tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.revoked)
            .map(|(id, _)| id)
            .collect()
    }

    /// Time at which a revoked task was cut (its lost work ends there).
    pub fn revoke_time_of(&self, id: TaskId) -> f64 {
        assert!(self.tasks[id].revoked, "task {id} was not revoked");
        self.tasks[id].finish
    }

    /// Busy time per resource (after `run`): actual occupancy, including
    /// the partial occupancy of interrupted tasks and speed scaling.
    pub fn busy_per_resource(&self) -> Vec<f64> {
        let mut busy = vec![0.0; self.n_resources];
        for t in &self.tasks {
            if t.started {
                busy[t.resource] += t.finish - t.start;
            }
        }
        busy
    }

    /// Busy time per resource restricted to a tag.
    pub fn busy_per_resource_tagged(&self, tag: u32) -> Vec<f64> {
        let mut busy = vec![0.0; self.n_resources];
        for t in &self.tasks {
            if t.tag == tag && t.started {
                busy[t.resource] += t.finish - t.start;
            }
        }
        busy
    }

    /// Live-byte high-water mark per resource (after `run`): the peak
    /// transient footprint of admitted CA-tasks — the per-server series
    /// a `MemReport` summarizes.
    pub fn mem_peak_per_resource(&self) -> Vec<f64> {
        self.mem_peak.clone()
    }

    /// OOM evictions recorded during `run`: `(resource, task, time)` for
    /// every task whose admission would have overflowed its resource's
    /// byte budget. Each evicted task is revoked (with its transitive
    /// dependents) and is re-dispatchable by the failover layer —
    /// statelessness makes recovery one resend (§3).
    pub fn oom_evictions(&self) -> &[(ResourceId, TaskId, f64)] {
        &self.oom_events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        assert_eq!(Engine::new(2).run(), 0.0);
    }

    #[test]
    fn serial_on_one_resource() {
        let mut e = Engine::new(1);
        e.add_task(0, 1.0, &[]);
        e.add_task(0, 2.0, &[]);
        e.add_task(0, 3.0, &[]);
        assert!((e.run() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn parallel_resources() {
        let mut e = Engine::new(3);
        e.add_task(0, 1.0, &[]);
        e.add_task(1, 2.0, &[]);
        e.add_task(2, 3.0, &[]);
        assert!((e.run() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn dependency_chain_across_resources() {
        let mut e = Engine::new(2);
        let a = e.add_task(0, 1.0, &[]);
        let b = e.add_task(1, 1.0, &[a]);
        let c = e.add_task(0, 1.0, &[b]);
        assert!((e.run() - 3.0).abs() < 1e-12);
        assert!((e.finish_of(c) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn diamond_dag() {
        let mut e = Engine::new(2);
        let a = e.add_task(0, 1.0, &[]);
        let b = e.add_task(0, 2.0, &[a]);
        let c = e.add_task(1, 3.0, &[a]);
        let _d = e.add_task(0, 1.0, &[b, c]);
        // a(0..1); b(1..3) on r0; c(1..4) on r1; d starts at 4 -> 5.
        assert!((e.run() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn fifo_order_on_resource() {
        // Tasks on the same resource run in insertion order when both
        // ready — models program order on a GPU stream.
        let mut e = Engine::new(1);
        let a = e.add_task(0, 5.0, &[]);
        let b = e.add_task(0, 1.0, &[]);
        e.run();
        assert!(e.finish_of(a) < e.finish_of(b));
    }

    #[test]
    fn pipeline_two_stages() {
        // Two-stage pipeline, 3 microbatches, fwd only, unit time:
        // classic makespan = stages + microbatches - 1 = 4.
        let mut e = Engine::new(2);
        let mut prev: Option<TaskId> = None;
        let mut finals = Vec::new();
        for _mb in 0..3 {
            let s0 = match prev {
                // enforce program order on stage 0 implicitly by FIFO
                _ => e.add_task(0, 1.0, &[]),
            };
            let s1 = e.add_task(1, 1.0, &[s0]);
            prev = Some(s0);
            finals.push(s1);
        }
        assert!((e.run() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn start_and_finish_bound_the_span() {
        let mut e = Engine::new(1);
        let a = e.add_task(0, 1.0, &[]);
        let b = e.add_task(0, 2.0, &[]);
        e.run();
        assert_eq!(e.start_of(a), 0.0);
        assert!((e.start_of(b) - 1.0).abs() < 1e-12);
        assert!((e.finish_of(b) - e.start_of(b) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn start_of_unstarted_task_panics() {
        let mut e = Engine::new(1);
        let a = e.add_task(0, 1.0, &[]);
        e.revoke_resource(0, 0.0);
        e.run();
        e.start_of(a);
    }

    #[test]
    fn busy_accounting() {
        let mut e = Engine::new(2);
        e.add_task_tagged(0, 1.5, &[], 7);
        e.add_task_tagged(1, 2.5, &[], 7);
        e.add_task_tagged(0, 1.0, &[], 9);
        e.run();
        let busy = e.busy_per_resource();
        assert_eq!(busy, vec![2.5, 2.5]);
        assert_eq!(e.busy_per_resource_tagged(7), vec![1.5, 2.5]);
    }

    #[test]
    #[should_panic]
    fn forward_dep_rejected() {
        let mut e = Engine::new(1);
        e.add_task(0, 1.0, &[3]);
    }

    #[test]
    fn deterministic_makespan() {
        let build = || {
            let mut e = Engine::new(4);
            let mut r = crate::util::rng::Rng::new(42);
            let mut ids: Vec<TaskId> = Vec::new();
            for i in 0..200 {
                let res = r.gen_index(0, 4);
                let dur = r.gen_f64(0.1, 2.0);
                let deps: Vec<TaskId> = if i > 0 && r.gen_bool(0.5) {
                    vec![ids[r.gen_index(0, ids.len())]]
                } else {
                    vec![]
                };
                ids.push(e.add_task(res, dur, &deps));
            }
            e.run()
        };
        assert_eq!(build(), build());
    }

    // ----- elastic extensions -------------------------------------------

    #[test]
    fn slow_resource_stretches_duration() {
        let mut e = Engine::new(2);
        e.set_speed(1, 0.5); // half rate => 2x duration
        let a = e.add_task(0, 1.0, &[]);
        let b = e.add_task(1, 1.0, &[]);
        assert!((e.run() - 2.0).abs() < 1e-12);
        assert!((e.finish_of(a) - 1.0).abs() < 1e-12);
        assert!((e.finish_of(b) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn earliest_start_respected() {
        let mut e = Engine::new(1);
        let a = e.add_task_at(0, 1.0, &[], 5.0);
        assert!((e.run() - 6.0).abs() < 1e-12);
        assert!((e.finish_of(a) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn revoked_resource_cuts_running_task() {
        let mut e = Engine::new(2);
        let a = e.add_task(0, 10.0, &[]); // cut at t=3
        let b = e.add_task(1, 4.0, &[]);
        e.revoke_resource(0, 3.0);
        let makespan = e.run();
        assert!((makespan - 4.0).abs() < 1e-12, "makespan {makespan}");
        assert_eq!(e.revoked(), vec![a]);
        assert!(!e.is_done(a));
        assert!(e.is_done(b));
        assert!((e.revoke_time_of(a) - 3.0).abs() < 1e-12);
        // Occupancy accounting includes the lost partial work.
        assert!((e.busy_per_resource()[0] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn revocation_cascades_to_dependents() {
        let mut e = Engine::new(2);
        let a = e.add_task(0, 2.0, &[]); // revoked at t=1
        let b = e.add_task(1, 1.0, &[a]); // can never run
        let c = e.add_task(1, 1.0, &[]); // independent, completes
        e.revoke_resource(0, 1.0);
        e.run();
        assert_eq!(e.revoked(), vec![a, b]);
        assert!(e.is_done(c));
    }

    #[test]
    fn queued_tasks_on_dead_resource_never_start() {
        let mut e = Engine::new(2);
        let a = e.add_task(0, 2.0, &[]); // cut at 1
        let b = e.add_task(0, 2.0, &[]); // queued behind a: revoked, 0 busy
        let c = e.add_task(1, 5.0, &[]);
        e.revoke_resource(0, 1.0);
        let makespan = e.run();
        assert_eq!(e.revoked(), vec![a, b]);
        assert!((makespan - 5.0).abs() < 1e-12);
        assert!((e.busy_per_resource()[0] - 1.0).abs() < 1e-12);
        let _ = c;
    }

    #[test]
    fn recovery_wave_after_revocation() {
        // The failover pattern: wave 0 loses a task at t=1; the caller
        // re-dispatches an equivalent task on a healthy resource with an
        // earliest start at detection time.
        let mut e = Engine::new(2);
        let lost = e.add_task(0, 3.0, &[]);
        e.add_task(1, 1.0, &[]);
        e.revoke_resource(0, 1.0);
        e.run();
        assert_eq!(e.revoked(), vec![lost]);

        let detect = 1.0 + 0.25;
        let mut r = Engine::new(2);
        let re = r.add_task_at(1, 3.0, &[], detect);
        let makespan = r.run();
        assert!((makespan - (detect + 3.0)).abs() < 1e-12);
        assert!(r.is_done(re));
    }

    #[test]
    fn drain_keeps_running_task_and_revokes_tail() {
        let mut e = Engine::new(2);
        let a = e.add_task(0, 4.0, &[]); // running at drain time: finishes
        let b = e.add_task(0, 2.0, &[]); // queued tail: revoked, unstarted
        let c = e.add_task(1, 1.0, &[]);
        e.drain_resource(0, 1.0);
        let makespan = e.run();
        assert!((makespan - 4.0).abs() < 1e-12, "makespan {makespan}");
        assert!(e.is_done(a), "started task must finish on a draining resource");
        assert_eq!(e.revoked(), vec![b]);
        assert!(!e.started(b), "partial drain must never cut a started task");
        assert!(e.is_done(c));
        // The drainee's occupancy is exactly the started task.
        assert!((e.busy_per_resource()[0] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn drain_after_queue_empties_is_noop() {
        let mut e = Engine::new(1);
        let a = e.add_task(0, 1.0, &[]);
        e.drain_resource(0, 5.0);
        assert!((e.run() - 1.0).abs() < 1e-12);
        assert!(e.is_done(a));
        assert!(e.revoked().is_empty());
    }

    #[test]
    fn barrier_joins_all_dependencies() {
        let mut e = Engine::new(2);
        let a = e.add_task(0, 2.0, &[]);
        let b = e.add_task(1, 3.0, &[]);
        let bar = e.add_barrier(&[a, b]);
        let c = e.add_task(0, 1.0, &[bar]);
        let makespan = e.run();
        assert!((makespan - 4.0).abs() < 1e-12, "makespan {makespan}");
        assert!((e.finish_of(bar) - 3.0).abs() < 1e-12);
        assert!((e.finish_of(c) - 4.0).abs() < 1e-12);
        // Barriers occupy no resource.
        assert_eq!(e.busy_per_resource(), vec![3.0, 3.0]);
    }

    #[test]
    fn revocation_cascade_stops_at_tick_barrier() {
        // Tick t loses a task to a kill; tick t+1 work sits behind the
        // barrier and must survive (the failover layer re-dispatches the
        // loss within tick t, so the barrier resolves, not revokes).
        let mut e = Engine::new(2);
        let lost = e.add_task(0, 2.0, &[]); // cut at t=1
        let ok = e.add_task(1, 1.5, &[]);
        let bar = e.add_barrier(&[lost, ok]);
        let next = e.add_task(1, 1.0, &[bar]);
        e.revoke_resource(0, 1.0);
        let makespan = e.run();
        assert_eq!(e.revoked(), vec![lost], "cascade must not cross the barrier");
        assert!(e.is_done(bar));
        assert!(e.is_done(next), "next-tick work must survive the kill");
        // Barrier resolves at max(cut=1.0, ok=1.5); next runs 1.5..2.5.
        assert!((e.finish_of(bar) - 1.5).abs() < 1e-12);
        assert!((makespan - 2.5).abs() < 1e-12, "makespan {makespan}");
    }

    #[test]
    fn barrier_without_deps_completes_at_zero() {
        let mut e = Engine::new(1);
        let bar = e.add_barrier(&[]);
        let a = e.add_task(0, 1.0, &[bar]);
        assert!((e.run() - 1.0).abs() < 1e-12);
        assert!(e.is_done(bar));
        assert!(e.is_done(a));
    }

    #[test]
    fn drained_tail_behind_barrier_still_resolves() {
        // Partial drain revokes a queued task whose barrier must still
        // complete (resolution, not revocation, crosses the boundary).
        let mut e = Engine::new(2);
        let kept = e.add_task(0, 2.0, &[]);
        let tail = e.add_task(0, 2.0, &[]); // revoked by the drain
        let bar = e.add_barrier(&[kept, tail]);
        let next = e.add_task(1, 1.0, &[bar]);
        e.drain_resource(0, 0.5);
        e.run();
        assert_eq!(e.revoked(), vec![tail]);
        assert!(e.is_done(next));
        assert!(e.started(kept) && !e.started(tail));
    }

    // ----- live-byte tracking + OOM eviction ----------------------------

    #[test]
    fn mem_peak_counts_admitted_tasks() {
        // Two root tasks on one resource: both are admitted (dispatched)
        // at t=0, so their bytes coexist even though compute serializes.
        let mut e = Engine::new(2);
        e.add_task_mem(0, 1.0, &[], 100.0);
        e.add_task_mem(0, 1.0, &[], 50.0);
        e.add_task_mem(1, 1.0, &[], 30.0);
        e.run();
        assert_eq!(e.mem_peak_per_resource(), vec![150.0, 30.0]);
        assert!(e.oom_evictions().is_empty());
    }

    #[test]
    fn mem_releases_at_finish() {
        // A dependent admitted after its producer finished never
        // coexists with it: peak stays at the max single footprint.
        let mut e = Engine::new(1);
        let a = e.add_task_mem(0, 1.0, &[], 100.0);
        e.add_task_mem(0, 1.0, &[a], 80.0);
        e.run();
        assert_eq!(e.mem_peak_per_resource(), vec![100.0]);
    }

    #[test]
    fn oom_evicts_over_budget_task() {
        let mut e = Engine::new(2);
        e.set_mem_budget(0, 120.0);
        let a = e.add_task_mem(0, 1.0, &[], 100.0);
        let b = e.add_task_mem(0, 1.0, &[], 50.0); // 150 > 120: evicted
        let c = e.add_task_mem(1, 1.0, &[], 50.0);
        let makespan = e.run();
        assert!(e.is_done(a));
        assert!(e.is_done(c));
        assert!(!e.is_done(b) && !e.started(b));
        assert_eq!(e.revoked(), vec![b]);
        assert_eq!(e.oom_evictions(), &[(0, b, 0.0)]);
        assert!((makespan - 1.0).abs() < 1e-12);
        // The evicted task never contributed to the peak.
        assert_eq!(e.mem_peak_per_resource(), vec![100.0, 50.0]);
    }

    #[test]
    fn oom_eviction_cascades_to_dependents() {
        let mut e = Engine::new(2);
        e.set_mem_budget(0, 80.0);
        let big = e.add_task_mem(0, 1.0, &[], 100.0); // evicted at t=0
        let dep = e.add_task(1, 1.0, &[big]); // can never run
        let ok = e.add_task(1, 2.0, &[]);
        e.run();
        assert_eq!(e.revoked(), vec![big, dep]);
        assert!(e.is_done(ok));
        assert_eq!(e.oom_evictions().len(), 1);
    }

    #[test]
    fn oom_eviction_is_a_recoverable_loss() {
        // The failover pattern for an OOM: re-dispatch the evicted task
        // to a resource with headroom — one resend, nothing else lost.
        let mut e = Engine::new(2);
        e.set_mem_budget(0, 100.0);
        e.set_mem_budget(1, 200.0);
        let _a = e.add_task_mem(0, 1.0, &[], 90.0);
        let evicted = e.add_task_mem(0, 1.0, &[], 40.0);
        e.run();
        assert_eq!(e.revoked(), vec![evicted]);

        let mut r = Engine::new(2);
        r.set_mem_budget(1, 200.0);
        let re = r.add_task_mem(1, 1.0, &[], 40.0);
        r.run();
        assert!(r.is_done(re));
        assert_eq!(r.mem_peak_per_resource()[1], 40.0);
    }

    #[test]
    fn later_admission_can_fit_after_release() {
        // A dependency-gated task admits only after the producer's bytes
        // release, so it fits where simultaneous admission would not.
        let mut e = Engine::new(1);
        e.set_mem_budget(0, 120.0);
        let a = e.add_task_mem(0, 1.0, &[], 100.0);
        let b = e.add_task_mem(0, 1.0, &[a], 100.0);
        e.run();
        assert!(e.is_done(a) && e.is_done(b));
        assert!(e.oom_evictions().is_empty());
        assert_eq!(e.mem_peak_per_resource(), vec![100.0]);
    }

    #[test]
    fn zero_budget_is_unlimited() {
        let mut e = Engine::new(1);
        e.add_task_mem(0, 1.0, &[], 1e18);
        e.add_task_mem(0, 1.0, &[], 1e18);
        e.run();
        assert!(e.oom_evictions().is_empty());
        assert_eq!(e.mem_peak_per_resource(), vec![2e18]);
    }

    #[test]
    fn revoked_queued_task_releases_its_bytes() {
        // A queued task killed with its resource must release its
        // admitted bytes (no phantom residency).
        let mut e = Engine::new(2);
        let _a = e.add_task_mem(0, 2.0, &[], 50.0);
        let _b = e.add_task_mem(0, 2.0, &[], 50.0); // queued; revoked at t=1
        let c = e.add_task_mem(1, 3.0, &[], 10.0);
        e.revoke_resource(0, 1.0);
        e.run();
        assert!(e.is_done(c));
        // Peak saw both admissions; live accounting drained to zero.
        assert_eq!(e.mem_peak_per_resource(), vec![100.0, 10.0]);
        assert_eq!(e.live_mem, vec![0.0, 0.0]);
    }

    #[test]
    fn revoked_at_time_zero_runs_nothing() {
        let mut e = Engine::new(1);
        let a = e.add_task(0, 1.0, &[]);
        e.revoke_resource(0, 0.0);
        let makespan = e.run();
        assert_eq!(makespan, 0.0);
        assert_eq!(e.revoked(), vec![a]);
        assert_eq!(e.busy_per_resource(), vec![0.0]);
    }
}
