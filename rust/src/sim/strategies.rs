//! Strategy executors: build and time one training iteration under each
//! balancing scheme. All strategies share the same cost primitives
//! ([`SimParams`]) so comparisons isolate the *scheduling* differences —
//! exactly the paper's experimental design.
//!
//! Conventions:
//! * a **logical device** is one TP group (TP=8 ⇒ one DGX node): TP
//!   shards every GEMM and attention head-wise over the same tokens, so
//!   the group acts as a single device with `tp×` the FLOP rate;
//! * CA time is predicted by the [`Profiler`] (captures the Fig.-5
//!   sub-128-token tile penalty); linear time by the analytic β model;
//! * backward costs 2× (linear) / 2.5× (CA, recompute) forward;
//! * inter-device traffic crosses InfiniBand (logical device = node);
//! * the non-elastic executors here assume *uniform* devices (the
//!   paper's setting) and call [`schedule`] directly; the elastic
//!   flavors ([`crate::elastic`]) plan against per-server beliefs via
//!   [`crate::coordinator::schedule_with_beliefs`] instead.

use crate::config::{ClusterConfig, ModelConfig};
use crate::coordinator::{schedule, Item, Plan, Profiler, SchedulerCfg};
use crate::coordinator::pingpong::{
    layer_time_pingpong, layer_time_signal, layer_time_single_stream, split_nano,
};
use crate::coordinator::scheduler::items_from_chunks;
use crate::data::{pack_fixed, pack_variable_length, Chunk, Document};
use crate::model::flops::{CA_BWD_FACTOR, LINEAR_BWD_FACTOR};
use crate::model::{FlopsModel, MemoryModel};
use crate::parallel::pipeline::{distca_ticks, one_f_one_b, PipePhase};
use crate::sim::engine::Engine;
use crate::sim::report::IterationReport;

/// Communication-handling ablation (Fig. 11).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommMode {
    /// Ping-pong overlap (DistCA proper).
    PingPong,
    /// Communication serialized with compute ("Single Stream").
    SingleStream,
    /// 1-byte messages — pure compute-balance floor ("Signal").
    Signal,
}

/// Shared cost primitives for one simulated configuration.
#[derive(Debug, Clone)]
pub struct SimParams {
    pub model: ModelConfig,
    pub cluster: ClusterConfig,
    pub f: FlopsModel,
    pub mem: MemoryModel,
    pub prof: Profiler,
    pub tp: usize,
    pub pp: usize,
    /// Scheduler tolerance ε (DistCA only).
    pub tolerance: f64,
    pub comm_mode: CommMode,
}

impl SimParams {
    pub fn new(model: ModelConfig, cluster: ClusterConfig, tp: usize, pp: usize) -> SimParams {
        let f = FlopsModel::new(&model);
        let mem = MemoryModel::new(&model);
        let prof = Profiler::analytic(&f, &cluster);
        SimParams {
            model,
            cluster,
            f,
            mem,
            prof,
            tp,
            pp,
            // With the Appendix-A overlap guard in the scheduler, tighter
            // balance is free whenever communication hides — Fig. 12
            // sweeps ε explicitly; 0.02 is the tuned default.
            tolerance: 0.02,
            comm_mode: CommMode::PingPong,
        }
    }

    /// Logical devices (TP groups) in the cluster.
    pub fn n_logical(&self) -> usize {
        self.cluster.n_gpus() / self.tp
    }

    /// Aggregate linear-layer FLOP rate of one logical device.
    pub fn rate_linear(&self) -> f64 {
        self.tp as f64 * self.cluster.linear_flops()
    }

    /// Forward time of one layer's context-independent part for `tokens`
    /// on one logical device.
    pub fn linear_layer_fwd(&self, tokens: usize) -> f64 {
        self.f.linear_fwd(tokens) / self.rate_linear()
    }

    /// Forward CA time of a set of pieces (doc slices) on one logical
    /// device, one layer, via the profiler (TP splits the heads).
    pub fn ca_layer_fwd_pieces(&self, pieces: &[(usize, usize)]) -> f64 {
        let shapes: Vec<(f64, f64)> = pieces
            .iter()
            .map(|&(q, kv)| (q as f64, kv as f64))
            .collect();
        self.prof.predict_batch(&shapes) / self.tp as f64
    }

    /// Layers resident on one PP stage.
    pub fn layers_per_stage(&self) -> f64 {
        self.model.n_layers as f64 / self.pp as f64
    }

    /// Full fwd+bwd time of one *chunk* passing through one PP stage
    /// (all its layers), given its linear tokens and CA piece shapes.
    fn stage_time(&self, tokens: usize, pieces: &[(usize, usize)], phase: PipePhase) -> f64 {
        let lin = self.linear_layer_fwd(tokens);
        let ca = self.ca_layer_fwd_pieces(pieces);
        let per_layer = match phase {
            PipePhase::Forward => lin + ca,
            PipePhase::Backward => lin * LINEAR_BWD_FACTOR + ca * CA_BWD_FACTOR,
        };
        per_layer * self.layers_per_stage()
    }
}

/// CA piece shapes (q_len, kv_len) of a packed chunk under causal masking.
fn chunk_pieces(chunk: &Chunk) -> Vec<(usize, usize)> {
    chunk
        .pieces
        .iter()
        .map(|p| (p.len, p.offset + p.len))
        .collect()
}

/// Assign `chunks` to `n_groups` DP groups round-robin, returning the
/// per-group microbatch lists (chunk indices).
pub fn assign_round_robin(n_chunks: usize, n_groups: usize) -> Vec<Vec<usize>> {
    let mut groups = vec![Vec::new(); n_groups];
    for c in 0..n_chunks {
        groups[c % n_groups].push(c);
    }
    groups
}

/// Per-GPU memory of a stage holding `resident_tokens` of activations
/// plus `kv_tokens` gathered KV token-layers.
fn device_mem(p: &SimParams, resident_tokens: usize, kv_tokens: f64) -> f64 {
    p.mem
        .breakdown(resident_tokens, kv_tokens, p.tp, p.pp)
        .total()
}

// ---------------------------------------------------------------------
// Baseline 1: fixed-size packing + plain DP (with optional PP).
// ---------------------------------------------------------------------

/// Simulate one iteration of fixed-size packing + DP (+PP when `p.pp>1`).
pub fn run_packed_dp(docs: &[Document], chunk_tokens: usize, p: &SimParams) -> IterationReport {
    let chunks = pack_fixed(docs, chunk_tokens);
    run_chunks_dp(&chunks, chunk_tokens, p, "Packed+DP", 1)
}

/// Shared DP/PP executor for chunk-per-microbatch strategies at a given
/// CP degree (`cp=1` ⇒ no CP). Used by packed-DP, per-doc CP, and WLB.
fn run_chunks_dp(
    chunks: &[Chunk],
    chunk_tokens: usize,
    p: &SimParams,
    name: &str,
    cp: usize,
) -> IterationReport {
    let n_logical = p.n_logical();
    assert!(n_logical % (p.pp * cp) == 0, "logical {n_logical} not divisible");
    let n_groups = n_logical / (p.pp * cp);
    let groups = assign_round_robin(chunks.len(), n_groups);
    let total_tokens: usize = chunks.iter().map(|c| c.tokens()).sum();

    // Per-(group, microbatch) stage durations. Under CP, each rank holds
    // 1/cp of every document (head-tail), with the tile penalty for tiny
    // shards, plus the KV all-gather before CA of every layer.
    let mut iter_time = 0.0f64;
    let mut device_busy = vec![0.0; n_logical];
    let mut device_mem_v = vec![0.0; n_logical];
    let mut comm_bytes = 0.0;
    let mut comm_exposed = 0.0;
    let mut oom = false;

    for (g, mbs) in groups.iter().enumerate() {
        // Durations per microbatch for this group.
        let mut fwd = Vec::with_capacity(mbs.len());
        let mut bwd = Vec::with_capacity(mbs.len());
        let mut ag_per_stage = Vec::with_capacity(mbs.len());
        for &ci in mbs {
            let chunk = &chunks[ci];
            let tokens_rank = chunk.tokens() / cp;
            // CA pieces on the worst CP rank: head+tail per doc piece.
            let pieces: Vec<(usize, usize)> = if cp == 1 {
                chunk_pieces(chunk)
            } else {
                let mut v = Vec::new();
                for piece in &chunk.pieces {
                    for s in crate::parallel::cp::per_document_cp_shards(
                        piece.doc, piece.len, cp,
                    ) {
                        if s.cp_rank == 0 {
                            // rank 0 holds the widest pair incl. residue
                            if s.width > 0 {
                                v.push((s.width, piece.offset + s.head_start + s.width));
                            }
                            let tail_q = s.width + s.extra;
                            if tail_q > 0 {
                                v.push((
                                    tail_q,
                                    piece.offset + s.tail_start + tail_q,
                                ));
                            }
                        }
                    }
                }
                v
            };
            let f_t = p.stage_time(tokens_rank, &pieces, PipePhase::Forward);
            let b_t = p.stage_time(tokens_rank, &pieces, PipePhase::Backward);
            // All-gather of KV for the whole chunk, per layer, forward
            // only (KV is retained for backward — the Fig. 3b memory toll).
            let ag = if cp > 1 {
                // TP shards the KV heads, so each GPU all-gathers 1/tp of
                // the chunk's KV over its own NIC.
                let bytes_per_rank = (chunk.tokens() / cp * p.model.kv_bytes_per_token())
                    as f64
                    / p.tp as f64;
                comm_bytes += bytes_per_rank * (cp * p.tp) as f64 * p.layers_per_stage();
                p.cluster.allgather_time(bytes_per_rank, cp, true) * p.layers_per_stage()
            } else {
                0.0
            };
            fwd.push(f_t + ag);
            bwd.push(b_t);
            ag_per_stage.push(ag);
            comm_exposed += ag * p.pp as f64;
        }

        // Execute this group's pipeline (pp=1 collapses to a serial sum).
        let sched = one_f_one_b(p.pp, mbs.len());
        let mut eng = Engine::new(p.pp);
        // task ids per (stage, mb, phase)
        let mut fwd_id = vec![vec![usize::MAX; mbs.len()]; p.pp];
        let mut bwd_id = vec![vec![usize::MAX; mbs.len()]; p.pp];
        // We add ops stage-by-stage in program order; dependencies on
        // other stages' ops may not exist yet, so do two passes: build in
        // a global order that respects inter-stage deps. Simpler: iterate
        // "rounds" until all ops placed.
        let mut cursor = vec![0usize; p.pp];
        let total_ops: usize = sched.ops.iter().map(|v| v.len()).sum();
        let mut placed = 0usize;
        while placed < total_ops {
            let mut progressed = false;
            for s in 0..p.pp {
                while cursor[s] < sched.ops[s].len() {
                    let op = sched.ops[s][cursor[s]];
                    let (dep_ok, deps): (bool, Vec<usize>) = match op.phase {
                        PipePhase::Forward => {
                            if s == 0 {
                                (true, vec![])
                            } else if fwd_id[s - 1][op.mb] != usize::MAX {
                                (true, vec![fwd_id[s - 1][op.mb]])
                            } else {
                                (false, vec![])
                            }
                        }
                        PipePhase::Backward => {
                            let mut d = Vec::new();
                            let mut ok = true;
                            if fwd_id[s][op.mb] != usize::MAX {
                                d.push(fwd_id[s][op.mb]);
                            } else {
                                ok = false;
                            }
                            if s + 1 < p.pp {
                                if bwd_id[s + 1][op.mb] != usize::MAX {
                                    d.push(bwd_id[s + 1][op.mb]);
                                } else {
                                    ok = false;
                                }
                            }
                            (ok, d)
                        }
                    };
                    if !dep_ok {
                        break;
                    }
                    let dur = match op.phase {
                        PipePhase::Forward => fwd[op.mb],
                        PipePhase::Backward => bwd[op.mb],
                    };
                    let id = eng.add_task(s, dur, &deps);
                    match op.phase {
                        PipePhase::Forward => fwd_id[s][op.mb] = id,
                        PipePhase::Backward => bwd_id[s][op.mb] = id,
                    }
                    cursor[s] += 1;
                    placed += 1;
                    progressed = true;
                }
            }
            assert!(progressed, "pipeline construction deadlocked");
        }
        let makespan = eng.run();
        iter_time = iter_time.max(makespan);
        let busy = eng.busy_per_resource();

        // Map this group's stages onto logical device indices.
        for stage in 0..p.pp {
            for r in 0..cp {
                let dev = (g * p.pp + stage) * cp + r;
                device_busy[dev] = busy[stage];
                // Memory: in-flight microbatches on stage s under 1F1B is
                // ~ (pp - s); worst mb tokens on this rank + retained KV.
                let inflight = (p.pp - stage).max(1);
                let max_tokens = mbs
                    .iter()
                    .map(|&ci| chunks[ci].tokens() / cp)
                    .max()
                    .unwrap_or(0);
                let kv_tokens = if cp > 1 {
                    // retained gathered KV: full chunk tokens × resident
                    // layers (worst microbatch).
                    mbs.iter()
                        .map(|&ci| chunks[ci].tokens())
                        .max()
                        .unwrap_or(0) as f64
                        * p.layers_per_stage()
                } else {
                    0.0
                };
                let m = device_mem(p, max_tokens * inflight, kv_tokens);
                device_mem_v[dev] = m;
                if m > p.cluster.hbm_bytes {
                    oom = true;
                }
            }
        }
    }

    let _ = chunk_tokens;
    IterationReport {
        strategy: name.into(),
        iter_time,
        tokens: total_tokens,
        device_busy,
        device_mem: device_mem_v,
        comm_bytes,
        comm_exposed,
        oom,
        config: format!("dp={} pp={} cp={cp} tp={}", n_logical / (p.pp * cp), p.pp, p.tp),
        mem: None,
    }
}

// ---------------------------------------------------------------------
// Baseline 2: per-document context parallelism.
// ---------------------------------------------------------------------

/// Fixed-size packing + per-document head-tail CP at degree `cp`.
pub fn run_perdoc_cp(
    docs: &[Document],
    chunk_tokens: usize,
    cp: usize,
    p: &SimParams,
) -> IterationReport {
    let chunks = pack_fixed(docs, chunk_tokens);
    run_chunks_dp(&chunks, chunk_tokens, p, "PerDocCP", cp)
}

// ---------------------------------------------------------------------
// Baseline 3: WLB-ideal — variable-length chunks + best DP×CP sweep.
// ---------------------------------------------------------------------

/// WLB-LLM reproduction: variable-length chunking to balance attention
/// FLOPs, swept over CP degrees; returns the best non-OOM configuration
/// ("WLB-ideal", §6.1), falling back to the least-bad if all OOM.
pub fn run_wlb_ideal(docs: &[Document], chunk_tokens: usize, p: &SimParams) -> IterationReport {
    let reports = wlb_sweep(docs, chunk_tokens, p);
    pick_best(reports)
}

/// Pure variable-length chunking (no CP) — the method Fig. 4 isolates:
/// balance `Σl²` across DP ranks, bounded by the per-rank memory cap.
pub fn run_varlen_chunking(docs: &[Document], chunk_tokens: usize, p: &SimParams) -> IterationReport {
    let cap = p
        .mem
        .max_tokens_per_gpu(&p.cluster, p.tp, p.pp)
        .max(chunk_tokens / 4);
    let n_chunks = (docs.iter().map(|d| d.len).sum::<usize>() / chunk_tokens).max(1);
    let chunks = pack_variable_length(docs, n_chunks, cap, &p.f);
    run_chunks_dp(&chunks, chunk_tokens, p, "VarLenChunk", 1)
}

/// All points of the WLB DP×CP sweep (Fig. 6 plots these).
pub fn wlb_sweep(docs: &[Document], chunk_tokens: usize, p: &SimParams) -> Vec<IterationReport> {
    let n_per_pipeline = p.n_logical() / p.pp;
    let mut out = Vec::new();
    let mut cp = 1usize;
    while cp <= n_per_pipeline && cp <= 16 {
        if n_per_pipeline % cp == 0 {
            // Token cap per chunk: what fits in HBM for this topology.
            let cap = p
                .mem
                .max_tokens_per_gpu(&p.cluster, p.tp, p.pp)
                .saturating_mul(cp)
                .max(chunk_tokens / 4)
                .min(chunk_tokens * 4);
            let n_chunks = (docs.iter().map(|d| d.len).sum::<usize>() / chunk_tokens).max(1);
            let chunks = pack_variable_length(docs, n_chunks, cap, &p.f);
            let mut r = run_chunks_dp(&chunks, chunk_tokens, p, "WLB-ideal", cp);
            r.config = format!("dp={} cp={cp} pp={} tp={}", n_per_pipeline / cp, p.pp, p.tp);
            out.push(r);
        }
        cp *= 2;
    }
    out
}

fn pick_best(reports: Vec<IterationReport>) -> IterationReport {
    let feasible: Vec<&IterationReport> = reports.iter().filter(|r| !r.oom).collect();
    let pool: Vec<&IterationReport> = if feasible.is_empty() {
        reports.iter().collect()
    } else {
        feasible
    };
    pool.into_iter()
        .max_by(|a, b| a.throughput().partial_cmp(&b.throughput()).unwrap())
        .expect("empty sweep")
        .clone()
}

// ---------------------------------------------------------------------
// DistCA — core attention disaggregation.
// ---------------------------------------------------------------------

/// Sequential-fill placement (§6.1): each logical device takes
/// `total/n` tokens of context-independent work; documents crossing the
/// threshold spill onto the next device.
pub fn distca_placement(docs: &[Document], n_devices: usize) -> Vec<Chunk> {
    let total: usize = docs.iter().map(|d| d.len).sum();
    let per_dev = (total + n_devices - 1) / n_devices;
    pack_fixed(docs, per_dev.max(2))
}

/// Simulate one DistCA iteration (no PP).
///
/// Execution model (matching the baselines' gradient accumulation): the
/// global batch is processed as a sequence of *microbatches* — one per
/// `chunk_tokens`-sized data chunk — and each microbatch's tokens are
/// spread over ALL logical devices by sequential fill (§6.1). Every
/// device is an in-place attention server; the scheduler balances the
/// microbatch's CA-tasks across the whole pool; ping-pong hides the
/// dispatch communication. Activation residency is therefore
/// `chunk_tokens / n` per device per microbatch — the memory-balance
/// property the paper claims (baselines OOM first).
pub fn run_distca(docs: &[Document], chunk_tokens: usize, p: &SimParams) -> IterationReport {
    if p.pp > 1 {
        return run_distca_pp(docs, chunk_tokens, p);
    }
    let n = p.n_logical();
    // One DistCA microbatch holds up to `chunk_tokens` resident tokens on
    // EVERY device (the same per-device activation envelope the baseline
    // has with one chunk per DP rank), i.e. n·chunk_tokens tokens per
    // pass; gradient accumulation covers the rest of the batch.
    let global_chunks = pack_fixed(docs, n * chunk_tokens);
    let total_tokens: usize = global_chunks.iter().map(|c| c.tokens()).sum();
    let n_layers = p.model.n_layers as f64;

    let mut iter_time = 0.0f64;
    let mut device_busy = vec![0.0f64; n];
    let mut device_mem_v = vec![0.0f64; n];
    // Worst per-server transient arena bytes over the microbatches
    // (in-place replay, per GPU within the TP group) — §5, Fig. 3b.
    let mut arena_peaks = vec![0.0f64; n];
    let mut comm_bytes = 0.0f64;
    let mut comm_exposed = 0.0f64;
    let mut oom = false;

    for mb in &global_chunks {
        // Sequential-fill the microbatch over all devices.
        let mb_docs: Vec<Document> = mb
            .pieces
            .iter()
            .map(|piece| Document::new(piece.doc, piece.len))
            .collect();
        let per_dev = (mb.tokens() + n - 1) / n;
        let placed = pack_fixed(&mb_docs, per_dev.max(2));
        let items = items_from_chunks(&placed);
        let cfg = SchedulerCfg {
            tolerance: p.tolerance,
            // cap = bw·target + bw·tp·linear ≡ server_bw·(target + extra):
            // loads are single-GPU kernel seconds, linear is device secs.
            server_bw: p.cluster.ib_bw,
            extra_window: p.linear_layer_fwd(per_dev) * p.tp as f64,
            overlap_frac: 1.0,
            ..Default::default()
        };
        let plan = schedule(&items, n, &p.f, &p.prof, &p.model, &cfg);
        let mrep = crate::memplan::MemReport::for_plan(&plan, &p.model, 0.0)
            .expect("unbounded replay cannot OOM");
        for (s, &pk) in mrep.per_server_peak.iter().enumerate() {
            arena_peaks[s] = arena_peaks[s].max(pk / p.tp as f64);
        }
        let (layer_fwd, layer_bwd, mb_bytes, exposed) =
            distca_layer_times(&placed, &plan, p);
        iter_time += (layer_fwd + layer_bwd) * n_layers;
        comm_bytes += mb_bytes * n_layers;
        comm_exposed += exposed * n_layers;
        for s in 0..n {
            let tokens = placed.get(s).map(|c| c.tokens()).unwrap_or(0);
            let lin = p.linear_layer_fwd(tokens) * (1.0 + LINEAR_BWD_FACTOR);
            let ca = plan.server_load[s] / p.tp as f64 * (1.0 + CA_BWD_FACTOR);
            device_busy[s] += (lin + ca) * n_layers;
            let m = device_mem(p, tokens, 0.0);
            device_mem_v[s] = device_mem_v[s].max(m);
            if m > p.cluster.hbm_bytes {
                oom = true;
            }
        }
    }
    IterationReport {
        strategy: "DistCA".into(),
        iter_time,
        tokens: total_tokens,
        device_busy,
        device_mem: device_mem_v,
        comm_bytes,
        comm_exposed,
        oom,
        config: format!("servers={n} tol={} tp={}", p.tolerance, p.tp),
        mem: Some(crate::memplan::MemReport::from_peaks(arena_peaks, 0.0)),
    }
}

/// Per-layer forward and backward makespans of a DistCA plan under the
/// configured comm mode. Returns (fwd, bwd, dispatch_bytes_per_layer,
/// exposed_comm_per_layer).
fn distca_layer_times(chunks: &[Chunk], plan: &Plan, p: &SimParams) -> (f64, f64, f64, f64) {
    let n = plan.n_servers;
    let bw = p.cluster.ib_bw * p.tp as f64; // per logical device (node): tp NICs
    let mut fwd = 0.0f64;
    let mut bwd = 0.0f64;
    let mut signal_fwd = 0.0f64;
    let mut signal_bwd = 0.0f64;
    for s in 0..n {
        let tokens = chunks.get(s).map(|c| c.tokens()).unwrap_or(0);
        let lin = p.linear_layer_fwd(tokens);
        // server_load is single-GPU kernel latency; a logical device's TP
        // group splits the heads tp-ways.
        let ca = plan.server_load[s] / p.tp as f64;
        let send: f64 = plan.comm_matrix[s].iter().sum::<f64>()
            + plan.return_matrix[s].iter().sum::<f64>();
        let recv: f64 = (0..n)
            .map(|o| plan.comm_matrix[o][s] + plan.return_matrix[o][s])
            .sum();
        let comm_t = send.max(recv) / bw;
        let (ping, pong) = split_nano(lin, ca, comm_t * 0.7, comm_t * 0.3);
        let dev_fwd = match p.comm_mode {
            CommMode::PingPong => layer_time_pingpong(ping, pong),
            CommMode::SingleStream => layer_time_single_stream(ping, pong),
            CommMode::Signal => layer_time_signal(ping, pong),
        };
        // Backward: linear 2x, CA 2.5x, comm 2x (dO in, dQ/dKV back).
        let (bping, bpong) = split_nano(
            lin * LINEAR_BWD_FACTOR,
            ca * CA_BWD_FACTOR,
            comm_t * 2.0 * 0.7,
            comm_t * 2.0 * 0.3,
        );
        let dev_bwd = match p.comm_mode {
            CommMode::PingPong => layer_time_pingpong(bping, bpong),
            CommMode::SingleStream => layer_time_single_stream(bping, bpong),
            CommMode::Signal => layer_time_signal(bping, bpong),
        };
        fwd = fwd.max(dev_fwd);
        bwd = bwd.max(dev_bwd);
        signal_fwd = signal_fwd.max(layer_time_signal(ping, pong));
        signal_bwd = signal_bwd.max(layer_time_signal(bping, bpong));
    }
    let dispatch: f64 = plan.total_comm_bytes();
    let exposed = (fwd - signal_fwd) + (bwd - signal_bwd);
    (fwd, bwd, dispatch * 3.0, exposed) // fwd bytes + 2x bwd bytes
}

/// The active `(logical device, chunk index)` pairs of one PP tick row
/// across all DP groups (idle warm-up/drain stages contribute nothing —
/// they serve attention only).
pub fn pp_tick_active(
    groups: &[Vec<usize>],
    row: &[Option<usize>],
    pp: usize,
) -> Vec<(usize, usize)> {
    let mut active: Vec<(usize, usize)> = Vec::new();
    for (g, mbs) in groups.iter().enumerate() {
        for (stage, mb) in row.iter().enumerate().take(pp) {
            if let Some(mb) = *mb {
                if let Some(&ci) = mbs.get(mb) {
                    active.push((g * pp + stage, ci));
                }
            }
        }
    }
    active
}

/// Scheduling items of one PP tick: every active device's chunk pieces,
/// homed at that device. Shared by the fault-free PP executor and the
/// elastic PP path (`crate::elastic::pp`), so both plan the same shapes.
pub fn pp_tick_items(chunks: &[Chunk], active: &[(usize, usize)]) -> Vec<Item> {
    let mut items: Vec<Item> = Vec::new();
    for &(dev, ci) in active {
        for piece in &chunks[ci].pieces {
            let mut len = piece.len;
            if len % 2 == 1 {
                len -= 1;
            }
            if len == 0 {
                continue;
            }
            if piece.offset == 0 {
                items.push(Item::whole_doc(piece.doc, len, dev));
            } else {
                items.push(Item {
                    doc: piece.doc,
                    doc_len: 2 * piece.offset + len,
                    i: piece.offset,
                    j: piece.offset + len / 2,
                    home: dev,
                });
            }
        }
    }
    items
}

/// DistCA under pipeline parallelism: tick-aligned same-phase schedule
/// (§4.1, Fig. 8); each tick's CA-tasks from *all* stages and DP groups
/// are pooled over every device, including warm-up/drain idle stages.
pub fn run_distca_pp(docs: &[Document], chunk_tokens: usize, p: &SimParams) -> IterationReport {
    let n = p.n_logical();
    let n_groups = n / p.pp;
    // Microbatches: fixed-size chunks (memory-balanced), round-robin to
    // DP groups.
    let chunks = pack_fixed(docs, chunk_tokens);
    let total_tokens: usize = chunks.iter().map(|c| c.tokens()).sum();
    let groups = assign_round_robin(chunks.len(), n_groups);
    let m = groups.iter().map(|g| g.len()).max().unwrap_or(0).max(1);
    let sched = distca_ticks(p.pp, m);
    let cfg = SchedulerCfg {
        tolerance: p.tolerance,
        server_bw: p.cluster.ib_bw,
        extra_window: p.linear_layer_fwd(chunk_tokens) * p.tp as f64,
        overlap_frac: 1.0,
        ..Default::default()
    };

    let mut iter_time = 0.0f64;
    let mut device_busy = vec![0.0; n];
    let mut arena_peaks = vec![0.0f64; n];
    let mut comm_bytes = 0.0f64;
    let mut comm_exposed = 0.0f64;

    for (t, row) in sched.tick_ops.iter().enumerate() {
        let phase = sched.tick_phases[t];
        // Gather active (device, chunk) pairs across all DP groups, then
        // build items homed at the active devices; schedule over ALL n
        // devices (idle warm-up/drain stages serve attention too).
        let active = pp_tick_active(&groups, row, p.pp);
        if active.is_empty() {
            continue;
        }
        let items = pp_tick_items(&chunks, &active);
        let plan = schedule(&items, n, &p.f, &p.prof, &p.model, &cfg);
        let mrep = crate::memplan::MemReport::for_plan(&plan, &p.model, 0.0)
            .expect("unbounded replay cannot OOM");
        for (s, &pk) in mrep.per_server_peak.iter().enumerate() {
            arena_peaks[s] = arena_peaks[s].max(pk / p.tp as f64);
        }
        // Tick time: max over devices of overlapped (linear_stage, ca,
        // comm); linear only on active devices, CA on all.
        let bw = p.cluster.ib_bw * p.tp as f64;
        let layers = p.layers_per_stage();
        let (lin_f, ca_f) = match phase {
            PipePhase::Forward => (1.0, 1.0),
            PipePhase::Backward => (LINEAR_BWD_FACTOR, CA_BWD_FACTOR),
        };
        let mut tick_time = 0.0f64;
        let mut tick_signal = 0.0f64;
        for dev in 0..n {
            let tokens = active
                .iter()
                .find(|&&(d, _)| d == dev)
                .map(|&(_, ci)| chunks[ci].tokens())
                .unwrap_or(0);
            let lin = p.linear_layer_fwd(tokens) * lin_f * layers;
            let ca = plan.server_load[dev] / p.tp as f64 * ca_f * layers;
            let send: f64 = plan.comm_matrix[dev].iter().sum::<f64>()
                + plan.return_matrix[dev].iter().sum::<f64>();
            let recv: f64 = (0..n)
                .map(|o| plan.comm_matrix[o][dev] + plan.return_matrix[o][dev])
                .sum();
            let comm_t = send.max(recv) / bw * layers * if ca_f > 1.0 { 2.0 } else { 1.0 };
            let (ping, pong) = split_nano(lin, ca, comm_t * 0.7, comm_t * 0.3);
            let dt = match p.comm_mode {
                CommMode::PingPong => layer_time_pingpong(ping, pong),
                CommMode::SingleStream => layer_time_single_stream(ping, pong),
                CommMode::Signal => layer_time_signal(ping, pong),
            };
            tick_time = tick_time.max(dt);
            tick_signal = tick_signal.max(layer_time_signal(ping, pong));
            device_busy[dev] += lin + ca;
        }
        iter_time += tick_time;
        comm_exposed += tick_time - tick_signal;
        comm_bytes += plan.total_comm_bytes() * layers;
    }

    let mut device_mem_v = vec![0.0; n];
    let mut oom = false;
    for g in 0..n_groups {
        for stage in 0..p.pp {
            let dev = g * p.pp + stage;
            let inflight = (p.pp - stage).max(1);
            let max_tokens = groups[g]
                .iter()
                .map(|&ci| chunks[ci].tokens())
                .max()
                .unwrap_or(0);
            let mem = device_mem(p, max_tokens * inflight, 0.0);
            device_mem_v[dev] = mem;
            if mem > p.cluster.hbm_bytes {
                oom = true;
            }
        }
    }
    IterationReport {
        strategy: "DistCA".into(),
        iter_time,
        tokens: total_tokens,
        device_busy,
        device_mem: device_mem_v,
        comm_bytes,
        comm_exposed,
        oom,
        config: format!(
            "servers={n} dp={n_groups} pp={} tol={} tp={}",
            p.pp, p.tolerance, p.tp
        ),
        mem: Some(crate::memplan::MemReport::from_peaks(arena_peaks, 0.0)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::run::DataDist;
    use crate::data::distributions::sampler_for;
    use crate::util::rng::Rng;

    fn params(nodes: usize, pp: usize) -> SimParams {
        SimParams::new(
            ModelConfig::llama3_8b(),
            ClusterConfig::h200(nodes),
            8,
            pp,
        )
    }

    fn sample_docs(max_len: usize, budget: usize, seed: u64) -> Vec<Document> {
        let mut rng = Rng::new(seed);
        sampler_for(DataDist::Pretrain, max_len).sample_tokens(&mut rng, budget, 0)
    }

    #[test]
    fn packed_dp_reports_sane() {
        let p = params(4, 1);
        let docs = sample_docs(65536, 4 * 65536, 1);
        let r = run_packed_dp(&docs, 65536, &p);
        assert!(r.iter_time > 0.0);
        assert_eq!(r.tokens, 4 * 65536);
        assert!(r.throughput() > 0.0);
        assert_eq!(r.device_busy.len(), 4);
        assert!(r.idle_fraction() >= 0.0 && r.idle_fraction() < 1.0);
    }

    #[test]
    fn distca_beats_packed_dp_on_skewed_batches() {
        // The headline claim at small scale: with skewed document lengths
        // DistCA's iteration is faster than packed DP's.
        let p = params(4, 1);
        let docs = sample_docs(131072, 4 * 131072, 2);
        let dp = run_packed_dp(&docs, 131072, &p);
        let ca = run_distca(&docs, 131072, &p);
        assert!(
            ca.iter_time < dp.iter_time,
            "DistCA {} should beat DP {}",
            ca.iter_time,
            dp.iter_time
        );
        // And with near-perfect balance:
        assert!(ca.idle_fraction() < dp.idle_fraction() + 1e-9);
    }

    #[test]
    fn distca_balances_memory_better_than_wlb() {
        let p = params(4, 1);
        let docs = sample_docs(131072, 4 * 131072, 3);
        let wlb = run_wlb_ideal(&docs, 131072, &p);
        let ca = run_distca(&docs, 131072, &p);
        assert!(
            ca.memory_divergence() <= wlb.memory_divergence() + 0.05,
            "distca div {} vs wlb {}",
            ca.memory_divergence(),
            wlb.memory_divergence()
        );
    }

    #[test]
    fn distca_reports_balanced_transient_memory() {
        // §5 / Fig. 3b: the scheduler spreads arena bytes with the
        // FLOPs, so the in-place transient peaks stay near-balanced and
        // strictly better than home placement would be.
        let p = params(4, 1);
        let docs = sample_docs(131072, 4 * 131072, 3);
        let ca = run_distca(&docs, 131072, &p);
        let mem = ca.mem.expect("DistCA must report transient memory");
        assert_eq!(mem.per_server_peak.len(), 4);
        assert!(mem.per_server_peak.iter().all(|&pk| pk > 0.0));
        assert!(
            mem.max_mean_ratio() < 2.0,
            "balanced plans keep transient memory near-even: {}",
            mem.max_mean_ratio()
        );
        // Baselines carry no CA-dispatch plan to replay.
        assert!(run_packed_dp(&docs, 131072, &p).mem.is_none());
    }

    #[test]
    fn cp_reduces_idle_but_adds_comm() {
        let p = params(4, 1);
        let docs = sample_docs(131072, 4 * 131072, 4);
        let dp = run_packed_dp(&docs, 131072, &p);
        let cp = run_perdoc_cp(&docs, 131072, 4, &p);
        assert!(cp.idle_fraction() < dp.idle_fraction());
        assert!(cp.comm_bytes > 0.0 && dp.comm_bytes == 0.0);
    }

    #[test]
    fn wlb_sweep_nonempty_and_best_not_oom_when_possible() {
        let p = params(4, 1);
        let docs = sample_docs(65536, 4 * 65536, 5);
        let sweep = wlb_sweep(&docs, 65536, &p);
        assert!(sweep.len() >= 2);
        let best = run_wlb_ideal(&docs, 65536, &p);
        if sweep.iter().any(|r| !r.oom) {
            assert!(!best.oom);
        }
    }

    #[test]
    fn distca_pp_runs_and_balances() {
        let p = params(4, 2);
        let docs = sample_docs(65536, 8 * 65536, 6);
        let r = run_distca(&docs, 65536, &p);
        assert!(r.iter_time > 0.0);
        assert!(!r.device_busy.iter().any(|&b| b < 0.0));
        // busy must not exceed iteration time
        for &b in &r.device_busy {
            assert!(b <= r.iter_time * 1.0001, "busy {b} > iter {}", r.iter_time);
        }
    }

    #[test]
    fn packed_dp_pp_has_bubbles() {
        let p = params(4, 2);
        let docs = sample_docs(65536, 8 * 65536, 7);
        let r = run_packed_dp(&docs, 65536, &p);
        assert!(r.idle_fraction() > 0.0, "PP must create bubbles");
    }

    #[test]
    fn signal_mode_is_fastest_singlestream_slowest() {
        let docs = sample_docs(131072, 4 * 131072, 8);
        let mk = |mode| {
            let mut p = params(4, 1);
            p.comm_mode = mode;
            run_distca(&docs, 131072, &p).iter_time
        };
        let sig = mk(CommMode::Signal);
        let pp = mk(CommMode::PingPong);
        let ss = mk(CommMode::SingleStream);
        assert!(sig <= pp + 1e-12, "signal {sig} > pingpong {pp}");
        assert!(pp <= ss + 1e-12, "pingpong {pp} > singlestream {ss}");
    }

    #[test]
    fn distca_idle_near_zero() {
        // Near-perfect compute balance (§6 headline).
        let p = params(8, 1);
        let docs = sample_docs(131072, 8 * 131072, 9);
        let r = run_distca(&docs, 131072, &p);
        assert!(
            r.idle_fraction() < 0.20,
            "DistCA idle {} should be small",
            r.idle_fraction()
        );
    }
}
