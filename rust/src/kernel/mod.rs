//! Fast-path GQA core attention: blocked streaming softmax,
//! thread-parallel across `(task, head)` pairs, AVX2/FMA inner loops
//! behind runtime feature detection — **bit-exact vs the oracle**.
//!
//! The repo's correctness story is "every execution path reproduces
//! [`ReferenceCaCompute`] byte-for-byte", so a fast kernel is only
//! admissible if it reproduces the oracle's bytes exactly. All three
//! implementations (oracle, scalar fast path, AVX2 fast path) therefore
//! execute the *pinned reduction order* documented in [`flash`] and in
//! `docs/ARCHITECTURE.md`: the same chunked streaming-softmax op
//! sequence built exclusively from correctly-rounded IEEE-754
//! operations (FMA everywhere, one shared [`math::pexp`] exponential),
//! which makes bit-equality a property of the *contract*, not of any
//! particular instruction selection. `tests/prop_kernel.rs` and the
//! `fastkernel` conformance column hold all backends to it.
//!
//! Backend selection is environmental, so any run of any binary can be
//! pinned for debugging or differential testing:
//!
//! | `DISTCA_KERNEL` | compute                                          |
//! |-----------------|--------------------------------------------------|
//! | unset / `fast`  | [`FastCaCompute`], AVX2 if detected else scalar  |
//! | `avx2`          | [`FastCaCompute`], AVX2 (panics if undetected)   |
//! | `scalar`        | [`FastCaCompute`], scalar fallback               |
//! | `oracle`        | [`ReferenceCaCompute`] (single-thread reference) |
//!
//! Thread count comes from `DISTCA_KERNEL_THREADS` (0/unset = all
//! available cores); small batches run inline regardless, so the tiny
//! CA-tasks of the conformance suites never pay thread-spawn overhead
//! under the already-threaded elastic coordinator.

pub mod flash;
pub mod math;

use anyhow::Result;

use crate::elastic::failover::{CaCompute, CaTaskView, ReferenceCaCompute};
use crate::runtime::ca_exec::CaTaskTensors;

pub use flash::{dot_pinned_scalar, KV_CHUNK};
pub use math::{pexp, PEXP_OVERFLOW, PEXP_UNDERFLOW};

/// Below this estimated FLOP count a batch runs inline on the calling
/// thread: conformance-sized tasks (tens of rows, d ≤ 16) are far
/// cheaper than a thread spawn, and the elastic runtime already runs
/// one server per thread.
const PAR_MIN_FLOPS: f64 = 4.0e6;

/// Is the AVX2/FMA backend usable on this machine?
pub fn avx2_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Which backend a [`FastCaCompute`] executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelBackend {
    /// Portable scalar rendering of the pinned reduction order.
    Scalar,
    /// AVX2/FMA rendering; requires [`avx2_available`].
    Avx2,
}

/// The `DISTCA_KERNEL` selection, including the oracle escape hatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelChoice {
    Oracle,
    Scalar,
    Avx2,
    /// AVX2 when detected, scalar otherwise (the default).
    Fast,
}

/// Parse `DISTCA_KERNEL` (unset = `fast`). Panics on an unknown value —
/// a silently ignored kernel override would defeat the differential
/// testing the variable exists for.
pub fn choice_from_env() -> KernelChoice {
    match std::env::var("DISTCA_KERNEL") {
        Err(_) => KernelChoice::Fast,
        Ok(s) => match s.trim() {
            "" | "fast" => KernelChoice::Fast,
            "oracle" => KernelChoice::Oracle,
            "scalar" => KernelChoice::Scalar,
            "avx2" => KernelChoice::Avx2,
            other => panic!("DISTCA_KERNEL must be fast|oracle|scalar|avx2, got `{other}`"),
        },
    }
}

/// Build the compute plug `DISTCA_KERNEL` asks for. This is the single
/// factory every runtime wire-in point uses (`distca worker`, the
/// threaded elastic coordinator, the gateway's in-process backend), so
/// one environment variable switches them all.
pub fn compute_from_env(n_heads: usize, n_kv_heads: usize, head_dim: usize) -> Box<dyn CaCompute> {
    match choice_from_env() {
        KernelChoice::Oracle => Box::new(ReferenceCaCompute::new(n_heads, n_kv_heads, head_dim)),
        KernelChoice::Scalar => Box::new(
            FastCaCompute::new(n_heads, n_kv_heads, head_dim).backend(KernelBackend::Scalar),
        ),
        KernelChoice::Avx2 => {
            assert!(avx2_available(), "DISTCA_KERNEL=avx2 but this CPU lacks AVX2/FMA");
            Box::new(FastCaCompute::new(n_heads, n_kv_heads, head_dim).backend(KernelBackend::Avx2))
        }
        KernelChoice::Fast => Box::new(FastCaCompute::new(n_heads, n_kv_heads, head_dim)),
    }
}

/// Short label of the backend [`compute_from_env`] would build — for
/// run banners and bench tables.
pub fn kernel_label() -> &'static str {
    match choice_from_env() {
        KernelChoice::Oracle => "oracle",
        KernelChoice::Scalar => "scalar",
        KernelChoice::Avx2 => "avx2",
        KernelChoice::Fast => {
            if avx2_available() {
                "avx2"
            } else {
                "scalar"
            }
        }
    }
}

fn threads_from_env() -> usize {
    let n = match std::env::var("DISTCA_KERNEL_THREADS") {
        Err(_) => 0,
        Ok(s) => s
            .trim()
            .parse::<usize>()
            .unwrap_or_else(|_| panic!("DISTCA_KERNEL_THREADS must be a usize, got `{s}`")),
    };
    if n > 0 {
        n
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    }
}

/// Raw output base pointer smuggled across the scoped-thread boundary.
/// Safety rests on the work partition: every `(task, head)` item owns a
/// disjoint set of output rows, so concurrent writers never overlap.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// The fast GQA attention compute plug: pinned-order streaming softmax
/// ([`flash`]), thread-parallel over the `(task, head)` pairs of a
/// fused batch, AVX2 when the host has it. Bit-exact vs
/// [`ReferenceCaCompute`] on every input, including NaN/±inf payloads.
#[derive(Debug, Clone)]
pub struct FastCaCompute {
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    backend: KernelBackend,
    threads: usize,
}

impl FastCaCompute {
    /// Auto backend (AVX2 when detected), `DISTCA_KERNEL_THREADS`
    /// threads (default: all cores).
    pub fn new(n_heads: usize, n_kv_heads: usize, head_dim: usize) -> FastCaCompute {
        assert!(n_heads % n_kv_heads == 0, "heads {n_heads} not grouped by {n_kv_heads}");
        FastCaCompute {
            n_heads,
            n_kv_heads,
            head_dim,
            backend: if avx2_available() { KernelBackend::Avx2 } else { KernelBackend::Scalar },
            threads: threads_from_env(),
        }
    }

    /// Pin the backend (panics if AVX2 is requested but unavailable).
    pub fn backend(mut self, b: KernelBackend) -> FastCaCompute {
        if b == KernelBackend::Avx2 {
            assert!(avx2_available(), "AVX2 backend requested but this CPU lacks AVX2/FMA");
        }
        self.backend = b;
        self
    }

    /// Pin the thread count (1 = always inline).
    pub fn threads(mut self, n: usize) -> FastCaCompute {
        assert!(n > 0, "thread count must be positive");
        self.threads = n;
        self
    }

    pub fn backend_kind(&self) -> KernelBackend {
        self.backend
    }

    fn validate(&self, t: &CaTaskView<'_>) -> Result<()> {
        let (h, hkv, d) = (self.n_heads, self.n_kv_heads, self.head_dim);
        anyhow::ensure!(t.q_len > 0 && t.q_len <= t.kv_len, "bad task lengths");
        anyhow::ensure!(t.q.len() == t.q_len * h * d, "q shape");
        anyhow::ensure!(t.k.len() == t.kv_len * hkv * d, "k shape");
        anyhow::ensure!(t.v.len() == t.kv_len * hkv * d, "v shape");
        Ok(())
    }

    /// One `(task, head)` item through the selected backend.
    ///
    /// # Safety
    /// `out` must be valid for the task's `q_len * h * d` f32 writes and
    /// no other thread may write this `(task, head)`'s rows.
    unsafe fn run_item(&self, t: &CaTaskView<'_>, head: usize, out: *mut f32, acc: &mut [f64]) {
        let (h, hkv, d) = (self.n_heads, self.n_kv_heads, self.head_dim);
        match self.backend {
            #[cfg(target_arch = "x86_64")]
            KernelBackend::Avx2 => {
                flash::attn_head_avx2(t.q, t.k, t.v, t.q_len, t.kv_len, h, hkv, d, head, out, acc)
            }
            #[cfg(not(target_arch = "x86_64"))]
            KernelBackend::Avx2 => unreachable!("AVX2 backend on non-x86_64"),
            KernelBackend::Scalar => {
                flash::attn_head_scalar(t.q, t.k, t.v, t.q_len, t.kv_len, h, hkv, d, head, out, acc)
            }
        }
    }

    /// Execute a fused batch of borrowed task views into preallocated
    /// outputs (one `[q_len, h, d]` vec per task).
    fn run_views_into(&self, tasks: &[CaTaskView<'_>], outs: &mut [Vec<f32>]) {
        debug_assert_eq!(tasks.len(), outs.len());
        let h = self.n_heads;
        let d = self.head_dim;
        let bases: Vec<SendPtr> = outs.iter_mut().map(|o| SendPtr(o.as_mut_ptr())).collect();
        let n_items = tasks.len() * h;
        let est_flops: f64 = tasks
            .iter()
            .map(|t| 2.0 * (t.q_len * t.kv_len * h * d) as f64)
            .sum();
        let n_threads = self.threads.min(n_items);
        if n_threads <= 1 || est_flops < PAR_MIN_FLOPS {
            let mut acc = vec![0.0f64; d];
            for item in 0..n_items {
                let (ti, head) = (item / h, item % h);
                // SAFETY: single thread, outs[ti] holds q_len*h*d f32s
                // (allocated by the callers below, shape-checked).
                unsafe { self.run_item(&tasks[ti], head, bases[ti].0, &mut acc) };
            }
            return;
        }
        let next = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..n_threads {
                scope.spawn(|| {
                    let mut acc = vec![0.0f64; d];
                    loop {
                        let item = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if item >= n_items {
                            break;
                        }
                        let (ti, head) = (item / h, item % h);
                        // SAFETY: the counter hands each (task, head) to
                        // exactly one worker, and distinct items write
                        // disjoint output rows.
                        unsafe { self.run_item(&tasks[ti], head, bases[ti].0, &mut acc) };
                    }
                });
            }
        });
    }

    /// Monolithic fused-batch entry (bench + conformance convenience):
    /// the batch-level twin of [`ReferenceCaCompute::run_batch`].
    pub fn run_batch(&self, tasks: &[CaTaskTensors]) -> Result<Vec<Vec<f32>>> {
        let views: Vec<CaTaskView<'_>> = tasks.iter().map(CaTaskView::from_tensors).collect();
        for v in &views {
            self.validate(v)?;
        }
        let mut outs: Vec<Vec<f32>> = tasks
            .iter()
            .map(|t| vec![0.0f32; t.q_len * self.n_heads * self.head_dim])
            .collect();
        self.run_views_into(&views, &mut outs);
        Ok(outs)
    }
}

impl CaCompute for FastCaCompute {
    fn run(&mut self, task: &CaTaskTensors) -> Result<Vec<f32>> {
        CaCompute::run_view(self, &CaTaskView::from_tensors(task))
    }

    /// Zero-copy entry: computes straight from the borrowed payload
    /// slices a pooled recv buffer exposes — no Q/K/V copies.
    fn run_view(&mut self, task: &CaTaskView<'_>) -> Result<Vec<f32>> {
        self.validate(task)?;
        let mut outs = vec![vec![0.0f32; task.q_len * self.n_heads * self.head_dim]];
        self.run_views_into(std::slice::from_ref(task), &mut outs);
        Ok(outs.pop().expect("one output"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ca_exec::synthetic_task;
    use crate::util::rng::Rng;

    #[test]
    fn fast_scalar_matches_oracle_bitwise() {
        let (h, hkv, d) = (4usize, 2usize, 16usize);
        let oracle = ReferenceCaCompute::new(h, hkv, d);
        let fast = FastCaCompute::new(h, hkv, d).backend(KernelBackend::Scalar).threads(1);
        let mut rng = Rng::new(21);
        for (q_len, kv_len) in [(1, 1), (3, 9), (16, 16), (65, 130)] {
            let t = synthetic_task(&mut rng, q_len, kv_len, h, hkv, d);
            let want = oracle.run_batch(std::slice::from_ref(&t));
            let got = fast.run_batch(std::slice::from_ref(&t)).unwrap();
            assert_eq!(want.len(), got.len());
            for (a, b) in want[0].iter().zip(&got[0]) {
                assert_eq!(a.to_bits(), b.to_bits(), "q{q_len}/kv{kv_len}");
            }
        }
    }

    #[test]
    fn threaded_equals_inline_bitwise() {
        let (h, hkv, d) = (4usize, 2usize, 16usize);
        let mut rng = Rng::new(22);
        // Big enough to clear PAR_MIN_FLOPS so threads actually engage.
        let tasks: Vec<_> =
            (0..6).map(|_| synthetic_task(&mut rng, 64, 128, h, hkv, d)).collect();
        let one = FastCaCompute::new(h, hkv, d).threads(1).run_batch(&tasks).unwrap();
        let four = FastCaCompute::new(h, hkv, d).threads(4).run_batch(&tasks).unwrap();
        assert_eq!(one, four, "thread count must not change a single byte");
    }

    #[test]
    fn rejects_malformed_shapes() {
        let fast = FastCaCompute::new(2, 1, 8);
        let mut rng = Rng::new(23);
        let mut t = synthetic_task(&mut rng, 4, 8, 2, 1, 8);
        t.q.pop();
        assert!(fast.run_batch(std::slice::from_ref(&t)).is_err());
    }
}
