//! The pinned exponential: one `exp` definition shared by every
//! attention backend.
//!
//! Bit-exactness across the oracle, the scalar fast path, and the AVX2
//! fast path hinges on every backend evaluating *the same IEEE-754
//! operation sequence*. `libm`'s `exp` is out: its result can differ
//! between libm versions, and there is no 4-wide form guaranteed to
//! match it lane-for-lane. So the repo pins its own: [`pexp`] (scalar)
//! and [`pexp4`] (AVX2, 4 lanes) evaluate the identical chain of
//! correctly-rounded ops — FMA range reduction against a hi/lo split of
//! `ln 2`, a degree-13 Taylor polynomial in Horner form (all FMA), and
//! a `2^n` scale built directly from the rounding-shift bit trick — so
//! `pexp4(x)[l] == pexp(x[l])` for **every** input bit pattern,
//! including NaN, ±inf, and the clamp boundaries.
//!
//! Accuracy is ~1 ulp over the clamped range, but accuracy is not the
//! contract — *identity between backends* is. `prop_kernel.rs` holds
//! the backends to it differentially.

/// Inputs above this return `+inf`. Chosen (rather than `ln(f64::MAX)`)
/// so the rounded exponent `n` stays ≤ 1023 and `2^n` is a normal f64.
pub const PEXP_OVERFLOW: f64 = 709.0;
/// Inputs below this (including `-inf`) return `+0.0`. Chosen so the
/// scale `2^n` stays normal (`exp(-708) ≈ 3.3e-308 > f64::MIN_POSITIVE`);
/// true results between `2^-1022` and `exp(-708)` are flushed to zero,
/// which softmax never notices (the max score always maps to `exp(0)`).
pub const PEXP_UNDERFLOW: f64 = -708.0;

const LOG2_E: f64 = std::f64::consts::LOG2_E;
/// `1.5 * 2^52`: adding it forces round-to-nearest-integer in the
/// low mantissa bits ("magic rounding shift").
const SHIFT: f64 = 6_755_399_441_055_744.0;
/// Bit pattern of [`SHIFT`]; `to_bits(SHIFT + n) - SHIFT_BITS == n`
/// (two's complement) for `|n| < 2^51`.
const SHIFT_BITS: u64 = 0x4338_0000_0000_0000;
/// `ln 2` split hi/lo (Cody–Waite): `LN2_HI + LN2_LO == ln 2` to
/// ~106 bits, and with FMA each reduction step is a single rounding.
const LN2_HI: f64 = 0.693_147_180_559_945_3;
const LN2_LO: f64 = 2.319_046_813_846_299_6e-17;

/// Taylor coefficients `1/13!, 1/12!, …, 1/1!, 1/0!` for Horner
/// evaluation (highest degree first). Written as literals so the scalar
/// and AVX2 paths load bit-identical constants.
pub(crate) const POLY: [f64; 14] = [
    1.605_904_383_682_161_3e-10, // 1/13!
    2.087_675_698_786_81e-9,     // 1/12!
    2.505_210_838_544_172e-8,    // 1/11!
    2.755_731_922_398_589e-7,    // 1/10!
    2.755_731_922_398_589_3e-6,  // 1/9!
    2.480_158_730_158_73e-5,     // 1/8!
    1.984_126_984_126_984e-4,    // 1/7!
    1.388_888_888_888_888_9e-3,  // 1/6!
    8.333_333_333_333_333e-3,    // 1/5!
    4.166_666_666_666_666_4e-2,  // 1/4!
    1.666_666_666_666_666_6e-1,  // 1/3!
    0.5,                         // 1/2!
    1.0,                         // 1/1!
    1.0,                         // 1/0!
];

/// Pinned `exp(x)`: the reduction-order contract's exponential.
///
/// Special cases (the AVX2 twin blends the same three masks):
/// NaN → canonical NaN, `x > PEXP_OVERFLOW` → `+inf`,
/// `x < PEXP_UNDERFLOW` (including `-inf`) → `0.0`.
#[inline]
pub fn pexp(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    if x > PEXP_OVERFLOW {
        return f64::INFINITY;
    }
    if x < PEXP_UNDERFLOW {
        return 0.0;
    }
    // n = round(x / ln 2) via the magic shift; `t - SHIFT` recovers n
    // exactly as an f64, and the low bits of `t` hold n as an integer.
    let t = x.mul_add(LOG2_E, SHIFT);
    let n = t - SHIFT;
    // r = x - n*ln2, Cody-Waite two-step; r ∈ ~[-0.347, 0.347].
    let r = n.mul_add(-LN2_HI, x);
    let r = n.mul_add(-LN2_LO, r);
    // exp(r) by Horner, all FMA.
    let mut p = POLY[0];
    for &c in &POLY[1..] {
        p = p.mul_add(r, c);
    }
    // 2^n assembled from n's integer bits; n ∈ [-1021, 1023] here, so
    // the biased exponent is a normal f64.
    let n_i = t.to_bits().wrapping_sub(SHIFT_BITS) as i64;
    let scale = f64::from_bits(((n_i + 1023) as u64) << 52);
    p * scale
}

/// AVX2 twin of [`pexp`]: per-lane identical results for every input.
///
/// # Safety
/// Caller must have verified `avx2` and `fma` via
/// `is_x86_feature_detected!`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
pub unsafe fn pexp4(x: core::arch::x86_64::__m256d) -> core::arch::x86_64::__m256d {
    use core::arch::x86_64::*;
    // Masks first: the main path runs unconditionally on all lanes and
    // produces garbage where a mask is set; the blends discard it.
    let nan = _mm256_cmp_pd::<_CMP_UNORD_Q>(x, x);
    let over = _mm256_cmp_pd::<_CMP_GT_OQ>(x, _mm256_set1_pd(PEXP_OVERFLOW));
    let under = _mm256_cmp_pd::<_CMP_LT_OQ>(x, _mm256_set1_pd(PEXP_UNDERFLOW));

    let shift = _mm256_set1_pd(SHIFT);
    let t = _mm256_fmadd_pd(x, _mm256_set1_pd(LOG2_E), shift);
    let n = _mm256_sub_pd(t, shift);
    let r = _mm256_fmadd_pd(n, _mm256_set1_pd(-LN2_HI), x);
    let r = _mm256_fmadd_pd(n, _mm256_set1_pd(-LN2_LO), r);
    let mut p = _mm256_set1_pd(POLY[0]);
    for &c in &POLY[1..] {
        p = _mm256_fmadd_pd(p, r, _mm256_set1_pd(c));
    }
    // 2^n from t's integer bits: bits(t) - bits(SHIFT) = n, then bias
    // and shift into the exponent field — same trick as the scalar path.
    let n_i = _mm256_sub_epi64(_mm256_castpd_si256(t), _mm256_set1_epi64x(SHIFT_BITS as i64));
    let scale = _mm256_castsi256_pd(_mm256_slli_epi64::<52>(_mm256_add_epi64(
        n_i,
        _mm256_set1_epi64x(1023),
    )));
    let y = _mm256_mul_pd(p, scale);

    let y = _mm256_blendv_pd(y, _mm256_set1_pd(f64::INFINITY), over);
    let y = _mm256_blendv_pd(y, _mm256_setzero_pd(), under);
    _mm256_blendv_pd(y, _mm256_set1_pd(f64::NAN), nan)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pexp_specials_are_pinned() {
        assert!(pexp(f64::NAN).is_nan());
        assert_eq!(pexp(f64::NAN).to_bits(), f64::NAN.to_bits(), "canonical NaN");
        assert_eq!(pexp(f64::INFINITY), f64::INFINITY);
        assert_eq!(pexp(f64::NEG_INFINITY), 0.0);
        assert_eq!(pexp(PEXP_OVERFLOW + 1.0), f64::INFINITY);
        assert_eq!(pexp(PEXP_UNDERFLOW - 1.0), 0.0);
        assert_eq!(pexp(0.0), 1.0, "exp(0) must be exactly 1 for softmax");
        assert_eq!(pexp(-0.0), 1.0);
    }

    #[test]
    fn pexp_tracks_libm_closely() {
        // Accuracy is not the contract, but a gross error would still be
        // a bug: stay within a few ulps of libm over the softmax range.
        let mut x = -40.0f64;
        while x < 40.0 {
            let want = x.exp();
            let got = pexp(x);
            let rel = ((got - want) / want).abs();
            assert!(rel < 1e-14, "pexp({x}) = {got:e}, libm {want:e}, rel {rel:e}");
            x += 0.003_7;
        }
    }

    #[test]
    fn pexp_boundaries_stay_finite_normal() {
        assert!(pexp(PEXP_OVERFLOW).is_finite());
        assert!(pexp(PEXP_UNDERFLOW) > 0.0);
        assert!(pexp(PEXP_UNDERFLOW).is_normal());
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn pexp4_matches_pexp_lane_for_lane() {
        if !(is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")) {
            eprintln!("skipping: no AVX2/FMA on this host");
            return;
        }
        use core::arch::x86_64::*;
        let mut rng = crate::util::rng::Rng::new(0xE9);
        let mut cases: Vec<f64> = vec![
            f64::NAN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            0.0,
            -0.0,
            PEXP_OVERFLOW,
            PEXP_UNDERFLOW,
            709.1,
            -708.1,
            f64::MIN_POSITIVE / 2.0, // subnormal
            f64::from_bits(0x7FF0_0000_0000_0001), // signaling-ish NaN payload
        ];
        for _ in 0..4000 {
            cases.push(rng.gen_f64(-760.0, 760.0));
            cases.push(rng.gen_f64(-2.0, 2.0));
        }
        while cases.len() % 4 != 0 {
            cases.push(0.0);
        }
        for quad in cases.chunks_exact(4) {
            let got = unsafe {
                let v = pexp4(_mm256_loadu_pd(quad.as_ptr()));
                let mut out = [0.0f64; 4];
                _mm256_storeu_pd(out.as_mut_ptr(), v);
                out
            };
            for l in 0..4 {
                let want = pexp(quad[l]);
                assert_eq!(
                    got[l].to_bits(),
                    want.to_bits(),
                    "lane {l} of {quad:?}: {:e} vs {want:e}",
                    got[l]
                );
            }
        }
    }
}
