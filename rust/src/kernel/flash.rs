//! The blocked streaming-softmax attention backends (scalar + AVX2).
//!
//! Both backends execute the **pinned reduction order** — the exact
//! IEEE-754 op sequence the oracle in `elastic::failover` also follows
//! (see `docs/ARCHITECTURE.md`, "The fast-path GQA kernel"), per
//! `(query row i, head)`:
//!
//! 1. the causal KV span `0..=kv_len-q_len+i` is walked in chunks of
//!    [`KV_CHUNK`] keys;
//! 2. scores are pinned 4-lane FMA dot products ([`dot_pinned_scalar`]):
//!    lane `l` accumulates elements `x ≡ l (mod 4)`, the horizontal
//!    combine is `(a0+a2) + (a1+a3)`, the `d % 4` tail is scalar FMA;
//! 3. the running max uses `if s > m` selection (NaN never wins —
//!    `_mm256_max_pd` semantics) and the rescale factor
//!    `α = pexp(m_old - m_new)` is **always** evaluated, even when the
//!    max did not move (`pexp(0) == 1` exactly);
//! 4. `p_j = pexp(s_j - m_new)` is element-wise (lane-pure, so the
//!    4-wide [`pexp4`][super::math::pexp4] form is bit-identical),
//!    the chunk sum is a sequential scalar add chain in `j` order, and
//!    `denom = fma(α, denom, chunk_sum)`;
//! 5. the accumulator rescale is an element-wise multiply and the V
//!    accumulation is `acc[x] = fma(p_j, v[j][x], acc[x])` with `j`
//!    outer-sequential (the order-dependent chain) and `x` inner
//!    (element-wise, vectorizable);
//! 6. `out[x] = (acc[x] / denom) as f32` — division and the f64→f32
//!    cast are correctly rounded in both scalar and packed forms.
//!
//! Every op in the sequence is either correctly rounded (FMA, add, mul,
//! div, casts, `pexp`) or an order-pinned selection, so any backend
//! that replays the sequence reproduces the oracle's output bytes
//! exactly. `tests/prop_kernel.rs` enforces it differentially.

use super::math::pexp;

/// Keys per streaming chunk. 64 keys × `d` floats keeps one chunk of K
/// (and of V) inside L1/L2 for realistic head dims while the score
/// scratch stays a fixed 512-byte stack array.
pub const KV_CHUNK: usize = 64;

/// Pinned 4-lane dot product of two `d`-length f32 rows, accumulated in
/// f64. This is the scalar rendering of the AVX2 sequence: four
/// independent FMA accumulator lanes over aligned quads, the pinned
/// horizontal combine, then a scalar FMA tail for `d % 4`.
#[inline]
pub fn dot_pinned_scalar(q: &[f32], k: &[f32]) -> f64 {
    debug_assert_eq!(q.len(), k.len());
    let d = q.len();
    let quads = d / 4 * 4;
    let mut a = [0.0f64; 4];
    let mut x = 0;
    while x < quads {
        a[0] = (q[x] as f64).mul_add(k[x] as f64, a[0]);
        a[1] = (q[x + 1] as f64).mul_add(k[x + 1] as f64, a[1]);
        a[2] = (q[x + 2] as f64).mul_add(k[x + 2] as f64, a[2]);
        a[3] = (q[x + 3] as f64).mul_add(k[x + 3] as f64, a[3]);
        x += 4;
    }
    let mut s = (a[0] + a[2]) + (a[1] + a[3]);
    while x < d {
        s = (q[x] as f64).mul_add(k[x] as f64, s);
        x += 1;
    }
    s
}

/// One `(task, head)` of causal GQA attention, scalar backend.
///
/// Writes rows `(i, head)` of the task's `[q_len, h, d]` output through
/// `out`. `acc` is caller-provided scratch of exactly `d` f64s.
///
/// # Safety
/// `out` must be valid for `q_len * h * d` f32 writes, and no other
/// thread may concurrently write the `(i, head)` rows this call owns
/// (disjoint heads of the same task are fine — that is the threading
/// contract of [`FastCaCompute`][super::FastCaCompute]).
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn attn_head_scalar(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    q_len: usize,
    kv_len: usize,
    h: usize,
    hkv: usize,
    d: usize,
    head: usize,
    out: *mut f32,
    acc: &mut [f64],
) {
    debug_assert_eq!(acc.len(), d);
    let group = h / hkv;
    let kvh = head / group;
    let scale = 1.0 / (d as f64).sqrt();
    let offset = kv_len - q_len;
    let mut scores = [0.0f64; KV_CHUNK];
    for i in 0..q_len {
        let causal = offset + i; // this row attends keys 0..=causal
        let q_base = (i * h + head) * d;
        let q_row = &q[q_base..q_base + d];
        let mut m = f64::NEG_INFINITY;
        let mut denom = 0.0f64;
        for a in acc.iter_mut() {
            *a = 0.0;
        }
        let mut start = 0usize;
        while start <= causal {
            let n = (causal + 1 - start).min(KV_CHUNK);
            // (2) chunk scores + chunk max.
            let mut m_c = f64::NEG_INFINITY;
            for jj in 0..n {
                let k_base = ((start + jj) * hkv + kvh) * d;
                let s = dot_pinned_scalar(q_row, &k[k_base..k_base + d]) * scale;
                scores[jj] = s;
                if s > m_c {
                    m_c = s;
                }
            }
            // (3) running max + unconditional rescale factor.
            let m_new = if m_c > m { m_c } else { m };
            let alpha = pexp(m - m_new);
            for a in acc.iter_mut() {
                *a = alpha * *a;
            }
            // (4) probabilities, sequential chunk sum, denominator.
            for s in scores.iter_mut().take(n) {
                *s = pexp(*s - m_new);
            }
            let mut csum = 0.0f64;
            for &p in scores.iter().take(n) {
                csum += p;
            }
            denom = alpha.mul_add(denom, csum);
            // (5) V accumulation: j outer (the pinned chain), x inner.
            for jj in 0..n {
                let p = scores[jj];
                let v_base = ((start + jj) * hkv + kvh) * d;
                for (x, a) in acc.iter_mut().enumerate() {
                    *a = p.mul_add(v[v_base + x] as f64, *a);
                }
            }
            m = m_new;
            start += n;
        }
        // (6) finalize.
        for (x, &a) in acc.iter().enumerate() {
            *out.add(q_base + x) = (a / denom) as f32;
        }
    }
}

/// Pinned 4-lane dot product, AVX2/FMA rendering — bit-identical to
/// [`dot_pinned_scalar`] by construction (same lanes, same combine,
/// same scalar-FMA tail).
///
/// # Safety
/// Caller verified `avx2`+`fma`; `q` and `k` are valid for `d` reads.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_pinned_avx2(q: *const f32, k: *const f32, d: usize) -> f64 {
    use core::arch::x86_64::*;
    let quads = d / 4 * 4;
    let mut acc = _mm256_setzero_pd();
    let mut x = 0;
    while x < quads {
        let qv = _mm256_cvtps_pd(_mm_loadu_ps(q.add(x)));
        let kv = _mm256_cvtps_pd(_mm_loadu_ps(k.add(x)));
        acc = _mm256_fmadd_pd(qv, kv, acc);
        x += 4;
    }
    // Horizontal combine pinned as (a0+a2) + (a1+a3).
    let lo = _mm256_castpd256_pd128(acc); // [a0, a1]
    let hi = _mm256_extractf128_pd::<1>(acc); // [a2, a3]
    let pair = _mm_add_pd(lo, hi); // [a0+a2, a1+a3]
    let swap = _mm_unpackhi_pd(pair, pair);
    let mut s = _mm_cvtsd_f64(_mm_add_sd(pair, swap));
    while x < d {
        s = (*q.add(x) as f64).mul_add(*k.add(x) as f64, s);
        x += 1;
    }
    s
}

/// One `(task, head)`, AVX2/FMA backend — the same pinned sequence as
/// [`attn_head_scalar`], vector ops only where they are element-wise or
/// lane-pure (dot lanes, `pexp4`, rescale, V quads); every
/// order-dependent chain (running max, chunk sum, denominator, the `j`
/// accumulation order) stays scalar-sequential.
///
/// # Safety
/// As [`attn_head_scalar`], plus the caller must have verified
/// `avx2`+`fma` via `is_x86_feature_detected!`.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2", enable = "fma")]
pub(crate) unsafe fn attn_head_avx2(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    q_len: usize,
    kv_len: usize,
    h: usize,
    hkv: usize,
    d: usize,
    head: usize,
    out: *mut f32,
    acc: &mut [f64],
) {
    use core::arch::x86_64::*;
    use super::math::pexp4;
    debug_assert_eq!(acc.len(), d);
    let group = h / hkv;
    let kvh = head / group;
    let scale = 1.0 / (d as f64).sqrt();
    let offset = kv_len - q_len;
    let quads = d / 4 * 4;
    let mut scores = [0.0f64; KV_CHUNK];
    for i in 0..q_len {
        let causal = offset + i;
        let q_base = (i * h + head) * d;
        let q_ptr = q.as_ptr().add(q_base);
        let mut m = f64::NEG_INFINITY;
        let mut denom = 0.0f64;
        for a in acc.iter_mut() {
            *a = 0.0;
        }
        let mut start = 0usize;
        while start <= causal {
            let n = (causal + 1 - start).min(KV_CHUNK);
            let mut m_c = f64::NEG_INFINITY;
            for jj in 0..n {
                let k_base = ((start + jj) * hkv + kvh) * d;
                let s = dot_pinned_avx2(q_ptr, k.as_ptr().add(k_base), d) * scale;
                scores[jj] = s;
                if s > m_c {
                    m_c = s;
                }
            }
            let m_new = if m_c > m { m_c } else { m };
            let alpha = pexp(m - m_new);
            let al = _mm256_set1_pd(alpha);
            let mut x = 0;
            while x < quads {
                let av = _mm256_loadu_pd(acc.as_ptr().add(x));
                _mm256_storeu_pd(acc.as_mut_ptr().add(x), _mm256_mul_pd(al, av));
                x += 4;
            }
            while x < d {
                acc[x] = alpha * acc[x];
                x += 1;
            }
            let mv = _mm256_set1_pd(m_new);
            let mut jj = 0;
            while jj + 4 <= n {
                let sv = _mm256_loadu_pd(scores.as_ptr().add(jj));
                let pv = pexp4(_mm256_sub_pd(sv, mv));
                _mm256_storeu_pd(scores.as_mut_ptr().add(jj), pv);
                jj += 4;
            }
            while jj < n {
                scores[jj] = pexp(scores[jj] - m_new);
                jj += 1;
            }
            let mut csum = 0.0f64;
            for &p in scores.iter().take(n) {
                csum += p;
            }
            denom = alpha.mul_add(denom, csum);
            for jj in 0..n {
                let p = _mm256_set1_pd(scores[jj]);
                let v_base = ((start + jj) * hkv + kvh) * d;
                let mut x = 0;
                while x < quads {
                    let vv = _mm256_cvtps_pd(_mm_loadu_ps(v.as_ptr().add(v_base + x)));
                    let av = _mm256_loadu_pd(acc.as_ptr().add(x));
                    _mm256_storeu_pd(acc.as_mut_ptr().add(x), _mm256_fmadd_pd(p, vv, av));
                    x += 4;
                }
                while x < d {
                    acc[x] = scores[jj].mul_add(v[v_base + x] as f64, acc[x]);
                    x += 1;
                }
            }
            m = m_new;
            start += n;
        }
        let dv = _mm256_set1_pd(denom);
        let mut x = 0;
        while x < quads {
            let av = _mm256_loadu_pd(acc.as_ptr().add(x));
            let ov = _mm256_cvtpd_ps(_mm256_div_pd(av, dv));
            _mm_storeu_ps(out.add(q_base + x), ov);
            x += 4;
        }
        while x < d {
            *out.add(q_base + x) = (acc[x] / denom) as f32;
            x += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_pinned_matches_naive_closely() {
        let mut rng = crate::util::rng::Rng::new(11);
        for d in [1usize, 3, 4, 7, 8, 16, 63, 64, 65] {
            let q: Vec<f32> = (0..d).map(|_| rng.gen_f64(-1.0, 1.0) as f32).collect();
            let k: Vec<f32> = (0..d).map(|_| rng.gen_f64(-1.0, 1.0) as f32).collect();
            let naive: f64 = q.iter().zip(&k).map(|(&a, &b)| a as f64 * b as f64).sum();
            let got = dot_pinned_scalar(&q, &k);
            assert!((got - naive).abs() < 1e-12, "d={d}: {got} vs {naive}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn dot_pinned_avx2_is_bit_exact_vs_scalar() {
        if !(is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")) {
            eprintln!("skipping: no AVX2/FMA on this host");
            return;
        }
        let mut rng = crate::util::rng::Rng::new(12);
        for d in [1usize, 2, 4, 5, 8, 15, 16, 64, 65, 127] {
            for _ in 0..50 {
                let q: Vec<f32> = (0..d).map(|_| rng.gen_f64(-3.0, 3.0) as f32).collect();
                let k: Vec<f32> = (0..d).map(|_| rng.gen_f64(-3.0, 3.0) as f32).collect();
                let want = dot_pinned_scalar(&q, &k);
                let got = unsafe { dot_pinned_avx2(q.as_ptr(), k.as_ptr(), d) };
                assert_eq!(got.to_bits(), want.to_bits(), "d={d}");
            }
        }
    }
}
