//! Leveled stderr logger (the vendor set has `log` but no backend; this is
//! a self-contained replacement with timestamps relative to process start).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Set the global verbosity; also reads `DISTCA_LOG` on first use.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from the `DISTCA_LOG` environment variable if present.
pub fn init_from_env() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("DISTCA_LOG") {
        if let Some(level) = Level::from_str(&v) {
            set_level(level);
        }
    }
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    eprintln!("[{t:10.4}s {} {module}] {args}", level.tag());
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("info"), Some(Level::Info));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
