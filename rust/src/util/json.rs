//! Minimal JSON implementation (parser + serializer).
//!
//! The offline vendor set has no `serde`/`serde_json`, and the repo needs
//! structured interchange in three places: run configs, the kernel
//! profiler grid emitted by `python/compile/aot.py`, and experiment
//! reports. This module implements RFC 8259 JSON with an ordered object
//! representation (insertion order preserved — convenient for stable
//! report diffs).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with insertion-ordered keys.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= u64::MAX as f64 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required-field lookup with a contextual error.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing field `{key}`")))
    }

    /// Array of f64 convenience accessor.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build from a BTreeMap (sorted keys).
    pub fn from_map(map: BTreeMap<String, Json>) -> Json {
        Json::Obj(map.into_iter().collect())
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    // JSON has no Inf/NaN; null is the conventional fallback.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..(width * depth) {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON document. Trailing whitespace allowed; trailing garbage is
/// an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> Result<Json, JsonError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| JsonError(format!("read {}: {e}", path.display())))?;
    parse(&text)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle UTF-16 surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            s.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                        } else {
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(c) => {
                    // Re-assemble multi-byte UTF-8: push raw bytes via a
                    // byte buffer; since input is &str it is valid UTF-8.
                    let start = self.pos - 1;
                    let width = utf8_width(c);
                    self.pos = start + width;
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.bump() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a') as u32 + 10,
                Some(c @ b'A'..=b'F') => (c - b'A') as u32 + 10,
                _ => return Err(self.err("bad \\u escape")),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("a\"b\\c\nd\te\u{1F600}".into());
        let text = original.to_string_compact();
        assert_eq!(parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(
            parse(r#""😀""#).unwrap(),
            Json::Str("\u{1F600}".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = Json::obj(vec![
            ("name", Json::Str("distca".into())),
            ("servers", Json::Num(64.0)),
            ("ratios", Json::Arr(vec![Json::Num(1.5), Json::Num(2.0)])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true))])),
        ]);
        for text in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        if let Json::Obj(fields) = &v {
            let keys: Vec<&str> = fields.iter().map(|(k, _)| k.as_str()).collect();
            assert_eq!(keys, vec!["z", "a", "m"]);
        } else {
            panic!("not an object");
        }
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(128.0).to_string_compact(), "128");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 7, "xs": [1.0, 2.0]}"#).unwrap();
        assert_eq!(v.req("n").unwrap().as_usize(), Some(7));
        assert!(v.req("missing").is_err());
        assert_eq!(v.get("xs").unwrap().as_f64_vec(), Some(vec![1.0, 2.0]));
    }
}
