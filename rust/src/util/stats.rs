//! Small statistics helpers used by the metrics, simulator, and bench
//! harness: summary statistics, percentiles, and imbalance measures that
//! mirror the quantities the paper reports (idle fraction, divergence).

/// Arithmetic mean. Returns 0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Coefficient of variation (stddev / mean); 0 when mean is 0.
pub fn cv(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        stddev(xs) / m
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Linear-interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p), "percentile out of range: {p}");
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// `max / mean` — the paper's notion of load imbalance across ranks: the
/// straggler's excess over the ideal. 1.0 means perfectly balanced.
pub fn imbalance_ratio(loads: &[f64]) -> f64 {
    let m = mean(loads);
    if m == 0.0 {
        1.0
    } else {
        max(loads) / m
    }
}

/// Fraction of aggregate device time spent idle when every rank must wait
/// for the slowest: `1 - mean/max`. This is Fig. 4b's "percentage of
/// average idle time to average iteration time".
pub fn idle_fraction(loads: &[f64]) -> f64 {
    let mx = max(loads);
    if mx <= 0.0 {
        0.0
    } else {
        1.0 - mean(loads) / mx
    }
}

/// `max / min` divergence, the memory-divergence measure of Fig. 4a.
pub fn divergence(xs: &[f64]) -> f64 {
    let mn = min(xs);
    if mn <= 0.0 {
        f64::INFINITY
    } else {
        max(xs) / mn
    }
}

/// Weighted mean.
pub fn weighted_mean(xs: &[f64], ws: &[f64]) -> f64 {
    assert_eq!(xs.len(), ws.len());
    let wsum: f64 = ws.iter().sum();
    if wsum == 0.0 {
        return 0.0;
    }
    xs.iter().zip(ws).map(|(x, w)| x * w).sum::<f64>() / wsum
}

/// Simple online accumulator for streams (simulator event timings).
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    pub n: u64,
    pub sum: f64,
    pub sum_sq: f64,
    pub min: f64,
    pub max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Self {
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        ((self.sum_sq / self.n as f64) - m * m).max(0.0).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn idle_fraction_balanced_is_zero() {
        assert_eq!(idle_fraction(&[3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn idle_fraction_straggler() {
        // loads 1,1,1,2: mean 1.25, max 2 -> idle 0.375
        assert!((idle_fraction(&[1.0, 1.0, 1.0, 2.0]) - 0.375).abs() < 1e-12);
    }

    #[test]
    fn imbalance_ratio_basics() {
        assert!((imbalance_ratio(&[1.0, 1.0]) - 1.0).abs() < 1e-12);
        assert!((imbalance_ratio(&[1.0, 3.0]) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn divergence_max_over_min() {
        assert!((divergence(&[2.0, 4.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn accumulator_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.add(x);
        }
        assert!((acc.mean() - mean(&xs)).abs() < 1e-12);
        assert!((acc.stddev() - stddev(&xs)).abs() < 1e-9);
        assert_eq!(acc.min, 1.0);
        assert_eq!(acc.max, 5.0);
    }

    #[test]
    fn weighted_mean_basic() {
        assert!((weighted_mean(&[1.0, 3.0], &[1.0, 3.0]) - 2.5).abs() < 1e-12);
    }
}
