//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so we implement the two
//! generators the repo needs: SplitMix64 (seeding / cheap streams) and
//! xoshiro256** (the workhorse). Both are well-known public-domain
//! algorithms (Blackman & Vigna). Everything downstream — document length
//! sampling, packing shuffles, property-test case generation — goes through
//! [`Rng`], so every experiment in the repo is reproducible from a `u64`
//! seed.

/// Resolve the run seed: the `DISTCA_SEED` environment variable when set
/// (benches have no CLI flags, so the env var is their `--seed`), else
/// `default`. Every bench and the fault injector derive their streams
/// from this one value, making elastic-recovery runs byte-reproducible:
/// `DISTCA_SEED=7 cargo bench ...` twice prints identical tables.
/// Panics on an unparsable value — a silently ignored seed would defeat
/// the reproducibility contract.
pub fn seed_from_env(default: u64) -> u64 {
    match std::env::var("DISTCA_SEED") {
        Err(_) => default,
        Ok(s) => s
            .trim()
            .parse::<u64>()
            .unwrap_or_else(|_| panic!("DISTCA_SEED must be a u64, got `{s}`")),
    }
}

/// SplitMix64: used to expand a single `u64` seed into the 256-bit state of
/// xoshiro256**, and as a standalone cheap generator for tests.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a 64-bit seed via SplitMix64 expansion (the
    /// initialization recommended by the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-worker determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`, 53-bit precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (unbiased via Lemire's method).
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range [{lo}, {hi})");
        let span = hi - lo;
        // Lemire's nearly-divisionless bounded generation.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)`.
    pub fn gen_index(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo as u64, hi as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn gen_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (polar form would need rejection; the
    /// trig form is branch-free and plenty fast for data generation).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with the given parameters of the underlying normal.
    pub fn gen_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.gen_normal()).exp()
    }

    /// Pareto (power-law) sample with scale `x_min` and shape `alpha`.
    /// Document lengths in pretraining corpora are famously heavy-tailed;
    /// this is the tail generator for the "Pretrain" distribution.
    pub fn gen_pareto(&mut self, x_min: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // in (0, 1]
        x_min / u.powf(1.0 / alpha)
    }

    /// Exponential with rate `lambda`.
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.next_f64();
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.is_empty() {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose: empty slice");
        &xs[self.gen_index(0, xs.len())]
    }

    /// Sample an index according to (unnormalized, non-negative) weights.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "choose_weighted: weights sum to zero");
        let mut target = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed 0 from the public-domain reference impl.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(5, 15);
            assert!((5..15).contains(&x));
            seen[(x - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range should appear");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn pareto_at_least_xmin() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.gen_pareto(100.0, 1.5) >= 100.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(19);
        let mut counts = [0usize; 3];
        for _ in 0..6000 {
            counts[r.choose_weighted(&[1.0, 2.0, 3.0])] += 1;
        }
        assert!(counts[0] < counts[1] && counts[1] < counts[2], "{counts:?}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(23);
        let mut child = parent.fork();
        let a: Vec<u64> = (0..32).map(|_| parent.next_u64()).collect();
        let b: Vec<u64> = (0..32).map(|_| child.next_u64()).collect();
        assert_ne!(a, b);
    }
}
