//! Miniature property-based testing harness (the vendor set has no
//! `proptest`/`quickcheck`). Provides seeded case generation with greedy
//! input shrinking for the scheduler/packing invariants this repo
//! property-tests.
//!
//! Usage:
//! ```ignore
//! check(100, gen_docs, |docs| prop_tokens_conserved(docs));
//! ```
//! On failure the harness re-runs the generator's shrink candidates and
//! panics with the smallest failing input's debug representation and the
//! seed needed to reproduce it.

use super::rng::Rng;

/// A generated test case must be shrinkable: return strictly "smaller"
/// candidate inputs (the harness re-tests each).
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    fn shrink(&self) -> Vec<Self>;
}

/// Integer shrink: candidates `x - x/2, x - x/4, …, x - 1` — a binary
/// search toward zero, so a threshold counterexample is found in
/// O(log x) steps instead of O(x).
fn shrink_int(x: u64) -> Vec<u64> {
    let mut out = Vec::new();
    let mut d = x / 2;
    while d > 0 {
        out.push(x - d);
        d /= 2;
    }
    if x > 0 {
        out.push(0);
        out.push(x - 1);
        out.dedup();
    }
    out
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        shrink_int(*self)
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        shrink_int(*self as u64).into_iter().map(|x| x as usize).collect()
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            vec![]
        } else {
            vec![0.0, self / 2.0]
        }
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Remove halves, remove single elements, shrink single elements.
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        for i in 0..self.len().min(8) {
            let mut v = self.clone();
            v.remove(i);
            out.push(v);
        }
        for i in 0..self.len().min(4) {
            for smaller in self[i].shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = smaller;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a single property evaluation.
pub type PropResult = Result<(), String>;

/// Convenience: assert-like helper inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run `cases` random cases of `prop` over inputs from `gen`. Panics with
/// the (shrunk) counterexample on failure. Seed comes from
/// `DISTCA_QC_SEED` if set so failures are replayable.
pub fn check<T, G, P>(cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> PropResult,
{
    let seed = std::env::var("DISTCA_QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD15C_A5EEDu64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (smallest, smallest_msg, steps) = shrink_failure(input, msg, &mut prop);
            panic!(
                "property failed (case {case}/{cases}, seed {seed}, {steps} shrink steps)\n\
                 counterexample: {smallest:?}\nreason: {smallest_msg}"
            );
        }
    }
}

fn shrink_failure<T, P>(mut input: T, mut msg: String, prop: &mut P) -> (T, String, usize)
where
    T: Shrink,
    P: FnMut(&T) -> PropResult,
{
    let mut steps = 0;
    'outer: loop {
        if steps > 1000 {
            break;
        }
        for candidate in input.shrink() {
            if let Err(m) = prop(&candidate) {
                input = candidate;
                msg = m;
                steps += 1;
                continue 'outer;
            }
        }
        break;
    }
    (input, msg, steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            50,
            |r| r.gen_range(0, 1000),
            |&x| ensure(x < 1000, "in range"),
        );
    }

    #[test]
    fn failing_property_shrinks() {
        let result = std::panic::catch_unwind(|| {
            check(
                100,
                |r| r.gen_range(0, 1000),
                |&x| ensure(x < 500, format!("{x} >= 500")),
            );
        });
        let err = result.unwrap_err();
        let text = err.downcast_ref::<String>().unwrap();
        // Shrinking should land exactly on the boundary value 500.
        assert!(text.contains("counterexample: 500"), "got: {text}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let v = vec![1u64, 2, 3, 4];
        let shrunk = v.shrink();
        assert!(shrunk.iter().any(|s| s.len() < v.len()));
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (4u64, 6u64);
        let shrunk = t.shrink();
        assert!(shrunk.iter().any(|s| s.0 < 4));
        assert!(shrunk.iter().any(|s| s.1 < 6));
    }
}
