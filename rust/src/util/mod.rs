//! Shared substrates: JSON, PRNG, statistics, logging, table rendering,
//! and a mini property-testing harness. These replace `serde`, `rand`,
//! `env_logger`, and `proptest`, none of which exist in the offline
//! vendor set — per the reproduction rule, substrates are built, not
//! stubbed.

pub mod json;
pub mod logging;
pub mod quickcheck;
pub mod rng;
pub mod stats;
pub mod tables;
