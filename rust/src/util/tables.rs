//! ASCII table rendering for benchmark and report output. Every bench
//! regenerating a paper table/figure prints through this so the rows are
//! aligned and stable (easy to diff against EXPERIMENTS.md).

/// A simple column-aligned table builder.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-able items.
    pub fn row_display<T: std::fmt::Display>(&mut self, cells: &[T]) -> &mut Self {
        let strs: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&strs)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push('|');
                }
                line.push_str(&format!(" {:<width$} ", cells[i], width = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format a float with fixed decimals (helper for bench rows).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Format bytes human-readably.
pub fn bytes(n: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Format a duration in seconds adaptively.
pub fn secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Format a large count with thousands separators.
pub fn count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("longer"));
        // all data lines equal width
        let lines: Vec<&str> = r.lines().skip(1).collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn humanize() {
        assert_eq!(bytes(1536.0), "1.50 KiB");
        assert_eq!(secs(0.0025), "2.500 ms");
        assert_eq!(count(1234567), "1,234,567");
        assert_eq!(f(1.23456, 2), "1.23");
    }
}
