//! Benchmark harness (criterion is not in the offline vendor set).
//!
//! Benches are plain binaries (`harness = false`); each builds a
//! [`BenchRunner`], registers closures, and prints a timing table plus the
//! paper-figure tables. Methodology: warm-up runs, then timed iterations
//! until both a minimum iteration count and a minimum wall-clock budget
//! are met; report mean / p50 / p95 / throughput.

pub mod harness;

pub use harness::{BenchRunner, Measurement};
