//! Timing harness used by all `rust/benches/*` binaries.

use std::time::{Duration, Instant};

use crate::util::stats;
use crate::util::tables::{secs, Table};

/// One benchmark's timing result.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
    /// Optional user-supplied work units per iteration (e.g. tokens) to
    /// derive throughput.
    pub units_per_iter: Option<f64>,
}

impl Measurement {
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / self.mean_s)
    }
}

/// Collects benchmarks and prints a summary table.
pub struct BenchRunner {
    suite: String,
    warmup: usize,
    min_iters: usize,
    min_time: Duration,
    results: Vec<Measurement>,
}

impl BenchRunner {
    pub fn new(suite: &str) -> Self {
        // Honour a quick mode so `cargo bench` finishes fast in CI; callers
        // can override via env.
        let quick = std::env::var("DISTCA_BENCH_QUICK").is_ok();
        Self {
            suite: suite.to_string(),
            warmup: if quick { 1 } else { 3 },
            min_iters: if quick { 3 } else { 10 },
            min_time: Duration::from_millis(if quick { 50 } else { 300 }),
            results: Vec::new(),
        }
    }

    pub fn with_iters(mut self, min_iters: usize) -> Self {
        self.min_iters = min_iters;
        self
    }

    /// Time `f`, which performs one full iteration of the workload and
    /// returns an observable value (preventing dead-code elimination).
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &Measurement {
        self.bench_units(name, None, &mut f)
    }

    /// Like [`bench`], with `units` work items per iteration for
    /// throughput reporting.
    pub fn bench_with_units<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        units: f64,
        mut f: F,
    ) -> &Measurement {
        self.bench_units(name, Some(units), &mut f)
    }

    fn bench_units<T>(
        &mut self,
        name: &str,
        units: Option<f64>,
        f: &mut dyn FnMut() -> T,
    ) -> &Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters || start.elapsed() < self.min_time {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() >= 10_000 {
                break; // enough precision; avoid unbounded loops on tiny fns
            }
        }
        let m = Measurement {
            name: name.to_string(),
            iters: samples.len(),
            mean_s: stats::mean(&samples),
            p50_s: stats::percentile(&samples, 50.0),
            p95_s: stats::percentile(&samples, 95.0),
            min_s: stats::min(&samples),
            units_per_iter: units,
        };
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Render the timing summary for all registered benchmarks.
    pub fn finish(&self) {
        let mut t = Table::new(
            &format!("bench suite: {}", self.suite),
            &["benchmark", "iters", "mean", "p50", "p95", "min", "throughput"],
        );
        for m in &self.results {
            t.row(&[
                m.name.clone(),
                m.iters.to_string(),
                secs(m.mean_s),
                secs(m.p50_s),
                secs(m.p95_s),
                secs(m.min_s),
                m.throughput()
                    .map(|tp| format!("{tp:.3e} units/s"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        t.print();
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("DISTCA_BENCH_QUICK", "1");
        let mut r = BenchRunner::new("test");
        let m = r
            .bench("spin", || {
                let mut acc = 0u64;
                for i in 0..10_000u64 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            })
            .clone();
        assert!(m.iters >= 3);
        assert!(m.mean_s > 0.0);
        assert!(m.p95_s >= m.p50_s);
        assert!(m.min_s <= m.mean_s);
    }

    #[test]
    fn throughput_derived_from_units() {
        std::env::set_var("DISTCA_BENCH_QUICK", "1");
        let mut r = BenchRunner::new("test");
        let m = r.bench_with_units("u", 100.0, || 1 + 1).clone();
        let tp = m.throughput().unwrap();
        assert!(tp > 0.0);
    }
}
