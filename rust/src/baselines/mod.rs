//! Baseline balancing schemes the paper compares against (§3.2, §6.1).
//!
//! The strategy *executors* live in [`crate::sim::strategies`] (they share
//! the simulator's cost primitives); this module holds the baseline
//! *algorithms* themselves plus re-exports:
//!
//! * fixed-size packing — `data::pack_fixed`;
//! * variable-length (WLB) chunking — `data::pack_variable_length`;
//! * per-document head-tail CP — `parallel::cp`;
//! * naive contiguous CP slicing ([`naive_cp_slices`]) — kept as the
//!   strawman §2.2 dismisses, used by tests/benches to demonstrate why
//!   head-tail pairing exists.

use crate::model::FlopsModel;

pub use crate::data::{pack_fixed, pack_variable_length};
pub use crate::parallel::cp::per_document_cp_shards;
pub use crate::sim::strategies::{
    run_distca, run_packed_dp, run_perdoc_cp, run_wlb_ideal, wlb_sweep,
};

/// Naive CP: slice the *concatenated chunk* (not each document) into `c`
/// contiguous equal slices. Under a causal mask early slices do less work
/// — the imbalance head-tail sharding fixes (§2.2).
/// Returns per-rank (q_len, q_offset) pairs for a chunk of `tokens`.
pub fn naive_cp_slices(tokens: usize, c: usize) -> Vec<(usize, usize)> {
    assert!(c >= 1);
    let base = tokens / c;
    let mut out = Vec::with_capacity(c);
    let mut off = 0usize;
    for r in 0..c {
        let len = if r == c - 1 { tokens - off } else { base };
        out.push((len, off));
        off += len;
    }
    out
}

/// Per-rank forward CA FLOPs under naive slicing (for the comparison).
pub fn naive_cp_flops(tokens: usize, c: usize, f: &FlopsModel) -> Vec<f64> {
    naive_cp_slices(tokens, c)
        .into_iter()
        .map(|(len, off)| f.ca_task_fwd(len, off))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::stats;

    #[test]
    fn naive_slices_cover() {
        for &(t, c) in &[(1000usize, 3usize), (4096, 4), (7, 2)] {
            let slices = naive_cp_slices(t, c);
            assert_eq!(slices.iter().map(|s| s.0).sum::<usize>(), t);
            assert_eq!(slices.len(), c);
        }
    }

    #[test]
    fn naive_cp_is_imbalanced_headtail_is_not() {
        let f = FlopsModel::new(&ModelConfig::llama3_8b());
        let naive = naive_cp_flops(65536, 8, &f);
        assert!(stats::imbalance_ratio(&naive) > 1.5, "naive {naive:?}");
        let ht: Vec<f64> = per_document_cp_shards(0, 65536, 8)
            .iter()
            .map(|s| s.ca_fwd_flops(&f))
            .collect();
        assert!(stats::imbalance_ratio(&ht) < 1.01, "head-tail {ht:?}");
    }
}
