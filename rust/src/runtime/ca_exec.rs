//! CA-task execution on the real (CPU PJRT) backend: the attention-server
//! compute primitive.
//!
//! One `CaExecutor` wraps one compiled `ca_fwd_<Tq>x<Tkv>_*.hlo.txt`
//! artifact. Attention servers batch their assigned CA-tasks into the
//! artifact's packed layout (padding to the fixed AOT shape — one
//! compiled executable per size variant, §Runtime in DESIGN.md) and run
//! a single fused kernel call, exactly the composability contract the
//! kernel exposes.

use std::path::Path;

use anyhow::{Context, Result};

use super::client::{literal_f32, literal_i32, Runtime};

/// Kernel block size (matches `python/compile/kernels/core_attention.py`).
pub const BLOCK_Q: usize = 128;

/// One CA-task's tensors, in the packed layout.
#[derive(Debug, Clone)]
pub struct CaTaskTensors {
    /// `[q_len, n_heads, d]` flattened.
    pub q: Vec<f32>,
    /// `[kv_len, n_kv_heads, d]` flattened (K).
    pub k: Vec<f32>,
    /// same shape as `k` (V).
    pub v: Vec<f32>,
    pub q_len: usize,
    pub kv_len: usize,
}

/// A compiled fused-CA executable of fixed packed shape.
pub struct CaExecutor {
    exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    pub tq: usize,
    pub tkv: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl CaExecutor {
    /// Load `ca_fwd_<tq>x<tkv>_h<h>kv<hkv>d<d>.hlo.txt` from `dir`.
    pub fn load(
        rt: &Runtime,
        dir: &Path,
        tq: usize,
        tkv: usize,
        n_heads: usize,
        n_kv_heads: usize,
        head_dim: usize,
    ) -> Result<CaExecutor> {
        let name = format!("ca_fwd_{tq}x{tkv}_h{n_heads}kv{n_kv_heads}d{head_dim}.hlo.txt");
        let exe = rt.load(&dir.join(name))?;
        Ok(CaExecutor {
            exe,
            tq,
            tkv,
            n_heads,
            n_kv_heads,
            head_dim,
        })
    }

    fn q_row(&self) -> usize {
        self.n_heads * self.head_dim
    }

    fn kv_row(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Execute a fused batch of CA-tasks. Tasks are packed back-to-back
    /// (q rows must be BLOCK_Q-aligned per task); the remainder of the
    /// fixed AOT shape is padding (valid=0 blocks produce zeros).
    /// Returns each task's output rows `[q_len, n_heads, d]`.
    pub fn run_batch(&self, rt: &Runtime, tasks: &[CaTaskTensors]) -> Result<Vec<Vec<f32>>> {
        let q_row = self.q_row();
        let kv_row = self.kv_row();
        let mut q = vec![0.0f32; self.tq * q_row];
        let mut k = vec![0.0f32; self.tkv * kv_row];
        let mut v = vec![0.0f32; self.tkv * kv_row];
        let n_blocks = self.tq / BLOCK_Q;
        let mut meta = vec![0i32; n_blocks * 4];

        let mut q_ofs = 0usize;
        let mut kv_ofs = 0usize;
        for t in tasks {
            anyhow::ensure!(t.q_len % BLOCK_Q == 0, "task q_len {} not aligned", t.q_len);
            anyhow::ensure!(t.q_len <= t.kv_len, "q_len > kv_len");
            anyhow::ensure!(q_ofs + t.q_len <= self.tq, "batch overflows Tq={}", self.tq);
            anyhow::ensure!(kv_ofs + t.kv_len <= self.tkv, "batch overflows Tkv={}", self.tkv);
            anyhow::ensure!(t.q.len() == t.q_len * q_row, "q payload shape");
            anyhow::ensure!(t.k.len() == t.kv_len * kv_row, "k payload shape");
            q[q_ofs * q_row..(q_ofs + t.q_len) * q_row].copy_from_slice(&t.q);
            k[kv_ofs * kv_row..(kv_ofs + t.kv_len) * kv_row].copy_from_slice(&t.k);
            v[kv_ofs * kv_row..(kv_ofs + t.kv_len) * kv_row].copy_from_slice(&t.v);
            for b in 0..t.q_len / BLOCK_Q {
                let blk = q_ofs / BLOCK_Q + b;
                meta[blk * 4] = kv_ofs as i32;
                meta[blk * 4 + 1] = t.kv_len as i32;
                meta[blk * 4 + 2] = (t.kv_len - t.q_len + b * BLOCK_Q) as i32;
                meta[blk * 4 + 3] = 1;
            }
            q_ofs += t.q_len;
            kv_ofs += t.kv_len;
        }

        let inputs = [
            literal_f32(&q, &[self.tq as i64, self.n_heads as i64, self.head_dim as i64])?,
            literal_f32(&k, &[self.tkv as i64, self.n_kv_heads as i64, self.head_dim as i64])?,
            literal_f32(&v, &[self.tkv as i64, self.n_kv_heads as i64, self.head_dim as i64])?,
            literal_i32(&meta, &[n_blocks as i64, 4])?,
        ];
        let out = rt.execute_tuple(&self.exe, &inputs).context("CA execute")?;
        anyhow::ensure!(out.len() == 1, "CA artifact returns one tensor");
        let flat: Vec<f32> = out[0].to_vec::<f32>()?;

        let mut results = Vec::with_capacity(tasks.len());
        let mut ofs = 0usize;
        for t in tasks {
            results.push(flat[ofs * q_row..(ofs + t.q_len) * q_row].to_vec());
            ofs += t.q_len;
        }
        Ok(results)
    }

    /// Can this executor hold the batch?
    pub fn fits(&self, tasks: &[CaTaskTensors]) -> bool {
        let q: usize = tasks.iter().map(|t| t.q_len).sum();
        let kv: usize = tasks.iter().map(|t| t.kv_len).sum();
        q <= self.tq && kv <= self.tkv
    }
}

/// Generate a deterministic pseudo-random CA task (test/demo helper).
pub fn synthetic_task(
    rng: &mut crate::util::rng::Rng,
    q_len: usize,
    kv_len: usize,
    n_heads: usize,
    n_kv_heads: usize,
    head_dim: usize,
) -> CaTaskTensors {
    let mut fill = |n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.gen_f64(-1.0, 1.0) as f32).collect()
    };
    CaTaskTensors {
        q: fill(q_len * n_heads * head_dim),
        k: fill(kv_len * n_kv_heads * head_dim),
        v: fill(kv_len * n_kv_heads * head_dim),
        q_len,
        kv_len,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_task_shapes() {
        let mut rng = crate::util::rng::Rng::new(1);
        let t = synthetic_task(&mut rng, 128, 256, 4, 2, 16);
        assert_eq!(t.q.len(), 128 * 4 * 16);
        assert_eq!(t.k.len(), 256 * 2 * 16);
    }
}
