//! PJRT client wrapper with an executable cache.
//!
//! Wire: `HloModuleProto::from_text_file` → `XlaComputation::from_proto`
//! → `client.compile` → `execute`. Compilation is the expensive step
//! (seconds for the train step), so executables are cached by path — the
//! steady-state request path is execute-only (§Perf).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// Shared PJRT runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, path: &Path) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(std::sync::Arc::clone(exe));
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), std::sync::Arc::clone(&exe));
        Ok(exe)
    }

    /// Execute with literal inputs; unwraps the 1-element replica/partition
    /// nesting and returns the output buffers.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let mut out = exe.execute::<xla::Literal>(inputs).context("execute")?;
        anyhow::ensure!(!out.is_empty() && !out[0].is_empty(), "empty result");
        Ok(out.remove(0))
    }

    /// Execute a `return_tuple=True` artifact and decompose the tuple.
    pub fn execute_tuple(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let bufs = self.execute(exe, inputs)?;
        let lit = bufs[0].to_literal_sync().context("device->host")?;
        lit.to_tuple().context("untuple")
    }

    pub fn cached_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar literals.
pub fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_builders_check_shape() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.element_count(), 4);
        let i = literal_i32(&[1, 2], &[2]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    // PJRT-dependent tests live in rust/tests/integration_runtime.rs and
    // skip when artifacts are absent.
}
