//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts`) and executes them on the CPU PJRT client.
//! Python never runs here — HLO text is the only thing that crosses the
//! language boundary (see /opt/xla-example/README.md for why text, not
//! serialized protos).

pub mod ca_exec;
pub mod client;
pub mod train;

pub use ca_exec::CaExecutor;
pub use client::Runtime;
pub use train::{TrainDriver, TrainReport};

/// Default artifacts directory, overridable via `DISTCA_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("DISTCA_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// True if the AOT artifacts exist (integration tests skip otherwise).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("train_step.hlo.txt").exists()
}
