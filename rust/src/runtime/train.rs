//! End-to-end training driver: runs the AOT-compiled tiny-LM train step
//! from rust. This is the `examples/train_e2e` engine — proof that
//! L1 (Pallas CA inside the step) → L2 (JAX fwd+bwd+AdamW) → L3 (this
//! driver: data generation, batching, execution) compose with Python off
//! the request path.

use std::path::Path;

use anyhow::{Context, Result};

use super::client::{literal_f32, literal_i32, scalar_i32, Runtime};
use crate::util::rng::Rng;

/// Tokens per train step (matches `python/compile/aot.py::TRAIN_T`).
pub const TRAIN_T: usize = 512;
/// Kernel block size.
pub const BLOCK_Q: usize = 128;

/// Synthetic corpus with learnable structure: a vocab-wide first-order
/// Markov chain (each token has a preferred successor, followed with
/// probability `p_follow`, else uniform noise). The minimum achievable
/// cross-entropy is `H = -p log p - (1-p) log((1-p)/(V-1))`, so the loss
/// curve has a known floor — the driver checks training moves toward it.
#[derive(Debug, Clone)]
pub struct MarkovCorpus {
    pub vocab: usize,
    pub p_follow: f64,
    successor: Vec<u32>,
}

impl MarkovCorpus {
    pub fn new(vocab: usize, p_follow: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut successor: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut successor);
        Self { vocab, p_follow, successor }
    }

    /// Entropy floor (nats/token) of this source.
    pub fn entropy_floor(&self) -> f64 {
        let p = self.p_follow;
        let v = self.vocab as f64;
        -(p * p.ln() + (1.0 - p) * ((1.0 - p) / (v - 1.0)).ln())
    }

    /// Sample a document of `len` tokens.
    pub fn sample_doc(&self, rng: &mut Rng, len: usize) -> Vec<i32> {
        let mut doc = Vec::with_capacity(len);
        let mut cur = rng.gen_index(0, self.vocab) as u32;
        doc.push(cur as i32);
        for _ in 1..len {
            cur = if rng.gen_bool(self.p_follow) {
                self.successor[cur as usize]
            } else {
                rng.gen_index(0, self.vocab) as u32
            };
            doc.push(cur as i32);
        }
        doc
    }
}

/// One batch: a packed token stream + targets + CA-task block metadata.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    /// `[T/128, 4]` rows `(kv_ofs, kv_len, diag, valid)`.
    pub block_meta: Vec<i32>,
}

/// Pack documents of the given lengths (multiples of BLOCK_Q summing to
/// TRAIN_T) into a batch. Targets are next-token within each document;
/// the final position of each document gets target -1 (masked).
pub fn make_batch(corpus: &MarkovCorpus, rng: &mut Rng, doc_lens: &[usize]) -> Batch {
    assert_eq!(doc_lens.iter().sum::<usize>(), TRAIN_T);
    let mut tokens = Vec::with_capacity(TRAIN_T);
    let mut targets = Vec::with_capacity(TRAIN_T);
    let mut block_meta = Vec::with_capacity(TRAIN_T / BLOCK_Q * 4);
    let mut ofs = 0usize;
    for &len in doc_lens {
        assert!(len % BLOCK_Q == 0, "doc len {len} not 128-aligned");
        let doc = corpus.sample_doc(rng, len + 1);
        tokens.extend_from_slice(&doc[..len]);
        targets.extend_from_slice(&doc[1..len]);
        targets.push(doc[len]); // real next token (we sampled len+1)
        for b in 0..len / BLOCK_Q {
            block_meta.extend_from_slice(&[
                ofs as i32,
                len as i32,
                (b * BLOCK_Q) as i32,
                1,
            ]);
        }
        ofs += len;
    }
    Batch { tokens, targets, block_meta }
}

/// Loss curve + timing of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f64>,
    pub steps: usize,
    pub tokens_per_step: usize,
    pub secs_per_step: f64,
    pub entropy_floor: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f64 {
        *self.losses.first().unwrap_or(&0.0)
    }

    pub fn last_loss(&self) -> f64 {
        *self.losses.last().unwrap_or(&0.0)
    }
}

/// The train-step driver.
pub struct TrainDriver {
    rt: Runtime,
    step_exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    init_exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    n_params: usize,
}

impl TrainDriver {
    pub fn load(artifacts: &Path) -> Result<TrainDriver> {
        let rt = Runtime::cpu()?;
        let step_exe = rt.load(&artifacts.join("train_step.hlo.txt"))?;
        let init_exe = rt.load(&artifacts.join("init_params.hlo.txt"))?;
        // n_params from the manifest.
        let manifest = crate::util::json::parse_file(&artifacts.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let n_params = manifest
            .req("train_step")
            .and_then(|t| t.req("n_params"))
            .ok()
            .and_then(|v| v.as_usize())
            .context("manifest missing train_step.n_params")?;
        Ok(TrainDriver { rt, step_exe, init_exe, n_params })
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// Run `steps` training steps over batches drawn from `corpus`,
    /// logging loss every `log_every` (via the `progress` callback).
    pub fn train(
        &self,
        corpus: &MarkovCorpus,
        steps: usize,
        seed: u64,
        mut progress: impl FnMut(usize, f64),
    ) -> Result<TrainReport> {
        let mut rng = Rng::new(seed);
        // Initialize state.
        let init_out = self
            .rt
            .execute_tuple(&self.init_exe, &[scalar_i32(seed as i32)])?;
        let mut params = init_out.into_iter().next().context("init output")?;
        let zeros = vec![0.0f32; self.n_params];
        let mut m = literal_f32(&zeros, &[self.n_params as i64])?;
        let mut v = literal_f32(&zeros, &[self.n_params as i64])?;
        let mut step_lit = scalar_i32(0);

        let mut losses = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            // Vary document mix: 1×512, 2×256, or 4×128 per step.
            let lens: &[usize] = match s % 3 {
                0 => &[512],
                1 => &[256, 256],
                _ => &[128, 128, 128, 128],
            };
            let batch = make_batch(corpus, &mut rng, lens);
            let inputs = [
                params,
                m,
                v,
                step_lit,
                literal_i32(&batch.tokens, &[TRAIN_T as i64])?,
                literal_i32(&batch.targets, &[TRAIN_T as i64])?,
                literal_i32(&batch.block_meta, &[(TRAIN_T / BLOCK_Q) as i64, 4])?,
            ];
            let mut out = self.rt.execute_tuple(&self.step_exe, &inputs)?;
            anyhow::ensure!(out.len() == 5, "train step returns 5 outputs, got {}", out.len());
            let loss_lit = out.pop().unwrap();
            step_lit = out.pop().unwrap();
            v = out.pop().unwrap();
            m = out.pop().unwrap();
            params = out.pop().unwrap();
            let loss = loss_lit.to_vec::<f32>()?[0] as f64;
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {s}");
            losses.push(loss);
            progress(s, loss);
        }
        let secs = t0.elapsed().as_secs_f64() / steps.max(1) as f64;
        Ok(TrainReport {
            losses,
            steps,
            tokens_per_step: TRAIN_T,
            secs_per_step: secs,
            entropy_floor: corpus.entropy_floor(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_is_learnable_structure() {
        let c = MarkovCorpus::new(100, 0.9, 7);
        let mut rng = Rng::new(1);
        let doc = c.sample_doc(&mut rng, 1000);
        // With p=0.9, ~90% of transitions follow the successor table.
        let follows = doc
            .windows(2)
            .filter(|w| c.successor[w[0] as usize] == w[1] as u32)
            .count();
        let frac = follows as f64 / 999.0;
        assert!(frac > 0.8 && frac <= 1.0, "frac {frac}");
        // Entropy floor sanity: far below uniform ln(100)≈4.6.
        assert!(c.entropy_floor() < 1.5);
    }

    #[test]
    fn batch_layout() {
        let c = MarkovCorpus::new(100, 0.9, 7);
        let mut rng = Rng::new(2);
        let b = make_batch(&c, &mut rng, &[256, 256]);
        assert_eq!(b.tokens.len(), TRAIN_T);
        assert_eq!(b.targets.len(), TRAIN_T);
        assert_eq!(b.block_meta.len(), TRAIN_T / BLOCK_Q * 4);
        // second doc's first block restarts diag at 0 with kv_ofs 256
        let row2 = &b.block_meta[2 * 4..3 * 4];
        assert_eq!(row2, &[256, 256, 0, 1]);
    }

    #[test]
    #[should_panic]
    fn misaligned_doc_panics() {
        let c = MarkovCorpus::new(100, 0.9, 7);
        let mut rng = Rng::new(2);
        make_batch(&c, &mut rng, &[100, 412]);
    }
}
