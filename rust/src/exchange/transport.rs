//! Pluggable transport for the real (CPU) disaggregated execution path.
//!
//! The paper's NVSHMEM all-to-all becomes, on this testbed, an in-process
//! channel fabric between attention-server worker threads: same message
//! discipline (tagged point-to-point sends, per-destination queues),
//! different wire. The byte accounting feeding the simulator is identical
//! either way.

use std::fmt;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A tagged message: raw f32 payload plus an opaque task tag.
#[derive(Debug, Clone, PartialEq)]
pub struct Message {
    pub src: usize,
    pub tag: u64,
    pub payload: Vec<f32>,
}

/// A send that could not reach its destination rank. On the in-process
/// channel fabric this means the receiver was dropped; on the networked
/// fabric it means the connection is down — either way the peer is
/// gone, and the caller must treat the destination as dead and recover
/// through the elastic re-dispatch path (never panic: a lost server
/// loses only re-sendable bytes, §3 statelessness).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SendError {
    /// Destination rank of the failed send.
    pub dst: usize,
    /// Human-readable cause.
    pub reason: String,
}

impl fmt::Display for SendError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "send to rank {} failed: {}", self.dst, self.reason)
    }
}

impl std::error::Error for SendError {}

/// Message synthesized by [`Transport::recv`] when the fabric is torn
/// down while a receive is blocked: an orderly coordinator shutdown
/// (`CTRL_SHUTDOWN` from `COORD_SRC`) rather than a panic, so a
/// blocked server loop or gather exits through its normal shutdown
/// path and in-flight work is recovered by victim re-dispatch.
pub fn shutdown_sentinel() -> Message {
    Message {
        src: crate::elastic::failover::COORD_SRC,
        tag: crate::elastic::failover::CTRL_SHUTDOWN,
        payload: vec![],
    }
}

/// Point-to-point transport between `n` ranks.
pub trait Transport: Send + Sync {
    fn n_ranks(&self) -> usize;
    /// Send `msg` to `dst` (non-blocking). A send error means the
    /// destination is unreachable (dropped receiver / dead connection);
    /// callers on the dispatch path must fail over, not panic.
    fn send(&self, dst: usize, msg: Message) -> Result<(), SendError>;
    /// Receive the next message addressed to `rank` (blocking). If the
    /// fabric is torn down mid-receive, implementations return
    /// [`shutdown_sentinel`] instead of panicking.
    fn recv(&self, rank: usize) -> Message;
    /// Try to receive without blocking.
    fn try_recv(&self, rank: usize) -> Option<Message>;
    /// Receive with a deadline: `None` if nothing arrived within
    /// `timeout`. The default polls [`Transport::try_recv`]; fabrics
    /// with native timed receives override it.
    fn try_recv_for(&self, rank: usize, timeout: Duration) -> Option<Message> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(m) = self.try_recv(rank) {
                return Some(m);
            }
            if Instant::now() >= deadline {
                return None;
            }
            std::thread::sleep(Duration::from_micros(200));
        }
    }
    /// Stamp subsequent outbound data-plane sends with a ping-pong
    /// wave index and pool membership epoch (the wire form of
    /// `WaveStamp`). In-process fabrics need no wire stamp — the
    /// default is a no-op; the TCP fabric carries it in the frame
    /// header so mid-wave faults are scoped per wave across processes.
    fn set_wave_stamp(&self, _wave: usize, _epoch: u64) {}
    /// Stamp the next outbound data frame carrying `tag` with the
    /// lineage trace id of the dispatch that produced it (DCA3 `trace`
    /// header field, [`crate::obs::lineage`]). Workers echo the
    /// request's trace onto the matching response, so the coordinator
    /// can attribute which dispatch hop won under first-response-wins
    /// dedup. In-process fabrics deliver the same `Message` end-to-end
    /// and need no wire stamp — the default is a no-op.
    fn set_trace_stamp(&self, _tag: u64, _trace: u64) {}
    /// Drain the `(tag, trace)` pairs echoed on responses since the
    /// last call (coordinator side of [`Transport::set_trace_stamp`]).
    /// Fabrics without a wire trace field have nothing to report — the
    /// default returns an empty vec.
    fn take_trace_echoes(&self) -> Vec<(u64, u64)> {
        Vec::new()
    }
    /// Return a spent recv-payload buffer to the fabric's pool so the
    /// next inbound frame decodes into it instead of a fresh
    /// allocation (the zero-copy data plane). In-process fabrics move
    /// payload `Vec`s end-to-end and have nothing to pool — the
    /// default just drops the buffer.
    fn recycle_payload(&self, _buf: Vec<f32>) {}
}

/// In-process channel fabric.
pub struct ChannelTransport {
    senders: Vec<Sender<Message>>,
    receivers: Vec<Mutex<Receiver<Message>>>,
}

impl ChannelTransport {
    pub fn new(n: usize) -> Self {
        let mut senders = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Mutex::new(rx));
        }
        Self { senders, receivers }
    }
}

impl Transport for ChannelTransport {
    fn n_ranks(&self) -> usize {
        self.senders.len()
    }

    fn send(&self, dst: usize, msg: Message) -> Result<(), SendError> {
        let Some(tx) = self.senders.get(dst) else {
            return Err(SendError {
                dst,
                reason: format!("rank out of range (fabric has {})", self.senders.len()),
            });
        };
        tx.send(msg).map_err(|_| SendError { dst, reason: "receiver dropped".into() })
    }

    fn recv(&self, rank: usize) -> Message {
        match self.receivers[rank].lock().unwrap().recv() {
            Ok(m) => m,
            // Every sender gone mid-receive = the fabric is being torn
            // down around a blocked receiver: exit via the shutdown
            // path, don't abort the process.
            Err(_) => shutdown_sentinel(),
        }
    }

    fn try_recv(&self, rank: usize) -> Option<Message> {
        self.receivers[rank].lock().unwrap().try_recv().ok()
    }

    fn try_recv_for(&self, rank: usize, timeout: Duration) -> Option<Message> {
        match self.receivers[rank].lock().unwrap().recv_timeout(timeout) {
            Ok(m) => Some(m),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => Some(shutdown_sentinel()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn point_to_point() {
        let t = ChannelTransport::new(2);
        t.send(1, Message { src: 0, tag: 7, payload: vec![1.0, 2.0] }).unwrap();
        let m = t.recv(1);
        assert_eq!(m.src, 0);
        assert_eq!(m.tag, 7);
        assert_eq!(m.payload, vec![1.0, 2.0]);
    }

    #[test]
    fn try_recv_nonblocking() {
        let t = ChannelTransport::new(1);
        assert!(t.try_recv(0).is_none());
        t.send(0, Message { src: 0, tag: 1, payload: vec![] }).unwrap();
        assert!(t.try_recv(0).is_some());
    }

    #[test]
    fn send_out_of_range_is_an_error_not_a_panic() {
        let t = ChannelTransport::new(2);
        let err = t.send(5, Message { src: 0, tag: 1, payload: vec![] }).unwrap_err();
        assert_eq!(err.dst, 5);
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn cross_thread_exchange() {
        let t = Arc::new(ChannelTransport::new(4));
        let mut handles = Vec::new();
        for rank in 0..4usize {
            let t = Arc::clone(&t);
            handles.push(std::thread::spawn(move || {
                // every rank sends its id to every other rank
                for dst in 0..4 {
                    if dst != rank {
                        let m =
                            Message { src: rank, tag: rank as u64, payload: vec![rank as f32] };
                        t.send(dst, m).unwrap();
                    }
                }
                let mut got = Vec::new();
                for _ in 0..3 {
                    got.push(t.recv(rank).src);
                }
                got.sort_unstable();
                got
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            let expect: Vec<usize> = (0..4).filter(|&r| r != rank).collect();
            assert_eq!(got, expect);
        }
    }
}
