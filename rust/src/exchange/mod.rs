//! All-to-all exchange: the dispatch fabric between home devices and
//! attention servers (§5 implements this over NVSHMEM; here the byte
//! accounting is exact and the transport is pluggable — an in-process
//! channel transport for the real CPU execution path, and the simulator's
//! link model for scale experiments).

pub mod transport;

pub use transport::{ChannelTransport, SendError, Transport};

use crate::coordinator::Plan;

/// Dense all-to-all byte matrix with helpers for straggler analysis.
#[derive(Debug, Clone, PartialEq)]
pub struct AllToAll {
    pub n: usize,
    /// `bytes[src][dst]`
    pub bytes: Vec<Vec<f64>>,
}

impl AllToAll {
    pub fn new(n: usize) -> Self {
        Self { n, bytes: vec![vec![0.0; n]; n] }
    }

    /// Combined dispatch + return traffic of a plan.
    pub fn from_plan(plan: &Plan) -> Self {
        let n = plan.n_servers;
        let mut m = Self::new(n);
        for s in 0..n {
            for d in 0..n {
                m.bytes[s][d] += plan.comm_matrix[s][d] + plan.return_matrix[s][d];
            }
        }
        m
    }

    pub fn add(&mut self, src: usize, dst: usize, bytes: f64) {
        self.bytes[src][dst] += bytes;
    }

    pub fn total(&self) -> f64 {
        self.bytes.iter().flatten().sum()
    }

    pub fn row_sum(&self, src: usize) -> f64 {
        self.bytes[src].iter().sum()
    }

    pub fn col_sum(&self, dst: usize) -> f64 {
        (0..self.n).map(|s| self.bytes[s][dst]).sum()
    }

    /// The bottleneck: max over ranks of max(send, recv) — an all-to-all
    /// completes when the busiest port finishes (§3.3's straggler point).
    pub fn bottleneck_bytes(&self) -> f64 {
        (0..self.n)
            .map(|r| self.row_sum(r).max(self.col_sum(r)))
            .fold(0.0, f64::max)
    }

    /// Time on full-duplex links of `bw` bytes/s per rank.
    pub fn time(&self, bw: f64) -> f64 {
        self.bottleneck_bytes() / bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_and_bottleneck() {
        let mut m = AllToAll::new(3);
        m.add(0, 1, 10.0);
        m.add(0, 2, 5.0);
        m.add(2, 1, 7.0);
        assert_eq!(m.total(), 22.0);
        assert_eq!(m.row_sum(0), 15.0);
        assert_eq!(m.col_sum(1), 17.0);
        // rank0 sends 15, rank1 recvs 17 -> bottleneck 17
        assert_eq!(m.bottleneck_bytes(), 17.0);
        assert!((m.time(17.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn balanced_spread_lowers_bottleneck() {
        // §3.3: dispatching comm-heavy shards to different destinations
        // avoids an all-to-all straggler.
        let mut skew = AllToAll::new(4);
        skew.add(0, 1, 100.0);
        let mut spread = AllToAll::new(4);
        for d in 1..4 {
            spread.add(0, d, 100.0 / 3.0);
        }
        // same total sent by rank 0, but recv bottleneck improves
        assert!(spread.bottleneck_bytes() >= 100.0 - 1e-9); // send side equal
        assert!(spread.col_sum(1) < skew.col_sum(1));
    }
}
