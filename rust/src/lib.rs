//! # DistCA — Core Attention Disaggregation
//!
//! Reproduction of *"Efficient Long-context Language Model Training by
//! Core Attention Disaggregation"* (CS.LG 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: the communication-aware greedy
//!   scheduler over token-level CA-tasks ([`coordinator`] — including the
//!   heterogeneity-aware [`coordinator::schedule_with_beliefs`], which
//!   balances estimated *seconds* against per-server believed speeds and
//!   arena byte budgets instead of assuming uniform servers), attention
//!   servers ([`server`]), the elastic server pool — dynamic membership,
//!   fault injection, straggler mitigation, autoscaling ([`elastic`]) —
//!   the memory-disaggregated execution model ([`memplan`]: per-server
//!   transient arenas with in-place CA buffers, the scheduler's hard
//!   `mem_budget`, and `oom:` eviction-recovery — the §5 / Fig. 3b
//!   "compute **and memory** balance" claim made byte-accurate and
//!   fault-injectable), ping-pong overlap, pipeline integration
//!   ([`parallel`]), a discrete-event cluster simulator ([`sim`])
//!   standing in for the paper's 512×H200 testbed — with per-resource
//!   live-byte tracking and OOM eviction in its engine — the baselines
//!   it compares against ([`baselines`]), a **networked runtime**
//!   ([`net`]: attention servers as separate OS processes speaking a
//!   length-prefixed binary protocol over TCP, driven bit-exact by the
//!   same elastic coordinator through the pluggable
//!   [`exchange::Transport`]), a **multi-tenant serving gateway**
//!   ([`gateway`]: seeded synthetic tenant streams folded by weighted-
//!   fair queueing and believed-capacity admission into fused
//!   cross-tenant waves over the shared pool, with tenant ids riding
//!   the task tags across the wire and a double-entry per-tenant
//!   ledger), a unified **tracing & metrics plane**
//!   ([`obs`]: tick-phase spans with wall and virtual clock sources, a
//!   Chrome/Perfetto `trace_event` exporter behind `--trace-out`, the
//!   `distca report` straggler-attribution table, and the `distca
//!   drift` perf-snapshot checker), a **fast-path CPU kernel**
//!   ([`kernel`]: blocked streaming-softmax GQA core attention,
//!   thread-parallel across (task, head) pairs with an AVX2/FMA inner
//!   loop, bit-exact against the scalar oracle under a pinned reduction
//!   order, selected via `DISTCA_KERNEL`), and a PJRT runtime ([`runtime`]) that
//!   executes the AOT-compiled JAX/Pallas artifacts on the real CPU
//!   backend.
//!
//! Fault tolerance rests on the paper's §3 observation that core
//! attention is *stateless*: a CA-task is (Q, KV) → O with no trainable
//! state, so a task lost to a dead server is recovered by resending the
//! same bytes elsewhere, a straggler's tasks can be speculatively
//! duplicated (first response wins, duplicates suppressed by the
//! `(doc, q_start)` tag), and the pool can grow or shrink between ticks
//! with the scheduler simply re-planning against live membership.
//! Statelessness also covers *memory* faults: a CA-task's buffers are
//! transient (O overwrites Q in place, KV frees after the layer — §5,
//! Fig. 3b), so an arena overflow (`oom:<srv>@<tick>`) evicts only
//! re-sendable work and the victim rejoins within the same tick. Under
//! pipeline parallelism this holds *mid-PP-tick*: each tick's two
//! ping-pong nano-batch waves carry wave-scoped membership epochs, so a
//! fault re-dispatches only the in-flight wave while the other wave
//! re-plans against the fresh epoch with its communication still
//! overlapped. See [`elastic`] for the module map, the PP-tick
//! membership-epoch model, and the `FaultPlan` format.
//! * **L2 (python/compile/model.py)** — the JAX transformer split at the
//!   core-attention boundary, lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — the Pallas packed-varlen causal
//!   core-attention kernel (the FlashAttention stand-in), validated
//!   against a pure-jnp oracle.
//!
//! Python never runs on the request path: `make artifacts` lowers
//! everything to `artifacts/*.hlo.txt`, and the `distca` binary is
//! self-contained afterwards.
//!
//! For the paper-section → module map, the matrix of the four elastic
//! execution paths (and which tests cross-validate them), and the
//! PP-tick data-flow diagram, see `docs/ARCHITECTURE.md` at the repo
//! root.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod elastic;
pub mod exchange;
pub mod gateway;
pub mod kernel;
pub mod memplan;
pub mod metrics;
pub mod model;
pub mod net;
pub mod obs;
pub mod parallel;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
