//! Admission control: folds queued tenant work into one fused
//! cross-tenant wave without overrunning the pool's believed capacity.
//!
//! Two budgets bound a wave, both derived from the coordinator's
//! [`PoolCapacity`](crate::coordinator::PoolCapacity) view of the live
//! pool (believed per-server speeds × arena byte budgets) or pinned
//! explicitly for tests:
//!
//! * **pair budget** — total causal-pair work (`Σ len²`) the pool is
//!   believed to finish inside one wave;
//! * **byte budget** — total Q+K+V wire bytes the pool's arenas can
//!   hold at the configured fill fraction.
//!
//! The admit loop walks the WFQ queue *in order* and stops at the first
//! task that does not fit — it does **not** skip ahead to smaller
//! tasks. Skipping would silently starve tenants with long contexts;
//! stopping preserves the WFQ ordering guarantee, and because
//! [`Admission::push`] rejects any task that could never fit an *empty*
//! wave, the head task always fits a fresh wave — so every wave admits
//! at least one task whenever the queue is backlogged (liveness).
//! Tasks that don't fit the *remaining* headroom simply wait; that is
//! the backpressure signal surfaced per wave in [`AdmitStats`].

use super::queue::{QueuedTask, WfqQueue};
use super::tenant::SloClass;

/// Per-wave capacity limits.
#[derive(Debug, Clone, Copy)]
pub struct WaveBudget {
    /// Max total `len²` causal-pair work per wave.
    pub pairs: f64,
    /// Max total task wire bytes per wave.
    pub bytes: f64,
}

impl WaveBudget {
    pub fn new(pairs: f64, bytes: f64) -> WaveBudget {
        assert!(pairs > 0.0, "pair budget must be positive");
        assert!(bytes > 0.0, "byte budget must be positive");
        WaveBudget { pairs, bytes }
    }

    fn fits_empty(&self, task: &QueuedTask) -> bool {
        task.cost <= self.pairs && task.bytes <= self.bytes
    }
}

/// What happened in one admission round.
#[derive(Debug, Clone, Default)]
pub struct AdmitStats {
    /// Tasks admitted into this wave.
    pub admitted: usize,
    /// Causal-pair work admitted.
    pub admitted_pairs: f64,
    /// Wire bytes admitted.
    pub admitted_bytes: f64,
    /// Tasks still queued after the wave filled (backpressure depth).
    pub backlog: usize,
    /// True when the wave closed because a task exceeded remaining
    /// headroom (as opposed to the queue simply running dry).
    pub saturated: bool,
}

/// The gateway's admission gate: a WFQ queue plus a per-wave budget.
#[derive(Debug)]
pub struct Admission {
    queue: WfqQueue,
    budget: WaveBudget,
    /// Tasks rejected at enqueue time because they could never fit
    /// even an empty wave (counted, never queued).
    pub rejected_oversize: usize,
}

impl Admission {
    pub fn new(budget: WaveBudget) -> Admission {
        Admission {
            queue: WfqQueue::new(),
            budget,
            rejected_oversize: 0,
        }
    }

    pub fn queue(&self) -> &WfqQueue {
        &self.queue
    }

    /// Re-derive the per-wave budget from fresh pool beliefs (workers
    /// die, drain, and rejoin mid-run). Applies to subsequent pushes
    /// and waves; already-queued tasks keep their place.
    pub fn set_budget(&mut self, budget: WaveBudget) {
        self.budget = budget;
    }

    /// Minimum-progress override: pop the WFQ head unconditionally.
    /// Used only when a *shrunken* budget (capacity lost after the task
    /// was legally enqueued) no longer fits even an empty wave —
    /// without it the strict-order admit loop would wedge forever on a
    /// task admission can neither dispatch nor drop.
    pub fn force_pop(&mut self) -> Option<QueuedTask> {
        self.queue.pop()
    }

    /// Enqueue one task under its tenant's SLO weight. Returns `false`
    /// (and counts the rejection) if the task exceeds the whole-wave
    /// budget — such a task could never dispatch and would wedge the
    /// strict-order admit loop forever.
    pub fn push(&mut self, task: QueuedTask, slo: SloClass) -> bool {
        if !self.budget.fits_empty(&task) {
            self.rejected_oversize += 1;
            return false;
        }
        self.queue.push(task, slo.weight());
        true
    }

    /// Pop tasks in WFQ order into one wave until the next task would
    /// exceed the remaining pair or byte headroom.
    pub fn admit_wave(&mut self) -> (Vec<QueuedTask>, AdmitStats) {
        let mut wave = Vec::new();
        let mut stats = AdmitStats::default();
        let mut pairs_left = self.budget.pairs;
        let mut bytes_left = self.budget.bytes;
        while let Some(head) = self.queue.peek() {
            if head.cost > pairs_left || head.bytes > bytes_left {
                stats.saturated = true;
                break;
            }
            let task = self.queue.pop().expect("peeked task pops");
            pairs_left -= task.cost;
            bytes_left -= task.bytes;
            stats.admitted += 1;
            stats.admitted_pairs += task.cost;
            stats.admitted_bytes += task.bytes;
            wave.push(task);
        }
        stats.backlog = self.queue.len();
        (wave, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(tenant: u32, seq: u32, len: usize) -> QueuedTask {
        // 1 byte per causal pair keeps both budgets easy to reason about.
        QueuedTask::new(tenant, seq, len, 0, (len * len) as f64)
    }

    #[test]
    fn oversize_tasks_are_rejected_at_enqueue() {
        let mut adm = Admission::new(WaveBudget::new(100.0, 1e9));
        assert!(adm.push(task(0, 0, 8), SloClass::Standard)); // cost 64
        assert!(!adm.push(task(0, 1, 16), SloClass::Standard)); // cost 256 > 100
        assert_eq!(adm.rejected_oversize, 1);
        assert_eq!(adm.queue().len(), 1);
    }

    #[test]
    fn wave_never_exceeds_budget_and_always_admits_head() {
        let mut adm = Admission::new(WaveBudget::new(200.0, 1e9));
        for seq in 0..10 {
            assert!(adm.push(task(seq, 0, 8), SloClass::Standard)); // cost 64 each
        }
        let (wave, stats) = adm.admit_wave();
        // 3×64 = 192 fits, a 4th would hit 256 > 200.
        assert_eq!(wave.len(), 3);
        assert!(stats.saturated);
        assert_eq!(stats.backlog, 7);
        assert!(stats.admitted_pairs <= 200.0);
        // Next wave admits again: no wedging.
        let (wave2, _) = adm.admit_wave();
        assert_eq!(wave2.len(), 3);
    }

    #[test]
    fn byte_headroom_also_closes_the_wave() {
        let mut adm = Admission::new(WaveBudget::new(1e9, 130.0));
        for seq in 0..4 {
            assert!(adm.push(task(0, seq, 8), SloClass::Batch)); // 64 bytes each
        }
        let (wave, stats) = adm.admit_wave();
        assert_eq!(wave.len(), 2); // 128 <= 130, third would be 192
        assert!(stats.saturated);
        assert!(stats.admitted_bytes <= 130.0);
    }

    #[test]
    fn queue_running_dry_is_not_saturation() {
        let mut adm = Admission::new(WaveBudget::new(1e9, 1e9));
        adm.push(task(0, 0, 8), SloClass::Interactive);
        let (wave, stats) = adm.admit_wave();
        assert_eq!(wave.len(), 1);
        assert!(!stats.saturated);
        assert_eq!(stats.backlog, 0);
    }
}
