//! Per-tenant queues under self-clocked weighted-fair queueing.
//!
//! Every enqueued task gets a **virtual finish stamp** `F = max(V,
//! F_tenant) + cost / weight` (SCFQ): `V` is the queue's virtual time
//! (advanced to the stamp of each popped task), `F_tenant` the
//! tenant's previous stamp, `cost` the task's causal-pair work, and
//! `weight` the tenant's SLO share. Dequeue order is ascending stamp,
//! ties broken by tenant id — deterministic, and starvation-free by
//! construction: a backlogged tenant's head stamp is fixed while `V`
//! only grows, so every head is overtaken in bounded work. Heavy
//! tenants don't starve light ones (their stamps grow per unit cost);
//! high-weight tenants drain proportionally faster.
//!
//! Stamps are non-negative finite f64s, so their IEEE-754 bit patterns
//! order identically to their values — the ready-set is a plain
//! `BTreeSet<(stamp.to_bits(), tenant)>` holding one entry per
//! *backlogged tenant* (its head's stamp), giving O(log T) pushes and
//! pops across any number of tenants.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// One admitted-or-waiting unit of tenant work: everything the gateway
/// needs to rebuild the task at dispatch (tensors are re-derived from
/// the seed chain, never queued).
#[derive(Debug, Clone)]
pub struct QueuedTask {
    pub tenant: u32,
    /// Per-tenant doc sequence number.
    pub seq: u32,
    /// Context length (kernel units); `q_len = kv_len = len`.
    pub len: usize,
    /// Wave index at which the task entered the queue (queue-wait base).
    pub enqueued_wave: usize,
    /// Causal-pair cost `len²` — the WFQ and admission work unit.
    pub cost: f64,
    /// Wire bytes of the task's f32 Q+K+V tensors.
    pub bytes: f64,
    /// Virtual finish stamp (assigned by [`WfqQueue::push`]).
    stamp: f64,
}

impl QueuedTask {
    pub fn new(tenant: u32, seq: u32, len: usize, enqueued_wave: usize, bytes: f64) -> QueuedTask {
        QueuedTask {
            tenant,
            seq,
            len,
            enqueued_wave,
            cost: (len * len) as f64,
            bytes,
            stamp: 0.0,
        }
    }
}

/// The gateway's cross-tenant ready queue.
#[derive(Debug, Default)]
pub struct WfqQueue {
    queues: BTreeMap<u32, VecDeque<QueuedTask>>,
    /// Last assigned finish stamp per tenant (monotone per tenant).
    finish: BTreeMap<u32, f64>,
    /// Ready set: `(head stamp bits, tenant)` for each backlogged
    /// tenant.
    ready: BTreeSet<(u64, u32)>,
    /// Virtual time: stamp of the most recently popped task.
    vtime: f64,
    len: usize,
}

impl WfqQueue {
    pub fn new() -> WfqQueue {
        WfqQueue::default()
    }

    /// Total queued tasks across all tenants.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of tenants with at least one queued task.
    pub fn backlogged_tenants(&self) -> usize {
        self.ready.len()
    }

    /// Enqueue `task` for its tenant at WFQ weight `weight` (> 0).
    pub fn push(&mut self, mut task: QueuedTask, weight: f64) {
        assert!(weight > 0.0 && weight.is_finite(), "WFQ weight must be positive");
        assert!(task.cost >= 0.0 && task.cost.is_finite(), "task cost must be finite");
        let prev = self.finish.get(&task.tenant).copied().unwrap_or(0.0);
        let start = self.vtime.max(prev);
        task.stamp = start + task.cost / weight;
        self.finish.insert(task.tenant, task.stamp);
        let q = self.queues.entry(task.tenant).or_default();
        if q.is_empty() {
            self.ready.insert((task.stamp.to_bits(), task.tenant));
        }
        q.push_back(task);
        self.len += 1;
    }

    /// The next task in WFQ order, without removing it.
    pub fn peek(&self) -> Option<&QueuedTask> {
        let &(_, tenant) = self.ready.first()?;
        self.queues.get(&tenant).and_then(|q| q.front())
    }

    /// Remove and return the next task in WFQ order, advancing virtual
    /// time to its stamp.
    pub fn pop(&mut self) -> Option<QueuedTask> {
        let (bits, tenant) = self.ready.pop_first()?;
        let q = self.queues.get_mut(&tenant).expect("ready tenant has a queue");
        let task = q.pop_front().expect("ready tenant queue non-empty");
        debug_assert_eq!(task.stamp.to_bits(), bits, "ready set out of sync");
        if let Some(next) = q.front() {
            self.ready.insert((next.stamp.to_bits(), tenant));
        }
        self.vtime = self.vtime.max(task.stamp);
        self.len -= 1;
        Some(task)
    }

    /// Oldest `enqueued_wave` still queued for `tenant`, if backlogged.
    pub fn head_wave(&self, tenant: u32) -> Option<usize> {
        self.queues.get(&tenant).and_then(|q| q.front()).map(|t| t.enqueued_wave)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(tenant: u32, seq: u32, len: usize) -> QueuedTask {
        QueuedTask::new(tenant, seq, len, 0, 0.0)
    }

    #[test]
    fn fifo_within_a_tenant() {
        let mut q = WfqQueue::new();
        for seq in 0..5 {
            q.push(t(3, seq, 8), 1.0);
        }
        for seq in 0..5 {
            assert_eq!(q.pop().unwrap().seq, seq);
        }
        assert!(q.pop().is_none());
    }

    #[test]
    fn weights_set_the_service_ratio() {
        // Tenant 0 at weight 4, tenant 1 at weight 1, identical work:
        // in any prefix of the drain order tenant 0 should lead ~4:1.
        let mut q = WfqQueue::new();
        for seq in 0..40 {
            q.push(t(0, seq, 8), 4.0);
            q.push(t(1, seq, 8), 1.0);
        }
        let first_ten: Vec<u32> = (0..10).map(|_| q.pop().unwrap().tenant).collect();
        let t0 = first_ten.iter().filter(|&&x| x == 0).count();
        assert!(t0 >= 7, "weight-4 tenant got only {t0}/10 of the first slots: {first_ten:?}");
    }

    #[test]
    fn equal_weights_interleave_by_cost() {
        // A tenant with 4x-cost tasks gets ~1/4 the slots.
        let mut q = WfqQueue::new();
        for seq in 0..32 {
            q.push(t(0, seq, 16), 1.0); // cost 256
            q.push(t(1, seq, 8), 1.0); // cost 64
        }
        let first: Vec<u32> = (0..20).map(|_| q.pop().unwrap().tenant).collect();
        let heavy = first.iter().filter(|&&x| x == 0).count();
        assert!(
            (2..=7).contains(&heavy),
            "heavy tenant took {heavy}/20 slots (expected ~1/5): {first:?}"
        );
    }

    #[test]
    fn late_arrival_is_not_starved() {
        let mut q = WfqQueue::new();
        for seq in 0..1000 {
            q.push(t(0, seq, 8), 1.0);
        }
        // Drain a while, then a new tenant shows up: its first task's
        // stamp starts at current vtime, so it must pop within one
        // tenant-0 task's worth of service, not after the 900 backlog.
        for _ in 0..100 {
            q.pop();
        }
        q.push(t(9, 0, 8), 1.0);
        let mut popped_after = 0usize;
        loop {
            let x = q.pop().unwrap();
            if x.tenant == 9 {
                break;
            }
            popped_after += 1;
            assert!(popped_after < 4, "late arrival starved behind the backlog");
        }
    }
}
