//! Per-tenant accounting: tasks, bytes, estimated FLOPs, queue wait,
//! and makespan contribution, kept in a ledger whose per-tenant rows
//! must sum *exactly* to independently-tracked pool totals.
//!
//! The ledger double-books on purpose: every `admit`/`complete` call
//! bumps both the tenant row and a pool-level total that is **not**
//! derived from the rows. [`Ledger::conservation_errors`] then checks
//! the two views agree — a structural audit that catches dropped or
//! double-counted tenant attributions (e.g. a re-dispatched task billed
//! twice, or a response whose tenant tag was lost on the wire).
//!
//! FLOPs use the standard causal-attention estimate `4·h·d·pairs` per
//! task (QKᵀ + AV, multiply-accumulate = 2 each); makespan contribution
//! is the tenant's pair-share of each wave's measured wall clock.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::tenant::SloClass;

/// Running totals for one tenant.
#[derive(Debug, Clone, Default)]
pub struct TenantAccount {
    pub slo: Option<SloClass>,
    /// Docs emitted by the arrival process (admitted + rejected + queued).
    pub arrived: usize,
    /// Tasks folded into dispatched waves.
    pub admitted: usize,
    /// Tasks whose outputs came back and verified.
    pub completed: usize,
    /// Oversize docs refused at enqueue.
    pub rejected: usize,
    /// Wire bytes of admitted task tensors.
    pub bytes: f64,
    /// Estimated core-attention FLOPs of admitted tasks.
    pub flops: f64,
    /// Summed admit-wave − enqueue-wave (for mean wait).
    pub wait_waves_sum: usize,
    /// Worst single-task queue wait, in waves.
    pub max_wait_waves: usize,
    /// Pair-weighted share of wave wall-clock, in seconds.
    pub makespan_s: f64,
    /// Tasks of this tenant the elastic layer had to re-dispatch.
    pub redispatched: usize,
}

/// Pool-wide totals tracked independently of the per-tenant rows.
#[derive(Debug, Clone, Default)]
pub struct PoolTotals {
    pub arrived: usize,
    pub admitted: usize,
    pub completed: usize,
    pub rejected: usize,
    pub bytes: f64,
    pub flops: f64,
    pub redispatched: usize,
}

/// Fraction of a class's completed tasks allowed to miss its latency
/// target before the error budget is spent: burn rate 1.0 means
/// breaches are arriving at exactly the budgeted rate.
pub const SLO_BUDGET: f64 = 0.01;

/// One SLO class's latency accounting against its
/// [`SloClass::latency_target_s`] target.
#[derive(Debug, Clone, Default)]
pub struct ClassSlo {
    /// Completed tasks whose end-to-end latency was observed.
    pub tasks: usize,
    /// Observations that exceeded the class target.
    pub breaches: usize,
    pub latency_sum_s: f64,
    pub max_latency_s: f64,
}

impl ClassSlo {
    /// Breach fraction over the error budget: 0 = no breaches, 1.0 =
    /// budget exactly spent, >1 = the class is burning faster than the
    /// SLO allows.
    pub fn burn_rate(&self) -> f64 {
        if self.tasks == 0 {
            return 0.0;
        }
        (self.breaches as f64 / self.tasks as f64) / SLO_BUDGET
    }

    pub fn mean_latency_s(&self) -> f64 {
        if self.tasks == 0 {
            return 0.0;
        }
        self.latency_sum_s / self.tasks as f64
    }
}

/// The gateway's double-entry ledger.
#[derive(Debug, Default)]
pub struct Ledger {
    tenants: BTreeMap<u32, TenantAccount>,
    pool: PoolTotals,
    slo: BTreeMap<SloClass, ClassSlo>,
}

/// FLOPs for one CA task: `4 · h · d · pairs` (per head-dim MAC in
/// QKᵀ and AV), with `pairs = len²` for self-attention.
pub fn task_flops(len: usize, h: usize, d: usize) -> f64 {
    4.0 * (h * d) as f64 * (len * len) as f64
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    fn row(&mut self, tenant: u32, slo: SloClass) -> &mut TenantAccount {
        let row = self.tenants.entry(tenant).or_default();
        row.slo.get_or_insert(slo);
        row
    }

    pub fn note_arrival(&mut self, tenant: u32, slo: SloClass) {
        self.row(tenant, slo).arrived += 1;
        self.pool.arrived += 1;
    }

    pub fn note_rejected(&mut self, tenant: u32, slo: SloClass) {
        self.row(tenant, slo).rejected += 1;
        self.pool.rejected += 1;
    }

    pub fn note_admit(&mut self, tenant: u32, slo: SloClass, bytes: f64, flops: f64, wait: usize) {
        let row = self.row(tenant, slo);
        row.admitted += 1;
        row.bytes += bytes;
        row.flops += flops;
        row.wait_waves_sum += wait;
        row.max_wait_waves = row.max_wait_waves.max(wait);
        self.pool.admitted += 1;
        self.pool.bytes += bytes;
        self.pool.flops += flops;
    }

    pub fn note_complete(&mut self, tenant: u32, slo: SloClass) {
        self.row(tenant, slo).completed += 1;
        self.pool.completed += 1;
    }

    pub fn note_redispatch(&mut self, tenant: u32, slo: SloClass, n: usize) {
        self.row(tenant, slo).redispatched += n;
        self.pool.redispatched += n;
    }

    /// Record one completed task's end-to-end latency against its
    /// class target. Returns `true` if the observation breached the
    /// target (burning error budget) so the caller can emit a breach
    /// event.
    pub fn note_latency(&mut self, slo: SloClass, latency_s: f64) -> bool {
        let cell = self.slo.entry(slo).or_default();
        cell.tasks += 1;
        cell.latency_sum_s += latency_s;
        cell.max_latency_s = cell.max_latency_s.max(latency_s);
        let breached = latency_s > slo.latency_target_s();
        if breached {
            cell.breaches += 1;
        }
        breached
    }

    /// Current burn rate for one class (0.0 before any observation).
    pub fn burn_rate(&self, slo: SloClass) -> f64 {
        self.slo.get(&slo).map(ClassSlo::burn_rate).unwrap_or(0.0)
    }

    pub fn slo(&self) -> &BTreeMap<SloClass, ClassSlo> {
        &self.slo
    }

    /// Attribute one wave's wall clock to its tenants by pair share.
    pub fn note_wave_makespan(&mut self, shares: &[(u32, SloClass, f64)], wall_s: f64) {
        let total: f64 = shares.iter().map(|&(_, _, p)| p).sum();
        if total <= 0.0 {
            return;
        }
        for &(tenant, slo, pairs) in shares {
            self.row(tenant, slo).makespan_s += wall_s * pairs / total;
        }
    }

    pub fn tenants(&self) -> &BTreeMap<u32, TenantAccount> {
        &self.tenants
    }

    pub fn pool(&self) -> &PoolTotals {
        &self.pool
    }

    /// Audit: per-tenant rows must sum exactly to the pool totals, and
    /// no tenant may have completed more than it admitted. Returns a
    /// human-readable description per violated invariant.
    pub fn conservation_errors(&self) -> Vec<String> {
        let mut errs = Vec::new();
        let mut sum = PoolTotals::default();
        for (id, row) in &self.tenants {
            sum.arrived += row.arrived;
            sum.admitted += row.admitted;
            sum.completed += row.completed;
            sum.rejected += row.rejected;
            sum.bytes += row.bytes;
            sum.flops += row.flops;
            sum.redispatched += row.redispatched;
            if row.completed > row.admitted {
                errs.push(format!(
                    "tenant {id}: completed {} > admitted {}",
                    row.completed, row.admitted
                ));
            }
            if row.admitted + row.rejected > row.arrived {
                errs.push(format!(
                    "tenant {id}: admitted {} + rejected {} > arrived {}",
                    row.admitted, row.rejected, row.arrived
                ));
            }
        }
        let checks: [(&str, usize, usize); 5] = [
            ("arrived", sum.arrived, self.pool.arrived),
            ("admitted", sum.admitted, self.pool.admitted),
            ("completed", sum.completed, self.pool.completed),
            ("rejected", sum.rejected, self.pool.rejected),
            ("redispatched", sum.redispatched, self.pool.redispatched),
        ];
        for (name, rows, pool) in checks {
            if rows != pool {
                errs.push(format!("{name}: tenant rows sum to {rows} but pool total is {pool}"));
            }
        }
        // Bytes/FLOPs accumulate in the same order on both sides
        // (f64 addition per admit), so equality is still exact.
        if sum.bytes.to_bits() != self.pool.bytes.to_bits() {
            errs.push(format!(
                "bytes: tenant rows sum to {} but pool total is {}",
                sum.bytes, self.pool.bytes
            ));
        }
        if sum.flops.to_bits() != self.pool.flops.to_bits() {
            errs.push(format!(
                "flops: tenant rows sum to {} but pool total is {}",
                sum.flops, self.pool.flops
            ));
        }
        errs
    }

    /// One JSONL row per tenant (streamed to `--accounting-out`).
    pub fn tenant_rows(&self) -> Vec<Json> {
        self.tenants
            .iter()
            .map(|(id, row)| {
                let mean_wait = if row.admitted > 0 {
                    row.wait_waves_sum as f64 / row.admitted as f64
                } else {
                    0.0
                };
                Json::obj(vec![
                    ("kind", Json::Str("tenant".into())),
                    ("tenant", Json::Num(*id as f64)),
                    (
                        "slo",
                        Json::Str(row.slo.map(|s| s.name()).unwrap_or("unknown").into()),
                    ),
                    ("arrived", Json::Num(row.arrived as f64)),
                    ("admitted", Json::Num(row.admitted as f64)),
                    ("completed", Json::Num(row.completed as f64)),
                    ("rejected", Json::Num(row.rejected as f64)),
                    ("bytes", Json::Num(row.bytes)),
                    ("flops", Json::Num(row.flops)),
                    ("mean_wait_waves", Json::Num(mean_wait)),
                    ("max_wait_waves", Json::Num(row.max_wait_waves as f64)),
                    ("makespan_s", Json::Num(row.makespan_s)),
                    ("redispatched", Json::Num(row.redispatched as f64)),
                ])
            })
            .collect()
    }

    /// Aggregate per SLO class for the bench snapshot: tenant counts,
    /// task/byte/FLOP totals, and the class's worst queue wait.
    pub fn class_summary(&self) -> Json {
        let mut out = Vec::new();
        for class in SloClass::ALL {
            let rows: Vec<&TenantAccount> = self
                .tenants
                .values()
                .filter(|r| r.slo == Some(class))
                .collect();
            let slo = self.slo.get(&class).cloned().unwrap_or_default();
            let admitted: usize = rows.iter().map(|r| r.admitted).sum();
            let wait_sum: usize = rows.iter().map(|r| r.wait_waves_sum).sum();
            let mean_wait = if admitted > 0 {
                wait_sum as f64 / admitted as f64
            } else {
                0.0
            };
            out.push((
                class.name(),
                Json::obj(vec![
                    ("tenants", Json::Num(rows.len() as f64)),
                    ("admitted", Json::Num(admitted as f64)),
                    (
                        "completed",
                        Json::Num(rows.iter().map(|r| r.completed).sum::<usize>() as f64),
                    ),
                    ("bytes", Json::Num(rows.iter().map(|r| r.bytes).sum::<f64>())),
                    ("flops", Json::Num(rows.iter().map(|r| r.flops).sum::<f64>())),
                    ("mean_wait_waves", Json::Num(mean_wait)),
                    (
                        "max_wait_waves",
                        Json::Num(rows.iter().map(|r| r.max_wait_waves).max().unwrap_or(0) as f64),
                    ),
                    ("wait_bound_waves", Json::Num(class.wait_bound_waves() as f64)),
                    ("latency_target_s", Json::Num(class.latency_target_s())),
                    ("latency_tasks", Json::Num(slo.tasks as f64)),
                    ("latency_breaches", Json::Num(slo.breaches as f64)),
                    ("burn_rate", Json::Num(slo.burn_rate())),
                    ("mean_latency_s", Json::Num(slo.mean_latency_s())),
                    ("max_latency_s", Json::Num(slo.max_latency_s)),
                ]),
            ));
        }
        Json::obj(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_sum_to_pool_totals() {
        let mut l = Ledger::new();
        for t in 0..20u32 {
            let slo = SloClass::ALL[(t % 3) as usize];
            for _ in 0..=t {
                l.note_arrival(t, slo);
            }
            for s in 0..t as usize {
                l.note_admit(t, slo, 64.0, 1e6, s % 5);
            }
            for _ in 0..t as usize / 2 {
                l.note_complete(t, slo);
            }
            if t % 4 == 0 {
                l.note_rejected(t, slo);
            }
        }
        assert!(l.conservation_errors().is_empty(), "{:?}", l.conservation_errors());
    }

    #[test]
    fn imbalance_is_detected() {
        let mut l = Ledger::new();
        l.note_arrival(1, SloClass::Standard);
        l.note_admit(1, SloClass::Standard, 10.0, 1.0, 0);
        // Complete a task under a tenant that never admitted one: both
        // the per-tenant invariant and the completed-sum check fire.
        l.note_complete(2, SloClass::Batch);
        l.note_complete(2, SloClass::Batch);
        let errs = l.conservation_errors();
        assert!(errs.iter().any(|e| e.contains("tenant 2")), "{errs:?}");
    }

    #[test]
    fn latency_breaches_burn_the_class_budget() {
        let mut l = Ledger::new();
        // 99 in-target observations, one breach: exactly the 1% budget.
        for _ in 0..99 {
            assert!(!l.note_latency(SloClass::Interactive, 0.5));
        }
        assert!(l.note_latency(SloClass::Interactive, 2.0));
        let cell = &l.slo()[&SloClass::Interactive];
        assert_eq!((cell.tasks, cell.breaches), (100, 1));
        assert!((l.burn_rate(SloClass::Interactive) - 1.0).abs() < 1e-12);
        assert!((cell.max_latency_s - 2.0).abs() < 1e-12);
        // Untouched classes report zero burn, and the summary carries
        // the new keys.
        assert_eq!(l.burn_rate(SloClass::Batch), 0.0);
        let summary = l.class_summary().to_string_compact();
        for key in ["burn_rate", "latency_breaches", "latency_target_s"] {
            assert!(summary.contains(key), "missing {key} in {summary}");
        }
    }

    #[test]
    fn makespan_attribution_follows_pair_share() {
        let mut l = Ledger::new();
        l.note_wave_makespan(
            &[(0, SloClass::Standard, 75.0), (1, SloClass::Batch, 25.0)],
            2.0,
        );
        let t0 = l.tenants().get(&0).unwrap().makespan_s;
        let t1 = l.tenants().get(&1).unwrap().makespan_s;
        assert!((t0 - 1.5).abs() < 1e-12 && (t1 - 0.5).abs() < 1e-12, "{t0} {t1}");
    }
}
