//! Synthetic tenant population: seeded per-tenant streams of doc
//! batches, each with its own context-length distribution, arrival
//! rate, and SLO class, modulated by a shared diurnal load curve.
//!
//! Tenants are *specifications*, not state: everything a tenant ever
//! emits is a deterministic function of `(gateway seed, tenant id,
//! per-tenant sequence number)`, so a soak with 10k+ tenants carries no
//! per-tenant tensor state — queued work is described by `(tenant,
//! seq, len)` and the tensors are re-derived at dispatch (and again by
//! the per-tenant oracle check, which is what makes the bit-exactness
//! comparison meaningful end to end).

use crate::util::rng::Rng;

/// Service class: sets the tenant's weighted-fair-queueing weight and
/// the queue-wait bound the soak holds it to (in waves). Interactive
/// tenants get 4× the scheduling share of batch tenants and a 8× tighter
/// wait bound.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloClass {
    /// Latency-sensitive: highest WFQ weight, tightest wait bound.
    Interactive,
    /// Default class.
    Standard,
    /// Throughput-oriented: lowest weight, loosest bound.
    Batch,
}

impl SloClass {
    /// WFQ weight: scheduling share relative to other backlogged
    /// tenants.
    pub fn weight(self) -> f64 {
        match self {
            SloClass::Interactive => 4.0,
            SloClass::Standard => 2.0,
            SloClass::Batch => 1.0,
        }
    }

    /// Queue-wait bound in waves: the soak reports a starvation breach
    /// for any tenant of this class whose max admit-wait exceeds it.
    pub fn wait_bound_waves(self) -> usize {
        match self {
            SloClass::Interactive => 8,
            SloClass::Standard => 24,
            SloClass::Batch => 64,
        }
    }

    /// End-to-end latency target (enqueue → verified completion),
    /// testbed-scaled: a completed task slower than this burns its
    /// class's error budget ([`super::accounting::SLO_BUDGET`]). The
    /// ratios mirror the wait bounds (8/24/64 waves → 1/3/8 seconds).
    pub fn latency_target_s(self) -> f64 {
        match self {
            SloClass::Interactive => 1.0,
            SloClass::Standard => 3.0,
            SloClass::Batch => 8.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Standard => "standard",
            SloClass::Batch => "batch",
        }
    }

    pub const ALL: [SloClass; 3] = [SloClass::Interactive, SloClass::Standard, SloClass::Batch];
}

/// One synthetic tenant: a seeded stream specification.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub id: u32,
    pub slo: SloClass,
    /// Mean doc arrivals per wave at diurnal factor 1.0.
    pub rate: f64,
    /// Lognormal context-length parameters (of the underlying normal):
    /// each tenant has its *own* length distribution — the cross-tenant
    /// mix is what the fused waves rebatch.
    pub len_mu: f64,
    pub len_sigma: f64,
    /// Diurnal phase offset: tenants peak at different times of "day".
    pub phase: f64,
    /// Per-tenant arrival-stream seed (forked from the gateway seed).
    pub seed: u64,
}

/// Clamp bounds for sampled per-doc context lengths (kernel units: the
/// oracle is O(len²), and the wire ships `len·(h + 2·hkv)·d` floats).
pub const MIN_LEN: usize = 4;
pub const MAX_LEN: usize = 96;

/// Build a seeded tenant population whose rates sum to `total_rate`
/// (mean pool-wide arrivals per wave at diurnal factor 1.0). Rate
/// shares are Pareto-skewed — a few heavy tenants, a long tail of
/// light ones — and SLO classes are drawn 20/50/30.
pub fn synth_tenants(n: usize, total_rate: f64, rng: &mut Rng) -> Vec<TenantSpec> {
    assert!(n >= 1, "need at least one tenant");
    assert!(
        n as u32 <= crate::server::MAX_TENANTS,
        "{n} tenants exceeds the {}-tenant id space",
        crate::server::MAX_TENANTS
    );
    let shares: Vec<f64> = (0..n).map(|_| rng.gen_pareto(1.0, 1.5)).collect();
    let share_sum: f64 = shares.iter().sum();
    let class_weights = [0.2, 0.5, 0.3];
    (0..n)
        .map(|i| TenantSpec {
            id: i as u32,
            slo: SloClass::ALL[rng.choose_weighted(&class_weights)],
            rate: total_rate * shares[i] / share_sum,
            len_mu: rng.gen_f64(2.2, 3.6),   // median length ~9..37
            len_sigma: rng.gen_f64(0.2, 0.8),
            phase: rng.gen_f64(0.0, 2.0 * std::f64::consts::PI),
            seed: rng.fork().next_u64(),
        })
        .collect()
}

/// Diurnal load multiplier at `wave` for a cycle of `period` waves:
/// `1 + 0.8·sin(2π·wave/period + phase)`, in `[0.2, 1.8]`. `period <=
/// 0` disables modulation.
pub fn diurnal_factor(wave: usize, period: f64, phase: f64) -> f64 {
    if period <= 0.0 {
        return 1.0;
    }
    1.0 + 0.8 * (2.0 * std::f64::consts::PI * wave as f64 / period + phase).sin()
}

/// Seeded Poisson sample (Knuth): the number of docs a tenant emits in
/// one wave at mean `lambda`. Exact for the small per-tenant rates a
/// 10k-tenant soak runs at.
pub fn poisson(rng: &mut Rng, lambda: f64) -> usize {
    if lambda <= 0.0 {
        return 0;
    }
    // For large lambda the product-of-uniforms loop underflows; split
    // off deterministic bulk via the additivity of Poisson.
    if lambda > 30.0 {
        return poisson(rng, lambda / 2.0) + poisson(rng, lambda - lambda / 2.0);
    }
    let limit = (-lambda).exp();
    let mut k = 0usize;
    let mut p = 1.0f64;
    loop {
        p *= rng.next_f64();
        if p <= limit {
            return k;
        }
        k += 1;
    }
}

/// Sample one doc's context length from the tenant's distribution.
pub fn sample_len(spec: &TenantSpec, rng: &mut Rng) -> usize {
    (spec.len_mu + spec.len_sigma * rng.gen_normal()).exp().round() as usize
}

/// Clamped kernel-unit length.
pub fn clamp_len(len: usize) -> usize {
    len.clamp(MIN_LEN, MAX_LEN)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_population_is_seed_deterministic() {
        let a = synth_tenants(64, 12.0, &mut Rng::new(7));
        let b = synth_tenants(64, 12.0, &mut Rng::new(7));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.slo, y.slo);
            assert_eq!(x.rate.to_bits(), y.rate.to_bits());
            assert_eq!(x.seed, y.seed);
        }
        let total: f64 = a.iter().map(|t| t.rate).sum();
        assert!((total - 12.0).abs() < 1e-9, "rates sum to the pool rate, got {total}");
    }

    #[test]
    fn diurnal_factor_stays_positive_and_cycles() {
        for w in 0..200 {
            let f = diurnal_factor(w, 24.0, 1.0);
            assert!((0.19..=1.81).contains(&f), "wave {w}: {f}");
        }
        assert_eq!(diurnal_factor(5, 0.0, 1.0), 1.0);
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut rng = Rng::new(3);
        for &lam in &[0.3, 2.0, 50.0] {
            let n = 4000;
            let total: usize = (0..n).map(|_| poisson(&mut rng, lam)).sum();
            let mean = total as f64 / n as f64;
            assert!(
                (mean - lam).abs() < 0.15 * lam.max(1.0),
                "lambda {lam}: sample mean {mean}"
            );
        }
    }
}
