//! Multi-tenant serving gateway: continuous cross-tenant batching into
//! the shared attention-server pool.
//!
//! CA-tasks are pure and composable (§4.1): a fused wave may mix tasks
//! from *any* set of documents — and therefore any set of tenants —
//! without changing a single output bit. This module exploits that to
//! put one shared elastic pool behind many tenants:
//!
//! ```text
//!  tenant streams          gateway                      shared pool
//!  ─────────────   ──────────────────────────   ──────────────────────
//!  t0 ─ docs ──▶ ┌─────────┐   ┌───────────┐    ┌────────────────────┐
//!  t1 ─ docs ──▶ │ per-    │   │ admission │    │ ElasticCoordinator │
//!  t2 ─ docs ──▶ │ tenant  ├──▶│ (pair +   ├──▶ │  dispatch/gather   │
//!   ⋮            │ WFQ     │   │  byte     │    │  failover, dedup   │
//!  tN ─ docs ──▶ │ queues  │   │  budgets) │    │  (tenant-blind)    │
//!                └─────────┘   └───────────┘    └────────────────────┘
//!                 SCFQ stamps   strict order     fused cross-tenant
//!                 weight = SLO  stop-at-first-   wave, tenant id in
//!                               non-fit          every task's doc bits
//! ```
//!
//! * [`tenant`] — seeded synthetic tenant populations: per-tenant
//!   context-length distributions, Poisson arrival rates under a
//!   diurnal curve, SLO classes;
//! * [`queue`] — self-clocked weighted-fair queueing across per-tenant
//!   queues (starvation-free by construction);
//! * [`admission`] — backpressure: a wave admits in WFQ order until the
//!   pool's believed pair/byte capacity
//!   ([`PoolCapacity`](crate::coordinator::PoolCapacity)) is spent;
//! * [`accounting`] — the double-entry per-tenant ledger (tasks, bytes,
//!   FLOPs, queue-wait, makespan share) streamed to `--accounting-out`
//!   JSONL and aggregated into `BENCH_gateway.json`.
//!
//! The elastic layer stays **tenant-blind**: tenancy rides in the doc
//! id ([`crate::server::tenant_doc`], echoed in every tag), so
//! first-response-wins dedup, cancel, and re-dispatch are per-tenant-
//! correct with zero changes to dispatch/gather — and the wire codec
//! surfaces the same id in the frame header for observability
//! ([`crate::net::codec`]). Every gathered output is verified bit-exact
//! against the per-tenant GQA oracle: fused cross-tenant batching must
//! be invisible in the outputs.

pub mod accounting;
pub mod admission;
pub mod queue;
pub mod tenant;

pub use accounting::{Ledger, PoolTotals, TenantAccount};
pub use admission::{Admission, AdmitStats, WaveBudget};
pub use queue::{QueuedTask, WfqQueue};
pub use tenant::{SloClass, TenantSpec};

use std::io::Write;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{PoolCapacity, ServerBelief};
use crate::elastic::{
    ElasticCfg, ElasticCoordinator, ElasticTask, FaultEvent, FaultPlan, ReferenceCaCompute,
    ServerState,
};
use crate::exchange::transport::Transport;
use crate::net::serve::{
    connect_and_config, drain_events, split_fault_plan, wait_hello, WorkerProcs,
};
use crate::net::{NetEvent, TcpTransport, NET_DIMS};
use crate::obs::export::MetricsHub;
use crate::runtime::ca_exec::synthetic_task;
use crate::server::{tenant_doc, MAX_TENANT_SEQ};
use crate::util::json::Json;
use crate::util::rng::Rng;

use accounting::task_flops;
use tenant::{clamp_len, diurnal_factor, poisson, sample_len, synth_tenants};

/// Everything a gateway run needs.
#[derive(Debug, Clone)]
pub struct GatewayCfg {
    /// Synthetic tenant population size.
    pub tenants: usize,
    /// Shared pool size.
    pub workers: usize,
    /// Arrival waves; the run then drains the backlog (bounded by
    /// [`GatewayCfg::max_drain_waves`]).
    pub waves: usize,
    /// Pool-wide mean doc arrivals per wave at diurnal factor 1.0,
    /// Pareto-split across tenants.
    pub arrival_rate: f64,
    pub seed: u64,
    /// Scripted faults, indexed by *dispatched-wave* number. Networked
    /// mode executes kills/rejoins at the process level (SIGKILL /
    /// respawn); everything else goes in-band through the elastic tick.
    pub fault: FaultPlan,
    /// Networked mode: spawn `distca worker` child processes.
    pub spawn: bool,
    /// Networked mode: dial externally started daemons (len == workers).
    pub connect: Vec<String>,
    /// Diurnal cycle length in waves (≤ 0 disables modulation).
    pub diurnal_period: f64,
    /// Believed causal-pair work one nominal server completes per wave
    /// (the supply half of admission).
    pub pairs_per_server: f64,
    /// Per-server transient-arena byte budget (0 = bytes unbounded) —
    /// the [`crate::memplan`] §5 budget role, applied at admission.
    pub arena_per_server: f64,
    /// Fraction of the arena budget admission may fill (< 1 keeps
    /// headroom for recovery re-sends).
    pub fill: f64,
    /// Per-tenant accounting JSONL sink.
    pub accounting_out: Option<PathBuf>,
    /// Summary JSON (`BENCH_gateway.json`).
    pub bench_out: Option<PathBuf>,
    /// Safety cap on post-arrival drain waves.
    pub max_drain_waves: usize,
    /// Live Prometheus-text metrics endpoint (`--metrics-listen`).
    pub metrics_listen: Option<String>,
}

impl Default for GatewayCfg {
    fn default() -> GatewayCfg {
        GatewayCfg {
            tenants: 32,
            workers: 4,
            waves: 8,
            arrival_rate: 48.0,
            seed: 42,
            fault: FaultPlan::new(),
            spawn: false,
            connect: Vec::new(),
            diurnal_period: 24.0,
            pairs_per_server: 40_000.0,
            arena_per_server: 4.0 * 1024.0 * 1024.0,
            fill: 0.8,
            accounting_out: None,
            bench_out: None,
            max_drain_waves: 10_000,
            metrics_listen: None,
        }
    }
}

/// One wave's gateway-level accounting row.
#[derive(Debug, Clone)]
pub struct GatewayWaveRecord {
    pub wave: usize,
    /// Docs emitted by the arrival processes this wave.
    pub arrivals: usize,
    /// Tasks folded into this wave's fused batch.
    pub admitted: usize,
    /// Queue depth after admission closed (backpressure signal).
    pub backlog: usize,
    /// Tenants with work in this wave's fused batch.
    pub wave_tenants: usize,
    /// Whether admission closed on budget (vs the queue running dry).
    pub saturated: bool,
    pub admitted_pairs: f64,
    pub admitted_bytes: f64,
    pub n_alive: usize,
    /// Elastic-layer recovery re-sends within the wave.
    pub redispatched: usize,
    /// Wall-clock seconds of the dispatched wave (0 for skipped waves).
    pub elapsed: f64,
}

impl GatewayWaveRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("wave".into())),
            ("wave", Json::Num(self.wave as f64)),
            ("arrivals", Json::Num(self.arrivals as f64)),
            ("admitted", Json::Num(self.admitted as f64)),
            ("backlog", Json::Num(self.backlog as f64)),
            ("wave_tenants", Json::Num(self.wave_tenants as f64)),
            ("saturated", Json::Bool(self.saturated)),
            ("admitted_pairs", Json::Num(self.admitted_pairs)),
            ("admitted_bytes", Json::Num(self.admitted_bytes)),
            ("alive", Json::Num(self.n_alive as f64)),
            ("redispatched", Json::Num(self.redispatched as f64)),
            ("elapsed_s", Json::Num(self.elapsed)),
        ])
    }
}

/// A tenant whose worst queue wait exceeded its SLO class bound.
#[derive(Debug, Clone)]
pub struct StarvationBreach {
    pub tenant: u32,
    pub slo: SloClass,
    pub max_wait_waves: usize,
    pub bound_waves: usize,
}

/// Outcome of a gateway run. Construction implies every output of
/// every wave verified bit-exact against its tenant's oracle and the
/// ledger passed its conservation audit.
#[derive(Debug)]
pub struct GatewayReport {
    pub tenants: usize,
    pub workers: usize,
    pub arrival_waves: usize,
    /// Arrival waves + drain waves actually run.
    pub total_waves: usize,
    /// Waves that dispatched a non-empty fused batch.
    pub dispatched_waves: usize,
    pub seed: u64,
    pub per_wave: Vec<GatewayWaveRecord>,
    pub ledger: Ledger,
    /// Oversize docs refused at enqueue (whole-wave-budget misfits).
    pub rejected_oversize: usize,
    /// Tenants whose max queue wait broke their SLO bound (a clean
    /// soak has none).
    pub starvation_breaches: Vec<StarvationBreach>,
    /// Deepest backlog any wave closed with.
    pub max_backlog: usize,
    /// Waves closed by budget rather than an empty queue.
    pub saturated_waves: usize,
    /// Minimum-progress overrides (head force-popped after capacity
    /// loss shrank the budget below it).
    pub forced_admissions: usize,
}

impl GatewayReport {
    /// The `BENCH_gateway.json` shape: pool-level totals, per-SLO-class
    /// aggregates, and queueing summary — no per-wave array (the JSONL
    /// stream carries the per-wave rows; the bench stays drift-friendly).
    pub fn to_json(&self) -> Json {
        let p = self.ledger.pool();
        Json::obj(vec![
            ("bench", Json::Str("gateway_soak".into())),
            ("tenants", Json::Num(self.tenants as f64)),
            ("workers", Json::Num(self.workers as f64)),
            ("arrival_waves", Json::Num(self.arrival_waves as f64)),
            ("total_waves", Json::Num(self.total_waves as f64)),
            ("dispatched_waves", Json::Num(self.dispatched_waves as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("bit_exact", Json::Bool(true)),
            ("conservation_ok", Json::Bool(true)),
            (
                "pool",
                Json::obj(vec![
                    ("arrived", Json::Num(p.arrived as f64)),
                    ("admitted", Json::Num(p.admitted as f64)),
                    ("completed", Json::Num(p.completed as f64)),
                    ("rejected", Json::Num(p.rejected as f64)),
                    ("bytes", Json::Num(p.bytes)),
                    ("flops", Json::Num(p.flops)),
                    ("redispatched", Json::Num(p.redispatched as f64)),
                ]),
            ),
            ("classes", self.ledger.class_summary()),
            ("starvation_breaches", Json::Num(self.starvation_breaches.len() as f64)),
            ("max_backlog", Json::Num(self.max_backlog as f64)),
            ("saturated_waves", Json::Num(self.saturated_waves as f64)),
            ("forced_admissions", Json::Num(self.forced_admissions as f64)),
            ("rejected_oversize", Json::Num(self.rejected_oversize as f64)),
        ])
    }
}

/// The pool backend: in-process worker threads, or worker processes
/// over TCP (the [`crate::net`] runtime).
enum Backend {
    InProcess,
    Net { fabric: Arc<TcpTransport>, procs: WorkerProcs, pending: Vec<NetEvent> },
}

/// Derive this wave's admission budget from the pool's live beliefs:
/// believed speeds aggregate into pair capacity, per-server arena
/// budgets into byte capacity.
fn live_budget(co: &ElasticCoordinator, cfg: &GatewayCfg) -> Option<WaveBudget> {
    let alive = co.pool.schedulable();
    if alive.is_empty() {
        return None;
    }
    let view = co.pool.view();
    let speeds = co.pool.believed_speeds(&view);
    let beliefs = ServerBelief::from_speeds(&speeds, cfg.arena_per_server);
    let cap = PoolCapacity::from_beliefs(&beliefs, cfg.arena_per_server);
    Some(WaveBudget::new(
        cap.pair_budget(1.0, cfg.pairs_per_server),
        cap.byte_budget(cfg.fill),
    ))
}

/// Wire bytes of one task's f32 Q+K+V at the gateway dims.
fn task_bytes(len: usize) -> f64 {
    let (h, hkv, d) = NET_DIMS;
    (len * (h + 2 * hkv) * d * 4) as f64
}

/// Deterministic per-doc tensor stream: a fresh generator keyed by the
/// tenant's seed and the doc's *full* sequence number. Queued work
/// carries only `(tenant, seq, len)` — the tensors are re-derived here
/// at dispatch, which is what keeps a 10k-tenant backlog byte-cheap
/// and the per-tenant oracle comparison exact.
fn doc_rng(spec: &TenantSpec, seq: u32) -> Rng {
    Rng::new(spec.seed ^ (seq as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Run a gateway session. Returns only if every wave's outputs were
/// bit-exact per tenant, the ledger's per-tenant rows summed exactly to
/// the pool totals, and (networked mode) shutdown leaked nothing.
pub fn run_gateway(cfg: &GatewayCfg) -> Result<GatewayReport> {
    let n = cfg.workers;
    let (h, hkv, d) = NET_DIMS;
    anyhow::ensure!(n >= 2, "need at least 2 workers");
    anyhow::ensure!(cfg.waves >= 1, "need at least 1 arrival wave");
    anyhow::ensure!(cfg.tenants >= 1, "need at least 1 tenant");
    anyhow::ensure!(cfg.fill > 0.0 && cfg.fill <= 1.0, "--fill must be in (0, 1]");
    let networked = cfg.spawn || !cfg.connect.is_empty();
    anyhow::ensure!(
        !(cfg.spawn && !cfg.connect.is_empty()),
        "pass at most one of --spawn and --connect a,b,c"
    );
    anyhow::ensure!(
        cfg.spawn
            || !networked
            || !cfg.fault.events.iter().any(|e| matches!(e, FaultEvent::Rejoin { .. })),
        "scripted rejoin: requires --spawn (a remote daemon cannot be respawned)"
    );

    // Pool backend + coordinator. In-process mode runs the whole fault
    // plan in-band (the threaded runtime models kills itself);
    // networked mode executes kills/rejoins at the process level.
    let (mut backend, mut co, process_plan, inband) = if networked {
        let fabric = TcpTransport::coordinator(n);
        let mut procs = WorkerProcs::start(cfg.spawn, n, &cfg.connect)?;
        for rank in 0..n {
            connect_and_config(&fabric, rank, n, procs.addr(rank), Duration::ZERO)?;
        }
        let mut pending: Vec<NetEvent> = Vec::new();
        for rank in 0..n {
            wait_hello(&fabric, rank, &mut pending, Duration::from_secs(10))?;
        }
        let dyn_fabric: Arc<dyn Transport> = Arc::clone(&fabric) as Arc<dyn Transport>;
        let co = ElasticCoordinator::over_transport(dyn_fabric, n, ElasticCfg::default());
        let (process_plan, inband) = split_fault_plan(&cfg.fault);
        (Backend::Net { fabric, procs, pending }, co, process_plan, inband)
    } else {
        let co =
            ElasticCoordinator::spawn(n, ElasticCfg::default(), |_| {
                crate::kernel::compute_from_env(h, hkv, d)
            });
        (Backend::InProcess, co, FaultPlan::new(), cfg.fault.clone())
    };
    let oracle = ReferenceCaCompute::new(h, hkv, d);

    // The tenant population and its per-stream arrival generators.
    let mut pop_rng = Rng::new(cfg.seed);
    let specs = synth_tenants(cfg.tenants, cfg.arrival_rate, &mut pop_rng);
    let mut arrival_rngs: Vec<Rng> = specs.iter().map(|s| Rng::new(s.seed)).collect();
    let mut seqs: Vec<u32> = vec![0; cfg.tenants];

    let initial = live_budget(&co, cfg).context("pool has no live workers at start")?;
    let mut adm = Admission::new(initial);
    let mut ledger = Ledger::new();
    let mut per_wave: Vec<GatewayWaveRecord> = Vec::new();
    let mut acct_file = match &cfg.accounting_out {
        Some(p) => Some(std::io::BufWriter::new(
            std::fs::File::create(p).with_context(|| format!("creating {}", p.display()))?,
        )),
        None => None,
    };

    // Live metrics: the gateway runs no recorder, so it feeds the hub
    // directly (task latency, queue delay, breach counters, burn-rate
    // gauges).
    let hub = match &cfg.metrics_listen {
        Some(addr) => {
            let hub = MetricsHub::new();
            let bound = hub.serve(addr)?;
            println!("metrics: http://{bound}/metrics");
            Some(hub)
        }
        None => None,
    };

    let mut dispatch_tick = 0usize; // fault-plan clock: dispatched waves only
    let mut forced_admissions = 0usize;
    let mut wave = 0usize;
    // Wall-clock start of each wave, indexed by wave number: a task's
    // end-to-end latency is measured from the start of the wave it was
    // *enqueued* in (the SLO clock starts at arrival, not admission).
    let mut wave_started: Vec<Instant> = Vec::new();
    loop {
        wave_started.push(Instant::now());
        let arriving = wave < cfg.waves;
        if !arriving && adm.queue().is_empty() {
            break;
        }
        anyhow::ensure!(
            wave < cfg.waves + cfg.max_drain_waves,
            "backlog failed to drain within {} extra waves ({} tasks left)",
            cfg.max_drain_waves,
            adm.queue().len()
        );

        // Connection evidence → membership (networked only; cheap).
        if let Backend::Net { fabric, pending, .. } = &mut backend {
            drain_events(fabric, pending);
            for ev in pending.drain(..) {
                match ev {
                    NetEvent::Disconnected { rank } => {
                        if rank < n && co.pool.is_schedulable(rank) {
                            co.pool.kill(rank);
                            co.health.mark_dead(rank);
                        }
                    }
                    NetEvent::Hello { rank } => {
                        if rank < n && co.pool.state(rank) == ServerState::Dead {
                            co.pool.restore(rank);
                            co.health.reset(rank);
                        }
                    }
                    // Heartbeats are disabled (interval zero) and the
                    // gateway runs no recorder; drains are honored as a
                    // plain in-band graceful leave at the next tick.
                    _ => {}
                }
            }
        }

        // 1. Arrivals: each tenant's Poisson stream under its diurnal
        // phase, enqueued under its SLO weight (or refused if the doc
        // could never fit a whole wave).
        let mut arrivals = 0usize;
        if arriving {
            for (t, spec) in specs.iter().enumerate() {
                let lambda = spec.rate * diurnal_factor(wave, cfg.diurnal_period, spec.phase);
                let k = poisson(&mut arrival_rngs[t], lambda);
                for _ in 0..k {
                    arrivals += 1;
                    ledger.note_arrival(spec.id, spec.slo);
                    let len = clamp_len(sample_len(spec, &mut arrival_rngs[t]));
                    let task =
                        QueuedTask::new(spec.id, seqs[t], len, wave, task_bytes(len));
                    seqs[t] = seqs[t].wrapping_add(1);
                    if !adm.push(task, spec.slo) {
                        ledger.note_rejected(spec.id, spec.slo);
                    }
                }
            }
        }

        // 2. Admission against the pool's *current* believed capacity.
        let alive = co.pool.schedulable();
        anyhow::ensure!(!alive.is_empty(), "wave {wave}: no live workers");
        if let Some(b) = live_budget(&co, cfg) {
            adm.set_budget(b);
        }
        let (mut admitted, mut stats) = adm.admit_wave();
        if admitted.is_empty() && !adm.queue().is_empty() {
            // Capacity shrank below the (legally enqueued) head task:
            // force minimum progress rather than wedging the queue.
            if let Some(head) = adm.force_pop() {
                stats.admitted_pairs += head.cost;
                stats.admitted_bytes += head.bytes;
                admitted.push(head);
                forced_admissions += 1;
            }
        }

        let mut rec = GatewayWaveRecord {
            wave,
            arrivals,
            admitted: admitted.len(),
            backlog: adm.queue().len(),
            wave_tenants: 0,
            saturated: stats.saturated,
            admitted_pairs: stats.admitted_pairs,
            admitted_bytes: stats.admitted_bytes,
            n_alive: alive.len(),
            redispatched: 0,
            elapsed: 0.0,
        };

        if !admitted.is_empty() {
            // 3. Scripted faults, keyed on the dispatch clock. Process
            // kills/rejoins first (networked), in-band events ride into
            // run_tick below.
            if let Backend::Net { fabric, procs, pending } = &mut backend {
                for ev in process_plan.events_at(dispatch_tick) {
                    match ev {
                        FaultEvent::Kill { server, .. } if server < n => {
                            procs.kill(server, fabric);
                        }
                        FaultEvent::Rejoin { server, .. } if server < n => {
                            procs.respawn(server)?;
                            connect_and_config(
                                fabric,
                                server,
                                n,
                                procs.addr(server),
                                Duration::ZERO,
                            )?;
                            wait_hello(fabric, server, pending, Duration::from_secs(10))?;
                            pending.retain(|e| {
                                !matches!(e, NetEvent::Disconnected { rank } if *rank == server)
                            });
                            co.pool.restore(server);
                            co.health.reset(server);
                        }
                        _ => {}
                    }
                }
            }

            // 4. Materialize the fused cross-tenant wave: tenant id in
            // the doc bits (surviving the wire round-trip in every
            // tag), tensors re-derived from the per-doc seed chain.
            let mut tasks = Vec::with_capacity(admitted.len());
            let mut shares: Vec<(u32, SloClass, f64)> = Vec::new();
            let mut wave_tenants = std::collections::BTreeSet::new();
            for (i, qt) in admitted.iter().enumerate() {
                let spec = &specs[qt.tenant as usize];
                let mut trng = doc_rng(spec, qt.seq);
                let server = alive[i % alive.len()];
                tasks.push(ElasticTask {
                    doc: tenant_doc(qt.tenant, qt.seq % MAX_TENANT_SEQ),
                    q_start: 0,
                    server,
                    home: server,
                    tensors: synthetic_task(&mut trng, qt.len, qt.len, h, hkv, d),
                });
                ledger.note_admit(
                    qt.tenant,
                    spec.slo,
                    qt.bytes,
                    task_flops(qt.len, h, d),
                    wave - qt.enqueued_wave,
                );
                if let Some(hub) = &hub {
                    hub.observe(
                        &format!("distca_queue_delay_waves|class={}", spec.slo.name()),
                        (wave - qt.enqueued_wave) as f64,
                    );
                }
                shares.push((qt.tenant, spec.slo, qt.cost));
                wave_tenants.insert(qt.tenant);
            }
            rec.wave_tenants = wave_tenants.len();

            // 5. One elastic tick over the shared pool, tenant-blind.
            let outputs = co.run_tick(dispatch_tick, &tasks, &inband)?;
            dispatch_tick += 1;

            // 6. Per-tenant bit-exactness: each output must equal its
            // tenant's own oracle result, regardless of which server
            // computed it or how many times it was re-dispatched.
            anyhow::ensure!(
                outputs.len() == tasks.len(),
                "wave {wave}: gathered {} of {} outputs",
                outputs.len(),
                tasks.len()
            );
            for out in &outputs {
                let (i, task) = tasks
                    .iter()
                    .enumerate()
                    .find(|(_, t)| t.doc == out.doc && t.q_start == out.q_start)
                    .ok_or_else(|| {
                        anyhow::anyhow!("wave {wave}: unknown output doc {}", out.doc)
                    })?;
                let expect = oracle.run_batch(std::slice::from_ref(&task.tensors));
                let qt = &admitted[i];
                anyhow::ensure!(
                    out.o == expect[0],
                    "wave {wave} tenant {} seq {}: output diverged from the tenant's oracle",
                    qt.tenant,
                    qt.seq
                );
                let slo = specs[qt.tenant as usize].slo;
                ledger.note_complete(qt.tenant, slo);

                // End-to-end latency (enqueue-wave start → verified
                // completion) against the class target; a breach burns
                // error budget and emits an observable event.
                let latency_s = wave_started[qt.enqueued_wave].elapsed().as_secs_f64();
                let breached = ledger.note_latency(slo, latency_s);
                if let Some(hub) = &hub {
                    hub.observe("distca_task_latency_seconds", latency_s);
                    hub.observe(
                        &format!("distca_task_latency_seconds|tenant={}", qt.tenant),
                        latency_s,
                    );
                    if breached {
                        hub.add(&format!("distca_slo_breach_total|class={}", slo.name()), 1.0);
                    }
                }
                if breached {
                    if let Some(f) = acct_file.as_mut() {
                        let row = Json::obj(vec![
                            ("kind", Json::Str("breach".into())),
                            ("wave", Json::Num(wave as f64)),
                            ("tenant", Json::Num(qt.tenant as f64)),
                            ("slo", Json::Str(slo.name().into())),
                            ("latency_s", Json::Num(latency_s)),
                            ("target_s", Json::Num(slo.latency_target_s())),
                        ]);
                        writeln!(f, "{}", row.to_string_compact())
                            .context("writing --accounting-out breach row")?;
                    }
                }
            }

            // 7. Fold the elastic layer's per-tenant splits back into
            // the ledger (who paid for this wave's faults) and
            // attribute the wave's wall clock by pair share.
            let st = co.stats.last().expect("run_tick records stats");
            for (&t, &k) in &st.tenant_redispatched {
                ledger.note_redispatch(t, specs[t as usize].slo, k);
            }
            ledger.note_wave_makespan(&shares, st.elapsed);
            rec.redispatched = st.redispatched + st.send_failovers + st.oom_evicted;
            rec.elapsed = st.elapsed;
        }

        if let Some(f) = acct_file.as_mut() {
            writeln!(f, "{}", rec.to_json().to_string_compact())
                .context("writing --accounting-out wave row")?;
        }
        if let Some(hub) = &hub {
            for class in SloClass::ALL {
                hub.set(
                    &format!("distca_slo_burn_rate|class={}", class.name()),
                    ledger.burn_rate(class),
                );
            }
            hub.set("distca_gateway_backlog", adm.queue().len() as f64);
        }
        per_wave.push(rec);
        wave += 1;
    }

    // Orderly shutdown before the audit: a leaked worker is a failure
    // even if the numbers balance.
    co.shutdown()?;
    if let Backend::Net { procs, .. } = &mut backend {
        procs.shutdown()?;
    }

    // Conservation audit: per-tenant rows must sum exactly to the
    // independently tracked pool totals, and — arrivals having stopped
    // before the drain — everything admitted must have completed.
    let errs = ledger.conservation_errors();
    anyhow::ensure!(
        errs.is_empty(),
        "accounting conservation violated:\n  {}",
        errs.join("\n  ")
    );
    let p = ledger.pool();
    anyhow::ensure!(
        p.completed == p.admitted,
        "drained run completed {} of {} admitted tasks",
        p.completed,
        p.admitted
    );
    anyhow::ensure!(
        p.admitted + p.rejected == p.arrived,
        "admitted {} + rejected {} != arrived {}",
        p.admitted,
        p.rejected,
        p.arrived
    );

    let starvation_breaches: Vec<StarvationBreach> = ledger
        .tenants()
        .iter()
        .filter_map(|(&tenant, row)| {
            let slo = row.slo?;
            (row.max_wait_waves > slo.wait_bound_waves()).then(|| StarvationBreach {
                tenant,
                slo,
                max_wait_waves: row.max_wait_waves,
                bound_waves: slo.wait_bound_waves(),
            })
        })
        .collect();

    // Stream the per-tenant rows, then the completion marker: a reader
    // that sees the flush record knows the file is whole.
    if let Some(f) = acct_file.as_mut() {
        for row in ledger.tenant_rows() {
            writeln!(f, "{}", row.to_string_compact())
                .context("writing --accounting-out tenant row")?;
        }
        let flush = Json::obj(vec![
            ("kind", Json::Str("flush".into())),
            ("waves", Json::Num(wave as f64)),
            ("tenants", Json::Num(ledger.tenants().len() as f64)),
        ]);
        writeln!(f, "{}", flush.to_string_compact())
            .context("writing --accounting-out flush record")?;
        f.flush().context("flushing --accounting-out")?;
    }

    let report = GatewayReport {
        tenants: cfg.tenants,
        workers: n,
        arrival_waves: cfg.waves,
        total_waves: wave,
        dispatched_waves: dispatch_tick,
        seed: cfg.seed,
        max_backlog: per_wave.iter().map(|r| r.backlog).max().unwrap_or(0),
        saturated_waves: per_wave.iter().filter(|r| r.saturated).count(),
        rejected_oversize: adm.rejected_oversize,
        per_wave,
        ledger,
        starvation_breaches,
        forced_admissions,
    };
    if let Some(path) = &cfg.bench_out {
        std::fs::write(path, report.to_json().to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))?;
    }
    Ok(report)
}
