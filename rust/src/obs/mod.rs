//! Unified tracing & metrics plane: tick-phase spans, straggler
//! attribution, and trace-file drift checks.
//!
//! Every execution path in the repo — the threaded
//! [`ElasticCoordinator`](crate::elastic::ElasticCoordinator), the
//! deterministic exec references, the discrete-event simulators, and
//! the TCP runtime (`distca worker|serve|soak`) — reports into the same
//! [`Recorder`]: typed spans ([`Phase`]) and counters keyed by
//! `(tick, wave, server, task_tag)`. The recorder supports two clock
//! sources ([`ClockSource`]): monotonic wall-clock for the threaded and
//! networked paths, and virtual sim-time for the engine-backed
//! simulators — so one exporter and one report cover all of them.
//!
//! On top of the recorder:
//!
//! * [`trace`] — a Chrome `trace_event` JSON exporter/importer
//!   (`--trace-out`, loadable in Perfetto) plus structural validation
//!   (every span nests inside its tick; `compute` never overlaps
//!   `wire_wait` on the same thread row);
//! * [`report`] — the per-tick straggler-attribution report: per-server
//!   compute vs wire-wait vs gather-idle seconds (summing to the tick
//!   wall-time by construction), max/mean imbalance, and believed-vs-
//!   observed speed divergence — the Fig. 11-style overlap table behind
//!   `distca report`;
//! * [`drift`] — schema + tolerance comparison of committed
//!   `BENCH_*.json` perf snapshots against freshly regenerated ones
//!   (`distca drift`), the repo's committed perf trajectory.
//!
//! ## The phase-accounting identity
//!
//! Per tick and per server `s`, the recorder tracks the *busy window*
//! `[first dispatch to s, last receipt from s]` and attributes:
//!
//! * `compute_s` — worker-measured per-task compute seconds (in-process
//!   servers and TCP workers both report them; see
//!   [`ComputeSink`]), clamped to the window;
//! * `wire_wait_s = window − compute_s` — serialization, transit, and
//!   queue time on the wire;
//! * `gather_idle_s = tick_s − window` — plan/dispatch lead-in plus the
//!   tail where the coordinator is gathering *other* servers.
//!
//! The three phases sum to the measured tick wall-time exactly, which
//! is what makes the per-server breakdown auditable against the tick
//! clock (the acceptance bound is ±5%; the identity gives ~0).

pub mod drift;
pub mod export;
pub mod hist;
pub mod lineage;
pub mod report;
pub mod trace;

use lineage::{LineageEvent, LineageStage, RedispatchReason};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Span taxonomy. `Tick` is the container every other span nests in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// Whole-tick container span (one per tick, coordinator row).
    Tick,
    /// Event application, gray demotion, belief-aware planning.
    Plan,
    /// Serializing + sending the wave(s) onto the fabric.
    Dispatch,
    /// A CA-task's kernel time on its server.
    Compute,
    /// Window time on a server not covered by compute: wire + queue.
    WireWait,
    /// Tick time outside a server's busy window (coordinator gathers
    /// others / plan lead-in): idle from that server's perspective.
    Gather,
    /// A task cancelled on a suspect and re-sent elsewhere.
    Redispatch,
    /// A task evicted by an arena byte-budget overflow.
    Evict,
}

impl Phase {
    /// Stable lowercase name used in trace files.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Tick => "tick",
            Phase::Plan => "plan",
            Phase::Dispatch => "dispatch",
            Phase::Compute => "compute",
            Phase::WireWait => "wire_wait",
            Phase::Gather => "gather",
            Phase::Redispatch => "redispatch",
            Phase::Evict => "evict",
        }
    }

    /// Inverse of [`Phase::name`].
    pub fn from_name(s: &str) -> Option<Phase> {
        Some(match s {
            "tick" => Phase::Tick,
            "plan" => Phase::Plan,
            "dispatch" => Phase::Dispatch,
            "compute" => Phase::Compute,
            "wire_wait" => Phase::WireWait,
            "gather" => Phase::Gather,
            "redispatch" => Phase::Redispatch,
            "evict" => Phase::Evict,
            _ => return None,
        })
    }
}

/// One typed span. Times are seconds on the recorder's clock
/// ([`ClockSource`]); `server == None` means the coordinator row.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub phase: Phase,
    pub tick: usize,
    pub wave: usize,
    pub server: Option<usize>,
    pub task_tag: Option<u64>,
    pub start_s: f64,
    pub dur_s: f64,
}

/// Which clock the recorder's timestamps come from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClockSource {
    /// Monotonic wall-clock, seconds since recorder creation (threaded
    /// coordinator, `distca worker|serve|soak`).
    Wall,
    /// Virtual sim-time, seconds since simulation start (the
    /// discrete-event engine and both elastic simulators).
    Virtual,
}

impl ClockSource {
    pub fn name(self) -> &'static str {
        match self {
            ClockSource::Wall => "wall",
            ClockSource::Virtual => "virtual",
        }
    }

    pub fn from_name(s: &str) -> Option<ClockSource> {
        match s {
            "wall" => Some(ClockSource::Wall),
            "virtual" => Some(ClockSource::Virtual),
            _ => None,
        }
    }
}

/// Anything a server loop can report per-task compute durations into:
/// the in-process paths hand the recorder itself (via
/// [`RecorderCell`]); a TCP worker hands a frame buffer that ships the
/// records over the heartbeat wire (`net::worker`).
pub trait ComputeSink: Send + Sync {
    /// `dur_s` seconds of kernel time for `tag` in `tick`.
    fn record_compute(&self, tick: usize, tag: u64, dur_s: f64);
}

/// A late-bindable recorder slot: workers spawned before the recorder
/// exists hold the cell; [`RecorderCell::set`] arms it afterwards.
#[derive(Default)]
pub struct RecorderCell {
    inner: Mutex<Option<Arc<Recorder>>>,
}

impl RecorderCell {
    pub fn new() -> Arc<RecorderCell> {
        Arc::new(RecorderCell::default())
    }

    pub fn set(&self, r: Arc<Recorder>) {
        *self.inner.lock().unwrap() = Some(r);
    }

    pub fn get(&self) -> Option<Arc<Recorder>> {
        self.inner.lock().unwrap().clone()
    }
}

impl ComputeSink for RecorderCell {
    fn record_compute(&self, tick: usize, tag: u64, dur_s: f64) {
        if let Some(r) = self.get() {
            r.observe_compute(tick, tag, dur_s);
        }
    }
}

impl ComputeSink for Recorder {
    fn record_compute(&self, tick: usize, tag: u64, dur_s: f64) {
        self.observe_compute(tick, tag, dur_s);
    }
}

/// A task completion as the coordinator's gather observed it.
#[derive(Debug, Clone)]
pub(crate) struct TaskObs {
    pub tag: u64,
    pub server: usize,
    pub wave: usize,
    /// Dispatch → receipt latency (coordinator clock).
    pub latency_s: f64,
    /// Receipt instant (coordinator clock).
    pub receipt_s: f64,
}

/// Per-(tick, server) busy window plus the tick's aggregate phases.
#[derive(Debug, Clone, Default)]
pub(crate) struct TickObs {
    pub start_s: f64,
    pub end_s: Option<f64>,
    pub plan_s: f64,
    pub dispatch_s: f64,
    pub tasks: Vec<TaskObs>,
    /// server → (believed speed, observed speed) at plan time.
    pub speeds: BTreeMap<usize, (f64, Option<f64>)>,
}

#[derive(Default)]
struct Inner {
    ticks: BTreeMap<usize, TickObs>,
    /// Worker-measured kernel seconds, keyed `(tick, tag)`.
    compute: BTreeMap<(usize, u64), f64>,
    /// Freeform spans pushed directly (simulator paths).
    spans: Vec<Span>,
    counters: BTreeMap<String, f64>,
    /// Per-task causal trace ([`lineage`]).
    lineage: Vec<LineageEvent>,
    /// Re-dispatch hop counters keyed `(tick, tag)` — assigns the `hop`
    /// ordinal so callers don't have to thread per-task state.
    hops: BTreeMap<(usize, u64), u32>,
}

/// The tracing/metrics collector every execution path reports into.
/// All methods take `&self` — share it as `Arc<Recorder>` across the
/// coordinator, its in-process servers, and the net event loop.
pub struct Recorder {
    clock: ClockSource,
    /// Wall epoch: instants are reported as seconds since creation so
    /// a trace file is self-contained. `None` for virtual clocks.
    epoch: Option<Instant>,
    inner: Mutex<Inner>,
    /// Optional live-metrics hub ([`export::MetricsHub`]): when armed,
    /// completions, phase durations, and counters are mirrored into
    /// the Prometheus registry as they happen.
    hub: Mutex<Option<Arc<export::MetricsHub>>>,
}

impl Recorder {
    /// Wall-clock recorder (threaded coordinator, TCP runtime).
    pub fn new_wall() -> Arc<Recorder> {
        Arc::new(Recorder {
            clock: ClockSource::Wall,
            epoch: Some(Instant::now()),
            inner: Mutex::new(Inner::default()),
            hub: Mutex::new(None),
        })
    }

    /// Virtual-time recorder (discrete-event simulators).
    pub fn new_virtual() -> Arc<Recorder> {
        Arc::new(Recorder {
            clock: ClockSource::Virtual,
            epoch: None,
            inner: Mutex::new(Inner::default()),
            hub: Mutex::new(None),
        })
    }

    /// Mirror subsequent observations into a live-metrics hub.
    pub fn set_hub(&self, hub: Arc<export::MetricsHub>) {
        *self.hub.lock().unwrap() = Some(hub);
    }

    /// The attached hub, if any.
    pub fn hub(&self) -> Option<Arc<export::MetricsHub>> {
        self.hub.lock().unwrap().clone()
    }

    /// Lineage timestamp: wall seconds since the epoch, or `0.0` on a
    /// virtual recorder (sim paths order events by sequence, not time).
    fn t_now(&self) -> f64 {
        self.epoch.map(|e| e.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    pub fn clock(&self) -> ClockSource {
        self.clock
    }

    /// Seconds since the wall epoch. Panics on a virtual recorder —
    /// virtual paths pass explicit sim-times instead.
    pub fn now(&self) -> f64 {
        self.epoch.expect("virtual recorder has no wall clock").elapsed().as_secs_f64()
    }

    /// Open tick `tick` at the current wall time.
    pub fn tick_begin(&self, tick: usize) {
        let at = self.now();
        self.inner.lock().unwrap().ticks.entry(tick).or_default().start_s = at;
    }

    /// Close tick `tick` at the current wall time.
    pub fn tick_end(&self, tick: usize) {
        let at = self.now();
        self.inner.lock().unwrap().ticks.entry(tick).or_default().end_s = Some(at);
    }

    /// Virtual-clock variant: the tick's `[start, end)` window in
    /// sim seconds.
    pub fn tick_window(&self, tick: usize, start_s: f64, end_s: f64) {
        let mut g = self.inner.lock().unwrap();
        let t = g.ticks.entry(tick).or_default();
        t.start_s = start_s;
        t.end_s = Some(end_s);
    }

    /// Aggregate seconds a coordinator-side phase took this tick
    /// (`Plan` or `Dispatch`; other phases are derived or per-task).
    pub fn phase_seconds(&self, tick: usize, phase: Phase, dur_s: f64) {
        {
            let mut g = self.inner.lock().unwrap();
            let t = g.ticks.entry(tick).or_default();
            match phase {
                Phase::Plan => t.plan_s += dur_s,
                Phase::Dispatch => t.dispatch_s += dur_s,
                _ => {}
            }
        }
        if let Some(hub) = self.hub() {
            hub.observe(&format!("distca_phase_seconds|phase={}", phase.name()), dur_s);
        }
    }

    /// A completion as gather saw it: `server` computed `tag`, the
    /// receipt landed `latency_s` after its dispatch. The per-server
    /// busy window is derived from these (first dispatch = min over
    /// `receipt − latency`, window end = max receipt).
    pub fn task_completed(
        &self,
        tick: usize,
        wave: usize,
        server: usize,
        tag: u64,
        latency_s: f64,
    ) {
        let receipt_s = self.now();
        {
            let mut g = self.inner.lock().unwrap();
            g.ticks
                .entry(tick)
                .or_default()
                .tasks
                .push(TaskObs { tag, server, wave, latency_s, receipt_s });
        }
        self.lineage(LineageEvent {
            tick,
            wave,
            tag,
            t_s: receipt_s,
            stage: LineageStage::Completed { server, latency_s },
        });
        if let Some(hub) = self.hub() {
            hub.observe("distca_task_latency_seconds", latency_s);
            let tenant = crate::server::tag_wire_tenant(tag);
            if tenant > 0 {
                hub.observe(
                    &format!("distca_task_latency_seconds|tenant={}", tenant - 1),
                    latency_s,
                );
            }
        }
    }

    /// A suspect's task was cancelled and re-sent `from → to`.
    pub fn redispatch(&self, tick: usize, wave: usize, from: usize, to: usize, tag: u64) {
        let at = self.now();
        let mut g = self.inner.lock().unwrap();
        g.spans.push(Span {
            phase: Phase::Redispatch,
            tick,
            wave,
            server: Some(to),
            task_tag: Some(tag),
            start_s: at,
            dur_s: 0.0,
        });
        *g.counters.entry(format!("redispatch.from.{from}")).or_insert(0.0) += 1.0;
    }

    /// Append a raw lineage event ([`lineage`]).
    pub fn lineage(&self, ev: LineageEvent) {
        self.inner.lock().unwrap().lineage.push(ev);
    }

    /// `planned(server, cost)` — the balancer assigned `tag` to
    /// `server` with predicted cost `cost_pairs` causal pairs.
    pub fn lineage_planned(&self, tick: usize, tag: u64, server: usize, cost_pairs: f64) {
        self.lineage(LineageEvent {
            tick,
            wave: 0,
            tag,
            t_s: self.t_now(),
            stage: LineageStage::Planned { server, cost_pairs },
        });
    }

    /// `dispatched(server)` — one physical send landed `tag`'s bytes at
    /// `server`, stamped with wire trace id `trace` (0 off-wire).
    pub fn lineage_dispatched(&self, tick: usize, wave: usize, tag: u64, server: usize, trace: u64) {
        self.lineage(LineageEvent {
            tick,
            wave,
            tag,
            t_s: self.t_now(),
            stage: LineageStage::Dispatched { server, trace },
        });
    }

    /// `redispatched(reason, hop)` — `tag` was sent again `from → to`.
    /// The hop ordinal (1 = first re-dispatch of this task within its
    /// tick) is assigned here, so call sites stay stateless; every call
    /// MUST be adjacent to the `TickStats` counter bump for `reason`,
    /// which is what keeps lineage hop totals equal to the counters.
    pub fn lineage_redispatched(
        &self,
        tick: usize,
        wave: usize,
        tag: u64,
        from: usize,
        to: usize,
        reason: RedispatchReason,
    ) -> u32 {
        let (hop, t_s) = {
            let mut g = self.inner.lock().unwrap();
            let hop = g.hops.entry((tick, tag)).or_insert(0);
            *hop += 1;
            (*hop, self.t_now())
        };
        self.lineage(LineageEvent {
            tick,
            wave,
            tag,
            t_s,
            stage: LineageStage::Redispatched { from, to, reason, hop },
        });
        if let Some(hub) = self.hub() {
            hub.add(&format!("distca_redispatch_total|reason={}", reason.name()), 1.0);
        }
        hop
    }

    /// `stale-deduped` — a duplicate response from `server` suppressed
    /// by first-response-wins dedup.
    pub fn lineage_stale(&self, tick: usize, wave: usize, tag: u64, server: usize) {
        self.lineage(LineageEvent {
            tick,
            wave,
            tag,
            t_s: self.t_now(),
            stage: LineageStage::StaleDeduped { server },
        });
    }

    /// The worker-echoed wire trace id observed on `tag`'s winning
    /// response (TCP path; see [`crate::net::codec`]).
    pub fn lineage_wire_echo(&self, tick: usize, tag: u64, trace: u64) {
        self.lineage(LineageEvent {
            tick,
            wave: 0,
            tag,
            t_s: self.t_now(),
            stage: LineageStage::WireEcho { trace },
        });
    }

    /// Snapshot of the lineage log, in recording order.
    pub fn lineage_events(&self) -> Vec<LineageEvent> {
        self.inner.lock().unwrap().lineage.clone()
    }

    /// Worker-measured kernel seconds for `(tick, tag)` — refines the
    /// compute/wire split without changing the per-server sum.
    pub fn observe_compute(&self, tick: usize, tag: u64, dur_s: f64) {
        if !(dur_s.is_finite() && dur_s >= 0.0) {
            return;
        }
        self.inner.lock().unwrap().compute.insert((tick, tag), dur_s);
    }

    /// Believed vs observed speed for `server` at `tick` plan time
    /// (observed from the health EWMA; `None` until it has samples).
    pub fn speed_sample(&self, tick: usize, server: usize, believed: f64, observed: Option<f64>) {
        let mut g = self.inner.lock().unwrap();
        g.ticks.entry(tick).or_default().speeds.insert(server, (believed, observed));
    }

    /// Push a fully formed span (virtual-clock paths).
    pub fn push_span(&self, span: Span) {
        self.inner.lock().unwrap().spans.push(span);
    }

    /// Bump a named counter.
    pub fn counter(&self, name: &str, delta: f64) {
        *self.inner.lock().unwrap().counters.entry(name.to_string()).or_insert(0.0) += delta;
        if let Some(hub) = self.hub() {
            hub.add(&format!("distca_counter_total|name={name}"), delta);
        }
    }

    /// Counter snapshot (sorted by name).
    pub fn counters(&self) -> Vec<(String, f64)> {
        self.inner.lock().unwrap().counters.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// Synthesize the full span list: tick containers, coordinator
    /// plan/dispatch, per-server sequential-packed compute + wire-wait
    /// + gather-idle, plus every freeform span. Packing is per server
    /// per tick — computes back-to-back from the first dispatch in
    /// receipt order, then one wire-wait span to the last receipt, then
    /// gather-idle to tick end — so nesting and compute/wire
    /// disjointness hold by construction.
    pub fn spans(&self) -> Vec<Span> {
        let g = self.inner.lock().unwrap();
        let mut out: Vec<Span> = Vec::new();
        for (&tick, t) in &g.ticks {
            let end = t.end_s.unwrap_or_else(|| {
                t.tasks.iter().map(|x| x.receipt_s).fold(t.start_s, f64::max)
            });
            let tick_dur = (end - t.start_s).max(0.0);
            out.push(Span {
                phase: Phase::Tick,
                tick,
                wave: 0,
                server: None,
                task_tag: None,
                start_s: t.start_s,
                dur_s: tick_dur,
            });
            let mut at = t.start_s;
            for (phase, dur) in [(Phase::Plan, t.plan_s), (Phase::Dispatch, t.dispatch_s)] {
                if dur > 0.0 {
                    let dur = dur.min(t.start_s + tick_dur - at).max(0.0);
                    out.push(Span {
                        phase,
                        tick,
                        wave: 0,
                        server: None,
                        task_tag: None,
                        start_s: at,
                        dur_s: dur,
                    });
                    at += dur;
                }
            }
            // Group completions per server, receipt order.
            let mut by_srv: BTreeMap<usize, Vec<&TaskObs>> = BTreeMap::new();
            for task in &t.tasks {
                by_srv.entry(task.server).or_default().push(task);
            }
            for (&srv, tasks) in &mut by_srv {
                tasks.sort_by(|a, b| a.receipt_s.total_cmp(&b.receipt_s));
                let first_dispatch = tasks
                    .iter()
                    .map(|x| x.receipt_s - x.latency_s)
                    .fold(f64::INFINITY, f64::min)
                    .max(t.start_s)
                    .min(end);
                let last_receipt = tasks
                    .iter()
                    .map(|x| x.receipt_s)
                    .fold(first_dispatch, f64::max)
                    .min(end);
                let window = (last_receipt - first_dispatch).max(0.0);
                // Attribute per-task compute: worker-measured where
                // available, else the receipt gap (serialized model).
                let mut durs: Vec<f64> = Vec::with_capacity(tasks.len());
                let mut prev = first_dispatch;
                for task in tasks.iter() {
                    let gap = (task.receipt_s - prev).max(0.0);
                    prev = task.receipt_s.max(prev);
                    let d = match g.compute.get(&(tick, task.tag)) {
                        Some(&m) => m.min(gap),
                        None => gap,
                    };
                    durs.push(d);
                }
                let total: f64 = durs.iter().sum();
                if total > window && total > 0.0 {
                    let scale = window / total;
                    for d in &mut durs {
                        *d *= scale;
                    }
                }
                let mut cursor = first_dispatch;
                for (task, &d) in tasks.iter().zip(&durs) {
                    out.push(Span {
                        phase: Phase::Compute,
                        tick,
                        wave: task.wave,
                        server: Some(srv),
                        task_tag: Some(task.tag),
                        start_s: cursor,
                        dur_s: d,
                    });
                    cursor += d;
                }
                if last_receipt > cursor {
                    out.push(Span {
                        phase: Phase::WireWait,
                        tick,
                        wave: 0,
                        server: Some(srv),
                        task_tag: None,
                        start_s: cursor,
                        dur_s: last_receipt - cursor,
                    });
                }
                // Idle outside the busy window: lead-in + gather tail.
                if first_dispatch > t.start_s {
                    out.push(Span {
                        phase: Phase::Gather,
                        tick,
                        wave: 0,
                        server: Some(srv),
                        task_tag: None,
                        start_s: t.start_s,
                        dur_s: first_dispatch - t.start_s,
                    });
                }
                if end > last_receipt {
                    out.push(Span {
                        phase: Phase::Gather,
                        tick,
                        wave: 0,
                        server: Some(srv),
                        task_tag: None,
                        start_s: last_receipt,
                        dur_s: end - last_receipt,
                    });
                }
            }
        }
        out.extend(g.spans.iter().cloned());
        out
    }

    /// Believed/observed speed samples: `(tick, server, believed,
    /// observed)` in tick order.
    pub fn speed_samples(&self) -> Vec<(usize, usize, f64, Option<f64>)> {
        let g = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for (&tick, t) in &g.ticks {
            for (&srv, &(b, o)) in &t.speeds {
                out.push((tick, srv, b, o));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_names_roundtrip() {
        for p in [
            Phase::Tick,
            Phase::Plan,
            Phase::Dispatch,
            Phase::Compute,
            Phase::WireWait,
            Phase::Gather,
            Phase::Redispatch,
            Phase::Evict,
        ] {
            assert_eq!(Phase::from_name(p.name()), Some(p));
        }
        assert_eq!(Phase::from_name("bogus"), None);
    }

    #[test]
    fn sequential_packing_preserves_the_tick_sum() {
        // Virtual-style control over time via direct task observations:
        // build a wall recorder but synthesize receipts through the
        // public API, then check compute + wire + gather == tick span
        // per server.
        let r = Recorder::new_wall();
        r.tick_begin(0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        r.observe_compute(0, 7, 0.001);
        r.task_completed(0, 0, 1, 7, 0.004);
        std::thread::sleep(std::time::Duration::from_millis(3));
        r.task_completed(0, 0, 1, 8, 0.002);
        std::thread::sleep(std::time::Duration::from_millis(2));
        r.tick_end(0);
        let spans = r.spans();
        let tick = spans.iter().find(|s| s.phase == Phase::Tick).unwrap();
        let sum: f64 = spans
            .iter()
            .filter(|s| {
                s.server == Some(1)
                    && matches!(s.phase, Phase::Compute | Phase::WireWait | Phase::Gather)
            })
            .map(|s| s.dur_s)
            .sum();
        assert!(
            (sum - tick.dur_s).abs() <= 1e-9 + 1e-6 * tick.dur_s,
            "phases sum {sum} vs tick {}",
            tick.dur_s
        );
        // Compute and wire-wait never overlap on the server row.
        let mut windows: Vec<(f64, f64, Phase)> = spans
            .iter()
            .filter(|s| s.server == Some(1) && matches!(s.phase, Phase::Compute | Phase::WireWait))
            .map(|s| (s.start_s, s.start_s + s.dur_s, s.phase))
            .collect();
        windows.sort_by(|a, b| a.0.total_cmp(&b.0));
        for pair in windows.windows(2) {
            assert!(pair[0].1 <= pair[1].0 + 1e-12, "overlap: {pair:?}");
        }
    }

    #[test]
    fn worker_measured_compute_caps_the_attribution() {
        let r = Recorder::new_wall();
        r.tick_begin(3);
        r.observe_compute(3, 1, 0.0); // measured: instant kernel
        std::thread::sleep(std::time::Duration::from_millis(4));
        r.task_completed(3, 0, 0, 1, 0.003);
        r.tick_end(3);
        let spans = r.spans();
        let compute: f64 = spans
            .iter()
            .filter(|s| s.phase == Phase::Compute)
            .map(|s| s.dur_s)
            .sum();
        let wire: f64 = spans
            .iter()
            .filter(|s| s.phase == Phase::WireWait)
            .map(|s| s.dur_s)
            .sum();
        assert!(compute <= 1e-12, "measured 0s kernel, got {compute}");
        assert!(wire > 0.0, "latency must surface as wire-wait");
    }

    #[test]
    fn recorder_cell_binds_late() {
        let cell = RecorderCell::new();
        cell.record_compute(0, 1, 0.5); // unarmed: dropped
        let r = Recorder::new_wall();
        cell.set(Arc::clone(&r));
        cell.record_compute(0, 2, 0.25);
        let g = r.inner.lock().unwrap();
        assert!(!g.compute.contains_key(&(0, 1)));
        assert_eq!(g.compute.get(&(0, 2)), Some(&0.25));
    }

    #[test]
    fn lineage_hops_are_assigned_per_task_per_tick() {
        let r = Recorder::new_wall();
        let tag = 0x40u64;
        r.lineage_planned(0, tag, 1, 64.0);
        r.lineage_dispatched(0, 0, tag, 1, 0);
        assert_eq!(r.lineage_redispatched(0, 0, tag, 1, 2, RedispatchReason::Speculative), 1);
        assert_eq!(r.lineage_redispatched(0, 0, tag, 2, 3, RedispatchReason::Kill), 2);
        // A different tick restarts the ordinal.
        assert_eq!(r.lineage_redispatched(1, 0, tag, 1, 2, RedispatchReason::Oom), 1);
        let events = r.lineage_events();
        assert_eq!(events.len(), 5);
        let js = lineage::journeys(&events);
        assert_eq!(js.len(), 2);
        assert_eq!(js[0].hops(), 2);
        assert_eq!(js[0].reason_chain(), "speculative\u{2192}kill");
    }

    #[test]
    fn completions_feed_the_metrics_hub() {
        let r = Recorder::new_wall();
        let hub = export::MetricsHub::new();
        r.set_hub(Arc::clone(&hub));
        r.tick_begin(0);
        r.task_completed(0, 0, 1, 0x40, 0.002);
        r.counter("stats.frames.1", 1.0);
        assert_eq!(hub.hist("distca_task_latency_seconds").unwrap().count(), 1);
        assert_eq!(hub.scalar("distca_counter_total|name=stats.frames.1"), Some(1.0));
    }

    #[test]
    fn non_finite_compute_observations_are_dropped() {
        let r = Recorder::new_wall();
        r.observe_compute(0, 1, f64::NAN);
        r.observe_compute(0, 2, -1.0);
        assert!(r.inner.lock().unwrap().compute.is_empty());
    }
}
