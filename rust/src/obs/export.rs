//! Zero-dependency live metrics: a Prometheus-text-format HTTP
//! exporter over `std::net::TcpListener`.
//!
//! `serve|soak|gateway --metrics-listen ADDR` arm a shared
//! [`MetricsHub`] — counters, gauges, and [`LogHistogram`] families —
//! and serve it at `GET /metrics` in Prometheus text exposition format
//! 0.0.4. Histogram families render as *summaries* (`quantile` labels
//! p50/p95/p99 plus `_sum`/`_count`), computed from the same log
//! buckets the post-hoc report reads, so the live p99 and the
//! post-run p99 agree within the documented
//! [`super::hist::QUANTILE_REL_ERROR`] by construction.
//!
//! `distca top` is the matching client: it polls the endpoint with a
//! hand-rolled HTTP GET ([`fetch_metrics`]) and renders a refreshing
//! terminal dashboard — no HTTP library on either side (the vendor set
//! has none).
//!
//! ## Metric keys
//!
//! A hub key is `family` or `family|k=v,k2=v2` — the part after `|` is
//! rendered as Prometheus labels. Family names must match
//! `[a-zA-Z_:][a-zA-Z0-9_:]*`; [`MetricsHub`] sanitizes on insert so
//! dotted recorder counter names are safe to forward.

use super::hist::LogHistogram;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Shared live-metrics registry: scalar gauges/counters plus histogram
/// families, all keyed by `family` or `family|label=value,...`.
#[derive(Default)]
pub struct MetricsHub {
    scalars: Mutex<BTreeMap<String, f64>>,
    hists: Mutex<BTreeMap<String, LogHistogram>>,
}

/// Replace every character Prometheus disallows in a metric name with
/// `_` (labels keep their value text — only names are constrained).
fn sanitize_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphabetic()
            || c == '_'
            || c == ':'
            || (i > 0 && c.is_ascii_digit());
        out.push(if ok { c } else { '_' });
    }
    out
}

/// Split a hub key into (sanitized family, raw label part).
fn split_key(key: &str) -> (String, Option<&str>) {
    match key.split_once('|') {
        Some((fam, labels)) => (sanitize_name(fam), Some(labels)),
        None => (sanitize_name(key), None),
    }
}

/// Render `k=v,k2=v2` as `{k="v",k2="v2"}` with `extra` appended.
fn render_labels(labels: Option<&str>, extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = Vec::new();
    if let Some(l) = labels {
        for pair in l.split(',').filter(|p| !p.is_empty()) {
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            parts.push(format!("{}=\"{}\"", sanitize_name(k), v.replace('"', "'")));
        }
    }
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() { String::new() } else { format!("{{{}}}", parts.join(",")) }
}

impl MetricsHub {
    pub fn new() -> Arc<MetricsHub> {
        Arc::new(MetricsHub::default())
    }

    /// Add to a scalar (counter semantics).
    pub fn add(&self, key: &str, v: f64) {
        *self.scalars.lock().unwrap().entry(key.to_string()).or_insert(0.0) += v;
    }

    /// Overwrite a scalar (gauge semantics).
    pub fn set(&self, key: &str, v: f64) {
        self.scalars.lock().unwrap().insert(key.to_string(), v);
    }

    /// Record a sample into a histogram family.
    pub fn observe(&self, key: &str, v: f64) {
        self.hists.lock().unwrap().entry(key.to_string()).or_default().observe(v);
    }

    /// Merge a pre-aggregated shard (e.g. decoded from a worker STATS
    /// frame) into a histogram family.
    pub fn merge_hist(&self, key: &str, shard: &LogHistogram) {
        self.hists.lock().unwrap().entry(key.to_string()).or_default().merge(shard);
    }

    /// Snapshot one histogram family (exact key match).
    pub fn hist(&self, key: &str) -> Option<LogHistogram> {
        self.hists.lock().unwrap().get(key).cloned()
    }

    /// Snapshot one scalar.
    pub fn scalar(&self, key: &str) -> Option<f64> {
        self.scalars.lock().unwrap().get(key).copied()
    }

    /// All histogram keys, sorted.
    pub fn hist_keys(&self) -> Vec<String> {
        self.hists.lock().unwrap().keys().cloned().collect()
    }

    /// Render the whole registry in Prometheus text exposition format
    /// 0.0.4 (scalars as gauges, histogram families as summaries).
    /// Keys are regrouped by family first so each `# TYPE` header is
    /// emitted exactly once, with its series contiguous.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        let scalars = self.scalars.lock().unwrap().clone();
        let mut by_fam: BTreeMap<String, Vec<(String, f64)>> = BTreeMap::new();
        for (key, v) in &scalars {
            let (fam, labels) = split_key(key);
            by_fam.entry(fam).or_default().push((render_labels(labels, None), *v));
        }
        for (fam, series) in &by_fam {
            out.push_str(&format!("# TYPE {fam} gauge\n"));
            for (labels, v) in series {
                out.push_str(&format!("{fam}{labels} {v}\n"));
            }
        }
        let hists = self.hists.lock().unwrap().clone();
        let mut by_fam: BTreeMap<String, Vec<(Option<String>, LogHistogram)>> = BTreeMap::new();
        for (key, h) in &hists {
            let (fam, labels) = split_key(key);
            by_fam.entry(fam).or_default().push((labels.map(|s| s.to_string()), h.clone()));
        }
        for (fam, series) in &by_fam {
            out.push_str(&format!("# TYPE {fam} summary\n"));
            for (labels, h) in series {
                let labels = labels.as_deref();
                for q in [0.5, 0.95, 0.99] {
                    out.push_str(&format!(
                        "{fam}{} {}\n",
                        render_labels(labels, Some(("quantile", &format!("{q}")))),
                        h.quantile(q).unwrap_or(0.0),
                    ));
                }
                let plain = render_labels(labels, None);
                out.push_str(&format!("{fam}_sum{plain} {}\n", h.sum()));
                out.push_str(&format!("{fam}_count{plain} {}\n", h.count()));
            }
        }
        out
    }

    /// Post-hoc JSON snapshot of every histogram family's quantiles —
    /// what the soak summary and `BENCH_obs.json` read.
    pub fn quantile_snapshot(&self) -> Json {
        let hists = self.hists.lock().unwrap();
        let fields = hists
            .iter()
            .map(|(k, h)| {
                let (p50, p95, p99) = h.p50_p95_p99();
                (
                    k.clone(),
                    Json::obj(vec![
                        ("count", Json::Num(h.count() as f64)),
                        ("p50", Json::Num(p50)),
                        ("p95", Json::Num(p95)),
                        ("p99", Json::Num(p99)),
                        ("max", Json::Num(h.max())),
                    ]),
                )
            })
            .collect();
        Json::Obj(fields)
    }

    /// Bind `addr` (e.g. `127.0.0.1:9464`, port 0 for ephemeral) and
    /// serve `GET /metrics` from a detached thread for the life of the
    /// process. Returns the bound address.
    pub fn serve(self: &Arc<Self>, addr: &str) -> Result<SocketAddr> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("metrics listener bind {addr}"))?;
        let bound = listener.local_addr()?;
        let hub = Arc::clone(self);
        std::thread::Builder::new()
            .name("distca-metrics".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    let Ok(stream) = conn else { continue };
                    let hub = Arc::clone(&hub);
                    // One short-lived thread per scrape: scrapers are
                    // rare (CI curl, `distca top`) and a stuck client
                    // must not stall the accept loop.
                    std::thread::spawn(move || {
                        let _ = serve_one(stream, &hub);
                    });
                }
            })
            .context("spawn metrics thread")?;
        Ok(bound)
    }
}

/// Handle one HTTP exchange: minimal request parse, text response.
fn serve_one(mut stream: TcpStream, hub: &MetricsHub) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    let mut buf = [0u8; 4096];
    let mut req = Vec::new();
    loop {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        req.extend_from_slice(&buf[..n]);
        if req.windows(4).any(|w| w == b"\r\n\r\n") || req.len() > 65536 {
            break;
        }
    }
    let line = String::from_utf8_lossy(&req);
    let path = line.split_whitespace().nth(1).unwrap_or("/");
    let (status, body) = if path == "/metrics" || path == "/" {
        ("200 OK", hub.render_prometheus())
    } else {
        ("404 Not Found", "not found\n".to_string())
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; version=0.0.4; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    stream.write_all(resp.as_bytes())
}

/// Fetch `/metrics` from `addr` (`host:port`) with a hand-rolled HTTP
/// GET; returns the response body.
pub fn fetch_metrics(addr: &str) -> Result<String> {
    let addr = addr.trim_start_matches("http://").trim_end_matches('/');
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connect to metrics endpoint {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    stream.set_write_timeout(Some(Duration::from_secs(5)))?;
    stream.write_all(
        format!("GET /metrics HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw)?;
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .context("malformed HTTP response (no header/body split)")?;
    let status = head.lines().next().unwrap_or("");
    if !status.contains("200") {
        bail!("metrics endpoint returned {status:?}");
    }
    Ok(body.to_string())
}

/// One parsed sample line: `(family, labels, value)`.
pub type Sample = (String, Vec<(String, String)>, f64);

/// Minimal Prometheus text-format parser — enough for `distca top` and
/// the CI format check: comment lines skipped, `name{labels} value`
/// lines decoded.
pub fn parse_prometheus(text: &str) -> Vec<Sample> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name_part, value_part) = match line.rsplit_once(' ') {
            Some(p) => p,
            None => continue,
        };
        let Ok(value) = value_part.trim().parse::<f64>() else { continue };
        let (family, labels) = match name_part.split_once('{') {
            Some((fam, rest)) => {
                let rest = rest.trim_end_matches('}');
                let labels = rest
                    .split(',')
                    .filter(|p| !p.is_empty())
                    .filter_map(|p| {
                        let (k, v) = p.split_once('=')?;
                        Some((k.trim().to_string(), v.trim().trim_matches('"').to_string()))
                    })
                    .collect();
                (fam.trim().to_string(), labels)
            }
            None => (name_part.trim().to_string(), Vec::new()),
        };
        out.push((family, labels, value));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_parse_roundtrip() {
        let hub = MetricsHub::new();
        hub.add("distca_ticks_total", 3.0);
        hub.set("distca_alive_servers", 4.0);
        for i in 1..=100 {
            hub.observe("distca_task_latency_seconds|tenant=3", i as f64 * 1e-3);
        }
        let text = hub.render_prometheus();
        assert!(text.contains("# TYPE distca_task_latency_seconds summary"), "{text}");
        assert!(text.contains("# TYPE distca_ticks_total gauge"), "{text}");
        let samples = parse_prometheus(&text);
        let p99 = samples
            .iter()
            .find(|(f, l, _)| {
                f == "distca_task_latency_seconds"
                    && l.contains(&("tenant".into(), "3".into()))
                    && l.contains(&("quantile".into(), "0.99".into()))
            })
            .map(|(_, _, v)| *v)
            .unwrap();
        assert!((p99 - 0.099).abs() / 0.099 < 0.02, "p99 {p99}");
        let count = samples
            .iter()
            .find(|(f, _, _)| f == "distca_task_latency_seconds_count")
            .map(|(_, _, v)| *v)
            .unwrap();
        assert_eq!(count, 100.0);
    }

    #[test]
    fn dotted_names_are_sanitized() {
        let hub = MetricsHub::new();
        hub.add("stats.frames.3", 1.0);
        let text = hub.render_prometheus();
        assert!(text.contains("stats_frames_3 1"), "{text}");
    }

    #[test]
    fn http_server_serves_the_registry() {
        let hub = MetricsHub::new();
        hub.observe("distca_phase_seconds|phase=compute", 0.25);
        let addr = hub.serve("127.0.0.1:0").unwrap();
        let body = fetch_metrics(&addr.to_string()).unwrap();
        assert!(body.contains("distca_phase_seconds_count"), "{body}");
        // Unknown paths 404 without killing the accept loop.
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /nope HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        assert!(resp.contains("404"), "{resp}");
        assert!(fetch_metrics(&addr.to_string()).is_ok());
    }

    #[test]
    fn quantile_snapshot_lists_families() {
        let hub = MetricsHub::new();
        hub.observe("a", 1.0);
        hub.observe("a", 2.0);
        let snap = hub.quantile_snapshot();
        assert_eq!(snap.get("a").unwrap().get("count").unwrap().as_u64(), Some(2));
    }
}
