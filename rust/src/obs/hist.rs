//! Mergeable log-bucketed latency histograms (HDR/DDSketch-style).
//!
//! The repo's distributional claims — "eliminates stragglers", per-class
//! SLO latency — need percentiles, not means, and they need them both
//! *live* (the `/metrics` exporter) and *post hoc* (merged across worker
//! STATS frames after a run). A [`LogHistogram`] supports both from one
//! representation:
//!
//! * **Log-spaced buckets.** Bucket `i >= 1` covers the half-open
//!   interval `(MIN_V * GAMMA^(i-1), MIN_V * GAMMA^i]`; bucket `0`
//!   absorbs everything at or below [`MIN_V`] (including zeros and
//!   negatives, which physical durations never are). With
//!   `GAMMA = 1.02` and 1408 buckets the range spans ~1 ns to ~20 min —
//!   every duration the system measures, from an AVX2 inner-loop span
//!   to a full soak.
//! * **Bounded relative error.** A quantile query returns the geometric
//!   midpoint of the selected bucket, clamped into the observed
//!   `[min, max]`; the true quantile lies inside the same bucket, so the
//!   relative error is at most `sqrt(GAMMA) - 1 ≈ 1%`. The documented
//!   (and tested) bound is the conservative [`QUANTILE_REL_ERROR`] = 2%.
//! * **Exact mergeability.** Buckets are fixed and global, so merging is
//!   element-wise addition: a histogram merged from per-worker shards is
//!   *identical* (bit-for-bit, see `tests/prop_obs_hist.rs`) to the
//!   histogram of the concatenated samples. That is what lets per-worker
//!   STATS shards roll up into one truthful tail.
//! * **Bit-exact serialization.** `sum`/`min`/`max` travel as f64 bit
//!   patterns (hex strings — JSON `f64` numbers cannot carry 2^53+
//!   integers or NaN payloads), counts as sparse `[bucket, count]`
//!   pairs; `to_json` → [`LogHistogram::from_json`] round-trips exactly.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Bucket growth factor: consecutive bucket bounds differ by 2%.
pub const GAMMA: f64 = 1.02;

/// Lower edge of the tracked range (seconds): 1 ns.
pub const MIN_V: f64 = 1e-9;

/// Bucket count. `MIN_V * GAMMA^1407 ≈ 1.2e3 s`, so the top regular
/// bucket ends around 20 minutes; anything larger clamps into it.
pub const N_BUCKETS: usize = 1408;

/// The documented quantile relative-error bound. The geometric-midpoint
/// estimate is within `sqrt(GAMMA) - 1 ≈ 0.995%` of the true quantile
/// for in-range values; 2% leaves headroom and is the bound the
/// property tests enforce across magnitudes.
pub const QUANTILE_REL_ERROR: f64 = 0.02;

/// A mergeable log-bucketed histogram of non-negative samples
/// (seconds, by convention — but any unit works, the buckets are
/// relative).
#[derive(Debug, Clone, PartialEq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Bucket index for a sample value.
fn bucket_index(v: f64) -> usize {
    if !(v > MIN_V) {
        // NaN, negatives, zero, and sub-ns all land in the floor bucket.
        return 0;
    }
    let i = ((v / MIN_V).ln() / GAMMA.ln()).ceil() as isize;
    (i.max(1) as usize).min(N_BUCKETS - 1)
}

/// Representative value for a bucket: the geometric midpoint of its
/// bounds (the floor bucket reports its upper edge, `MIN_V`).
fn bucket_value(i: usize) -> f64 {
    if i == 0 {
        MIN_V
    } else {
        MIN_V * GAMMA.powi(i as i32) / GAMMA.sqrt()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample.
    pub fn observe(&mut self, v: f64) {
        let v = if v.is_finite() { v.max(0.0) } else { 0.0 };
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold `other` into `self`. Because buckets are fixed and global,
    /// this is exact: merge(shard_a, shard_b) == histogram(a ++ b).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// The `q`-quantile (`q` in `[0, 1]`), or `None` when empty. The
    /// estimate is within [`QUANTILE_REL_ERROR`] of the true sample
    /// quantile (nearest-rank definition) for values above [`MIN_V`].
    pub fn quantile(&self, q: f64) -> Option<f64> {
        if self.count == 0 {
            return None;
        }
        let q = q.clamp(0.0, 1.0);
        // Nearest-rank: the smallest value whose cumulative count
        // reaches ceil(q * N) (rank >= 1).
        let target = ((q * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Some(bucket_value(i).clamp(self.min, self.max));
            }
        }
        Some(self.max)
    }

    /// Convenience: `(p50, p95, p99)`, zeros when empty.
    pub fn p50_p95_p99(&self) -> (f64, f64, f64) {
        (
            self.quantile(0.50).unwrap_or(0.0),
            self.quantile(0.95).unwrap_or(0.0),
            self.quantile(0.99).unwrap_or(0.0),
        )
    }

    /// Bit-exact serialization: sparse `[bucket, count]` pairs plus the
    /// f64 bit patterns of `sum`/`min`/`max` as 16-digit hex strings.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                Json::Arr(vec![Json::Num(i as f64), Json::Num(c as f64)])
            })
            .collect();
        Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("count", Json::Num(self.count as f64)),
            ("sum_bits", Json::Str(format!("{:016x}", self.sum.to_bits()))),
            ("min_bits", Json::Str(format!("{:016x}", self.min.to_bits()))),
            ("max_bits", Json::Str(format!("{:016x}", self.max.to_bits()))),
            ("buckets", Json::Arr(buckets)),
        ])
    }

    /// Inverse of [`LogHistogram::to_json`].
    pub fn from_json(v: &Json) -> Result<LogHistogram> {
        let ver = v.get("v").and_then(Json::as_u64).unwrap_or(0);
        if ver != 1 {
            bail!("unsupported histogram version {ver}");
        }
        let bits = |key: &str| -> Result<f64> {
            let s = v
                .get(key)
                .and_then(Json::as_str)
                .with_context(|| format!("histogram missing `{key}`"))?;
            let b = u64::from_str_radix(s, 16)
                .with_context(|| format!("bad hex in `{key}`: {s:?}"))?;
            Ok(f64::from_bits(b))
        };
        let mut h = LogHistogram::new();
        h.count = v
            .get("count")
            .and_then(Json::as_u64)
            .context("histogram missing `count`")?;
        h.sum = bits("sum_bits")?;
        h.min = bits("min_bits")?;
        h.max = bits("max_bits")?;
        let buckets = v
            .get("buckets")
            .and_then(Json::as_arr)
            .context("histogram missing `buckets`")?;
        let mut folded = 0u64;
        for b in buckets {
            let pair = b.as_arr().context("bucket entry is not a pair")?;
            let (i, c) = match pair {
                [i, c] => (
                    i.as_usize().context("bucket index")?,
                    c.as_u64().context("bucket count")?,
                ),
                _ => bail!("bucket entry is not a [index, count] pair"),
            };
            if i >= N_BUCKETS {
                bail!("bucket index {i} out of range");
            }
            h.counts[i] += c;
            folded += c;
        }
        if folded != h.count {
            bail!("histogram count {} != bucket total {folded}", h.count);
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_well_behaved() {
        let h = LogHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), None);
        assert_eq!(h.p50_p95_p99(), (0.0, 0.0, 0.0));
        assert_eq!(h.mean(), 0.0);
        let back = LogHistogram::from_json(&h.to_json()).unwrap();
        assert_eq!(back, h);
    }

    #[test]
    fn single_sample_quantiles_hit_the_sample() {
        let mut h = LogHistogram::new();
        h.observe(0.125);
        for q in [0.0, 0.5, 0.99, 1.0] {
            let est = h.quantile(q).unwrap();
            let rel = (est - 0.125).abs() / 0.125;
            assert!(rel <= QUANTILE_REL_ERROR, "q={q}: est {est}, rel {rel}");
        }
    }

    #[test]
    fn quantile_error_bound_on_a_known_ladder() {
        // 1..=1000 ms: true p50 = 0.500 s, p99 = 0.990 s.
        let mut h = LogHistogram::new();
        for i in 1..=1000 {
            h.observe(i as f64 * 1e-3);
        }
        for (q, truth) in [(0.50, 0.500), (0.95, 0.950), (0.99, 0.990)] {
            let est = h.quantile(q).unwrap();
            assert!(
                (est - truth).abs() / truth <= QUANTILE_REL_ERROR,
                "q={q}: est {est} vs {truth}"
            );
        }
    }

    #[test]
    fn merge_is_elementwise_exact() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut whole = LogHistogram::new();
        for i in 0..100 {
            let v = 1e-6 * (i + 1) as f64;
            if i % 2 == 0 { a.observe(v) } else { b.observe(v) }
            whole.observe(v);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged.counts, whole.counts);
        assert_eq!(merged.count, whole.count);
        assert_eq!(merged.min.to_bits(), whole.min.to_bits());
        assert_eq!(merged.max.to_bits(), whole.max.to_bits());
    }

    #[test]
    fn zeros_and_subnormal_values_hit_the_floor_bucket() {
        let mut h = LogHistogram::new();
        h.observe(0.0);
        h.observe(-3.0); // clamped: durations are never negative
        h.observe(1e-12);
        h.observe(f64::NAN);
        assert_eq!(h.count(), 4);
        assert_eq!(h.counts[0], 4);
    }

    #[test]
    fn huge_values_clamp_into_the_top_bucket() {
        let mut h = LogHistogram::new();
        h.observe(1e9); // ~31 years, way past the 20-min top edge
        assert_eq!(h.counts[N_BUCKETS - 1], 1);
        // The clamp into [min, max] keeps the estimate truthful even
        // for out-of-range samples.
        assert_eq!(h.quantile(1.0), Some(1e9));
    }

    #[test]
    fn serialization_rejects_malformed_documents() {
        let mut h = LogHistogram::new();
        h.observe(1.0);
        let good = h.to_json();
        assert_eq!(LogHistogram::from_json(&good).unwrap(), h);
        let bad = crate::util::json::parse(r#"{"v":1,"count":5,"buckets":[]}"#).unwrap();
        assert!(LogHistogram::from_json(&bad).is_err());
        let wrong_ver = crate::util::json::parse(r#"{"v":9}"#).unwrap();
        assert!(LogHistogram::from_json(&wrong_ver).is_err());
    }
}
