//! Chrome `trace_event` JSON export/import for [`Recorder`] spans.
//!
//! The on-disk format is the trace-event "JSON object format":
//!
//! ```json
//! {
//!   "traceEvents": [
//!     {"name": "compute 42", "cat": "compute", "ph": "X",
//!      "ts": 1234.5, "dur": 88.0, "pid": 0, "tid": 3,
//!      "args": {"tick": 1, "wave": 0, "tag": 42}}
//!   ],
//!   "displayTimeUnit": "ms",
//!   "distca": {"clock": "wall", "counters": {...}, "speeds": [...]}
//! }
//! ```
//!
//! * one complete event (`ph: "X"`) per span, `ts`/`dur` in
//!   microseconds (fractional — full f64 precision survives);
//! * `tid 0` is the coordinator row, `tid s+1` is server `s` —
//!   `thread_name` metadata events label the rows in Perfetto;
//! * the `distca` sidecar object carries the clock source, counters,
//!   and believed/observed speed samples. Perfetto ignores unknown
//!   top-level keys, so the same file loads in the UI *and*
//!   round-trips through [`read_trace`] for `distca report`.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

use super::lineage::LineageEvent;
use super::{ClockSource, Phase, Recorder, Span};

const US: f64 = 1e6;

fn tid_of(server: Option<usize>) -> usize {
    match server {
        None => 0,
        Some(s) => s + 1,
    }
}

fn server_of(tid: usize) -> Option<usize> {
    tid.checked_sub(1)
}

fn span_event(s: &Span) -> Json {
    let name = match s.task_tag {
        Some(tag) => format!("{} {tag}", s.phase.name()),
        None => format!("{} t{}", s.phase.name(), s.tick),
    };
    let mut args = vec![
        ("tick".to_string(), Json::Num(s.tick as f64)),
        ("wave".to_string(), Json::Num(s.wave as f64)),
    ];
    if let Some(tag) = s.task_tag {
        args.push(("tag".to_string(), Json::Num(tag as f64)));
    }
    Json::obj(vec![
        ("name", Json::Str(name)),
        ("cat", Json::Str(s.phase.name().to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(s.start_s * US)),
        ("dur", Json::Num(s.dur_s * US)),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid_of(s.server) as f64)),
        ("args", Json::Obj(args)),
    ])
}

fn thread_name_event(tid: usize, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::Str("thread_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::Str(name.to_string()))])),
    ])
}

/// Render the recorder into the trace-file JSON value.
pub fn export(recorder: &Recorder) -> Json {
    let spans = recorder.spans();
    let mut events: Vec<Json> = Vec::with_capacity(spans.len() + 8);
    let mut tids: Vec<usize> = spans.iter().map(|s| tid_of(s.server)).collect();
    tids.sort_unstable();
    tids.dedup();
    for tid in tids {
        let name = match server_of(tid) {
            None => "coordinator".to_string(),
            Some(s) => format!("server {s}"),
        };
        events.push(thread_name_event(tid, &name));
    }
    events.extend(spans.iter().map(span_event));
    let counters =
        Json::Obj(recorder.counters().into_iter().map(|(k, v)| (k, Json::Num(v))).collect());
    let speeds = Json::Arr(
        recorder
            .speed_samples()
            .into_iter()
            .map(|(tick, server, believed, observed)| {
                Json::obj(vec![
                    ("tick", Json::Num(tick as f64)),
                    ("server", Json::Num(server as f64)),
                    ("believed", Json::Num(believed)),
                    ("observed", observed.map(Json::Num).unwrap_or(Json::Null)),
                ])
            })
            .collect(),
    );
    let lineage =
        Json::Arr(recorder.lineage_events().iter().map(LineageEvent::to_json).collect());
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "distca",
            Json::obj(vec![
                ("clock", Json::Str(recorder.clock().name().to_string())),
                ("counters", counters),
                ("speeds", speeds),
                ("lineage", lineage),
            ]),
        ),
    ])
}

/// Write the trace file (pretty JSON — Perfetto loads it as-is).
pub fn write_trace(recorder: &Recorder, path: &Path) -> Result<()> {
    std::fs::write(path, export(recorder).to_string_pretty())
        .with_context(|| format!("writing trace {}", path.display()))
}

/// A parsed trace file.
#[derive(Debug, Clone)]
pub struct TraceFile {
    pub clock: ClockSource,
    pub spans: Vec<Span>,
    pub counters: Vec<(String, f64)>,
    /// `(tick, server, believed, observed)` speed samples.
    pub speeds: Vec<(usize, usize, f64, Option<f64>)>,
    /// Per-task causal lineage log (empty for traces written before
    /// the lineage sidecar existed).
    pub lineage: Vec<LineageEvent>,
}

/// Parse a trace-file JSON value back into spans + sidecar.
pub fn parse_trace(v: &Json) -> Result<TraceFile> {
    let events = v
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .context("trace has no traceEvents array")?;
    let mut spans = Vec::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).unwrap_or("");
        if ph != "X" {
            continue; // metadata / instant events carry no phase time
        }
        let cat = ev.get("cat").and_then(|c| c.as_str()).context("X event missing cat")?;
        let Some(phase) = Phase::from_name(cat) else {
            continue; // foreign category: not ours to account
        };
        let ts = ev.get("ts").and_then(|t| t.as_f64()).context("X event missing ts")?;
        let dur = ev.get("dur").and_then(|d| d.as_f64()).unwrap_or(0.0);
        let tid = ev.get("tid").and_then(|t| t.as_usize()).unwrap_or(0);
        let args = ev.get("args");
        let tick = args
            .and_then(|a| a.get("tick"))
            .and_then(|t| t.as_usize())
            .context("span missing args.tick")?;
        let wave = args.and_then(|a| a.get("wave")).and_then(|w| w.as_usize()).unwrap_or(0);
        let task_tag = args.and_then(|a| a.get("tag")).and_then(|t| t.as_u64());
        spans.push(Span {
            phase,
            tick,
            wave,
            server: server_of(tid),
            task_tag,
            start_s: ts / US,
            dur_s: dur / US,
        });
    }
    let sidecar = v.get("distca");
    let clock = sidecar
        .and_then(|d| d.get("clock"))
        .and_then(|c| c.as_str())
        .and_then(ClockSource::from_name)
        .unwrap_or(ClockSource::Wall);
    let mut counters = Vec::new();
    if let Some(Json::Obj(fields)) = sidecar.and_then(|d| d.get("counters")) {
        for (k, val) in fields {
            if let Some(n) = val.as_f64() {
                counters.push((k.clone(), n));
            }
        }
    }
    let mut speeds = Vec::new();
    if let Some(arr) = sidecar.and_then(|d| d.get("speeds")).and_then(|s| s.as_arr()) {
        for row in arr {
            let (Some(tick), Some(server), Some(believed)) = (
                row.get("tick").and_then(|x| x.as_usize()),
                row.get("server").and_then(|x| x.as_usize()),
                row.get("believed").and_then(|x| x.as_f64()),
            ) else {
                continue;
            };
            let observed = row.get("observed").and_then(|x| x.as_f64());
            speeds.push((tick, server, believed, observed));
        }
    }
    let mut lineage = Vec::new();
    if let Some(arr) = sidecar.and_then(|d| d.get("lineage")).and_then(|s| s.as_arr()) {
        for row in arr {
            lineage.push(
                LineageEvent::from_json(row).context("malformed lineage sidecar row")?,
            );
        }
    }
    Ok(TraceFile { clock, spans, counters, speeds, lineage })
}

/// Read + parse a trace file from disk.
pub fn read_trace(path: &Path) -> Result<TraceFile> {
    let v = json::parse_file(path).with_context(|| format!("parsing {}", path.display()))?;
    parse_trace(&v)
}

/// Structural validation of a span set: every non-tick span must nest
/// inside its tick's container span, and on any single thread row no
/// `compute` span may overlap a `wire_wait` span (nor another
/// `compute`) — the invariants the sequential-packing exporter
/// guarantees and CI asserts on real soak traces.
pub fn validate(spans: &[Span]) -> Result<()> {
    const EPS: f64 = 1e-9;
    let mut tick_window: std::collections::BTreeMap<usize, (f64, f64)> = Default::default();
    for s in spans {
        if s.phase == Phase::Tick {
            anyhow::ensure!(
                tick_window.insert(s.tick, (s.start_s, s.start_s + s.dur_s)).is_none(),
                "duplicate tick span for tick {}",
                s.tick
            );
        }
    }
    let mut busy: std::collections::BTreeMap<usize, Vec<(f64, f64, Phase, usize)>> =
        Default::default();
    for s in spans {
        if s.phase == Phase::Tick {
            continue;
        }
        let (lo, hi) = *tick_window
            .get(&s.tick)
            .with_context(|| format!("span in tick {} has no tick container", s.tick))?;
        anyhow::ensure!(
            s.start_s + EPS >= lo && s.start_s + s.dur_s <= hi + EPS,
            "{} span [{:.9}, {:.9}] escapes tick {} [{lo:.9}, {hi:.9}]",
            s.phase.name(),
            s.start_s,
            s.start_s + s.dur_s,
            s.tick,
        );
        if matches!(s.phase, Phase::Compute | Phase::WireWait) {
            if let Some(srv) = s.server {
                busy.entry(srv).or_default().push((
                    s.start_s,
                    s.start_s + s.dur_s,
                    s.phase,
                    s.tick,
                ));
            }
        }
    }
    for (srv, mut iv) in busy {
        iv.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        for w in iv.windows(2) {
            anyhow::ensure!(
                w[0].1 <= w[1].0 + EPS,
                "server {srv}: {} [{:.9}, {:.9}] (tick {}) overlaps {} [{:.9}, {:.9}] (tick {})",
                w[0].2.name(),
                w[0].0,
                w[0].1,
                w[0].3,
                w[1].2.name(),
                w[1].0,
                w[1].1,
                w[1].3,
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(phase: Phase, tick: usize, server: Option<usize>, start: f64, dur: f64) -> Span {
        Span { phase, tick, wave: 0, server, task_tag: None, start_s: start, dur_s: dur }
    }

    #[test]
    fn export_parse_roundtrip_preserves_spans() {
        let r = Recorder::new_virtual();
        r.tick_window(0, 0.0, 2.0);
        r.push_span(Span {
            phase: Phase::Compute,
            tick: 0,
            wave: 1,
            server: Some(2),
            task_tag: Some(99),
            start_s: 0.25,
            dur_s: 1.0,
        });
        r.counter("evictions", 3.0);
        r.speed_sample(0, 2, 0.5, Some(0.45));
        let v = export(&r);
        let parsed = parse_trace(&v).unwrap();
        assert_eq!(parsed.clock, ClockSource::Virtual);
        assert_eq!(parsed.counters, vec![("evictions".to_string(), 3.0)]);
        assert_eq!(parsed.speeds, vec![(0, 2, 0.5, Some(0.45))]);
        let c = parsed.spans.iter().find(|s| s.phase == Phase::Compute).unwrap();
        assert_eq!((c.tick, c.wave, c.server, c.task_tag), (0, 1, Some(2), Some(99)));
        assert!((c.start_s - 0.25).abs() < 1e-12 && (c.dur_s - 1.0).abs() < 1e-12);
        let t = parsed.spans.iter().find(|s| s.phase == Phase::Tick).unwrap();
        assert!((t.dur_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lineage_sidecar_roundtrips_and_is_optional() {
        use crate::obs::lineage::{LineageStage, RedispatchReason};
        let r = Recorder::new_virtual();
        r.tick_window(0, 0.0, 1.0);
        r.lineage_planned(0, 42, 1, 1024.0);
        r.lineage_dispatched(0, 0, 42, 1, 7);
        let hop = r.lineage_redispatched(0, 0, 42, 1, 2, RedispatchReason::Kill);
        assert_eq!(hop, 1);
        let parsed = parse_trace(&export(&r)).unwrap();
        assert_eq!(parsed.lineage.len(), 3);
        assert_eq!(parsed.lineage, r.lineage_events());
        assert!(matches!(
            parsed.lineage[2].stage,
            LineageStage::Redispatched { from: 1, to: 2, reason: RedispatchReason::Kill, hop: 1 }
        ));
        // Pre-lineage trace files parse with an empty log.
        let legacy = Json::obj(vec![("traceEvents", Json::Arr(vec![]))]);
        assert!(parse_trace(&legacy).unwrap().lineage.is_empty());
    }

    #[test]
    fn validate_accepts_nested_disjoint_spans() {
        let spans = vec![
            span(Phase::Tick, 0, None, 0.0, 10.0),
            span(Phase::Compute, 0, Some(0), 1.0, 3.0),
            span(Phase::WireWait, 0, Some(0), 4.0, 2.0),
            span(Phase::Gather, 0, Some(0), 6.0, 4.0),
        ];
        validate(&spans).unwrap();
    }

    #[test]
    fn validate_rejects_span_escaping_its_tick() {
        let spans = vec![
            span(Phase::Tick, 0, None, 0.0, 1.0),
            span(Phase::Compute, 0, Some(0), 0.5, 1.0),
        ];
        assert!(validate(&spans).is_err());
    }

    #[test]
    fn validate_rejects_compute_overlapping_wire_wait() {
        let spans = vec![
            span(Phase::Tick, 0, None, 0.0, 10.0),
            span(Phase::Compute, 0, Some(1), 1.0, 3.0),
            span(Phase::WireWait, 0, Some(1), 2.0, 3.0),
        ];
        assert!(validate(&spans).is_err());
    }

    #[test]
    fn validate_rejects_orphan_span() {
        let spans = vec![span(Phase::Compute, 4, Some(0), 0.0, 1.0)];
        assert!(validate(&spans).is_err());
    }

    #[test]
    fn exported_recorder_spans_validate() {
        let r = Recorder::new_wall();
        r.tick_begin(0);
        std::thread::sleep(std::time::Duration::from_millis(2));
        r.task_completed(0, 0, 0, 11, 0.001);
        r.task_completed(0, 0, 1, 12, 0.0005);
        r.tick_end(0);
        validate(&r.spans()).unwrap();
        // And they still validate after a disk-format roundtrip.
        let parsed = parse_trace(&export(&r)).unwrap();
        validate(&parsed.spans).unwrap();
    }
}
