//! Perf-trajectory drift detection for the committed `BENCH_*.json`
//! snapshots.
//!
//! CI regenerates each snapshot under the pinned `DISTCA_SEED` and
//! compares it against the committed baseline with
//! [`compare`]: the *schema* must match exactly (same keys, same array
//! shapes, same value kinds), and every numeric leaf must stay within
//! a relative tolerance (default 20%). Keys named in the skip list are
//! exempt from the numeric check (but not the schema check) — that is
//! where wall-clock-dependent fields like a soak's `makespan_s` live,
//! since they legitimately vary run-to-run while everything seeded
//! stays bit-identical.
//!
//! `distca drift --baseline a.json --candidate b.json` is the CLI
//! front-end; it exits non-zero when violations are found.

use crate::util::json::Json;

/// Drift-comparison knobs.
#[derive(Debug, Clone)]
pub struct DriftCfg {
    /// Max relative deviation for numeric leaves (0.2 = ±20%).
    pub tolerance: f64,
    /// Leaf key names exempt from the numeric check (wall-clock
    /// fields). Schema presence is still enforced.
    pub skip_keys: Vec<String>,
}

impl Default for DriftCfg {
    fn default() -> Self {
        DriftCfg { tolerance: 0.2, skip_keys: wall_clock_keys() }
    }
}

/// The wall-clock-dependent leaf keys present in the repo's committed
/// snapshots: timing measured on the host, never comparable run-to-run.
/// Two families live here:
///
/// * directly measured durations (`makespan_s`, `compute_s`, …);
/// * counters whose value is *decided by* wall-clock racing — how many
///   completions happened to land while a wave was still dispatching
///   (`overlap_gathered`), whether a gather deadline fired before a
///   straggler's response (`redispatched`, `send_failovers`), how many
///   late frames crossed a wave boundary (`stale_wave_frames`), how
///   many tasks were remapped vs re-sent when EOF evidence landed
///   (`remapped`), the byte totals that shift when a re-dispatch
///   changes who computed what (`bytes_dispatched`,
///   `peak_server_bytes`), and the wave epochs themselves — the pool
///   epoch also advances on health-verdict demotions, which are
///   wall-clock decisions (`wave_epoch_ping`/`wave_epoch_pong`).
///
/// Everything seeded — task counts, alive counts, scripted
/// kill/rejoin/mid-wave totals, the bit-exact verdict — stays under
/// the full ±tolerance comparison.
pub fn wall_clock_keys() -> Vec<String> {
    [
        "makespan_s",
        "elapsed_s",
        "hb_ewma_s",
        "wall_s",
        "elapsed_ms",
        "compute_s",
        "wire_wait_s",
        "overlap_efficiency",
        "overlap_gathered",
        "total_overlap_gathered",
        "stale_wave_frames",
        "total_stale_wave_frames",
        "redispatched",
        "remapped",
        "wave_epoch_ping",
        "wave_epoch_pong",
        "wave_redispatched_ping",
        "wave_redispatched_pong",
        "total_redispatched",
        "send_failovers",
        "total_send_failovers",
        "bytes_dispatched",
        "peak_server_bytes",
        // Kernel-benchmark timing and its derived ratios
        // (`BENCH_kernel.json`): host-dependent throughput, never
        // comparable across machines. The committed baseline pins the
        // *schema* (and the seeded `bit_exact`/shape leaves), not the
        // speed of the CI box.
        "tokens_per_s",
        "avx2_detected",
        "mean_s",
        "gflops",
        "speedup_vs_oracle",
        "tasks_per_s",
        "speedup_vs_1t",
        "parallel_efficiency",
        // SLO latency accounting (`BENCH_gateway.json` class rows):
        // per-task latency is host wall-time against a fixed target, so
        // breach counts and everything derived from them race the CI
        // box's clock. `latency_tasks` (= completions per class) and
        // the target itself stay under the full comparison.
        "latency_breaches",
        "burn_rate",
        "mean_latency_s",
        "max_latency_s",
        // Recorder-overhead microbench (`BENCH_obs.json`): two timed
        // passes over the same batch plus their ratio — host speed, not
        // schema. The seeded event/sample counts stay checked.
        "obs_off_s",
        "obs_on_s",
        "overhead_pct",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

fn kind(v: &Json) -> &'static str {
    match v {
        Json::Null => "null",
        Json::Bool(_) => "bool",
        Json::Num(_) => "number",
        Json::Str(_) => "string",
        Json::Arr(_) => "array",
        Json::Obj(_) => "object",
    }
}

/// Compare `candidate` against `baseline`; returns human-readable
/// violations (empty = within tolerance). Never panics on malformed
/// shapes — mismatches are violations, not errors.
pub fn compare(baseline: &Json, candidate: &Json, cfg: &DriftCfg) -> Vec<String> {
    let mut out = Vec::new();
    walk(baseline, candidate, "$", cfg, &mut out);
    out
}

fn walk(b: &Json, c: &Json, path: &str, cfg: &DriftCfg, out: &mut Vec<String>) {
    if kind(b) != kind(c) {
        out.push(format!("{path}: kind changed {} -> {}", kind(b), kind(c)));
        return;
    }
    match (b, c) {
        (Json::Obj(bf), Json::Obj(cf)) => {
            for (k, bv) in bf {
                match cf.iter().find(|(ck, _)| ck == k) {
                    None => out.push(format!("{path}.{k}: missing from candidate")),
                    Some((_, cv)) => walk(bv, cv, &format!("{path}.{k}"), cfg, out),
                }
            }
            for (k, _) in cf {
                if !bf.iter().any(|(bk, _)| bk == k) {
                    out.push(format!("{path}.{k}: not in baseline (schema grew)"));
                }
            }
        }
        (Json::Arr(ba), Json::Arr(ca)) => {
            if ba.len() != ca.len() {
                out.push(format!("{path}: array length {} -> {}", ba.len(), ca.len()));
                return;
            }
            for (i, (bv, cv)) in ba.iter().zip(ca).enumerate() {
                walk(bv, cv, &format!("{path}[{i}]"), cfg, out);
            }
        }
        (Json::Num(bn), Json::Num(cn)) => {
            let leaf = path.rsplit('.').next().unwrap_or(path);
            let leaf = leaf.split('[').next().unwrap_or(leaf);
            if cfg.skip_keys.iter().any(|k| k == leaf) {
                return;
            }
            let denom = bn.abs().max(cn.abs());
            let diff = (bn - cn).abs();
            if diff > cfg.tolerance * denom + 1e-9 {
                out.push(format!(
                    "{path}: {bn} -> {cn} ({:+.1}% exceeds ±{:.0}%)",
                    if bn.abs() > 0.0 { 100.0 * (cn - bn) / bn.abs() } else { f64::INFINITY },
                    100.0 * cfg.tolerance,
                ));
            }
        }
        (Json::Str(bs), Json::Str(cs)) => {
            if bs != cs {
                out.push(format!("{path}: \"{bs}\" -> \"{cs}\""));
            }
        }
        (Json::Bool(bb), Json::Bool(cb)) => {
            if bb != cb {
                out.push(format!("{path}: {bb} -> {cb}"));
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn identical_documents_have_no_drift() {
        let v = parse(r#"{"a": 1.0, "b": [1, 2, {"c": "x"}]}"#).unwrap();
        assert!(compare(&v, &v, &DriftCfg::default()).is_empty());
    }

    #[test]
    fn within_tolerance_passes_beyond_fails() {
        let b = parse(r#"{"t": 100.0}"#).unwrap();
        let ok = parse(r#"{"t": 115.0}"#).unwrap();
        let bad = parse(r#"{"t": 130.0}"#).unwrap();
        let cfg = DriftCfg { tolerance: 0.2, skip_keys: vec![] };
        assert!(compare(&b, &ok, &cfg).is_empty());
        let v = compare(&b, &bad, &cfg);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("$.t"), "{v:?}");
    }

    #[test]
    fn schema_changes_are_violations() {
        let b = parse(r#"{"a": 1, "arr": [1, 2]}"#).unwrap();
        let missing = parse(r#"{"arr": [1, 2]}"#).unwrap();
        let grew = parse(r#"{"a": 1, "arr": [1, 2], "new": 0}"#).unwrap();
        let reshaped = parse(r#"{"a": 1, "arr": [1, 2, 3]}"#).unwrap();
        let retyped = parse(r#"{"a": "1", "arr": [1, 2]}"#).unwrap();
        let cfg = DriftCfg::default();
        for (c, what) in
            [(missing, "missing"), (grew, "grew"), (reshaped, "length"), (retyped, "kind")]
        {
            let v = compare(&b, &c, &cfg);
            assert!(!v.is_empty(), "{what} should be flagged");
        }
    }

    #[test]
    fn wall_clock_keys_are_exempt_from_tolerance_not_schema() {
        let b = parse(r#"{"makespan_s": 1.0}"#).unwrap();
        let c = parse(r#"{"makespan_s": 50.0}"#).unwrap();
        assert!(compare(&b, &c, &DriftCfg::default()).is_empty());
        // But deleting the key is still a schema violation.
        let gone = parse(r#"{}"#).unwrap();
        assert!(!compare(&b, &gone, &DriftCfg::default()).is_empty());
    }

    #[test]
    fn array_indexing_does_not_defeat_skip_keys() {
        // A skipped leaf inside an array of objects stays skipped.
        let b = parse(r#"{"per_tick": [{"makespan_s": 1.0}, {"makespan_s": 2.0}]}"#).unwrap();
        let c = parse(r#"{"per_tick": [{"makespan_s": 9.0}, {"makespan_s": 0.1}]}"#).unwrap();
        assert!(compare(&b, &c, &DriftCfg::default()).is_empty());
    }
}
