//! Per-CA-task causal lineage: the event trail that answers *why* a
//! specific task was slow.
//!
//! The PR-6 span plane aggregates per tick; the paper's straggler claim
//! is per *task*. Every task now leaves a causal trace through the
//! recorder:
//!
//! ```text
//! planned(server, cost)
//!   → dispatched(server, trace_id)          // one per physical send
//!   → redispatched(from, to, reason, hop)   // reason: kill|drain|oom|speculative
//!   → completed(server, latency) | stale-deduped(server)
//! ```
//!
//! Events are recorded at exactly the sites that bump the corresponding
//! [`crate::elastic::failover::TickStats`] counters, so per-tick hop
//! totals by reason equal `oom_evicted` / `drain_redirected` /
//! `send_failovers` / `redispatched` by construction — the conformance
//! suite holds that equality.
//!
//! On the TCP fabric each physical dispatch additionally carries a
//! compact wire **trace id** in the DCA3 frame header, echoed by the
//! worker on its response ([`crate::net::codec`]); the serve loop feeds
//! the echoes back as [`LineageStage::WireEcho`] events, attributing a
//! completion to the exact dispatch hop that produced it (under
//! first-response-wins dedup the *original* dispatch can win even after
//! a speculative re-dispatch — the echo is how the report can tell).
//!
//! The whole log serializes into the Chrome-trace sidecar
//! ([`crate::obs::trace`]), and `distca report --lineage` reconstructs
//! each task's journey ([`journeys`]) into a straggler root-cause
//! table.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Why a task was sent a second (or third…) time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RedispatchReason {
    /// A dead connection surfaced by a failed send (the send-failover
    /// path): the destination was killed under the task.
    Kill,
    /// The planned server is draining; the unstarted tail of its queue
    /// is redirected.
    Drain,
    /// The destination's arena overflowed; the evicted tail is re-sent
    /// to servers with headroom.
    Oom,
    /// A gather-deadline suspicion: the holder went quiet past its
    /// size-scaled deadline and the task was speculatively re-sent.
    Speculative,
}

impl RedispatchReason {
    pub fn name(self) -> &'static str {
        match self {
            RedispatchReason::Kill => "kill",
            RedispatchReason::Drain => "drain",
            RedispatchReason::Oom => "oom",
            RedispatchReason::Speculative => "speculative",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        Some(match s {
            "kill" => RedispatchReason::Kill,
            "drain" => RedispatchReason::Drain,
            "oom" => RedispatchReason::Oom,
            "speculative" => RedispatchReason::Speculative,
            _ => return None,
        })
    }

    pub const ALL: [RedispatchReason; 4] = [
        RedispatchReason::Kill,
        RedispatchReason::Drain,
        RedispatchReason::Oom,
        RedispatchReason::Speculative,
    ];
}

/// One step in a task's journey.
#[derive(Debug, Clone, PartialEq)]
pub enum LineageStage {
    /// The plan assigned this task to `server`; `cost_pairs` is the
    /// predicted cost (`q_len × kv_len` causal pairs) the balancer
    /// planned against.
    Planned { server: usize, cost_pairs: f64 },
    /// One physical send landed the task's bytes at `server`. `trace`
    /// is the wire trace id stamped into the DCA3 frame header (0 on
    /// in-process fabrics, which need no wire stamp).
    Dispatched { server: usize, trace: u64 },
    /// The task was sent again: `hop` is 1 for the first re-dispatch
    /// of the task within its tick, 2 for the second, …
    Redispatched { from: usize, to: usize, reason: RedispatchReason, hop: u32 },
    /// First kept response, from `server`, `latency_s` after the
    /// task's most recent dispatch.
    Completed { server: usize, latency_s: f64 },
    /// A duplicate response suppressed by first-response-wins dedup.
    StaleDeduped { server: usize },
    /// The worker-echoed wire trace id observed on the winning
    /// response frame (TCP path only): names the dispatch that won.
    WireEcho { trace: u64 },
}

impl LineageStage {
    pub fn name(&self) -> &'static str {
        match self {
            LineageStage::Planned { .. } => "planned",
            LineageStage::Dispatched { .. } => "dispatched",
            LineageStage::Redispatched { .. } => "redispatched",
            LineageStage::Completed { .. } => "completed",
            LineageStage::StaleDeduped { .. } => "stale-deduped",
            LineageStage::WireEcho { .. } => "wire-echo",
        }
    }
}

/// One lineage event: a task (`tag`) hit `stage` at recorder time
/// `t_s`, during `tick`/`wave`.
#[derive(Debug, Clone, PartialEq)]
pub struct LineageEvent {
    pub tick: usize,
    pub wave: usize,
    pub tag: u64,
    pub t_s: f64,
    pub stage: LineageStage,
}

impl LineageEvent {
    /// Sidecar serialization. The tag is hex — task tags use up to 62
    /// bits and a JSON `f64` is exact only to 2^53.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("ev", Json::Str(self.stage.name().into())),
            ("tick", Json::Num(self.tick as f64)),
            ("wave", Json::Num(self.wave as f64)),
            ("tag", Json::Str(format!("{:016x}", self.tag))),
            ("t_s", Json::Num(self.t_s)),
        ];
        match &self.stage {
            LineageStage::Planned { server, cost_pairs } => {
                fields.push(("server", Json::Num(*server as f64)));
                fields.push(("cost_pairs", Json::Num(*cost_pairs)));
            }
            LineageStage::Dispatched { server, trace } => {
                fields.push(("server", Json::Num(*server as f64)));
                fields.push(("trace", Json::Str(format!("{trace:016x}"))));
            }
            LineageStage::Redispatched { from, to, reason, hop } => {
                fields.push(("from", Json::Num(*from as f64)));
                fields.push(("to", Json::Num(*to as f64)));
                fields.push(("reason", Json::Str(reason.name().into())));
                fields.push(("hop", Json::Num(*hop as f64)));
            }
            LineageStage::Completed { server, latency_s } => {
                fields.push(("server", Json::Num(*server as f64)));
                fields.push(("latency_s", Json::Num(*latency_s)));
            }
            LineageStage::StaleDeduped { server } => {
                fields.push(("server", Json::Num(*server as f64)));
            }
            LineageStage::WireEcho { trace } => {
                fields.push(("trace", Json::Str(format!("{trace:016x}"))));
            }
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<LineageEvent> {
        let ev = v.req("ev")?.as_str().context("`ev` is not a string")?.to_string();
        let num = |key: &str| -> Result<f64> {
            v.req(key)?.as_f64().with_context(|| format!("`{key}` is not a number"))
        };
        let srv = |key: &str| -> Result<usize> { Ok(num(key)? as usize) };
        let hex = |key: &str| -> Result<u64> {
            let s = v.req(key)?.as_str().with_context(|| format!("`{key}` is not a string"))?;
            u64::from_str_radix(s, 16).with_context(|| format!("bad hex in `{key}`: {s:?}"))
        };
        let stage = match ev.as_str() {
            "planned" => LineageStage::Planned {
                server: srv("server")?,
                cost_pairs: num("cost_pairs")?,
            },
            "dispatched" => {
                LineageStage::Dispatched { server: srv("server")?, trace: hex("trace")? }
            }
            "redispatched" => LineageStage::Redispatched {
                from: srv("from")?,
                to: srv("to")?,
                reason: RedispatchReason::from_name(
                    v.req("reason")?.as_str().context("`reason` is not a string")?,
                )
                .context("unknown redispatch reason")?,
                hop: num("hop")? as u32,
            },
            "completed" => LineageStage::Completed {
                server: srv("server")?,
                latency_s: num("latency_s")?,
            },
            "stale-deduped" => LineageStage::StaleDeduped { server: srv("server")? },
            "wire-echo" => LineageStage::WireEcho { trace: hex("trace")? },
            other => bail!("unknown lineage event kind {other:?}"),
        };
        Ok(LineageEvent {
            tick: num("tick")? as usize,
            wave: num("wave")? as usize,
            tag: hex("tag")?,
            t_s: num("t_s")?,
            stage,
        })
    }
}

/// A task's reconstructed journey: the per-task row `report --lineage`
/// renders and the conformance suite audits against `TickStats`.
#[derive(Debug, Clone, Default)]
pub struct TaskJourney {
    pub tick: usize,
    pub wave: usize,
    pub tag: u64,
    /// Plan-time assignment (first `planned` event), if recorded.
    pub planned_server: Option<usize>,
    pub cost_pairs: f64,
    /// Every physical send, in order: `(server, wire trace id)`.
    pub dispatches: Vec<(usize, u64)>,
    /// Every re-dispatch, in order.
    pub redispatches: Vec<(RedispatchReason, usize, usize, u32)>,
    /// `(server, latency_s)` of the first kept response.
    pub completed: Option<(usize, f64)>,
    /// Duplicate responses suppressed by dedup.
    pub stale_duplicates: u32,
    /// Worker-echoed trace id on the winning response (TCP path).
    pub winning_trace: Option<u64>,
}

impl TaskJourney {
    /// Hop count: number of re-dispatches this task suffered.
    pub fn hops(&self) -> u32 {
        self.redispatches.len() as u32
    }

    /// Short human rendering of the re-dispatch chain, e.g.
    /// `"kill→speculative"`.
    pub fn reason_chain(&self) -> String {
        if self.redispatches.is_empty() {
            return "-".into();
        }
        self.redispatches
            .iter()
            .map(|(r, _, _, _)| r.name())
            .collect::<Vec<_>>()
            .join("\u{2192}")
    }

    /// Which dispatch won, if the wire echo identified it: index into
    /// `dispatches` (0 = the original send).
    pub fn winning_hop(&self) -> Option<usize> {
        let t = self.winning_trace?;
        self.dispatches.iter().position(|&(_, tr)| tr == t)
    }
}

/// Group a lineage log into per-`(tick, tag)` journeys, ordered by
/// (tick, tag).
pub fn journeys(events: &[LineageEvent]) -> Vec<TaskJourney> {
    let mut map: BTreeMap<(usize, u64), TaskJourney> = BTreeMap::new();
    for ev in events {
        let j = map.entry((ev.tick, ev.tag)).or_insert_with(|| TaskJourney {
            tick: ev.tick,
            wave: ev.wave,
            tag: ev.tag,
            ..TaskJourney::default()
        });
        match &ev.stage {
            LineageStage::Planned { server, cost_pairs } => {
                if j.planned_server.is_none() {
                    j.planned_server = Some(*server);
                }
                j.cost_pairs = *cost_pairs;
            }
            LineageStage::Dispatched { server, trace } => {
                j.dispatches.push((*server, *trace));
            }
            LineageStage::Redispatched { from, to, reason, hop } => {
                j.wave = ev.wave;
                j.redispatches.push((*reason, *from, *to, *hop));
            }
            LineageStage::Completed { server, latency_s } => {
                if j.completed.is_none() {
                    j.completed = Some((*server, *latency_s));
                }
            }
            LineageStage::StaleDeduped { .. } => j.stale_duplicates += 1,
            LineageStage::WireEcho { trace } => {
                if j.winning_trace.is_none() && *trace != 0 {
                    j.winning_trace = Some(*trace);
                }
            }
        }
    }
    map.into_values().collect()
}

/// Per-tick re-dispatch totals by reason, derived from the lineage log
/// — the numbers that must equal the `TickStats` counters.
pub fn hop_totals(events: &[LineageEvent]) -> BTreeMap<usize, BTreeMap<RedispatchReason, u64>> {
    let mut out: BTreeMap<usize, BTreeMap<RedispatchReason, u64>> = BTreeMap::new();
    for ev in events {
        if let LineageStage::Redispatched { reason, .. } = ev.stage {
            *out.entry(ev.tick).or_default().entry(reason).or_insert(0) += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_events() -> Vec<LineageEvent> {
        vec![
            LineageEvent {
                tick: 3,
                wave: 0,
                tag: 0x2000_0001_0000_0040,
                t_s: 0.001,
                stage: LineageStage::Planned { server: 1, cost_pairs: 4096.0 },
            },
            LineageEvent {
                tick: 3,
                wave: 0,
                tag: 0x2000_0001_0000_0040,
                t_s: 0.002,
                stage: LineageStage::Dispatched { server: 1, trace: 7 },
            },
            LineageEvent {
                tick: 3,
                wave: 0,
                tag: 0x2000_0001_0000_0040,
                t_s: 0.050,
                stage: LineageStage::Redispatched {
                    from: 1,
                    to: 2,
                    reason: RedispatchReason::Speculative,
                    hop: 1,
                },
            },
            LineageEvent {
                tick: 3,
                wave: 0,
                tag: 0x2000_0001_0000_0040,
                t_s: 0.051,
                stage: LineageStage::Dispatched { server: 2, trace: 8 },
            },
            LineageEvent {
                tick: 3,
                wave: 0,
                tag: 0x2000_0001_0000_0040,
                t_s: 0.060,
                stage: LineageStage::Completed { server: 2, latency_s: 0.009 },
            },
            LineageEvent {
                tick: 3,
                wave: 0,
                tag: 0x2000_0001_0000_0040,
                t_s: 0.070,
                stage: LineageStage::StaleDeduped { server: 1 },
            },
            LineageEvent {
                tick: 3,
                wave: 0,
                tag: 0x2000_0001_0000_0040,
                t_s: 0.061,
                stage: LineageStage::WireEcho { trace: 8 },
            },
        ]
    }

    #[test]
    fn events_roundtrip_through_json() {
        for ev in sample_events() {
            let back = LineageEvent::from_json(&ev.to_json()).unwrap();
            assert_eq!(back, ev);
        }
    }

    #[test]
    fn journeys_reconstruct_the_chain() {
        let js = journeys(&sample_events());
        assert_eq!(js.len(), 1);
        let j = &js[0];
        assert_eq!(j.planned_server, Some(1));
        assert_eq!(j.dispatches, vec![(1, 7), (2, 8)]);
        assert_eq!(j.hops(), 1);
        assert_eq!(j.reason_chain(), "speculative");
        assert_eq!(j.completed, Some((2, 0.009)));
        assert_eq!(j.stale_duplicates, 1);
        assert_eq!(j.winning_hop(), Some(1));
    }

    #[test]
    fn hop_totals_group_by_tick_and_reason() {
        let totals = hop_totals(&sample_events());
        assert_eq!(totals[&3][&RedispatchReason::Speculative], 1);
        assert_eq!(totals[&3].get(&RedispatchReason::Kill), None);
    }

    #[test]
    fn unknown_event_kinds_are_rejected() {
        let v = crate::util::json::parse(
            r#"{"ev":"teleported","tick":0,"wave":0,"tag":"00","t_s":0}"#,
        )
        .unwrap();
        assert!(LineageEvent::from_json(&v).is_err());
    }
}
