//! Straggler attribution: turn a trace into the Fig. 11-style per-tick
//! overlap table.
//!
//! For every tick the report breaks each server's share of the tick
//! wall-time into `compute` / `wire_wait` / `gather_idle` seconds (the
//! three sum to the tick time by the recorder's phase-accounting
//! identity — see the [module docs](super)), then derives:
//!
//! * **max/mean imbalance** — slowest server's compute over the mean:
//!   the straggler amplitude the paper's balanced dispatch eliminates;
//! * **overlap efficiency** — total compute over total busy
//!   (compute + wire-wait): how much of the wire time is hidden;
//! * **believed-vs-observed divergence** — how far the coordinator's
//!   planning beliefs drifted from the health EWMA's observations, the
//!   quantity that should shrink as `health.rs` demotions converge.
//!
//! `distca report --trace f.json` renders this for any trace the
//! exporter wrote — threaded, networked, or virtual-time simulated.
//!
//! The report command's second input is the gateway's accounting
//! stream: `distca report --gateway acct.jsonl` renders the per-tenant
//! table ([`render_gateway_accounting`]) from a `--accounting-out`
//! file, refusing truncated streams (no trailing `flush` record).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::util::json::Json;
use crate::util::tables::{bytes, f, secs, Table};

use super::trace::TraceFile;
use super::{ClockSource, Phase};

/// One server's phase split within one tick.
#[derive(Debug, Clone, PartialEq)]
pub struct ServerPhases {
    pub server: usize,
    pub compute_s: f64,
    pub wire_wait_s: f64,
    pub gather_idle_s: f64,
}

impl ServerPhases {
    /// Total accounted seconds (== tick time on wall traces).
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.wire_wait_s + self.gather_idle_s
    }
}

/// One tick's attribution.
#[derive(Debug, Clone)]
pub struct TickBreakdown {
    pub tick: usize,
    pub tick_s: f64,
    pub servers: Vec<ServerPhases>,
    pub redispatched: usize,
    pub evicted: usize,
    /// max server compute / mean server compute (1.0 = perfectly flat).
    pub max_imbalance: f64,
    /// Mean relative |believed − observed| speed error over servers
    /// with an observation this tick.
    pub speed_divergence: Option<f64>,
}

impl TickBreakdown {
    /// Compute seconds over busy (compute + wire-wait) seconds: the
    /// fraction of on-wire time hidden behind compute.
    pub fn overlap_efficiency(&self) -> f64 {
        let compute: f64 = self.servers.iter().map(|s| s.compute_s).sum();
        let busy: f64 = self.servers.iter().map(|s| s.compute_s + s.wire_wait_s).sum();
        if busy <= 0.0 {
            return 1.0;
        }
        compute / busy
    }
}

/// The full per-tick attribution of one trace.
#[derive(Debug, Clone)]
pub struct TraceReport {
    pub clock: ClockSource,
    pub ticks: Vec<TickBreakdown>,
    pub counters: Vec<(String, f64)>,
}

/// Aggregate a parsed trace into per-tick, per-server phase seconds.
pub fn breakdown(trace: &TraceFile) -> Result<TraceReport> {
    let mut tick_s: BTreeMap<usize, f64> = BTreeMap::new();
    let mut phases: BTreeMap<usize, BTreeMap<usize, ServerPhases>> = BTreeMap::new();
    let mut redispatched: BTreeMap<usize, usize> = BTreeMap::new();
    let mut evicted: BTreeMap<usize, usize> = BTreeMap::new();
    for s in &trace.spans {
        match s.phase {
            Phase::Tick => {
                tick_s.insert(s.tick, s.dur_s);
            }
            Phase::Compute | Phase::WireWait | Phase::Gather => {
                let Some(srv) = s.server else { continue };
                let e = phases.entry(s.tick).or_default().entry(srv).or_insert(ServerPhases {
                    server: srv,
                    compute_s: 0.0,
                    wire_wait_s: 0.0,
                    gather_idle_s: 0.0,
                });
                match s.phase {
                    Phase::Compute => e.compute_s += s.dur_s,
                    Phase::WireWait => e.wire_wait_s += s.dur_s,
                    _ => e.gather_idle_s += s.dur_s,
                }
            }
            Phase::Redispatch => *redispatched.entry(s.tick).or_insert(0) += 1,
            Phase::Evict => *evicted.entry(s.tick).or_insert(0) += 1,
            Phase::Plan | Phase::Dispatch => {}
        }
    }
    // Divergence per tick from the sidecar speed samples.
    let mut divergence: BTreeMap<usize, (f64, usize)> = BTreeMap::new();
    for &(tick, _server, believed, observed) in &trace.speeds {
        if let Some(obs) = observed {
            if believed > 0.0 {
                let d = divergence.entry(tick).or_insert((0.0, 0));
                d.0 += (believed - obs).abs() / believed;
                d.1 += 1;
            }
        }
    }
    let mut ticks = Vec::new();
    for (&tick, &dur) in &tick_s {
        let servers: Vec<ServerPhases> =
            phases.remove(&tick).map(|m| m.into_values().collect()).unwrap_or_default();
        let computes: Vec<f64> = servers.iter().map(|s| s.compute_s).collect();
        let mean = if computes.is_empty() {
            0.0
        } else {
            computes.iter().sum::<f64>() / computes.len() as f64
        };
        let max = computes.iter().cloned().fold(0.0f64, f64::max);
        let max_imbalance = if mean > 0.0 { max / mean } else { 1.0 };
        ticks.push(TickBreakdown {
            tick,
            tick_s: dur,
            servers,
            redispatched: redispatched.get(&tick).copied().unwrap_or(0),
            evicted: evicted.get(&tick).copied().unwrap_or(0),
            max_imbalance,
            speed_divergence: divergence
                .get(&tick)
                .map(|&(sum, n)| if n > 0 { sum / n as f64 } else { 0.0 }),
        });
    }
    Ok(TraceReport { clock: trace.clock, ticks, counters: trace.counters.clone() })
}

impl TraceReport {
    /// Render the Fig. 11-style overlap table: one row per
    /// (tick, server) with the phase split, plus a per-tick summary of
    /// imbalance, overlap efficiency, and belief divergence.
    pub fn render(&self) -> String {
        let mut per_server = Table::new(
            &format!("Per-server phase attribution ({} clock)", self.clock.name()),
            &["tick", "server", "compute", "wire_wait", "gather_idle", "compute %"],
        );
        for t in &self.ticks {
            for s in &t.servers {
                let pct = if t.tick_s > 0.0 { 100.0 * s.compute_s / t.tick_s } else { 0.0 };
                per_server.row(&[
                    t.tick.to_string(),
                    s.server.to_string(),
                    secs(s.compute_s),
                    secs(s.wire_wait_s),
                    secs(s.gather_idle_s),
                    f(pct, 1),
                ]);
            }
        }
        let mut summary = Table::new(
            "Per-tick summary",
            &["tick", "tick time", "servers", "redisp", "evict", "max/mean", "overlap", "belief err"],
        );
        for t in &self.ticks {
            summary.row(&[
                t.tick.to_string(),
                secs(t.tick_s),
                t.servers.len().to_string(),
                t.redispatched.to_string(),
                t.evicted.to_string(),
                f(t.max_imbalance, 2),
                f(t.overlap_efficiency(), 3),
                t.speed_divergence.map(|d| f(d, 3)).unwrap_or_else(|| "-".to_string()),
            ]);
        }
        format!("{}\n{}", per_server.render(), summary.render())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("clock", Json::Str(self.clock.name().to_string())),
            (
                "per_tick",
                Json::Arr(
                    self.ticks
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("tick", Json::Num(t.tick as f64)),
                                ("tick_s", Json::Num(t.tick_s)),
                                ("redispatched", Json::Num(t.redispatched as f64)),
                                ("evicted", Json::Num(t.evicted as f64)),
                                ("max_imbalance", Json::Num(t.max_imbalance)),
                                ("overlap_efficiency", Json::Num(t.overlap_efficiency())),
                                (
                                    "speed_divergence",
                                    t.speed_divergence.map(Json::Num).unwrap_or(Json::Null),
                                ),
                                (
                                    "servers",
                                    Json::Arr(
                                        t.servers
                                            .iter()
                                            .map(|s| {
                                                Json::obj(vec![
                                                    ("server", Json::Num(s.server as f64)),
                                                    ("compute_s", Json::Num(s.compute_s)),
                                                    ("wire_wait_s", Json::Num(s.wire_wait_s)),
                                                    (
                                                        "gather_idle_s",
                                                        Json::Num(s.gather_idle_s),
                                                    ),
                                                ])
                                            })
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "counters",
                Json::Obj(
                    self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect(),
                ),
            ),
        ])
    }
}

/// Render the per-tenant accounting table from a gateway
/// `--accounting-out` JSONL stream: the top-`top` tenants by admitted
/// tasks, plus the wave-level backpressure summary. The stream must end
/// with its `flush` record — a file without one came from a run that
/// died mid-write, and a partial table would silently under-report.
pub fn render_gateway_accounting(rows: &[Json], top: usize) -> Result<String> {
    fn num(r: &Json, k: &str) -> Result<f64> {
        r.get(k)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("accounting row missing numeric `{k}`"))
    }
    anyhow::ensure!(
        rows.last().and_then(|r| r.get("kind")).and_then(Json::as_str) == Some("flush"),
        "accounting stream ends without a flush record (truncated run?)"
    );
    let mut tenants: Vec<&Json> = Vec::new();
    let mut waves = 0usize;
    let mut saturated = 0usize;
    let mut max_backlog = 0.0f64;
    let mut admitted_total = 0.0f64;
    for r in rows {
        match r.get("kind").and_then(Json::as_str) {
            Some("tenant") => tenants.push(r),
            Some("wave") => {
                waves += 1;
                if r.get("saturated").and_then(Json::as_bool).unwrap_or(false) {
                    saturated += 1;
                }
                max_backlog = max_backlog.max(num(r, "backlog")?);
                admitted_total += num(r, "admitted")?;
            }
            Some("flush") => {}
            other => anyhow::bail!("unknown accounting row kind {other:?}"),
        }
    }
    let mut order: Vec<(f64, &Json)> = tenants
        .iter()
        .map(|r| Ok((num(r, "admitted")?, *r)))
        .collect::<Result<_>>()?;
    order.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
    let shown = order.len().min(top);
    let mut t = Table::new(
        &format!(
            "gateway per-tenant accounting: top {shown} of {} tenants by admitted tasks",
            order.len()
        ),
        &[
            "tenant", "slo", "arrived", "admitted", "completed", "rejected", "bytes",
            "flops", "mean wait", "max wait", "makespan", "redisp",
        ],
    );
    for (_, r) in order.iter().take(top) {
        t.row(&[
            format!("{}", num(r, "tenant")? as u64),
            r.get("slo").and_then(Json::as_str).unwrap_or("?").to_string(),
            format!("{}", num(r, "arrived")? as u64),
            format!("{}", num(r, "admitted")? as u64),
            format!("{}", num(r, "completed")? as u64),
            format!("{}", num(r, "rejected")? as u64),
            bytes(num(r, "bytes")?),
            format!("{:.2e}", num(r, "flops")?),
            f(num(r, "mean_wait_waves")?, 2),
            format!("{}", num(r, "max_wait_waves")? as u64),
            secs(num(r, "makespan_s")?),
            format!("{}", num(r, "redispatched")? as u64),
        ]);
    }
    Ok(format!(
        "{}\n{waves} waves ({saturated} saturated, max backlog {}) | {} tasks admitted",
        t.render(),
        max_backlog as u64,
        admitted_total as u64,
    ))
}

#[cfg(test)]
mod tests {
    use super::super::Span;
    use super::*;

    fn trace_with(spans: Vec<Span>) -> TraceFile {
        TraceFile { clock: ClockSource::Wall, spans, counters: vec![], speeds: vec![] }
    }

    fn span(phase: Phase, tick: usize, server: Option<usize>, start: f64, dur: f64) -> Span {
        Span { phase, tick, wave: 0, server, task_tag: None, start_s: start, dur_s: dur }
    }

    #[test]
    fn phases_sum_to_tick_time() {
        let t = trace_with(vec![
            span(Phase::Tick, 0, None, 0.0, 10.0),
            span(Phase::Compute, 0, Some(0), 1.0, 6.0),
            span(Phase::WireWait, 0, Some(0), 7.0, 2.0),
            span(Phase::Gather, 0, Some(0), 0.0, 1.0),
            span(Phase::Gather, 0, Some(0), 9.0, 1.0),
        ]);
        let r = breakdown(&t).unwrap();
        assert_eq!(r.ticks.len(), 1);
        let s = &r.ticks[0].servers[0];
        assert!((s.total_s() - 10.0).abs() < 1e-12);
        assert!((s.compute_s - 6.0).abs() < 1e-12);
        assert!((r.ticks[0].overlap_efficiency() - 6.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_is_max_over_mean_compute() {
        let t = trace_with(vec![
            span(Phase::Tick, 2, None, 0.0, 4.0),
            span(Phase::Compute, 2, Some(0), 0.0, 1.0),
            span(Phase::Compute, 2, Some(1), 0.0, 3.0),
        ]);
        let r = breakdown(&t).unwrap();
        assert!((r.ticks[0].max_imbalance - 1.5).abs() < 1e-12);
    }

    #[test]
    fn divergence_averages_relative_belief_error() {
        let mut t = trace_with(vec![span(Phase::Tick, 0, None, 0.0, 1.0)]);
        t.speeds = vec![(0, 0, 1.0, Some(0.5)), (0, 1, 1.0, None), (0, 2, 0.5, Some(0.5))];
        let r = breakdown(&t).unwrap();
        // Only the two observed samples count: (0.5 + 0.0) / 2.
        assert!((r.ticks[0].speed_divergence.unwrap() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn redispatch_and_evict_are_counted() {
        let t = trace_with(vec![
            span(Phase::Tick, 1, None, 0.0, 1.0),
            span(Phase::Redispatch, 1, Some(0), 0.5, 0.0),
            span(Phase::Redispatch, 1, Some(1), 0.6, 0.0),
            span(Phase::Evict, 1, Some(0), 0.7, 0.0),
        ]);
        let r = breakdown(&t).unwrap();
        assert_eq!((r.ticks[0].redispatched, r.ticks[0].evicted), (2, 1));
        // The table renders without panicking even with no compute.
        assert!(r.render().contains("Per-tick summary"));
    }

    fn tenant_row(id: f64, admitted: f64) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("tenant".into())),
            ("tenant", Json::Num(id)),
            ("slo", Json::Str("standard".into())),
            ("arrived", Json::Num(admitted)),
            ("admitted", Json::Num(admitted)),
            ("completed", Json::Num(admitted)),
            ("rejected", Json::Num(0.0)),
            ("bytes", Json::Num(64.0 * admitted)),
            ("flops", Json::Num(1e6 * admitted)),
            ("mean_wait_waves", Json::Num(0.5)),
            ("max_wait_waves", Json::Num(2.0)),
            ("makespan_s", Json::Num(0.25)),
            ("redispatched", Json::Num(0.0)),
        ])
    }

    #[test]
    fn gateway_accounting_renders_top_tenants() {
        let rows = vec![
            Json::obj(vec![
                ("kind", Json::Str("wave".into())),
                ("saturated", Json::Bool(true)),
                ("backlog", Json::Num(7.0)),
                ("admitted", Json::Num(11.0)),
            ]),
            tenant_row(3.0, 5.0),
            tenant_row(9.0, 6.0),
            Json::obj(vec![("kind", Json::Str("flush".into()))]),
        ];
        let out = render_gateway_accounting(&rows, 1).unwrap();
        // Top-1 by admitted is tenant 9; tenant 3 is summarized only.
        assert!(out.contains("top 1 of 2"), "{out}");
        assert!(out.contains("1 waves (1 saturated, max backlog 7)"), "{out}");
    }

    #[test]
    fn gateway_accounting_rejects_truncated_streams() {
        let rows = vec![tenant_row(0.0, 1.0)];
        let err = render_gateway_accounting(&rows, 10).unwrap_err();
        assert!(err.to_string().contains("flush"), "{err}");
    }
}
